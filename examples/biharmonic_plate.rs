//! Biharmonic plate, end to end through the jet subsystem:
//! build → plan (compile-once) → sharded execute → residual vs the exact
//! solution.
//!
//! The manufactured solution `u*(z) = sin(w·z + φ)` is representable
//! *exactly* as a graph (`Linear → Sin → Linear`), so the jet-computed
//! `Δ²u*` must match the closed-form source `f = |w|⁴·u*` to machine
//! precision — a true end-to-end check of basis assembly, program
//! compilation, and sharded execution. A randomly initialized MLP is then
//! pushed through the same pipeline to show the serving-shaped path
//! (compile once, execute per batch, bit-identical across thread counts).
//!
//! ```sh
//! cargo run --release --example biharmonic_plate
//! ```

use dof::graph::{builder::random_layers, mlp_graph, Act, Graph};
use dof::parallel::{Pool, DEFAULT_SHARD_ROWS};
use dof::pde::{biharmonic_plate, ExactSolution};
use dof::tensor::Tensor;
use dof::util::{fmt_bytes, fmt_duration, Xoshiro256};

fn main() {
    let d = 3;
    let problem = biharmonic_plate(d);
    println!(
        "problem: {} — Δ²u = f on [0,1]^{d}, operator order {}, {} jet directions (d² = {})",
        problem.name,
        problem.operator.order(),
        problem.operator.directions(),
        d * d
    );

    // ---- exact-solution graph: u*(z) = amp·sin(w·z + phase) -------------
    let (w, phase, amp) = match &problem.exact {
        ExactSolution::SineWave { w, phase, amp } => (w.clone(), *phase, *amp),
        _ => unreachable!("biharmonic plate ships a sine solution"),
    };
    let mut exact_graph = Graph::new();
    let x = exact_graph.input(d);
    let lin = exact_graph.linear(x, Tensor::from_vec(&[1, d], w), vec![phase]);
    let act = exact_graph.activation(lin, Act::Sin);
    exact_graph.linear(act, Tensor::from_vec(&[1, 1], vec![amp]), vec![0.0]);

    // ---- plan once ------------------------------------------------------
    let engine = problem.operator.jet_engine();
    let t0 = std::time::Instant::now();
    let program = engine.plan(&exact_graph);
    println!(
        "compiled jet program in {}: {} steps ({} fused), {} slab scalars/row, \
         {} muls/row and {} peak/row analytic",
        fmt_duration(t0.elapsed().as_secs_f64()),
        program.steps().len(),
        program.fused_steps(),
        program.slab_per_row(),
        program.cost(1).muls,
        fmt_bytes(program.peak_jet_bytes(1)),
    );

    // ---- sharded execute: residual of the exact solution ----------------
    let mut rng = Xoshiro256::new(5);
    let z = Tensor::rand_uniform(&[64, d], 0.0, 1.0, &mut rng);
    let pool = Pool::from_env();
    let res = engine.execute_sharded(&program, &exact_graph, &z, &pool, DEFAULT_SHARD_ROWS);
    let f = problem.source_batch(&z);
    let mut max_rel: f64 = 0.0;
    for b in 0..64 {
        let got = res.operator_values.at(b, 0);
        let want = f.at(b, 0);
        max_rel = max_rel.max((got - want).abs() / want.abs().max(1.0));
    }
    println!(
        "exact-solution residual max|Δ²u* − f|/|f| = {max_rel:.2e} over 64 points \
         ({} threads)",
        pool.threads()
    );
    assert!(max_rel < 1e-9, "jet Δ² must match the manufactured source");

    // ---- determinism: 1 vs 4 threads, bit for bit -----------------------
    let serial = engine.execute_sharded(&program, &exact_graph, &z, &Pool::new(1), 8);
    let par = engine.execute_sharded(&program, &exact_graph, &z, &Pool::new(4), 8);
    assert_eq!(serial.operator_values, par.operator_values);
    assert_eq!(serial.cost, par.cost);
    println!("determinism: 1-thread and 4-thread Δ²u* bit-identical ✓");

    // ---- an MLP through the same serving-shaped pipeline ----------------
    // (What a trained plate PINN would execute: compile once, run batches.)
    let model_graph = mlp_graph(&random_layers(&[d, 32, 32, 1], &mut rng), Act::Tanh);
    let t1 = std::time::Instant::now();
    let mprog = engine.plan(&model_graph);
    let compile = t1.elapsed().as_secs_f64();
    let t2 = std::time::Instant::now();
    let mres = engine.execute_sharded(&mprog, &model_graph, &z, &pool, DEFAULT_SHARD_ROWS);
    let exec = t2.elapsed().as_secs_f64();
    // Residual of an untrained net is just a magnitude readout — the point
    // is the pipeline shape and the exact instrumentation.
    let mut l2 = 0.0;
    for b in 0..64 {
        let r = mres.operator_values.at(b, 0) - f.at(b, 0);
        l2 += r * r;
    }
    println!(
        "untrained MLP: compile {} once, execute batch-64 in {} — \
         ‖Δ²φ − f‖₂ = {:.3e}, {} muls (exact), peak {}",
        fmt_duration(compile),
        fmt_duration(exec),
        (l2 / 64.0).sqrt(),
        mres.cost.muls,
        fmt_bytes(mres.peak_jet_bytes),
    );
    println!("\nbiharmonic_plate OK — jet Δ² exact end to end");
}
