//! Non-homogeneous heat equation via a DOF-trained PINN.
//!
//! `u_t = Δ_x u + q(x, t)` on `[0,1]² × [0,1]`, written as `L[u] = f` with
//! `A = diag(1,1,0)` — a *naturally rank-deficient* operator, so DOF's
//! tangent width is 2 instead of 3 for free (§2.2 low-rank).
//!
//! ```sh
//! cargo run --release --example heat_equation [-- --steps 400]
//! ```

use dof::graph::Act;
use dof::nn::{Mlp, MlpSpec};
use dof::pde::heat_equation;
use dof::pde::trainer::{PinnConfig, PinnTrainer};
use dof::train::AdamConfig;
use dof::util::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize_or("steps", 400);

    let problem = heat_equation(2);
    println!(
        "problem: {} | N = {} | rank(A) = {} (DOF tangent width)",
        problem.name,
        problem.operator.n(),
        problem.operator.rank()
    );

    let model = Mlp::init(
        MlpSpec {
            in_dim: 3,
            hidden: args.usize_or("hidden", 48),
            layers: args.usize_or("layers", 3),
            out_dim: 1,
            act: Act::Tanh,
        },
        args.u64_or("seed", 0),
    );
    println!(
        "model: MLP 3→{}×{}→1 ({} params)",
        model.spec.hidden,
        model.spec.layers,
        model.spec.param_count()
    );

    let cfg = PinnConfig {
        interior_batch: args.usize_or("batch", 128),
        boundary_batch: 64,
        boundary_weight: 10.0,
        adam: AdamConfig {
            lr: args.f64_or("lr", 2e-3),
            ..Default::default()
        },
        seed: 0,
    };
    let mut trainer = PinnTrainer::new(problem, model, cfg);

    println!("\nstep   residual      boundary      total");
    for step in 0..steps {
        let r = trainer.train_step();
        if step % (steps / 10).max(1) == 0 || step + 1 == steps {
            println!(
                "{:>5}  {:.4e}   {:.4e}   {:.4e}",
                r.step, r.residual_loss, r.boundary_loss, r.total_loss
            );
        }
    }
    let err = trainer.rel_l2_error(4096);
    println!("\nrelative L2 error vs manufactured solution: {err:.4e}");
    assert!(err.is_finite());
    println!("heat_equation OK");
}
