//! Klein–Gordon equation via a DOF-trained PINN.
//!
//! `u_tt − Δ_x u + m² u = f` on `[0,1] × [0,1]`: the coefficient matrix
//! `A = diag(−1, +1)` is **indefinite** — the "general operator" class that
//! motivates DOF over Forward Laplacian (which only handles `A = I`). The
//! decomposition produces `D = diag(−1, +1)` and the forward pass contracts
//! tangents through those signs.
//!
//! ```sh
//! cargo run --release --example klein_gordon [-- --steps 400]
//! ```

use dof::graph::Act;
use dof::nn::{Mlp, MlpSpec};
use dof::pde::klein_gordon;
use dof::pde::trainer::{PinnConfig, PinnTrainer};
use dof::train::AdamConfig;
use dof::util::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize_or("steps", 400);
    let mass = args.f64_or("mass", 1.0);

    let problem = klein_gordon(1, mass);
    println!(
        "problem: {} | A signs: +{} / −{} (indefinite) | c = m² = {}",
        problem.name,
        problem.operator.ldl.positive_directions(),
        problem.operator.rank() - problem.operator.ldl.positive_directions(),
        mass * mass
    );

    let model = Mlp::init(
        MlpSpec {
            in_dim: 2,
            hidden: args.usize_or("hidden", 48),
            layers: args.usize_or("layers", 3),
            out_dim: 1,
            act: Act::Tanh,
        },
        args.u64_or("seed", 0),
    );

    let cfg = PinnConfig {
        interior_batch: args.usize_or("batch", 128),
        boundary_batch: 64,
        boundary_weight: 10.0,
        adam: AdamConfig {
            lr: args.f64_or("lr", 2e-3),
            ..Default::default()
        },
        seed: 0,
    };
    let mut trainer = PinnTrainer::new(problem, model, cfg);

    println!("\nstep   residual      boundary      total");
    for step in 0..steps {
        let r = trainer.train_step();
        if step % (steps / 10).max(1) == 0 || step + 1 == steps {
            println!(
                "{:>5}  {:.4e}   {:.4e}   {:.4e}",
                r.step, r.residual_loss, r.boundary_loss, r.total_loss
            );
        }
    }
    let err = trainer.rel_l2_error(4096);
    println!("\nrelative L2 error vs manufactured solution: {err:.4e}");
    assert!(err.is_finite());
    println!("klein_gordon OK");
}
