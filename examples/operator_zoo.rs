//! Operator zoo: every coefficient-matrix class from Table 4 plus the PDE
//! operators, evaluated with both engines on both architectures — a
//! correctness × cost panorama of the whole operator space.
//!
//! ```sh
//! cargo run --release --example operator_zoo
//! ```

use dof::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act};
use dof::operators::{CoeffSpec, Operator};
use dof::pde::{fokker_planck, heat_equation, klein_gordon, poisson};
use dof::tensor::Tensor;
use dof::util::{fmt_bytes, Xoshiro256};

fn check(name: &str, op: &Operator, graph: &dof::graph::Graph, x: &Tensor) {
    let dof_r = op.dof_engine().compute(graph, x);
    let hes_r = op.hessian_engine().compute(graph, x);
    let mut max_rel: f64 = 0.0;
    for b in 0..x.dims()[0] {
        let d = dof_r.operator_values.at(b, 0);
        let h = hes_r.operator_values.at(b, 0);
        max_rel = max_rel.max((d - h).abs() / h.abs().max(1.0));
    }
    println!(
        "  {:<22} rank {:>2}/{:<2} | agree {:.1e} | FLOP ratio {:>5.1}× | mem {:>9} vs {:<9}",
        name,
        op.rank(),
        op.n(),
        max_rel,
        hes_r.cost.muls as f64 / dof_r.cost.muls as f64,
        fmt_bytes(dof_r.peak_tangent_bytes),
        fmt_bytes(hes_r.peak_tangent_bytes),
    );
    assert!(max_rel < 1e-7, "{name}: engines disagree");
}

fn main() {
    let mut rng = Xoshiro256::new(1);

    println!("=== plain MLP (16 → 48×3 → 1) ===");
    let n = 16;
    let graph = mlp_graph(&random_layers(&[n, 48, 48, 48, 1], &mut rng), Act::Tanh);
    let x = Tensor::randn(&[4, n], &mut rng);
    for (name, spec) in [
        ("identity (Laplacian)", CoeffSpec::Identity { n }),
        ("elliptic gram", CoeffSpec::EllipticGram { n, rank: n, seed: 3 }),
        ("low-rank r=8", CoeffSpec::EllipticGram { n, rank: 8, seed: 3 }),
        ("low-rank r=2", CoeffSpec::EllipticGram { n, rank: 2, seed: 3 }),
        ("general signed", CoeffSpec::SignedDiag { n }),
    ] {
        check(name, &Operator::from_spec(spec), &graph, &x);
    }

    println!("\n=== Jacobian-sparse MLP (4 blocks × 4 → 32×2 → 4) ===");
    let blocks: Vec<_> = (0..4)
        .map(|_| random_layers(&[4, 32, 32, 4], &mut rng))
        .collect();
    let sgraph = sparse_mlp_graph(&blocks, Act::Tanh);
    let sx = Tensor::randn(&[4, 16], &mut rng).scale(0.5);
    for (name, spec) in [
        (
            "block elliptic",
            CoeffSpec::BlockDiagGram { blocks: 4, block: 4, rank: 4, seed: 5 },
        ),
        (
            "block low-rank r=2",
            CoeffSpec::BlockDiagGram { blocks: 4, block: 4, rank: 2, seed: 5 },
        ),
        (
            "block general",
            CoeffSpec::BlockDiagSigned { blocks: 4, block: 4 },
        ),
    ] {
        check(name, &Operator::from_spec(spec), &sgraph, &sx);
    }

    println!("\n=== PDE operators (on matching-dim MLPs) ===");
    for problem in [
        poisson(6),
        heat_equation(5),
        klein_gordon(5, 1.0),
        fokker_planck(6, 9),
    ] {
        let nn = problem.operator.n();
        let g = mlp_graph(&random_layers(&[nn, 32, 32, 1], &mut rng), Act::Tanh);
        let xx = Tensor::rand_uniform(&[4, nn], 0.0, 1.0, &mut rng);
        check(&problem.name, &problem.operator, &g, &xx);
    }

    println!("\noperator_zoo OK — every operator class exact on both engines");
}
