//! Operator zoo: every coefficient-matrix class from Table 4 plus the PDE
//! operators, evaluated with both engines on both architectures — a
//! correctness × cost panorama of the whole operator space.
//!
//! ```sh
//! cargo run --release --example operator_zoo
//! ```

use dof::autodiff::DofEngine;
use dof::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act};
use dof::operators::{CoeffSpec, HigherOrderOperator, HigherOrderSpec, Operator};
use dof::pde::{fokker_planck, heat_equation, klein_gordon, poisson};
use dof::tensor::Tensor;
use dof::util::{fmt_bytes, Xoshiro256};

fn check(name: &str, op: &Operator, graph: &dof::graph::Graph, x: &Tensor) {
    let dof_r = op.dof_engine().compute(graph, x);
    let hes_r = op.hessian_engine().compute(graph, x);
    let mut max_rel: f64 = 0.0;
    for b in 0..x.dims()[0] {
        let d = dof_r.operator_values.at(b, 0);
        let h = hes_r.operator_values.at(b, 0);
        max_rel = max_rel.max((d - h).abs() / h.abs().max(1.0));
    }
    println!(
        "  {:<22} rank {:>2}/{:<2} | agree {:.1e} | FLOP ratio {:>5.1}× | mem {:>9} vs {:<9}",
        name,
        op.rank(),
        op.n(),
        max_rel,
        hes_r.cost.muls as f64 / dof_r.cost.muls as f64,
        fmt_bytes(dof_r.peak_tangent_bytes),
        fmt_bytes(hes_r.peak_tangent_bytes),
    );
    assert!(max_rel < 1e-7, "{name}: engines disagree");
}

fn main() {
    let mut rng = Xoshiro256::new(1);

    println!("=== plain MLP (16 → 48×3 → 1) ===");
    let n = 16;
    let graph = mlp_graph(&random_layers(&[n, 48, 48, 48, 1], &mut rng), Act::Tanh);
    let x = Tensor::randn(&[4, n], &mut rng);
    for (name, spec) in [
        ("identity (Laplacian)", CoeffSpec::Identity { n }),
        ("elliptic gram", CoeffSpec::EllipticGram { n, rank: n, seed: 3 }),
        ("low-rank r=8", CoeffSpec::EllipticGram { n, rank: 8, seed: 3 }),
        ("low-rank r=2", CoeffSpec::EllipticGram { n, rank: 2, seed: 3 }),
        ("general signed", CoeffSpec::SignedDiag { n }),
    ] {
        check(name, &Operator::from_spec(spec), &graph, &x);
    }

    println!("\n=== Jacobian-sparse MLP (4 blocks × 4 → 32×2 → 4) ===");
    let blocks: Vec<_> = (0..4)
        .map(|_| random_layers(&[4, 32, 32, 4], &mut rng))
        .collect();
    let sgraph = sparse_mlp_graph(&blocks, Act::Tanh);
    let sx = Tensor::randn(&[4, 16], &mut rng).scale(0.5);
    for (name, spec) in [
        (
            "block elliptic",
            CoeffSpec::BlockDiagGram { blocks: 4, block: 4, rank: 4, seed: 5 },
        ),
        (
            "block low-rank r=2",
            CoeffSpec::BlockDiagGram { blocks: 4, block: 4, rank: 2, seed: 5 },
        ),
        (
            "block general",
            CoeffSpec::BlockDiagSigned { blocks: 4, block: 4 },
        ),
    ] {
        check(name, &Operator::from_spec(spec), &sgraph, &sx);
    }

    println!("\n=== PDE operators (on matching-dim MLPs) ===");
    for problem in [
        poisson(6),
        heat_equation(5),
        klein_gordon(5, 1.0),
        fokker_planck(6, 9),
    ] {
        let nn = problem.operator.n();
        let g = mlp_graph(&random_layers(&[nn, 32, 32, 1], &mut rng), Act::Tanh);
        let xx = Tensor::rand_uniform(&[4, nn], 0.0, 1.0, &mut rng);
        check(&problem.name, &problem.operator, &g, &xx);
    }

    println!("\n=== order-4 operators (jet subsystem, MLP 5 → 24×2 → 1) ===");
    let n4 = 5;
    let g4 = mlp_graph(&random_layers(&[n4, 24, 24, 1], &mut rng), Act::Tanh);
    let x4 = Tensor::randn(&[3, n4], &mut rng).scale(0.5);
    let bih = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: n4 });
    let bih_r = bih.jet_engine().compute(&g4, &x4);
    // Internal consistency oracle: Δ²φ from jets vs the second central
    // difference of the exactly-computed DofEngine Laplacian.
    let lap_engine = DofEngine::new(&Tensor::eye(n4));
    let h = 1e-4;
    let mut max_rel: f64 = 0.0;
    for b in 0..3 {
        let z = x4.row(b);
        let lap = |zz: &[f64]| {
            lap_engine
                .compute(&g4, &Tensor::from_vec(&[1, n4], zz.to_vec()))
                .operator_values
                .item()
        };
        let center = lap(z);
        let mut fd = 0.0;
        for i in 0..n4 {
            let mut zp = z.to_vec();
            let mut zm = z.to_vec();
            zp[i] += h;
            zm[i] -= h;
            fd += (lap(&zp) - 2.0 * center + lap(&zm)) / (h * h);
        }
        let got = bih_r.operator_values.at(b, 0);
        max_rel = max_rel.max((got - fd).abs() / fd.abs().max(1.0));
    }
    println!(
        "  {:<22} order {} | {:>3} dirs (d²={}) | vs FD-of-DOF oracle {max_rel:.1e} | \
         {} muls | peak {}",
        bih.label,
        bih.order(),
        bih.directions(),
        n4 * n4,
        bih_r.cost.muls,
        fmt_bytes(bih_r.peak_jet_bytes),
    );
    assert!(max_rel < 1e-5, "biharmonic disagrees with the FD oracle");

    // Composite specs decompose exactly: L_SH = −Δ² − 2Δ + (r−1)·id and
    // L_KS = −Δ² − Δ, checked against the parts (jet Δ², DOF Δ).
    let lap_r = lap_engine.compute(&g4, &x4);
    for (spec, parts) in [
        (
            HigherOrderSpec::SwiftHohenberg { d: n4, r: 0.3 },
            [-1.0, -2.0, 0.3 - 1.0],
        ),
        (HigherOrderSpec::KuramotoSivashinsky { d: n4 }, [-1.0, -1.0, 0.0]),
    ] {
        let op = HigherOrderOperator::from_spec(spec);
        let r = op.jet_engine().compute(&g4, &x4);
        let mut worst: f64 = 0.0;
        for b in 0..3 {
            let want = parts[0] * bih_r.operator_values.at(b, 0)
                + parts[1] * lap_r.operator_values.at(b, 0)
                + parts[2] * r.values.at(b, 0);
            let got = r.operator_values.at(b, 0);
            worst = worst.max((got - want).abs() / want.abs().max(1.0));
        }
        println!(
            "  {:<22} order {} | {:>3} dirs | decomposition agree {worst:.1e}",
            op.label,
            op.order(),
            op.directions(),
        );
        assert!(worst < 1e-9, "{}: composite spec disagrees with parts", op.label);
    }

    println!(
        "\noperator_zoo OK — every operator class exact on both engines, \
         order-4 jets exact vs oracles"
    );
}
