//! Quickstart: compute a general second-order differential operator of a
//! neural network with DOF, and verify it against the Hessian-based
//! baseline and the theory.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dof::autodiff::CostModel;
use dof::graph::{builder::random_layers, mlp_graph, Act};
use dof::operators::{CoeffSpec, Operator};
use dof::tensor::Tensor;
use dof::util::{fmt_bytes, Xoshiro256};

fn main() {
    let mut rng = Xoshiro256::new(0);

    // 1. A neural network φ: R^16 → R (an MLP, but any graph works).
    let n = 16;
    let graph = mlp_graph(&random_layers(&[n, 64, 64, 1], &mut rng), Act::Tanh);
    println!("φ: MLP 16→64→64→1 ({} graph nodes)", graph.len());

    // 2. A second-order operator L = Σ a_ij ∂²_ij with an indefinite A —
    //    the class Forward Laplacian cannot handle and DOF generalizes to.
    let op = Operator::from_spec(CoeffSpec::SignedDiag { n });
    println!(
        "L: general operator, rank(A) = {}, elliptic = {}",
        op.rank(),
        op.ldl.is_elliptic()
    );

    // 3. Compile the operator program ONCE. Everything static per
    //    (architecture, operator) — the fused schedule, the liveness/slab
    //    layout, the §3.2 active tangent rows, the exact FLOP/peak costs —
    //    is derived here and reused for every batch. (The `compute*`
    //    convenience wrappers do this implicitly through the keyed global
    //    plan cache; serving and training get compile-once for free.)
    let engine = op.dof_engine();
    let program = engine.plan(&graph);
    println!(
        "\ncompiled program: {} steps ({} fused Linear→Activation), {} slab scalars/row",
        program.steps().len(),
        program.fused_steps(),
        program.slab_per_row()
    );
    println!(
        "analytic, no execution: {} muls/row, {} peak tangent bytes/row",
        program.cost(1).muls,
        program.peak_tangent_bytes(1)
    );

    // 4. Execute L[φ] on a batch of points — ONE forward pass (eqs. 7–9)
    //    over the precompiled program.
    let x = Tensor::randn(&[4, n], &mut rng);
    let dof = engine.execute(&program, &graph, &x);
    println!("\nDOF (single forward pass):");
    for b in 0..4 {
        println!(
            "  x[{b}]: φ = {:+.6}, L[φ] = {:+.6}",
            dof.values.at(b, 0),
            dof.operator_values.at(b, 0)
        );
    }

    // 5. Cross-check against the Hessian-based method (what standard
    //    AutoDiff does): identical numbers, ~2× the FLOPs, more memory.
    //    The baseline runs on the same compiled machinery: the program
    //    lazily holds its Hessian plan (schedule + static slab layout),
    //    so both sides of the comparison are program-scheduled.
    let hes = op.hessian_engine().compute_with_program(&program, &graph, &x);
    let mut max_diff: f64 = 0.0;
    for b in 0..4 {
        max_diff = max_diff
            .max((dof.operator_values.at(b, 0) - hes.operator_values.at(b, 0)).abs());
    }
    println!("\nHessian-based baseline agrees to {max_diff:.2e}");
    println!(
        "measured FLOPs   : DOF {} vs Hessian {}  (ratio {:.2}×)",
        dof.cost.muls,
        hes.cost.muls,
        hes.cost.muls as f64 / dof.cost.muls as f64
    );
    println!(
        "peak tangent mem : DOF {} vs Hessian {}  (ratio {:.2}×)",
        fmt_bytes(dof.peak_tangent_bytes),
        fmt_bytes(hes.peak_tangent_bytes),
        hes.peak_tangent_bytes as f64 / dof.peak_tangent_bytes as f64
    );

    // 6. The analytic model (Appendix B) predicts the same — also carried
    //    on the program itself (program.analytics()).
    let model = CostModel::new(&graph, op.rank());
    println!(
        "analytic (App. B): Hessian {} muls, DOF {} muls (ratio {:.2}×)",
        model.hessian_muls(),
        model.dof_muls(),
        model.predicted_ratio()
    );
    assert_eq!(program.analytics().dof_muls_model, model.dof_muls());

    // 7. Low-rank operators shrink the tangent width (§2.2) — rank 4 of 16.
    //    (`compute` = compile-then-run through the global plan cache.)
    let lowrank = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: 4, seed: 1 });
    let lr = lowrank.dof_engine().compute(&graph, &x);
    println!(
        "\nlow-rank (r=4) : {} muls — {:.1}× cheaper than full-rank DOF",
        lr.cost.muls,
        dof.cost.muls as f64 / lr.cost.muls as f64
    );
    println!("\nquickstart OK");
}
