//! END-TO-END VALIDATION DRIVER (recorded in EXPERIMENTS.md).
//!
//! Proves all three layers compose on a real workload:
//!
//! 1. **Rust path** — train a PINN on the 2+1-D non-homogeneous heat
//!    equation for several hundred Adam steps, with the residual computed
//!    by the DOF engine and gradients taken *through* the operator
//!    (third-order AD); log the loss curve and the final relative-L2 error
//!    against the manufactured solution.
//! 2. **XLA path** — train the same PDE through the AOT artifact
//!    `pinn_heat_step.hlo.txt` (jax-lowered loss+grad, Rust-owned Adam),
//!    executing on the PJRT CPU client that the serving stack uses.
//! 3. **Cross-check** — one residual batch evaluated on both the Rust
//!    engine and the `dof_mlp_*` artifact family must agree (done in
//!    `cargo test --test xla_cross_check`; here we verify the loss curves
//!    of both training paths fall).
//!
//! ```sh
//! cargo run --release --example train_pinn_e2e [-- --steps 500]
//! ```

use dof::graph::Act;
use dof::nn::serialize::read_dofw;
use dof::nn::{Mlp, MlpSpec};
use dof::pde::heat_equation;
use dof::pde::trainer::{PinnConfig, PinnTrainer};
use dof::runtime::{ArtifactRegistry, Executor};
use dof::train::{Adam, AdamConfig};
use dof::util::{Args, CsvTable, Xoshiro256};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize_or("steps", 500);
    let out_csv = args.get_or("csv", "target/e2e_loss_curve.csv");

    // ---------------------------------------------------------------
    // Path 1: pure-Rust DOF training (engine + tape + Adam).
    // ---------------------------------------------------------------
    println!("=== path 1: Rust DOF engine training ===");
    let problem = heat_equation(2);
    println!(
        "{}: N = {}, rank(A) = {} (low-rank operator for free)",
        problem.name,
        problem.operator.n(),
        problem.operator.rank()
    );
    let model = Mlp::init(
        MlpSpec {
            in_dim: 3,
            hidden: args.usize_or("hidden", 48),
            layers: args.usize_or("layers", 3),
            out_dim: 1,
            act: Act::Tanh,
        },
        0,
    );
    println!("model: {} params", model.spec.param_count());
    let cfg = PinnConfig {
        interior_batch: 128,
        boundary_batch: 64,
        boundary_weight: 10.0,
        adam: AdamConfig { lr: 2e-3, ..Default::default() },
        seed: 0,
    };
    let mut trainer = PinnTrainer::new(problem, model, cfg);
    let mut curve = CsvTable::new(vec!["step", "rust_residual", "rust_total"]);
    let t0 = std::time::Instant::now();
    let mut rust_losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let r = trainer.train_step();
        rust_losses.push(r.total_loss);
        curve.push(vec![
            r.step.to_string(),
            format!("{:.6e}", r.residual_loss),
            format!("{:.6e}", r.total_loss),
        ]);
        if step % (steps / 10).max(1) == 0 || step + 1 == steps {
            println!(
                "step {:>5}  residual {:.4e}  total {:.4e}",
                r.step, r.residual_loss, r.total_loss
            );
        }
    }
    let rust_secs = t0.elapsed().as_secs_f64();
    let err = trainer.rel_l2_error(4096);
    println!(
        "rust path: {steps} steps in {rust_secs:.1}s ({:.1} steps/s), rel-L2 error {err:.4e}",
        steps as f64 / rust_secs
    );
    // Compile-once in action: every step rebuilds the graph with moved
    // weights, but plan keys are weight-value independent, so the operator
    // program compiled at step 1 served every later step from the cache.
    let plan_stats = PinnTrainer::plan_stats();
    println!(
        "plan cache: {} compile(s), {} hits over {steps} steps",
        plan_stats.misses, plan_stats.hits
    );
    anyhow::ensure!(
        plan_stats.hits >= steps as u64 - 1,
        "training should hit the plan cache from step 2 onward: {plan_stats:?}"
    );
    let first5: f64 = rust_losses[..5].iter().sum::<f64>() / 5.0;
    let last5: f64 = rust_losses[steps - 5..].iter().sum::<f64>() / 5.0;
    anyhow::ensure!(
        last5 < 0.2 * first5,
        "rust loss should drop ≥5×: {first5:.3e} → {last5:.3e}"
    );

    // ---------------------------------------------------------------
    // Path 2: XLA artifact training (jax-lowered step, Rust Adam).
    // ---------------------------------------------------------------
    println!("\n=== path 2: XLA pinn_heat_step artifact training ===");
    match ArtifactRegistry::open(args.get_or("artifacts", "artifacts")) {
        Err(e) => {
            println!("skipping XLA path ({e}); run `make artifacts` first");
        }
        Ok(reg) => {
            let mut exec = Executor::cpu()?;
            exec.load("pinn_heat_step", &reg.path("pinn_heat_step")?)?;
            let theta0 = read_dofw(reg.dir.join("pinn_heat_theta0.dofw"))?;
            let mut theta: Vec<f32> =
                theta0[0].tensor.data().iter().map(|&v| v as f32).collect();
            let p = theta.len();
            let batch = reg.batch_of("pinn_heat_step").unwrap_or(128);
            println!("artifact: θ ∈ R^{p}, batch {batch}");

            let mut adam = Adam::new(p, AdamConfig { lr: 2e-3, ..Default::default() });
            let mut rng = Xoshiro256::new(1);
            let xla_steps = args.usize_or("xla-steps", steps.min(300));
            let t1 = std::time::Instant::now();
            let mut first_loss = 0.0f32;
            let mut last_loss = 0.0f32;
            let mut params64 = vec![0.0f64; p];
            let mut grads64 = vec![0.0f64; p];
            for step in 0..xla_steps {
                let x: Vec<f32> =
                    (0..batch * 3).map(|_| rng.next_f64() as f32).collect();
                let outs =
                    exec.run_f32("pinn_heat_step", &[(&theta, &[p]), (&x, &[batch, 3])])?;
                let loss = outs[0][0];
                if step == 0 {
                    first_loss = loss;
                }
                last_loss = loss;
                for (d, &s) in params64.iter_mut().zip(&theta) {
                    *d = s as f64;
                }
                for (d, &s) in grads64.iter_mut().zip(&outs[1]) {
                    *d = s as f64;
                }
                adam.step(&mut params64, &grads64);
                for (d, &s) in theta.iter_mut().zip(&params64) {
                    *d = s as f32;
                }
                if step % (xla_steps / 10).max(1) == 0 || step + 1 == xla_steps {
                    println!("step {:>5}  residual loss {:.4e}", step, loss);
                }
            }
            let xla_secs = t1.elapsed().as_secs_f64();
            println!(
                "xla path: {xla_steps} steps in {xla_secs:.1}s ({:.1} steps/s), loss {first_loss:.3e} → {last_loss:.3e}",
                xla_steps as f64 / xla_secs
            );
            anyhow::ensure!(
                (last_loss as f64) < 0.5 * first_loss as f64,
                "xla loss should drop ≥2×"
            );
        }
    }

    curve.write_to(&out_csv)?;
    println!("\nloss curve written to {out_csv}");
    println!("train_pinn_e2e OK — all layers compose");
    Ok(())
}
