"""AOT pipeline: lower every serving entry point to HLO **text** in
``artifacts/``.

HLO text — not ``lowered.compiler_ir().serialize()`` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the published xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Artifacts (batch sizes fixed at lowering time; the Rust batcher pads):

    dof_mlp_{elliptic,lowrank,general}.hlo.txt      x[B,64] -> (phi, Lphi)
    hessian_mlp_{elliptic,lowrank,general}.hlo.txt  x[B,64] -> (phi, Lphi)
    dof_sparse_{elliptic,lowrank,general}.hlo.txt   x[B,64] -> (phi, Lphi)
    hessian_sparse_general.hlo.txt                  x[B,64] -> (phi, Lphi)
    pinn_heat_step.hlo.txt             (theta[P], x[B,3]) -> (loss, grad[P])
    mlp_weights.dofw / sparse_weights.dofw / coeff_*.dofw / manifest.txt

Weights are baked into the operator artifacts as constants (the serving
path evaluates a fixed trained/initialized model); the PINN step keeps
parameters as a runtime argument so Rust owns the optimizer loop.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import coeffs
from .decomp import ldl_decompose
from .dof_engine import dof_mlp, dof_sparse, sparse_blocks_from_a
from .hessian_engine import hessian_operator_mlp, hessian_operator_sparse
from .model import init_mlp, init_sparse, mlp_entries, write_dofw, make_heat_step

# Serving batch for the operator artifacts.
BATCH = 32
SEED = 7
# Reduced serving copies of the Table 3 architectures: same input dim and
# depth structure, narrower hidden width so Hessian-baseline artifacts
# compile in seconds (width does not change who-wins, only constants).
MLP_DIMS = [64, 128, 128, 128, 1]
SPARSE_BLOCKS = 16
SPARSE_BLOCK_DIMS = [4, 32, 32, 8]
HEAT_DIMS = [3, 32, 32, 1]
HEAT_BATCH = 128


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default printer elides big arrays as `constant({...})`,
    # silently dropping baked weights from the text round-trip. Print with
    # full constants so the Rust loader reconstructs the exact module.
    # Metadata must be off: jax 0.8 emits `source_end_line` etc., which the
    # 0.5.1-era parser in the rust-side XLA rejects.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_to(path: str, fn, *example_args) -> None:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1024:.0f} KiB)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts directory (default: ../artifacts)")
    ap.add_argument("--skip-sparse-hessian", action="store_true",
                    help="skip the slow dense-Hessian sparse artifacts")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)

    manifest = []

    # ---- weights ----------------------------------------------------------
    mlp_params = init_mlp(MLP_DIMS, SEED)
    write_dofw(os.path.join(outdir, "mlp_weights.dofw"), mlp_entries(mlp_params))
    manifest.append(f"mlp_weights.dofw dims={MLP_DIMS} act=tanh seed={SEED}")

    sparse_params = init_sparse(SPARSE_BLOCKS, SPARSE_BLOCK_DIMS, SEED)
    sparse_entries = []
    for bi, stack in enumerate(sparse_params):
        for li, (w, b) in enumerate(stack):
            sparse_entries.append((f"blk{bi}_w{li}", np.asarray(w, np.float64)))
            sparse_entries.append(
                (f"blk{bi}_b{li}", np.asarray(b, np.float64).reshape(-1, 1)))
    write_dofw(os.path.join(outdir, "sparse_weights.dofw"), sparse_entries)
    manifest.append(
        f"sparse_weights.dofw blocks={SPARSE_BLOCKS} dims={SPARSE_BLOCK_DIMS}")

    # ---- coefficient matrices --------------------------------------------
    mlp_ops = coeffs.table4_mlp(SEED)
    sparse_ops = coeffs.table4_sparse(SEED)
    for name, a in {**{f"mlp_{k}": v for k, v in mlp_ops.items()},
                    **{f"sparse_{k}": v for k, v in sparse_ops.items()}}.items():
        write_dofw(os.path.join(outdir, f"coeff_{name}.dofw"), [("a", a)])
        manifest.append(f"coeff_{name}.dofw n={a.shape[0]}")

    xspec = jax.ShapeDtypeStruct((BATCH, 64), jnp.float32)

    # ---- MLP operator artifacts -------------------------------------------
    for op_name, a in mlp_ops.items():
        l_mat, d_signs = ldl_decompose(a)
        l32 = l_mat.astype(np.float32)
        d32 = d_signs.astype(np.float32)

        def dof_fn(x, l32=l32, d32=d32):
            phi, _, s = dof_mlp(mlp_params, x, l32, d32, "tanh",
                                use_kernel=True, interpret=True)
            return phi, s

        lower_to(os.path.join(outdir, f"dof_mlp_{op_name}.hlo.txt"),
                 dof_fn, xspec)
        manifest.append(
            f"dof_mlp_{op_name}.hlo.txt in=x[{BATCH},64]f32 out=(phi,lphi) rank={l32.shape[0]}")

        # jnp-path variant: identical math through pure-XLA einsums instead
        # of the interpret-mode Pallas kernel. On CPU the interpreter's
        # emulation HLO (grid loops, bounds checks) dominates; this variant
        # is the serving-optimal CPU artifact (see EXPERIMENTS.md §Perf).
        def dof_jnp_fn(x, l32=l32, d32=d32):
            phi, _, s = dof_mlp(mlp_params, x, l32, d32, "tanh",
                                use_kernel=False)
            return phi, s

        lower_to(os.path.join(outdir, f"dof_mlp_{op_name}_jnp.hlo.txt"),
                 dof_jnp_fn, xspec)
        manifest.append(
            f"dof_mlp_{op_name}_jnp.hlo.txt in=x[{BATCH},64]f32 out=(phi,lphi) rank={l32.shape[0]}")

        def hes_fn(x, a=a):
            return hessian_operator_mlp(mlp_params, x, a.astype(np.float32))

        lower_to(os.path.join(outdir, f"hessian_mlp_{op_name}.hlo.txt"),
                 hes_fn, xspec)
        manifest.append(
            f"hessian_mlp_{op_name}.hlo.txt in=x[{BATCH},64]f32 out=(phi,lphi)")

    # ---- sparse-architecture artifacts -------------------------------------
    for op_name, a in sparse_ops.items():
        ls, ds = sparse_blocks_from_a(a, SPARSE_BLOCKS)

        def dof_sp_fn(x, ls=ls, ds=ds):
            phi, s = dof_sparse(sparse_params, x, ls, ds, "tanh",
                                use_kernel=False)
            return phi, s

        lower_to(os.path.join(outdir, f"dof_sparse_{op_name}.hlo.txt"),
                 dof_sp_fn, xspec)
        manifest.append(
            f"dof_sparse_{op_name}.hlo.txt in=x[{BATCH},64]f32 out=(phi,lphi)")

    if not args.skip_sparse_hessian:
        a = sparse_ops["general"]

        def hes_sp_fn(x, a=a):
            return hessian_operator_sparse(sparse_params, x,
                                           a.astype(np.float32))

        lower_to(os.path.join(outdir, "hessian_sparse_general.hlo.txt"),
                 hes_sp_fn, xspec)
        manifest.append(
            f"hessian_sparse_general.hlo.txt in=x[{BATCH},64]f32 out=(phi,lphi)")

    # ---- PINN train step ----------------------------------------------------
    step, flat0 = make_heat_step(HEAT_DIMS, "tanh", SEED)
    write_dofw(os.path.join(outdir, "pinn_heat_theta0.dofw"),
               [("theta0", flat0.reshape(-1, 1))])
    tspec = jax.ShapeDtypeStruct((flat0.size,), jnp.float32)
    zspec = jax.ShapeDtypeStruct((HEAT_BATCH, 3), jnp.float32)
    lower_to(os.path.join(outdir, "pinn_heat_step.hlo.txt"), step, tspec, zspec)
    manifest.append(
        f"pinn_heat_step.hlo.txt in=(theta[{flat0.size}],x[{HEAT_BATCH},3])f32 "
        f"out=(loss,grad) dims={HEAT_DIMS}")

    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"  wrote manifest with {len(manifest)} entries")


if __name__ == "__main__":
    sys.exit(main())
