"""Coefficient matrices of Table 4 (NumPy mirror of
``rust/src/operators/coeff.rs``).

All constructions are deterministic in a single integer seed so the same
matrices can be rebuilt on the Rust side for cross-checks.
"""

from __future__ import annotations

import numpy as np


def elliptic_gram(n: int, rank: int, seed: int) -> np.ndarray:
    """a_ij = sum_{k<=rank} alpha_ik alpha_jk, alpha ~ N(0,1) — PSD."""
    rng = np.random.default_rng(seed)
    alpha = rng.standard_normal((n, rank))
    return alpha @ alpha.T


def signed_diag(n: int) -> np.ndarray:
    """diag(s), s_0 = -1, s_i = 1 — the paper's 'general' operator."""
    a = np.eye(n)
    a[0, 0] = -1.0
    return a


def block_diag_gram(blocks: int, block: int, rank: int, seed: int) -> np.ndarray:
    """Block-diagonal Gram (Table 4 row 2, elliptic/low-rank)."""
    rng = np.random.default_rng(seed)
    n = blocks * block
    a = np.zeros((n, n))
    for l in range(blocks):
        sigma = rng.standard_normal((block, rank))
        g = sigma @ sigma.T
        a[l * block:(l + 1) * block, l * block:(l + 1) * block] = g
    return a


def block_diag_signed(blocks: int, block: int) -> np.ndarray:
    """Block-diagonal signed identity (Table 4 row 2, general)."""
    n = blocks * block
    a = np.zeros((n, n))
    for l in range(blocks):
        for i in range(block):
            a[l * block + i, l * block + i] = -1.0 if i == 0 else 1.0
    return a


def table4_mlp(seed: int) -> dict[str, np.ndarray]:
    """The three MLP-experiment matrices (N = 64)."""
    return {
        "elliptic": elliptic_gram(64, 64, seed),
        "lowrank": elliptic_gram(64, 32, seed),
        "general": signed_diag(64),
    }


def table4_sparse(seed: int) -> dict[str, np.ndarray]:
    """The three sparse-experiment matrices (16 blocks x 4)."""
    return {
        "elliptic": block_diag_gram(16, 4, 4, seed),
        "lowrank": block_diag_gram(16, 4, 2, seed),
        "general": block_diag_signed(16, 4),
    }
