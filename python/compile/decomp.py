"""Coefficient-matrix decomposition A = L^T D L (paper section 2.2).

NumPy mirror of ``rust/src/linalg/decomp.rs``: eigendecompose the symmetric
coefficient matrix, keep eigenpairs above a relative tolerance, and return
``L = |Sigma|^{1/2} S`` (rows are scaled eigenvectors) together with the
sign vector ``d`` (+-1 per retained direction). Rank-deficient directions
are dropped, which is what shrinks the DOF tangent width for low-rank
operators.
"""

from __future__ import annotations

import numpy as np

RANK_TOL = 1e-10


def ldl_decompose(a: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Return (L, d) with A = L.T @ diag(d) @ L, L: (r, n), d in {+-1}^r.

    The input is symmetrized first; the operator only sees the symmetric
    part of A.
    """
    a = np.asarray(a, dtype=np.float64)
    assert a.ndim == 2 and a.shape[0] == a.shape[1], "A must be square"
    sym = 0.5 * (a + a.T)
    # eigh returns ascending eigenvalues; sort by |lambda| descending so the
    # retained block is a prefix (matches the rust implementation).
    vals, vecs = np.linalg.eigh(sym)
    order = np.argsort(-np.abs(vals))
    vals = vals[order]
    vecs = vecs[:, order]
    tol = np.abs(vals).max(initial=0.0) * RANK_TOL
    keep = np.abs(vals) > tol
    vals = vals[keep]
    vecs = vecs[:, keep]
    l_mat = (np.sqrt(np.abs(vals))[:, None]) * vecs.T
    d = np.sign(vals)
    d[d == 0] = 1.0
    return l_mat, d


def reconstruct(l_mat: np.ndarray, d: np.ndarray) -> np.ndarray:
    """L.T @ diag(d) @ L — test helper."""
    return l_mat.T @ (d[:, None] * l_mat)
