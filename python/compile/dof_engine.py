"""Layer-2: full-network DOF forward propagation in JAX.

Composes the Layer-1 fused kernel (``kernels.dof_layer``) across an MLP
stack, and implements the block-sparse architecture with *structural*
Jacobian sparsity: per-block tangents carry only that block's rows of L
(section 3.2 of the paper), and with a block-diagonal coefficient matrix
the cross-block terms of eq. 9 vanish identically at the product-sum head.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .decomp import ldl_decompose
from .kernels.dof_layer import dof_layer
from .kernels.ref import dof_layer_ref


def dof_mlp(params, x, l_mat, d_signs, activation="tanh", use_kernel=True,
            interpret=True):
    """DOF pass over an MLP parameter stack.

    Args:
        params: list of (W [M,K], b [M]) pairs; last layer has no activation.
        x: input batch [B, N].
        l_mat: L factor [R, N] (numpy or jnp).
        d_signs: D diagonal [R].
        activation: hidden activation name.
        use_kernel: route hidden layers through the Pallas kernel (True) or
            the pure-jnp reference (False) — numerics must match either way.

    Returns:
        (phi [B, 1], g_out [B, R, out], s_out [B, 1]); ``s_out`` is
        ``sum_ij a_ij d2phi/dx_i dx_j`` (pure second-order part).
    """
    bsz = x.shape[0]
    r = l_mat.shape[0]
    u = x
    g = jnp.broadcast_to(jnp.asarray(l_mat, x.dtype)[None, :, :], (bsz, r, x.shape[1]))
    s = jnp.zeros_like(x)
    d_signs = jnp.asarray(d_signs, x.dtype)

    layer_fn = dof_layer if use_kernel else (
        lambda *a, **k: dof_layer_ref(*a, **{kk: vv for kk, vv in k.items()
                                             if kk == "activation"}))
    n_layers = len(params)
    for i, (w, b) in enumerate(params):
        act_name = activation if i < n_layers - 1 else "identity"
        if use_kernel:
            # Tile sizes: keep the whole feature dim per program unless it
            # exceeds 128 (paper dims are 256 -> two tiles).
            m = w.shape[0]
            bm = m if m <= 128 else 128
            bb = bsz if bsz <= 8 else 8
            # Fall back to full-tensor tiles when shapes do not divide.
            if bsz % bb != 0:
                bb = bsz
            if m % bm != 0:
                bm = m
            u, g, s = dof_layer(u, g, s, jnp.asarray(w, x.dtype),
                                jnp.asarray(b, x.dtype), d_signs,
                                activation=act_name, block_b=bb, block_m=bm,
                                interpret=interpret)
        else:
            u, g, s = dof_layer_ref(u, g, s, jnp.asarray(w, x.dtype),
                                    jnp.asarray(b, x.dtype), d_signs,
                                    activation=act_name)
    return u, g, s


def dof_operator_mlp(params, x, a_mat, activation="tanh", use_kernel=True,
                     interpret=True):
    """Convenience: decompose A and return (phi, L[phi]) for an MLP."""
    l_mat, d_signs = ldl_decompose(np.asarray(a_mat))
    phi, _, s = dof_mlp(params, x, l_mat.astype(np.float32),
                        d_signs.astype(np.float32), activation,
                        use_kernel, interpret)
    return phi, s


def dof_sparse(block_params, x, block_ls, block_ds, activation="tanh",
               use_kernel=False, interpret=True):
    """DOF pass over the Jacobian-sparse architecture (Appendix E).

    output = sum_d prod_i [MLP^i(x_i)]_d, with a *block-diagonal* A:
    per-block tangents only carry that block's L rows (width r_i), and the
    cross-block sigma''-terms of eq. 9 are exactly zero because distinct
    blocks' tangents have disjoint support through D.

    Args:
        block_params: per-block list of (W, b) stacks.
        x: [B, N] with N = sum of block input dims.
        block_ls: per-block L_i [r_i, n_i] (from the block-diagonal A).
        block_ds: per-block D_i signs [r_i].

    Returns:
        (phi [B, 1], s [B, 1]).
    """
    k = len(block_params)
    bsz = x.shape[0]
    n_i = block_ls[0].shape[1]
    # Per-block DOF tuples.
    ys, gs, ss = [], [], []
    for i in range(k):
        xi = x[:, i * n_i:(i + 1) * n_i]
        yi, gi, si = dof_mlp(block_params[i], xi, block_ls[i], block_ds[i],
                             activation, use_kernel, interpret)
        ys.append(yi)   # [B, d_out]
        gs.append(gi)   # [B, r_i, d_out]
        ss.append(si)   # [B, d_out]

    # Product-sum head. For each output index d:
    #   v    = prod_i y_i
    #   s    = sum_i (prod_{j!=i} y_j) s_i   (cross terms vanish: disjoint D)
    # then reduce over d.
    y_stack = jnp.stack(ys, axis=0)              # [k, B, d_out]
    prod_all = jnp.prod(y_stack, axis=0)         # [B, d_out]
    phi = jnp.sum(prod_all, axis=1, keepdims=True)

    s_total = jnp.zeros_like(prod_all)
    for i in range(k):
        # prod_{j != i} y_j — numerically safe leave-one-out product.
        loo = jnp.prod(jnp.concatenate([y_stack[:i], y_stack[i + 1:]], axis=0),
                       axis=0)
        s_total = s_total + loo * ss[i]
    s = jnp.sum(s_total, axis=1, keepdims=True)
    return phi, s


def sparse_blocks_from_a(a_mat: np.ndarray, blocks: int):
    """Split a block-diagonal A into per-block (L_i, D_i) factors."""
    n = a_mat.shape[0]
    nb = n // blocks
    ls, ds = [], []
    for i in range(blocks):
        sub = a_mat[i * nb:(i + 1) * nb, i * nb:(i + 1) * nb]
        l_i, d_i = ldl_decompose(sub)
        ls.append(l_i.astype(np.float32))
        ds.append(d_i.astype(np.float32))
    return ls, ds
