"""Layer-2 baseline: Hessian-based operator evaluation via jax.hessian.

This is what a standard AutoDiff user writes (and what the paper's
baseline measures): materialize H = d2phi/dx2 per point with
forward-over-reverse, then contract with A. Used both as the comparator in
the XLA benches and as ground truth for the DOF engine's unit tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mlp_forward(params, x, activation="tanh"):
    """Plain MLP forward, x [B, N] -> [B, 1]."""
    act = {"tanh": jnp.tanh, "sin": jnp.sin}[activation]
    u = x
    for i, (w, b) in enumerate(params):
        u = u @ w.T + b
        if i < len(params) - 1:
            u = act(u)
    return u


def sparse_forward(block_params, x, activation="tanh"):
    """Jacobian-sparse architecture forward (Appendix E)."""
    k = len(block_params)
    n_i = x.shape[1] // k
    ys = []
    for i in range(k):
        xi = x[:, i * n_i:(i + 1) * n_i]
        ys.append(mlp_forward(block_params[i], xi, activation))
    prod = ys[0]
    for y in ys[1:]:
        prod = prod * y
    return jnp.sum(prod, axis=1, keepdims=True)


def hessian_operator(forward_fn, x, a_mat):
    """L[phi](x) = sum_ij a_ij H_ij via the full per-point Hessian.

    forward_fn maps [N] -> scalar for a single point; vmapped over the
    batch. Returns (phi [B, 1], Lphi [B, 1]).
    """
    a_mat = jnp.asarray(a_mat, x.dtype)

    def scalar_fn(z):
        return forward_fn(z[None, :])[0, 0]

    def per_point(z):
        h = jax.hessian(scalar_fn)(z)
        return scalar_fn(z), jnp.sum(a_mat * h)

    phi, lphi = jax.vmap(per_point)(x)
    return phi[:, None], lphi[:, None]


def hessian_operator_mlp(params, x, a_mat, activation="tanh"):
    return hessian_operator(lambda z: mlp_forward(params, z, activation), x, a_mat)


def hessian_operator_sparse(block_params, x, a_mat, activation="tanh"):
    return hessian_operator(lambda z: sparse_forward(block_params, z, activation),
                            x, a_mat)
