"""Layer-1 Pallas kernel: fused DOF layer propagation.

One kernel invocation advances the whole DOF tuple (u, G, s) through a
Linear+activation layer — eqs. 7-9 specialised to the MLP with the
Appendix C fast path (the sigma'' contraction uses the *output-side*
tangent G1, eq. 23), so the tuple never round-trips to HBM between the
affine map and the activation epilogue.

TPU mapping (see DESIGN.md section Hardware-Adaptation):

* grid over (batch tiles, output-feature tiles); each program owns a
  (bB x bM) output tile of all three streams;
* ``u``/``s``/``G`` tiles and the ``W`` tile are staged into VMEM via
  BlockSpec; the three matmuls (h, G1, s1) hit the MXU with the K axis
  kept whole per program (K <= 256 in all paper configs, so a [bB*R, K] x
  [K, bM] product fits VMEM comfortably);
* the activation epilogue (sigma, sigma', sigma'' * sum_r d_r G1^2) is fused in
  registers/VMEM before the single store per stream.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU numbers are estimated analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import act, act_d, act_d2


def _dof_layer_kernel(u_ref, g_ref, s_ref, w_ref, b_ref, d_ref,
                      uo_ref, go_ref, so_ref, *, activation: str):
    """Pallas program body for one (batch-tile, out-tile) grid cell.

    Block shapes (leading grid axes already sliced away):
        u_ref: [bB, K]     g_ref: [bB, R, K]   s_ref: [bB, K]
        w_ref: [bM, K]     b_ref: [bM]         d_ref: [R]
        uo_ref: [bB, bM]   go_ref: [bB, R, bM] so_ref: [bB, bM]
    """
    u = u_ref[...]
    g = g_ref[...]
    s = s_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    d_signs = d_ref[...]

    bb, r, k = g.shape
    bm = w.shape[0]

    # Affine stage — three MXU matmuls sharing the W tile.
    h = jnp.dot(u, w.T, preferred_element_type=jnp.float32) + b[None, :]
    # Fold (B, R) so the tangent push-through is a single [bB*R, K] @ [K, bM].
    g1 = jnp.dot(g.reshape(bb * r, k), w.T,
                 preferred_element_type=jnp.float32).reshape(bb, r, bm)
    s1 = jnp.dot(s, w.T, preferred_element_type=jnp.float32)

    # Fused epilogue (Appendix C, eq. 23): quad uses the output-side tangent.
    quad = jnp.einsum("r,brm->bm", d_signs, g1 * g1)
    uo_ref[...] = act(activation, h)
    go_ref[...] = act_d(activation, h)[:, None, :] * g1
    so_ref[...] = act_d(activation, h) * s1 + act_d2(activation, h) * quad


def dof_layer(u, g, s, w, b, d_signs, activation: str = "tanh",
              block_b: int = 8, block_m: int = 128, interpret: bool = True):
    """Fused DOF layer via pallas_call.

    Shapes: u [B,K], g [B,R,K], s [B,K], w [M,K], b [M], d_signs [R].
    Returns (u', g', s'): [B,M], [B,R,M], [B,M].

    Grid: (B/bB, M/bM). Tile sizes are clamped to the actual dims; the
    paper configs (K,M <= 256, R <= 64) keep each program's VMEM footprint
    around (bB*R*K + bM*K + bB*R*bM) * 4 bytes ~ a few MB.
    """
    bsz, k = u.shape
    _, r, _ = g.shape
    m = w.shape[0]
    bb = min(block_b, bsz)
    bm = min(block_m, m)
    assert bsz % bb == 0, f"batch {bsz} not divisible by tile {bb}"
    assert m % bm == 0, f"out dim {m} not divisible by tile {bm}"

    grid = (bsz // bb, m // bm)
    kernel = functools.partial(_dof_layer_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),          # u
            pl.BlockSpec((bb, r, k), lambda i, j: (i, 0, 0)),    # g
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),          # s
            pl.BlockSpec((bm, k), lambda i, j: (j, 0)),          # w
            pl.BlockSpec((bm,), lambda i, j: (j,)),              # b
            pl.BlockSpec((r,), lambda i, j: (0,)),               # d_signs
        ],
        out_specs=[
            pl.BlockSpec((bb, bm), lambda i, j: (i, j)),         # u'
            pl.BlockSpec((bb, r, bm), lambda i, j: (i, 0, j)),   # g'
            pl.BlockSpec((bb, bm), lambda i, j: (i, j)),         # s'
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, m), u.dtype),
            jax.ShapeDtypeStruct((bsz, r, m), g.dtype),
            jax.ShapeDtypeStruct((bsz, m), s.dtype),
        ],
        interpret=interpret,
    )(u, g, s, w, b, d_signs)


def vmem_bytes(bb: int, bm: int, k: int, r: int, dtype_bytes: int = 4) -> int:
    """Analytic per-program VMEM footprint of the kernel (DESIGN.md Perf).

    Inputs staged: u (bb*k) + g (bb*r*k) + s (bb*k) + w (bm*k) + b (bm)
    + d (r); outputs: u' (bb*bm) + g' (bb*r*bm) + s' (bb*bm); plus the h/g1
    intermediates (~ outputs again).
    """
    inputs = bb * k * 2 + bb * r * k + bm * k + bm + r
    outputs = bb * bm * 2 + bb * r * bm
    return (inputs + 2 * outputs) * dtype_bytes


def mxu_utilization_estimate(bb: int, bm: int, k: int, r: int) -> float:
    """Fraction of MXU 128x128 tile occupancy for the dominant G1 matmul.

    The folded tangent matmul is [bb*r, k] @ [k, bm]; the MXU prefers both
    output dims >= 128. Utilization ~ min(bb*r,128)/128 * min(bm,128)/128.
    """
    rows = min(bb * r, 128) / 128.0
    cols = min(bm, 128) / 128.0
    return rows * cols
