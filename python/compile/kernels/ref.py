"""Pure-jnp oracle for the fused DOF layer kernel.

The hot-spot of the DOF forward pass is one MLP layer's tuple propagation
(eqs. 7-9 with the Appendix C fast path):

    h  = u @ W.T + b          # pre-activation                    [B, M]
    G1 = G @ W.T              # tangent through the affine map    [B, R, M]
    s1 = s @ W.T              # operator stream through affine    [B, M]
    u' = sigma(h)
    G' = sigma'(h) * G1
    s' = sigma'(h) * s1 + sigma''(h) * sum_r d_r * G1_r^2

This module is the correctness reference the Pallas kernel is tested
against (and is itself validated against jax.hessian in the engine tests).
"""

from __future__ import annotations

import jax.numpy as jnp


def act(name: str, x):
    if name == "tanh":
        return jnp.tanh(x)
    if name == "sin":
        return jnp.sin(x)
    if name == "identity":
        return x
    raise ValueError(f"unknown activation {name}")


def act_d(name: str, x):
    if name == "tanh":
        t = jnp.tanh(x)
        return 1.0 - t * t
    if name == "sin":
        return jnp.cos(x)
    if name == "identity":
        return jnp.ones_like(x)
    raise ValueError(f"unknown activation {name}")


def act_d2(name: str, x):
    if name == "tanh":
        t = jnp.tanh(x)
        return -2.0 * t * (1.0 - t * t)
    if name == "sin":
        return -jnp.sin(x)
    if name == "identity":
        return jnp.zeros_like(x)
    raise ValueError(f"unknown activation {name}")


def dof_layer_ref(u, g, s, w, b, d_signs, activation: str = "tanh"):
    """Reference fused DOF layer.

    Args:
        u: values, [B, K]
        g: tangents, [B, R, K]
        s: operator stream, [B, K]
        w: weights, [M, K]
        b: bias, [M]
        d_signs: D diagonal (+-1), [R]
        activation: sigma name ('identity' = affine-only layer / head)

    Returns:
        (u', g', s') with shapes [B, M], [B, R, M], [B, M].
    """
    h = u @ w.T + b
    g1 = jnp.einsum("brk,mk->brm", g, w)
    s1 = s @ w.T
    quad = jnp.einsum("r,brm->bm", d_signs, g1 * g1)
    u_out = act(activation, h)
    g_out = act_d(activation, h)[:, None, :] * g1
    s_out = act_d(activation, h) * s1 + act_d2(activation, h) * quad
    return u_out, g_out, s_out
