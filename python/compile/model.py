"""Layer-2 model definitions: parameter init, weight export for the Rust
side, and the PINN train-step computation that gets AOT-lowered.

Python never runs at serving time — everything here exists to be lowered
to HLO text by ``aot.py`` or to generate weights consumed by the Rust
coordinator.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .hessian_engine import mlp_forward


def init_mlp(dims, seed: int, scale_mode: str = "lecun"):
    """Random MLP params [(W, b), ...] as float32 numpy arrays."""
    rng = np.random.default_rng(seed)
    params = []
    for k, (n_in, n_out) in enumerate(zip(dims[:-1], dims[1:])):
        std = 1.0 / np.sqrt(n_in) if scale_mode == "lecun" else 1.0
        w = rng.standard_normal((n_out, n_in)).astype(np.float32) * std
        b = (0.1 * rng.standard_normal(n_out)).astype(np.float32)
        params.append((w, b))
        del k
    return params


def init_sparse(blocks: int, block_dims, seed: int):
    """Per-block MLP stacks for the Jacobian-sparse architecture."""
    return [init_mlp(block_dims, seed + 1000 * i) for i in range(blocks)]


# ---------------------------------------------------------------------------
# .dofw weight exchange (mirror of rust/src/nn/serialize.rs)
# ---------------------------------------------------------------------------

def write_dofw(path, entries):
    """entries: list of (name, 2-D float array). Binary payload is f64 LE."""
    header = "dofw v1\n"
    header += f"tensors {len(entries)}\n"
    for name, arr in entries:
        arr = np.asarray(arr)
        assert arr.ndim == 2, f"{name}: dofw stores 2-D tensors"
        header += f"{name} {arr.shape[0]} {arr.shape[1]}\n"
    header += "@\n"
    with open(path, "wb") as f:
        f.write(header.encode())
        for _, arr in entries:
            f.write(np.asarray(arr, dtype="<f8").tobytes())


def read_dofw(path):
    """Inverse of write_dofw; returns list of (name, float64 array)."""
    with open(path, "rb") as f:
        blob = f.read()
    sent = b"\n@\n"
    pos = blob.index(sent)
    lines = blob[:pos].decode().splitlines()
    assert lines[0] == "dofw v1", lines[0]
    count = int(lines[1].split()[1])
    shapes = []
    for line in lines[2:2 + count]:
        name, rows, cols = line.split()
        shapes.append((name, int(rows), int(cols)))
    off = pos + len(sent)
    out = []
    for name, rows, cols in shapes:
        n = rows * cols
        arr = np.frombuffer(blob, dtype="<f8", count=n, offset=off).reshape(rows, cols)
        off += n * 8
        out.append((name, arr))
    return out


def mlp_entries(params):
    """(W,b) stack -> dofw entries, biases as column vectors."""
    entries = []
    for i, (w, b) in enumerate(params):
        entries.append((f"w{i}", np.asarray(w, dtype=np.float64)))
        entries.append((f"b{i}", np.asarray(b, dtype=np.float64).reshape(-1, 1)))
    return entries


# ---------------------------------------------------------------------------
# PINN train step (heat equation) — AOT-lowered whole, Adam kept in Rust
# ---------------------------------------------------------------------------

def heat_residual_loss(flat_params, unravel, x, activation="tanh"):
    """Residual loss for u_t = Laplacian_x u + q on z = (x_1..x_d, t).

    The manufactured solution is u* = sin(w.z + 0.4) with w = (pi,..,pi,1),
    matching rust/src/pde/problems.rs::heat_equation so both stacks train
    the same problem. Derivatives here are plain JAX autodiff (this is the
    jax-side *baseline* train step; the Rust engine trains through DOF).
    """
    params = unravel(flat_params)
    d = x.shape[1] - 1
    w_vec = jnp.array([jnp.pi] * d + [1.0], dtype=x.dtype)

    def u_fn(z):
        return mlp_forward(params, z[None, :], activation)[0, 0]

    def source(z):
        arg = jnp.dot(w_vec, z) + 0.4
        # L[u*] = sum_i<d (-w_i^2 sin) - w_t cos  (A = diag(1..1,0), b_t=-1)
        lap = -jnp.sum(w_vec[:d] ** 2) * jnp.sin(arg)
        ut = w_vec[d] * jnp.cos(arg)
        return lap - ut

    def residual(z):
        h = jax.hessian(u_fn)(z)
        g = jax.grad(u_fn)(z)
        lap = jnp.trace(h[:d, :d])
        return lap - g[d] - source(z)

    res = jax.vmap(residual)(x)
    return jnp.mean(res ** 2)


def make_heat_step(dims, activation="tanh", seed=0):
    """Build (step_fn, flat_params0): step maps (theta, x) -> (loss, grad)."""
    from jax.flatten_util import ravel_pytree

    params0 = [(jnp.asarray(w), jnp.asarray(b)) for w, b in init_mlp(dims, seed)]
    flat0, unravel = ravel_pytree(params0)

    def step(flat_params, x):
        loss, grad = jax.value_and_grad(heat_residual_loss)(
            flat_params, unravel, x, activation)
        return loss, grad

    return step, np.asarray(flat0)
