"""AOT path tests: HLO-text lowering round-trips and executes with the
same numerics as the traced function (the property the Rust runtime
depends on)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import coeffs
from compile.aot import to_hlo_text
from compile.dof_engine import dof_operator_mlp
from compile.model import init_mlp, mlp_entries, read_dofw, write_dofw


def compile_hlo_text(text: str):
    """Parse HLO text and compile on the CPU client (what Rust does)."""
    client = xc._xla.get_local_backend("cpu")
    comp = xc._xla.hlo_module_from_text(text)
    return client, comp


def test_hlo_text_roundtrip_small_dof():
    params = init_mlp([4, 8, 1], seed=1)
    a = coeffs.elliptic_gram(4, 4, 2)
    x = np.random.default_rng(0).standard_normal((2, 4)).astype(np.float32)

    def fn(x):
        return dof_operator_mlp(params, x, a, use_kernel=True)

    expect_phi, expect_lphi = fn(jnp.asarray(x))
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 4), jnp.float32))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    # The default printer elides big constants as `{...}`, which would
    # silently drop baked weights — the exporter must never emit that.
    assert "{...}" not in text, "HLO text elides large constants"
    np.testing.assert_allclose(np.asarray(expect_phi).shape, (2, 1))
    assert np.all(np.isfinite(np.asarray(expect_lphi)))


def test_dofw_roundtrip(tmp_path):
    params = init_mlp([3, 5, 1], seed=2)
    p = tmp_path / "w.dofw"
    write_dofw(str(p), mlp_entries(params))
    back = read_dofw(str(p))
    assert [n for n, _ in back] == ["w0", "b0", "w1", "b1"]
    np.testing.assert_allclose(back[0][1], np.asarray(params[0][0], np.float64),
                               rtol=1e-7)


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.txt")),
    reason="artifacts not built (run make artifacts)")
def test_built_artifacts_manifest_complete():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.txt")) as f:
        manifest = f.read()
    for required in [
        "dof_mlp_elliptic.hlo.txt",
        "dof_mlp_lowrank.hlo.txt",
        "dof_mlp_general.hlo.txt",
        "hessian_mlp_elliptic.hlo.txt",
        "dof_sparse_elliptic.hlo.txt",
        "pinn_heat_step.hlo.txt",
        "mlp_weights.dofw",
    ]:
        assert required in manifest, f"missing {required} in manifest"
        assert os.path.exists(os.path.join(root, required)), required


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/mlp_weights.dofw")),
    reason="artifacts not built")
def test_artifact_weights_match_generator():
    """The exported .dofw weights are exactly the seeded init."""
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    from compile.aot import MLP_DIMS, SEED
    params = init_mlp(MLP_DIMS, SEED)
    back = read_dofw(os.path.join(root, "mlp_weights.dofw"))
    np.testing.assert_allclose(back[0][1],
                               np.asarray(params[0][0], np.float64), rtol=1e-7)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
