"""Tests for the A = L^T D L decomposition and Table 4 coefficients."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import coeffs
from compile.decomp import ldl_decompose, reconstruct


def test_identity_decomposition():
    l_mat, d = ldl_decompose(np.eye(5))
    assert l_mat.shape == (5, 5)
    assert np.all(d == 1.0)
    np.testing.assert_allclose(reconstruct(l_mat, d), np.eye(5), atol=1e-12)


def test_low_rank_truncation():
    rng = np.random.default_rng(1)
    b = rng.standard_normal((8, 3))
    a = b @ b.T
    l_mat, d = ldl_decompose(a)
    assert l_mat.shape == (3, 8)
    assert np.all(d == 1.0)
    np.testing.assert_allclose(reconstruct(l_mat, d), a, atol=1e-9)


def test_indefinite_signs():
    a = np.diag([2.0, -1.0, 0.0, 0.5])
    l_mat, d = ldl_decompose(a)
    assert l_mat.shape == (3, 4)
    assert sorted(d) == [-1.0, 1.0, 1.0]
    np.testing.assert_allclose(reconstruct(l_mat, d), a, atol=1e-12)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 12), seed=st.integers(0, 10_000))
def test_random_symmetric_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    b = rng.standard_normal((n, n))
    a = 0.5 * (b + b.T)
    l_mat, d = ldl_decompose(a)
    assert set(np.unique(d)).issubset({-1.0, 1.0})
    np.testing.assert_allclose(reconstruct(l_mat, d), a, atol=1e-8)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 10), rank=st.integers(1, 10), seed=st.integers(0, 100))
def test_gram_rank(n, rank, seed):
    rank = min(rank, n)
    a = coeffs.elliptic_gram(n, rank, seed)
    l_mat, d = ldl_decompose(a)
    assert l_mat.shape[0] == rank
    assert np.all(d == 1.0)


def test_table4_shapes_and_structure():
    m = coeffs.table4_mlp(3)
    assert all(a.shape == (64, 64) for a in m.values())
    l_lr, _ = ldl_decompose(m["lowrank"])
    assert l_lr.shape[0] == 32
    s = coeffs.table4_sparse(3)
    # block-diagonal: off-block entries exactly zero
    a = s["elliptic"]
    assert a[0, 4] == 0.0 and a[10, 2] == 0.0
    l_sp, d_sp = ldl_decompose(s["general"])
    assert l_sp.shape[0] == 64
    assert (d_sp == -1).sum() == 16  # one negative direction per block


def test_quadratic_form_identity():
    """x^T A x == (Lx)^T D (Lx) for random x."""
    rng = np.random.default_rng(5)
    b = rng.standard_normal((7, 7))
    a = 0.5 * (b + b.T)
    l_mat, d = ldl_decompose(a)
    for _ in range(5):
        x = rng.standard_normal(7)
        lx = l_mat @ x
        assert abs(x @ a @ x - lx @ (d * lx)) < 1e-9


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
