"""L2 correctness: full-network DOF (kernel-composed) vs jax.hessian
ground truth, for MLP and the Jacobian-sparse architecture, across the
three Table 4 operator classes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import coeffs
from compile.decomp import ldl_decompose
from compile.dof_engine import dof_mlp, dof_operator_mlp, dof_sparse, sparse_blocks_from_a
from compile.hessian_engine import (hessian_operator_mlp,
                                    hessian_operator_sparse, mlp_forward,
                                    sparse_forward)
from compile.model import init_mlp, init_sparse

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def mlp_setup():
    params = init_mlp([6, 16, 16, 1], seed=0)
    rng = np.random.default_rng(1)
    x = rng.standard_normal((4, 6)).astype(np.float32)
    return params, x


@pytest.mark.parametrize("op_builder", [
    lambda: coeffs.elliptic_gram(6, 6, 2),
    lambda: coeffs.elliptic_gram(6, 3, 2),
    lambda: coeffs.signed_diag(6),
    lambda: np.eye(6),
])
def test_dof_mlp_matches_hessian(mlp_setup, op_builder):
    params, x = mlp_setup
    a = op_builder()
    phi_d, lphi_d = dof_operator_mlp(params, x, a, use_kernel=True)
    phi_h, lphi_h = hessian_operator_mlp(params, x, a.astype(np.float32))
    np.testing.assert_allclose(np.asarray(phi_d), np.asarray(phi_h),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lphi_d), np.asarray(lphi_h),
                               rtol=2e-3, atol=2e-3)


def test_dof_kernel_and_ref_paths_agree(mlp_setup):
    params, x = mlp_setup
    a = coeffs.elliptic_gram(6, 6, 3)
    l_mat, d = ldl_decompose(a)
    l32, d32 = l_mat.astype(np.float32), d.astype(np.float32)
    k = dof_mlp(params, x, l32, d32, use_kernel=True)
    r = dof_mlp(params, x, l32, d32, use_kernel=False)
    for kk, rr in zip(k, r):
        np.testing.assert_allclose(np.asarray(kk), np.asarray(rr),
                                   rtol=2e-5, atol=2e-5)


def test_low_rank_tangent_width(mlp_setup):
    params, x = mlp_setup
    a = coeffs.elliptic_gram(6, 2, 4)
    l_mat, d = ldl_decompose(a)
    assert l_mat.shape[0] == 2
    phi, g, s = dof_mlp(params, x, l_mat.astype(np.float32),
                        d.astype(np.float32))
    assert g.shape == (4, 2, 1)
    # Exactness preserved under rank truncation.
    _, lphi_h = hessian_operator_mlp(params, x, a.astype(np.float32))
    np.testing.assert_allclose(np.asarray(s), np.asarray(lphi_h),
                               rtol=2e-3, atol=2e-3)


def test_dof_gradient_stream_is_l_grad(mlp_setup):
    params, x = mlp_setup
    a = np.eye(6)
    l_mat, d = ldl_decompose(a)
    _, g, _ = dof_mlp(params, x, l_mat.astype(np.float32),
                      d.astype(np.float32))

    def scalar(z):
        return mlp_forward(params, z[None, :])[0, 0]

    grads = jax.vmap(jax.grad(scalar))(jnp.asarray(x))  # [B, 6]
    want = jnp.einsum("rn,bn->br", jnp.asarray(l_mat, jnp.float32), grads)
    np.testing.assert_allclose(np.asarray(g[:, :, 0]), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.fixture(scope="module")
def sparse_setup():
    blocks = 4
    params = init_sparse(blocks, [3, 8, 4], seed=0)
    rng = np.random.default_rng(2)
    x = (0.5 * rng.standard_normal((3, 12))).astype(np.float32)
    return blocks, params, x


@pytest.mark.parametrize("kind", ["elliptic", "lowrank", "general"])
def test_dof_sparse_matches_hessian(sparse_setup, kind):
    blocks, params, x = sparse_setup
    if kind == "elliptic":
        a = coeffs.block_diag_gram(blocks, 3, 3, 5)
    elif kind == "lowrank":
        a = coeffs.block_diag_gram(blocks, 3, 1, 5)
    else:
        a = coeffs.block_diag_signed(blocks, 3)
    ls, ds = sparse_blocks_from_a(a, blocks)
    phi_d, lphi_d = dof_sparse(params, x, ls, ds)
    phi_h, lphi_h = hessian_operator_sparse(params, x, a.astype(np.float32))
    np.testing.assert_allclose(np.asarray(phi_d), np.asarray(phi_h),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lphi_d), np.asarray(lphi_h),
                               rtol=2e-3, atol=2e-3)


def test_sparse_forward_matches_manual(sparse_setup):
    blocks, params, x = sparse_setup
    phi = sparse_forward(params, x)
    # Manual product-sum.
    outs = []
    for i in range(blocks):
        outs.append(np.asarray(mlp_forward(params[i], x[:, 3 * i:3 * i + 3])))
    prod = np.ones_like(outs[0])
    for o in outs:
        prod = prod * o
    want = prod.sum(axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(phi), want, rtol=1e-5, atol=1e-6)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
