"""L1 correctness: the Pallas fused DOF layer kernel vs the pure-jnp oracle.

The CORE kernel-correctness signal: hypothesis sweeps shapes/ranks/
activations/tiles and asserts allclose between pallas (interpret=True) and
ref.py; ref.py itself is validated against jax.hessian in
test_dof_engine.py, closing the chain kernel == ref == ground truth.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dof_layer import dof_layer, mxu_utilization_estimate, vmem_bytes
from compile.kernels.ref import dof_layer_ref


def rand_inputs(rng, bsz, k, m, r):
    u = rng.standard_normal((bsz, k)).astype(np.float32)
    g = rng.standard_normal((bsz, r, k)).astype(np.float32)
    s = rng.standard_normal((bsz, k)).astype(np.float32)
    w = (rng.standard_normal((m, k)) / np.sqrt(k)).astype(np.float32)
    b = (0.1 * rng.standard_normal(m)).astype(np.float32)
    d = rng.choice([-1.0, 1.0], size=r).astype(np.float32)
    return u, g, s, w, b, d


def assert_matches_ref(u, g, s, w, b, d, activation, block_b=8, block_m=128):
    got = dof_layer(u, g, s, w, b, d, activation=activation,
                    block_b=block_b, block_m=block_m, interpret=True)
    want = dof_layer_ref(u, g, s, w, b, d, activation=activation)
    for name, gg, ww in zip(("u'", "g'", "s'"), got, want):
        np.testing.assert_allclose(
            np.asarray(gg), np.asarray(ww), rtol=2e-5, atol=2e-5,
            err_msg=f"stream {name} ({activation})")


def test_basic_tanh_layer():
    rng = np.random.default_rng(0)
    assert_matches_ref(*rand_inputs(rng, 8, 16, 32, 4), "tanh")


def test_identity_head_layer():
    rng = np.random.default_rng(1)
    assert_matches_ref(*rand_inputs(rng, 4, 32, 1, 8), "identity",
                       block_b=4, block_m=1)


def test_multi_tile_grid():
    """Grid with several batch and feature tiles."""
    rng = np.random.default_rng(2)
    u, g, s, w, b, d = rand_inputs(rng, 16, 24, 64, 6)
    assert_matches_ref(u, g, s, w, b, d, "tanh", block_b=4, block_m=32)


@settings(max_examples=25, deadline=None)
@given(
    bsz=st.sampled_from([1, 2, 4, 8]),
    k=st.integers(1, 24),
    m=st.sampled_from([1, 2, 8, 16, 64]),
    r=st.integers(1, 16),
    activation=st.sampled_from(["tanh", "sin", "identity"]),
    seed=st.integers(0, 1000),
)
def test_kernel_matches_ref_swept(bsz, k, m, r, activation, seed):
    rng = np.random.default_rng(seed)
    u, g, s, w, b, d = rand_inputs(rng, bsz, k, m, r)
    assert_matches_ref(u, g, s, w, b, d, activation,
                       block_b=min(8, bsz), block_m=min(128, m))


def test_paper_scale_shapes():
    """The Table 3 layer shape: K=256 -> M=256 at R=64 (one layer)."""
    rng = np.random.default_rng(3)
    u, g, s, w, b, d = rand_inputs(rng, 8, 256, 256, 64)
    assert_matches_ref(u, g, s, w, b, d, "tanh", block_b=8, block_m=128)


def test_chained_layers_stay_consistent():
    """Two kernel layers == two ref layers (error does not compound)."""
    rng = np.random.default_rng(4)
    u, g, s, w1, b1, d = rand_inputs(rng, 4, 12, 20, 5)
    w2 = (rng.standard_normal((8, 20)) / np.sqrt(20)).astype(np.float32)
    b2 = (0.1 * rng.standard_normal(8)).astype(np.float32)
    k1 = dof_layer(u, g, s, w1, b1, d, activation="tanh", block_b=4, block_m=20)
    k2 = dof_layer(*k1, w2, b2, d, activation="identity", block_b=4, block_m=8)
    r1 = dof_layer_ref(u, g, s, w1, b1, d, activation="tanh")
    r2 = dof_layer_ref(*r1, w2, b2, d, activation="identity")
    for gg, ww in zip(k2, r2):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww),
                                   rtol=5e-5, atol=5e-5)


def test_zero_rank_sign_invariance():
    """Flipping a sign with zero tangent rows changes nothing."""
    rng = np.random.default_rng(5)
    u, g, s, w, b, d = rand_inputs(rng, 2, 6, 4, 3)
    g = g.at[:, 2, :].set(0.0) if hasattr(g, "at") else g
    g = np.asarray(g)
    g[:, 2, :] = 0.0
    d2 = d.copy()
    d2[2] = -d2[2]
    out1 = dof_layer(u, jnp.asarray(g), s, w, b, d, activation="tanh",
                     block_b=2, block_m=4)
    out2 = dof_layer(u, jnp.asarray(g), s, w, b, d2, activation="tanh",
                     block_b=2, block_m=4)
    for a_, b_ in zip(out1, out2):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_), atol=1e-6)


def test_vmem_model_sane():
    """Analytic VMEM footprint of the paper-scale tile fits a TPU core."""
    bytes_ = vmem_bytes(bb=8, bm=128, k=256, r=64)
    assert bytes_ < 16 * 1024 * 1024, f"{bytes_} exceeds 16MiB VMEM"
    util = mxu_utilization_estimate(bb=8, bm=128, k=256, r=64)
    assert util == 1.0  # 8*64 >= 128 rows, 128 cols


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
