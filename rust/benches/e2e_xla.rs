//! Bench: the XLA serving path — AOT artifacts (jax/pallas-lowered DOF and
//! Hessian operators) executed via PJRT from the Rust coordinator, plus
//! batching-server throughput/latency.
//!
//! Requires `make artifacts`. Exits 0 with a notice when absent so
//! `cargo bench` works on a fresh clone.
//!
//! ```sh
//! cargo bench --bench e2e_xla
//! ```

use std::time::{Duration, Instant};

use dof::coordinator::ModelServer;
use dof::runtime::{ArtifactRegistry, Executor};
use dof::util::{fmt_duration, CsvTable, Summary, Xoshiro256};

fn median_time(
    exec: &Executor,
    name: &str,
    x: &[f32],
    batch: usize,
    reps: usize,
) -> anyhow::Result<Summary> {
    exec.run_f32(name, &[(x, &[batch, 64])])?; // warmup
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let out = exec.run_f32(name, &[(x, &[batch, 64])])?;
        std::hint::black_box(&out);
        times.push(t0.elapsed().as_secs_f64());
    }
    Ok(Summary::of(&times))
}

fn main() -> anyhow::Result<()> {
    let reg = match ArtifactRegistry::open("artifacts") {
        Ok(r) => r,
        Err(e) => {
            eprintln!("e2e_xla: skipping ({e})");
            return Ok(());
        }
    };
    let reps = 20;
    let mut exec = Executor::cpu()?;
    let mut rng = Xoshiro256::new(31);
    let mut csv = CsvTable::new(vec!["artifact", "median_ms", "p95_ms"]);

    // ---- operator artifact pairs -------------------------------------------
    println!("## XLA artifact wall-clock (PJRT CPU, batch = artifact batch)\n");
    println!("| artifact | median | p95 | vs pair |");
    println!("|----------|--------|-----|---------|");
    let groups: [(&str, Vec<&str>); 2] = [
        (
            "mlp",
            vec![
                "dof_mlp_elliptic",
                "dof_mlp_lowrank",
                "dof_mlp_general",
                "dof_mlp_elliptic_jnp",
                "dof_mlp_lowrank_jnp",
                "dof_mlp_general_jnp",
                "hessian_mlp_elliptic",
                "hessian_mlp_lowrank",
                "hessian_mlp_general",
            ],
        ),
        (
            "sparse",
            vec![
                "dof_sparse_elliptic",
                "dof_sparse_lowrank",
                "dof_sparse_general",
                "hessian_sparse_general",
            ],
        ),
    ];
    let mut medians: std::collections::HashMap<String, f64> = Default::default();
    for (_, names) in &groups {
        for name in names {
            if reg.path(name).is_err() {
                continue;
            }
            let batch = reg.batch_of(name).unwrap_or(32);
            exec.load(name, &reg.path(name)?)?;
            let x: Vec<f32> = (0..batch * 64)
                .map(|_| (0.4 * rng.normal()) as f32)
                .collect();
            let s = median_time(&exec, name, &x, batch, reps)?;
            medians.insert(name.to_string(), s.median);
            let pair_note = if let Some(h) = name.strip_prefix("dof_") {
                medians
                    .get(&format!("hessian_{h}"))
                    .map(|hm| format!("{:.2}×", hm / s.median))
                    .unwrap_or_default()
            } else if let Some(d) = name.strip_prefix("hessian_") {
                medians
                    .get(&format!("dof_{d}"))
                    .map(|dm| format!("dof is {:.2}×", s.median / dm))
                    .unwrap_or_default()
            } else {
                String::new()
            };
            println!(
                "| {name} | {} | {} | {pair_note} |",
                fmt_duration(s.median),
                fmt_duration(s.p95)
            );
            csv.push(vec![
                name.to_string(),
                format!("{:.4}", s.median * 1e3),
                format!("{:.4}", s.p95 * 1e3),
            ]);
        }
    }

    // ---- batching server throughput ---------------------------------------
    println!("\n## Batching-server throughput (dof_mlp_lowrank)\n");
    let artifact = "dof_mlp_lowrank";
    if reg.path(artifact).is_ok() {
        let batch = reg.batch_of(artifact).unwrap_or(32);
        println!("| clients | rows/req | rows/s | mean latency | p95 | batch efficiency |");
        println!("|---------|----------|--------|--------------|-----|------------------|");
        for (clients, rows) in [(1usize, 32usize), (4, 8), (8, 4), (16, 1)] {
            let server = ModelServer::spawn_xla(
                reg.dir.clone(),
                artifact.to_string(),
                64,
                batch,
                Duration::from_millis(2),
            )?;
            let h = server.handle();
            let per_client = 24;
            let t0 = Instant::now();
            let joins: Vec<_> = (0..clients)
                .map(|c| {
                    let h = h.clone();
                    std::thread::spawn(move || {
                        let mut rng = Xoshiro256::new(500 + c as u64);
                        for _ in 0..per_client {
                            let pts: Vec<f32> =
                                (0..rows * 64).map(|_| rng.normal() as f32).collect();
                            h.eval_blocking(pts).expect("eval");
                        }
                    })
                })
                .collect();
            for j in joins {
                j.join().expect("client");
            }
            let wall = t0.elapsed().as_secs_f64();
            let snap = h.metrics.snapshot();
            println!(
                "| {clients} | {rows} | {:.0} | {} | {} | {:.0}% |",
                snap.rows as f64 / wall,
                fmt_duration(snap.mean_latency),
                fmt_duration(snap.p95_latency),
                snap.batch_efficiency * 100.0
            );
            server.shutdown();
        }
    }

    let path = "target/bench_e2e_xla.csv";
    csv.write_to(path)?;
    eprintln!("\nseries written to {path}");
    Ok(())
}
