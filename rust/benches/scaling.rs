//! Bench: theorem sweeps — the FLOP and memory claims (Theorems 2.1/2.2)
//! as *series* over input dimension, depth, width, and operator rank.
//! The paper has no figures; these CSVs are the curves its theorems
//! describe, measured and analytic side by side.
//!
//! ```sh
//! cargo bench --bench scaling
//! ```

use dof::autodiff::{CostModel, DofEngine, HessianEngine, MemoryModel};
use dof::graph::{builder::random_layers, mlp_graph, Act};
use dof::operators::CoeffSpec;
use dof::tensor::Tensor;
use dof::util::{CsvTable, Xoshiro256};

fn engines_at(
    dims: &[usize],
    rank: usize,
    seed: u64,
) -> (u64, u64, u64, u64, f64, f64) {
    let mut rng = Xoshiro256::new(seed);
    let graph = mlp_graph(&random_layers(dims, &mut rng), Act::Tanh);
    let n = dims[0];
    let spec = if rank < n {
        CoeffSpec::EllipticGram { n, rank, seed }
    } else {
        CoeffSpec::EllipticGram { n, rank: n, seed }
    };
    let a = spec.build();
    let x = Tensor::randn(&[1, n], &mut rng);
    let dof = DofEngine::new(&a).compute(&graph, &x);
    let hes = HessianEngine::new(&a).compute(&graph, &x);
    let model = CostModel::new(&graph, rank.min(n));
    (
        dof.cost.muls,
        hes.cost.muls,
        dof.peak_tangent_bytes,
        hes.peak_tangent_bytes,
        model.dof_muls() as f64,
        model.hessian_muls() as f64,
    )
}

fn main() {
    // ---- sweep 1: input dimension N (width fixed) ------------------------
    let mut csv = CsvTable::new(vec![
        "sweep", "param", "dof_muls", "hessian_muls", "flop_ratio",
        "dof_peak_bytes", "hessian_peak_bytes", "mem_ratio",
        "analytic_dof_muls", "analytic_hessian_muls",
    ]);
    println!("## Theorem sweeps\n");
    println!("### FLOP & memory ratio vs input dimension N (hidden 128×4)");
    println!("| N | measured FLOP ratio | analytic | memory ratio |");
    println!("|---|---------------------|----------|--------------|");
    for n in [4usize, 8, 16, 32, 64] {
        let dims = [n, 128, 128, 128, 128, 1];
        let (dm, hm, dp, hp, adm, ahm) = engines_at(&dims, n, 11);
        println!(
            "| {n} | {:.2} | {:.2} | {:.2} |",
            hm as f64 / dm as f64,
            ahm / adm,
            hp as f64 / dp as f64
        );
        csv.push(vec![
            "input_dim".to_string(),
            n.to_string(),
            dm.to_string(),
            hm.to_string(),
            format!("{:.3}", hm as f64 / dm as f64),
            dp.to_string(),
            hp.to_string(),
            format!("{:.3}", hp as f64 / dp as f64),
            format!("{adm:.0}"),
            format!("{ahm:.0}"),
        ]);
    }

    // ---- sweep 2: depth (Theorem 2.2's 2/L memory scaling) ----------------
    println!("\n### Memory ratio vs depth L (Theorem 2.2: M₁/M₂ ≲ 2/L)");
    println!("| L | mem ratio (Hessian/DOF) | 2/L reference |");
    println!("|---|--------------------------|---------------|");
    for depth in [2usize, 4, 8, 12, 16] {
        let mut dims = vec![16usize];
        dims.extend(std::iter::repeat(96).take(depth));
        dims.push(1);
        let (_, _, dp, hp, _, _) = engines_at(&dims, 16, 13);
        println!(
            "| {depth} | {:.2} | {:.2} |",
            hp as f64 / dp as f64,
            depth as f64 / 2.0
        );
        csv.push(vec![
            "depth".to_string(),
            depth.to_string(),
            String::new(),
            String::new(),
            String::new(),
            dp.to_string(),
            hp.to_string(),
            format!("{:.3}", hp as f64 / dp as f64),
            String::new(),
            String::new(),
        ]);
    }

    // ---- sweep 3: operator rank (the low-rank r/N law, §2.2) --------------
    println!("\n### FLOP ratio vs operator rank r (N = 32): DOF cost ∝ r");
    println!("| r | measured FLOP ratio | expected ≈ (2N+1)/(r+2) |");
    println!("|---|---------------------|--------------------------|");
    for rank in [2usize, 4, 8, 16, 32] {
        let dims = [32usize, 128, 128, 128, 1];
        let (dm, hm, _, _, _, _) = engines_at(&dims, rank, 17);
        println!(
            "| {rank} | {:.2} | {:.2} |",
            hm as f64 / dm as f64,
            (2.0 * 32.0 + 1.0) / (rank as f64 + 2.0)
        );
        csv.push(vec![
            "rank".to_string(),
            rank.to_string(),
            dm.to_string(),
            hm.to_string(),
            format!("{:.3}", hm as f64 / dm as f64),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
        ]);
    }

    // ---- sweep 4: analytic liveness profile C(j) (eq. 25) -----------------
    println!("\n### Analytic forward-liveness peak vs width (eq. 25/26)");
    println!("| hidden | M₁ scalars (t=16) | N·|V| bound |");
    println!("|--------|-------------------|-------------|");
    let mut rng = Xoshiro256::new(19);
    for hidden in [32usize, 64, 128, 256] {
        let dims = [16usize, hidden, hidden, hidden, 1];
        let graph = mlp_graph(&random_layers(&dims, &mut rng), Act::Tanh);
        let m = MemoryModel::new(&graph);
        let fwd = m.forward_peak_scalars(16);
        let bound = 16 * graph.scalar_node_count();
        println!("| {hidden} | {fwd} | {bound} |");
        csv.push(vec![
            "liveness".to_string(),
            hidden.to_string(),
            String::new(),
            String::new(),
            String::new(),
            fwd.to_string(),
            bound.to_string(),
            String::new(),
            String::new(),
            String::new(),
        ]);
    }

    let path = "target/bench_scaling.csv";
    csv.write_to(path).expect("csv written");
    eprintln!("\nseries written to {path}");

    // Assertions: ratios behave per theory.
    let (dm32, hm32, _, _, _, _) = engines_at(&[32, 128, 128, 1], 32, 23);
    let (dm4, hm4, _, _, _, _) = engines_at(&[32, 128, 128, 1], 4, 23);
    let full = hm32 as f64 / dm32 as f64;
    let low = hm4 as f64 / dm4 as f64;
    assert!(full > 1.5, "full-rank ratio {full:.2}");
    assert!(low > 2.5 * full, "rank-4 ratio {low:.2} vs full {full:.2}");
    eprintln!("scaling assertions OK");
}
