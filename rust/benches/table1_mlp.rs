//! Bench: regenerate **Table 1** — DOF vs Hessian-based on the plain MLP
//! (paper architecture: in 64, hidden 256, 8 layers; operators of Table 4
//! row 1: elliptic Gram, rank-32 Gram, signed diagonal).
//!
//! The paper reports V100 milliseconds and GPU-MB at an unstated batch; we
//! report CPU wall-clock, exact FLOPs, and exact peak tangent bytes. The
//! claims under test are the *ratios*: paper observed ≈3.3/4.9/3.3 memory
//! and ≈1.8/3.5/1.6 time.
//!
//! ```sh
//! cargo bench --bench table1_mlp            # paper scale (slow-ish)
//! DOF_BENCH_FAST=1 cargo bench --bench table1_mlp   # reduced widths
//! ```

use dof::bench_harness::report::{run_table1_grid, write_grid_json};
use dof::bench_harness::table1::{run_table1, Table1Config};
use dof::bench_harness::{render_table, BenchConfig};
use dof::util::CsvTable;

fn main() {
    let fast = std::env::var("DOF_BENCH_FAST").is_ok();
    let cfg = if fast {
        Table1Config {
            n: 64,
            hidden: 64,
            layers: 4,
            batch: 4,
            threads: 1,
            seed: 7,
            bench: BenchConfig {
                warmup_iters: 1,
                measure_iters: 3,
                max_seconds: 60.0,
            },
        }
    } else {
        Table1Config {
            batch: 8,
            bench: BenchConfig {
                warmup_iters: 1,
                measure_iters: 5,
                max_seconds: 240.0,
            },
            ..Default::default()
        }
    };
    eprintln!(
        "table1_mlp: N={} hidden={} layers={} batch={} (fast={fast})",
        cfg.n, cfg.hidden, cfg.layers, cfg.batch
    );
    let rows = run_table1(&cfg);
    println!(
        "{}",
        render_table(
            &format!(
                "Table 1 — MLP (N={}, hidden={}, layers={}, batch={})",
                cfg.n, cfg.hidden, cfg.layers, cfg.batch
            ),
            &rows
        )
    );

    let mut csv = CsvTable::new(vec![
        "operator",
        "hessian_ms",
        "dof_ms",
        "time_ratio",
        "hessian_bytes",
        "dof_bytes",
        "mem_ratio",
        "flop_ratio",
    ]);
    for r in &rows {
        csv.push(vec![
            r.operator.clone(),
            format!("{:.3}", r.hessian.seconds.median * 1e3),
            format!("{:.3}", r.dof.seconds.median * 1e3),
            format!("{:.2}", r.time_ratio()),
            r.hessian.peak_bytes.unwrap_or(0).to_string(),
            r.dof.peak_bytes.unwrap_or(0).to_string(),
            format!("{:.2}", r.memory_ratio().unwrap_or(0.0)),
            format!("{:.2}", r.flop_ratio().unwrap_or(0.0)),
        ]);
    }
    let path = "target/bench_table1.csv";
    csv.write_to(path).expect("csv written");
    eprintln!("series written to {path}");

    // Paper-shape assertions (who wins, roughly by how much).
    for r in &rows {
        assert!(
            r.time_ratio() > 1.2,
            "{}: DOF should win wall-clock, ratio {:.2}",
            r.operator,
            r.time_ratio()
        );
        assert!(
            r.memory_ratio().unwrap_or(0.0) > 1.5,
            "{}: DOF should win memory",
            r.operator
        );
    }
    let elliptic_t = rows[0].time_ratio();
    let lowrank_t = rows[1].time_ratio();
    assert!(
        lowrank_t > elliptic_t,
        "low-rank should be the biggest time win ({lowrank_t:.2} vs {elliptic_t:.2})"
    );
    eprintln!("table1 shape assertions OK");

    // Batch × threads grid → machine-readable perf-trajectory file.
    let grid_cfg = Table1Config {
        bench: BenchConfig {
            warmup_iters: 1,
            measure_iters: if fast { 2 } else { 3 },
            max_seconds: if fast { 120.0 } else { 600.0 },
        },
        ..cfg
    };
    let batches: Vec<usize> = if fast { vec![8, 64] } else { vec![8, 64, 256] };
    let threads: Vec<usize> = vec![1, 2, 4, 8];
    eprintln!("grid: batches {batches:?} × threads {threads:?} …");
    let report = run_table1_grid(&grid_cfg, &batches, &threads);
    eprintln!(
        "  plan compile {:.2} ms (once), reused for every cell below",
        report.plan.compile_seconds * 1e3
    );
    let cells = &report.cells;
    for c in cells {
        eprintln!(
            "  batch {:>4} threads {} → dof {:.2} ms, hessian {:.2} ms",
            c.batch,
            c.threads,
            c.dof_seconds * 1e3,
            c.hessian_seconds * 1e3
        );
    }
    write_grid_json("BENCH_table1.json", &grid_cfg, &report).expect("grid json written");
    eprintln!("grid written to BENCH_table1.json");

    // The acceptance claim behind the parallel subsystem: ≥3× wall-clock at
    // batch ≥ 256 with 8 threads vs 1 thread. available_parallelism counts
    // *logical* CPUs, and loaded/SMT machines legitimately fall short, so
    // this warns by default and only hard-fails under DOF_BENCH_STRICT=1.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if let (Some(t1), Some(t8)) = (
        cells.iter().find(|c| c.batch >= 256 && c.threads == 1),
        cells.iter().find(|c| c.batch >= 256 && c.threads == 8),
    ) {
        let speedup = t1.dof_seconds / t8.dof_seconds.max(1e-12);
        eprintln!("dof speedup at batch {}: {speedup:.2}× (8 vs 1 threads, {cores} CPUs)", t1.batch);
        if speedup < 3.0 {
            let msg = format!(
                "parallel DOF speedup {speedup:.2}× below the 3× target at batch {} \
                 (8 vs 1 threads on {cores} logical CPUs)",
                t1.batch
            );
            let strict = std::env::var("DOF_BENCH_STRICT").map(|v| v == "1").unwrap_or(false);
            if strict && cores >= 8 {
                panic!("{msg}");
            }
            eprintln!("WARNING: {msg}");
        }
    }
}
