//! Bench: regenerate **Table 1** — DOF vs Hessian-based on the plain MLP
//! (paper architecture: in 64, hidden 256, 8 layers; operators of Table 4
//! row 1: elliptic Gram, rank-32 Gram, signed diagonal).
//!
//! The paper reports V100 milliseconds and GPU-MB at an unstated batch; we
//! report CPU wall-clock, exact FLOPs, and exact peak tangent bytes. The
//! claims under test are the *ratios*: paper observed ≈3.3/4.9/3.3 memory
//! and ≈1.8/3.5/1.6 time.
//!
//! ```sh
//! cargo bench --bench table1_mlp            # paper scale (slow-ish)
//! DOF_BENCH_FAST=1 cargo bench --bench table1_mlp   # reduced widths
//! ```

use dof::bench_harness::table1::{run_table1, Table1Config};
use dof::bench_harness::{render_table, BenchConfig};
use dof::util::CsvTable;

fn main() {
    let fast = std::env::var("DOF_BENCH_FAST").is_ok();
    let cfg = if fast {
        Table1Config {
            n: 64,
            hidden: 64,
            layers: 4,
            batch: 4,
            seed: 7,
            bench: BenchConfig {
                warmup_iters: 1,
                measure_iters: 3,
                max_seconds: 60.0,
            },
        }
    } else {
        Table1Config {
            batch: 8,
            bench: BenchConfig {
                warmup_iters: 1,
                measure_iters: 5,
                max_seconds: 240.0,
            },
            ..Default::default()
        }
    };
    eprintln!(
        "table1_mlp: N={} hidden={} layers={} batch={} (fast={fast})",
        cfg.n, cfg.hidden, cfg.layers, cfg.batch
    );
    let rows = run_table1(&cfg);
    println!(
        "{}",
        render_table(
            &format!(
                "Table 1 — MLP (N={}, hidden={}, layers={}, batch={})",
                cfg.n, cfg.hidden, cfg.layers, cfg.batch
            ),
            &rows
        )
    );

    let mut csv = CsvTable::new(vec![
        "operator",
        "hessian_ms",
        "dof_ms",
        "time_ratio",
        "hessian_bytes",
        "dof_bytes",
        "mem_ratio",
        "flop_ratio",
    ]);
    for r in &rows {
        csv.push(vec![
            r.operator.clone(),
            format!("{:.3}", r.hessian.seconds.median * 1e3),
            format!("{:.3}", r.dof.seconds.median * 1e3),
            format!("{:.2}", r.time_ratio()),
            r.hessian.peak_bytes.unwrap_or(0).to_string(),
            r.dof.peak_bytes.unwrap_or(0).to_string(),
            format!("{:.2}", r.memory_ratio().unwrap_or(0.0)),
            format!("{:.2}", r.flop_ratio().unwrap_or(0.0)),
        ]);
    }
    let path = "target/bench_table1.csv";
    csv.write_to(path).expect("csv written");
    eprintln!("series written to {path}");

    // Paper-shape assertions (who wins, roughly by how much).
    for r in &rows {
        assert!(
            r.time_ratio() > 1.2,
            "{}: DOF should win wall-clock, ratio {:.2}",
            r.operator,
            r.time_ratio()
        );
        assert!(
            r.memory_ratio().unwrap_or(0.0) > 1.5,
            "{}: DOF should win memory",
            r.operator
        );
    }
    let elliptic_t = rows[0].time_ratio();
    let lowrank_t = rows[1].time_ratio();
    assert!(
        lowrank_t > elliptic_t,
        "low-rank should be the biggest time win ({lowrank_t:.2} vs {elliptic_t:.2})"
    );
    eprintln!("table1 shape assertions OK");
}
