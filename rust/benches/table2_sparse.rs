//! Bench: regenerate **Table 2** — DOF vs Hessian-based on the MLP with
//! Jacobian sparsity (16 blocks × 4 input dims, hidden 256 × 8 layers,
//! per-block output 8, product-sum head; block-diagonal operators of
//! Table 4 row 2).
//!
//! Paper ratios: ≈21.5/24.6/21.5 memory, ≈19.4/28.9/19.4 time. The win is
//! dominated by DOF's structural exploitation of the per-block tangent
//! support (active-row tracking), which the dense Hessian path cannot use.
//!
//! ```sh
//! cargo bench --bench table2_sparse
//! DOF_BENCH_FAST=1 cargo bench --bench table2_sparse
//! ```

use dof::bench_harness::table2::{run_table2, Table2Config};
use dof::bench_harness::{render_table, BenchConfig};
use dof::util::CsvTable;

fn main() {
    let fast = std::env::var("DOF_BENCH_FAST").is_ok();
    let cfg = if fast {
        Table2Config {
            blocks: 8,
            block_in: 4,
            hidden: 64,
            layers: 3,
            block_out: 8,
            batch: 2,
            threads: 1,
            seed: 7,
            bench: BenchConfig {
                warmup_iters: 1,
                measure_iters: 3,
                max_seconds: 120.0,
            },
        }
    } else {
        Table2Config {
            batch: 4,
            bench: BenchConfig {
                warmup_iters: 1,
                measure_iters: 3,
                max_seconds: 600.0,
            },
            ..Default::default()
        }
    };
    eprintln!(
        "table2_sparse: {}×{} blocks, hidden {}×{}, out {}, batch {} (fast={fast})",
        cfg.blocks, cfg.block_in, cfg.hidden, cfg.layers, cfg.block_out, cfg.batch
    );
    let rows = run_table2(&cfg);
    println!(
        "{}",
        render_table(
            &format!(
                "Table 2 — MLP with Jacobian sparsity ({}×{} blocks, batch {})",
                cfg.blocks, cfg.block_in, cfg.batch
            ),
            &rows
        )
    );

    let mut csv = CsvTable::new(vec![
        "operator",
        "hessian_ms",
        "dof_ms",
        "time_ratio",
        "hessian_bytes",
        "dof_bytes",
        "mem_ratio",
        "flop_ratio",
    ]);
    for r in &rows {
        csv.push(vec![
            r.operator.clone(),
            format!("{:.3}", r.hessian.seconds.median * 1e3),
            format!("{:.3}", r.dof.seconds.median * 1e3),
            format!("{:.2}", r.time_ratio()),
            r.hessian.peak_bytes.unwrap_or(0).to_string(),
            r.dof.peak_bytes.unwrap_or(0).to_string(),
            format!("{:.2}", r.memory_ratio().unwrap_or(0.0)),
            format!("{:.2}", r.flop_ratio().unwrap_or(0.0)),
        ]);
    }
    let path = "target/bench_table2.csv";
    csv.write_to(path).expect("csv written");
    eprintln!("series written to {path}");

    // Paper-shape assertions: the sparsity win must be far beyond dense 2×.
    for r in &rows {
        assert!(
            r.time_ratio() > 4.0,
            "{}: sparse DOF should win ≫2× wall-clock, got {:.1}",
            r.operator,
            r.time_ratio()
        );
        assert!(
            r.memory_ratio().unwrap_or(0.0) > 4.0,
            "{}: sparse DOF should win ≫2× memory, got {:.1}",
            r.operator,
            r.memory_ratio().unwrap_or(0.0)
        );
    }
    eprintln!("table2 shape assertions OK");
}
