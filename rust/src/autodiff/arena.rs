//! Tangent arena: a reusable buffer pool for the engines' per-node tensors.
//!
//! The DOF pass allocates a fresh `(v, g, s)` tuple per graph node and the
//! liveness rule (eq. 24) frees it a few nodes later — on an 8-layer MLP
//! that is hundreds of multi-megabyte allocator round-trips per batch. The
//! arena breaks the churn: freed buffers are parked in a size-bucketed free
//! list and handed back to the next allocation of a compatible size —
//! zeroed by default ([`TangentArena::take`]), or as-is for destinations
//! the engine fully overwrites ([`TangentArena::take_scratch`], skipping
//! the memset on the hottest buffers) — so a steady-state engine pass
//! performs **no heap allocation** for tangent storage after its first
//! iteration.
//!
//! Buffers are keyed by *capacity* (a `BTreeMap` bucket per capacity) and an
//! allocation takes the smallest parked buffer that fits, so the pool also
//! serves mixed shapes (e.g. the `[batch·(t+2), d]` stacked GEMM input next
//! to `[batch, d]` value rows).
//!
//! The arena is **accounting-neutral**: [`crate::autodiff::PeakTracker`] is
//! driven by the engines' logical alloc/free events, which do not change
//! when the backing store is recycled — the Theorem 2.2 `M₁`/`M₂`
//! measurements are bit-identical with or without pooling (asserted by
//! `rust/tests/parallel_determinism.rs`).
//!
//! Serial engine passes use the calling thread's arena
//! ([`with_thread_arena`]); sharded parallel passes check arenas out of a
//! process-wide depot ([`with_pooled_arena`]) instead, because pool workers
//! are fresh scoped threads whose thread-locals die with each parallel
//! region — only the depot preserves the warmed pools across regions. In
//! both cases no lock sits inside the per-node hot path; the depot is
//! touched twice per *shard*.
//!
//! Since the plan subsystem landed, the **planned** executors
//! ([`crate::plan::exec`], [`crate::jet::program`]) no longer allocate per
//! node at all: they check one slab out per execution, so the arena's
//! per-node traffic now belongs to the reference interpreters
//! (`DofEngine::compute_with_arena`, `JetEngine::compute_with_arena`).
//! Slab checkout goes through the **program-keyed slab pool**
//! ([`with_program_slab`]): slabs are keyed by `(program fingerprint,
//! shard rows)` and returned exact-fit, skipping the size-bucket search
//! entirely on the steady-state serving/bench path. The pool is
//! **lock-sharded by key hash** (16 independent mutexes), so concurrent
//! unsharded `execute()` calls from caller-owned threads —
//! the multi-model serving router's per-model workers, stress harnesses —
//! no longer contend on one process-global lock.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

use crate::tensor::Tensor;

use super::forward_jacobian::TangentBatch;

/// Size-bucketed free list of `f64` buffers.
#[derive(Debug, Default)]
pub struct TangentArena {
    /// capacity → parked buffers of exactly that capacity.
    free: BTreeMap<usize, Vec<Vec<f64>>>,
    hits: u64,
    misses: u64,
    recycled: u64,
}

/// Reuse counters (diagnostics and tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaStats {
    /// Allocations served from the free list.
    pub hits: u64,
    /// Allocations that fell through to the heap.
    pub misses: u64,
    /// Buffers returned to the pool.
    pub recycled: u64,
}

impl TangentArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// A zeroed buffer of exactly `len` elements, recycled when possible.
    pub fn take(&mut self, len: usize) -> Vec<f64> {
        match self.take_recycled(len) {
            Some(mut buf) => {
                buf.clear();
                buf.resize(len, 0.0);
                buf
            }
            None => vec![0.0; len],
        }
    }

    /// A buffer of exactly `len` elements **without zeroing** the recycled
    /// prefix — the cheap path for buffers the caller fully overwrites
    /// before reading (the Linear stack/copy targets, activation outputs).
    /// Never hand one to an accumulating consumer.
    pub fn take_scratch(&mut self, len: usize) -> Vec<f64> {
        match self.take_recycled(len) {
            Some(mut buf) => {
                // Stale values may remain in 0..min(old_len, len); only the
                // grown tail is zero-filled (no uninitialized memory).
                buf.resize(len, 0.0);
                buf
            }
            None => vec![0.0; len],
        }
    }

    /// Pop the smallest parked buffer with capacity ≥ `len`, counting
    /// hits/misses. `None` for len 0 or an empty-fit pool.
    fn take_recycled(&mut self, len: usize) -> Option<Vec<f64>> {
        if len == 0 {
            return None;
        }
        if let Some((&cap, _)) = self.free.range(len..).next() {
            let bucket = self.free.get_mut(&cap).expect("bucket exists");
            let buf = bucket.pop().expect("bucket non-empty");
            if bucket.is_empty() {
                self.free.remove(&cap);
            }
            self.hits += 1;
            return Some(buf);
        }
        self.misses += 1;
        None
    }

    /// Park a buffer for reuse.
    pub fn put(&mut self, buf: Vec<f64>) {
        let cap = buf.capacity();
        if cap == 0 {
            return;
        }
        self.recycled += 1;
        self.free.entry(cap).or_default().push(buf);
    }

    /// A zeroed tensor backed by recycled storage.
    pub fn tensor(&mut self, dims: &[usize]) -> Tensor {
        let n: usize = dims.iter().product();
        Tensor::from_vec(dims, self.take(n))
    }

    /// A tensor backed by recycled storage **without zeroing** (see
    /// [`Self::take_scratch`]): only for fully-overwritten destinations.
    pub fn tensor_scratch(&mut self, dims: &[usize]) -> Tensor {
        let n: usize = dims.iter().product();
        Tensor::from_vec(dims, self.take_scratch(n))
    }

    /// A tangent block backed by recycled storage **without zeroing** (see
    /// [`Self::take_scratch`]): only for fully-overwritten destinations.
    pub fn tangent_scratch(&mut self, batch: usize, t: usize, dim: usize) -> TangentBatch {
        TangentBatch {
            data: self.tensor_scratch(&[batch * t, dim]),
            batch,
            t,
        }
    }

    /// Recycle a tensor's storage.
    pub fn put_tensor(&mut self, t: Tensor) {
        self.put(t.into_vec());
    }

    /// A zeroed tangent block backed by recycled storage.
    pub fn tangent(&mut self, batch: usize, t: usize, dim: usize) -> TangentBatch {
        TangentBatch {
            data: self.tensor(&[batch * t, dim]),
            batch,
            t,
        }
    }

    /// Recycle a tangent block's storage.
    pub fn put_tangent(&mut self, g: TangentBatch) {
        self.put_tensor(g.data);
    }

    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            hits: self.hits,
            misses: self.misses,
            recycled: self.recycled,
        }
    }

    /// Number of parked buffers.
    pub fn pooled(&self) -> usize {
        self.free.values().map(Vec::len).sum()
    }
}

thread_local! {
    static THREAD_ARENA: RefCell<TangentArena> = RefCell::new(TangentArena::new());
}

/// Run `f` with the calling thread's persistent arena (serial engine paths).
pub fn with_thread_arena<R>(f: impl FnOnce(&mut TangentArena) -> R) -> R {
    THREAD_ARENA.with(|a| f(&mut a.borrow_mut()))
}

/// Cap on parked depot arenas — bounds retention at roughly the maximum
/// number of concurrently running shard workers ever observed.
const DEPOT_CAP: usize = 64;

static DEPOT: Mutex<Vec<TangentArena>> = Mutex::new(Vec::new());

/// Check an arena out of the process-wide depot for the duration of `f`,
/// then park it again. Shard workers use this instead of a thread-local:
/// scoped worker threads die with their parallel region, so thread-local
/// arenas would start cold every region, re-heap-allocating the whole
/// working set each bench rep / server batch.
pub fn with_pooled_arena<R>(f: impl FnOnce(&mut TangentArena) -> R) -> R {
    let mut arena = DEPOT
        .lock()
        .expect("arena depot poisoned")
        .pop()
        .unwrap_or_default();
    let out = f(&mut arena);
    let mut depot = DEPOT.lock().expect("arena depot poisoned");
    if depot.len() < DEPOT_CAP {
        depot.push(arena);
    }
    out
}

// ---- program-keyed slab pool ---------------------------------------------

/// Key of a program-shaped slab: the compiled program's structural
/// fingerprint plus the shard row count it was sized for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SlabKey {
    /// `OperatorProgram`/`JetProgram` cache-key fingerprint.
    pub program: u64,
    /// Rows the slab was sized for (shard rows or the full batch).
    pub rows: usize,
}

/// Reuse counters for the slab pool (diagnostics and tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlabPoolStats {
    /// Checkouts served by a parked exact-fit slab.
    pub hits: u64,
    /// Checkouts that heap-allocated.
    pub misses: u64,
    /// Slabs currently parked.
    pub retained: usize,
}

/// Lock shards of the slab pool. Concurrent unsharded `execute()` calls
/// from caller-owned threads (serving routers, test harnesses) each lock
/// the pool twice per execution; one global mutex serialized them all
/// (ROADMAP jet follow-up). Keys hash onto [`SLAB_POOL_SHARDS`] independent
/// mutexes instead, so contention only arises between executions of the
/// *same* `(program, rows)` neighborhood.
const SLAB_POOL_SHARDS: usize = 16;

/// Cap on parked slabs **per lock shard** — a backstop against unbounded
/// retention, not a working-set budget: real retention is bounded by the
/// live `(program, rows)` keys actually parked. Sized so that even a
/// hash-unlucky shard holding many hot keys (a multi-model serving mix
/// landing on one mutex) keeps them all warm instead of thrash-evicting
/// on every park.
const SLAB_SHARD_CAP: usize = 32;

struct SlabPool {
    slabs: HashMap<SlabKey, Vec<Vec<f64>>>,
    retained: usize,
    hits: u64,
    misses: u64,
}

#[allow(clippy::declare_interior_mutable_const)]
const SLAB_SHARD_INIT: Mutex<Option<SlabPool>> = Mutex::new(None);
static SLAB_POOL: [Mutex<Option<SlabPool>>; SLAB_POOL_SHARDS] =
    [SLAB_SHARD_INIT; SLAB_POOL_SHARDS];

/// Lock shard for a key: a 64-bit finalizer mix of `(program, rows)` folded
/// onto the shard array. Purely a function of the key, so a given
/// `(program, rows)` pair always lands on the same mutex.
fn slab_shard(key: &SlabKey) -> usize {
    let mut h = key
        .program
        .wrapping_add((key.rows as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    h ^= h >> 33;
    (h as usize) % SLAB_POOL_SHARDS
}

fn with_slab_pool<R>(shard: usize, f: impl FnOnce(&mut SlabPool) -> R) -> R {
    let mut guard = SLAB_POOL[shard].lock().expect("slab pool poisoned");
    let pool = guard.get_or_insert_with(|| SlabPool {
        slabs: HashMap::new(),
        retained: 0,
        hits: 0,
        misses: 0,
    });
    f(pool)
}

/// Check an **exact-fit** slab out of the process-wide pool for the
/// duration of `f`, then park it again under its key.
///
/// Unlike the arena's size-bucketed scratch path, slabs here are keyed by
/// `(program, rows)`: a steady-state serving or bench loop executing the
/// same compiled program on same-shaped shards gets its own warmed slab
/// back without any best-fit search, and slabs of different programs never
/// alias (ROADMAP PR 2 follow-up; used by `DofEngine`, `HessianEngine`,
/// and `JetEngine`). The pool is **lock-sharded by key hash**, so
/// concurrent unsharded executions on caller-owned threads no longer
/// serialize on one global mutex. The slab is handed to
/// `f` as-is — executors fully assign their slots before reading, the same
/// contract as [`TangentArena::take_scratch`].
pub fn with_program_slab<R>(key: SlabKey, f: impl FnOnce(&mut Vec<f64>) -> R) -> R {
    let shard = slab_shard(&key);
    let mut slab = with_slab_pool(shard, |pool| {
        let popped = match pool.slabs.get_mut(&key) {
            Some(bucket) => {
                let s = bucket.pop();
                // Drop emptied buckets so the shard's key set always maps
                // to parked slabs (keeps eviction victims real).
                if bucket.is_empty() {
                    pool.slabs.remove(&key);
                }
                s
            }
            None => None,
        };
        match popped {
            Some(s) => {
                pool.retained -= 1;
                pool.hits += 1;
                Some(s)
            }
            None => {
                pool.misses += 1;
                None
            }
        }
    })
    .unwrap_or_default();
    let out = f(&mut slab);
    with_slab_pool(shard, |pool| {
        // Always park the just-used slab — it belongs to a live key — and
        // evict from a *different* key when over the cap, so key churn
        // (changing batch shapes, model rollovers) ages stale slabs out
        // instead of permanently locking new keys out of the pool.
        pool.slabs.entry(key).or_default().push(slab);
        pool.retained += 1;
        if pool.retained > SLAB_SHARD_CAP {
            let victim = pool
                .slabs
                .keys()
                .find(|&&k| k != key)
                .copied()
                .unwrap_or(key);
            if let Some(bucket) = pool.slabs.get_mut(&victim) {
                // A key's bucket can be empty while its slab is checked
                // out; only a real pop frees retention.
                if bucket.pop().is_some() {
                    pool.retained -= 1;
                }
                if bucket.is_empty() {
                    pool.slabs.remove(&victim);
                }
            }
        }
    });
    out
}

/// Current slab-pool counters, aggregated over the lock shards.
pub fn slab_pool_stats() -> SlabPoolStats {
    let mut out = SlabPoolStats {
        hits: 0,
        misses: 0,
        retained: 0,
    };
    for shard in 0..SLAB_POOL_SHARDS {
        with_slab_pool(shard, |pool| {
            out.hits += pool.hits;
            out.misses += pool.misses;
            out.retained += pool.retained;
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_roundtrip_reuses() {
        let mut a = TangentArena::new();
        let b1 = a.take(100);
        assert_eq!(a.stats().misses, 1);
        a.put(b1);
        let b2 = a.take(64); // smaller fits in the 100-cap buffer
        assert_eq!(a.stats().hits, 1);
        assert_eq!(b2.len(), 64);
        assert!(b2.iter().all(|&v| v == 0.0));
        assert_eq!(a.pooled(), 0);
    }

    #[test]
    fn recycled_buffers_are_zeroed() {
        let mut a = TangentArena::new();
        let mut t = a.tensor(&[4, 4]);
        t.data_mut().iter_mut().for_each(|v| *v = 7.0);
        a.put_tensor(t);
        let t2 = a.tensor(&[2, 8]);
        assert!(t2.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn scratch_skips_zeroing_but_sizes_exactly() {
        let mut a = TangentArena::new();
        let mut t = a.tensor(&[4, 4]);
        t.data_mut().iter_mut().for_each(|v| *v = 9.0);
        a.put_tensor(t);
        let s = a.tensor_scratch(&[2, 4]);
        assert_eq!(s.numel(), 8);
        // Stale contents are allowed — that is the point — but a grown
        // request must still zero-fill its tail past any recycled prefix.
        let mut a2 = TangentArena::new();
        let mut parked = Vec::with_capacity(12);
        parked.extend_from_slice(&[7.0; 4]);
        a2.put(parked);
        let big = a2.take_scratch(10);
        assert_eq!(big.len(), 10);
        assert!(big[..4].iter().all(|&v| v == 7.0), "stale prefix kept");
        assert!(big[4..].iter().all(|&v| v == 0.0), "grown tail zeroed");
    }

    #[test]
    fn oversized_requests_fall_through() {
        let mut a = TangentArena::new();
        a.put(vec![0.0; 8]);
        let b = a.take(1000);
        assert_eq!(b.len(), 1000);
        assert_eq!(a.stats().misses, 1);
        assert_eq!(a.pooled(), 1); // small buffer still parked
    }

    #[test]
    fn pooled_arena_roundtrip() {
        // The depot is process-global (shared with concurrently running
        // tests), so assert behaviour, not counters: buffers survive one
        // checkout and are served zeroed on the next.
        with_pooled_arena(|a| {
            let mut t = a.tensor(&[8, 8]);
            t.data_mut()[0] = 3.5;
            a.put_tensor(t);
        });
        let ok = with_pooled_arena(|a| {
            let t = a.tensor(&[8, 8]);
            t.data().iter().all(|&v| v == 0.0)
        });
        assert!(ok);
    }

    #[test]
    fn program_slab_pool_is_exact_fit_per_key() {
        // The pool is process-global and other tests run concurrently, so a
        // parked slab may be evicted between calls once the cap is reached;
        // assert the invariants that hold regardless: a warm hit under the
        // same key returns the slab *verbatim* (exact length, stale
        // contents — executors overwrite before reading), and a different
        // key never aliases it.
        let ka = SlabKey { program: 0xA11CE, rows: 3 };
        let kb = SlabKey { program: 0xA11CE, rows: 5 };
        with_program_slab(ka, |s| {
            s.clear();
            s.resize(30, 0.0);
            s[0] = 1.25;
        });
        let (len, first) = with_program_slab(ka, |s| (s.len(), s.first().copied()));
        if len != 0 {
            // Warm hit (no concurrent eviction raced us): exact fit.
            assert_eq!(len, 30);
            assert_eq!(first, Some(1.25));
        }
        // Different rows under the same program: a distinct (possibly also
        // warmed by this test's earlier runs — but never 30-long) slab.
        let len_b = with_program_slab(kb, |s| s.len());
        assert_ne!(len_b, 30, "different key must not alias");
        let st = slab_pool_stats();
        assert!(st.hits + st.misses >= 3, "all three checkouts counted");
    }

    #[test]
    fn thread_arena_is_reusable() {
        let first = with_thread_arena(|a| {
            let b = a.take(32);
            a.put(b);
            a.stats()
        });
        let second = with_thread_arena(|a| {
            let _ = a.take(32);
            a.stats()
        });
        assert!(second.hits > first.hits);
    }
}
