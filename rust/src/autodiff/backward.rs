//! Reverse-mode adjoint propagation (eq. 12): `v̄ⁱ = ∂φ/∂vⁱ`.
//!
//! Used in two places: inside the Hessian-based baseline (the `Ĝ` graph of
//! Appendix B), and by the training loop for parameter gradients of the
//! PINN loss.

use crate::graph::{Graph, Op};
use crate::tensor::{matmul, matmul_tn, Tensor};

use super::Cost;

/// Result of a reverse sweep.
pub struct BackwardResult {
    /// Adjoint `∂(Σ_c seed_c · φ_c)/∂vⁱ` per node, `[batch, dim_i]`.
    pub adjoints: Vec<Tensor>,
    /// For each Linear node id: (∂/∂W `[out, in]`, ∂/∂b `[out]`), summed
    /// over the batch. Empty unless `with_params`.
    pub param_grads: Vec<(usize, Tensor, Vec<f64>)>,
    pub cost: Cost,
}

/// Run a reverse sweep from the output node.
///
/// `values` must come from `graph.eval_all`. `out_seed` is the cotangent of
/// the output node, `[batch, out_dim]` (all-ones for a plain scalar `∂φ/∂v`).
/// When `with_params` is set, Linear weight/bias gradients are accumulated
/// (needed for training; skipped in the operator benchmarks to keep the
/// baseline's cost exactly eq. 12's).
pub fn backward(
    graph: &Graph,
    values: &[Tensor],
    out_seed: &Tensor,
    with_params: bool,
) -> BackwardResult {
    let batch = out_seed.dims()[0];
    let mut cost = Cost::zero();
    let mut adjoints: Vec<Tensor> = graph
        .nodes()
        .iter()
        .map(|n| Tensor::zeros(&[batch, n.dim]))
        .collect();
    adjoints[graph.output()] = out_seed.clone();
    let mut param_grads = Vec::new();

    for id in (0..graph.len()).rev() {
        let node = graph.node(id);
        // Take the accumulated adjoint of this node.
        let vbar = adjoints[id].clone();
        match &node.op {
            Op::Input { .. } => {}
            Op::Linear { weight, .. } => {
                let p = node.inputs[0];
                // parent += v̄ · W : [batch,out]·[out,in] → [batch,in]
                let contrib = matmul(&vbar, weight);
                adjoints[p] = adjoints[p].add(&contrib);
                let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
                cost.muls += (batch * out_d * in_d) as u64;
                cost.adds += (batch * out_d * in_d) as u64;
                if with_params {
                    // ∂/∂W = v̄ᵀ · v_parent (summed over batch).
                    let gw = matmul_tn(&vbar, &values[p]);
                    let mut gb = vec![0.0; out_d];
                    for b in 0..batch {
                        for (g, &v) in gb.iter_mut().zip(vbar.row(b)) {
                            *g += v;
                        }
                    }
                    cost.muls += (batch * out_d * in_d) as u64;
                    param_grads.push((id, gw, gb));
                }
            }
            Op::Activation { act } => {
                let p = node.inputs[0];
                let h = &values[p];
                let contrib = vbar.zip_with(h, |v, hh| v * act.df(hh));
                adjoints[p] = adjoints[p].add(&contrib);
                cost.muls += (batch * node.dim) as u64;
            }
            Op::Slice { start, len } => {
                let p = node.inputs[0];
                for b in 0..batch {
                    let src = vbar.row(b).to_vec();
                    let dst = adjoints[p].row_mut(b);
                    for j in 0..*len {
                        dst[*start + j] += src[j];
                    }
                }
            }
            Op::Add => {
                for &p in &node.inputs {
                    adjoints[p] = adjoints[p].add(&vbar);
                    cost.adds += (batch * node.dim) as u64;
                }
            }
            Op::Mul => {
                let k = node.inputs.len();
                for (pi, &p) in node.inputs.iter().enumerate() {
                    // parent_p += v̄ ⊙ Π_{q≠p} v^q
                    let mut contrib = vbar.clone();
                    for (qi, &q) in node.inputs.iter().enumerate() {
                        if qi != pi {
                            contrib = contrib.mul(&values[q]);
                        }
                    }
                    cost.muls += (batch * node.dim * (k - 1)) as u64;
                    adjoints[p] = adjoints[p].add(&contrib);
                }
            }
            Op::SumReduce => {
                let p = node.inputs[0];
                let pd = graph.node(p).dim;
                for b in 0..batch {
                    let v = vbar.at(b, 0);
                    for x in adjoints[p].row_mut(b) {
                        *x += v;
                    }
                    let _ = pd;
                }
            }
            Op::Concat => {
                for b in 0..batch {
                    let mut off = 0;
                    let src = vbar.row(b).to_vec();
                    for &p in &node.inputs {
                        let pd = graph.node(p).dim;
                        let dst = adjoints[p].row_mut(b);
                        for j in 0..pd {
                            dst[j] += src[off + j];
                        }
                        off += pd;
                    }
                }
            }
        }
    }

    BackwardResult {
        adjoints,
        param_grads,
        cost,
    }
}

/// Gradient of a scalar-output graph w.r.t. its input, `[batch, N]`.
pub fn input_gradient(graph: &Graph, x: &Tensor) -> Tensor {
    let values = graph.eval_all(x);
    let batch = x.dims()[0];
    let out_dim = graph.node(graph.output()).dim;
    assert_eq!(out_dim, 1, "input_gradient expects scalar output");
    let seed = Tensor::full(&[batch, 1], 1.0);
    let res = backward(graph, &values, &seed, false);
    // Gather input-node adjoints into a flat [batch, N].
    let n = graph.input_dim();
    let mut grad = Tensor::zeros(&[batch, n]);
    let mut off = 0;
    for &i in graph.input_ids() {
        let d = graph.node(i).dim;
        for b in 0..batch {
            grad.row_mut(b)[off..off + d].copy_from_slice(res.adjoints[i].row(b));
        }
        off += d;
    }
    grad
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::forward_jacobian::jacobian;
    use crate::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act, Graph};
    use crate::util::Xoshiro256;

    #[test]
    fn backward_matches_forward_jacobian_mlp() {
        let mut rng = Xoshiro256::new(8);
        let g = mlp_graph(&random_layers(&[6, 11, 9, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[4, 6], &mut rng);
        let grad = input_gradient(&g, &x);
        let jac = jacobian(&g, &x); // [batch, 1, N]
        for b in 0..4 {
            for i in 0..6 {
                let jv = jac.data()[b * 6 + i];
                assert!(
                    (grad.at(b, i) - jv).abs() < 1e-10,
                    "b={b} i={i}: {} vs {jv}",
                    grad.at(b, i)
                );
            }
        }
    }

    #[test]
    fn backward_matches_forward_jacobian_sparse() {
        let mut rng = Xoshiro256::new(9);
        let blocks: Vec<_> = (0..4)
            .map(|_| random_layers(&[3, 7, 5], &mut rng))
            .collect();
        let g = sparse_mlp_graph(&blocks, Act::Gelu);
        let x = Tensor::randn(&[2, 12], &mut rng);
        let grad = input_gradient(&g, &x);
        let jac = jacobian(&g, &x);
        for b in 0..2 {
            for i in 0..12 {
                let jv = jac.data()[b * 12 + i];
                assert!((grad.at(b, i) - jv).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn param_grads_match_finite_difference() {
        let mut rng = Xoshiro256::new(10);
        let layers = random_layers(&[3, 4, 1], &mut rng);
        let g = mlp_graph(&layers, Act::Tanh);
        let x = Tensor::randn(&[5, 3], &mut rng);
        let values = g.eval_all(&x);
        let seed = Tensor::full(&[5, 1], 1.0);
        let res = backward(&g, &values, &seed, true);
        // Locate the first Linear node (id 1) and its weight grad.
        let (nid, gw, gb) = &res.param_grads[res
            .param_grads
            .iter()
            .position(|(id, _, _)| *id == 1)
            .unwrap()];
        assert_eq!(*nid, 1);

        // Finite-difference check on W[0][1] and b[2].
        let h = 1e-6;
        let loss = |layers: &crate::graph::builder::LayerWeights| -> f64 {
            let g2 = mlp_graph(layers, Act::Tanh);
            g2.eval(&x).sum()
        };
        let w01 = layers[0].0.at(0, 1);
        let mut lp = layers.clone();
        lp[0].0.set(0, 1, w01 + h);
        let mut lm = layers.clone();
        lm[0].0.set(0, 1, w01 - h);
        let fd_w = (loss(&lp) - loss(&lm)) / (2.0 * h);
        assert!((gw.at(0, 1) - fd_w).abs() < 1e-5, "{} vs {fd_w}", gw.at(0, 1));

        let mut lp = layers.clone();
        lp[0].1[2] += h;
        let mut lm = layers.clone();
        lm[0].1[2] -= h;
        let fd_b = (loss(&lp) - loss(&lm)) / (2.0 * h);
        assert!((gb[2] - fd_b).abs() < 1e-5, "{} vs {fd_b}", gb[2]);
    }

    #[test]
    fn slice_concat_adjoints_roundtrip() {
        // φ = sum(concat(x[0..2], x[2..4])) ⇒ ∇φ = 1.
        let mut g = Graph::new();
        let x = g.input(4);
        let a = g.slice(x, 0, 2);
        let b = g.slice(x, 2, 2);
        let c = g.push(Op::Concat, vec![a, b]);
        g.sum_reduce(c);
        let xin = Tensor::from_vec(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let grad = input_gradient(&g, &xin);
        for i in 0..4 {
            assert!((grad.at(0, i) - 1.0).abs() < 1e-12);
        }
    }
}
