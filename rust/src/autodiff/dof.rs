//! **DOF** — Differential Operator with Forward-propagation (§2.2,
//! eqs. 7–9). The paper's contribution.
//!
//! Given `A = Lᵀ D L` (see [`crate::linalg::LdlDecomposition`]), one forward
//! pass propagates the tuple `(v, g, s) = (v, L∇v, L[v])` per node:
//!
//! ```text
//! gʲ = Σ_{i→j} ∂F_j/∂vⁱ · gⁱ                                   (eq. 8)
//! sʲ = Σ_{i,l→j} ∂²F_j/∂vⁱ∂vˡ · gⁱᵀ D gˡ + Σ_{i→j} ∂F_j/∂vⁱ · sⁱ  (eq. 9)
//! ```
//!
//! Three structural optimizations, all from the paper:
//!
//! * **rank truncation** (§2.2 low-rank): tangent width is `r = rank(A)`;
//! * **liveness freeing** (Thm 2.2 / eq. 24): parent tuples are released at
//!   their last consumer, which is what bounds peak memory by `C(j)`;
//! * **Jacobian sparsity** (§3.2): each node tracks its *active tangent
//!   rows* — the subset of `L`'s rows with a nonzero entry in the node's
//!   input cone. For the block-sparse architecture with block-diagonal `A`,
//!   every per-block neuron carries only its block's rows (`r/k` of them),
//!   which is the source of the ~20× win in Table 2. A dense Hessian-based
//!   baseline cannot exploit this.
//!
//! The affine/elementwise node granularity realises the Appendix C fast
//! path: the eq. 9 contraction touches only diagonal pairs of elementwise
//! ops.
//!
//! First-order (`Σ b_i ∂_i`) and zeroth-order (`c·φ`) terms compose
//! exactly: the `b`-part seeds `s` at the inputs and propagates through the
//! same linear recursion; `c·φ` is added at the output.
//!
//! Execution is **planned**: every `compute*` entry point compiles (or
//! fetches from [`crate::plan::global_cache`]) an
//! [`crate::plan::OperatorProgram`] — fused schedule, static slab slots,
//! precomputed §3.2 active rows, exact analytic costs — and runs the thin
//! slab executor ([`crate::plan::exec`]). The original per-call graph walk
//! survives as [`DofEngine::compute_with_arena`], the differential-testing
//! reference the planned path is asserted bit-identical to.

use crate::graph::{Graph, Op};
use crate::linalg::LdlDecomposition;
use crate::parallel::{self, Pool};
use crate::plan::{self, kernels, OperatorProgram, PanelSet, PlanOptions};
use crate::tensor::{GemmPlan, Tensor};

use super::arena::{with_program_slab, SlabKey, TangentArena};
use super::forward_jacobian::TangentBatch;
use super::memory::PeakTracker;
use super::Cost;

/// The DOF operator engine, seeded by a coefficient decomposition.
pub struct DofEngine {
    /// `A = Lᵀ D L`.
    pub ldl: LdlDecomposition,
    /// Optional first-order coefficients `b ∈ R^N`.
    pub b: Option<Vec<f64>>,
    /// Optional zeroth-order coefficient `c`.
    pub c: Option<f64>,
    /// Exploit tangent-row sparsity (§3.2). On by default; benchmarks can
    /// disable it to ablate.
    pub exploit_sparsity: bool,
}

/// Output of [`DofEngine::compute`].
pub struct DofResult {
    /// `φ(x)`, `[batch, out]`.
    pub values: Tensor,
    /// Output tangent `g^M` restricted to its active rows, folded
    /// `[batch·t, out]`.
    pub out_tangent: TangentBatch,
    /// Active (global) tangent-row indices of `out_tangent`.
    pub out_active: Vec<usize>,
    /// `L[φ](x)`, `[batch, out]`.
    pub operator_values: Tensor,
    /// Exact FLOP count of the run.
    pub cost: Cost,
    /// Peak live tangent bytes (the Theorem 2.2 `M₁` measurement).
    pub peak_tangent_bytes: u64,
}

/// Per-node tuple state during the pass.
struct NodeState {
    v: Tensor,
    g: TangentBatch,
    /// Global row indices of `g` (sorted). `g.t == active.len()`.
    active: Vec<usize>,
    s: Tensor,
}

impl DofEngine {
    /// Engine for `Σ a_ij ∂²_ij` from a coefficient matrix (decomposed
    /// internally).
    pub fn new(a: &Tensor) -> Self {
        Self {
            ldl: LdlDecomposition::of(a),
            b: None,
            c: None,
            exploit_sparsity: true,
        }
    }

    /// Engine from a precomputed decomposition (lets callers cache it).
    pub fn from_ldl(ldl: LdlDecomposition) -> Self {
        Self {
            ldl,
            b: None,
            c: None,
            exploit_sparsity: true,
        }
    }

    /// Add first-order and zeroth-order terms.
    pub fn with_lower_order(mut self, b: Option<Vec<f64>>, c: Option<f64>) -> Self {
        if let Some(ref bv) = b {
            assert_eq!(bv.len(), self.ldl.n);
        }
        self.b = b;
        self.c = c;
        self
    }

    /// Disable the §3.2 sparsity optimization (ablation).
    pub fn dense(mut self) -> Self {
        self.exploit_sparsity = false;
        self
    }

    /// Tangent width `r = rank(A)`.
    pub fn rank(&self) -> usize {
        self.ldl.rank()
    }

    /// Plan options implied by this engine's configuration (part of the
    /// program cache key).
    pub fn plan_options(&self) -> PlanOptions {
        PlanOptions {
            sparsity: self.exploit_sparsity,
            lower_order_c: self.c.is_some(),
        }
    }

    /// Compile the operator program for `graph` — the static side of the
    /// eq. 7–9 pass (schedule with fused `Linear→Activation` steps,
    /// liveness, slab slot assignment, §3.2 active rows, exact analytic
    /// costs). Uncached; the `compute*` wrappers go through
    /// [`plan::global_cache`] instead.
    pub fn plan(&self, graph: &Graph) -> OperatorProgram {
        OperatorProgram::compile(graph, &self.ldl, self.plan_options())
    }

    /// Structured batch-input validation against `graph`'s input
    /// dimension: shape, width, and finiteness, through the shared
    /// [`crate::tensor::ops::validate_batch_input`] gate — every engine
    /// rejects a malformed batch with the **identical** message, which the
    /// serving tier surfaces as `ServeError::InvalidRequest` and the
    /// cross-engine fuzz harness asserts on.
    pub fn validate_input(&self, graph: &Graph, x: &Tensor) -> Result<(), String> {
        crate::tensor::ops::validate_batch_input(graph.input_dim(), x)
    }

    /// Evaluate `L[φ]` on a batch `x: [batch, N]` in one forward pass.
    ///
    /// Compile-then-run wrapper: the [`OperatorProgram`] comes from the
    /// keyed [`plan::global_cache`] (compiled on first use, value-
    /// independent so training steps reuse it) and executes over the
    /// calling thread's slab.
    pub fn compute(&self, graph: &Graph, x: &Tensor) -> DofResult {
        let program = plan::global_cache().get_or_compile(graph, &self.ldl, self.plan_options());
        self.execute(&program, graph, x)
    }

    /// Execute a precompiled program, with slab storage checked out of the
    /// process-wide **program-keyed slab pool** (exact fit by
    /// `(program, rows)` — no size-bucket search; one pool transaction per
    /// call, and the per-node hot path touches no allocator).
    ///
    /// Weight panels for the `PackedAxpy`-form Linear steps are packed once
    /// here (never cached with the program — panels hold weight values).
    pub fn execute(&self, program: &OperatorProgram, graph: &Graph, x: &Tensor) -> DofResult {
        let panels = plan::pack_panels(program.steps(), graph);
        let key = SlabKey {
            program: program.key().fingerprint,
            rows: x.dims()[0],
        };
        with_program_slab(key, |slab| {
            self.execute_with_slab(program, graph, x, &panels, slab)
        })
    }

    /// Execute a precompiled program with caller-supplied panel set (from
    /// [`plan::pack_panels`]; an all-`None` set is always valid and
    /// bit-identical) and slab storage.
    pub fn execute_with_slab(
        &self,
        program: &OperatorProgram,
        graph: &Graph,
        x: &Tensor,
        panels: &PanelSet,
        slab: &mut Vec<f64>,
    ) -> DofResult {
        // A program compiled under different options would execute with
        // the wrong active sets / cost accounting for this engine (e.g.
        // the `dense()` ablation handed a sparse plan) — reject loudly.
        assert_eq!(
            program.options(),
            self.plan_options(),
            "program options do not match this engine's configuration"
        );
        plan::exec::execute_dof(
            program,
            graph,
            &self.ldl,
            self.b.as_deref(),
            self.c,
            x,
            panels,
            slab,
        )
    }

    /// [`Self::compute`] sharded across the process-wide pool (`--threads` /
    /// `DOF_THREADS`) in [`parallel::DEFAULT_SHARD_ROWS`]-row chunks.
    pub fn compute_parallel(&self, graph: &Graph, x: &Tensor) -> DofResult {
        self.compute_sharded(graph, x, &parallel::global(), parallel::DEFAULT_SHARD_ROWS)
    }

    /// Evaluate `L[φ]` with the batch partitioned into fixed `shard_rows`-row
    /// chunks executed across `pool`, each worker using a [`TangentArena`]
    /// checked out of the process-wide depot (warm across calls).
    ///
    /// Determinism contract: chunk boundaries depend only on the batch size
    /// and `shard_rows` — never on the pool width — and shard results are
    /// reduced in shard order, so `values`, `operator_values`, `cost`, and
    /// `peak_tangent_bytes` (the per-shard maximum) are bit-identical across
    /// thread counts. Per-row arithmetic is independent of the rows it is
    /// batched with, so `values`/`operator_values` also match the unsharded
    /// [`Self::compute`] exactly.
    pub fn compute_sharded(
        &self,
        graph: &Graph,
        x: &Tensor,
        pool: &Pool,
        shard_rows: usize,
    ) -> DofResult {
        // Compile once per (structure, operator); the program is
        // shard-invariant, so every shard executes the same plan.
        let program = plan::global_cache().get_or_compile(graph, &self.ldl, self.plan_options());
        self.execute_sharded(&program, graph, x, pool, shard_rows)
    }

    /// [`Self::compute_sharded`] over a precompiled program (the
    /// compile-once half already done by the caller).
    pub fn execute_sharded(
        &self,
        program: &OperatorProgram,
        graph: &Graph,
        x: &Tensor,
        pool: &Pool,
        shard_rows: usize,
    ) -> DofResult {
        let batch = x.dims()[0];
        let n = x.dims()[1];
        let ranges = parallel::split_rows(batch, shard_rows);
        if ranges.len() <= 1 {
            let serial = || self.execute(program, graph, x);
            // A 1-thread pool means genuinely serial, including the GEMMs.
            if pool.threads() == 1 {
                return parallel::with_serial_guard(serial);
            }
            return serial();
        }
        // Pack weight panels ONCE for the whole call and share them
        // read-only across shards — repacking per shard would undo the
        // point of packing.
        let panels = plan::pack_panels(program.steps(), graph);
        let shards = pool.run_sharded(ranges, |_, r| {
            let rows = r.end - r.start;
            let xs = Tensor::from_vec(&[rows, n], x.data()[r.start * n..r.end * n].to_vec());
            // Process-wide (not thread-local) slab storage: pool workers are
            // fresh scoped threads per region, so only the program-keyed
            // pool preserves the warmed slabs across bench reps / server
            // batches — and returns them exact-fit by (program, rows).
            let key = SlabKey {
                program: program.key().fingerprint,
                rows,
            };
            with_program_slab(key, |slab| {
                self.execute_with_slab(program, graph, &xs, &panels, slab)
            })
        });
        merge_dof_shards(shards, batch)
    }

    /// The **reference interpreter**: the original per-call graph walk with
    /// arena-recycled tangent storage and runtime liveness/FLOP accounting.
    /// It dispatches the same shared op kernels
    /// ([`crate::plan::kernels`]) as the planned executor
    /// ([`Self::execute`]) — one arithmetic definition, different storage
    /// policy — so `rust/tests/plan_equivalence.rs` and
    /// `rust/tests/cross_engine_fuzz.rs` assert the two agree bit for bit
    /// on values, `L[φ]`, FLOP counts, and peak tangent bytes. Kept as the
    /// differential-testing oracle (and as the spec of the runtime
    /// semantics the plan compiler precomputes analytically).
    pub fn compute_with_arena(
        &self,
        graph: &Graph,
        x: &Tensor,
        arena: &mut TangentArena,
    ) -> DofResult {
        let n = graph.input_dim();
        assert_eq!(self.ldl.n, n, "decomposition N != graph input dim");
        let batch = x.dims()[0];
        let r = self.ldl.rank();
        let signs = &self.ldl.d;
        let mut cost = Cost::zero();
        let mut peak = PeakTracker::new();

        let tau = graph.tau();
        let mut frees_at: Vec<Vec<usize>> = vec![Vec::new(); graph.len()];
        for i in 0..graph.len() {
            frees_at[tau[i]].push(i);
        }

        let mut states: Vec<Option<NodeState>> = (0..graph.len()).map(|_| None).collect();
        let mut in_off = 0usize;
        let out_id = graph.output();

        for j in 0..graph.len() {
            let node = graph.node(j);
            let st = match &node.op {
                Op::Input { dim } => {
                    // Active rows: rows of L with a nonzero entry in this
                    // input's column range (the §3.2 sparsity hook).
                    let active: Vec<usize> = if self.exploit_sparsity {
                        (0..r)
                            .filter(|&k| {
                                self.ldl.l.row(k)[in_off..in_off + dim]
                                    .iter()
                                    .any(|&v| v != 0.0)
                            })
                            .collect()
                    } else {
                        (0..r).collect()
                    };
                    let t = active.len();
                    // Scratch (non-zeroed) storage: input_seed fully assigns
                    // all three streams.
                    let mut v = arena.tensor_scratch(&[batch, *dim]);
                    let mut s = arena.tensor_scratch(&[batch, *dim]);
                    let mut g = arena.tangent_scratch(batch, t, *dim);
                    kernels::input_seed(
                        x,
                        in_off,
                        *dim,
                        batch,
                        self.b.as_deref(),
                        &self.ldl.l,
                        &active,
                        v.data_mut(),
                        s.data_mut(),
                        g.data.data_mut(),
                    );
                    in_off += dim;
                    NodeState { v, g, active, s }
                }
                Op::Linear { weight, bias } => {
                    let p = states[node.inputs[0]].as_ref().unwrap();
                    let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
                    let t = p.active.len();
                    // Shared fused-linear kernel (one stacked [v; s; G] GEMM)
                    // with arena storage: scratch (non-zeroed) buffers are
                    // safe because the kernel fully assigns or zero-fills
                    // every destination before reading.
                    let rows = batch * (t + 2);
                    let mut stacked = arena.tensor_scratch(&[rows, in_d]);
                    let mut out = arena.tensor_scratch(&[rows, out_d]);
                    let mut v = arena.tensor_scratch(&[batch, out_d]);
                    let mut s = arena.tensor_scratch(&[batch, out_d]);
                    let mut g = arena.tangent_scratch(batch, t, out_d);
                    kernels::linear_forward(
                        weight,
                        bias,
                        GemmPlan::choose(t + 2, in_d, out_d),
                        None,
                        batch,
                        t,
                        p.v.data(),
                        p.s.data(),
                        p.g.data.data(),
                        stacked.data_mut(),
                        out.data_mut(),
                        v.data_mut(),
                        s.data_mut(),
                        g.data.data_mut(),
                    );
                    cost.muls += (rows * out_d * in_d) as u64;
                    cost.adds += (batch * t * out_d * in_d) as u64;
                    let active = p.active.clone();
                    arena.put_tensor(stacked);
                    arena.put_tensor(out);
                    NodeState { v, g, active, s }
                }
                Op::Activation { act } => {
                    let p = states[node.inputs[0]].as_ref().unwrap();
                    let d = node.dim;
                    let t = p.active.len();
                    // Shared fused activation kernel (σ value sweep + one
                    // fused tangent/quad pass + scalar stream), arena
                    // storage.
                    let mut v = arena.tensor_scratch(&[batch, d]);
                    let mut s = arena.tensor_scratch(&[batch, d]);
                    let mut g = arena.tangent_scratch(batch, t, d);
                    kernels::activation_forward(
                        *act,
                        signs,
                        &p.active,
                        batch,
                        d,
                        p.v.data(),
                        p.s.data(),
                        p.g.data.data(),
                        v.data_mut(),
                        s.data_mut(),
                        g.data.data_mut(),
                    );
                    cost.muls += (batch * (2 * t * d + 2 * d)) as u64;
                    cost.adds += (batch * (t * d + d)) as u64;
                    NodeState {
                        v,
                        g,
                        active: p.active.clone(),
                        s,
                    }
                }
                Op::Slice { start, len } => {
                    let p = states[node.inputs[0]].as_ref().unwrap();
                    let t = p.active.len();
                    let mut v = arena.tensor(&[batch, *len]);
                    let mut s = arena.tensor(&[batch, *len]);
                    for b in 0..batch {
                        v.row_mut(b).copy_from_slice(&p.v.row(b)[*start..*start + *len]);
                        s.row_mut(b).copy_from_slice(&p.s.row(b)[*start..*start + *len]);
                    }
                    let mut g = arena.tangent(batch, t, *len);
                    for row in 0..batch * t {
                        g.data
                            .row_mut(row)
                            .copy_from_slice(&p.g.data.row(row)[*start..*start + *len]);
                    }
                    // Re-scan for rows that became all-zero after slicing
                    // (e.g. slicing one block out of a block-diagonal seed).
                    let (g, active) = if self.exploit_sparsity {
                        let active = p.active.clone();
                        compact_zero_rows(g, &active, arena)
                    } else {
                        (g, p.active.clone())
                    };
                    NodeState { v, g, active, s }
                }
                Op::Add | Op::Mul | Op::Concat => {
                    // Multi-parent ops: align parents onto the union of
                    // their active row sets first.
                    let parents: Vec<&NodeState> = node
                        .inputs
                        .iter()
                        .map(|&p| states[p].as_ref().unwrap())
                        .collect();
                    let union = union_active(parents.iter().map(|p| p.active.as_slice()));
                    let t = union.len();
                    let aligned: Vec<TangentBatch> = parents
                        .iter()
                        .map(|p| expand_to(&p.g, &p.active, &union, batch, arena))
                        .collect();
                    let st = match &node.op {
                        Op::Add => {
                            let mut v = parents[0].v.clone();
                            let mut s = parents[0].s.clone();
                            let mut gd = aligned[0].data.clone();
                            for (p, al) in parents.iter().zip(&aligned).skip(1) {
                                v = v.add(&p.v);
                                s = s.add(&p.s);
                                gd = gd.add(&al.data);
                                cost.adds += (gd.numel() + 2 * v.numel()) as u64;
                            }
                            NodeState {
                                v,
                                g: TangentBatch { data: gd, batch, t },
                                active: union,
                                s,
                            }
                        }
                        Op::Concat => {
                            let mut v = arena.tensor(&[batch, node.dim]);
                            let mut s = arena.tensor(&[batch, node.dim]);
                            let mut g = arena.tangent(batch, t, node.dim);
                            for b in 0..batch {
                                let mut off = 0;
                                for p in &parents {
                                    let pv = p.v.row(b);
                                    v.row_mut(b)[off..off + pv.len()].copy_from_slice(pv);
                                    let ps = p.s.row(b);
                                    s.row_mut(b)[off..off + ps.len()].copy_from_slice(ps);
                                    off += pv.len();
                                }
                            }
                            for row in 0..batch * t {
                                let mut off = 0;
                                for al in &aligned {
                                    let src = al.data.row(row);
                                    g.data.row_mut(row)[off..off + src.len()]
                                        .copy_from_slice(src);
                                    off += src.len();
                                }
                            }
                            NodeState { v, g, active: union, s }
                        }
                        Op::Mul => {
                            let k = parents.len();
                            let d = node.dim;
                            // Shared eq. 9 product-rule kernel (incl. the
                            // cross term) over the union-aligned tangents.
                            let mut v = arena.tensor_scratch(&[batch, d]);
                            let mut s = arena.tensor_scratch(&[batch, d]);
                            let mut g = arena.tangent_scratch(batch, t, d);
                            {
                                let pvals: Vec<&[f64]> =
                                    parents.iter().map(|p| p.v.data()).collect();
                                let psums: Vec<&[f64]> =
                                    parents.iter().map(|p| p.s.data()).collect();
                                let arefs: Vec<&[f64]> =
                                    aligned.iter().map(|a| a.data.data()).collect();
                                kernels::mul_forward(
                                    signs,
                                    &union,
                                    batch,
                                    d,
                                    &pvals,
                                    &psums,
                                    &arefs,
                                    v.data_mut(),
                                    s.data_mut(),
                                    g.data.data_mut(),
                                );
                            }
                            cost.muls += ((k - 1) * batch * d) as u64;
                            cost.muls += (batch * k * ((k - 1) * d + t * d + d)) as u64;
                            cost.muls +=
                                (batch * (k * (k - 1) / 2) * (t * d + 2 * d)) as u64;
                            NodeState { v, g, active: union, s }
                        }
                        _ => unreachable!(),
                    };
                    // The union-aligned scratch tangents are dead now; park
                    // their storage instead of dropping it.
                    for al in aligned {
                        arena.put_tangent(al);
                    }
                    st
                }
                Op::SumReduce => {
                    let p = states[node.inputs[0]].as_ref().unwrap();
                    let t = p.active.len();
                    let mut v = arena.tensor(&[batch, 1]);
                    let mut s = arena.tensor(&[batch, 1]);
                    for b in 0..batch {
                        v.set(b, 0, p.v.row(b).iter().sum());
                        s.set(b, 0, p.s.row(b).iter().sum());
                    }
                    let mut g = arena.tangent(batch, t, 1);
                    for row in 0..batch * t {
                        g.data.data_mut()[row] = p.g.data.row(row).iter().sum();
                    }
                    cost.adds += (p.g.data.numel() + 2 * p.v.numel()) as u64;
                    NodeState {
                        v,
                        g,
                        active: p.active.clone(),
                        s,
                    }
                }
            };

            peak.alloc(st.g.bytes());
            states[j] = Some(st);

            for &i in &frees_at[j] {
                if i == out_id {
                    continue;
                }
                if let Some(st) = states[i].take() {
                    peak.free(st.g.bytes());
                    // Logical free recorded above; the storage itself is
                    // parked for the next node's allocations.
                    arena.put_tangent(st.g);
                    arena.put_tensor(st.v);
                    arena.put_tensor(st.s);
                }
            }
        }

        let out_state = states[out_id].take().expect("graph has an output node");
        let NodeState {
            v: vals,
            g: out_tangent,
            active: out_active,
            s: mut op_vals,
        } = out_state;
        if let Some(c) = self.c {
            for b in 0..batch {
                for o in 0..op_vals.dims()[1] {
                    op_vals.set(b, o, op_vals.at(b, o) + c * vals.at(b, o));
                }
            }
            cost.muls += op_vals.numel() as u64;
        }

        DofResult {
            values: vals,
            out_tangent,
            out_active,
            operator_values: op_vals,
            cost,
            peak_tangent_bytes: peak.peak(),
        }
    }
}

/// Sorted union of active row sets.
fn union_active<'a>(sets: impl Iterator<Item = &'a [usize]>) -> Vec<usize> {
    let mut u: Vec<usize> = Vec::new();
    for s in sets {
        u.extend_from_slice(s);
    }
    u.sort_unstable();
    u.dedup();
    u
}

/// Expand a tangent from its own active layout to the union layout
/// (zero-fills missing rows).
fn expand_to(
    g: &TangentBatch,
    active: &[usize],
    union: &[usize],
    batch: usize,
    arena: &mut TangentArena,
) -> TangentBatch {
    if active.len() == union.len() && active == union {
        return g.clone();
    }
    let d = g.dim();
    let mut out = arena.tangent(batch, union.len(), d);
    // Map each own-row to its union position.
    for (kk, &k) in active.iter().enumerate() {
        let pos = union.binary_search(&k).expect("active ⊆ union");
        for b in 0..batch {
            out.row_mut(b, pos).copy_from_slice(g.row(b, kk));
        }
    }
    out
}

/// Drop tangent rows that are exactly zero across the batch, returning the
/// compacted tangent and its new active set.
fn compact_zero_rows(
    g: TangentBatch,
    active: &[usize],
    arena: &mut TangentArena,
) -> (TangentBatch, Vec<usize>) {
    let t = active.len();
    let batch = g.batch;
    let d = g.dim();
    let mut keep: Vec<usize> = Vec::with_capacity(t);
    for kk in 0..t {
        let mut nonzero = false;
        for b in 0..batch {
            if g.row(b, kk).iter().any(|&v| v != 0.0) {
                nonzero = true;
                break;
            }
        }
        if nonzero {
            keep.push(kk);
        }
    }
    if keep.len() == t {
        return (g, active.to_vec());
    }
    let mut out = arena.tangent(batch, keep.len(), d);
    let mut new_active = Vec::with_capacity(keep.len());
    for (nk, &kk) in keep.iter().enumerate() {
        new_active.push(active[kk]);
        for b in 0..batch {
            out.row_mut(b, nk).copy_from_slice(g.row(b, kk));
        }
    }
    arena.put_tangent(g);
    (out, new_active)
}

/// Stitch per-shard results back into one batch-ordered [`DofResult`].
///
/// Values and operator values are concatenated in shard order; the output
/// tangent is re-laid-out onto the union of the shards' active row sets
/// (shards of a block-sparse batch may have compacted different rows). The
/// cost is the exact sum over shards and the peak is the per-shard maximum —
/// the quantity Theorem 2.2 bounds for a shard-sized batch.
fn merge_dof_shards(shards: Vec<DofResult>, batch: usize) -> DofResult {
    let out_d = shards[0].values.dims()[1];
    let mut union: Vec<usize> = Vec::new();
    for s in &shards {
        union.extend_from_slice(&s.out_active);
    }
    union.sort_unstable();
    union.dedup();
    let t = union.len();

    let mut values = Tensor::zeros(&[batch, out_d]);
    let mut op_vals = Tensor::zeros(&[batch, out_d]);
    let mut out_tangent = TangentBatch::zeros(batch, t, out_d);
    let mut cost = Cost::zero();
    let mut peak = 0u64;
    let mut row = 0usize;
    for s in shards {
        let rows = s.values.dims()[0];
        values.data_mut()[row * out_d..(row + rows) * out_d].copy_from_slice(s.values.data());
        op_vals.data_mut()[row * out_d..(row + rows) * out_d]
            .copy_from_slice(s.operator_values.data());
        for b in 0..rows {
            for (kk, &kglob) in s.out_active.iter().enumerate() {
                let pos = union.binary_search(&kglob).expect("active ⊆ union");
                out_tangent
                    .row_mut(row + b, pos)
                    .copy_from_slice(s.out_tangent.row(b, kk));
            }
        }
        cost += s.cost;
        peak = peak.max(s.peak_tangent_bytes);
        row += rows;
    }
    DofResult {
        values,
        out_tangent,
        out_active: union,
        operator_values: op_vals,
        cost,
        peak_tangent_bytes: peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::hessian::HessianEngine;
    use crate::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act};
    use crate::operators::CoeffSpec;
    use crate::tensor::matmul;
    use crate::util::Xoshiro256;

    fn random_symmetric(n: usize, rng: &mut Xoshiro256) -> Tensor {
        let b = Tensor::randn(&[n, n], rng);
        b.add(&b.transpose()).scale(0.5)
    }

    /// DOF and the Hessian baseline must agree exactly (both are exact).
    #[test]
    fn dof_matches_hessian_general_operator_mlp() {
        let mut rng = Xoshiro256::new(41);
        let g = mlp_graph(&random_layers(&[6, 12, 10, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[5, 6], &mut rng);
        let a = random_symmetric(6, &mut rng);
        let dof = DofEngine::new(&a).compute(&g, &x);
        let hes = HessianEngine::new(&a).compute(&g, &x);
        for b in 0..5 {
            let dv = dof.operator_values.at(b, 0);
            let hv = hes.operator_values.at(b, 0);
            assert!(
                (dv - hv).abs() < 1e-8 * hv.abs().max(1.0),
                "b={b}: DOF {dv} vs Hessian {hv}"
            );
            assert!((dof.values.at(b, 0) - hes.values.at(b, 0)).abs() < 1e-12);
        }
    }

    #[test]
    fn dof_laplacian_matches_hessian_trace() {
        let mut rng = Xoshiro256::new(42);
        let g = mlp_graph(&random_layers(&[4, 9, 1], &mut rng), Act::Sin);
        let x = Tensor::randn(&[3, 4], &mut rng);
        let eye = Tensor::eye(4);
        let dof = DofEngine::new(&eye).compute(&g, &x);
        let hes = HessianEngine::new(&eye).compute(&g, &x);
        for b in 0..3 {
            let trace: f64 = (0..4).map(|i| hes.hessian.data()[(b * 4 + i) * 4 + i]).sum();
            assert!((dof.operator_values.at(b, 0) - trace).abs() < 1e-9);
        }
    }

    #[test]
    fn dof_matches_hessian_sparse_architecture() {
        let mut rng = Xoshiro256::new(43);
        let blocks: Vec<_> = (0..4)
            .map(|_| random_layers(&[2, 6, 3], &mut rng))
            .collect();
        let g = sparse_mlp_graph(&blocks, Act::Gelu);
        let x = Tensor::randn(&[4, 8], &mut rng).scale(0.4);
        let a = random_symmetric(8, &mut rng);
        let dof = DofEngine::new(&a).compute(&g, &x);
        let hes = HessianEngine::new(&a).compute(&g, &x);
        for b in 0..4 {
            let dv = dof.operator_values.at(b, 0);
            let hv = hes.operator_values.at(b, 0);
            assert!(
                (dv - hv).abs() < 1e-8 * hv.abs().max(1.0),
                "b={b}: {dv} vs {hv}"
            );
        }
    }

    /// Sparse vs dense mode must agree exactly; block-diagonal operators on
    /// the block architecture shrink the active width and the cost (§3.2).
    #[test]
    fn sparsity_exploitation_exact_and_cheaper() {
        let mut rng = Xoshiro256::new(49);
        let blocks_n = 4usize;
        let block_in = 3usize;
        let blocks: Vec<_> = (0..blocks_n)
            .map(|_| random_layers(&[block_in, 10, 4], &mut rng))
            .collect();
        let g = sparse_mlp_graph(&blocks, Act::Tanh);
        let x = Tensor::randn(&[3, blocks_n * block_in], &mut rng).scale(0.4);
        let a = CoeffSpec::BlockDiagGram {
            blocks: blocks_n,
            block: block_in,
            rank: block_in,
            seed: 5,
        }
        .build();
        let sparse = DofEngine::new(&a).compute(&g, &x);
        let dense = DofEngine::new(&a).dense().compute(&g, &x);
        for b in 0..3 {
            assert!(
                (sparse.operator_values.at(b, 0) - dense.operator_values.at(b, 0)).abs()
                    < 1e-9,
                "sparse and dense DOF disagree"
            );
        }
        assert!(
            sparse.cost.muls * 2 < dense.cost.muls,
            "sparsity should cut tangent work ≥2× here: {} vs {}",
            sparse.cost.muls,
            dense.cost.muls
        );
        assert!(sparse.peak_tangent_bytes < dense.peak_tangent_bytes);
    }

    #[test]
    fn low_rank_reduces_tangent_width_and_stays_exact() {
        let mut rng = Xoshiro256::new(44);
        let g = mlp_graph(&random_layers(&[8, 14, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[2, 8], &mut rng);
        let bmat = Tensor::randn(&[8, 3], &mut rng);
        let a = matmul(&bmat, &bmat.transpose());
        let eng = DofEngine::new(&a);
        assert_eq!(eng.rank(), 3, "tangent width should equal rank(A)");
        let dof = eng.compute(&g, &x);
        let hes = HessianEngine::new(&a).compute(&g, &x);
        for b in 0..2 {
            let dv = dof.operator_values.at(b, 0);
            let hv = hes.operator_values.at(b, 0);
            assert!((dv - hv).abs() < 1e-8 * hv.abs().max(1.0));
        }
    }

    #[test]
    fn lower_order_terms_compose() {
        let mut rng = Xoshiro256::new(45);
        let g = mlp_graph(&random_layers(&[5, 9, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[3, 5], &mut rng);
        let a = random_symmetric(5, &mut rng);
        let bvec: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let c = -1.7;
        let dof = DofEngine::new(&a)
            .with_lower_order(Some(bvec.clone()), Some(c))
            .compute(&g, &x);
        let hes = HessianEngine::new(&a)
            .with_lower_order(Some(bvec), Some(c))
            .compute(&g, &x);
        for b in 0..3 {
            let dv = dof.operator_values.at(b, 0);
            let hv = hes.operator_values.at(b, 0);
            assert!((dv - hv).abs() < 1e-8 * hv.abs().max(1.0), "{dv} vs {hv}");
        }
    }

    #[test]
    fn out_tangent_is_l_times_gradient() {
        let mut rng = Xoshiro256::new(46);
        let g = mlp_graph(&random_layers(&[4, 8, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[2, 4], &mut rng);
        let a = random_symmetric(4, &mut rng);
        let eng = DofEngine::new(&a);
        let dof = eng.compute(&g, &x);
        let grad = crate::autodiff::backward::input_gradient(&g, &x);
        for b in 0..2 {
            for (kk, &k) in dof.out_active.iter().enumerate() {
                let mut expect = 0.0;
                for i in 0..4 {
                    expect += eng.ldl.l.at(k, i) * grad.at(b, i);
                }
                let got = dof.out_tangent.row(b, kk)[0];
                assert!((got - expect).abs() < 1e-9, "b={b} k={k}: {got} vs {expect}");
            }
        }
    }

    /// Theorem 2.1 (measured): DOF muls ≤ ½ Hessian muls on the MLP.
    #[test]
    fn theorem21_flops_halved_on_mlp() {
        let mut rng = Xoshiro256::new(47);
        let g = mlp_graph(&random_layers(&[16, 64, 64, 64, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[1, 16], &mut rng);
        let a = random_symmetric(16, &mut rng);
        let dof = DofEngine::new(&a).compute(&g, &x);
        let hes = HessianEngine::new(&a).compute(&g, &x);
        assert!(
            2 * dof.cost.muls <= hes.cost.muls + hes.cost.muls / 10,
            "DOF muls {} vs Hessian muls {} — ratio {:.2}",
            dof.cost.muls,
            hes.cost.muls,
            hes.cost.muls as f64 / dof.cost.muls as f64
        );
    }

    /// Theorem 2.2 (measured): DOF peak tangent memory < Hessian's.
    #[test]
    fn theorem22_memory_smaller_on_mlp() {
        let mut rng = Xoshiro256::new(48);
        let g = mlp_graph(&random_layers(&[16, 64, 64, 64, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[1, 16], &mut rng);
        let a = random_symmetric(16, &mut rng);
        let dof = DofEngine::new(&a).compute(&g, &x);
        let hes = HessianEngine::new(&a).compute(&g, &x);
        assert!(
            dof.peak_tangent_bytes < hes.peak_tangent_bytes,
            "DOF peak {} !< Hessian peak {}",
            dof.peak_tangent_bytes,
            hes.peak_tangent_bytes
        );
    }
}
