//! Reverse-mode differentiation **through** the DOF forward pass — the
//! machinery that makes PINN training on `L[φ]`-based losses possible.
//!
//! A PINN loss is `ℓ(θ) = Σ_b w_b · (L[φ_θ](x_b) − f(x_b))² + …`, so the
//! optimizer needs `∂ℓ/∂θ` where `L[φ]` itself contains second derivatives
//! — a third-order quantity overall. The DOF pass is an ordinary (if
//! tuple-valued) computation graph, so we record it on a tape and run
//! reverse-mode over the tuple states `(v, g, s)` per node:
//!
//! * Linear `W`: all three streams are right-multiplications by `Wᵀ`;
//!   the weight adjoint accumulates `v̄'vᵀ + Σ_k ḡ'_k g_kᵀ + s̄'sᵀ`.
//! * Activation `σ(h)`: the eq. 9 term `σ''(h)·Σ_k d_k g_k²` differentiates
//!   to `σ'''(h)` w.r.t. `h` (hence [`crate::graph::Act::d3f`]) and to
//!   `2 d_k σ''(h) g_k` w.r.t. the tangent.
//! * `Mul` (Hadamard) closes the sparse architecture; adjoints of the
//!   leave-one-out products are assembled per component.
//!
//! The tape keeps every node tuple alive (unlike the benchmark engine,
//! which frees aggressively), trading Theorem 2.2's memory win for
//! trainability — the same trade PyTorch makes with `create_graph=True`.

use crate::graph::{Graph, Op};
use crate::linalg::LdlDecomposition;
use crate::plan::{self, OperatorProgram, PlanOptions};
use crate::tensor::{matmul, matmul_tn, Tensor};

use super::forward_jacobian::TangentBatch;
use super::Cost;

/// Recorded DOF forward pass: all per-node tuples retained.
pub struct DofTape {
    pub values: Vec<Tensor>,
    pub tangents: Vec<TangentBatch>,
    pub scalars: Vec<Tensor>,
    pub batch: usize,
    pub r: usize,
    pub cost: Cost,
}

/// Parameter gradients produced by the backward sweep: one entry per
/// Linear node, `(linear_index_in_graph_order, ∂W, ∂b)`.
pub struct DofGrads {
    pub by_linear: Vec<(usize, Tensor, Vec<f64>)>,
    pub cost: Cost,
}

/// Forward DOF pass that retains the full tape.
///
/// Compile-then-run wrapper: the schedule comes from the same
/// [`OperatorProgram`] the benchmark engines execute (fetched from
/// [`plan::global_cache`], so a training loop compiles once on step 1 and
/// hits the cache from step 2 onward — plan keys are weight-value
/// independent). Tape programs are compiled **dense** (`sparsity: false`):
/// the reverse sweep needs the full rank-`r` tangent at every node, the
/// same trade the pre-plan implementation made.
pub fn dof_forward_tape(
    graph: &Graph,
    ldl: &LdlDecomposition,
    b_coef: Option<&[f64]>,
    x: &Tensor,
) -> DofTape {
    let program = plan::global_cache().get_or_compile(
        graph,
        ldl,
        PlanOptions {
            sparsity: false,
            lower_order_c: false,
        },
    );
    dof_forward_tape_with_program(&program, graph, ldl, b_coef, x)
}

/// [`dof_forward_tape`] over a caller-held (dense) program.
pub fn dof_forward_tape_with_program(
    program: &OperatorProgram,
    graph: &Graph,
    ldl: &LdlDecomposition,
    b_coef: Option<&[f64]>,
    x: &Tensor,
) -> DofTape {
    plan::exec::execute_tape(program, graph, ldl, b_coef, x)
}

/// Reverse sweep over the tape.
///
/// `v_bar_out`, `s_bar_out` are the loss cotangents of the output node's
/// value and operator streams, each `[batch, out_dim]` (e.g. for an MSE
/// residual loss, `s_bar = 2(L[φ]−f)/batch` and `v_bar` carries any direct
/// value term). Returns per-Linear parameter gradients.
pub fn dof_backward_tape(
    graph: &Graph,
    ldl: &LdlDecomposition,
    tape: &DofTape,
    v_bar_out: &Tensor,
    s_bar_out: &Tensor,
) -> DofGrads {
    let batch = tape.batch;
    let r = tape.r;
    let mut cost = Cost::zero();
    let out_id = graph.output();

    // Cotangent state per node.
    let mut v_bar: Vec<Tensor> = graph
        .nodes()
        .iter()
        .map(|n| Tensor::zeros(&[batch, n.dim]))
        .collect();
    let mut g_bar: Vec<TangentBatch> = graph
        .nodes()
        .iter()
        .map(|n| TangentBatch::zeros(batch, r, n.dim))
        .collect();
    let mut s_bar: Vec<Tensor> = graph
        .nodes()
        .iter()
        .map(|n| Tensor::zeros(&[batch, n.dim]))
        .collect();
    v_bar[out_id] = v_bar_out.clone();
    s_bar[out_id] = s_bar_out.clone();

    let mut by_linear: Vec<(usize, Tensor, Vec<f64>)> = Vec::new();
    let mut linear_counter = graph
        .nodes()
        .iter()
        .filter(|n| matches!(n.op, Op::Linear { .. }))
        .count();

    for j in (0..graph.len()).rev() {
        let node = graph.node(j);
        let vb = v_bar[j].clone();
        let gb = g_bar[j].clone();
        let sb = s_bar[j].clone();
        match &node.op {
            Op::Input { .. } => {}
            Op::Linear { weight, .. } => {
                linear_counter -= 1;
                let p = node.inputs[0];
                // Stream adjoints: all three are  ā += ā' · W.
                v_bar[p] = v_bar[p].add(&matmul(&vb, weight));
                s_bar[p] = s_bar[p].add(&matmul(&sb, weight));
                g_bar[p].data = g_bar[p].data.add(&matmul(&gb.data, weight));
                let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
                cost.muls += ((batch * (r + 2)) * out_d * in_d) as u64;
                // Weight adjoint: v̄'vᵀ + Σ_k ḡ'_k g_kᵀ + s̄'sᵀ.
                let mut gw = matmul_tn(&vb, &tape.values[p]);
                gw = gw.add(&matmul_tn(&sb, &tape.scalars[p]));
                gw = gw.add(&matmul_tn(&gb.data, &tape.tangents[p].data));
                cost.muls += ((batch * (r + 2)) * out_d * in_d) as u64;
                let mut gbias = vec![0.0; out_d];
                for b in 0..batch {
                    for (gz, &v) in gbias.iter_mut().zip(vb.row(b)) {
                        *gz += v;
                    }
                }
                by_linear.push((linear_counter, gw, gbias));
            }
            Op::Activation { act } => {
                let p = node.inputs[0];
                let h = &tape.values[p];
                let gp = &tape.tangents[p];
                let sp = &tape.scalars[p];
                let d = node.dim;
                let d3 = |x: f64| -> f64 {
                    act.d3f(x).unwrap_or_else(|| {
                        panic!(
                            "training through DOF requires σ''' — activation {act:?} \
                             lacks a closed form (use tanh/sin/softplus)"
                        )
                    })
                };
                for b in 0..batch {
                    let hrow = h.row(b);
                    let df: Vec<f64> = hrow.iter().map(|&x| act.df(x)).collect();
                    let d2f: Vec<f64> = hrow.iter().map(|&x| act.d2f(x)).collect();
                    let d3f: Vec<f64> = hrow.iter().map(|&x| d3(x)).collect();
                    // quad_c = Σ_k d_k g_k²  (recompute from tape).
                    let mut quad = vec![0.0; d];
                    for k in 0..r {
                        let sign = ldl.d[k];
                        let row = gp.row(b, k);
                        for c in 0..d {
                            quad[c] += sign * row[c] * row[c];
                        }
                    }
                    // ḡ-weighted dot with g: Σ_k ḡ'_k g_k per component.
                    let mut gdot = vec![0.0; d];
                    for k in 0..r {
                        let grow = gp.row(b, k);
                        let gbrow = gb.row(b, k);
                        for c in 0..d {
                            gdot[c] += gbrow[c] * grow[c];
                        }
                    }
                    // h adjoint:
                    //   v̄'·σ'  +  (Σ_k ḡ'_k g_k)·σ''  +  s̄'·(σ'''·quad + σ''·s_p)
                    {
                        let vrow = vb.row(b).to_vec();
                        let srow = sb.row(b).to_vec();
                        let sprow = sp.row(b).to_vec();
                        let dst = v_bar[p].row_mut(b);
                        for c in 0..d {
                            dst[c] += vrow[c] * df[c]
                                + gdot[c] * d2f[c]
                                + srow[c] * (d3f[c] * quad[c] + d2f[c] * sprow[c]);
                        }
                    }
                    // tangent adjoint: ḡ_k += σ'·ḡ'_k + 2 d_k σ''·s̄'·g_k
                    for k in 0..r {
                        let sign = ldl.d[k];
                        let grow = gp.row(b, k).to_vec();
                        let gbrow = gb.row(b, k).to_vec();
                        let srow = sb.row(b).to_vec();
                        let dst = g_bar[p].row_mut(b, k);
                        for c in 0..d {
                            dst[c] += df[c] * gbrow[c]
                                + 2.0 * sign * d2f[c] * srow[c] * grow[c];
                        }
                    }
                    // scalar adjoint: s̄ += σ'·s̄'
                    {
                        let srow = sb.row(b).to_vec();
                        let dst = s_bar[p].row_mut(b);
                        for c in 0..d {
                            dst[c] += df[c] * srow[c];
                        }
                    }
                }
                cost.muls += (batch * d * (6 + 4 * r)) as u64;
            }
            Op::Slice { start, len } => {
                let p = node.inputs[0];
                for b in 0..batch {
                    let src = vb.row(b).to_vec();
                    let dst = v_bar[p].row_mut(b);
                    for c in 0..*len {
                        dst[*start + c] += src[c];
                    }
                    let src = sb.row(b).to_vec();
                    let dst = s_bar[p].row_mut(b);
                    for c in 0..*len {
                        dst[*start + c] += src[c];
                    }
                }
                for row in 0..batch * r {
                    let src = gb.data.row(row).to_vec();
                    let dst = g_bar[p].data.row_mut(row);
                    for c in 0..*len {
                        dst[*start + c] += src[c];
                    }
                }
            }
            Op::Add => {
                for &p in &node.inputs {
                    v_bar[p] = v_bar[p].add(&vb);
                    s_bar[p] = s_bar[p].add(&sb);
                    g_bar[p].data = g_bar[p].data.add(&gb.data);
                }
            }
            Op::Mul => {
                let k = node.inputs.len();
                let d = node.dim;
                for b in 0..batch {
                    let prows: Vec<Vec<f64>> = node
                        .inputs
                        .iter()
                        .map(|&p| tape.values[p].row(b).to_vec())
                        .collect();
                    // For each parent pi, adjoints of the three output
                    // streams w.r.t. (v^pi, g^pi, s^pi).
                    for pi in 0..k {
                        // coef = Π_{q≠pi} v^q.
                        let mut coef = vec![1.0; d];
                        for (qi, pr) in prows.iter().enumerate() {
                            if qi != pi {
                                for (c, &xv) in coef.iter_mut().zip(pr) {
                                    *c *= xv;
                                }
                            }
                        }
                        // --- value stream: v̄^pi += v̄'·coef ---
                        {
                            let vrow = vb.row(b).to_vec();
                            let dst = v_bar[node.inputs[pi]].row_mut(b);
                            for c in 0..d {
                                dst[c] += vrow[c] * coef[c];
                            }
                        }
                        // --- g' = Σ_p coef_p ⊙ g^p:
                        //       ḡ^pi += coef ⊙ ḡ';
                        //       v̄^pi += Σ_{p≠pi} (Π_{q≠p,pi} v^q) Σ_k ḡ'_k g^p_k
                        for kk in 0..r {
                            let gbrow = gb.row(b, kk).to_vec();
                            let dst = g_bar[node.inputs[pi]].row_mut(b, kk);
                            for c in 0..d {
                                dst[c] += coef[c] * gbrow[c];
                            }
                        }
                        for qi in 0..k {
                            if qi == pi {
                                continue;
                            }
                            // ∂coef_qi/∂v^pi = Π_{ri≠qi,pi} v^ri
                            let mut coef2 = vec![1.0; d];
                            for (ri, pr) in prows.iter().enumerate() {
                                if ri != qi && ri != pi {
                                    for (c, &xv) in coef2.iter_mut().zip(pr) {
                                        *c *= xv;
                                    }
                                }
                            }
                            let gq = &tape.tangents[node.inputs[qi]];
                            let mut acc = vec![0.0; d];
                            for kk in 0..r {
                                let gbrow = gb.row(b, kk);
                                let gqrow = gq.row(b, kk);
                                for c in 0..d {
                                    acc[c] += gbrow[c] * gqrow[c];
                                }
                            }
                            let dst = v_bar[node.inputs[pi]].row_mut(b);
                            for c in 0..d {
                                dst[c] += coef2[c] * acc[c];
                            }
                        }
                        // --- s' = Σ_p coef_p s^p + Σ_{p<q} 2·coef_pq·(g^pᵀDg^q):
                        // s̄^pi += coef ⊙ s̄'
                        {
                            let srow = sb.row(b).to_vec();
                            let dst = s_bar[node.inputs[pi]].row_mut(b);
                            for c in 0..d {
                                dst[c] += coef[c] * srow[c];
                            }
                        }
                        // v̄^pi += s̄'·[Σ_{q≠pi} (Π_{r≠pi,q}v^r)·s^q
                        //          + Σ_{q<t, q,t≠pi} 2(Π_{r≠pi,q,t}v^r)(g^qᵀDg^t)]
                        for qi in 0..k {
                            if qi == pi {
                                continue;
                            }
                            let mut coef2 = vec![1.0; d];
                            for (ri, pr) in prows.iter().enumerate() {
                                if ri != qi && ri != pi {
                                    for (c, &xv) in coef2.iter_mut().zip(pr) {
                                        *c *= xv;
                                    }
                                }
                            }
                            let sq = &tape.scalars[node.inputs[qi]];
                            let srow = sb.row(b).to_vec();
                            let dst = v_bar[node.inputs[pi]].row_mut(b);
                            for c in 0..d {
                                dst[c] += srow[c] * coef2[c] * sq.row(b)[c];
                            }
                        }
                        for qi in 0..k {
                            for ti in (qi + 1)..k {
                                if qi == pi || ti == pi {
                                    continue;
                                }
                                let mut coef3 = vec![1.0; d];
                                for (ri, pr) in prows.iter().enumerate() {
                                    if ri != qi && ri != ti && ri != pi {
                                        for (c, &xv) in coef3.iter_mut().zip(pr) {
                                            *c *= xv;
                                        }
                                    }
                                }
                                let gq = &tape.tangents[node.inputs[qi]];
                                let gt = &tape.tangents[node.inputs[ti]];
                                let mut cross = vec![0.0; d];
                                for kk in 0..r {
                                    let sign = ldl.d[kk];
                                    let gqrow = gq.row(b, kk);
                                    let gtrow = gt.row(b, kk);
                                    for c in 0..d {
                                        cross[c] += sign * gqrow[c] * gtrow[c];
                                    }
                                }
                                let srow = sb.row(b).to_vec();
                                let dst = v_bar[node.inputs[pi]].row_mut(b);
                                for c in 0..d {
                                    dst[c] += 2.0 * srow[c] * coef3[c] * cross[c];
                                }
                            }
                        }
                        // ḡ^pi += 2·s̄'·Σ_{q≠pi} coef_pq D g^q  (from the
                        // cross term with p = pi).
                        for qi in 0..k {
                            if qi == pi {
                                continue;
                            }
                            let mut coef2 = vec![1.0; d];
                            for (ri, pr) in prows.iter().enumerate() {
                                if ri != qi && ri != pi {
                                    for (c, &xv) in coef2.iter_mut().zip(pr) {
                                        *c *= xv;
                                    }
                                }
                            }
                            let gq = &tape.tangents[node.inputs[qi]];
                            let srow = sb.row(b).to_vec();
                            for kk in 0..r {
                                let sign = ldl.d[kk];
                                let gqrow = gq.row(b, kk).to_vec();
                                let dst = g_bar[node.inputs[pi]].row_mut(b, kk);
                                for c in 0..d {
                                    dst[c] += 2.0 * sign * srow[c] * coef2[c] * gqrow[c];
                                }
                            }
                        }
                    }
                }
                cost.muls += (batch * d * k * k * (r + k)) as u64;
            }
            Op::SumReduce => {
                let p = node.inputs[0];
                let pd = graph.node(p).dim;
                for b in 0..batch {
                    let v = vb.at(b, 0);
                    for c in v_bar[p].row_mut(b) {
                        *c += v;
                    }
                    let sv = sb.at(b, 0);
                    for c in s_bar[p].row_mut(b) {
                        *c += sv;
                    }
                    let _ = pd;
                }
                for row in 0..batch * r {
                    let v = gb.data.row(row)[0];
                    for c in g_bar[p].data.row_mut(row) {
                        *c += v;
                    }
                }
            }
            Op::Concat => {
                let mut off = 0;
                for &p in &node.inputs {
                    let pd = graph.node(p).dim;
                    for b in 0..batch {
                        let src = vb.row(b).to_vec();
                        let dst = v_bar[p].row_mut(b);
                        for c in 0..pd {
                            dst[c] += src[off + c];
                        }
                        let src = sb.row(b).to_vec();
                        let dst = s_bar[p].row_mut(b);
                        for c in 0..pd {
                            dst[c] += src[off + c];
                        }
                    }
                    for row in 0..batch * r {
                        let src = gb.data.row(row).to_vec();
                        let dst = g_bar[p].data.row_mut(row);
                        for c in 0..pd {
                            dst[c] += src[off + c];
                        }
                    }
                    off += pd;
                }
            }
        }
    }

    DofGrads { by_linear, cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act};
    use crate::util::Xoshiro256;

    /// ∂/∂θ of ℓ = Σ_b s^M_b  checked against finite differences of the
    /// DOF operator value (the core "train through the operator" test).
    #[test]
    fn tape_gradient_matches_fd_mlp() {
        let mut rng = Xoshiro256::new(71);
        let layers = random_layers(&[3, 6, 5, 1], &mut rng);
        let g = mlp_graph(&layers, Act::Tanh);
        let x = Tensor::randn(&[4, 3], &mut rng);
        let araw = Tensor::randn(&[3, 3], &mut rng);
        let a = araw.add(&araw.transpose()).scale(0.5);
        let ldl = LdlDecomposition::of(&a);

        let tape = dof_forward_tape(&g, &ldl, None, &x);
        let v_bar = Tensor::zeros(&[4, 1]);
        let s_bar = Tensor::full(&[4, 1], 1.0);
        let grads = dof_backward_tape(&g, &ldl, &tape, &v_bar, &s_bar);

        // FD on a few weight entries across layers.
        let h = 1e-6;
        let loss = |ls: &crate::graph::builder::LayerWeights| -> f64 {
            let g2 = mlp_graph(ls, Act::Tanh);
            let t = dof_forward_tape(&g2, &ldl, None, &x);
            t.scalars[g2.output()].sum()
        };
        for (li, wi, wj) in [(0usize, 1usize, 2usize), (1, 3, 4), (2, 0, 3)] {
            let base = layers[li].0.at(wi, wj);
            let mut lp = layers.clone();
            lp[li].0.set(wi, wj, base + h);
            let mut lm = layers.clone();
            lm[li].0.set(wi, wj, base - h);
            let fd = (loss(&lp) - loss(&lm)) / (2.0 * h);
            let got = grads
                .by_linear
                .iter()
                .find(|(i, _, _)| *i == li)
                .map(|(_, gw, _)| gw.at(wi, wj))
                .unwrap();
            assert!(
                (got - fd).abs() < 1e-4 * fd.abs().max(1.0),
                "layer {li} W[{wi}][{wj}]: {got} vs fd {fd}"
            );
        }
        // And a bias entry.
        let base = layers[0].1[2];
        let mut lp = layers.clone();
        lp[0].1[2] = base + h;
        let mut lm = layers.clone();
        lm[0].1[2] = base - h;
        let _fd_b = (loss(&lp) - loss(&lm)) / (2.0 * h);
        // Bias enters only via the value stream; with s̄-only cotangent its
        // gradient flows through h. Our by_linear bias adjoint tracks the
        // value-stream cotangent, which for an s̄-seeded loss is the
        // correct ∂ℓ/∂b because b shifts h. Verify:
        let got_b = grads
            .by_linear
            .iter()
            .find(|(i, _, _)| *i == 0)
            .map(|(_, _, gb)| gb[2])
            .unwrap();
        assert!(
            (got_b - _fd_b).abs() < 1e-4 * _fd_b.abs().max(1.0),
            "bias: {got_b} vs fd {_fd_b}"
        );
    }

    #[test]
    fn tape_gradient_matches_fd_sparse() {
        let mut rng = Xoshiro256::new(72);
        let blocks: Vec<_> = (0..3)
            .map(|_| random_layers(&[2, 4, 3], &mut rng))
            .collect();
        let g = sparse_mlp_graph(&blocks, Act::Sin);
        let x = Tensor::randn(&[2, 6], &mut rng).scale(0.5);
        let a = CoeffTest::block_diag(3, 2);
        let ldl = LdlDecomposition::of(&a);

        let tape = dof_forward_tape(&g, &ldl, None, &x);
        let grads = dof_backward_tape(
            &g,
            &ldl,
            &tape,
            &Tensor::zeros(&[2, 1]),
            &Tensor::full(&[2, 1], 1.0),
        );

        let h = 1e-6;
        let loss = |bls: &[crate::graph::builder::LayerWeights]| -> f64 {
            let g2 = sparse_mlp_graph(bls, Act::Sin);
            let t = dof_forward_tape(&g2, &ldl, None, &x);
            t.scalars[g2.output()].sum()
        };
        // Perturb weight in block 1, layer 0 — linear index: block 0 has 2
        // linears, so block1/layer0 is linear index 2.
        let base = blocks[1][0].0.at(1, 0);
        let mut bp = blocks.clone();
        bp[1][0].0.set(1, 0, base + h);
        let mut bm = blocks.clone();
        bm[1][0].0.set(1, 0, base - h);
        let fd = (loss(&bp) - loss(&bm)) / (2.0 * h);
        let got = grads
            .by_linear
            .iter()
            .find(|(i, _, _)| *i == 2)
            .map(|(_, gw, _)| gw.at(1, 0))
            .unwrap();
        assert!(
            (got - fd).abs() < 1e-4 * fd.abs().max(1.0),
            "{got} vs fd {fd}"
        );
    }

    /// Mixed v̄/s̄ cotangents: ℓ = Σ (v^M)² + Σ s^M.
    #[test]
    fn mixed_cotangents() {
        let mut rng = Xoshiro256::new(73);
        let layers = random_layers(&[2, 5, 1], &mut rng);
        let g = mlp_graph(&layers, Act::Softplus);
        let x = Tensor::randn(&[3, 2], &mut rng);
        let ldl = LdlDecomposition::of(&Tensor::eye(2));
        let tape = dof_forward_tape(&g, &ldl, None, &x);
        let out = g.output();
        let v_bar = tape.values[out].scale(2.0); // ∂(v²)/∂v
        let s_bar = Tensor::full(&[3, 1], 1.0);
        let grads = dof_backward_tape(&g, &ldl, &tape, &v_bar, &s_bar);

        let h = 1e-6;
        let loss = |ls: &crate::graph::builder::LayerWeights| -> f64 {
            let g2 = mlp_graph(ls, Act::Softplus);
            let t = dof_forward_tape(&g2, &ldl, None, &x);
            t.values[g2.output()].norm_sq() + t.scalars[g2.output()].sum()
        };
        let base = layers[0].0.at(2, 1);
        let mut lp = layers.clone();
        lp[0].0.set(2, 1, base + h);
        let mut lm = layers.clone();
        lm[0].0.set(2, 1, base - h);
        let fd = (loss(&lp) - loss(&lm)) / (2.0 * h);
        let got = grads
            .by_linear
            .iter()
            .find(|(i, _, _)| *i == 0)
            .map(|(_, gw, _)| gw.at(2, 1))
            .unwrap();
        assert!((got - fd).abs() < 1e-4 * fd.abs().max(1.0), "{got} vs {fd}");
    }

    /// Helper to build small block-diagonal test matrices.
    struct CoeffTest;
    impl CoeffTest {
        fn block_diag(blocks: usize, block: usize) -> Tensor {
            let n = blocks * block;
            let mut rng = Xoshiro256::new(99);
            let mut a = Tensor::zeros(&[n, n]);
            for l in 0..blocks {
                let b = Tensor::randn(&[block, block], &mut rng);
                let g = crate::tensor::matmul(&b, &b.transpose());
                for i in 0..block {
                    for j in 0..block {
                        a.set(l * block + i, l * block + j, g.at(i, j));
                    }
                }
            }
            a
        }
    }
}
