//! Analytic FLOP accounting — the `|E|`, `|R|`, `|T|` machinery of
//! Appendix B and the closed-form costs of both methods.
//!
//! Definitions (scalar-level, eq. 15):
//!
//! * `|E|` — scalar edges of the computation graph `G`;
//! * `T = {(i,l,j) | i→j, l→j, ∂²F_j/∂vⁱ∂vˡ ≠ 0}`;
//! * `R = {(i,l) | ∃j. (i,l,j) ∈ T}`.
//!
//! Costs (multiplications only, as in the paper):
//!
//! * Hessian-based: `N(|R| + 2|E|) + 0.5|T|`
//! * DOF:           `r(0.5|R| + |E|) + 0.5|T|`  (`r = rank(D)`; the paper
//!   states `0.5·N(|R|+2|E|) + 0.5|T|` for full rank and notes the `r/N`
//!   reduction for low-rank `A`)

use crate::graph::{Graph, Op};

/// Scalar-level structural counts of a graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GraphCounts {
    /// Scalar edges `|E|`.
    pub edges: u64,
    /// `|R|` — scalar pairs with a nonzero second derivative at some op.
    pub r_pairs: u64,
    /// `|T|` — scalar triples with a nonzero second derivative.
    pub t_triples: u64,
    /// Scalar node count `|V|` (internal nodes).
    pub scalar_nodes: u64,
}

/// Compute the structural counts for a graph.
///
/// Per-op contributions (node output dim `d`, parent dims `d_p`):
///
/// * `Linear (out×in)`: `out·in` edges, no `T`/`R` (zero second derivative);
/// * `Activation`: `d` edges; diagonal second derivative ⇒ `d` triples
///   `(i,i,i)` and `d` pairs;
/// * `Add`/`Concat`/`Slice`/`SumReduce`: edges only;
/// * `Mul` (k parents): `k·d` edges; nonzero cross second derivatives for
///   each unordered parent pair per component: `k(k−1)·d` ordered triples,
///   same count of ordered pairs.
pub fn graph_counts(graph: &Graph) -> GraphCounts {
    let mut edges = 0u64;
    let mut r_pairs = 0u64;
    let mut t_triples = 0u64;
    let mut scalar_nodes = 0u64;
    for node in graph.nodes() {
        let d = node.dim as u64;
        scalar_nodes += d;
        match &node.op {
            Op::Input { .. } => {}
            Op::Linear { weight, .. } => {
                edges += (weight.dims()[0] * weight.dims()[1]) as u64;
            }
            Op::Activation { act } => {
                edges += d;
                if !act.is_linear() {
                    r_pairs += d;
                    t_triples += d;
                }
            }
            Op::Slice { len, .. } => {
                edges += *len as u64;
            }
            Op::Add => {
                edges += node.inputs.len() as u64 * d;
            }
            Op::Mul => {
                let k = node.inputs.len() as u64;
                edges += k * d;
                r_pairs += k * (k - 1) * d;
                t_triples += k * (k - 1) * d;
            }
            Op::SumReduce => {
                edges += graph.node(node.inputs[0]).dim as u64;
            }
            Op::Concat => {
                edges += d;
            }
        }
    }
    GraphCounts {
        edges,
        r_pairs,
        t_triples,
        scalar_nodes,
    }
}

/// Closed-form cost model for a graph/operator pairing.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub counts: GraphCounts,
    /// Input dimension `N`.
    pub n: u64,
    /// Tangent width `r = rank(A)` used by DOF.
    pub r: u64,
}

impl CostModel {
    pub fn new(graph: &Graph, rank: usize) -> Self {
        Self {
            counts: graph_counts(graph),
            n: graph.input_dim() as u64,
            r: rank as u64,
        }
    }

    /// Appendix B: Hessian-based method ≈ `N(|R| + 2|E|) + 0.5|T|` muls.
    pub fn hessian_muls(&self) -> u64 {
        self.n * (self.counts.r_pairs + 2 * self.counts.edges) + self.counts.t_triples / 2
    }

    /// Appendix B: DOF ≈ `r·(0.5|R| + |E|) + 0.5|T|` muls.
    pub fn dof_muls(&self) -> u64 {
        self.r * (self.counts.r_pairs / 2 + self.counts.edges) + self.counts.t_triples / 2
    }

    /// Predicted speedup factor (≥ 2 per Theorem 2.1 when `r = N`).
    pub fn predicted_ratio(&self) -> f64 {
        self.hessian_muls() as f64 / self.dof_muls() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act};
    use crate::util::Xoshiro256;

    /// Appendix B closed form for a plain MLP with our op granularity:
    /// |E| = Σ_l N_l·N_{l+1} (affine edges) + Σ activations; |R| = |T| =
    /// Σ hidden activations (diagonal).
    #[test]
    fn mlp_counts_match_closed_form() {
        let mut rng = Xoshiro256::new(51);
        let dims = [64usize, 256, 256, 256, 1];
        let g = mlp_graph(&random_layers(&dims, &mut rng), Act::Tanh);
        let c = graph_counts(&g);
        let affine_edges: u64 = dims.windows(2).map(|w| (w[0] * w[1]) as u64).sum();
        let act_scalars: u64 = dims[1..dims.len() - 1].iter().map(|&d| d as u64).sum();
        assert_eq!(c.edges, affine_edges + act_scalars);
        assert_eq!(c.r_pairs, act_scalars);
        assert_eq!(c.t_triples, act_scalars);
        // |V| = input + all linears + all activations
        let v: u64 = dims[0] as u64
            + dims[1..].iter().map(|&d| d as u64).sum::<u64>()
            + act_scalars;
        assert_eq!(c.scalar_nodes, v);
    }

    #[test]
    fn theorem21_analytic_ratio_at_least_two() {
        let mut rng = Xoshiro256::new(52);
        let g = mlp_graph(&random_layers(&[64, 256, 256, 256, 1], &mut rng), Act::Tanh);
        let m = CostModel::new(&g, 64); // full-rank operator
        // The shared 0.5|T| term makes the ratio approach 2 from below as
        // |T| ≪ N|E| (Appendix B's "about two times faster"); with the
        // affine/elementwise decomposition |T| is tiny, so ≥ 1.99 here.
        assert!(
            m.predicted_ratio() >= 1.99,
            "ratio {:.4}",
            m.predicted_ratio()
        );
    }

    #[test]
    fn low_rank_ratio_scales_with_rank() {
        let mut rng = Xoshiro256::new(53);
        let g = mlp_graph(&random_layers(&[64, 256, 256, 1], &mut rng), Act::Tanh);
        let full = CostModel::new(&g, 64).predicted_ratio();
        let half = CostModel::new(&g, 32).predicted_ratio();
        // Halving the rank should roughly double the advantage.
        assert!(half > 1.8 * full, "full {full:.2}, half {half:.2}");
    }

    #[test]
    fn analytic_model_tracks_measured_dof_cost() {
        // The engine's measured muls should be within ~25% of the analytic
        // model (the model ignores value-pass and bookkeeping terms).
        use crate::autodiff::dof::DofEngine;
        use crate::tensor::Tensor;
        let mut rng = Xoshiro256::new(54);
        let g = mlp_graph(&random_layers(&[16, 64, 64, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[1, 16], &mut rng);
        let a = Tensor::eye(16);
        let res = DofEngine::new(&a).compute(&g, &x);
        let model = CostModel::new(&g, 16);
        let predicted = model.dof_muls() as f64;
        let measured = res.cost.muls as f64;
        let ratio = measured / predicted;
        assert!(
            (0.8..1.4).contains(&ratio),
            "measured {measured} vs predicted {predicted} (ratio {ratio:.3})"
        );
    }

    #[test]
    fn sparse_architecture_counts() {
        let mut rng = Xoshiro256::new(55);
        let blocks: Vec<_> = (0..4)
            .map(|_| random_layers(&[2, 8, 3], &mut rng))
            .collect();
        let g = sparse_mlp_graph(&blocks, Act::Tanh);
        let c = graph_counts(&g);
        // Mul node over 4 parents of dim 3: edges 12, pairs/triples 4·3·3=36.
        assert!(c.r_pairs >= 36);
        assert!(c.edges > 0);
    }
}
