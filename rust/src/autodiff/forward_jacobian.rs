//! Forward-mode tangent propagation (eq. 13 for the full Jacobian seed,
//! eq. 17 for the DOF seed `g = L∇v`).
//!
//! A node's tangent is a matrix `G ∈ R^{t×d}` per batch point, where `t` is
//! the tangent width (`N` for the full gradient, `rank(A)` for DOF) and `d`
//! the node dimension. Batched storage folds the batch and tangent axes
//! into rows: `[batch·t, d]` with row index `b·t + k`, so the hot operation
//! — pushing a tangent through a Linear node — is a single `[batch·t, in] ×
//! [out, in]ᵀ` GEMM.

use crate::graph::{Node, Op};
use crate::graph::Graph;
use crate::tensor::{matmul_nt, Tensor};

use super::Cost;

/// Batched tangent block for one node: rows are `(batch, tangent-row)`
/// pairs, columns are node components.
#[derive(Debug, Clone)]
pub struct TangentBatch {
    /// `[batch·t, d]`.
    pub data: Tensor,
    pub batch: usize,
    /// Tangent width `t`.
    pub t: usize,
}

impl TangentBatch {
    pub fn zeros(batch: usize, t: usize, dim: usize) -> Self {
        Self {
            data: Tensor::zeros(&[batch * t, dim]),
            batch,
            t,
        }
    }

    pub fn dim(&self) -> usize {
        self.data.dims()[1]
    }

    /// Bytes of the underlying buffer (f64).
    pub fn bytes(&self) -> u64 {
        (self.data.numel() * std::mem::size_of::<f64>()) as u64
    }

    /// Row of the tangent for batch point `b`, tangent index `k`.
    pub fn row(&self, b: usize, k: usize) -> &[f64] {
        self.data.row(b * self.t + k)
    }

    pub fn row_mut(&mut self, b: usize, k: usize) -> &mut [f64] {
        self.data.row_mut(b * self.t + k)
    }

    /// Extract the `t×d` tangent matrix of one batch point.
    pub fn point(&self, b: usize) -> Tensor {
        let d = self.dim();
        let mut m = Tensor::zeros(&[self.t, d]);
        for k in 0..self.t {
            m.row_mut(k).copy_from_slice(self.row(b, k));
        }
        m
    }
}

/// Seed tangent for an input node spanning flat-input coordinates
/// `[offset, offset+dim)`: `G[k, j] = seed[k, offset + j]`, replicated
/// across the batch. `seed` is the `t×N` seed matrix (`I_N` for the full
/// Jacobian, `L` for DOF).
pub fn seed_input(seed: &Tensor, offset: usize, dim: usize, batch: usize) -> TangentBatch {
    let t = seed.dims()[0];
    let mut g = TangentBatch::zeros(batch, t, dim);
    for b in 0..batch {
        for k in 0..t {
            g.row_mut(b, k)
                .copy_from_slice(&seed.row(k)[offset..offset + dim]);
        }
    }
    g
}

/// Propagate a tangent through one node given parent tangents and parent
/// *values* (`vals[p]` is `[batch, dim_p]`). Returns the node tangent and
/// the exact multiplication/addition cost of the propagation (eq. 17's
/// `t·|E|`-type terms).
///
/// `node_val` is the node's own value tensor (needed by none of the ops
/// here but kept in the signature for symmetry with the DOF scalar rule).
pub fn propagate_tangent(
    node: &Node,
    parent_tangents: &[&TangentBatch],
    parent_vals: &[&Tensor],
    cost: &mut Cost,
) -> TangentBatch {
    match &node.op {
        Op::Input { .. } => unreachable!("inputs are seeded, not propagated"),
        Op::Linear { weight, .. } => {
            let g = parent_tangents[0];
            // G' = G Wᵀ — one GEMM over folded rows.
            let out = matmul_nt(&g.data, weight);
            let (rows, k, m) = (g.data.dims()[0], weight.dims()[1], weight.dims()[0]);
            cost.muls += (rows * k * m) as u64;
            cost.adds += (rows * k * m) as u64;
            TangentBatch {
                data: out,
                batch: g.batch,
                t: g.t,
            }
        }
        Op::Activation { act } => {
            let g = parent_tangents[0];
            let h = parent_vals[0]; // pre-activation values [batch, d]
            let d = node.dim;
            // Shared σ'-scaling kernel (also run by the program-scheduled
            // Hessian slab executor).
            let mut out = TangentBatch::zeros(g.batch, g.t, d);
            crate::plan::kernels::jac_activation(
                *act,
                g.batch,
                g.t,
                d,
                h.data(),
                g.data.data(),
                out.data.data_mut(),
            );
            // σ'(h) evaluated once per (b, j); the scaling is t·d muls per
            // batch point. We charge only the scaling (σ' itself is shared
            // with the value pass in a fused implementation).
            cost.muls += (g.batch * g.t * d) as u64;
            out
        }
        Op::Slice { start, len } => {
            let g = parent_tangents[0];
            let mut out = TangentBatch::zeros(g.batch, g.t, *len);
            for r in 0..g.batch * g.t {
                out.data
                    .row_mut(r)
                    .copy_from_slice(&g.data.row(r)[*start..*start + *len]);
            }
            out
        }
        Op::Add => {
            let mut out = parent_tangents[0].clone();
            for g in &parent_tangents[1..] {
                out.data = out.data.add(&g.data);
                cost.adds += out.data.numel() as u64;
            }
            out
        }
        Op::Mul => {
            // v = Π_p v^p ⇒ g'_j = Σ_p (Π_{q≠p} v^q_j) g^p_j — the shared
            // first-order product-rule kernel (also run by the
            // program-scheduled Hessian slab executor).
            let k = parent_tangents.len();
            let batch = parent_tangents[0].batch;
            let t = parent_tangents[0].t;
            let d = node.dim;
            let mut out = TangentBatch::zeros(batch, t, d);
            let pvals: Vec<&[f64]> = parent_vals.iter().map(|v| v.data()).collect();
            let ptans: Vec<&[f64]> = parent_tangents.iter().map(|g| g.data.data()).collect();
            crate::plan::kernels::jac_mul(batch, t, d, &pvals, &ptans, out.data.data_mut());
            cost.muls += (batch * k * ((k - 1) * d + t * d)) as u64;
            cost.adds += (batch * k * t * d) as u64;
            out
        }
        Op::SumReduce => {
            let g = parent_tangents[0];
            let mut out = TangentBatch::zeros(g.batch, g.t, 1);
            for r in 0..g.batch * g.t {
                out.data.data_mut()[r] = g.data.row(r).iter().sum();
            }
            cost.adds += g.data.numel() as u64;
            out
        }
        Op::Concat => {
            let batch = parent_tangents[0].batch;
            let t = parent_tangents[0].t;
            let mut out = TangentBatch::zeros(batch, t, node.dim);
            for r in 0..batch * t {
                let mut off = 0;
                for g in parent_tangents {
                    let src = g.data.row(r);
                    out.data.row_mut(r)[off..off + src.len()].copy_from_slice(src);
                    off += src.len();
                }
            }
            out
        }
    }
}

/// Compute the full Jacobian `∂φ/∂x ∈ R^{batch × out × N}` of a graph by
/// seeding with `I_N` and propagating forward. Returns per-node tangents as
/// well (used by the Hessian engine) and the cost.
pub struct ForwardJacobian {
    /// Tangent of every node (`t = N`).
    pub tangents: Vec<TangentBatch>,
    /// Node values.
    pub values: Vec<Tensor>,
    pub cost: Cost,
}

/// Run the forward-Jacobian pass with an arbitrary seed matrix `seed ∈
/// R^{t×N}` (use `I_N` for the true Jacobian, `L` for the DOF tangent).
pub fn forward_with_seed(graph: &Graph, x: &Tensor, seed: &Tensor) -> ForwardJacobian {
    assert_eq!(seed.dims()[1], graph.input_dim(), "seed width must be N");
    let batch = x.dims()[0];
    let values = graph.eval_all(x);
    let mut cost = Cost::zero();
    let mut tangents: Vec<TangentBatch> = Vec::with_capacity(graph.len());
    let mut in_off = 0usize;
    for (id, node) in graph.nodes().iter().enumerate() {
        let g = match &node.op {
            Op::Input { dim } => {
                let g = seed_input(seed, in_off, *dim, batch);
                in_off += dim;
                g
            }
            _ => {
                let pts: Vec<&TangentBatch> = node.inputs.iter().map(|&p| &tangents[p]).collect();
                let pvs: Vec<&Tensor> = node.inputs.iter().map(|&p| &values[p]).collect();
                propagate_tangent(node, &pts, &pvs, &mut cost)
            }
        };
        debug_assert_eq!(g.dim(), node.dim, "node {id} tangent dim");
        tangents.push(g);
    }
    ForwardJacobian {
        tangents,
        values,
        cost,
    }
}

/// Jacobian of the output node, shape `[batch, out_dim, N]`.
pub fn jacobian(graph: &Graph, x: &Tensor) -> Tensor {
    let n = graph.input_dim();
    let fj = forward_with_seed(graph, x, &Tensor::eye(n));
    let out = &fj.tangents[graph.output()];
    let batch = out.batch;
    let d = out.dim();
    let mut j = Tensor::zeros(&[batch, d, n]);
    for b in 0..batch {
        for k in 0..n {
            for c in 0..d {
                let idx = (b * d + c) * n + k;
                j.data_mut()[idx] = out.row(b, k)[c];
            }
        }
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act};
    use crate::util::Xoshiro256;

    /// Finite-difference Jacobian of the graph output (scalar outputs).
    fn fd_jacobian(graph: &Graph, x: &[f64]) -> Vec<f64> {
        let n = x.len();
        let h = 1e-6;
        let mut jac = vec![0.0; n];
        for i in 0..n {
            let mut xp = x.to_vec();
            let mut xm = x.to_vec();
            xp[i] += h;
            xm[i] -= h;
            let fp = graph.eval(&Tensor::from_vec(&[1, n], xp)).item();
            let fm = graph.eval(&Tensor::from_vec(&[1, n], xm)).item();
            jac[i] = (fp - fm) / (2.0 * h);
        }
        jac
    }

    #[test]
    fn jacobian_matches_finite_difference_mlp() {
        let mut rng = Xoshiro256::new(4);
        let g = mlp_graph(&random_layers(&[5, 9, 7, 1], &mut rng), Act::Tanh);
        let x: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let j = jacobian(&g, &Tensor::from_vec(&[1, 5], x.clone()));
        let fd = fd_jacobian(&g, &x);
        for i in 0..5 {
            assert!(
                (j.data()[i] - fd[i]).abs() < 1e-6,
                "∂φ/∂x_{i}: {} vs {}",
                j.data()[i],
                fd[i]
            );
        }
    }

    #[test]
    fn jacobian_matches_finite_difference_sparse() {
        let mut rng = Xoshiro256::new(5);
        let blocks: Vec<_> = (0..3)
            .map(|_| random_layers(&[2, 6, 4], &mut rng))
            .collect();
        let g = sparse_mlp_graph(&blocks, Act::Sin);
        let x: Vec<f64> = (0..6).map(|_| 0.5 * rng.normal()).collect();
        let j = jacobian(&g, &Tensor::from_vec(&[1, 6], x.clone()));
        let fd = fd_jacobian(&g, &x);
        for i in 0..6 {
            assert!(
                (j.data()[i] - fd[i]).abs() < 1e-5,
                "∂φ/∂x_{i}: {} vs {}",
                j.data()[i],
                fd[i]
            );
        }
    }

    #[test]
    fn seeded_tangent_is_seed_times_jacobian() {
        // g^M = seed · (∂φ/∂x)ᵀ — check against full Jacobian.
        let mut rng = Xoshiro256::new(6);
        let g = mlp_graph(&random_layers(&[4, 8, 1], &mut rng), Act::Gelu);
        let x = Tensor::randn(&[3, 4], &mut rng);
        let seed = Tensor::randn(&[2, 4], &mut rng); // t=2
        let fj = forward_with_seed(&g, &x, &seed);
        let out = &fj.tangents[g.output()];
        let jac = jacobian(&g, &x);
        for b in 0..3 {
            for k in 0..2 {
                let mut expect = 0.0;
                for i in 0..4 {
                    expect += seed.at(k, i) * jac.data()[b * 4 + i];
                }
                let got = out.row(b, k)[0];
                assert!((got - expect).abs() < 1e-10, "b={b} k={k}: {got} vs {expect}");
            }
        }
    }

    #[test]
    fn linear_cost_counted() {
        let mut rng = Xoshiro256::new(7);
        let g = mlp_graph(&random_layers(&[3, 5, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[1, 3], &mut rng);
        let fj = forward_with_seed(&g, &x, &Tensor::eye(3));
        // Linear1: 3·(3·5); act: 3·5; Linear2: 3·(5·1) muls.
        assert_eq!(fj.cost.muls, 3 * 15 + 15 + 3 * 5);
    }
}
