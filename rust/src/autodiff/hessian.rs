//! The Hessian-based baseline (Appendix B, eqs. 12–14).
//!
//! This is what standard AutoDiff packages do for `Σ a_ij ∂²_ij φ`:
//!
//! 1. forward pass for values;
//! 2. forward-mode Jacobian `∇vⁱ` seeded with `I_N` (eq. 13);
//! 3. reverse pass for adjoints `v̄ⁱ = ∂φ/∂vⁱ` (eq. 12);
//! 4. a second-order reverse sweep propagating `∇v̄ⁱ` (eq. 14), whose value
//!    at the input nodes is the full Hessian `H = ∇²φ`;
//! 5. contraction `Σ_ij a_ij H_ij`.
//!
//! The engine tracks the exact multiplication count and — via
//! [`PeakTracker`] — the peak number of live tangent bytes, which is the
//! quantity Theorem 2.2 bounds. All `∇vⁱ` must stay alive across the
//! reverse sweep (the `∇v̄` recursion consumes them), which is why this
//! method's peak memory exceeds `N·|V|` (Appendix D).

use crate::graph::{Graph, Op};
use crate::parallel::{self, Pool};
use crate::plan::OperatorProgram;
use crate::tensor::{matmul, Tensor};

use super::backward::backward;
use super::forward_jacobian::{forward_with_seed, TangentBatch};
use super::memory::PeakTracker;
use super::Cost;

/// Hessian-based operator evaluation.
pub struct HessianEngine {
    /// Symmetric coefficient matrix `A ∈ R^{N×N}`.
    pub a: Tensor,
    /// Optional first-order coefficients `b ∈ R^N`.
    pub b: Option<Vec<f64>>,
    /// Optional zeroth-order coefficient `c`.
    pub c: Option<f64>,
}

/// Output of [`HessianEngine::compute`].
pub struct HessianResult {
    /// `φ(x)`, `[batch, 1]`.
    pub values: Tensor,
    /// `∇φ(x)`, `[batch, N]`.
    pub gradient: Tensor,
    /// Full Hessian `∇²φ(x)`, `[batch, N, N]`.
    pub hessian: Tensor,
    /// `L[φ](x)`, `[batch, 1]`.
    pub operator_values: Tensor,
    /// Exact FLOP count of the run.
    pub cost: Cost,
    /// Peak live tangent bytes (the Theorem 2.2 `M₂` measurement).
    pub peak_tangent_bytes: u64,
}

impl HessianEngine {
    /// Engine for the pure second-order operator `Σ a_ij ∂²_ij`.
    pub fn new(a: &Tensor) -> Self {
        assert_eq!(a.rank(), 2);
        assert_eq!(a.dims()[0], a.dims()[1]);
        Self {
            a: a.clone(),
            b: None,
            c: None,
        }
    }

    /// Add first-order (`Σ b_i ∂_i`) and zeroth-order (`c·`) terms.
    pub fn with_lower_order(mut self, b: Option<Vec<f64>>, c: Option<f64>) -> Self {
        if let Some(ref bv) = b {
            assert_eq!(bv.len(), self.a.dims()[0]);
        }
        self.b = b;
        self.c = c;
        self
    }

    /// [`Self::compute`] sharded across the process-wide pool (`--threads` /
    /// `DOF_THREADS`) in [`parallel::DEFAULT_SHARD_ROWS`]-row chunks.
    pub fn compute_parallel(&self, graph: &Graph, x: &Tensor) -> HessianResult {
        self.compute_sharded(graph, x, &parallel::global(), parallel::DEFAULT_SHARD_ROWS)
    }

    /// Evaluate `L[φ]` with the batch partitioned into fixed `shard_rows`-row
    /// chunks executed across `pool`. Same determinism contract as
    /// [`crate::autodiff::DofEngine::compute_sharded`]: shard boundaries are
    /// thread-count-independent, reduction is shard-ordered, and the Hessian
    /// method's per-row passes (forward Jacobian, reverse adjoints, the
    /// eq. 14 sweep) are row-independent, so results are bit-identical
    /// across thread counts.
    pub fn compute_sharded(
        &self,
        graph: &Graph,
        x: &Tensor,
        pool: &Pool,
        shard_rows: usize,
    ) -> HessianResult {
        self.execute_sharded(None, graph, x, pool, shard_rows)
    }

    /// [`Self::compute_sharded`] over a caller-held [`OperatorProgram`]
    /// (typically shared with the DOF engine through the plan cache): the
    /// program is compiled once and every shard reuses its metadata and
    /// cached Jacobian seed.
    pub fn compute_sharded_with_program(
        &self,
        program: &OperatorProgram,
        graph: &Graph,
        x: &Tensor,
        pool: &Pool,
        shard_rows: usize,
    ) -> HessianResult {
        self.execute_sharded(Some(program), graph, x, pool, shard_rows)
    }

    fn execute_sharded(
        &self,
        program: Option<&OperatorProgram>,
        graph: &Graph,
        x: &Tensor,
        pool: &Pool,
        shard_rows: usize,
    ) -> HessianResult {
        let batch = x.dims()[0];
        let nin = x.dims()[1];
        let ranges = parallel::split_rows(batch, shard_rows);
        if ranges.len() <= 1 {
            // A 1-thread pool means genuinely serial, including the GEMMs.
            if pool.threads() == 1 {
                return parallel::with_serial_guard(|| self.execute(program, graph, x));
            }
            return self.execute(program, graph, x);
        }
        let shards = pool.run_sharded(ranges, |_, r| {
            let rows = r.end - r.start;
            let xs = Tensor::from_vec(
                &[rows, nin],
                x.data()[r.start * nin..r.end * nin].to_vec(),
            );
            self.execute(program, graph, &xs)
        });
        merge_hessian_shards(shards, batch)
    }

    /// Evaluate `L[φ]` on a batch `x: [batch, N]` of points.
    pub fn compute(&self, graph: &Graph, x: &Tensor) -> HessianResult {
        self.execute(None, graph, x)
    }

    /// [`Self::compute`] as a thin executor over a shared
    /// [`OperatorProgram`]: the program supplies validated schedule
    /// metadata and the cached `I_N` Jacobian seed (rebuilt per call on
    /// the plain path), and its [`crate::plan::PlanAnalytics`] carry this
    /// method's closed-form Appendix B/D numbers so benches can report
    /// them without executing. Measured results (values, Hessian, exact
    /// FLOPs, peak bytes) are identical on both entry points.
    pub fn compute_with_program(
        &self,
        program: &OperatorProgram,
        graph: &Graph,
        x: &Tensor,
    ) -> HessianResult {
        assert_eq!(
            program.input_dim(),
            graph.input_dim(),
            "program/graph mismatch"
        );
        assert_eq!(program.node_count(), graph.len(), "program/graph mismatch");
        self.execute(Some(program), graph, x)
    }

    fn execute(
        &self,
        program: Option<&OperatorProgram>,
        graph: &Graph,
        x: &Tensor,
    ) -> HessianResult {
        let n = graph.input_dim();
        assert_eq!(self.a.dims()[0], n, "A must be N×N with N = input dim");
        let batch = x.dims()[0];
        let mut peak = PeakTracker::new();
        let mut cost = Cost::zero();

        // (1) + (2): forward values and full-Jacobian tangents (eq. 13),
        // seeded with the program's cached identity when one is shared.
        let owned_seed;
        let seed = match program {
            Some(p) => p.identity_seed(),
            None => {
                owned_seed = Tensor::eye(n);
                &owned_seed
            }
        };
        let fj = forward_with_seed(graph, x, seed);
        cost += fj.cost;
        for t in &fj.tangents {
            peak.alloc(t.bytes());
        }

        // (3): reverse adjoints (eq. 12).
        let seed = Tensor::full(&[batch, 1], 1.0);
        let bw = backward(graph, &fj.values, &seed, false);
        cost += bw.cost;

        // (4): second-order reverse sweep (eq. 14) on folded tangents.
        let mut grad_adjoint: Vec<Option<TangentBatch>> =
            (0..graph.len()).map(|_| None).collect();
        // ∇v̄^M = ∇(1) = 0.
        let out_id = graph.output();
        let out_dim = graph.node(out_id).dim;
        let init = TangentBatch::zeros(batch, n, out_dim);
        peak.alloc(init.bytes());
        grad_adjoint[out_id] = Some(init);

        for j in (0..graph.len()).rev() {
            let node = graph.node(j);
            let gbar_j = match grad_adjoint[j].take() {
                Some(g) => g,
                None => {
                    // Node does not influence the output; nothing flows.
                    TangentBatch::zeros(batch, n, node.dim)
                }
            };
            let vbar_j = &bw.adjoints[j];
            match &node.op {
                Op::Input { .. } => {
                    // Keep: its ∇v̄ is a block of Hessian rows (extracted
                    // below). Re-store.
                    grad_adjoint[j] = Some(gbar_j);
                    continue;
                }
                Op::Linear { weight, .. } => {
                    let p = node.inputs[0];
                    // ∇v̄^p += ∇v̄^j · W (linear op, no second-derivative term)
                    let contrib = matmul(&gbar_j.data, weight);
                    let rows = gbar_j.data.dims()[0];
                    cost.muls += (rows * weight.dims()[0] * weight.dims()[1]) as u64;
                    cost.adds += (rows * weight.dims()[0] * weight.dims()[1]) as u64;
                    accumulate(
                        &mut grad_adjoint[p],
                        TangentBatch {
                            data: contrib,
                            batch,
                            t: n,
                        },
                        &mut peak,
                    );
                }
                Op::Activation { act } => {
                    let p = node.inputs[0];
                    let h = &fj.values[p];
                    let gp = &fj.tangents[p];
                    let d = node.dim;
                    let mut contrib = TangentBatch::zeros(batch, n, d);
                    for b in 0..batch {
                        let hrow = h.row(b);
                        // coef1 = σ'(h), coef2 = σ''(h)·v̄^j — shared across
                        // tangent rows (this is the |T|-term of eq. 14).
                        let coef1: Vec<f64> = hrow.iter().map(|&v| act.df(v)).collect();
                        let coef2: Vec<f64> = hrow
                            .iter()
                            .zip(vbar_j.row(b))
                            .map(|(&hv, &vb)| act.d2f(hv) * vb)
                            .collect();
                        cost.muls += d as u64; // σ''·v̄ products
                        for k in 0..n {
                            let gj = gbar_j.row(b, k).to_vec();
                            let gpt = gp.row(b, k).to_vec();
                            let dst = contrib.row_mut(b, k);
                            for c in 0..d {
                                dst[c] = coef1[c] * gj[c] + coef2[c] * gpt[c];
                            }
                        }
                        cost.muls += (2 * n * d) as u64;
                        cost.adds += (n * d) as u64;
                    }
                    accumulate(&mut grad_adjoint[p], contrib, &mut peak);
                }
                Op::Slice { start, len } => {
                    let p = node.inputs[0];
                    let pd = graph.node(p).dim;
                    let mut contrib = TangentBatch::zeros(batch, n, pd);
                    for r in 0..batch * n {
                        let src = gbar_j.data.row(r);
                        contrib.data.row_mut(r)[*start..*start + *len].copy_from_slice(src);
                    }
                    accumulate(&mut grad_adjoint[p], contrib, &mut peak);
                }
                Op::Add => {
                    for &p in &node.inputs {
                        accumulate(&mut grad_adjoint[p], gbar_j.clone(), &mut peak);
                    }
                }
                Op::Mul => {
                    let d = node.dim;
                    for (pi, &p) in node.inputs.iter().enumerate() {
                        let mut contrib = TangentBatch::zeros(batch, n, d);
                        for b in 0..batch {
                            // coef_p = Π_{q≠p} v^q (first-derivative factor)
                            let mut coefp = vec![1.0; d];
                            for (qi, &q) in node.inputs.iter().enumerate() {
                                if qi != pi {
                                    for (cc, &v) in
                                        coefp.iter_mut().zip(fj.values[q].row(b))
                                    {
                                        *cc *= v;
                                    }
                                }
                            }
                            for k in 0..n {
                                let gj = gbar_j.row(b, k).to_vec();
                                let dst = contrib.row_mut(b, k);
                                for c in 0..d {
                                    dst[c] = coefp[c] * gj[c];
                                }
                            }
                            cost.muls += (n * d) as u64;
                            // Second-derivative terms: Σ_{q≠p} (Π_{r≠p,q} v^r)
                            // ⊙ v̄^j ⊙ ∇v^q.
                            for (qi, &q) in node.inputs.iter().enumerate() {
                                if qi == pi {
                                    continue;
                                }
                                let mut coefpq = vec![1.0; d];
                                for (ri, &r) in node.inputs.iter().enumerate() {
                                    if ri != pi && ri != qi {
                                        for (cc, &v) in
                                            coefpq.iter_mut().zip(fj.values[r].row(b))
                                        {
                                            *cc *= v;
                                        }
                                    }
                                }
                                let scal: Vec<f64> = coefpq
                                    .iter()
                                    .zip(vbar_j.row(b))
                                    .map(|(&cc, &vb)| cc * vb)
                                    .collect();
                                cost.muls += d as u64;
                                let gq = &fj.tangents[q];
                                for k in 0..n {
                                    let gqt = gq.row(b, k).to_vec();
                                    let dst = contrib.row_mut(b, k);
                                    for c in 0..d {
                                        dst[c] += scal[c] * gqt[c];
                                    }
                                }
                                cost.muls += (n * d) as u64;
                                cost.adds += (n * d) as u64;
                            }
                        }
                        accumulate(&mut grad_adjoint[p], contrib, &mut peak);
                    }
                }
                Op::SumReduce => {
                    let p = node.inputs[0];
                    let pd = graph.node(p).dim;
                    let mut contrib = TangentBatch::zeros(batch, n, pd);
                    for r in 0..batch * n {
                        let v = gbar_j.data.row(r)[0];
                        for c in contrib.data.row_mut(r) {
                            *c = v;
                        }
                    }
                    accumulate(&mut grad_adjoint[p], contrib, &mut peak);
                }
                Op::Concat => {
                    let mut off = 0;
                    for &p in &node.inputs {
                        let pd = graph.node(p).dim;
                        let mut contrib = TangentBatch::zeros(batch, n, pd);
                        for r in 0..batch * n {
                            contrib
                                .data
                                .row_mut(r)
                                .copy_from_slice(&gbar_j.data.row(r)[off..off + pd]);
                        }
                        accumulate(&mut grad_adjoint[p], contrib, &mut peak);
                        off += pd;
                    }
                }
            }
            // ∇v̄^j consumed; its forward tangent ∇v^j is also dead now
            // (all consumers already processed in reverse order).
            peak.free(gbar_j.bytes());
            peak.free(fj.tangents[j].bytes());
        }

        // Assemble Hessian from input-node ∇v̄ blocks.
        let mut hessian = Tensor::zeros(&[batch, n, n]);
        let mut off = 0;
        for &i in graph.input_ids() {
            let d = graph.node(i).dim;
            if let Some(g) = &grad_adjoint[i] {
                for b in 0..batch {
                    for k in 0..n {
                        let row = g.row(b, k);
                        for c in 0..d {
                            hessian.data_mut()[(b * n + k) * n + off + c] = row[c];
                        }
                    }
                }
            }
            off += d;
        }
        // Free input blocks + remaining forward tangents of inputs.
        for &i in graph.input_ids() {
            if let Some(g) = grad_adjoint[i].take() {
                peak.free(g.bytes());
            }
        }

        // (5): contract with A (+ optional lower-order terms).
        let mut op_vals = Tensor::zeros(&[batch, 1]);
        let ad = self.a.data();
        for b in 0..batch {
            let hb = &hessian.data()[b * n * n..(b + 1) * n * n];
            let mut acc = 0.0;
            for idx in 0..n * n {
                acc += ad[idx] * hb[idx];
            }
            cost.muls += (n * n) as u64;
            cost.adds += (n * n) as u64;
            op_vals.set(b, 0, acc);
        }

        // Gradient from adjoints at inputs.
        let grad = super::backward::input_gradient(graph, x);
        if let Some(ref bv) = self.b {
            for b in 0..batch {
                let extra: f64 = bv.iter().zip(grad.row(b)).map(|(&c, &g)| c * g).sum();
                op_vals.set(b, 0, op_vals.at(b, 0) + extra);
            }
            cost.muls += (batch * n) as u64;
        }
        let values = fj.values[graph.output()].clone();
        if let Some(c) = self.c {
            for b in 0..batch {
                op_vals.set(b, 0, op_vals.at(b, 0) + c * values.at(b, 0));
            }
            cost.muls += batch as u64;
        }

        HessianResult {
            values,
            gradient: grad,
            hessian,
            operator_values: op_vals,
            cost,
            peak_tangent_bytes: peak.peak(),
        }
    }
}

/// Stitch per-shard results back into one batch-ordered [`HessianResult`]:
/// row-concatenated tensors, exact cost sum, per-shard peak maximum.
fn merge_hessian_shards(shards: Vec<HessianResult>, batch: usize) -> HessianResult {
    let out_d = shards[0].values.dims()[1];
    let op_d = shards[0].operator_values.dims()[1];
    let n = shards[0].gradient.dims()[1];
    let mut values = Tensor::zeros(&[batch, out_d]);
    let mut gradient = Tensor::zeros(&[batch, n]);
    let mut hessian = Tensor::zeros(&[batch, n, n]);
    let mut op_vals = Tensor::zeros(&[batch, op_d]);
    let mut cost = Cost::zero();
    let mut peak = 0u64;
    let mut row = 0usize;
    for s in shards {
        let rows = s.values.dims()[0];
        values.data_mut()[row * out_d..(row + rows) * out_d].copy_from_slice(s.values.data());
        gradient.data_mut()[row * n..(row + rows) * n].copy_from_slice(s.gradient.data());
        hessian.data_mut()[row * n * n..(row + rows) * n * n]
            .copy_from_slice(s.hessian.data());
        op_vals.data_mut()[row * op_d..(row + rows) * op_d]
            .copy_from_slice(s.operator_values.data());
        cost += s.cost;
        peak = peak.max(s.peak_tangent_bytes);
        row += rows;
    }
    HessianResult {
        values,
        gradient,
        hessian,
        operator_values: op_vals,
        cost,
        peak_tangent_bytes: peak,
    }
}

/// Accumulate a tangent contribution into an optional slot, tracking
/// allocations.
fn accumulate(slot: &mut Option<TangentBatch>, contrib: TangentBatch, peak: &mut PeakTracker) {
    match slot {
        None => {
            peak.alloc(contrib.bytes());
            *slot = Some(contrib);
        }
        Some(existing) => {
            existing.data = existing.data.add(&contrib.data);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act, Graph};
    use crate::util::Xoshiro256;

    /// Finite-difference Hessian of a scalar-output graph at one point.
    fn fd_hessian(graph: &Graph, x: &[f64]) -> Tensor {
        let n = x.len();
        let h = 1e-4;
        let f = |xv: &[f64]| -> f64 {
            graph.eval(&Tensor::from_vec(&[1, n], xv.to_vec())).item()
        };
        let mut hes = Tensor::zeros(&[n, n]);
        for i in 0..n {
            for j in 0..n {
                let mut xpp = x.to_vec();
                let mut xpm = x.to_vec();
                let mut xmp = x.to_vec();
                let mut xmm = x.to_vec();
                xpp[i] += h;
                xpp[j] += h;
                xpm[i] += h;
                xpm[j] -= h;
                xmp[i] -= h;
                xmp[j] += h;
                xmm[i] -= h;
                xmm[j] -= h;
                hes.set(i, j, (f(&xpp) - f(&xpm) - f(&xmp) + f(&xmm)) / (4.0 * h * h));
            }
        }
        hes
    }

    #[test]
    fn hessian_matches_finite_difference_mlp() {
        let mut rng = Xoshiro256::new(21);
        let g = mlp_graph(&random_layers(&[4, 7, 6, 1], &mut rng), Act::Tanh);
        let x: Vec<f64> = (0..4).map(|_| 0.5 * rng.normal()).collect();
        let eng = HessianEngine::new(&Tensor::eye(4));
        let res = eng.compute(&g, &Tensor::from_vec(&[1, 4], x.clone()));
        let fd = fd_hessian(&g, &x);
        for i in 0..4 {
            for j in 0..4 {
                let got = res.hessian.data()[i * 4 + j];
                let want = fd.at(i, j);
                assert!(
                    (got - want).abs() < 1e-4,
                    "H[{i}][{j}] = {got} vs fd {want}"
                );
            }
        }
        // With A = I the operator is the Laplacian = trace of H.
        let trace: f64 = (0..4).map(|i| res.hessian.data()[i * 4 + i]).sum();
        assert!((res.operator_values.item() - trace).abs() < 1e-10);
    }

    #[test]
    fn hessian_matches_finite_difference_sparse() {
        let mut rng = Xoshiro256::new(22);
        let blocks: Vec<_> = (0..3)
            .map(|_| random_layers(&[2, 5, 3], &mut rng))
            .collect();
        let g = sparse_mlp_graph(&blocks, Act::Sin);
        let x: Vec<f64> = (0..6).map(|_| 0.3 * rng.normal()).collect();
        let eng = HessianEngine::new(&Tensor::eye(6));
        let res = eng.compute(&g, &Tensor::from_vec(&[1, 6], x.clone()));
        let fd = fd_hessian(&g, &x);
        for i in 0..6 {
            for j in 0..6 {
                let got = res.hessian.data()[i * 6 + j];
                assert!(
                    (got - fd.at(i, j)).abs() < 1e-4,
                    "H[{i}][{j}] = {got} vs {}",
                    fd.at(i, j)
                );
            }
        }
    }

    #[test]
    fn hessian_is_symmetric() {
        let mut rng = Xoshiro256::new(23);
        let g = mlp_graph(&random_layers(&[5, 8, 1], &mut rng), Act::Gelu);
        let x = Tensor::randn(&[3, 5], &mut rng);
        let eng = HessianEngine::new(&Tensor::eye(5));
        let res = eng.compute(&g, &x);
        for b in 0..3 {
            for i in 0..5 {
                for j in 0..5 {
                    let hij = res.hessian.data()[(b * 5 + i) * 5 + j];
                    let hji = res.hessian.data()[(b * 5 + j) * 5 + i];
                    assert!((hij - hji).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn general_a_contraction() {
        let mut rng = Xoshiro256::new(24);
        let g = mlp_graph(&random_layers(&[3, 6, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[1, 3], &mut rng);
        let araw = Tensor::randn(&[3, 3], &mut rng);
        let a = araw.add(&araw.transpose()).scale(0.5);
        let eng = HessianEngine::new(&a);
        let res = eng.compute(&g, &x);
        let mut expect = 0.0;
        for i in 0..3 {
            for j in 0..3 {
                expect += a.at(i, j) * res.hessian.data()[i * 3 + j];
            }
        }
        assert!((res.operator_values.item() - expect).abs() < 1e-12);
    }

    #[test]
    fn lower_order_terms() {
        let mut rng = Xoshiro256::new(25);
        let g = mlp_graph(&random_layers(&[3, 5, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[1, 3], &mut rng);
        let a = Tensor::zeros(&[3, 3]); // pure first/zeroth-order operator
        let bvec = vec![1.0, -2.0, 0.5];
        let eng = HessianEngine::new(&a).with_lower_order(Some(bvec.clone()), Some(3.0));
        let res = eng.compute(&g, &x);
        let expect: f64 = bvec
            .iter()
            .zip(res.gradient.row(0))
            .map(|(&c, &gv)| c * gv)
            .sum::<f64>()
            + 3.0 * res.values.item();
        assert!((res.operator_values.item() - expect).abs() < 1e-10);
    }

    #[test]
    fn peak_memory_positive_and_cost_counted() {
        let mut rng = Xoshiro256::new(26);
        let g = mlp_graph(&random_layers(&[4, 16, 16, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[2, 4], &mut rng);
        let res = HessianEngine::new(&Tensor::eye(4)).compute(&g, &x);
        assert!(res.peak_tangent_bytes > 0);
        assert!(res.cost.muls > 0);
    }
}
