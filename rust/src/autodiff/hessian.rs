//! The Hessian-based baseline (Appendix B, eqs. 12–14).
//!
//! This is what standard AutoDiff packages do for `Σ a_ij ∂²_ij φ`:
//!
//! 1. forward pass for values;
//! 2. forward-mode Jacobian `∇vⁱ` seeded with `I_N` (eq. 13);
//! 3. reverse pass for adjoints `v̄ⁱ = ∂φ/∂vⁱ` (eq. 12);
//! 4. a second-order reverse sweep propagating `∇v̄ⁱ` (eq. 14), whose value
//!    at the input nodes is the full Hessian `H = ∇²φ`;
//! 5. contraction `Σ_ij a_ij H_ij`.
//!
//! The engine tracks the exact multiplication count and — via
//! [`PeakTracker`] — the peak number of live tangent bytes, which is the
//! quantity Theorem 2.2 bounds. All `∇vⁱ` must stay alive across the
//! reverse sweep (the `∇v̄` recursion consumes them), which is why this
//! method's peak memory exceeds `N·|V|` (Appendix D).
//!
//! Execution is **planned**: every `compute*` entry point fetches (or
//! compiles) a [`crate::plan::hessian::HessianPlan`] — the shared program
//! schedule, a static slab layout for the forward tangents and the eq. 14
//! reverse pass, and exact analytic FLOP/peak replays — and runs the slab
//! executor with storage from the program-keyed slab pool
//! ([`crate::autodiff::arena::with_program_slab`]). The original per-call
//! graph walk survives as [`HessianEngine::compute_reference`], the
//! differential-testing oracle the planned path is asserted bit-identical
//! to; both paths run the same shared op kernels
//! ([`crate::plan::kernels`]).

use crate::graph::{Graph, Op};
use crate::parallel::{self, Pool};
use crate::plan::hessian::{execute_hessian, global_hessian_cache, HessianPlan};
use crate::plan::{self, kernels, OperatorProgram, PanelSet};
use crate::tensor::Tensor;

use super::arena::{with_program_slab, SlabKey};
use super::backward::backward;
use super::forward_jacobian::{forward_with_seed, TangentBatch};
use super::memory::PeakTracker;
use super::Cost;

/// Hessian-based operator evaluation.
pub struct HessianEngine {
    /// Symmetric coefficient matrix `A ∈ R^{N×N}`.
    pub a: Tensor,
    /// Optional first-order coefficients `b ∈ R^N`.
    pub b: Option<Vec<f64>>,
    /// Optional zeroth-order coefficient `c`.
    pub c: Option<f64>,
}

/// Output of [`HessianEngine::compute`].
pub struct HessianResult {
    /// `φ(x)`, `[batch, 1]`.
    pub values: Tensor,
    /// `∇φ(x)`, `[batch, N]`.
    pub gradient: Tensor,
    /// Full Hessian `∇²φ(x)`, `[batch, N, N]`.
    pub hessian: Tensor,
    /// `L[φ](x)`, `[batch, 1]`.
    pub operator_values: Tensor,
    /// Exact FLOP count of the run.
    pub cost: Cost,
    /// Peak live tangent bytes (the Theorem 2.2 `M₂` measurement).
    pub peak_tangent_bytes: u64,
}

impl HessianEngine {
    /// Engine for the pure second-order operator `Σ a_ij ∂²_ij`.
    pub fn new(a: &Tensor) -> Self {
        assert_eq!(a.rank(), 2);
        assert_eq!(a.dims()[0], a.dims()[1]);
        Self {
            a: a.clone(),
            b: None,
            c: None,
        }
    }

    /// Add first-order (`Σ b_i ∂_i`) and zeroth-order (`c·`) terms.
    pub fn with_lower_order(mut self, b: Option<Vec<f64>>, c: Option<f64>) -> Self {
        if let Some(ref bv) = b {
            assert_eq!(bv.len(), self.a.dims()[0]);
        }
        self.b = b;
        self.c = c;
        self
    }

    /// [`Self::compute`] sharded across the process-wide pool (`--threads` /
    /// `DOF_THREADS`) in [`parallel::DEFAULT_SHARD_ROWS`]-row chunks.
    pub fn compute_parallel(&self, graph: &Graph, x: &Tensor) -> HessianResult {
        self.compute_sharded(graph, x, &parallel::global(), parallel::DEFAULT_SHARD_ROWS)
    }

    /// Evaluate `L[φ]` with the batch partitioned into fixed `shard_rows`-row
    /// chunks executed across `pool`. Same determinism contract as
    /// [`crate::autodiff::DofEngine::compute_sharded`]: shard boundaries are
    /// thread-count-independent, reduction is shard-ordered, and the Hessian
    /// method's per-row passes (forward Jacobian, reverse adjoints, the
    /// eq. 14 sweep) are row-independent, so results are bit-identical
    /// across thread counts. The plan is compiled once (shard-invariant)
    /// and every shard executes it with a pool slab.
    pub fn compute_sharded(
        &self,
        graph: &Graph,
        x: &Tensor,
        pool: &Pool,
        shard_rows: usize,
    ) -> HessianResult {
        let plan = global_hessian_cache().get_or_compile(graph);
        self.execute_sharded_planned(&plan, graph, x, pool, shard_rows)
    }

    /// [`Self::compute_sharded`] over a caller-held [`OperatorProgram`]
    /// (typically shared with the DOF engine through the plan cache): the
    /// program's lazily attached [`HessianPlan`] is compiled once and every
    /// shard executes it.
    pub fn compute_sharded_with_program(
        &self,
        program: &OperatorProgram,
        graph: &Graph,
        x: &Tensor,
        pool: &Pool,
        shard_rows: usize,
    ) -> HessianResult {
        let plan = program.hessian_plan(graph);
        self.execute_sharded_planned(&plan, graph, x, pool, shard_rows)
    }

    fn execute_sharded_planned(
        &self,
        plan: &HessianPlan,
        graph: &Graph,
        x: &Tensor,
        pool: &Pool,
        shard_rows: usize,
    ) -> HessianResult {
        let batch = x.dims()[0];
        let nin = x.dims()[1];
        let ranges = parallel::split_rows(batch, shard_rows);
        // Pack weight panels ONCE for the whole call and share them
        // read-only across shards — repacking per shard would undo the
        // point of packing.
        let panels = plan::pack_panels(plan.steps(), graph);
        if ranges.len() <= 1 {
            // A 1-thread pool means genuinely serial, including the GEMMs.
            if pool.threads() == 1 {
                return parallel::with_serial_guard(|| {
                    self.execute_planned(plan, graph, x, &panels)
                });
            }
            return self.execute_planned(plan, graph, x, &panels);
        }
        let shards = pool.run_sharded(ranges, |_, r| {
            let rows = r.end - r.start;
            let xs = Tensor::from_vec(
                &[rows, nin],
                x.data()[r.start * nin..r.end * nin].to_vec(),
            );
            self.execute_planned(plan, graph, &xs, &panels)
        });
        merge_hessian_shards(shards, batch)
    }

    /// Structured batch-input validation against `graph`'s input
    /// dimension (shared [`crate::tensor::ops::validate_batch_input`]
    /// gate — identical rejection message across every engine).
    pub fn validate_input(&self, graph: &Graph, x: &Tensor) -> Result<(), String> {
        crate::tensor::ops::validate_batch_input(graph.input_dim(), x)
    }

    /// Evaluate `L[φ]` on a batch `x: [batch, N]` of points.
    ///
    /// Compile-then-run wrapper: the [`HessianPlan`] comes from the keyed
    /// [`global_hessian_cache`] (structure-keyed, so training steps and
    /// repeated evaluation reuse it) and executes on a slab from the
    /// program-keyed pool.
    pub fn compute(&self, graph: &Graph, x: &Tensor) -> HessianResult {
        let plan = global_hessian_cache().get_or_compile(graph);
        self.execute(&plan, graph, x)
    }

    /// [`Self::compute`] over a shared [`OperatorProgram`]: the program
    /// lazily holds the (globally cached) [`HessianPlan`] for its graph, so
    /// bench/serving callers that already compiled the DOF program get the
    /// baseline on the same compiled machinery without extra plumbing.
    /// Results are identical on both entry points.
    pub fn compute_with_program(
        &self,
        program: &OperatorProgram,
        graph: &Graph,
        x: &Tensor,
    ) -> HessianResult {
        assert_eq!(
            program.input_dim(),
            graph.input_dim(),
            "program/graph mismatch"
        );
        assert_eq!(program.node_count(), graph.len(), "program/graph mismatch");
        let plan = program.hessian_plan(graph);
        self.execute(&plan, graph, x)
    }

    /// Execute a caller-held compiled plan (the compile-once half already
    /// done, e.g. fetched from [`global_hessian_cache`] at server spawn).
    /// Storage comes from the program-keyed slab pool like every other
    /// `compute*` entry point.
    pub fn execute(&self, plan: &HessianPlan, graph: &Graph, x: &Tensor) -> HessianResult {
        let panels = plan::pack_panels(plan.steps(), graph);
        self.execute_planned(plan, graph, x, &panels)
    }

    /// Execute a compiled plan with an exact-fit slab from the
    /// program-keyed pool (the plan's key fingerprint is domain-tagged, so
    /// Hessian slabs never alias DOF program slabs) and caller-packed
    /// weight panels (an all-`None` set is always valid and bit-identical).
    fn execute_planned(
        &self,
        plan: &HessianPlan,
        graph: &Graph,
        x: &Tensor,
        panels: &PanelSet,
    ) -> HessianResult {
        let key = SlabKey {
            program: plan.key().fingerprint,
            rows: x.dims()[0],
        };
        with_program_slab(key, |slab| {
            execute_hessian(
                plan,
                graph,
                &self.a,
                self.b.as_deref(),
                self.c,
                x,
                panels,
                slab,
            )
        })
    }

    /// The **reference path**: the original per-call graph walk with owned
    /// tangent storage, runtime [`PeakTracker`] accounting, and runtime
    /// FLOP accumulation. The planned executor replicates this pass through
    /// the same shared kernels, so `rust/tests/cross_engine_fuzz.rs` and
    /// the determinism suite assert the two agree bit for bit on values,
    /// gradient, Hessian, `L[φ]`, FLOP counts, and peak tangent bytes.
    /// Kept as the differential-testing oracle (and as the spec of the
    /// event order the plan's analytic replays mirror).
    pub fn compute_reference(&self, graph: &Graph, x: &Tensor) -> HessianResult {
        let n = graph.input_dim();
        assert_eq!(self.a.dims()[0], n, "A must be N×N with N = input dim");
        let batch = x.dims()[0];
        let mut peak = PeakTracker::new();
        let mut cost = Cost::zero();

        // (1) + (2): forward values and full-Jacobian tangents (eq. 13).
        let seed = Tensor::eye(n);
        let fj = forward_with_seed(graph, x, &seed);
        cost += fj.cost;
        for t in &fj.tangents {
            peak.alloc(t.bytes());
        }

        // (3): reverse adjoints (eq. 12).
        let seed = Tensor::full(&[batch, 1], 1.0);
        let bw = backward(graph, &fj.values, &seed, false);
        cost += bw.cost;

        // (4): second-order reverse sweep (eq. 14) on folded tangents.
        let mut grad_adjoint: Vec<Option<TangentBatch>> =
            (0..graph.len()).map(|_| None).collect();
        // ∇v̄^M = ∇(1) = 0.
        let out_id = graph.output();
        let out_dim = graph.node(out_id).dim;
        let init = TangentBatch::zeros(batch, n, out_dim);
        peak.alloc(init.bytes());
        grad_adjoint[out_id] = Some(init);

        for j in (0..graph.len()).rev() {
            let node = graph.node(j);
            let gbar_j = match grad_adjoint[j].take() {
                Some(g) => g,
                None => {
                    // Node does not influence the output; nothing flows.
                    TangentBatch::zeros(batch, n, node.dim)
                }
            };
            let vbar_j = &bw.adjoints[j];
            match &node.op {
                Op::Input { .. } => {
                    // Keep: its ∇v̄ is a block of Hessian rows (extracted
                    // below). Re-store.
                    grad_adjoint[j] = Some(gbar_j);
                    continue;
                }
                Op::Linear { weight, .. } => {
                    let p = node.inputs[0];
                    // ∇v̄^p += ∇v̄^j · W (linear op, no second-derivative
                    // term) — shared kernel.
                    let rows = gbar_j.data.dims()[0];
                    let in_d = weight.dims()[1];
                    let mut contrib = TangentBatch::zeros(batch, n, in_d);
                    kernels::hess_linear_reverse(
                        weight,
                        rows,
                        gbar_j.data.data(),
                        contrib.data.data_mut(),
                    );
                    cost.muls += (rows * weight.dims()[0] * weight.dims()[1]) as u64;
                    cost.adds += (rows * weight.dims()[0] * weight.dims()[1]) as u64;
                    accumulate(&mut grad_adjoint[p], contrib, &mut peak);
                }
                Op::Activation { act } => {
                    let p = node.inputs[0];
                    let d = node.dim;
                    // coef1 = σ'(h), coef2 = σ''(h)·v̄^j — the |T|-term of
                    // eq. 14, shared kernel.
                    let mut contrib = TangentBatch::zeros(batch, n, d);
                    kernels::hess_activation_reverse(
                        *act,
                        batch,
                        n,
                        d,
                        fj.values[p].data(),
                        vbar_j.data(),
                        gbar_j.data.data(),
                        fj.tangents[p].data.data(),
                        contrib.data.data_mut(),
                    );
                    cost.muls += (batch * (d + 2 * n * d)) as u64;
                    cost.adds += (batch * n * d) as u64;
                    accumulate(&mut grad_adjoint[p], contrib, &mut peak);
                }
                Op::Slice { start, len } => {
                    let p = node.inputs[0];
                    let pd = graph.node(p).dim;
                    let mut contrib = TangentBatch::zeros(batch, n, pd);
                    for r in 0..batch * n {
                        let src = gbar_j.data.row(r);
                        contrib.data.row_mut(r)[*start..*start + *len].copy_from_slice(src);
                    }
                    accumulate(&mut grad_adjoint[p], contrib, &mut peak);
                }
                Op::Add => {
                    for &p in &node.inputs {
                        accumulate(&mut grad_adjoint[p], gbar_j.clone(), &mut peak);
                    }
                }
                Op::Mul => {
                    let d = node.dim;
                    let k = node.inputs.len();
                    // First-derivative factor (Π_{q≠p} v^q) ⊙ ∇v̄^j plus the
                    // second-derivative cross terms Σ_{q≠p} (Π_{r≠p,q} v^r)
                    // ⊙ v̄^j ⊙ ∇v^q — shared kernel, one call per parent.
                    let pvals: Vec<&[f64]> =
                        node.inputs.iter().map(|&q| fj.values[q].data()).collect();
                    let ptans: Vec<&[f64]> = node
                        .inputs
                        .iter()
                        .map(|&q| fj.tangents[q].data.data())
                        .collect();
                    for (pi, &p) in node.inputs.iter().enumerate() {
                        let mut contrib = TangentBatch::zeros(batch, n, d);
                        kernels::hess_mul_reverse_parent(
                            batch,
                            n,
                            d,
                            pi,
                            &pvals,
                            vbar_j.data(),
                            gbar_j.data.data(),
                            &ptans,
                            contrib.data.data_mut(),
                        );
                        accumulate(&mut grad_adjoint[p], contrib, &mut peak);
                    }
                    cost.muls += (batch * k * (n * d + (k - 1) * (d + n * d))) as u64;
                    cost.adds += (batch * k * (k - 1) * n * d) as u64;
                }
                Op::SumReduce => {
                    let p = node.inputs[0];
                    let pd = graph.node(p).dim;
                    let mut contrib = TangentBatch::zeros(batch, n, pd);
                    for r in 0..batch * n {
                        let v = gbar_j.data.row(r)[0];
                        for c in contrib.data.row_mut(r) {
                            *c = v;
                        }
                    }
                    accumulate(&mut grad_adjoint[p], contrib, &mut peak);
                }
                Op::Concat => {
                    let mut off = 0;
                    for &p in &node.inputs {
                        let pd = graph.node(p).dim;
                        let mut contrib = TangentBatch::zeros(batch, n, pd);
                        for r in 0..batch * n {
                            contrib
                                .data
                                .row_mut(r)
                                .copy_from_slice(&gbar_j.data.row(r)[off..off + pd]);
                        }
                        accumulate(&mut grad_adjoint[p], contrib, &mut peak);
                        off += pd;
                    }
                }
            }
            // ∇v̄^j consumed; its forward tangent ∇v^j is also dead now
            // (all consumers already processed in reverse order).
            peak.free(gbar_j.bytes());
            peak.free(fj.tangents[j].bytes());
        }

        // Assemble Hessian from input-node ∇v̄ blocks.
        let mut hessian = Tensor::zeros(&[batch, n, n]);
        let mut off = 0;
        for &i in graph.input_ids() {
            let d = graph.node(i).dim;
            if let Some(g) = &grad_adjoint[i] {
                for b in 0..batch {
                    for k in 0..n {
                        let row = g.row(b, k);
                        for c in 0..d {
                            hessian.data_mut()[(b * n + k) * n + off + c] = row[c];
                        }
                    }
                }
            }
            off += d;
        }
        // Free input blocks + remaining forward tangents of inputs.
        for &i in graph.input_ids() {
            if let Some(g) = grad_adjoint[i].take() {
                peak.free(g.bytes());
            }
        }

        // (5): contract with A (+ optional lower-order terms).
        let mut op_vals = Tensor::zeros(&[batch, 1]);
        let ad = self.a.data();
        for b in 0..batch {
            let hb = &hessian.data()[b * n * n..(b + 1) * n * n];
            let mut acc = 0.0;
            for idx in 0..n * n {
                acc += ad[idx] * hb[idx];
            }
            cost.muls += (n * n) as u64;
            cost.adds += (n * n) as u64;
            op_vals.set(b, 0, acc);
        }

        // Gradient from adjoints at inputs.
        let grad = super::backward::input_gradient(graph, x);
        if let Some(ref bv) = self.b {
            for b in 0..batch {
                let extra: f64 = bv.iter().zip(grad.row(b)).map(|(&c, &g)| c * g).sum();
                op_vals.set(b, 0, op_vals.at(b, 0) + extra);
            }
            cost.muls += (batch * n) as u64;
        }
        let values = fj.values[graph.output()].clone();
        if let Some(c) = self.c {
            for b in 0..batch {
                op_vals.set(b, 0, op_vals.at(b, 0) + c * values.at(b, 0));
            }
            cost.muls += batch as u64;
        }

        HessianResult {
            values,
            gradient: grad,
            hessian,
            operator_values: op_vals,
            cost,
            peak_tangent_bytes: peak.peak(),
        }
    }
}

/// Stitch per-shard results back into one batch-ordered [`HessianResult`]:
/// row-concatenated tensors, exact cost sum, per-shard peak maximum.
fn merge_hessian_shards(shards: Vec<HessianResult>, batch: usize) -> HessianResult {
    let out_d = shards[0].values.dims()[1];
    let op_d = shards[0].operator_values.dims()[1];
    let n = shards[0].gradient.dims()[1];
    let mut values = Tensor::zeros(&[batch, out_d]);
    let mut gradient = Tensor::zeros(&[batch, n]);
    let mut hessian = Tensor::zeros(&[batch, n, n]);
    let mut op_vals = Tensor::zeros(&[batch, op_d]);
    let mut cost = Cost::zero();
    let mut peak = 0u64;
    let mut row = 0usize;
    for s in shards {
        let rows = s.values.dims()[0];
        values.data_mut()[row * out_d..(row + rows) * out_d].copy_from_slice(s.values.data());
        gradient.data_mut()[row * n..(row + rows) * n].copy_from_slice(s.gradient.data());
        hessian.data_mut()[row * n * n..(row + rows) * n * n]
            .copy_from_slice(s.hessian.data());
        op_vals.data_mut()[row * op_d..(row + rows) * op_d]
            .copy_from_slice(s.operator_values.data());
        cost += s.cost;
        peak = peak.max(s.peak_tangent_bytes);
        row += rows;
    }
    HessianResult {
        values,
        gradient,
        hessian,
        operator_values: op_vals,
        cost,
        peak_tangent_bytes: peak,
    }
}

/// Accumulate a tangent contribution into an optional slot, tracking
/// allocations.
fn accumulate(slot: &mut Option<TangentBatch>, contrib: TangentBatch, peak: &mut PeakTracker) {
    match slot {
        None => {
            peak.alloc(contrib.bytes());
            *slot = Some(contrib);
        }
        Some(existing) => {
            existing.data = existing.data.add(&contrib.data);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act, Graph};
    use crate::util::Xoshiro256;

    /// Finite-difference Hessian of a scalar-output graph at one point.
    fn fd_hessian(graph: &Graph, x: &[f64]) -> Tensor {
        let n = x.len();
        let h = 1e-4;
        let f = |xv: &[f64]| -> f64 {
            graph.eval(&Tensor::from_vec(&[1, n], xv.to_vec())).item()
        };
        let mut hes = Tensor::zeros(&[n, n]);
        for i in 0..n {
            for j in 0..n {
                let mut xpp = x.to_vec();
                let mut xpm = x.to_vec();
                let mut xmp = x.to_vec();
                let mut xmm = x.to_vec();
                xpp[i] += h;
                xpp[j] += h;
                xpm[i] += h;
                xpm[j] -= h;
                xmp[i] -= h;
                xmp[j] += h;
                xmm[i] -= h;
                xmm[j] -= h;
                hes.set(i, j, (f(&xpp) - f(&xpm) - f(&xmp) + f(&xmm)) / (4.0 * h * h));
            }
        }
        hes
    }

    #[test]
    fn hessian_matches_finite_difference_mlp() {
        let mut rng = Xoshiro256::new(21);
        let g = mlp_graph(&random_layers(&[4, 7, 6, 1], &mut rng), Act::Tanh);
        let x: Vec<f64> = (0..4).map(|_| 0.5 * rng.normal()).collect();
        let eng = HessianEngine::new(&Tensor::eye(4));
        let res = eng.compute(&g, &Tensor::from_vec(&[1, 4], x.clone()));
        let fd = fd_hessian(&g, &x);
        for i in 0..4 {
            for j in 0..4 {
                let got = res.hessian.data()[i * 4 + j];
                let want = fd.at(i, j);
                assert!(
                    (got - want).abs() < 1e-4,
                    "H[{i}][{j}] = {got} vs fd {want}"
                );
            }
        }
        // With A = I the operator is the Laplacian = trace of H.
        let trace: f64 = (0..4).map(|i| res.hessian.data()[i * 4 + i]).sum();
        assert!((res.operator_values.item() - trace).abs() < 1e-10);
    }

    #[test]
    fn hessian_matches_finite_difference_sparse() {
        let mut rng = Xoshiro256::new(22);
        let blocks: Vec<_> = (0..3)
            .map(|_| random_layers(&[2, 5, 3], &mut rng))
            .collect();
        let g = sparse_mlp_graph(&blocks, Act::Sin);
        let x: Vec<f64> = (0..6).map(|_| 0.3 * rng.normal()).collect();
        let eng = HessianEngine::new(&Tensor::eye(6));
        let res = eng.compute(&g, &Tensor::from_vec(&[1, 6], x.clone()));
        let fd = fd_hessian(&g, &x);
        for i in 0..6 {
            for j in 0..6 {
                let got = res.hessian.data()[i * 6 + j];
                assert!(
                    (got - fd.at(i, j)).abs() < 1e-4,
                    "H[{i}][{j}] = {got} vs {}",
                    fd.at(i, j)
                );
            }
        }
    }

    #[test]
    fn hessian_is_symmetric() {
        let mut rng = Xoshiro256::new(23);
        let g = mlp_graph(&random_layers(&[5, 8, 1], &mut rng), Act::Gelu);
        let x = Tensor::randn(&[3, 5], &mut rng);
        let eng = HessianEngine::new(&Tensor::eye(5));
        let res = eng.compute(&g, &x);
        for b in 0..3 {
            for i in 0..5 {
                for j in 0..5 {
                    let hij = res.hessian.data()[(b * 5 + i) * 5 + j];
                    let hji = res.hessian.data()[(b * 5 + j) * 5 + i];
                    assert!((hij - hji).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn general_a_contraction() {
        let mut rng = Xoshiro256::new(24);
        let g = mlp_graph(&random_layers(&[3, 6, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[1, 3], &mut rng);
        let araw = Tensor::randn(&[3, 3], &mut rng);
        let a = araw.add(&araw.transpose()).scale(0.5);
        let eng = HessianEngine::new(&a);
        let res = eng.compute(&g, &x);
        let mut expect = 0.0;
        for i in 0..3 {
            for j in 0..3 {
                expect += a.at(i, j) * res.hessian.data()[i * 3 + j];
            }
        }
        assert!((res.operator_values.item() - expect).abs() < 1e-12);
    }

    #[test]
    fn lower_order_terms() {
        let mut rng = Xoshiro256::new(25);
        let g = mlp_graph(&random_layers(&[3, 5, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[1, 3], &mut rng);
        let a = Tensor::zeros(&[3, 3]); // pure first/zeroth-order operator
        let bvec = vec![1.0, -2.0, 0.5];
        let eng = HessianEngine::new(&a).with_lower_order(Some(bvec.clone()), Some(3.0));
        let res = eng.compute(&g, &x);
        let expect: f64 = bvec
            .iter()
            .zip(res.gradient.row(0))
            .map(|(&c, &gv)| c * gv)
            .sum::<f64>()
            + 3.0 * res.values.item();
        assert!((res.operator_values.item() - expect).abs() < 1e-10);
    }

    #[test]
    fn peak_memory_positive_and_cost_counted() {
        let mut rng = Xoshiro256::new(26);
        let g = mlp_graph(&random_layers(&[4, 16, 16, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[2, 4], &mut rng);
        let res = HessianEngine::new(&Tensor::eye(4)).compute(&g, &x);
        assert!(res.peak_tangent_bytes > 0);
        assert!(res.cost.muls > 0);
    }
}
