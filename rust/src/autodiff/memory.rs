//! Peak-memory accounting (Appendix D).
//!
//! Two complementary instruments:
//!
//! * [`PeakTracker`] — a live counter the engines drive with real
//!   allocation/free events of tangent buffers; its `peak()` is the
//!   *measured* `M₁`/`M₂` of Theorem 2.2.
//! * [`MemoryModel`] — the analytic model: `C(j) = t · Σ_{i: i ≤ j ≤ τ(i)} dim(i)`
//!   (eq. 25 generalized to vector nodes), whose max over `j` is the
//!   forward-mode peak (eq. 26), and the Hessian-method lower bound
//!   `M₂ > N·|V|` from Appendix D.

use crate::graph::Graph;

/// Running live-byte counter with peak.
#[derive(Debug, Default, Clone)]
pub struct PeakTracker {
    current: u64,
    peak: u64,
}

impl PeakTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&mut self, bytes: u64) {
        self.current += bytes;
        if self.current > self.peak {
            self.peak = self.current;
        }
    }

    pub fn free(&mut self, bytes: u64) {
        debug_assert!(self.current >= bytes, "free underflow");
        self.current = self.current.saturating_sub(bytes);
    }

    pub fn current(&self) -> u64 {
        self.current
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }
}

/// Analytic peak-memory model for a graph.
pub struct MemoryModel<'g> {
    graph: &'g Graph,
}

impl<'g> MemoryModel<'g> {
    pub fn new(graph: &'g Graph) -> Self {
        Self { graph }
    }

    /// Peak live tangent *scalars* for a forward pass with tangent width
    /// `t`, assuming each node's tangent is freed once its last consumer
    /// (`τ(i)`, eq. 24) has been computed. This is eq. 26's `M₁` (per batch
    /// point, in scalars; multiply by 8 for f64 bytes).
    pub fn forward_peak_scalars(&self, t: usize) -> u64 {
        let tau = self.graph.tau();
        let n = self.graph.len();
        let mut peak = 0u64;
        let mut live = 0u64;
        // Sweep j in topological order: node i is live while i ≤ j ≤ τ(i).
        // Incremental: at step j, allocate node j, then free every i with
        // τ(i) == j (including j itself if it has no consumers, except we
        // keep the output).
        let mut frees_at: Vec<Vec<usize>> = vec![Vec::new(); n];
        for i in 0..n {
            frees_at[tau[i]].push(i);
        }
        for j in 0..n {
            live += (t * self.graph.node(j).dim) as u64;
            if live > peak {
                peak = live;
            }
            for &i in &frees_at[j] {
                if i != self.graph.output() {
                    live -= (t * self.graph.node(i).dim) as u64;
                }
            }
        }
        peak
    }

    /// Lower bound on the Hessian method's peak live tangent scalars: all
    /// `∇vⁱ` (width `N`) are simultaneously live when the reverse sweep
    /// starts (Appendix D: "every ∇vⁱ ... could not be released since v̂ⁱ
    /// have not been computed yet"), i.e. `N·|V|` scalars, plus the largest
    /// `∇v̄` buffer.
    pub fn hessian_peak_scalars(&self) -> u64 {
        let n = self.graph.input_dim() as u64;
        let v = self.graph.scalar_node_count() as u64;
        let max_dim = self
            .graph
            .nodes()
            .iter()
            .map(|nd| nd.dim)
            .max()
            .unwrap_or(0) as u64;
        n * v + n * max_dim
    }

    /// The Theorem 2.2 ratio bound for an MLP: `M₁ ≲ (2/L)·M₂` — returns
    /// `(forward_peak, hessian_peak)` with tangent width `t`.
    pub fn theorem22_pair(&self, t: usize) -> (u64, u64) {
        (self.forward_peak_scalars(t), self.hessian_peak_scalars())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder::random_layers, mlp_graph, Act};
    use crate::util::Xoshiro256;

    #[test]
    fn tracker_peak_semantics() {
        let mut t = PeakTracker::new();
        t.alloc(100);
        t.alloc(50);
        t.free(100);
        t.alloc(20);
        assert_eq!(t.current(), 70);
        assert_eq!(t.peak(), 150);
    }

    #[test]
    fn forward_peak_is_adjacent_layer_pair_for_mlp() {
        // For a chain MLP the live set at any Linear node is {parent, self},
        // so peak ≈ t · max_l (N_l + N_{l+1}) — Appendix D's eq. 28.
        let mut rng = Xoshiro256::new(31);
        let dims = [8usize, 32, 32, 32, 1];
        let g = mlp_graph(&random_layers(&dims, &mut rng), Act::Tanh);
        let m = MemoryModel::new(&g);
        let t = 8;
        let peak = m.forward_peak_scalars(t);
        // Max adjacent sum: 32+32 = 64 → peak = t·64 (+ output retention ≤ t).
        let bound = (t * (32 + 32 + 1)) as u64;
        assert!(peak <= bound, "peak {peak} > bound {bound}");
        assert!(peak >= (t * 64) as u64, "peak {peak} too small");
    }

    #[test]
    fn hessian_peak_exceeds_forward_peak() {
        // Theorem 2.2: M₁ < M₂ for any architecture; check on MLPs of
        // several depths with t = N.
        let mut rng = Xoshiro256::new(32);
        for depth in [2usize, 4, 8] {
            let mut dims = vec![16usize];
            dims.extend(std::iter::repeat(64).take(depth));
            dims.push(1);
            let g = mlp_graph(&random_layers(&dims, &mut rng), Act::Tanh);
            let m = MemoryModel::new(&g);
            let (fwd, hess) = m.theorem22_pair(16);
            assert!(
                fwd < hess,
                "depth {depth}: forward {fwd} !< hessian {hess}"
            );
        }
    }

    #[test]
    fn theorem22_mlp_ratio_scales_with_depth() {
        // M₁/M₂ ≲ 2/L: the ratio should shrink as the MLP deepens.
        let mut rng = Xoshiro256::new(33);
        let ratio_for_depth = |l: usize, rng: &mut Xoshiro256| -> f64 {
            let mut dims = vec![16usize];
            dims.extend(std::iter::repeat(64).take(l));
            dims.push(1);
            let g = mlp_graph(&random_layers(&dims, rng), Act::Tanh);
            let m = MemoryModel::new(&g);
            let (fwd, hess) = m.theorem22_pair(16);
            fwd as f64 / hess as f64
        };
        let r2 = ratio_for_depth(2, &mut rng);
        let r8 = ratio_for_depth(8, &mut rng);
        assert!(r8 < r2, "ratio should fall with depth: {r2} → {r8}");
        // And the 2/L bound (loose, up to constants): for L=8 expect < 0.5.
        assert!(r8 < 0.5, "r8 = {r8}");
    }
}
