//! Automatic differentiation engines — the paper's contribution and its
//! baseline, both exactly instrumented.
//!
//! * [`forward_jacobian`] — forward-mode tangent propagation (eq. 13 /
//!   eq. 17): the shared machinery that pushes an `r×N`-seeded tangent
//!   through the graph.
//! * [`backward`] — reverse-mode adjoints `∂φ/∂vⁱ` (eq. 12), also used by
//!   the training loop for parameter gradients.
//! * [`hessian`] — the **Hessian-based baseline**: forward Jacobian +
//!   reverse pass + the second-order reverse sweep of eq. 14, yielding the
//!   full Hessian, then contracted with `A`. This mirrors what standard
//!   AutoDiff packages do and is the comparator in Tables 1–2.
//! * [`dof`] — **DOF** (eqs. 7–9): one forward pass over the tuple
//!   `(v, g, s) = (v, L∇v, L[v])`.
//! * [`flops`] — analytic FLOP accounting (`|E|`, `|R|`, `|T|` of
//!   Appendix B) plus the closed-form cost of both methods.
//! * [`memory`] — liveness-based peak-memory accounting (`τ(i)`, `C(j)` of
//!   Appendix D).
//! * [`arena`] — reusable tangent-buffer pool: the liveness-freed `(v, g, s)`
//!   storage is recycled instead of returned to the allocator, so repeated
//!   engine passes run allocation-free while the [`PeakTracker`] accounting
//!   stays bit-identical.
//!
//! ### Planned execution
//!
//! The engines are thin executors over compiled
//! [`crate::plan::OperatorProgram`]s: every `compute*` entry point fetches
//! the program for its `(graph structure, operator)` pair from the keyed
//! [`crate::plan::global_cache`] (compiling on first use) and runs the
//! slab executor — fused schedule, static buffer slots, precomputed §3.2
//! active rows, analytic cost/peak accounting. The pre-plan interpreter is
//! retained as `DofEngine::compute_with_arena`, the differential-testing
//! reference. `dof_tape`'s forward pass executes the same program in
//! retain-all mode; the Hessian baseline runs its own program-scheduled
//! slab executor ([`crate::plan::hessian`]) with the per-call walk
//! retained as `HessianEngine::compute_reference`. All executors dispatch
//! the **shared op kernels** ([`crate::plan::kernels`]) — one numeric
//! definition per op, N storage policies.
//!
//! ### Parallel execution
//!
//! Both engines expose `compute_sharded` / `compute_parallel`: the batch is
//! split into fixed 8-row shards ([`crate::parallel::DEFAULT_SHARD_ROWS`])
//! executed across the **persistent worker team**
//! ([`crate::parallel::Pool`] / [`crate::parallel::pool`] — OS threads
//! spawned once per process, parked between regions), each worker running
//! with slab storage checked out of the process-wide **program-keyed slab
//! pool** ([`arena::with_program_slab`]; exact fit by `(program, rows)`,
//! lock-sharded by key hash so concurrent caller threads don't serialize —
//! the size-bucketed [`arena::with_pooled_arena`] depot remains available
//! for arena-based callers such as the reference interpreters). The
//! program is compiled once per batch call and is shard-invariant; shard
//! boundaries depend only on the batch size and reduction is
//! shard-ordered, so values, `L[φ]`, FLOP tallies, and per-shard peak
//! bytes are bit-identical across thread counts.
//!
//! ### Op granularity and Appendix C
//!
//! The graph decomposes each MLP layer into an affine node (zero second
//! derivative) followed by an elementwise activation (diagonal second
//! derivative). This decomposition *is* the Appendix C fast path: the
//! Hessian-contraction term of eq. 9 touches only `Σ_l N_{l+1}` diagonal
//! pairs instead of `Σ_l N_l(N_l−1)` cross pairs, for both engines alike,
//! so the comparison between methods stays apples-to-apples.

pub mod arena;
pub mod backward;
pub mod dof;
pub mod dof_tape;
pub mod flops;
pub mod forward_jacobian;
pub mod hessian;
pub mod memory;

pub use arena::{
    slab_pool_stats, with_program_slab, ArenaStats, SlabKey, SlabPoolStats, TangentArena,
};
pub use dof::{DofEngine, DofResult};
pub use flops::{CostModel, GraphCounts};
pub use forward_jacobian::TangentBatch;
pub use hessian::{HessianEngine, HessianResult};
pub use memory::{MemoryModel, PeakTracker};

/// Exact floating-point operation counts accumulated by an engine run.
///
/// Multiplications and additions are tracked separately; the paper's proofs
/// count multiplications ("we only count multiplications", Appendix B), so
/// comparisons use [`Cost::muls`] while `adds` is kept for completeness.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Cost {
    pub muls: u64,
    pub adds: u64,
}

impl Cost {
    pub fn zero() -> Self {
        Self::default()
    }

    pub fn total(&self) -> u64 {
        self.muls + self.adds
    }
}

impl std::ops::Add for Cost {
    type Output = Cost;
    fn add(self, o: Cost) -> Cost {
        Cost {
            muls: self.muls + o.muls,
            adds: self.adds + o.adds,
        }
    }
}

impl std::ops::AddAssign for Cost {
    fn add_assign(&mut self, o: Cost) {
        self.muls += o.muls;
        self.adds += o.adds;
    }
}
