//! Order-4 grid bench: the biharmonic operator evaluated by the jet
//! subsystem on both shipped architectures (plain MLP and the sparse
//! `Op::Mul` product-head), swept over batch × threads.
//!
//! Reports, per architecture: the one-time **plan-compile** cost of the
//! [`crate::jet::JetProgram`] (measured uncached, the cost the keyed jet
//! cache amortizes) plus the program's analytic columns (slab scalars/row,
//! direction count, exact muls/row and peak bytes/row, Appendix B-style —
//! derived per op kind from the same closed counts the executor's runtime
//! accumulation uses, so they are exact, not estimates). Per cell: the
//! per-batch **execute** wall-clock of the reused program through the same
//! sharded path serving uses. Emitted as schema-v2 JSON next to the
//! order-2 grid (`dof bench grid --order 4`).

use std::io::Write as _;

use crate::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act, Graph};
use crate::operators::{HigherOrderOperator, HigherOrderSpec};
use crate::parallel::{Pool, DEFAULT_SHARD_ROWS};
use crate::tensor::Tensor;
use crate::util::Xoshiro256;

use super::{BenchConfig, Bencher};

/// Order-4 grid configuration.
#[derive(Debug, Clone, Copy)]
pub struct JetGridConfig {
    /// Input dimension `N` (jet directions scale as `N²` — keep modest).
    pub n: usize,
    /// Hidden width of the MLP architecture.
    pub hidden: usize,
    /// Hidden layers of the MLP architecture.
    pub layers: usize,
    pub seed: u64,
    pub bench: BenchConfig,
}

impl Default for JetGridConfig {
    fn default() -> Self {
        Self {
            n: 8,
            hidden: 32,
            layers: 3,
            seed: 7,
            bench: BenchConfig::default(),
        }
    }
}

/// One-time plan-compile datum per architecture.
#[derive(Debug, Clone)]
pub struct JetPlanTiming {
    pub arch: String,
    /// Median wall-clock of an uncached `JetProgram` compile.
    pub compile_seconds: f64,
    pub slab_per_row: usize,
    /// Jet directions `t` (`N²` for the biharmonic).
    pub dirs: usize,
    pub fused_steps: usize,
    /// Exact jet multiplications per batch row (analytic, no execution).
    pub muls_per_row: u64,
    /// Exact peak jet bytes per batch row (analytic).
    pub peak_bytes_per_row: u64,
}

/// One (arch, batch, threads) execute measurement.
#[derive(Debug, Clone)]
pub struct JetGridCell {
    pub arch: String,
    pub batch: usize,
    pub threads: usize,
    pub jet_seconds: f64,
    /// Exact FLOPs of the cell (analytic = measured; thread-invariant).
    pub jet_muls: u64,
    /// Exact per-shard peak jet bytes (thread-invariant).
    pub jet_peak_bytes: u64,
}

/// Grid sweep output.
#[derive(Debug, Clone)]
pub struct JetGridReport {
    pub plans: Vec<JetPlanTiming>,
    pub cells: Vec<JetGridCell>,
}

/// Build the two shipped architectures at input dimension `n`.
fn architectures(cfg: &JetGridConfig) -> Vec<(String, Graph)> {
    let mut rng = Xoshiro256::new(cfg.seed);
    let mut dims = vec![cfg.n];
    dims.extend(std::iter::repeat(cfg.hidden).take(cfg.layers));
    dims.push(1);
    let mlp = mlp_graph(&random_layers(&dims, &mut rng), Act::Tanh);
    // Sparse-Mul architecture: n/2 blocks of 2 inputs each (requires even
    // n ≥ 4, validated by the CLI).
    let blocks_n = cfg.n / 2;
    let bdims = vec![2usize, cfg.hidden / 2, 4];
    let blocks: Vec<_> = (0..blocks_n)
        .map(|_| random_layers(&bdims, &mut rng))
        .collect();
    let sparse = sparse_mlp_graph(&blocks, Act::Tanh);
    vec![("mlp".to_string(), mlp), ("sparse".to_string(), sparse)]
}

/// Sweep the biharmonic jet operator over arch × batch × threads.
pub fn run_jet_grid(cfg: &JetGridConfig, batches: &[usize], threads: &[usize]) -> JetGridReport {
    assert!(
        cfg.n >= 4 && cfg.n % 2 == 0,
        "--order 4 grid needs an even N ≥ 4 (sparse architecture blocks), got {}",
        cfg.n
    );
    let op = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: cfg.n });
    let engine = op.jet_engine();
    let bencher = Bencher::new(cfg.bench);
    let mut rng = Xoshiro256::new(cfg.seed ^ 0x4A45);
    let mut plans = Vec::new();
    let mut cells = Vec::new();
    // The cell's thread count also governs the row-parallel GEMM via the
    // process-global pool; restored after the sweep (same discipline as
    // the order-2 grid).
    let ambient_threads = Pool::from_env().threads();
    for (arch, graph) in architectures(cfg) {
        // Plan-compile cost, measured uncached; every cell reuses one
        // compiled program.
        let compile_reps = 5usize;
        let mut compile_times = Vec::with_capacity(compile_reps);
        for _ in 0..compile_reps {
            let t0 = std::time::Instant::now();
            std::hint::black_box(engine.plan(&graph));
            compile_times.push(t0.elapsed().as_secs_f64());
        }
        compile_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let program = engine.plan(&graph);
        plans.push(JetPlanTiming {
            arch: arch.clone(),
            compile_seconds: compile_times[compile_reps / 2],
            slab_per_row: program.slab_per_row(),
            dirs: program.directions(),
            fused_steps: program.fused_steps(),
            muls_per_row: program.cost(1).muls,
            peak_bytes_per_row: program.peak_jet_bytes(1),
        });
        for &batch in batches {
            let x = Tensor::rand_uniform(&[batch, cfg.n], -1.0, 1.0, &mut rng);
            for &t in threads {
                let pool = Pool::new(t.max(1));
                crate::parallel::set_global_threads(t.max(1));
                let m = bencher.run(&format!("jet/{arch}/b{batch}t{t}"), || {
                    let r = engine.execute_sharded(
                        &program,
                        &graph,
                        &x,
                        &pool,
                        DEFAULT_SHARD_ROWS,
                    );
                    std::hint::black_box(&r.operator_values);
                    (Some(r.cost.muls), Some(r.peak_jet_bytes))
                });
                cells.push(JetGridCell {
                    arch: arch.clone(),
                    batch,
                    threads: t.max(1),
                    jet_seconds: m.seconds.median,
                    jet_muls: m.muls.unwrap_or(0),
                    jet_peak_bytes: m.peak_bytes.unwrap_or(0),
                });
            }
        }
    }
    crate::parallel::set_global_threads(ambient_threads);
    JetGridReport { plans, cells }
}

/// Serialize an order-4 grid to the schema-v2 JSON (see
/// [`super::report::grid_json`] for the order-2 twin; `schema: 2` added the
/// `order` discriminator and the provenance note).
pub fn jet_grid_json(cfg: &JetGridConfig, report: &JetGridReport) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"jet_grid\",\n");
    s.push_str("  \"schema\": 2,\n");
    s.push_str("  \"order\": 4,\n");
    s.push_str("  \"operator\": \"biharmonic\",\n");
    s.push_str(
        "  \"provenance\": \"schema v2 (jet subsystem): adds order + per-arch plan objects; \
         flop/peak columns are exact analytic counts from the compiled JetProgram\",\n",
    );
    s.push_str(&format!(
        "  \"config\": {{\"n\": {}, \"hidden\": {}, \"layers\": {}, \"seed\": {}, \"shard_rows\": {}}},\n",
        cfg.n, cfg.hidden, cfg.layers, cfg.seed, DEFAULT_SHARD_ROWS
    ));
    s.push_str("  \"plans\": [\n");
    for (i, p) in report.plans.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"arch\": \"{}\", \"compile_ms\": {:.4}, \"slab_scalars_per_row\": {}, \
             \"dirs\": {}, \"fused_steps\": {}, \"jet_muls_per_row\": {}, \
             \"jet_peak_bytes_per_row\": {}}}{}\n",
            p.arch,
            p.compile_seconds * 1e3,
            p.slab_per_row,
            p.dirs,
            p.fused_steps,
            p.muls_per_row,
            p.peak_bytes_per_row,
            if i + 1 < report.plans.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"cells\": [\n");
    for (i, c) in report.cells.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"arch\": \"{}\", \"batch\": {}, \"threads\": {}, \"jet_ms\": {:.4}, \
             \"jet_muls\": {}, \"jet_peak_bytes\": {}}}{}\n",
            c.arch,
            c.batch,
            c.threads,
            c.jet_seconds * 1e3,
            c.jet_muls,
            c.jet_peak_bytes,
            if i + 1 < report.cells.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Write the order-4 grid JSON to `path`.
pub fn write_jet_grid_json(
    path: &str,
    cfg: &JetGridConfig,
    report: &JetGridReport,
) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(jet_grid_json(cfg, report).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jet_grid_runs_and_serializes() {
        let cfg = JetGridConfig {
            n: 4,
            hidden: 8,
            layers: 2,
            seed: 11,
            bench: BenchConfig {
                warmup_iters: 0,
                measure_iters: 1,
                max_seconds: 10.0,
            },
        };
        let report = run_jet_grid(&cfg, &[3, 9], &[1, 2]);
        assert_eq!(report.plans.len(), 2);
        assert_eq!(report.cells.len(), 8);
        // Thread-invariant exact counters (determinism contract).
        assert_eq!(report.cells[0].jet_muls, report.cells[1].jet_muls);
        assert_eq!(report.cells[0].jet_peak_bytes, report.cells[1].jet_peak_bytes);
        // Analytic per-row numbers match the executed cells exactly.
        let mlp_plan = &report.plans[0];
        assert_eq!(report.cells[0].jet_muls, mlp_plan.muls_per_row * 3);
        assert_eq!(mlp_plan.dirs, 16);
        let json = jet_grid_json(&cfg, &report);
        assert!(json.contains("\"schema\": 2"));
        assert!(json.contains("\"order\": 4"));
        assert!(json.contains("\"arch\": \"sparse\""));
        assert!(json.ends_with("}\n"));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }
}
