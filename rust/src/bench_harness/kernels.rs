//! Kernel-level microbench: per-helper ns/element for the chunked lane
//! sweeps and packed-vs-unpacked throughput for the planned NT GEMM —
//! `dof bench kernels`.
//!
//! Emits the schema-v6 `BENCH_kernels.json` trajectory file. Two column
//! classes:
//!
//! * **analytic** — element counts, MAC counts, and the [`GemmPlan`] each
//!   shape compiles to. Exact, machine-independent, asserted in tests and
//!   grepped by CI (a silent change to the micro-kernel selection shows up
//!   as a column change here, not just as a perf drift);
//! * **measured** — wall-clock ns/element and GFLOP/s. Machine-dependent
//!   perf trajectory; may be near-noise on tiny configs.

use crate::tensor::lanes::{self, LANES};
use crate::tensor::{
    matmul_nt_dot, matmul_nt_planned, GemmForm, GemmPlan, PackedPanel, GEMM_DOT_MAX_MACS,
};
use crate::util::Xoshiro256;

use super::{BenchConfig, Bencher};

/// `dof bench kernels` configuration.
#[derive(Debug, Clone)]
pub struct KernelsConfig {
    /// Elementwise sweep length (deliberately not a multiple of the lane
    /// width so the measured loop includes the scalar tail).
    pub len: usize,
    /// NT-GEMM shapes `(m, k, n)` to measure in all three forms.
    pub gemm_shapes: Vec<(usize, usize, usize)>,
    pub seed: u64,
    pub bench: BenchConfig,
}

impl Default for KernelsConfig {
    fn default() -> Self {
        Self {
            len: 8 * 1024 + 3,
            gemm_shapes: vec![(10, 16, 16), (66, 64, 64), (258, 128, 128)],
            seed: 17,
            bench: BenchConfig::default(),
        }
    }
}

/// One elementwise lane-helper measurement.
#[derive(Debug, Clone)]
pub struct KernelCell {
    pub name: &'static str,
    /// Elements per invocation (analytic).
    pub elements: usize,
    /// Median wall-clock per element (measured).
    pub ns_per_element: f64,
}

/// One NT-GEMM shape measured in all three dispatch forms.
#[derive(Debug, Clone)]
pub struct GemmCell {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// `m·k·n` multiply-accumulates (analytic).
    pub macs: usize,
    /// What [`GemmPlan::choose`] compiles for this shape when the whole
    /// `m` is one batch item (analytic).
    pub plan: GemmPlan,
    /// Measured GFLOP/s (2 FLOPs per MAC) per form.
    pub dot_gflops: f64,
    pub unpacked_gflops: f64,
    pub packed_gflops: f64,
}

/// Output of [`run_kernel_bench`].
#[derive(Debug, Clone)]
pub struct KernelsReport {
    pub elementwise: Vec<KernelCell>,
    pub gemm: Vec<GemmCell>,
}

fn randv(rng: &mut Xoshiro256, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

/// Run the kernel microbench: every public lane helper at `cfg.len`
/// elements, then each GEMM shape through the dot, ad-hoc-transpose AXPY,
/// and packed-panel AXPY forms.
pub fn run_kernel_bench(cfg: &KernelsConfig) -> KernelsReport {
    let bencher = Bencher::new(cfg.bench);
    let mut rng = Xoshiro256::new(cfg.seed);
    let len = cfg.len;
    let a = randv(&mut rng, len);
    let b = randv(&mut rng, len);
    let c = randv(&mut rng, len);
    let e = randv(&mut rng, len);
    let mut dst = randv(&mut rng, len);
    let alpha = rng.normal();

    let mut elementwise = Vec::new();
    // Measure each helper through one monomorphized closure shape so the
    // per-helper numbers are comparable.
    macro_rules! bench_helper {
        ($name:ident, $body:expr) => {{
            let m = bencher.run(concat!("kernels/", stringify!($name)), || {
                $body;
                std::hint::black_box(&dst);
                (None, None)
            });
            elementwise.push(KernelCell {
                name: stringify!($name),
                elements: len,
                ns_per_element: m.seconds.median * 1e9 / len as f64,
            });
        }};
    }
    bench_helper!(add_into, lanes::add_into(&mut dst, &a, &b));
    bench_helper!(mul_into, lanes::mul_into(&mut dst, &a, &b));
    bench_helper!(scale_into, lanes::scale_into(&mut dst, &a, alpha));
    bench_helper!(add_assign, lanes::add_assign(&mut dst, &a));
    bench_helper!(mul_assign, lanes::mul_assign(&mut dst, &a));
    bench_helper!(axpy, lanes::axpy(&mut dst, alpha, &a));
    bench_helper!(mul_acc, lanes::mul_acc(&mut dst, &a, &b));
    bench_helper!(scaled_mul_acc, lanes::scaled_mul_acc(&mut dst, alpha, &a, &b));
    bench_helper!(scaled_sq_acc, lanes::scaled_sq_acc(&mut dst, alpha, &a));
    bench_helper!(
        mul_mul_add_into,
        lanes::mul_mul_add_into(&mut dst, &a, &b, &c, &e)
    );

    let mut gemm = Vec::new();
    for &(m, k, n) in &cfg.gemm_shapes {
        let ga = randv(&mut rng, m * k);
        let gb = randv(&mut rng, n * k);
        let mut gc = vec![0.0f64; m * n];
        let macs = m * k * n;
        let flops = (2 * macs) as f64;
        let gflops = |median: f64| flops / median.max(1e-12) / 1e9;

        let dot = bencher.run(&format!("kernels/gemm_dot/{m}x{k}x{n}"), || {
            gc.fill(0.0);
            matmul_nt_dot(&ga, &gb, &mut gc, m, k, n);
            std::hint::black_box(&gc);
            (None, None)
        });
        let axpy_plan = GemmPlan {
            form: GemmForm::PackedAxpy,
            parallel: false,
        };
        let unpacked = bencher.run(&format!("kernels/gemm_unpacked/{m}x{k}x{n}"), || {
            gc.fill(0.0);
            matmul_nt_planned(&ga, &gb, None, axpy_plan, &mut gc, m, k, n);
            std::hint::black_box(&gc);
            (None, None)
        });
        let panel = PackedPanel::pack(&gb, k, n);
        let packed = bencher.run(&format!("kernels/gemm_packed/{m}x{k}x{n}"), || {
            gc.fill(0.0);
            matmul_nt_planned(&ga, &gb, Some(&panel), axpy_plan, &mut gc, m, k, n);
            std::hint::black_box(&gc);
            (None, None)
        });
        gemm.push(GemmCell {
            m,
            k,
            n,
            macs,
            plan: GemmPlan::choose(m, k, n),
            dot_gflops: gflops(dot.seconds.median),
            unpacked_gflops: gflops(unpacked.seconds.median),
            packed_gflops: gflops(packed.seconds.median),
        });
    }

    KernelsReport { elementwise, gemm }
}

/// Serialize to the schema-v6 `BENCH_kernels.json` format: a top-level
/// `kernels` object carrying the analytic selection constants, the
/// per-helper ns/element rows, and the packed-vs-unpacked GEMM rows.
pub fn kernels_json(cfg: &KernelsConfig, report: &KernelsReport) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"kernels\",\n");
    s.push_str("  \"schema\": 6,\n");
    s.push_str(
        "  \"provenance\": \"schema v6 (observability): version lockstep with the \
         grid report, whose v6 adds the latency_percentiles object; v5 (SIMD-ized \
         kernels + plan-time micro-kernel specialization) added this kernels object \
         — per-helper ns/element for the chunked lane sweeps and dot vs \
         unpacked-AXPY vs packed-panel NT-GEMM throughput, with the analytic \
         GemmPlan choice per shape; v4 added the robustness object, v3 the pool \
         object, v2 the order column\",\n",
    );
    s.push_str(&format!(
        "  \"config\": {{\"len\": {}, \"seed\": {}}},\n",
        cfg.len, cfg.seed
    ));
    s.push_str("  \"kernels\": {\n");
    s.push_str(&format!("    \"lanes\": {LANES},\n"));
    s.push_str(&format!("    \"dot_max_macs\": {GEMM_DOT_MAX_MACS},\n"));
    s.push_str("    \"elementwise\": [\n");
    for (i, cell) in report.elementwise.iter().enumerate() {
        s.push_str(&format!(
            "      {{\"name\": \"{}\", \"elements\": {}, \"ns_per_element\": {:.4}}}{}\n",
            cell.name,
            cell.elements,
            cell.ns_per_element,
            if i + 1 < report.elementwise.len() { "," } else { "" },
        ));
    }
    s.push_str("    ],\n");
    s.push_str("    \"gemm\": [\n");
    for (i, g) in report.gemm.iter().enumerate() {
        let form = match g.plan.form {
            GemmForm::Dot => "dot",
            GemmForm::PackedAxpy => "packed_axpy",
        };
        s.push_str(&format!(
            "      {{\"m\": {}, \"k\": {}, \"n\": {}, \"macs\": {}, \
             \"plan_form\": \"{}\", \"plan_parallel\": {}, \
             \"dot_gflops\": {:.3}, \"unpacked_gflops\": {:.3}, \"packed_gflops\": {:.3}}}{}\n",
            g.m,
            g.k,
            g.n,
            g.macs,
            form,
            g.plan.parallel,
            g.dot_gflops,
            g.unpacked_gflops,
            g.packed_gflops,
            if i + 1 < report.gemm.len() { "," } else { "" },
        ));
    }
    s.push_str("    ]\n");
    s.push_str("  }\n}\n");
    s
}

/// Write the kernels JSON to `path`.
pub fn write_kernels_json(
    path: &str,
    cfg: &KernelsConfig,
    report: &KernelsReport,
) -> std::io::Result<()> {
    use std::io::Write as _;
    let mut f = std::fs::File::create(path)?;
    f.write_all(kernels_json(cfg, report).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_bench_runs_and_serializes_schema_v6() {
        let cfg = KernelsConfig {
            len: 67,
            gemm_shapes: vec![(3, 5, 7), (66, 64, 64)],
            seed: 3,
            bench: BenchConfig {
                warmup_iters: 0,
                measure_iters: 1,
                max_seconds: 5.0,
            },
        };
        let report = run_kernel_bench(&cfg);
        assert_eq!(report.elementwise.len(), 10);
        assert!(report.elementwise.iter().all(|c| c.elements == 67));
        assert_eq!(report.gemm.len(), 2);
        // Analytic columns are exact: MAC counts and the compiled plan.
        assert_eq!(report.gemm[0].macs, 3 * 5 * 7);
        assert_eq!(report.gemm[0].plan.form, GemmForm::Dot);
        assert!(!report.gemm[0].plan.parallel);
        assert_eq!(report.gemm[1].plan.form, GemmForm::PackedAxpy);
        assert!(report.gemm[1].plan.parallel);
        let json = kernels_json(&cfg, &report);
        assert!(json.contains("\"bench\": \"kernels\""));
        assert!(json.contains("\"schema\": 6"));
        assert!(json.contains("\"kernels\""));
        assert!(json.contains(&format!("\"lanes\": {LANES}")));
        assert!(json.contains(&format!("\"dot_max_macs\": {GEMM_DOT_MAX_MACS}")));
        assert!(json.contains("\"name\": \"mul_mul_add_into\""));
        assert!(json.contains("\"plan_form\": \"dot\""));
        assert!(json.contains("\"plan_form\": \"packed_axpy\""));
        assert!(json.contains("\"packed_gflops\""));
        assert!(json.ends_with("}\n"));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }
}
