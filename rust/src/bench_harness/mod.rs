//! Benchmark harness — replaces `criterion` in the offline build.
//!
//! [`Bencher`] runs a closure with warmup + repetitions and reports a
//! [`Measurement`] (wall-clock summary + optional FLOP/byte annotations);
//! [`table`] renders rows in the paper's Table 1/2 format
//! (`Operator | Memory Hessian/DOF/ratio | Time Hessian/DOF/ratio`);
//! [`report`] sweeps the batch × threads grid and emits the
//! machine-readable `BENCH_table1.json` perf-trajectory file.

pub mod jet_grid;
pub mod kernels;
pub mod report;
pub mod table1;
pub mod table2;

use std::time::Instant;

use crate::util::{fmt_bytes, fmt_duration, Summary};

/// Wall-clock measurement with optional annotations.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Per-iteration seconds.
    pub seconds: Summary,
    /// FLOPs per iteration (multiplications), if known.
    pub muls: Option<u64>,
    /// Peak tangent bytes per iteration, if known.
    pub peak_bytes: Option<u64>,
}

impl Measurement {
    /// Effective multiply throughput (muls/s) at the median.
    pub fn mul_rate(&self) -> Option<f64> {
        self.muls.map(|m| m as f64 / self.seconds.median.max(1e-12))
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Hard cap on total measured time; reps stop early past this.
    pub max_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            measure_iters: 10,
            max_seconds: 30.0,
        }
    }
}

/// Timing driver.
pub struct Bencher {
    pub cfg: BenchConfig,
}

impl Bencher {
    pub fn new(cfg: BenchConfig) -> Self {
        Self { cfg }
    }

    /// Run `f` with warmup and repetitions; `f` returns optional
    /// (muls, peak_bytes) annotations (from the engines' exact counters).
    pub fn run<F>(&self, name: &str, mut f: F) -> Measurement
    where
        F: FnMut() -> (Option<u64>, Option<u64>),
    {
        let mut muls = None;
        let mut peak = None;
        for _ in 0..self.cfg.warmup_iters {
            let (m, p) = f();
            muls = m.or(muls);
            peak = p.or(peak);
        }
        let mut times = Vec::with_capacity(self.cfg.measure_iters);
        let start_all = Instant::now();
        for _ in 0..self.cfg.measure_iters {
            let t0 = Instant::now();
            let (m, p) = f();
            times.push(t0.elapsed().as_secs_f64());
            muls = m.or(muls);
            peak = p.or(peak);
            if start_all.elapsed().as_secs_f64() > self.cfg.max_seconds {
                break;
            }
        }
        Measurement {
            name: name.to_string(),
            seconds: Summary::of(&times),
            muls,
            peak_bytes: peak,
        }
    }
}

/// One paper-style comparison row: operator class, Hessian vs DOF.
#[derive(Debug, Clone)]
pub struct CompareRow {
    pub operator: String,
    pub hessian: Measurement,
    pub dof: Measurement,
}

impl CompareRow {
    pub fn time_ratio(&self) -> f64 {
        self.hessian.seconds.median / self.dof.seconds.median.max(1e-12)
    }

    pub fn memory_ratio(&self) -> Option<f64> {
        match (self.hessian.peak_bytes, self.dof.peak_bytes) {
            (Some(h), Some(d)) if d > 0 => Some(h as f64 / d as f64),
            _ => None,
        }
    }

    pub fn flop_ratio(&self) -> Option<f64> {
        match (self.hessian.muls, self.dof.muls) {
            (Some(h), Some(d)) if d > 0 => Some(h as f64 / d as f64),
            _ => None,
        }
    }
}

/// Render rows in the paper's table format.
pub fn render_table(title: &str, rows: &[CompareRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!("## {title}\n\n"));
    out.push_str(
        "| Operator | Mem Hessian | Mem DOF | ratio | Time Hessian | Time DOF | ratio | FLOP ratio |\n",
    );
    out.push_str(
        "|----------|-------------|---------|-------|--------------|----------|-------|------------|\n",
    );
    for r in rows {
        let mh = r
            .hessian
            .peak_bytes
            .map(fmt_bytes)
            .unwrap_or_else(|| "-".into());
        let md = r.dof.peak_bytes.map(fmt_bytes).unwrap_or_else(|| "-".into());
        let mr = r
            .memory_ratio()
            .map(|v| format!("{v:.1}"))
            .unwrap_or_else(|| "-".into());
        let fr = r
            .flop_ratio()
            .map(|v| format!("{v:.2}"))
            .unwrap_or_else(|| "-".into());
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {:.1} | {} |\n",
            r.operator,
            mh,
            md,
            mr,
            fmt_duration(r.hessian.seconds.median),
            fmt_duration(r.dof.seconds.median),
            r.time_ratio(),
            fr,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_measures_and_annotates() {
        let b = Bencher::new(BenchConfig {
            warmup_iters: 1,
            measure_iters: 5,
            max_seconds: 5.0,
        });
        let m = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
            (Some(10_000), Some(1024))
        });
        assert_eq!(m.seconds.n, 5);
        assert!(m.seconds.median > 0.0);
        assert_eq!(m.muls, Some(10_000));
        assert!(m.mul_rate().unwrap() > 0.0);
    }

    #[test]
    fn table_rendering() {
        let mk = |name: &str, t: f64, mem: u64, muls: u64| Measurement {
            name: name.into(),
            seconds: Summary::of(&[t, t, t]),
            muls: Some(muls),
            peak_bytes: Some(mem),
        };
        let rows = vec![CompareRow {
            operator: "Elliptic".into(),
            hessian: mk("h", 0.2, 10_000_000, 2_000_000),
            dof: mk("d", 0.1, 3_000_000, 1_000_000),
        }];
        let s = render_table("Table 1", &rows);
        assert!(s.contains("Elliptic"));
        assert!(s.contains("2.0")); // time & flop ratio
        assert!(s.contains("3.3")); // memory ratio
    }
}
