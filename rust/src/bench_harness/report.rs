//! Machine-readable perf reports: the batch × threads grid behind
//! `BENCH_table1.json`, so future changes can track the perf trajectory
//! without scraping terminal tables.
//!
//! The JSON is hand-rolled (no `serde` in the offline build) and carries,
//! per grid cell, DOF and Hessian wall-clock plus the exact peak-tangent
//! bytes and multiplication counts from the engines' own instrumentation.
//!
//! Produced by `dof bench grid [--batches 8,64,256 --threads-grid 1,2,4,8]`
//! and by `cargo bench --bench table1_mlp`.
//!
//! Since the plan subsystem landed, the grid separates **plan-compile
//! time** (paid once per `(architecture, operator)` pair, measured
//! uncached) from **per-batch execute time** (every cell reuses one
//! compiled [`crate::plan::OperatorProgram`], which is what serving and
//! training see at steady state). Both land in the JSON.

use std::io::Write as _;

use crate::coordinator::{
    BatchPolicy, FaultConfig, FaultInjector, HealthPolicy, HealthState, ModelServer, Router,
    RouterConfig, ServeConfig, TickClock,
};
use crate::graph::Graph;
use crate::nn::{Mlp, MlpSpec};
use crate::operators::{CoeffSpec, Operator};
use crate::parallel::{Pool, DEFAULT_SHARD_ROWS};
use crate::tensor::Tensor;
use crate::util::stats::percentile_sorted;
use crate::util::Xoshiro256;

use super::table1::Table1Config;
use super::Bencher;

/// One (batch, threads) measurement of the Table-1 elliptic operator.
#[derive(Debug, Clone)]
pub struct GridCell {
    pub batch: usize,
    pub threads: usize,
    pub dof_seconds: f64,
    pub hessian_seconds: f64,
    pub dof_peak_bytes: u64,
    pub hessian_peak_bytes: u64,
    pub dof_muls: u64,
    pub hessian_muls: u64,
}

impl GridCell {
    /// Hessian / DOF wall-clock ratio.
    pub fn time_ratio(&self) -> f64 {
        self.hessian_seconds / self.dof_seconds.max(1e-12)
    }
}

/// One-time plan-compile measurement for the grid's (model, operator)
/// pair, reported alongside the per-batch execute times it amortizes.
#[derive(Debug, Clone, Copy)]
pub struct PlanTiming {
    /// Median wall-clock of an uncached `OperatorProgram` compile.
    pub compile_seconds: f64,
    /// Slab scalars per batch row (static slot assignment footprint).
    pub slab_per_row: usize,
    /// Fused `Linear→Activation` steps in the schedule.
    pub fused_steps: usize,
    /// Exact DOF multiplications per batch row (analytic, no execution).
    pub dof_muls_per_row: u64,
}

/// One-time worker-pool lifecycle measurement: what a parallel region
/// costs **cold** (first region in the process — includes the team's
/// one-time OS-thread spawn when this process hadn't parallelized yet) vs
/// **warm** (condvar-parked workers re-used). Both time the same trivial
/// 8-shard region, so the numbers isolate region dispatch overhead from
/// engine compute.
#[derive(Debug, Clone, Copy)]
pub struct PoolTiming {
    /// Wall-clock of the first measured region.
    pub cold_region_seconds: f64,
    /// Best wall-clock of subsequent identical regions.
    pub warm_region_seconds: f64,
    /// Whether the cold measurement actually included the one-time spawn
    /// (false when something earlier in the process already warmed the
    /// team).
    pub cold_included_spawn: bool,
    /// Spawn events observed at measurement end — stays 1 per process.
    pub spawn_events: usize,
    /// Warm helper threads in the team.
    pub workers: usize,
}

/// Deterministic fault-tier counters from a scripted routed-serving run
/// (see [`measure_robustness`]): schema v4 records what the serving tier
/// did under a known fault schedule, so a regression in failover, health
/// gating, or probe re-admission shows up as a *counter* change in the
/// perf trajectory — not just as a test failure.
#[derive(Debug, Clone, Copy)]
pub struct RobustnessProbe {
    /// Requests the probe drove through the router.
    pub requests: u64,
    pub completed: u64,
    pub failed: u64,
    /// Shed with `Overloaded` at admission.
    pub shed: u64,
    /// Failover attempts beyond each request's first.
    pub retries: u64,
    /// Expired on the logical tick clock.
    pub deadline_expired: u64,
    /// Engine-fault attempts (injected panics, per attempt).
    pub engine_faults: u64,
    /// Quarantine entries across the replica set.
    pub quarantine_events: u64,
    /// Replicas back to `Healthy` when the probe finished (recovery check:
    /// the quarantined replica must have been probe-readmitted).
    pub healthy_replicas: usize,
    pub replicas: usize,
}

/// Client-observed latency distribution from a deterministic routed soak
/// (see [`measure_latency_soak`]): serial capacity-sized requests against
/// one clean DOF replica, each round trip timed on the client and reduced
/// to p50/p95/p99 with [`percentile_sorted`]. Schema-v6 records these so a
/// latency-distribution regression in the serving tier shows up in the
/// perf trajectory, not just the means.
#[derive(Debug, Clone, Copy)]
pub struct LatencySoak {
    /// Requests the soak drove through the router.
    pub requests: u64,
    pub p50_seconds: f64,
    pub p95_seconds: f64,
    pub p99_seconds: f64,
}

/// One variance-vs-samples measurement of the stochastic (STDE) estimator
/// against the exact DOF engine on the same points: schema v7 records the
/// empirical error alongside the estimator's own variance report, so both
/// a perf regression *and* a silent estimator-quality regression (variance
/// no longer shrinking ~1/S) show up in the trajectory.
#[derive(Debug, Clone, Copy)]
pub struct StochasticTier {
    /// Sample count (direction groups per point).
    pub samples: u32,
    /// Median wall-clock of one sharded batch evaluation.
    pub seconds: f64,
    /// Mean |estimate − exact| over the probe points.
    pub mean_abs_error: f64,
    /// Mean Bessel-corrected sample variance reported by the engine.
    pub mean_variance: f64,
    /// Mean standard error `sqrt(variance / samples)`.
    pub mean_std_error: f64,
    /// Total jet directions pushed per point at this tier.
    pub dirs_per_point: usize,
}

/// Sample counts the grid's stochastic probe sweeps.
pub const STOCHASTIC_SAMPLE_TIERS: [u32; 3] = [8, 32, 128];

/// Grid sweep output: per-cell execute measurements plus the one-time
/// plan-compile, pool-lifecycle, fault-tier, latency-soak, and
/// stochastic-estimator data.
#[derive(Debug, Clone)]
pub struct GridReport {
    pub cells: Vec<GridCell>,
    pub plan: PlanTiming,
    pub pool: PoolTiming,
    pub robustness: RobustnessProbe,
    pub soak: LatencySoak,
    pub stochastic: Vec<StochasticTier>,
}

/// Measure [`PoolTiming`]: one region before any other parallel work in
/// this function (cold — pays the one-time spawn if the process hasn't
/// parallelized yet), then the best of a few identical warm regions.
pub fn measure_pool_timing(threads: usize) -> PoolTiming {
    let before = crate::parallel::pool::stats();
    let pool = Pool::new(threads.max(2));
    let region = |p: &Pool| {
        let t0 = std::time::Instant::now();
        let out = p.run_sharded(crate::parallel::split_rows(64, 8), |i, r| {
            std::hint::black_box(i + r.start + r.end)
        });
        std::hint::black_box(&out);
        t0.elapsed().as_secs_f64()
    };
    let cold = region(&pool);
    let mut warm = f64::INFINITY;
    for _ in 0..5 {
        warm = warm.min(region(&pool));
    }
    let after = crate::parallel::pool::stats();
    PoolTiming {
        cold_region_seconds: cold,
        warm_region_seconds: warm,
        cold_included_spawn: after.spawn_events > before.spawn_events,
        spawn_events: after.spawn_events,
        workers: after.workers,
    }
}

/// Run the scripted fault-tier probe against the grid's (graph, operator)
/// pair: two DOF replicas behind the router, replica 0 with a seeded
/// two-batch failing prefix, aggressive health policy (degrade after 1,
/// quarantine after 2, probe after 4 ticks, readmit after 1 clean probe),
/// and a retry budget of 1. Four capacity-sized requests then exercise the
/// full failure arc — failover, quarantine, and probe re-admission — on an
/// entirely deterministic schedule (seeded injector + serial traffic), so
/// every counter in the result is exact and reproducible.
pub fn measure_robustness(graph: &Graph, op: &Operator) -> RobustnessProbe {
    let clock = TickClock::new();
    let mut router = Router::with_config(RouterConfig {
        deadline_ticks: None,
        retries: 1,
        clock: clock.clone(),
        health: HealthPolicy {
            degrade_after: 1,
            quarantine_after: 2,
            probe_after_ticks: 4,
            probe_successes: 1,
        },
        tracer: None,
    });
    let rows = 2usize;
    let policy = BatchPolicy {
        // Capacity-sized requests cut immediately; max_wait never gates.
        capacity: rows,
        max_wait: std::time::Duration::from_millis(1),
        max_wait_ticks: None,
    };
    let pool = Pool::new(1);
    let spawn = |injector| {
        ModelServer::spawn_dof_cfg(
            graph.clone(),
            op.dof_engine(),
            policy,
            pool,
            DEFAULT_SHARD_ROWS,
            ServeConfig {
                injector,
                ..ServeConfig::labeled("robustness-probe")
            },
        )
    };
    // Replica 0: batches 0 and 1 panic (the deterministic failing prefix),
    // everything after is clean — so the post-quarantine health probe on
    // batch 2 succeeds and readmits it. Replica 1: clean failover target.
    router.register(
        "robustness-probe",
        spawn(Some(FaultInjector::new(
            0xD0F,
            FaultConfig {
                panic_first: 2,
                ..FaultConfig::default()
            },
        ))),
    );
    router
        .add_replica("robustness-probe", spawn(None))
        .expect("replica widths match by construction");
    let client = router
        .client("robustness-probe")
        .expect("model registered above");
    let n = graph.input_dim();
    let mut rng = Xoshiro256::new(7);
    let requests = 4u64;
    for i in 0..requests {
        if i == 3 {
            // Open replica 0's probe window (quarantined at tick 1, probe
            // due at tick 5) so the last request doubles as its re-
            // admission probe.
            clock.advance(4);
        }
        let pts: Vec<f32> = (0..rows * n).map(|_| rng.normal() as f32).collect();
        client
            .eval_blocking(pts)
            .expect("probe traffic always fails over to the clean replica");
        clock.advance(1);
    }
    let snap = router
        .snapshot()
        .into_iter()
        .next()
        .expect("router serves exactly one model");
    let healthy = snap
        .replicas
        .iter()
        .filter(|r| r.state == HealthState::Healthy)
        .count();
    let replicas = snap.replicas.len();
    router.shutdown();
    RobustnessProbe {
        requests,
        completed: snap.completed,
        failed: snap.failed,
        shed: snap.shed,
        retries: snap.retries,
        deadline_expired: snap.deadline_expired,
        engine_faults: snap.engine_faults,
        quarantine_events: snap.quarantine_events,
        healthy_replicas: healthy,
        replicas,
    }
}

/// Run the latency soak: one clean DOF replica behind a default router,
/// serial capacity-sized requests (no faults, no deadlines), each round
/// trip timed on the client. The measured seconds are data-plane wall
/// clock, but the schedule is fixed, so the sample count and percentile
/// positions are exact and reproducible.
pub fn measure_latency_soak(graph: &Graph, op: &Operator) -> LatencySoak {
    let mut router = Router::new();
    let rows = 2usize;
    let policy = BatchPolicy {
        capacity: rows,
        max_wait: std::time::Duration::from_millis(1),
        max_wait_ticks: None,
    };
    let pool = Pool::new(1);
    router.register(
        "latency-soak",
        ModelServer::spawn_dof_cfg(
            graph.clone(),
            op.dof_engine(),
            policy,
            pool,
            DEFAULT_SHARD_ROWS,
            ServeConfig::labeled("latency-soak"),
        ),
    );
    let client = router.client("latency-soak").expect("model registered above");
    let n = graph.input_dim();
    let mut rng = Xoshiro256::new(23);
    let requests = 32u64;
    let mut lat = Vec::with_capacity(requests as usize);
    for _ in 0..requests {
        let pts: Vec<f32> = (0..rows * n).map(|_| rng.normal() as f32).collect();
        let t0 = std::time::Instant::now();
        client
            .eval_blocking(pts)
            .expect("soak traffic has no fault injection");
        lat.push(t0.elapsed().as_secs_f64());
    }
    router.shutdown();
    lat.sort_by(f64::total_cmp);
    LatencySoak {
        requests,
        p50_seconds: percentile_sorted(&lat, 0.50),
        p95_seconds: percentile_sorted(&lat, 0.95),
        p99_seconds: percentile_sorted(&lat, 0.99),
    }
}

/// Run the variance-vs-samples probe: the stochastic (STDE) engine over a
/// fixed seeded 8-point batch at each tier in [`STOCHASTIC_SAMPLE_TIERS`],
/// timed per tier and compared against the exact DOF engine on the same
/// points. Estimates are a pure function of `(seed, point index, sample
/// index)`, so the error/variance columns are bit-reproducible; only the
/// seconds are wall-clock.
pub fn measure_stochastic_tiers(
    cfg: &Table1Config,
    graph: &Graph,
    op: &Operator,
    bencher: &Bencher,
) -> Vec<StochasticTier> {
    use crate::jet::DirectionSampling;
    let rows = 8usize;
    let mut rng = Xoshiro256::new(cfg.seed ^ 0x57DE);
    let x = Tensor::randn(&[rows, cfg.n], &mut rng);
    let pool = Pool::new(1);
    let dof_engine = op.dof_engine();
    let program = dof_engine.plan(graph);
    let exact = dof_engine.execute_sharded(&program, graph, &x, &pool, DEFAULT_SHARD_ROWS);
    let exact_vals: Vec<f64> = exact.operator_values.data().to_vec();
    let mut tiers = Vec::with_capacity(STOCHASTIC_SAMPLE_TIERS.len());
    for &s in &STOCHASTIC_SAMPLE_TIERS {
        let engine = op.stochastic_engine(DirectionSampling::Gaussian, s, cfg.seed);
        let timing = bencher.run(&format!("grid/stochastic/s{s}"), || {
            let r = engine.compute_sharded(graph, &x, &pool, DEFAULT_SHARD_ROWS);
            std::hint::black_box(&r.operator_values);
            (Some(r.cost.muls), Some(r.peak_jet_bytes))
        });
        let r = engine.compute_sharded(graph, &x, &pool, DEFAULT_SHARD_ROWS);
        let est = r.operator_values.data();
        let mean_abs_error = est
            .iter()
            .zip(exact_vals.iter())
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / rows as f64;
        let mean_variance = r.variance.data().iter().sum::<f64>() / rows as f64;
        let mean_std_error = r.std_error.data().iter().sum::<f64>() / rows as f64;
        tiers.push(StochasticTier {
            samples: s,
            seconds: timing.seconds.median,
            mean_abs_error,
            mean_variance,
            mean_std_error,
            dirs_per_point: engine.directions_per_point(),
        });
    }
    tiers
}

/// Sweep the Table-1 MLP (elliptic full-rank operator) over a batch ×
/// threads grid. The model, graph, and operator are built once; per cell
/// the engines run through the same sharded path the CLI exposes.
pub fn run_table1_grid(
    cfg: &Table1Config,
    batches: &[usize],
    threads: &[usize],
) -> GridReport {
    let model = Mlp::init(
        MlpSpec {
            in_dim: cfg.n,
            hidden: cfg.hidden,
            layers: cfg.layers,
            out_dim: 1,
            act: crate::graph::Act::Tanh,
        },
        cfg.seed,
    );
    let graph = model.to_graph();
    let op = Operator::from_spec(CoeffSpec::EllipticGram {
        n: cfg.n,
        rank: cfg.n,
        seed: cfg.seed,
    });
    let bencher = Bencher::new(cfg.bench);
    let mut rng = Xoshiro256::new(cfg.seed ^ 0xBEEF);
    let mut cells = Vec::with_capacity(batches.len() * threads.len());
    // The persistent team is provisioned once, at the first parallel
    // region, from max(machine width, resolved --threads knob): raise the
    // knob to the widest grid cell *before* that first region so a
    // threads-grid above the core count actually gets its lanes (otherwise
    // wide cells would silently run on a narrower team than their label).
    // Restored after the sweep.
    let ambient_threads = Pool::from_env().threads();
    let grid_max = threads.iter().copied().max().unwrap_or(1);
    crate::parallel::set_global_threads(grid_max.max(ambient_threads));
    // Pool lifecycle: measure the cold region before any other parallel
    // work in this sweep so the one-time spawn (if unpaid so far in this
    // process) lands in the cold number, never in a grid cell.
    let pool_timing = measure_pool_timing(grid_max);
    // Plan-compile cost, measured uncached (the cost the keyed cache
    // amortizes away); every cell below reuses this one program.
    let dof_engine = op.dof_engine();
    let hes_engine = op.hessian_engine();
    let compile_reps = 5usize;
    let mut compile_times = Vec::with_capacity(compile_reps);
    for _ in 0..compile_reps {
        let t0 = std::time::Instant::now();
        std::hint::black_box(dof_engine.plan(&graph));
        compile_times.push(t0.elapsed().as_secs_f64());
    }
    compile_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let program = dof_engine.plan(&graph);
    let plan = PlanTiming {
        compile_seconds: compile_times[compile_reps / 2],
        slab_per_row: program.slab_per_row(),
        fused_steps: program.fused_steps(),
        dof_muls_per_row: program.cost(1).muls,
    };
    // The cell's thread count must also govern the row-parallel GEMM, which
    // consults the process-global pool (reached on single-shard batches
    // where no worker suppression applies) — otherwise small-batch cells
    // would be mislabeled.
    for &batch in batches {
        let x = Tensor::randn(&[batch, cfg.n], &mut rng);
        for &t in threads {
            let pool = Pool::new(t.max(1));
            crate::parallel::set_global_threads(t.max(1));
            let dof = bencher.run(&format!("grid/dof/b{batch}t{t}"), || {
                let r = dof_engine.execute_sharded(&program, &graph, &x, &pool, DEFAULT_SHARD_ROWS);
                std::hint::black_box(&r.operator_values);
                (Some(r.cost.muls), Some(r.peak_tangent_bytes))
            });
            let hes = bencher.run(&format!("grid/hessian/b{batch}t{t}"), || {
                let r = hes_engine.compute_sharded_with_program(
                    &program,
                    &graph,
                    &x,
                    &pool,
                    DEFAULT_SHARD_ROWS,
                );
                std::hint::black_box(&r.operator_values);
                (Some(r.cost.muls), Some(r.peak_tangent_bytes))
            });
            cells.push(GridCell {
                batch,
                threads: t.max(1),
                dof_seconds: dof.seconds.median,
                hessian_seconds: hes.seconds.median,
                dof_peak_bytes: dof.peak_bytes.unwrap_or(0),
                hessian_peak_bytes: hes.peak_bytes.unwrap_or(0),
                dof_muls: dof.muls.unwrap_or(0),
                hessian_muls: hes.muls.unwrap_or(0),
            });
        }
    }
    crate::parallel::set_global_threads(ambient_threads);
    // The fault-tier probe and latency soak run last so their (tiny,
    // single-threaded) serving traffic cannot perturb the pool-lifecycle
    // or per-cell measurements.
    let robustness = measure_robustness(&graph, &op);
    let soak = measure_latency_soak(&graph, &op);
    let stochastic = measure_stochastic_tiers(cfg, &graph, &op, &bencher);
    GridReport {
        cells,
        plan,
        pool: pool_timing,
        robustness,
        soak,
        stochastic,
    }
}

/// Serialize a grid to the `BENCH_table1.json` schema. `dof_ms` /
/// `hessian_ms` are per-batch *execute* times over one reused compiled
/// program; the one-time compile cost is the top-level `plan` object.
pub fn grid_json(cfg: &Table1Config, report: &GridReport) -> String {
    let cells = &report.cells;
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"table1_mlp_grid\",\n");
    s.push_str("  \"schema\": 7,\n");
    s.push_str("  \"order\": 2,\n");
    s.push_str("  \"operator\": \"elliptic\",\n");
    s.push_str(
        "  \"provenance\": \"schema v7 (stochastic estimation): adds the stochastic \
         object (variance-vs-samples sweep of the STDE engine against the exact DOF \
         engine: per sample tier the empirical |estimate-exact| error, the engine's \
         own variance/std_error report, and the per-batch seconds); v6 \
         (observability): adds the latency_percentiles \
         object (client-observed p50/p95/p99 from a deterministic routed soak); v5 \
         (SIMD-ized kernels + plan-time micro-kernel specialization): grid cells \
         execute over plan-recorded GemmPlan dispatch and per-call packed weight \
         panels, and the companion `dof bench kernels` report carries the kernels \
         object; v4 added the robustness object (exact shed/retry/deadline/quarantine \
         counters from a scripted fault-injection serving run); v3 added the pool \
         object (cold vs warm region dispatch, spawn events); v2 added the order \
         column so order-2 (DOF) and order-4 (jet) grids share one trajectory \
         format\",\n",
    );
    s.push_str(&format!(
        "  \"config\": {{\"n\": {}, \"hidden\": {}, \"layers\": {}, \"seed\": {}, \"shard_rows\": {}}},\n",
        cfg.n, cfg.hidden, cfg.layers, cfg.seed, DEFAULT_SHARD_ROWS
    ));
    s.push_str(&format!(
        "  \"plan\": {{\"compile_ms\": {:.4}, \"slab_scalars_per_row\": {}, \"fused_steps\": {}, \"dof_muls_per_row\": {}, \"execution\": \"plan-reused\"}},\n",
        report.plan.compile_seconds * 1e3,
        report.plan.slab_per_row,
        report.plan.fused_steps,
        report.plan.dof_muls_per_row
    ));
    s.push_str(&format!(
        "  \"pool\": {{\"cold_region_ms\": {:.4}, \"warm_region_ms\": {:.4}, \
         \"cold_included_spawn\": {}, \"spawn_events\": {}, \"workers\": {}}},\n",
        report.pool.cold_region_seconds * 1e3,
        report.pool.warm_region_seconds * 1e3,
        report.pool.cold_included_spawn,
        report.pool.spawn_events,
        report.pool.workers
    ));
    s.push_str(&format!(
        "  \"robustness\": {{\"requests\": {}, \"completed\": {}, \"failed\": {}, \
         \"shed\": {}, \"retries\": {}, \"deadline_expired\": {}, \"engine_faults\": {}, \
         \"quarantine_events\": {}, \"healthy_replicas\": {}, \"replicas\": {}}},\n",
        report.robustness.requests,
        report.robustness.completed,
        report.robustness.failed,
        report.robustness.shed,
        report.robustness.retries,
        report.robustness.deadline_expired,
        report.robustness.engine_faults,
        report.robustness.quarantine_events,
        report.robustness.healthy_replicas,
        report.robustness.replicas
    ));
    s.push_str(&format!(
        "  \"latency_percentiles\": {{\"requests\": {}, \"p50_ms\": {:.4}, \
         \"p95_ms\": {:.4}, \"p99_ms\": {:.4}}},\n",
        report.soak.requests,
        report.soak.p50_seconds * 1e3,
        report.soak.p95_seconds * 1e3,
        report.soak.p99_seconds * 1e3
    ));
    s.push_str("  \"stochastic\": {\"sampling\": \"gaussian\", \"rows\": 8, \"tiers\": [\n");
    for (i, t) in report.stochastic.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"samples\": {}, \"seconds\": {:.6}, \"mean_abs_error\": {:.6e}, \
             \"mean_variance\": {:.6e}, \"mean_std_error\": {:.6e}, \
             \"dirs_per_point\": {}}}{}\n",
            t.samples,
            t.seconds,
            t.mean_abs_error,
            t.mean_variance,
            t.mean_std_error,
            t.dirs_per_point,
            if i + 1 < report.stochastic.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]},\n");
    s.push_str("  \"cells\": [\n");
    for (i, c) in cells.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"batch\": {}, \"threads\": {}, \"dof_ms\": {:.4}, \"hessian_ms\": {:.4}, \
             \"time_ratio\": {:.3}, \"dof_peak_bytes\": {}, \"hessian_peak_bytes\": {}, \
             \"dof_muls\": {}, \"hessian_muls\": {}}}{}\n",
            c.batch,
            c.threads,
            c.dof_seconds * 1e3,
            c.hessian_seconds * 1e3,
            c.time_ratio(),
            c.dof_peak_bytes,
            c.hessian_peak_bytes,
            c.dof_muls,
            c.hessian_muls,
            if i + 1 < cells.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Write the grid JSON to `path`.
pub fn write_grid_json(
    path: &str,
    cfg: &Table1Config,
    report: &GridReport,
) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(grid_json(cfg, report).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::BenchConfig;

    #[test]
    fn grid_runs_and_serializes() {
        let cfg = Table1Config {
            n: 8,
            hidden: 16,
            layers: 2,
            batch: 4,
            threads: 1,
            seed: 11,
            bench: BenchConfig {
                warmup_iters: 0,
                measure_iters: 1,
                max_seconds: 10.0,
            },
        };
        let report = run_table1_grid(&cfg, &[4, 9], &[1, 2]);
        let cells = &report.cells;
        assert_eq!(cells.len(), 4);
        // FLOP counts are exact and thread-count-invariant (the determinism
        // contract): same batch → identical muls across the threads axis.
        assert_eq!(cells[0].dof_muls, cells[1].dof_muls);
        assert_eq!(cells[2].hessian_muls, cells[3].hessian_muls);
        // The analytic per-row count matches the executed cell exactly.
        assert_eq!(cells[0].dof_muls, report.plan.dof_muls_per_row * 4);
        assert!(report.plan.compile_seconds >= 0.0);
        assert!(report.plan.slab_per_row > 0);
        // Pool lifecycle rides along: spawn happened at most once, and the
        // warm region number is a real measurement.
        assert_eq!(report.pool.spawn_events, 1);
        assert!(report.pool.warm_region_seconds.is_finite());
        // The fault-tier probe runs a deterministic schedule, so every
        // counter is exact: two scripted engine faults fail over (one
        // retry each), the failing replica is quarantined once, and the
        // final request's probe readmits it — both replicas end Healthy.
        let r = &report.robustness;
        assert_eq!(
            (r.requests, r.completed, r.failed),
            (4, 4, 0),
            "all probe traffic completes via failover"
        );
        assert_eq!((r.shed, r.deadline_expired), (0, 0));
        assert_eq!((r.retries, r.engine_faults), (2, 2));
        assert_eq!(r.quarantine_events, 1);
        assert_eq!((r.healthy_replicas, r.replicas), (2, 2));
        // The latency soak is a fixed-size schedule; its percentiles are
        // real client-observed measurements, so only order is asserted.
        assert_eq!(report.soak.requests, 32);
        assert!(report.soak.p50_seconds >= 0.0);
        assert!(report.soak.p50_seconds <= report.soak.p95_seconds);
        assert!(report.soak.p95_seconds <= report.soak.p99_seconds);
        // The stochastic probe sweeps every tier; its error/variance
        // columns are seeded and finite, and the estimator pays more
        // directions per point at higher sample counts.
        assert_eq!(report.stochastic.len(), STOCHASTIC_SAMPLE_TIERS.len());
        for t in &report.stochastic {
            assert!(t.mean_abs_error.is_finite() && t.mean_abs_error >= 0.0);
            assert!(t.mean_variance.is_finite() && t.mean_variance >= 0.0);
            assert!(t.mean_std_error.is_finite() && t.mean_std_error >= 0.0);
        }
        assert!(
            report.stochastic[0].dirs_per_point < report.stochastic[2].dirs_per_point
        );
        let json = grid_json(&cfg, &report);
        assert!(json.contains("\"bench\": \"table1_mlp_grid\""));
        assert!(json.contains("\"schema\": 7"));
        assert!(json.contains("\"stochastic\""));
        assert!(json.contains("\"mean_std_error\""));
        assert!(json.contains("\"latency_percentiles\""));
        assert!(json.contains("\"order\": 2"));
        assert!(json.contains("\"plan\""));
        assert!(json.contains("\"compile_ms\""));
        assert!(json.contains("\"pool\""));
        assert!(json.contains("\"warm_region_ms\""));
        assert!(json.contains("\"robustness\""));
        assert!(json.contains("\"quarantine_events\": 1"));
        assert!(json.contains("\"batch\": 9"));
        assert!(json.ends_with("}\n"));
        // Balanced braces/brackets as a cheap well-formedness check.
        assert_eq!(
            json.matches('{').count(),
            json.matches('}').count()
        );
        assert_eq!(
            json.matches('[').count(),
            json.matches(']').count()
        );
    }
}
