//! Machine-readable perf reports: the batch × threads grid behind
//! `BENCH_table1.json`, so future changes can track the perf trajectory
//! without scraping terminal tables.
//!
//! The JSON is hand-rolled (no `serde` in the offline build) and carries,
//! per grid cell, DOF and Hessian wall-clock plus the exact peak-tangent
//! bytes and multiplication counts from the engines' own instrumentation.
//!
//! Produced by `dof bench grid [--batches 8,64,256 --threads-grid 1,2,4,8]`
//! and by `cargo bench --bench table1_mlp`.

use std::io::Write as _;

use crate::nn::{Mlp, MlpSpec};
use crate::operators::{CoeffSpec, Operator};
use crate::parallel::{Pool, DEFAULT_SHARD_ROWS};
use crate::tensor::Tensor;
use crate::util::Xoshiro256;

use super::table1::Table1Config;
use super::Bencher;

/// One (batch, threads) measurement of the Table-1 elliptic operator.
#[derive(Debug, Clone)]
pub struct GridCell {
    pub batch: usize,
    pub threads: usize,
    pub dof_seconds: f64,
    pub hessian_seconds: f64,
    pub dof_peak_bytes: u64,
    pub hessian_peak_bytes: u64,
    pub dof_muls: u64,
    pub hessian_muls: u64,
}

impl GridCell {
    /// Hessian / DOF wall-clock ratio.
    pub fn time_ratio(&self) -> f64 {
        self.hessian_seconds / self.dof_seconds.max(1e-12)
    }
}

/// Sweep the Table-1 MLP (elliptic full-rank operator) over a batch ×
/// threads grid. The model, graph, and operator are built once; per cell
/// the engines run through the same sharded path the CLI exposes.
pub fn run_table1_grid(
    cfg: &Table1Config,
    batches: &[usize],
    threads: &[usize],
) -> Vec<GridCell> {
    let model = Mlp::init(
        MlpSpec {
            in_dim: cfg.n,
            hidden: cfg.hidden,
            layers: cfg.layers,
            out_dim: 1,
            act: crate::graph::Act::Tanh,
        },
        cfg.seed,
    );
    let graph = model.to_graph();
    let op = Operator::from_spec(CoeffSpec::EllipticGram {
        n: cfg.n,
        rank: cfg.n,
        seed: cfg.seed,
    });
    let bencher = Bencher::new(cfg.bench);
    let mut rng = Xoshiro256::new(cfg.seed ^ 0xBEEF);
    let mut cells = Vec::with_capacity(batches.len() * threads.len());
    // The cell's thread count must also govern the row-parallel GEMM, which
    // consults the process-global pool (reached on single-shard batches
    // where no worker suppression applies) — otherwise small-batch cells
    // would be mislabeled. Restored after the sweep.
    let ambient_threads = Pool::from_env().threads();
    for &batch in batches {
        let x = Tensor::randn(&[batch, cfg.n], &mut rng);
        for &t in threads {
            let pool = Pool::new(t.max(1));
            crate::parallel::set_global_threads(t.max(1));
            let dof_engine = op.dof_engine();
            let dof = bencher.run(&format!("grid/dof/b{batch}t{t}"), || {
                let r = dof_engine.compute_sharded(&graph, &x, &pool, DEFAULT_SHARD_ROWS);
                std::hint::black_box(&r.operator_values);
                (Some(r.cost.muls), Some(r.peak_tangent_bytes))
            });
            let hes_engine = op.hessian_engine();
            let hes = bencher.run(&format!("grid/hessian/b{batch}t{t}"), || {
                let r = hes_engine.compute_sharded(&graph, &x, &pool, DEFAULT_SHARD_ROWS);
                std::hint::black_box(&r.operator_values);
                (Some(r.cost.muls), Some(r.peak_tangent_bytes))
            });
            cells.push(GridCell {
                batch,
                threads: t.max(1),
                dof_seconds: dof.seconds.median,
                hessian_seconds: hes.seconds.median,
                dof_peak_bytes: dof.peak_bytes.unwrap_or(0),
                hessian_peak_bytes: hes.peak_bytes.unwrap_or(0),
                dof_muls: dof.muls.unwrap_or(0),
                hessian_muls: hes.muls.unwrap_or(0),
            });
        }
    }
    crate::parallel::set_global_threads(ambient_threads);
    cells
}

/// Serialize a grid to the `BENCH_table1.json` schema.
pub fn grid_json(cfg: &Table1Config, cells: &[GridCell]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"table1_mlp_grid\",\n");
    s.push_str("  \"operator\": \"elliptic\",\n");
    s.push_str(&format!(
        "  \"config\": {{\"n\": {}, \"hidden\": {}, \"layers\": {}, \"seed\": {}, \"shard_rows\": {}}},\n",
        cfg.n, cfg.hidden, cfg.layers, cfg.seed, DEFAULT_SHARD_ROWS
    ));
    s.push_str("  \"cells\": [\n");
    for (i, c) in cells.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"batch\": {}, \"threads\": {}, \"dof_ms\": {:.4}, \"hessian_ms\": {:.4}, \
             \"time_ratio\": {:.3}, \"dof_peak_bytes\": {}, \"hessian_peak_bytes\": {}, \
             \"dof_muls\": {}, \"hessian_muls\": {}}}{}\n",
            c.batch,
            c.threads,
            c.dof_seconds * 1e3,
            c.hessian_seconds * 1e3,
            c.time_ratio(),
            c.dof_peak_bytes,
            c.hessian_peak_bytes,
            c.dof_muls,
            c.hessian_muls,
            if i + 1 < cells.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Write the grid JSON to `path`.
pub fn write_grid_json(
    path: &str,
    cfg: &Table1Config,
    cells: &[GridCell],
) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(grid_json(cfg, cells).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::BenchConfig;

    #[test]
    fn grid_runs_and_serializes() {
        let cfg = Table1Config {
            n: 8,
            hidden: 16,
            layers: 2,
            batch: 4,
            threads: 1,
            seed: 11,
            bench: BenchConfig {
                warmup_iters: 0,
                measure_iters: 1,
                max_seconds: 10.0,
            },
        };
        let cells = run_table1_grid(&cfg, &[4, 9], &[1, 2]);
        assert_eq!(cells.len(), 4);
        // FLOP counts are exact and thread-count-invariant (the determinism
        // contract): same batch → identical muls across the threads axis.
        assert_eq!(cells[0].dof_muls, cells[1].dof_muls);
        assert_eq!(cells[2].hessian_muls, cells[3].hessian_muls);
        let json = grid_json(&cfg, &cells);
        assert!(json.contains("\"bench\": \"table1_mlp_grid\""));
        assert!(json.contains("\"batch\": 9"));
        assert!(json.ends_with("}\n"));
        // Balanced braces/brackets as a cheap well-formedness check.
        assert_eq!(
            json.matches('{').count(),
            json.matches('}').count()
        );
        assert_eq!(
            json.matches('[').count(),
            json.matches(']').count()
        );
    }
}
