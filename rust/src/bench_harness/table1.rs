//! Table 1 experiment driver: DOF vs Hessian-based on the plain MLP
//! (Appendix E / Table 3 architecture; Table 4 row 1 operators).
//!
//! The paper reports V100 GPU-memory MB and milliseconds at its (unstated)
//! batch size; we report CPU wall-clock, exact peak tangent bytes, and
//! exact multiplication counts at a configurable batch size — the claims
//! under test are the *ratios* (≈3.3× memory, ≈1.8×/3.5×/1.6× time).

use crate::graph::Act;
use crate::nn::{Mlp, MlpSpec};
use crate::operators::{table4_mlp, Operator};
use crate::parallel::{Pool, DEFAULT_SHARD_ROWS};
use crate::tensor::Tensor;
use crate::util::Xoshiro256;

use super::{BenchConfig, Bencher, CompareRow};

/// Table 1 configuration.
#[derive(Debug, Clone, Copy)]
pub struct Table1Config {
    /// Input dimension `N` (paper: 64).
    pub n: usize,
    /// Hidden width (paper: 256).
    pub hidden: usize,
    /// Hidden layers (paper: 8).
    pub layers: usize,
    /// Batch of collocation points per evaluation.
    pub batch: usize,
    /// Worker threads for batch sharding (1 = the legacy serial engines).
    pub threads: usize,
    pub seed: u64,
    pub bench: BenchConfig,
}

impl Default for Table1Config {
    fn default() -> Self {
        Self {
            n: 64,
            hidden: 256,
            layers: 8,
            batch: 8,
            threads: 1,
            seed: 7,
            bench: BenchConfig::default(),
        }
    }
}

/// Run the three operator rows of Table 1.
pub fn run_table1(cfg: &Table1Config) -> Vec<CompareRow> {
    let model = Mlp::init(
        MlpSpec {
            in_dim: cfg.n,
            hidden: cfg.hidden,
            layers: cfg.layers,
            out_dim: 1,
            act: Act::Tanh,
        },
        cfg.seed,
    );
    let graph = model.to_graph();
    let mut rng = Xoshiro256::new(cfg.seed ^ 0xBEEF);
    let x = Tensor::randn(&[cfg.batch, cfg.n], &mut rng);
    let bencher = Bencher::new(cfg.bench);

    // Table 4 row 1, rescaled to the configured N (ranks N and N/2).
    let specs: Vec<(String, Operator)> = if cfg.n == 64 {
        table4_mlp(cfg.seed)
            .into_iter()
            .map(|(name, s)| (name.to_string(), Operator::from_spec(s)))
            .collect()
    } else {
        use crate::operators::CoeffSpec;
        vec![
            (
                "Elliptic".into(),
                Operator::from_spec(CoeffSpec::EllipticGram {
                    n: cfg.n,
                    rank: cfg.n,
                    seed: cfg.seed,
                }),
            ),
            (
                "Low-rank".into(),
                Operator::from_spec(CoeffSpec::EllipticGram {
                    n: cfg.n,
                    rank: cfg.n / 2,
                    seed: cfg.seed,
                }),
            ),
            (
                "General".into(),
                Operator::from_spec(CoeffSpec::SignedDiag { n: cfg.n }),
            ),
        ]
    };

    // Always the sharded path: at `threads: 1` it runs inline under a serial
    // guard, so the FLOP and per-shard peak-byte columns are identical across
    // thread counts (the determinism contract) and only wall-clock moves.
    // Each operator's program is compiled once outside the timed loop and
    // reused by both engines — the steady state serving/training see.
    let pool = Pool::new(cfg.threads.max(1));
    specs
        .into_iter()
        .map(|(name, op)| {
            let hes_engine = op.hessian_engine();
            let dof_engine = op.dof_engine();
            let program = dof_engine.plan(&graph);
            let hessian = bencher.run(&format!("hessian/{name}"), || {
                let r = hes_engine.compute_sharded_with_program(
                    &program,
                    &graph,
                    &x,
                    &pool,
                    DEFAULT_SHARD_ROWS,
                );
                std::hint::black_box(&r.operator_values);
                (Some(r.cost.muls), Some(r.peak_tangent_bytes))
            });
            let dof = bencher.run(&format!("dof/{name}"), || {
                let r = dof_engine.execute_sharded(&program, &graph, &x, &pool, DEFAULT_SHARD_ROWS);
                std::hint::black_box(&r.operator_values);
                (Some(r.cost.muls), Some(r.peak_tangent_bytes))
            });
            CompareRow {
                operator: name,
                hessian,
                dof,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scaled-down Table 1 shape check: DOF wins time, memory, and FLOPs
    /// for all three operator classes.
    #[test]
    fn table1_shape_holds_scaled_down() {
        let cfg = Table1Config {
            n: 16,
            hidden: 32,
            layers: 3,
            batch: 2,
            threads: 1,
            seed: 3,
            bench: BenchConfig {
                warmup_iters: 1,
                measure_iters: 3,
                max_seconds: 20.0,
            },
        };
        let rows = run_table1(&cfg);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            let fr = r.flop_ratio().unwrap();
            // At N = 16 the value/s-stream overhead dilutes the ratio to
            // ≈ (2N+1)/(N+2) ≈ 1.8; at the paper's N = 64 it is ≈ 1.95.
            assert!(fr >= 1.7, "{}: FLOP ratio {fr:.2} < 1.7", r.operator);
            let mr = r.memory_ratio().unwrap();
            assert!(mr > 1.0, "{}: memory ratio {mr:.2} ≤ 1", r.operator);
        }
        // Low-rank should beat elliptic on FLOP ratio (r = N/2).
        let elliptic = rows[0].flop_ratio().unwrap();
        let lowrank = rows[1].flop_ratio().unwrap();
        assert!(
            lowrank > 1.5 * elliptic,
            "low-rank {lowrank:.2} !≫ elliptic {elliptic:.2}"
        );
    }
}
