//! Table 2 experiment driver: DOF vs Hessian-based on the MLP with
//! Jacobian sparsity (16 blocks × 4 input dims, per-block MLPs, product-sum
//! head; block-diagonal coefficient matrices of Table 4 row 2).
//!
//! The paper reports ≈21× memory and ≈19–29× time advantages here, because
//! DOF's forward tangents inherit the architecture's Jacobian sparsity (the
//! active-row tracking in [`crate::autodiff::dof`]) while the Hessian-based
//! method stays dense.

use crate::graph::Act;
use crate::nn::{SparseMlp, SparseMlpSpec};
use crate::operators::{table4_sparse, Operator};
use crate::parallel::{Pool, DEFAULT_SHARD_ROWS};
use crate::tensor::Tensor;
use crate::util::Xoshiro256;

use super::{BenchConfig, Bencher, CompareRow};

/// Table 2 configuration.
#[derive(Debug, Clone, Copy)]
pub struct Table2Config {
    /// Number of input blocks (paper: 16).
    pub blocks: usize,
    /// Per-block input dim (paper: 4).
    pub block_in: usize,
    /// Hidden width (paper: 256).
    pub hidden: usize,
    /// Hidden layers (paper: 8).
    pub layers: usize,
    /// Per-block output dim (paper: 8).
    pub block_out: usize,
    pub batch: usize,
    /// Worker threads for batch sharding (1 = the legacy serial engines).
    pub threads: usize,
    pub seed: u64,
    pub bench: BenchConfig,
}

impl Default for Table2Config {
    fn default() -> Self {
        Self {
            blocks: 16,
            block_in: 4,
            hidden: 256,
            layers: 8,
            block_out: 8,
            batch: 8,
            threads: 1,
            seed: 7,
            bench: BenchConfig::default(),
        }
    }
}

/// Run the three operator rows of Table 2.
pub fn run_table2(cfg: &Table2Config) -> Vec<CompareRow> {
    let model = SparseMlp::init(
        SparseMlpSpec {
            blocks: cfg.blocks,
            block_in: cfg.block_in,
            hidden: cfg.hidden,
            layers: cfg.layers,
            block_out: cfg.block_out,
            act: Act::Tanh,
        },
        cfg.seed,
    );
    let graph = model.to_graph();
    let n = cfg.blocks * cfg.block_in;
    let mut rng = Xoshiro256::new(cfg.seed ^ 0xF00D);
    let x = Tensor::randn(&[cfg.batch, n], &mut rng);
    let bencher = Bencher::new(cfg.bench);

    let specs: Vec<(String, Operator)> = if cfg.blocks == 16 && cfg.block_in == 4 {
        table4_sparse(cfg.seed)
            .into_iter()
            .map(|(name, s)| (name.to_string(), Operator::from_spec(s)))
            .collect()
    } else {
        use crate::operators::CoeffSpec;
        vec![
            (
                "Elliptic".into(),
                Operator::from_spec(CoeffSpec::BlockDiagGram {
                    blocks: cfg.blocks,
                    block: cfg.block_in,
                    rank: cfg.block_in,
                    seed: cfg.seed,
                }),
            ),
            (
                "Low-rank".into(),
                Operator::from_spec(CoeffSpec::BlockDiagGram {
                    blocks: cfg.blocks,
                    block: cfg.block_in,
                    rank: (cfg.block_in / 2).max(1),
                    seed: cfg.seed,
                }),
            ),
            (
                "General".into(),
                Operator::from_spec(CoeffSpec::BlockDiagSigned {
                    blocks: cfg.blocks,
                    block: cfg.block_in,
                }),
            ),
        ]
    };

    // Always the sharded path (see table1.rs): serial at `threads: 1`, and
    // the exact-count columns stay invariant under the thread knob.
    let pool = Pool::new(cfg.threads.max(1));
    specs
        .into_iter()
        .map(|(name, op)| {
            let hes_engine = op.hessian_engine();
            let hessian = bencher.run(&format!("hessian/{name}"), || {
                let r = hes_engine.compute_sharded(&graph, &x, &pool, DEFAULT_SHARD_ROWS);
                std::hint::black_box(&r.operator_values);
                (Some(r.cost.muls), Some(r.peak_tangent_bytes))
            });
            let dof_engine = op.dof_engine();
            let dof = bencher.run(&format!("dof/{name}"), || {
                let r = dof_engine.compute_sharded(&graph, &x, &pool, DEFAULT_SHARD_ROWS);
                std::hint::black_box(&r.operator_values);
                (Some(r.cost.muls), Some(r.peak_tangent_bytes))
            });
            CompareRow {
                operator: name,
                hessian,
                dof,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scaled-down Table 2: the sparsity advantage must be much larger
    /// than the dense 2× — approximately `2·blocks` on FLOPs.
    #[test]
    fn table2_sparsity_advantage_scaled_down() {
        let cfg = Table2Config {
            blocks: 4,
            block_in: 3,
            hidden: 16,
            layers: 2,
            block_out: 4,
            batch: 2,
            threads: 1,
            seed: 5,
            bench: BenchConfig {
                warmup_iters: 1,
                measure_iters: 3,
                max_seconds: 30.0,
            },
        };
        let rows = run_table2(&cfg);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            let fr = r.flop_ratio().unwrap();
            // Dense Hessian ≈ (2N+1)/(block_in+2) ≈ 5× the sparse DOF at
            // this small scale (N = 12, block 3); ≈ 21× at paper scale
            // (N = 64, block 4). Require comfortably above the dense 2×.
            assert!(
                fr > cfg.blocks as f64,
                "{}: FLOP ratio {fr:.1} too small for sparsity win",
                r.operator
            );
            let mr = r.memory_ratio().unwrap();
            assert!(mr > 2.0, "{}: memory ratio {mr:.1}", r.operator);
        }
    }
}
