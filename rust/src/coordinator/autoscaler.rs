//! Deterministic, tick-driven autoscaler over the [`Router`]'s replica
//! sets.
//!
//! The ROADMAP serving item asks for an autoscaler loop that consumes
//! [`RouterModelSnapshot`]s and spawns or retires replicas when queue
//! depth or occupancy crosses thresholds. The design constraint is the
//! same one the whole control plane lives under: **no wall clock, no
//! background nondeterminism**. So the autoscaler is not a thread — it is
//! a pure decision step, [`Autoscaler::step`], that the serve loop (or a
//! test driver) calls explicitly. Every input is either an exact counter
//! (`interval_peak_queue_depth`, replica counts) or the shared
//! [`TickClock`](super::fault::TickClock) read through
//! [`Router::clock`]; given the same scripted load and tick schedule, the
//! same scale events fire at the same ticks with the same replica counts
//! (asserted by `rust/tests/autoscaler.rs`).
//!
//! Each step, per model, in registration order:
//!
//! 1. Read the model's scaling snapshot — this swap-resets
//!    `interval_peak_queue_depth`, so the step sees the queue-depth
//!    high-water mark **since the previous step**.
//! 2. If the model scaled within the last
//!    [`cooldown_ticks`](AutoscalerConfig::cooldown_ticks), do nothing
//!    (hysteresis: the observation is discarded, not deferred).
//! 3. Otherwise scale **up** by one replica (via the model's registered
//!    [`ReplicaFactory`](super::router::ReplicaFactory)) when the replica
//!    count is below [`min_replicas`](AutoscalerConfig::min_replicas), or
//!    when the interval peak reaches
//!    [`up_queue_depth`](AutoscalerConfig::up_queue_depth) — or the
//!    aggregated `parallel_occupancy` reaches
//!    [`up_occupancy`](AutoscalerConfig::up_occupancy) — with the count
//!    below [`max_replicas`](AutoscalerConfig::max_replicas).
//! 4. Else scale **down** by one replica when the interval peak is at or
//!    below [`down_queue_depth`](AutoscalerConfig::down_queue_depth), the
//!    occupancy is at or below
//!    [`down_occupancy`](AutoscalerConfig::down_occupancy), and the count
//!    is above `min_replicas`. Retirement is draining: the router
//!    unpublishes the replica first and then runs its graceful shutdown,
//!    so no admitted request is lost.
//!
//! At most one replica is added or removed per model per step — scaling
//! is gradual by construction, and combined with the cooldown this gives
//! classic hysteresis (a spike must persist across steps to reach
//! `max_replicas`; a lull must persist to drain back down).
//!
//! The occupancy thresholds deserve a caveat: `parallel_occupancy` is
//! derived from measured compute seconds (data plane), so decisions gated
//! on it are load-aware but not replayable tick-for-tick. Both default to
//! infinity (disabled); the queue-depth thresholds alone keep the scaler
//! fully deterministic.

use std::collections::HashMap;

use super::router::{Router, RouterModelSnapshot};

/// Scaling thresholds and hysteresis knobs (see module docs).
#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    /// Floor on the replica count; the scaler also grows a model back up
    /// to this floor regardless of load. Must be ≥ 1.
    pub min_replicas: usize,
    /// Ceiling on the replica count. Must be ≥ `min_replicas`.
    pub max_replicas: usize,
    /// Scale up when the interval peak queue depth reaches this. Must be
    /// greater than `down_queue_depth` (the dead band between the two is
    /// what prevents flapping).
    pub up_queue_depth: usize,
    /// Scale up when aggregated `parallel_occupancy` reaches this
    /// (measured-seconds signal; `f64::INFINITY` = disabled).
    pub up_occupancy: f64,
    /// Scale down when the interval peak queue depth is at or below this.
    pub down_queue_depth: usize,
    /// Scale down only while aggregated `parallel_occupancy` is at or
    /// below this (`f64::INFINITY` = no occupancy condition).
    pub down_occupancy: f64,
    /// Ticks that must elapse after a model's last scale event before it
    /// may scale again.
    pub cooldown_ticks: u64,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        Self {
            min_replicas: 1,
            max_replicas: 4,
            up_queue_depth: 8,
            up_occupancy: f64::INFINITY,
            down_queue_depth: 1,
            down_occupancy: f64::INFINITY,
            cooldown_ticks: 16,
        }
    }
}

/// Which way a scale event moved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDirection {
    Up,
    Down,
}

/// One scaling action, recorded for telemetry and test assertions.
#[derive(Debug, Clone)]
pub struct ScaleEvent {
    pub model: String,
    pub direction: ScaleDirection,
    /// Logical tick at which the step fired the event.
    pub tick: u64,
    pub replicas_before: usize,
    pub replicas_after: usize,
    /// The queue-depth high-water mark that drove the decision.
    pub interval_peak_queue_depth: usize,
    /// Aggregated `parallel_occupancy` at decision time (informational;
    /// exact assertions should use the queue-depth field).
    pub occupancy: f64,
}

/// Point-in-time autoscaler accounting (rendered into telemetry by
/// `obs::Registry::add_autoscaler`).
#[derive(Debug, Clone, Default)]
pub struct AutoscalerSnapshot {
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Every event since construction, in firing order.
    pub events: Vec<ScaleEvent>,
}

/// The decision engine (see module docs). Owns only hysteresis state and
/// the event log; all load state lives in the router's counters.
pub struct Autoscaler {
    cfg: AutoscalerConfig,
    /// Tick of each model's most recent scale event.
    last_action: HashMap<String, u64>,
    scale_ups: u64,
    scale_downs: u64,
    events: Vec<ScaleEvent>,
}

impl Autoscaler {
    /// Panics on an inconsistent config: the replica bounds must satisfy
    /// `1 ≤ min ≤ max`, and the queue thresholds must leave a dead band
    /// (`up_queue_depth > down_queue_depth`).
    pub fn new(cfg: AutoscalerConfig) -> Self {
        assert!(cfg.min_replicas >= 1, "min_replicas must be at least 1");
        assert!(
            cfg.max_replicas >= cfg.min_replicas,
            "max_replicas must be >= min_replicas"
        );
        assert!(
            cfg.up_queue_depth > cfg.down_queue_depth,
            "up_queue_depth must exceed down_queue_depth (dead band)"
        );
        Self {
            cfg,
            last_action: HashMap::new(),
            scale_ups: 0,
            scale_downs: 0,
            events: Vec::new(),
        }
    }

    pub fn config(&self) -> &AutoscalerConfig {
        &self.cfg
    }

    /// Run one decision step over every registered model. Returns the
    /// events fired by this step (also appended to the cumulative log).
    pub fn step(&mut self, router: &mut Router) -> Vec<ScaleEvent> {
        let now = router.clock().now();
        let snaps = router.scaling_snapshot();
        let mut fired = Vec::new();
        for snap in &snaps {
            if let Some(ev) = self.step_model(router, snap, now) {
                fired.push(ev);
            }
        }
        self.events.extend(fired.iter().cloned());
        fired
    }

    /// Cumulative accounting since construction.
    pub fn snapshot(&self) -> AutoscalerSnapshot {
        AutoscalerSnapshot {
            scale_ups: self.scale_ups,
            scale_downs: self.scale_downs,
            events: self.events.clone(),
        }
    }

    fn step_model(
        &mut self,
        router: &mut Router,
        snap: &RouterModelSnapshot,
        now: u64,
    ) -> Option<ScaleEvent> {
        let before = snap.replicas.len();
        if let Some(&t) = self.last_action.get(&snap.model) {
            if now.saturating_sub(t) < self.cfg.cooldown_ticks {
                return None;
            }
        }
        let peak = snap.interval_peak_queue_depth;
        let occupancy = snap.server.parallel_occupancy;
        let below_floor = before < self.cfg.min_replicas;
        let overloaded = (peak >= self.cfg.up_queue_depth || occupancy >= self.cfg.up_occupancy)
            && before < self.cfg.max_replicas;
        let idle = peak <= self.cfg.down_queue_depth
            && occupancy <= self.cfg.down_occupancy
            && before > self.cfg.min_replicas;
        let (direction, after) = if below_floor || overloaded {
            // A model without a registered factory cannot grow; treat it
            // as unscalable rather than an error so mixed fleets work.
            (ScaleDirection::Up, router.scale_up(&snap.model).ok()?)
        } else if idle {
            (ScaleDirection::Down, router.retire_replica(&snap.model).ok()?)
        } else {
            return None;
        };
        self.last_action.insert(snap.model.clone(), now);
        match direction {
            ScaleDirection::Up => self.scale_ups += 1,
            ScaleDirection::Down => self.scale_downs += 1,
        }
        Some(ScaleEvent {
            model: snap.model.clone(),
            direction,
            tick: now,
            replicas_before: before,
            replicas_after: after,
            interval_peak_queue_depth: peak,
            occupancy,
        })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::coordinator::{BatchFn, BatchPolicy, ModelServer, RouterConfig, TickClock};

    fn echo_server() -> ModelServer {
        let compute: BatchFn = Box::new(|data, _| Ok((data.to_vec(), data.to_vec())));
        ModelServer::spawn(1, BatchPolicy::ticks(8, 0), compute)
    }

    fn scaler(cooldown: u64, max: usize) -> Autoscaler {
        Autoscaler::new(AutoscalerConfig {
            min_replicas: 1,
            max_replicas: max,
            up_queue_depth: 1,
            down_queue_depth: 0,
            cooldown_ticks: cooldown,
            ..AutoscalerConfig::default()
        })
    }

    fn router_with_factory(clock: &TickClock) -> Router {
        let mut router = Router::with_config(RouterConfig {
            clock: clock.clone(),
            ..RouterConfig::default()
        });
        router.register("m", echo_server());
        router
            .set_replica_factory("m", Box::new(echo_server))
            .unwrap();
        router
    }

    #[test]
    fn scales_up_and_down_at_exact_ticks() {
        let clock = TickClock::new();
        let mut router = router_with_factory(&clock);
        let mut scaler = scaler(5, 3);

        // Tick 0: traffic happened (interval peak >= 1) → scale up.
        router.eval_blocking("m", vec![1.0]).unwrap();
        let events = scaler.step(&mut router);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].direction, ScaleDirection::Up);
        assert_eq!(events[0].tick, 0);
        assert_eq!((events[0].replicas_before, events[0].replicas_after), (1, 2));
        assert_eq!(router.replica_count("m"), Some(2));

        // Still tick 0: cooldown discards the next observation entirely.
        router.eval_blocking("m", vec![1.0]).unwrap();
        assert!(scaler.step(&mut router).is_empty());
        assert_eq!(router.replica_count("m"), Some(2));

        // Tick 4: one tick short of the cooldown — still held.
        clock.advance(4);
        assert!(scaler.step(&mut router).is_empty());

        // Tick 5: cooldown over, interval quiet (peak 0) → scale down.
        clock.advance(1);
        let events = scaler.step(&mut router);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].direction, ScaleDirection::Down);
        assert_eq!(events[0].tick, 5);
        assert_eq!((events[0].replicas_before, events[0].replicas_after), (2, 1));
        assert_eq!(router.replica_count("m"), Some(1));

        // Tick 10: still quiet but already at min_replicas → no event.
        clock.advance(5);
        assert!(scaler.step(&mut router).is_empty());
        assert_eq!(router.replica_count("m"), Some(1));

        let snap = scaler.snapshot();
        assert_eq!((snap.scale_ups, snap.scale_downs), (1, 1));
        assert_eq!(snap.events.len(), 2);
        router.shutdown();
    }

    #[test]
    fn max_replicas_caps_growth() {
        let clock = TickClock::new();
        let mut router = router_with_factory(&clock);
        let mut scaler = scaler(1, 2);
        for _ in 0..4 {
            router.eval_blocking("m", vec![1.0]).unwrap();
            scaler.step(&mut router);
            clock.advance(1);
        }
        assert_eq!(router.replica_count("m"), Some(2), "capped at max");
        assert_eq!(scaler.snapshot().scale_ups, 1);
        router.shutdown();
    }

    #[test]
    fn grows_to_min_replicas_without_load() {
        let clock = TickClock::new();
        let mut router = router_with_factory(&clock);
        let mut scaler = Autoscaler::new(AutoscalerConfig {
            min_replicas: 3,
            max_replicas: 4,
            cooldown_ticks: 2,
            ..AutoscalerConfig::default()
        });
        // One replica per step, cooldown-paced, no traffic at all.
        assert_eq!(scaler.step(&mut router).len(), 1);
        clock.advance(2);
        assert_eq!(scaler.step(&mut router).len(), 1);
        clock.advance(2);
        assert!(scaler.step(&mut router).is_empty(), "floor reached");
        assert_eq!(router.replica_count("m"), Some(3));
        router.shutdown();
    }

    #[test]
    fn model_without_factory_is_left_alone() {
        let clock = TickClock::new();
        let mut router = Router::with_config(RouterConfig {
            clock: clock.clone(),
            ..RouterConfig::default()
        });
        router.register("m", echo_server());
        let mut scaler = scaler(1, 4);
        router.eval_blocking("m", vec![1.0]).unwrap();
        assert!(scaler.step(&mut router).is_empty());
        assert_eq!(router.replica_count("m"), Some(1));
        router.shutdown();
    }

    #[test]
    #[should_panic(expected = "dead band")]
    fn overlapping_thresholds_rejected() {
        let _ = Autoscaler::new(AutoscalerConfig {
            up_queue_depth: 1,
            down_queue_depth: 1,
            ..AutoscalerConfig::default()
        });
    }

    #[test]
    #[should_panic(expected = "min_replicas")]
    fn zero_min_replicas_rejected() {
        let _ = Autoscaler::new(AutoscalerConfig {
            min_replicas: 0,
            ..AutoscalerConfig::default()
        });
    }
}
