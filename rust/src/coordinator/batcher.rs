//! Dynamic batcher: accumulates requests into fixed-capacity batches.
//!
//! The AOT artifacts have a fixed batch dimension `B`; the batcher packs
//! incoming requests' rows into a `B×width` buffer, cutting a batch when
//! (a) it is full, (b) the oldest request has waited past `max_wait`, or
//! (c) `flush()` is called. A request larger than `B` is split across
//! batches transparently.

use std::time::{Duration, Instant};

use super::EvalRequest;

/// Flush policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Artifact batch capacity `B` (rows).
    pub capacity: usize,
    /// Max time the oldest row may wait before a partial batch is cut.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            capacity: 32,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// A request fragment tracked inside the batcher.
#[derive(Debug)]
pub struct PendingRequest<T> {
    /// Caller-provided tag used to route the response (e.g. a channel).
    pub tag: T,
    /// Rows of this request (in submit order) inside the *current* batch:
    /// `(batch_row_start, rows)`.
    pub span: (usize, usize),
}

/// A cut batch: padded flat buffer + the spans of each member request.
#[derive(Debug)]
pub struct CutBatch<T> {
    pub data: Vec<f32>,
    pub rows_used: usize,
    pub members: Vec<PendingRequest<T>>,
}

impl<T> CutBatch<T> {
    /// Total rows in the padded buffer (the batch capacity it was cut at) —
    /// what the server's executed-rows metrics are measured against.
    pub fn padded_rows(&self, width: usize) -> usize {
        debug_assert_eq!(self.data.len() % width.max(1), 0);
        self.data.len() / width.max(1)
    }
}

/// Accumulator. `T` is the per-request routing tag.
pub struct Batcher<T> {
    policy: BatchPolicy,
    width: usize,
    buf: Vec<f32>,
    rows: usize,
    members: Vec<PendingRequest<T>>,
    oldest: Option<Instant>,
}

impl<T> Batcher<T> {
    pub fn new(width: usize, policy: BatchPolicy) -> Self {
        Self {
            policy,
            width,
            buf: vec![0.0; policy.capacity * width],
            rows: 0,
            members: Vec::new(),
            oldest: None,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    pub fn free_rows(&self) -> usize {
        self.policy.capacity - self.rows
    }

    /// Push a request; returns any batches that became full while packing
    /// (a request larger than the capacity spans several).
    pub fn push(&mut self, req: EvalRequest, tag_for_fragment: impl Fn(usize) -> T) -> Vec<CutBatch<T>> {
        assert_eq!(req.width, self.width, "request width mismatch");
        let mut cut = Vec::new();
        let mut row_off = 0usize;
        let mut fragment = 0usize;
        while row_off < req.rows {
            if self.rows == self.policy.capacity {
                cut.push(self.cut());
            }
            let take = (req.rows - row_off).min(self.free_rows());
            let src =
                &req.points[row_off * self.width..(row_off + take) * self.width];
            let dst_start = self.rows * self.width;
            self.buf[dst_start..dst_start + src.len()].copy_from_slice(src);
            self.members.push(PendingRequest {
                tag: tag_for_fragment(fragment),
                span: (self.rows, take),
            });
            self.rows += take;
            if self.oldest.is_none() {
                self.oldest = Some(Instant::now());
            }
            row_off += take;
            fragment += 1;
        }
        if self.rows == self.policy.capacity {
            cut.push(self.cut());
        }
        cut
    }

    /// Should a partial batch be cut due to the wait deadline?
    pub fn deadline_expired(&self) -> bool {
        match self.oldest {
            Some(t) => t.elapsed() >= self.policy.max_wait && self.rows > 0,
            None => false,
        }
    }

    /// Cut whatever is accumulated (pads with zero rows).
    pub fn cut(&mut self) -> CutBatch<T> {
        let data = std::mem::replace(
            &mut self.buf,
            vec![0.0; self.policy.capacity * self.width],
        );
        let rows_used = self.rows;
        let members = std::mem::take(&mut self.members);
        self.rows = 0;
        self.oldest = None;
        CutBatch {
            data,
            rows_used,
            members,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(rows: usize, width: usize, fill: f32) -> EvalRequest {
        EvalRequest::new(vec![fill; rows * width], width)
    }

    #[test]
    fn packs_multiple_requests_into_one_batch() {
        let mut b: Batcher<usize> = Batcher::new(2, BatchPolicy { capacity: 8, max_wait: Duration::from_secs(1) });
        assert!(b.push(req(3, 2, 1.0), |_| 0).is_empty());
        assert!(b.push(req(4, 2, 2.0), |_| 1).is_empty());
        let cut = b.cut();
        assert_eq!(cut.rows_used, 7);
        assert_eq!(cut.members.len(), 2);
        assert_eq!(cut.members[0].span, (0, 3));
        assert_eq!(cut.members[1].span, (3, 4));
        // Padding rows are zero.
        assert_eq!(&cut.data[14..], &[0.0, 0.0]);
    }

    #[test]
    fn full_batch_auto_cuts() {
        let mut b: Batcher<usize> = Batcher::new(1, BatchPolicy { capacity: 4, max_wait: Duration::from_secs(1) });
        let cuts = b.push(req(4, 1, 3.0), |_| 7);
        assert_eq!(cuts.len(), 1);
        assert_eq!(cuts[0].rows_used, 4);
        assert!(b.is_empty());
    }

    #[test]
    fn oversize_request_spans_batches() {
        let mut b: Batcher<usize> = Batcher::new(1, BatchPolicy { capacity: 4, max_wait: Duration::from_secs(1) });
        let cuts = b.push(req(10, 1, 1.0), |frag| frag);
        // 10 rows over capacity 4: two full cuts, 2 rows remain.
        assert_eq!(cuts.len(), 2);
        assert_eq!(b.free_rows(), 2);
        // Fragments tagged in order.
        assert_eq!(cuts[0].members[0].tag, 0);
        assert_eq!(cuts[1].members[0].tag, 1);
        let tail = b.cut();
        assert_eq!(tail.rows_used, 2);
        assert_eq!(tail.members[0].tag, 2);
    }

    #[test]
    fn cut_batch_padded_rows() {
        let mut b: Batcher<usize> =
            Batcher::new(2, BatchPolicy { capacity: 8, max_wait: Duration::from_secs(1) });
        b.push(req(5, 2, 1.0), |_| 0);
        let cut = b.cut();
        assert_eq!(cut.padded_rows(2), 8);
        assert_eq!(cut.rows_used, 5);
    }

    #[test]
    fn deadline() {
        let mut b: Batcher<usize> = Batcher::new(1, BatchPolicy { capacity: 4, max_wait: Duration::from_millis(1) });
        assert!(!b.deadline_expired());
        b.push(req(1, 1, 1.0), |_| 0);
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.deadline_expired());
    }
}
