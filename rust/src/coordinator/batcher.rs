//! Dynamic batcher: accumulates requests into fixed-capacity batches.
//!
//! The AOT artifacts have a fixed batch dimension `B`; the batcher packs
//! incoming requests' rows into a `B×width` buffer, cutting a batch when
//! (a) it is full, (b) the oldest request has waited past the wait policy,
//! or (c) `cut()` is called explicitly (shutdown flush). A request larger
//! than `B` is split across batches transparently.
//!
//! ## Wait policy: logical ticks, with a legacy wall-clock mode
//!
//! Batch *composition* (`rows_used`, member spans, and therefore every
//! queue-wait sample) is a control-plane decision. Under
//! [`BatchPolicy::max_wait_ticks`] the cut deadline is measured on the
//! shared [`TickClock`](super::TickClock): the worker threads the current
//! tick into [`Batcher::push`] and [`Batcher::deadline_expired`], so batch
//! composition replays exactly under a scripted clock. When
//! `max_wait_ticks` is `None` (the legacy default) the batcher makes no
//! wait decision at all — the worker owns the wall-clock age of the oldest
//! pending row on its side of the channel and simply calls `cut()` when
//! `max_wait` elapses. Either way this file never reads wall time (CI
//! pins that).
//!
//! ## Buffer recycling
//!
//! `cut()` hands out the accumulation buffer and swaps in a spare instead
//! of allocating a fresh zeroed `B×width` buffer per cut; the worker hands
//! the buffer back via [`Batcher::recycle`], which zeroes **only the rows
//! the cut actually used** (padding rows were never written, so they are
//! still zero). The cut contents are bitwise identical to the old
//! allocate-per-cut path — the module tests pin this.

use std::time::Duration;

use super::EvalRequest;

/// Flush policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Artifact batch capacity `B` (rows).
    pub capacity: usize,
    /// Legacy wall-clock wait: max time the oldest row may wait before a
    /// partial batch is cut. Consulted only when [`Self::max_wait_ticks`]
    /// is `None`, and then only *outside* the batcher (the worker tracks
    /// the age on its side of the channel — this type never reads wall
    /// time). It doubles as the worker's channel poll interval in both
    /// modes.
    pub max_wait: Duration,
    /// Tick-based wait: cut a partial batch once the oldest accumulated
    /// row has waited `>= max_wait_ticks` logical ticks on the shared
    /// clock (the deadline fires exactly *at* the boundary). `Some(0)`
    /// cuts on the first wait check after any row lands. `None` (the
    /// legacy default) selects the wall-clock path above.
    pub max_wait_ticks: Option<u64>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            capacity: 32,
            max_wait: Duration::from_millis(2),
            max_wait_ticks: None,
        }
    }
}

impl BatchPolicy {
    /// Tick-driven policy: cut a partial batch once the oldest row has
    /// waited `max_wait_ticks` logical ticks.
    pub fn ticks(capacity: usize, max_wait_ticks: u64) -> Self {
        Self {
            capacity,
            max_wait_ticks: Some(max_wait_ticks),
            ..Self::default()
        }
    }
}

/// A request fragment tracked inside the batcher.
#[derive(Debug)]
pub struct PendingRequest<T> {
    /// Caller-provided tag used to route the response (e.g. a channel).
    pub tag: T,
    /// Rows of this request (in submit order) inside the *current* batch:
    /// `(batch_row_start, rows)`.
    pub span: (usize, usize),
}

/// A cut batch: padded flat buffer + the spans of each member request.
#[derive(Debug)]
pub struct CutBatch<T> {
    pub data: Vec<f32>,
    pub rows_used: usize,
    pub members: Vec<PendingRequest<T>>,
    /// The sample-count group every member of this cut shares (see
    /// [`EvalRequest::samples`]): the batcher cuts the pending batch
    /// before admitting a request with a different `samples` value, so a
    /// stochastic backend can apply one override to the whole cut.
    pub samples: Option<u32>,
}

impl<T> CutBatch<T> {
    /// Total rows in the padded buffer (the batch capacity it was cut at) —
    /// what the server's executed-rows metrics are measured against.
    /// `width` must be positive ([`Batcher::new`] rejects zero widths, so
    /// a cut produced by a batcher always has one).
    pub fn padded_rows(&self, width: usize) -> usize {
        debug_assert!(width > 0, "padded_rows requires a positive width");
        debug_assert_eq!(self.data.len() % width, 0);
        self.data.len() / width
    }
}

/// Accumulator. `T` is the per-request routing tag.
pub struct Batcher<T> {
    policy: BatchPolicy,
    width: usize,
    buf: Vec<f32>,
    /// Recycled all-zero buffer for the next cut (two-buffer swap).
    spare: Option<Vec<f32>>,
    rows: usize,
    members: Vec<PendingRequest<T>>,
    /// Logical tick at which the oldest accumulated row arrived.
    oldest_tick: Option<u64>,
    /// Sample-count group of the pending rows (meaningful only while
    /// `rows > 0`; a request with a different group forces a cut first).
    group: Option<u32>,
}

impl<T> Batcher<T> {
    /// Build a batcher. Panics on `width == 0` or `capacity == 0`: a
    /// zero-width batcher cannot hold rows, and masking it downstream
    /// (the old `width.max(1)` in `padded_rows`) would silently misreport
    /// padding metrics instead.
    pub fn new(width: usize, policy: BatchPolicy) -> Self {
        assert!(width > 0, "batcher width must be positive");
        assert!(policy.capacity > 0, "batch capacity must be positive");
        Self {
            policy,
            width,
            buf: vec![0.0; policy.capacity * width],
            spare: None,
            rows: 0,
            members: Vec::new(),
            oldest_tick: None,
            group: None,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    pub fn free_rows(&self) -> usize {
        self.policy.capacity - self.rows
    }

    /// Push a request at logical tick `now`; returns any batches that
    /// became full while packing (a request larger than the capacity spans
    /// several). `now` only seeds the tick-deadline bookkeeping — under
    /// the legacy wall-clock policy callers may pass any value.
    pub fn push(
        &mut self,
        req: EvalRequest,
        now: u64,
        tag_for_fragment: impl Fn(usize) -> T,
    ) -> Vec<CutBatch<T>> {
        assert_eq!(req.width, self.width, "request width mismatch");
        let mut cut = Vec::new();
        // Sample-count groups never mix: a pending partial batch with a
        // different group is cut before this request's rows land.
        if self.rows > 0 && self.group != req.samples {
            cut.push(self.cut());
        }
        self.group = req.samples;
        let mut row_off = 0usize;
        let mut fragment = 0usize;
        while row_off < req.rows {
            if self.rows == self.policy.capacity {
                cut.push(self.cut());
                // The remaining rows of this request stay in its group.
                self.group = req.samples;
            }
            let take = (req.rows - row_off).min(self.free_rows());
            let src =
                &req.points[row_off * self.width..(row_off + take) * self.width];
            let dst_start = self.rows * self.width;
            self.buf[dst_start..dst_start + src.len()].copy_from_slice(src);
            self.members.push(PendingRequest {
                tag: tag_for_fragment(fragment),
                span: (self.rows, take),
            });
            self.rows += take;
            if self.oldest_tick.is_none() {
                self.oldest_tick = Some(now);
            }
            row_off += take;
            fragment += 1;
        }
        if self.rows == self.policy.capacity {
            cut.push(self.cut());
        }
        cut
    }

    /// Should a partial batch be cut due to the tick-wait deadline at
    /// logical tick `now`? Always `false` under the legacy wall-clock
    /// policy (`max_wait_ticks == None`) — there the worker owns the wait.
    pub fn deadline_expired(&self, now: u64) -> bool {
        match (self.policy.max_wait_ticks, self.oldest_tick) {
            (Some(wait), Some(t0)) => {
                self.rows > 0 && now.saturating_sub(t0) >= wait
            }
            _ => false,
        }
    }

    /// Cut whatever is accumulated (pads with zero rows). Swaps in the
    /// recycled spare buffer when one is available; otherwise allocates.
    pub fn cut(&mut self) -> CutBatch<T> {
        let cap = self.policy.capacity * self.width;
        let fresh = match self.spare.take() {
            Some(b) => {
                debug_assert_eq!(b.len(), cap);
                debug_assert!(b.iter().all(|&v| v == 0.0), "recycled buffer not clean");
                b
            }
            None => vec![0.0; cap],
        };
        let data = std::mem::replace(&mut self.buf, fresh);
        let rows_used = self.rows;
        let members = std::mem::take(&mut self.members);
        let samples = self.group.take();
        self.rows = 0;
        self.oldest_tick = None;
        CutBatch {
            data,
            rows_used,
            members,
            samples,
        }
    }

    /// Hand a consumed cut's buffer back for reuse by the next `cut()`.
    /// Zeroes only the `rows_used` rows the cut wrote — the padding rows
    /// beyond were never touched, so the buffer is all-zero again.
    /// Buffers of the wrong size (e.g. from a batcher with a different
    /// policy) are dropped instead of poisoning the swap.
    pub fn recycle(&mut self, mut data: Vec<f32>, rows_used: usize) {
        let cap = self.policy.capacity * self.width;
        if data.len() != cap {
            return;
        }
        let used = (rows_used * self.width).min(cap);
        data[..used].fill(0.0);
        self.spare = Some(data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(rows: usize, width: usize, fill: f32) -> EvalRequest {
        EvalRequest::new(vec![fill; rows * width], width)
    }

    fn tick_policy(capacity: usize) -> BatchPolicy {
        BatchPolicy::ticks(capacity, 1_000)
    }

    #[test]
    fn packs_multiple_requests_into_one_batch() {
        let mut b: Batcher<usize> = Batcher::new(2, tick_policy(8));
        assert!(b.push(req(3, 2, 1.0), 0, |_| 0).is_empty());
        assert!(b.push(req(4, 2, 2.0), 0, |_| 1).is_empty());
        let cut = b.cut();
        assert_eq!(cut.rows_used, 7);
        assert_eq!(cut.members.len(), 2);
        assert_eq!(cut.members[0].span, (0, 3));
        assert_eq!(cut.members[1].span, (3, 4));
        // Padding rows are zero.
        assert_eq!(&cut.data[14..], &[0.0, 0.0]);
    }

    #[test]
    fn full_batch_auto_cuts() {
        let mut b: Batcher<usize> = Batcher::new(1, tick_policy(4));
        let cuts = b.push(req(4, 1, 3.0), 0, |_| 7);
        assert_eq!(cuts.len(), 1);
        assert_eq!(cuts[0].rows_used, 4);
        assert!(b.is_empty());
    }

    #[test]
    fn oversize_request_spans_batches() {
        let mut b: Batcher<usize> = Batcher::new(1, tick_policy(4));
        let cuts = b.push(req(10, 1, 1.0), 0, |frag| frag);
        // 10 rows over capacity 4: two full cuts, 2 rows remain.
        assert_eq!(cuts.len(), 2);
        assert_eq!(b.free_rows(), 2);
        // Fragments tagged in order.
        assert_eq!(cuts[0].members[0].tag, 0);
        assert_eq!(cuts[1].members[0].tag, 1);
        let tail = b.cut();
        assert_eq!(tail.rows_used, 2);
        assert_eq!(tail.members[0].tag, 2);
    }

    #[test]
    fn oversize_fragment_tags_survive_recycling_across_cuts() {
        // Same fragment-tag sequence when the cut buffers are recycled:
        // the swap must not disturb member bookkeeping.
        let mut b: Batcher<usize> = Batcher::new(1, tick_policy(3));
        let cuts = b.push(req(7, 1, 2.0), 5, |frag| frag);
        assert_eq!(cuts.len(), 2);
        for cut in cuts {
            assert_eq!(cut.members.len(), 1);
            let used = cut.rows_used;
            b.recycle(cut.data, used);
        }
        // Remaining single row is fragment 2 and the deadline tracks the
        // push tick, not the recycle.
        assert!(!b.deadline_expired(5));
        let tail = b.cut();
        assert_eq!(tail.members[0].tag, 2);
        assert_eq!(tail.rows_used, 1);
    }

    #[test]
    fn cut_batch_padded_rows() {
        let mut b: Batcher<usize> = Batcher::new(2, tick_policy(8));
        b.push(req(5, 2, 1.0), 0, |_| 0);
        let cut = b.cut();
        assert_eq!(cut.padded_rows(2), 8);
        assert_eq!(cut.rows_used, 5);
    }

    #[test]
    fn tick_deadline_fires_exactly_at_boundary() {
        let mut b: Batcher<usize> = Batcher::new(1, BatchPolicy::ticks(4, 3));
        // Empty batcher never expires.
        assert!(!b.deadline_expired(u64::MAX));
        b.push(req(1, 1, 1.0), 10, |_| 0);
        assert!(!b.deadline_expired(10)); // age 0
        assert!(!b.deadline_expired(12)); // age 2 < 3
        assert!(b.deadline_expired(13)); // age 3: exactly at the boundary
        assert!(b.deadline_expired(20));
        let _ = b.cut();
        // Cleared by the cut.
        assert!(!b.deadline_expired(u64::MAX));
    }

    #[test]
    fn zero_tick_wait_expires_immediately() {
        let mut b: Batcher<usize> = Batcher::new(1, BatchPolicy::ticks(4, 0));
        assert!(!b.deadline_expired(0));
        b.push(req(1, 1, 1.0), 7, |_| 0);
        assert!(b.deadline_expired(7));
    }

    #[test]
    fn legacy_wall_policy_never_expires_inside_the_batcher() {
        // Under the legacy Duration policy the worker owns the wait; the
        // batcher itself must never report expiry regardless of ticks.
        let mut b: Batcher<usize> = Batcher::new(1, BatchPolicy::default());
        b.push(req(1, 1, 1.0), 0, |_| 0);
        assert!(!b.deadline_expired(u64::MAX));
    }

    #[test]
    fn recycled_buffer_cuts_are_bitwise_identical_to_fresh_allocations() {
        // `a` recycles its cut buffers; `b` allocates fresh per cut (the
        // old path). Every cut must match bitwise, including padding after
        // a smaller second batch.
        let p = tick_policy(4);
        let mut a: Batcher<usize> = Batcher::new(2, p);
        let mut b: Batcher<usize> = Batcher::new(2, p);
        let r1 = EvalRequest::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2);
        assert!(a.push(r1.clone(), 0, |_| 0).is_empty());
        assert!(b.push(r1, 0, |_| 0).is_empty());
        let ca = a.cut();
        let cb = b.cut();
        assert_eq!(ca.rows_used, 3);
        assert_eq!(ca.data, cb.data);
        a.recycle(ca.data, ca.rows_used);
        // Second round uses fewer rows: recycled padding must still be zero.
        let r2 = EvalRequest::new(vec![9.0, 8.0], 2);
        assert!(a.push(r2.clone(), 1, |_| 0).is_empty());
        assert!(b.push(r2, 1, |_| 0).is_empty());
        let ca = a.cut();
        let cb = b.cut();
        assert_eq!(ca.rows_used, 1);
        assert_eq!(ca.data, cb.data);
        assert!(ca.data[2..].iter().all(|&v| v == 0.0));
        a.recycle(ca.data, ca.rows_used);
        // Third round fills the batch exactly, exercising the swap's
        // steady state through push's auto-cut.
        let r3 = EvalRequest::new(vec![7.0; 8], 2);
        let cuts_a = a.push(r3.clone(), 2, |f| f);
        let cuts_b = b.push(r3, 2, |f| f);
        assert_eq!(cuts_a.len(), 1);
        assert_eq!(cuts_b.len(), 1);
        assert_eq!(cuts_a[0].data, cuts_b[0].data);
    }

    #[test]
    fn recycle_rejects_foreign_buffer_sizes() {
        let mut b: Batcher<usize> = Batcher::new(2, tick_policy(4));
        b.recycle(vec![1.0; 3], 1); // wrong size: dropped
        b.push(req(1, 2, 5.0), 0, |_| 0);
        let cut = b.cut();
        // The cut came from a correctly sized (freshly allocated) buffer.
        assert_eq!(cut.data.len(), 8);
        assert_eq!(&cut.data[..2], &[5.0, 5.0]);
        assert!(cut.data[2..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn samples_group_mismatch_forces_a_cut() {
        let mut b: Batcher<usize> = Batcher::new(1, tick_policy(8));
        assert!(b.push(req(2, 1, 1.0), 0, |_| 0).is_empty());
        // Same group (None) packs into the same batch.
        assert!(b.push(req(1, 1, 2.0), 0, |_| 1).is_empty());
        // Different group: the pending None-batch is cut first.
        let cuts = b.push(req(3, 1, 3.0).with_samples(Some(64)), 0, |_| 2);
        assert_eq!(cuts.len(), 1);
        assert_eq!(cuts[0].rows_used, 3);
        assert_eq!(cuts[0].samples, None);
        assert_eq!(cuts[0].members.len(), 2);
        // The new group's rows are pending under its own tag.
        let tail = b.cut();
        assert_eq!(tail.rows_used, 3);
        assert_eq!(tail.samples, Some(64));
        // Matching groups keep packing; a fresh batcher carries the group.
        assert!(b
            .push(req(1, 1, 4.0).with_samples(Some(64)), 0, |_| 3)
            .is_empty());
        assert!(b
            .push(req(1, 1, 5.0).with_samples(Some(64)), 0, |_| 4)
            .is_empty());
        let same = b.cut();
        assert_eq!(same.rows_used, 2);
        assert_eq!(same.samples, Some(64));
    }

    #[test]
    fn oversize_request_keeps_its_samples_group_across_auto_cuts() {
        let mut b: Batcher<usize> = Batcher::new(1, tick_policy(4));
        let cuts = b.push(req(10, 1, 1.0).with_samples(Some(16)), 0, |frag| frag);
        assert_eq!(cuts.len(), 2);
        for c in &cuts {
            assert_eq!(c.samples, Some(16), "every auto-cut stays in the group");
        }
        let tail = b.cut();
        assert_eq!(tail.rows_used, 2);
        assert_eq!(tail.samples, Some(16));
        // Group cleared by the cut: the next batch starts fresh.
        b.push(req(1, 1, 2.0), 0, |_| 0);
        assert_eq!(b.cut().samples, None);
    }

    #[test]
    #[should_panic(expected = "width must be positive")]
    fn zero_width_rejected_at_construction() {
        let _b: Batcher<usize> = Batcher::new(0, tick_policy(4));
    }

    #[test]
    #[should_panic(expected = "capacity must be positive")]
    fn zero_capacity_rejected_at_construction() {
        let _b: Batcher<usize> = Batcher::new(1, tick_policy(0));
    }
}
