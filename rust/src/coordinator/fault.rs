//! Fault tier of the serving stack: the crate-wide error taxonomy
//! ([`ServeError`]), the logical tick clock every control-plane decision
//! is keyed on ([`TickClock`]), the retry/failover budget
//! ([`RetryPolicy`]), and the seeded deterministic fault injector
//! ([`FaultInjector`]) behind the `rust/tests/fault_injection.rs` battery.
//!
//! ## Why a logical clock
//!
//! Deadlines, retry backoff, and quarantine probe windows are *control
//! plane* — they decide which requests run, not what any request computes.
//! Driving them from wall clock would make test outcomes depend on
//! scheduler jitter; driving them from [`TickClock`] (a shared atomic
//! counter advanced explicitly by the harness, or by latency injection)
//! keeps every admission/expiry/probe decision a pure function of the
//! request schedule and the injector seed. The *data plane* is untouched:
//! batching `max_wait` and latency histograms stay wall clock because they
//! only shape batch composition and telemetry, which the determinism
//! contract already proves cannot change any per-row result.
//!
//! ## Error semantics (see also the crate docs in `lib.rs`)
//!
//! | variant             | meaning                                  | retryable |
//! |---------------------|------------------------------------------|-----------|
//! | `InvalidRequest`    | caller bug: shape/width/non-finite input | no        |
//! | `Overloaded`        | admission control shed the request       | yes       |
//! | `DeadlineExceeded`  | logical deadline passed                  | no        |
//! | `EngineFault`       | engine panicked / non-finite output      | yes       |
//!
//! `Overloaded` and `EngineFault` are worth failing over: another replica
//! may have queue room or healthy state. `InvalidRequest` would fail
//! identically everywhere (all engines share one validation gate), and a
//! `DeadlineExceeded` request has no budget left by definition.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::SplitMix64;

/// Structured serving error — what a client gets instead of a panic or a
/// stringly-typed failure at every `ServerHandle` / `Router` boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request itself is malformed: ragged width, empty, or carrying
    /// non-finite points. Never dispatched, never retried.
    InvalidRequest { reason: String },
    /// Admission control rejected the request (bounded queue at cap, or no
    /// replica currently admitting traffic).
    Overloaded { model: String, reason: String },
    /// The request's logical-tick deadline passed before (or while) it was
    /// served.
    DeadlineExceeded {
        model: String,
        deadline_tick: u64,
        now_tick: u64,
    },
    /// The engine failed: a caught panic (payload preserved, with pool
    /// shard context when the panic happened inside a parallel region) or
    /// a non-finite output withheld at the boundary.
    EngineFault {
        model: String,
        /// Failing shard index, when the payload carries pool region
        /// context (`pool region … shard i …`).
        shard: Option<usize>,
        payload: String,
    },
}

impl ServeError {
    /// Is a failover attempt to another replica worth making?
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            ServeError::Overloaded { .. } | ServeError::EngineFault { .. }
        )
    }

    /// Build an [`ServeError::EngineFault`] from a caught panic payload
    /// message, recovering the shard index from pool region context when
    /// present.
    pub fn engine_fault(model: &str, payload: String) -> Self {
        ServeError::EngineFault {
            model: model.to_string(),
            shard: shard_in_payload(&payload),
            payload,
        }
    }
}

/// Parse the shard index out of a pool region panic message
/// (`pool region "label" shard 3 (rows 12..16) panicked: …`).
fn shard_in_payload(payload: &str) -> Option<usize> {
    let rest = payload.split(" shard ").nth(1)?;
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::InvalidRequest { reason } => write!(f, "invalid request: {reason}"),
            ServeError::Overloaded { model, reason } => {
                write!(f, "model {model:?} overloaded: {reason}")
            }
            ServeError::DeadlineExceeded {
                model,
                deadline_tick,
                now_tick,
            } => write!(
                f,
                "model {model:?} deadline exceeded: deadline tick {deadline_tick}, now tick {now_tick}"
            ),
            ServeError::EngineFault {
                model,
                shard,
                payload,
            } => match shard {
                Some(i) => write!(f, "model {model:?} engine fault (shard {i}): {payload}"),
                None => write!(f, "model {model:?} engine fault: {payload}"),
            },
        }
    }
}

impl std::error::Error for ServeError {}

/// Logical time: a shared atomic tick counter.
///
/// Nothing in the serving stack ever reads wall clock for a control-plane
/// decision; ticks advance only when something *advances* them — the CLI
/// per completed request, the fault injector's latency actions, or a test
/// harness directly. Share one clock between a [`super::Router`] and the
/// servers it routes to when using deadlines, so both sides agree on
/// "now".
#[derive(Clone, Debug, Default)]
pub struct TickClock {
    ticks: Arc<AtomicU64>,
}

impl TickClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current tick.
    pub fn now(&self) -> u64 {
        self.ticks.load(Ordering::Acquire)
    }

    /// Advance logical time by `n` ticks; returns the new now.
    pub fn advance(&self, n: u64) -> u64 {
        self.ticks.fetch_add(n, Ordering::AcqRel) + n
    }
}

/// Capped attempt budget for routed requests: the first attempt plus up to
/// `retries` failovers to other replicas of the same model (retryable
/// errors only — see [`ServeError::retryable`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Extra attempts after the first (0 = fail fast).
    pub retries: u32,
}

impl RetryPolicy {
    /// Total attempts a request may consume.
    pub fn max_attempts(&self) -> u64 {
        self.retries as u64 + 1
    }
}

/// What the injector does to one batch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Panic inside the batch compute (exercises the `catch_unwind`
    /// containment and the `EngineFault` path).
    pub panic: bool,
    /// Poison the batch output with NaN after compute (exercises the
    /// non-finite output gate — the NaN must never reach a client).
    pub nan_output: bool,
    /// Logical ticks this batch consumes (drives deadline expiry).
    pub latency_ticks: u64,
    /// Admission slots held for the duration of the batch (artificial
    /// queue pressure: concurrent submissions see a deeper queue).
    pub occupy_slots: usize,
}

impl FaultPlan {
    pub fn is_noop(&self) -> bool {
        *self == FaultPlan::default()
    }
}

/// Deterministic fault schedule configuration. All rates are percents in
/// `0..=100` drawn per batch from the injector seed.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultConfig {
    /// Percent of batches that panic mid-compute.
    pub panic_percent: u8,
    /// Batches with index below this always panic (a deterministic failing
    /// prefix — used to script quarantine-then-recovery schedules).
    pub panic_first: u64,
    /// Percent of batches whose outputs are NaN-poisoned.
    pub nan_percent: u8,
    /// Percent of batches that consume [`FaultConfig::latency_ticks`].
    pub latency_percent: u8,
    pub latency_ticks: u64,
    /// Percent of batches that hold [`FaultConfig::occupy_slots`]
    /// admission slots while computing.
    pub occupy_percent: u8,
    pub occupy_slots: usize,
}

/// Seeded fault injector, wired behind a test-only hook on
/// [`super::ModelServer`] (see `ServeConfig::injector`). The plan for the
/// k-th batch a server cuts is a **pure function** of `(seed, config, k)`
/// — tests replay the exact schedule with [`FaultInjector::plan_for`] and
/// assert exact failure counters, never approximate ones.
#[derive(Debug)]
pub struct FaultInjector {
    seed: u64,
    cfg: FaultConfig,
    batches: AtomicU64,
    injected_panics: AtomicU64,
    injected_nans: AtomicU64,
    injected_latency_ticks: AtomicU64,
}

/// Point-in-time injector counters (what was actually injected).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultInjectorSnapshot {
    pub batches: u64,
    pub injected_panics: u64,
    pub injected_nans: u64,
    pub injected_latency_ticks: u64,
}

impl FaultInjector {
    pub fn new(seed: u64, cfg: FaultConfig) -> Arc<Self> {
        Arc::new(Self {
            seed,
            cfg,
            batches: AtomicU64::new(0),
            injected_panics: AtomicU64::new(0),
            injected_nans: AtomicU64::new(0),
            injected_latency_ticks: AtomicU64::new(0),
        })
    }

    /// The plan for batch `k` — pure, so a test can precompute the whole
    /// schedule and derive the expected outcome of every request.
    pub fn plan_for(seed: u64, cfg: &FaultConfig, k: u64) -> FaultPlan {
        let mut rng = SplitMix64::new(seed ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        // Fixed draw order — adding a fault family must append draws, never
        // reorder them, or seeds stop reproducing old schedules.
        let mut pct = || (rng.next_u64() % 100) as u8;
        let panic = k < cfg.panic_first || pct() < cfg.panic_percent;
        let nan_output = pct() < cfg.nan_percent;
        let latency = pct() < cfg.latency_percent;
        let occupy = pct() < cfg.occupy_percent;
        FaultPlan {
            panic,
            nan_output,
            latency_ticks: if latency { cfg.latency_ticks } else { 0 },
            occupy_slots: if occupy { cfg.occupy_slots } else { 0 },
        }
    }

    /// Consume the next batch slot and return its plan (called by the
    /// server worker once per cut batch, in cut order).
    pub fn next(&self) -> FaultPlan {
        let k = self.batches.fetch_add(1, Ordering::AcqRel);
        let plan = Self::plan_for(self.seed, &self.cfg, k);
        if plan.panic {
            self.injected_panics.fetch_add(1, Ordering::Relaxed);
        }
        if plan.nan_output {
            self.injected_nans.fetch_add(1, Ordering::Relaxed);
        }
        self.injected_latency_ticks
            .fetch_add(plan.latency_ticks, Ordering::Relaxed);
        plan
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn config(&self) -> FaultConfig {
        self.cfg
    }

    pub fn snapshot(&self) -> FaultInjectorSnapshot {
        FaultInjectorSnapshot {
            batches: self.batches.load(Ordering::Acquire),
            injected_panics: self.injected_panics.load(Ordering::Relaxed),
            injected_nans: self.injected_nans.load(Ordering::Relaxed),
            injected_latency_ticks: self.injected_latency_ticks.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn taxonomy_display_and_retryability() {
        let inv = ServeError::InvalidRequest {
            reason: "ragged".into(),
        };
        assert!(!inv.retryable());
        assert!(inv.to_string().contains("invalid request: ragged"));

        let over = ServeError::Overloaded {
            model: "m".into(),
            reason: "queue depth 4 at cap 4".into(),
        };
        assert!(over.retryable());
        assert!(over.to_string().contains("overloaded"));

        let dl = ServeError::DeadlineExceeded {
            model: "m".into(),
            deadline_tick: 10,
            now_tick: 12,
        };
        assert!(!dl.retryable());
        assert!(dl.to_string().contains("deadline tick 10"));

        let ef = ServeError::engine_fault(
            "m",
            "pool region \"serve-batch\" shard 3 (rows 12..16) panicked: boom".into(),
        );
        assert!(ef.retryable());
        match &ef {
            ServeError::EngineFault { shard, .. } => assert_eq!(*shard, Some(3)),
            _ => panic!("wrong variant"),
        }
        assert!(ef.to_string().contains("(shard 3)"));
        // Payload without pool context → no shard.
        match ServeError::engine_fault("m", "plain panic".into()) {
            ServeError::EngineFault { shard, .. } => assert_eq!(shard, None),
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn tick_clock_is_shared_and_monotonic() {
        let c = TickClock::new();
        let c2 = c.clone();
        assert_eq!(c.now(), 0);
        assert_eq!(c.advance(3), 3);
        assert_eq!(c2.now(), 3, "clones share the counter");
        c2.advance(2);
        assert_eq!(c.now(), 5);
    }

    #[test]
    fn injector_schedule_is_pure_and_counted() {
        let cfg = FaultConfig {
            panic_percent: 50,
            panic_first: 2,
            nan_percent: 20,
            latency_percent: 30,
            latency_ticks: 4,
            ..FaultConfig::default()
        };
        // Replay: next() consumes exactly the plan_for schedule.
        let inj = FaultInjector::new(0xFA017, cfg);
        let live: Vec<FaultPlan> = (0..64).map(|_| inj.next()).collect();
        let replay: Vec<FaultPlan> = (0..64)
            .map(|k| FaultInjector::plan_for(0xFA017, &cfg, k))
            .collect();
        assert_eq!(live, replay);
        // The failing prefix is deterministic.
        assert!(replay[0].panic && replay[1].panic);
        // Counters match the schedule exactly.
        let snap = inj.snapshot();
        assert_eq!(snap.batches, 64);
        assert_eq!(
            snap.injected_panics,
            replay.iter().filter(|p| p.panic).count() as u64
        );
        assert_eq!(
            snap.injected_latency_ticks,
            replay.iter().map(|p| p.latency_ticks).sum::<u64>()
        );
        // Rates are roughly honored (sanity, not exactness — exactness is
        // the replay assertion above).
        assert!(snap.injected_panics > 10);
        let nans = replay.iter().filter(|p| p.nan_output).count();
        assert!(nans > 2 && nans < 32, "nan draws way off: {nans}");
    }

    #[test]
    fn zero_config_injects_nothing() {
        let inj = FaultInjector::new(9, FaultConfig::default());
        for _ in 0..16 {
            assert!(inj.next().is_noop());
        }
    }
}
