//! Replica health states and probe-based re-admission.
//!
//! Every replica of a routed model carries a [`HealthTracker`]:
//! consecutive serving failures walk it `Healthy → Degraded → Quarantined`
//! and a quarantined replica stops receiving traffic until a logical-tick
//! probe window elapses ([`HealthPolicy::probe_after_ticks`], doubling
//! after each failed probe — tick-driven exponential backoff). Once the
//! window is open, the router routes a single live request to the replica
//! as a **probe**; [`HealthPolicy::probe_successes`] consecutive probe
//! successes restore `Healthy` and normal dispatch.
//!
//! What counts against health: [`super::ServeError::EngineFault`] only.
//! `Overloaded` is a *healthy* replica shedding by design, `InvalidRequest`
//! is the caller's fault, and `DeadlineExceeded` measures queue time, not
//! engine state. The tracker is driven entirely by the
//! [`super::TickClock`] — no wall-clock reads — so quarantine/re-admission
//! schedules are reproducible from the request schedule alone.

use std::fmt;

/// Replica health, in escalation order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum HealthState {
    Healthy,
    /// Still serving, but consecutive failures ≥ `degrade_after` — an
    /// autoscaler / operator signal, not yet a routing change.
    Degraded,
    /// Not serving; only tick-gated probes may reach it.
    Quarantined,
}

impl fmt::Display for HealthState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            HealthState::Healthy => "healthy",
            HealthState::Degraded => "degraded",
            HealthState::Quarantined => "quarantined",
        })
    }
}

/// What the router may send to a replica right now.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gate {
    /// Normal dispatch.
    Open,
    /// Quarantined, probe window elapsed: exactly one request may go
    /// through as a probe (router must call [`HealthTracker::begin_probe`]).
    ProbeDue,
    /// Quarantined, window not yet open (or a probe is already in flight).
    Closed,
}

/// Thresholds, all in consecutive-failure counts and logical ticks.
#[derive(Clone, Copy, Debug)]
pub struct HealthPolicy {
    /// Consecutive failures before `Healthy → Degraded`.
    pub degrade_after: u32,
    /// Consecutive failures before `→ Quarantined`.
    pub quarantine_after: u32,
    /// Ticks a quarantined replica waits before its first probe; doubles
    /// after each failed probe (capped at `<< 6`).
    pub probe_after_ticks: u64,
    /// Consecutive probe successes required to restore `Healthy`.
    pub probe_successes: u32,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        Self {
            degrade_after: 2,
            quarantine_after: 4,
            probe_after_ticks: 8,
            probe_successes: 2,
        }
    }
}

/// Per-replica health state machine (wrap in a mutex for sharing; all
/// transitions take `now` as an explicit tick so nothing here can read a
/// clock).
#[derive(Debug)]
pub struct HealthTracker {
    policy: HealthPolicy,
    state: HealthState,
    consecutive_failures: u32,
    probe_streak: u32,
    failed_probes: u32,
    probe_inflight: bool,
    quarantined_at_tick: u64,
    quarantine_events: u64,
}

impl HealthTracker {
    pub fn new(policy: HealthPolicy) -> Self {
        Self {
            policy,
            state: HealthState::Healthy,
            consecutive_failures: 0,
            probe_streak: 0,
            failed_probes: 0,
            probe_inflight: false,
            quarantined_at_tick: 0,
            quarantine_events: 0,
        }
    }

    pub fn state(&self) -> HealthState {
        self.state
    }

    pub fn consecutive_failures(&self) -> u32 {
        self.consecutive_failures
    }

    /// Times this replica entered quarantine.
    pub fn quarantine_events(&self) -> u64 {
        self.quarantine_events
    }

    /// Current probe wait: base window doubled per failed probe.
    fn probe_wait_ticks(&self) -> u64 {
        self.policy
            .probe_after_ticks
            .saturating_mul(1u64 << self.failed_probes.min(6))
    }

    /// May the router dispatch to this replica at tick `now`?
    pub fn gate(&self, now: u64) -> Gate {
        match self.state {
            HealthState::Healthy | HealthState::Degraded => Gate::Open,
            HealthState::Quarantined => {
                if self.probe_inflight {
                    Gate::Closed
                } else if now >= self.quarantined_at_tick.saturating_add(self.probe_wait_ticks()) {
                    Gate::ProbeDue
                } else {
                    Gate::Closed
                }
            }
        }
    }

    /// Mark the single admitted probe as in flight (call right after
    /// [`Self::gate`] returned [`Gate::ProbeDue`], under the same lock).
    pub fn begin_probe(&mut self) {
        self.probe_inflight = true;
    }

    /// A probe whose outcome is neither success nor an engine fault (e.g.
    /// the replica shed it): clear the in-flight flag so the next probe
    /// window can open, without judging health either way.
    pub fn abort_probe(&mut self) {
        self.probe_inflight = false;
    }

    /// Record a served success.
    pub fn on_success(&mut self) {
        if self.state == HealthState::Quarantined {
            if self.probe_inflight {
                self.probe_inflight = false;
                self.probe_streak += 1;
                if self.probe_streak >= self.policy.probe_successes.max(1) {
                    self.state = HealthState::Healthy;
                    self.consecutive_failures = 0;
                    self.probe_streak = 0;
                    self.failed_probes = 0;
                }
            }
            // A late success from a request dispatched before quarantine
            // is not a probe; re-admission stays probe-gated.
            return;
        }
        self.consecutive_failures = 0;
        self.state = HealthState::Healthy;
    }

    /// Record an engine fault at tick `now`.
    pub fn on_failure(&mut self, now: u64) {
        if self.state == HealthState::Quarantined {
            if self.probe_inflight {
                // Failed probe: stay quarantined, re-arm a longer window.
                self.probe_inflight = false;
                self.probe_streak = 0;
                self.failed_probes += 1;
                self.quarantined_at_tick = now;
            }
            // Late failures from pre-quarantine dispatches don't re-arm.
            return;
        }
        self.consecutive_failures += 1;
        if self.consecutive_failures >= self.policy.quarantine_after.max(1) {
            self.state = HealthState::Quarantined;
            self.quarantined_at_tick = now;
            self.quarantine_events += 1;
            self.probe_streak = 0;
            self.failed_probes = 0;
        } else if self.consecutive_failures >= self.policy.degrade_after.max(1) {
            self.state = HealthState::Degraded;
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn policy() -> HealthPolicy {
        HealthPolicy {
            degrade_after: 2,
            quarantine_after: 3,
            probe_after_ticks: 10,
            probe_successes: 2,
        }
    }

    #[test]
    fn escalation_walk_and_probe_readmission() {
        let mut h = HealthTracker::new(policy());
        assert_eq!(h.state(), HealthState::Healthy);
        h.on_failure(0);
        assert_eq!(h.state(), HealthState::Healthy);
        h.on_failure(1);
        assert_eq!(h.state(), HealthState::Degraded);
        h.on_failure(2);
        assert_eq!(h.state(), HealthState::Quarantined);
        assert_eq!(h.quarantine_events(), 1);

        // Probe window closed until quarantined_at + probe_after_ticks.
        assert_eq!(h.gate(11), Gate::Closed);
        assert_eq!(h.gate(12), Gate::ProbeDue);
        h.begin_probe();
        // While the probe is in flight, everything else is refused.
        assert_eq!(h.gate(50), Gate::Closed);
        h.on_success();
        assert_eq!(h.state(), HealthState::Quarantined, "needs 2 probe successes");
        assert_eq!(h.gate(12), Gate::ProbeDue, "second probe opens immediately");
        h.begin_probe();
        h.on_success();
        assert_eq!(h.state(), HealthState::Healthy);
        assert_eq!(h.consecutive_failures(), 0);
        assert_eq!(h.gate(12), Gate::Open);
    }

    #[test]
    fn failed_probe_backs_off_exponentially() {
        let mut h = HealthTracker::new(policy());
        for t in 0..3 {
            h.on_failure(t);
        }
        assert_eq!(h.state(), HealthState::Quarantined);
        assert_eq!(h.gate(12), Gate::ProbeDue);
        h.begin_probe();
        h.on_failure(12);
        // Window doubled: 12 + 20.
        assert_eq!(h.gate(31), Gate::Closed);
        assert_eq!(h.gate(32), Gate::ProbeDue);
        h.begin_probe();
        h.on_failure(32);
        // Doubled again: 32 + 40.
        assert_eq!(h.gate(71), Gate::Closed);
        assert_eq!(h.gate(72), Gate::ProbeDue);
    }

    #[test]
    fn success_resets_streak_before_quarantine() {
        let mut h = HealthTracker::new(policy());
        h.on_failure(0);
        h.on_failure(0);
        assert_eq!(h.state(), HealthState::Degraded);
        h.on_success();
        assert_eq!(h.state(), HealthState::Healthy);
        assert_eq!(h.consecutive_failures(), 0);
        // The streak restarts from scratch.
        h.on_failure(1);
        assert_eq!(h.state(), HealthState::Healthy);
    }

    #[test]
    fn late_outcomes_do_not_disturb_quarantine() {
        let mut h = HealthTracker::new(policy());
        for t in 0..3 {
            h.on_failure(t);
        }
        // Outcomes from requests dispatched before the quarantine land
        // late: neither re-arms the window nor counts as a probe.
        h.on_success();
        assert_eq!(h.state(), HealthState::Quarantined);
        h.on_failure(5);
        assert_eq!(h.gate(12), Gate::ProbeDue, "window not re-armed by late failure");
    }

    #[test]
    fn aborted_probe_reopens_window() {
        let mut h = HealthTracker::new(policy());
        for t in 0..3 {
            h.on_failure(t);
        }
        assert_eq!(h.gate(12), Gate::ProbeDue);
        h.begin_probe();
        assert_eq!(h.gate(12), Gate::Closed);
        h.abort_probe();
        assert_eq!(h.gate(12), Gate::ProbeDue);
    }
}
