//! Serving metrics: request/batch counters, latency histogram, padding
//! efficiency.

use std::sync::Mutex;

use crate::util::LatencyHistogram;

/// Shared metrics (interior mutability; cloneable via Arc by callers).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    /// Requests the worker has pulled off its channel (arrival count; a
    /// request is counted here before it is batched, so `received` is the
    /// race-free "safely inside the worker" signal shutdown-drain logic
    /// and tests key on).
    received: u64,
    rows: u64,
    batches: u64,
    padded_rows: u64,
    latency: Option<LatencyHistogram>,
    exec_latency: Option<LatencyHistogram>,
    // Parallel (sharded BatchFn) path.
    shards: u64,
    shard_seconds: f64,
    sharded_batches: u64,
    sharded_wall_seconds: f64,
}

/// Point-in-time snapshot for display.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    /// Requests the worker has pulled off its channel (≥ `requests`, which
    /// counts completed responses).
    pub received: u64,
    pub rows: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub mean_latency: f64,
    pub p95_latency: f64,
    pub mean_exec_latency: f64,
    /// Fraction of executed rows that were real (non-padding).
    pub batch_efficiency: f64,
    /// Shards executed by the parallel `BatchFn` path.
    pub shards: u64,
    /// Batches that went through the parallel path.
    pub sharded_batches: u64,
    /// Effective concurrency of the parallel path: summed per-shard compute
    /// seconds over wall seconds (≈ threads actually kept busy; 1.0 when
    /// serial, 0.0 when the parallel path was never used).
    pub parallel_occupancy: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a request arriving at the worker (pulled off the channel,
    /// about to be batched).
    pub fn record_received(&self) {
        self.inner.lock().unwrap().received += 1;
    }

    pub fn record_request(&self, rows: usize, latency_s: f64) {
        let mut g = self.inner.lock().unwrap();
        g.requests += 1;
        g.rows += rows as u64;
        g.latency
            .get_or_insert_with(LatencyHistogram::new)
            .record(latency_s);
    }

    pub fn record_batch(&self, rows_used: usize, capacity: usize, exec_s: f64) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.padded_rows += (capacity - rows_used) as u64;
        g.exec_latency
            .get_or_insert_with(LatencyHistogram::new)
            .record(exec_s);
    }

    /// Record one parallel (sharded) batch execution: per-shard compute
    /// seconds plus the wall time of the whole sharded region.
    pub fn record_shards(&self, shard_secs: &[f64], wall_s: f64) {
        let mut g = self.inner.lock().unwrap();
        g.shards += shard_secs.len() as u64;
        g.shard_seconds += shard_secs.iter().sum::<f64>();
        g.sharded_batches += 1;
        g.sharded_wall_seconds += wall_s;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let executed = g.rows + g.padded_rows;
        MetricsSnapshot {
            requests: g.requests,
            received: g.received,
            rows: g.rows,
            batches: g.batches,
            padded_rows: g.padded_rows,
            mean_latency: g.latency.as_ref().map(|h| h.mean()).unwrap_or(0.0),
            p95_latency: g.latency.as_ref().map(|h| h.quantile(0.95)).unwrap_or(0.0),
            mean_exec_latency: g.exec_latency.as_ref().map(|h| h.mean()).unwrap_or(0.0),
            batch_efficiency: if executed == 0 {
                1.0
            } else {
                g.rows as f64 / executed as f64
            },
            shards: g.shards,
            sharded_batches: g.sharded_batches,
            parallel_occupancy: if g.sharded_wall_seconds > 0.0 {
                g.shard_seconds / g.sharded_wall_seconds
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_efficiency() {
        let m = Metrics::new();
        m.record_request(10, 0.002);
        m.record_request(6, 0.004);
        m.record_batch(16, 32, 0.001);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.rows, 16);
        assert_eq!(s.batches, 1);
        assert_eq!(s.padded_rows, 16);
        assert!((s.batch_efficiency - 0.5).abs() < 1e-12);
        assert!(s.mean_latency > 0.0);
        assert!(s.p95_latency >= s.mean_latency * 0.5);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.batch_efficiency, 1.0);
        assert_eq!(s.shards, 0);
        assert_eq!(s.parallel_occupancy, 0.0);
    }

    #[test]
    fn shard_metrics_accumulate() {
        let m = Metrics::new();
        m.record_shards(&[0.010, 0.012, 0.011, 0.009], 0.014);
        m.record_shards(&[0.008, 0.008], 0.009);
        let s = m.snapshot();
        assert_eq!(s.shards, 6);
        assert_eq!(s.sharded_batches, 2);
        // 0.058 compute seconds over 0.023 wall seconds ≈ 2.5× concurrency.
        assert!(s.parallel_occupancy > 2.0 && s.parallel_occupancy < 3.0);
    }
}
