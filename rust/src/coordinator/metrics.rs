//! Serving metrics: request/batch counters, latency histogram, padding
//! efficiency, and the robustness counters (accept/shed, deadline expiry,
//! engine faults, invalid requests).
//!
//! Histogram-backed metrics live behind a mutex; the robustness counters
//! are plain atomics on the admission fast path (a shed decision must not
//! contend on the histogram lock). The mutex is taken through a
//! poison-recovering guard: metrics must stay observable even if a
//! recording thread panicked mid-update — a counter may then be off by
//! one, which is still more useful than losing all telemetry during the
//! exact incident the panic is part of.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

use crate::util::LatencyHistogram;

/// Shared metrics (interior mutability; cloneable via Arc by callers).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    /// Requests past admission control.
    accepted: AtomicU64,
    /// Requests rejected with `Overloaded` at the admission gate.
    shed: AtomicU64,
    /// Requests rejected with `InvalidRequest` at the front door.
    invalid: AtomicU64,
    /// Requests rejected with `DeadlineExceeded` (at dequeue).
    deadline_expired: AtomicU64,
    /// Batches that failed with `EngineFault` (caught panic or non-finite
    /// output withheld at the boundary).
    engine_faults: AtomicU64,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    /// Requests the worker has pulled off its channel (arrival count; a
    /// request is counted here before it is batched, so `received` is the
    /// race-free "safely inside the worker" signal shutdown-drain logic
    /// and tests key on).
    received: u64,
    rows: u64,
    batches: u64,
    padded_rows: u64,
    latency: Option<LatencyHistogram>,
    exec_latency: Option<LatencyHistogram>,
    /// Time requests sat in the worker queue before being cut into a batch
    /// (the end-to-end latency minus execute minus response plumbing).
    queue_wait: Option<LatencyHistogram>,
    // Parallel (sharded BatchFn) path.
    shards: u64,
    shard_seconds: f64,
    sharded_batches: u64,
    sharded_wall_seconds: f64,
}

impl Inner {
    /// Fold another recorder's state into this one: counts sum,
    /// histograms merge bucket-wise, and the occupancy numerator /
    /// denominator (`shard_seconds` / `sharded_wall_seconds`) sum — so an
    /// aggregate's `parallel_occupancy` is the per-part occupancies
    /// weighted by their sharded wall seconds.
    fn merge(&mut self, other: &Inner) {
        self.requests += other.requests;
        self.received += other.received;
        self.rows += other.rows;
        self.batches += other.batches;
        self.padded_rows += other.padded_rows;
        merge_hist(&mut self.latency, &other.latency);
        merge_hist(&mut self.exec_latency, &other.exec_latency);
        merge_hist(&mut self.queue_wait, &other.queue_wait);
        self.shards += other.shards;
        self.shard_seconds += other.shard_seconds;
        self.sharded_batches += other.sharded_batches;
        self.sharded_wall_seconds += other.sharded_wall_seconds;
    }
}

fn merge_hist(into: &mut Option<LatencyHistogram>, from: &Option<LatencyHistogram>) {
    if let Some(h) = from {
        into.get_or_insert_with(LatencyHistogram::new).merge(h);
    }
}

/// Point-in-time snapshot for display.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    /// Requests the worker has pulled off its channel (≥ `requests`, which
    /// counts completed responses).
    pub received: u64,
    pub rows: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    pub mean_exec_latency: f64,
    pub p95_exec_latency: f64,
    /// Mean time requests waited in the worker queue before batch cut.
    pub mean_queue_wait: f64,
    pub p95_queue_wait: f64,
    /// Fraction of executed rows that were real (non-padding).
    pub batch_efficiency: f64,
    /// Shards executed by the parallel `BatchFn` path.
    pub shards: u64,
    /// Batches that went through the parallel path.
    pub sharded_batches: u64,
    /// Effective concurrency of the parallel path: summed per-shard compute
    /// seconds over wall seconds (≈ threads actually kept busy; 1.0 when
    /// serial, 0.0 when the parallel path was never used).
    pub parallel_occupancy: f64,
    /// Requests past admission control.
    pub accepted: u64,
    /// Requests shed (`Overloaded`) at the admission gate.
    pub shed: u64,
    /// Requests rejected as invalid at the front door.
    pub invalid: u64,
    /// Requests expired (`DeadlineExceeded`) at dequeue.
    pub deadline_expired: u64,
    /// Batches failed with `EngineFault`.
    pub engine_faults: u64,
    /// Non-finite latency samples rejected across the latency / exec /
    /// queue-wait histograms (exact; see `LatencyHistogram::record`).
    pub dropped_latency_samples: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Poison-recovering lock (see module docs).
    fn guard(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Record a request arriving at the worker (pulled off the channel,
    /// about to be batched).
    pub fn record_received(&self) {
        self.guard().received += 1;
    }

    pub fn record_request(&self, rows: usize, latency_s: f64) {
        let mut g = self.guard();
        g.requests += 1;
        g.rows += rows as u64;
        g.latency
            .get_or_insert_with(LatencyHistogram::new)
            .record(latency_s);
    }

    pub fn record_batch(&self, rows_used: usize, capacity: usize, exec_s: f64) {
        let mut g = self.guard();
        g.batches += 1;
        g.padded_rows += (capacity - rows_used) as u64;
        g.exec_latency
            .get_or_insert_with(LatencyHistogram::new)
            .record(exec_s);
    }

    /// Record the queue wait of one request at the moment it is cut into a
    /// batch (enqueue → batch formation).
    pub fn record_queue_wait(&self, wait_s: f64) {
        self.guard()
            .queue_wait
            .get_or_insert_with(LatencyHistogram::new)
            .record(wait_s);
    }

    /// Record one parallel (sharded) batch execution: per-shard compute
    /// seconds plus the wall time of the whole sharded region.
    pub fn record_shards(&self, shard_secs: &[f64], wall_s: f64) {
        let mut g = self.guard();
        g.shards += shard_secs.len() as u64;
        g.shard_seconds += shard_secs.iter().sum::<f64>();
        g.sharded_batches += 1;
        g.sharded_wall_seconds += wall_s;
    }

    pub fn record_accepted(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_invalid(&self) {
        self.invalid.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_deadline_expired(&self) {
        self.deadline_expired.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_engine_fault(&self) {
        self.engine_faults.fetch_add(1, Ordering::Relaxed);
    }

    /// Cheap read of `parallel_occupancy` alone — the dispatch hot path
    /// scores replicas per pick, so it must not pay for a full snapshot
    /// (histogram quantiles) per replica per request.
    pub fn occupancy(&self) -> f64 {
        let g = self.guard();
        if g.sharded_wall_seconds > 0.0 {
            g.shard_seconds / g.sharded_wall_seconds
        } else {
            0.0
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.guard();
        Self::derive(
            &g,
            [
                self.accepted.load(Ordering::Relaxed),
                self.shed.load(Ordering::Relaxed),
                self.invalid.load(Ordering::Relaxed),
                self.deadline_expired.load(Ordering::Relaxed),
                self.engine_faults.load(Ordering::Relaxed),
            ],
        )
    }

    /// Aggregate snapshot across several recorders (one per replica):
    /// counts and robustness counters sum, latency / exec / queue-wait
    /// percentiles come from bucket-merged histograms, and
    /// `parallel_occupancy` weights each part by its sharded wall seconds
    /// (summed shard-compute seconds over summed wall seconds). An empty
    /// iterator yields the all-zero snapshot. This is what
    /// `RouterModelSnapshot.server` reports for multi-replica models —
    /// never a single replica's view.
    pub fn aggregate<'a, I>(parts: I) -> MetricsSnapshot
    where
        I: IntoIterator<Item = &'a Metrics>,
    {
        let mut merged = Inner::default();
        let mut robust = [0u64; 5];
        for m in parts {
            merged.merge(&m.guard());
            robust[0] += m.accepted.load(Ordering::Relaxed);
            robust[1] += m.shed.load(Ordering::Relaxed);
            robust[2] += m.invalid.load(Ordering::Relaxed);
            robust[3] += m.deadline_expired.load(Ordering::Relaxed);
            robust[4] += m.engine_faults.load(Ordering::Relaxed);
        }
        Self::derive(&merged, robust)
    }

    /// Shared snapshot derivation. `robust` is
    /// `[accepted, shed, invalid, deadline_expired, engine_faults]`.
    fn derive(g: &Inner, robust: [u64; 5]) -> MetricsSnapshot {
        let executed = g.rows + g.padded_rows;
        MetricsSnapshot {
            requests: g.requests,
            received: g.received,
            rows: g.rows,
            batches: g.batches,
            padded_rows: g.padded_rows,
            mean_latency: g.latency.as_ref().map(|h| h.mean()).unwrap_or(0.0),
            p50_latency: g.latency.as_ref().map(|h| h.quantile(0.50)).unwrap_or(0.0),
            p95_latency: g.latency.as_ref().map(|h| h.quantile(0.95)).unwrap_or(0.0),
            p99_latency: g.latency.as_ref().map(|h| h.quantile(0.99)).unwrap_or(0.0),
            mean_exec_latency: g.exec_latency.as_ref().map(|h| h.mean()).unwrap_or(0.0),
            p95_exec_latency: g
                .exec_latency
                .as_ref()
                .map(|h| h.quantile(0.95))
                .unwrap_or(0.0),
            mean_queue_wait: g.queue_wait.as_ref().map(|h| h.mean()).unwrap_or(0.0),
            p95_queue_wait: g
                .queue_wait
                .as_ref()
                .map(|h| h.quantile(0.95))
                .unwrap_or(0.0),
            batch_efficiency: if executed == 0 {
                1.0
            } else {
                g.rows as f64 / executed as f64
            },
            shards: g.shards,
            sharded_batches: g.sharded_batches,
            parallel_occupancy: if g.sharded_wall_seconds > 0.0 {
                g.shard_seconds / g.sharded_wall_seconds
            } else {
                0.0
            },
            accepted: robust[0],
            shed: robust[1],
            invalid: robust[2],
            deadline_expired: robust[3],
            engine_faults: robust[4],
            dropped_latency_samples: [&g.latency, &g.exec_latency, &g.queue_wait]
                .iter()
                .map(|h| h.as_ref().map(|h| h.dropped_samples()).unwrap_or(0))
                .sum(),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_efficiency() {
        let m = Metrics::new();
        m.record_request(10, 0.002);
        m.record_request(6, 0.004);
        m.record_batch(16, 32, 0.001);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.rows, 16);
        assert_eq!(s.batches, 1);
        assert_eq!(s.padded_rows, 16);
        assert!((s.batch_efficiency - 0.5).abs() < 1e-12);
        assert!(s.mean_latency > 0.0);
        assert!(s.p95_latency >= s.mean_latency * 0.5);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.batch_efficiency, 1.0);
        assert_eq!(s.shards, 0);
        assert_eq!(s.parallel_occupancy, 0.0);
        assert_eq!((s.accepted, s.shed, s.invalid), (0, 0, 0));
        assert_eq!((s.deadline_expired, s.engine_faults), (0, 0));
    }

    #[test]
    fn shard_metrics_accumulate() {
        let m = Metrics::new();
        m.record_shards(&[0.010, 0.012, 0.011, 0.009], 0.014);
        m.record_shards(&[0.008, 0.008], 0.009);
        let s = m.snapshot();
        assert_eq!(s.shards, 6);
        assert_eq!(s.sharded_batches, 2);
        // 0.058 compute seconds over 0.023 wall seconds ≈ 2.5× concurrency.
        assert!(s.parallel_occupancy > 2.0 && s.parallel_occupancy < 3.0);
    }

    #[test]
    fn latency_split_and_percentiles() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record_request(1, i as f64 * 1e-4);
        }
        m.record_queue_wait(5e-4);
        m.record_queue_wait(7e-4);
        m.record_batch(2, 2, 3e-4);
        let s = m.snapshot();
        // Percentile chain is monotone on the bucket bounds.
        assert!(s.p50_latency <= s.p95_latency);
        assert!(s.p95_latency <= s.p99_latency);
        assert!(s.p50_latency > 0.0);
        // Queue-wait vs execute split are recorded independently.
        assert!(s.mean_queue_wait > 0.0);
        assert!(s.p95_queue_wait >= s.mean_queue_wait * 0.5);
        assert!(s.mean_exec_latency > 0.0);
        assert!(s.p95_exec_latency >= s.mean_exec_latency * 0.5);
    }

    #[test]
    fn aggregate_sums_counts_and_weights_occupancy_by_wall_seconds() {
        let a = Metrics::new();
        let b = Metrics::new();
        a.record_received();
        a.record_request(4, 1e-3);
        a.record_batch(4, 8, 5e-4);
        a.record_queue_wait(2e-4);
        a.record_accepted();
        a.record_shed();
        // Replica a: occupancy 2.0 over 0.010 wall seconds.
        a.record_shards(&[0.010, 0.010], 0.010);
        b.record_received();
        b.record_received();
        b.record_request(2, 2e-3);
        b.record_request(2, 2e-3);
        b.record_batch(4, 8, 5e-4);
        b.record_engine_fault();
        b.record_deadline_expired();
        b.record_invalid();
        // Replica b: occupancy 4.0 over 0.030 wall seconds.
        b.record_shards(&[0.060, 0.060], 0.030);
        let s = Metrics::aggregate([&a, &b]);
        assert_eq!(s.requests, 3);
        assert_eq!(s.received, 3);
        assert_eq!(s.rows, 8);
        assert_eq!(s.batches, 2);
        assert_eq!(s.padded_rows, 8);
        assert!((s.batch_efficiency - 0.5).abs() < 1e-12);
        assert_eq!((s.accepted, s.shed, s.invalid), (1, 1, 1));
        assert_eq!((s.deadline_expired, s.engine_faults), (1, 1));
        assert_eq!(s.shards, 4);
        assert_eq!(s.sharded_batches, 2);
        // Wall-second weighted: (0.020 + 0.120) / (0.010 + 0.030) = 3.5,
        // not the unweighted mean of 2.0 and 4.0.
        assert!((s.parallel_occupancy - 3.5).abs() < 1e-9);
        // Histograms merged: aggregate mean over all three requests.
        assert!((s.mean_latency - (1e-3 + 2e-3 + 2e-3) / 3.0).abs() < 1e-12);
        // Aggregating a single part reproduces its own snapshot.
        let solo = a.snapshot();
        let agg1 = Metrics::aggregate([&a]);
        assert_eq!(solo.requests, agg1.requests);
        assert_eq!(solo.p95_latency, agg1.p95_latency);
        assert_eq!(solo.parallel_occupancy, agg1.parallel_occupancy);
        // Empty aggregation is the zero snapshot.
        let none = Metrics::aggregate(std::iter::empty::<&Metrics>());
        assert_eq!(none.requests, 0);
        assert_eq!(none.batch_efficiency, 1.0);
    }

    #[test]
    fn aggregate_p50_no_longer_one_microsecond_and_nan_latency_no_longer_poisons_mean() {
        // Two replicas whose requests are all slow (~2s), one of which also
        // recorded a NaN latency. Before the stats.rs fixes the router's
        // per-model aggregate reported p50 = bounds[0] (1µs) for q-style
        // lookups with empty leading buckets and mean_latency = NaN forever.
        let a = Metrics::new();
        let b = Metrics::new();
        for _ in 0..4 {
            a.record_request(1, 2.0);
            b.record_request(1, 2.0);
        }
        b.record_request(1, f64::NAN);
        let s = Metrics::aggregate([&a, &b]);
        // NaN sample dropped, not folded into sum: mean stays finite and
        // reflects only the 8 real samples.
        assert!(s.mean_latency.is_finite());
        assert!((s.mean_latency - 2.0).abs() < 0.5);
        assert_eq!(s.dropped_latency_samples, 1);
        // The NaN request still counted as a request (it completed), only
        // its latency sample was rejected.
        assert_eq!(s.requests, 9);
        // Quantiles of the merged histogram skip the empty fast buckets.
        assert!(s.p50_latency >= 1.0);
        assert!(s.p95_latency >= s.p50_latency);
    }

    #[test]
    fn robustness_counters_are_exact() {
        let m = Metrics::new();
        for _ in 0..5 {
            m.record_accepted();
        }
        m.record_shed();
        m.record_shed();
        m.record_invalid();
        m.record_deadline_expired();
        m.record_engine_fault();
        let s = m.snapshot();
        assert_eq!(s.accepted, 5);
        assert_eq!(s.shed, 2);
        assert_eq!(s.invalid, 1);
        assert_eq!(s.deadline_expired, 1);
        assert_eq!(s.engine_faults, 1);
    }
}
