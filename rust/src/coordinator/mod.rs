//! L3 coordinator: request routing, dynamic batching, and worker threads
//! that own the PJRT executables.
//!
//! The serving model: clients submit variable-size point sets for operator
//! evaluation (`(φ, L[φ])` at collocation points); a per-model worker
//! thread batches them up to the artifact's fixed AOT batch size (padding
//! the tail), executes, splits results back per request, and records
//! latency/throughput metrics. PJRT handles are not `Send`, so each worker
//! owns its own [`crate::runtime::Executor`]; the handle side is plain
//! `mpsc`, so any number of producer threads can submit.
//!
//! Multi-model traffic goes through the [`Router`]: per-model
//! [`ModelServer`]s (DOF / Hessian / jet engines mixed) registered under
//! names, tagged dispatch, and per-model queue-depth + occupancy metrics
//! for autoscaling decisions — see [`router`].

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, Batcher, PendingRequest};
pub use metrics::Metrics;
pub use router::{Router, RouterClient, RouterModelSnapshot};
pub use server::{BatchFn, ModelServer, ServerHandle};

/// A request: evaluate the operator at `rows` points of width `width`
/// (flat row-major).
#[derive(Debug, Clone)]
pub struct EvalRequest {
    pub points: Vec<f32>,
    pub rows: usize,
    pub width: usize,
}

impl EvalRequest {
    pub fn new(points: Vec<f32>, width: usize) -> Self {
        assert!(width > 0 && points.len() % width == 0, "ragged request");
        let rows = points.len() / width;
        Self {
            points,
            rows,
            width,
        }
    }
}

/// A response: `φ` and `L[φ]` per requested point.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalResponse {
    pub phi: Vec<f32>,
    pub lphi: Vec<f32>,
}
