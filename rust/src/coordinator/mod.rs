//! L3 coordinator: request routing, dynamic batching, fault tolerance,
//! and worker threads that own the PJRT executables.
//!
//! The serving model: clients submit variable-size point sets for operator
//! evaluation (`(φ, L[φ])` at collocation points); a per-model worker
//! thread batches them up to the artifact's fixed AOT batch size (padding
//! the tail), executes, splits results back per request, and records
//! latency/throughput metrics. PJRT handles are not `Send`, so each worker
//! owns its own [`crate::runtime::Executor`]; the handle side is plain
//! `mpsc`, so any number of producer threads can submit.
//!
//! Multi-model traffic goes through the [`Router`]: per-model replica sets
//! of [`ModelServer`]s (DOF / Hessian / jet engines mixed) registered
//! under names, tagged dispatch with retry/failover scored by
//! [`DispatchPolicy`], and per-model queue-depth + occupancy + robustness
//! metrics (aggregated across replicas) — see [`router`]. The
//! [`Autoscaler`] consumes those snapshots and grows/drains replica sets
//! deterministically on the shared [`TickClock`] — see [`autoscaler`].
//!
//! The fault tier ([`fault`], [`health`]) defines the serving error
//! taxonomy ([`ServeError`]), admission control, logical-tick deadlines,
//! panic quarantine, and the seeded fault injector; the crate-level
//! "error taxonomy & failure semantics" section in `lib.rs` documents the
//! contract. This module tree denies `unwrap`/`expect` in non-test code:
//! the serving boundary must degrade through [`ServeError`], never through
//! a panic.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod autoscaler;
pub mod batcher;
pub mod fault;
pub mod health;
pub mod metrics;
pub mod router;
pub mod server;

pub use autoscaler::{
    Autoscaler, AutoscalerConfig, AutoscalerSnapshot, ScaleDirection, ScaleEvent,
};
pub use batcher::{BatchPolicy, Batcher, PendingRequest};
pub use fault::{
    FaultConfig, FaultInjector, FaultInjectorSnapshot, FaultPlan, RetryPolicy, ServeError,
    TickClock,
};
pub use health::{Gate, HealthPolicy, HealthState, HealthTracker};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::{
    DispatchPolicy, ReplicaFactory, ReplicaSnapshot, Router, RouterClient, RouterConfig,
    RouterModelSnapshot,
};
pub use server::{BatchFn, ModelServer, ServeConfig, ServerHandle};

/// Poison-recovering lock used across the coordinator: a panicking holder
/// must never take the serving control plane down with it (the panic
/// itself is already being reported through [`ServeError::EngineFault`]).
pub(crate) fn plock<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// A request: evaluate the operator at `rows` points of width `width`
/// (flat row-major).
#[derive(Debug, Clone)]
pub struct EvalRequest {
    pub points: Vec<f32>,
    pub rows: usize,
    pub width: usize,
    /// Absolute logical-tick deadline (against the server's
    /// [`TickClock`]); `None` = no deadline. Checked when the worker
    /// dequeues the request — an expired request is answered with
    /// [`ServeError::DeadlineExceeded`] instead of entering a batch.
    pub deadline_tick: Option<u64>,
    /// Per-request sample-count override for stochastic (STDE) backends;
    /// `None` = the backend's spawn-time default. The batcher never mixes
    /// requests with different `samples` in one batch (the sample count is
    /// a property of the whole cut), and non-stochastic backends ignore
    /// it. See [`ServerHandle::eval_with_samples`].
    pub samples: Option<u32>,
}

impl EvalRequest {
    /// Construct a request, panicking on a ragged point buffer. Internal
    /// callers reach this only *after* front-door validation
    /// ([`ServerHandle::eval_blocking`] rejects ragged/non-finite input
    /// with [`ServeError::InvalidRequest`] first); external callers should
    /// prefer [`EvalRequest::try_new`].
    pub fn new(points: Vec<f32>, width: usize) -> Self {
        match Self::try_new(points, width, None) {
            Ok(req) => req,
            Err(e) => panic!("{e}"),
        }
    }

    /// Construct a request with structured validation: non-zero width, a
    /// non-empty point buffer that is a whole number of rows, and (unlike
    /// the panicking path) no further checks — finiteness is the serving
    /// front door's job, where the model label is known.
    pub fn try_new(
        points: Vec<f32>,
        width: usize,
        deadline_tick: Option<u64>,
    ) -> Result<Self, ServeError> {
        if width == 0 {
            return Err(ServeError::InvalidRequest {
                reason: "width must be positive".to_string(),
            });
        }
        if points.is_empty() || points.len() % width != 0 {
            return Err(ServeError::InvalidRequest {
                reason: format!(
                    "ragged request: {} values is not a positive multiple of width {width}",
                    points.len()
                ),
            });
        }
        let rows = points.len() / width;
        Ok(Self {
            points,
            rows,
            width,
            deadline_tick,
            samples: None,
        })
    }

    /// Attach a per-request sample-count override (stochastic backends
    /// only; see the field docs on [`EvalRequest::samples`]).
    pub fn with_samples(mut self, samples: Option<u32>) -> Self {
        self.samples = samples;
        self
    }
}

/// A response: `φ` and `L[φ]` per requested point.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalResponse {
    pub phi: Vec<f32>,
    pub lphi: Vec<f32>,
}
