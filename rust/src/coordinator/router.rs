//! Multi-model serving router: one front door over per-model **replica
//! sets** of [`ModelServer`] workers.
//!
//! `ModelServer` instances already compose — each owns its worker thread,
//! batcher, and metrics — but before the router every client had to hold
//! the right `ServerHandle` itself. The router closes that gap for
//! multi-model traffic (the ROADMAP serving follow-up):
//!
//! * **Registration** — each model (DOF / Hessian-baseline / jet engines
//!   mixed, or an XLA artifact worker) is registered once under a name;
//!   widths may differ per model. [`Router::add_replica`] attaches more
//!   servers to an existing name, and a registered
//!   [`ReplicaFactory`](Router::set_replica_factory) lets the autoscaler
//!   spawn further replicas on demand ([`Router::scale_up`] /
//!   [`Router::retire_replica`]).
//! * **Load-aware dispatch** — a request names its model;
//!   [`RouterClient::eval_blocking`] routes it to the healthy replica with
//!   the lowest [`DispatchPolicy`] score
//!   (`inflight_weight · router_inflight + queue_weight · admission_depth
//!   + occupancy_weight · parallel_occupancy`, ties to the lowest index).
//!   The default weights score exact counters only, so replica choice is
//!   deterministic under a deterministic schedule; `occupancy_weight`
//!   opts into the measured-seconds occupancy signal. On a retryable
//!   failure ([`ServeError::retryable`]) the attempt budget
//!   ([`RouterConfig::retries`]) fails over to another replica. Routing
//!   adds counters only — the bytes flow through the same `ServerHandle`
//!   path as a direct caller, so routed results are **bitwise identical**
//!   to direct engine calls (asserted by `rust/tests/router_serving.rs`).
//! * **Elastic replica sets** — each model's dispatch list lives behind an
//!   epoch-versioned shared handle: [`Router::scale_up`] and
//!   [`Router::retire_replica`] publish a new list and bump the epoch, and
//!   every existing [`RouterClient`] picks the change up on its next
//!   request (no client re-creation). Retirement publishes first and
//!   drains second, so every request admitted before the retire completes
//!   is answered.
//! * **Health gating** — each replica carries a
//!   [`HealthTracker`](super::health::HealthTracker): consecutive engine
//!   faults quarantine it, and once its logical-tick probe window opens the
//!   next live request is routed to it as a probe (opportunistic probing:
//!   re-admission needs no background thread and stays deterministic under
//!   a deterministic request schedule). The probe is consumed exactly once
//!   even under concurrent callers (`begin_probe` runs under the health
//!   mutex).
//! * **Deadlines** — [`RouterConfig::deadline_ticks`] stamps each request
//!   with an absolute deadline on the shared [`TickClock`]; the router
//!   checks it between attempts and the worker checks it at dequeue. No
//!   wall clock anywhere in the control plane.
//! * **Autoscaling signals** — per-model [`RouterModelSnapshot`]s expose
//!   exact dispatch/completion/shed/retry/deadline/fault counters, the
//!   instantaneous, peak, and per-interval **queue depth**, the replica-set
//!   epoch, per-replica health ([`ReplicaSnapshot`]), and server metrics.
//!   The `server` field aggregates **all** replicas
//!   ([`Metrics::aggregate`]): counts are summed, latency histograms
//!   merged, and `parallel_occupancy` weighted by per-replica sharded wall
//!   seconds. [`super::Autoscaler`](super::autoscaler::Autoscaler)
//!   consumes these snapshots.
//! * **Draining shutdown** — [`Router::shutdown`] stops every worker
//!   (quarantined replicas included) via its graceful path: partial
//!   batches are flushed and every in-flight request receives its response
//!   before the worker exits.
//!
//! Concurrency model: registration and scaling happen on the thread that
//! owns the `Router` (`&mut self`); clients obtain a cheap
//! [`RouterClient`] per model (cloneable, `Send`) and submit from as many
//! threads as they like — counters are atomics, health trackers sit
//! behind poison-recovering mutexes, and the dispatch list is an
//! `Arc`-swapped snapshot read once per request.
//!
//! For deadlines and health probes to mean anything, pass the **same**
//! [`TickClock`] to the [`RouterConfig`] and to every replica's
//! [`super::ServeConfig`], and advance it from the traffic driver.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::obs::{Span, SpanKind, TraceContext, Tracer};

use super::fault::{ServeError, TickClock};
use super::health::{Gate, HealthPolicy, HealthState, HealthTracker};
use super::metrics::{Metrics, MetricsSnapshot};
use super::plock;
use super::server::{ModelServer, ServerHandle};
use super::EvalResponse;

/// Replica-scoring weights for load-aware dispatch. Lower score wins;
/// exact ties break to the lowest replica index, and a replica the
/// current request has not yet tried always beats one it has.
///
/// `score = inflight_weight · router_inflight`
/// `      + queue_weight · admission_depth`
/// `      + occupancy_weight · parallel_occupancy`
///
/// where `router_inflight` is the replica's unresolved routed attempts
/// (exact atomic accounting), `admission_depth` is the replica server's
/// admitted-but-unanswered count ([`ServerHandle::inflight`]), and
/// `parallel_occupancy` is the replica's measured shard-seconds per wall
/// second ([`Metrics::occupancy`]).
///
/// The default weights (1, 1, 0) use exact counters only — replica choice
/// stays deterministic under a deterministic request schedule, and on
/// idle replicas reproduces classic least-inflight with lowest-index
/// ties. Setting `occupancy_weight > 0` folds in the wall-clock-derived
/// occupancy signal; results remain bitwise identical either way because
/// replica choice never affects the computed bytes.
#[derive(Debug, Clone, Copy)]
pub struct DispatchPolicy {
    /// Weight on the replica's unresolved routed attempts.
    pub inflight_weight: f64,
    /// Weight on the replica's admission-gate depth.
    pub queue_weight: f64,
    /// Weight on the replica's `parallel_occupancy` (0 = never read it).
    pub occupancy_weight: f64,
}

impl Default for DispatchPolicy {
    fn default() -> Self {
        Self {
            inflight_weight: 1.0,
            queue_weight: 1.0,
            occupancy_weight: 0.0,
        }
    }
}

impl DispatchPolicy {
    /// The dispatch score (see type docs); lower is better.
    pub fn score(&self, router_inflight: u64, admission_depth: usize, occupancy: f64) -> f64 {
        self.inflight_weight * router_inflight as f64
            + self.queue_weight * admission_depth as f64
            + self.occupancy_weight * occupancy
    }
}

/// Routing policy knobs (all logical-tick based; `Default` reproduces the
/// PR 5 behaviour: no deadlines, no retries, least-loaded dispatch).
#[derive(Clone, Default)]
pub struct RouterConfig {
    /// Relative deadline stamped on every routed request: absolute
    /// deadline = clock now + this. `None` = no deadlines.
    pub deadline_ticks: Option<u64>,
    /// Extra attempts after the first (failover budget). `0` = fail fast.
    pub retries: u32,
    /// The shared logical clock (share it with every replica's
    /// [`super::ServeConfig`]).
    pub clock: TickClock,
    /// Health escalation thresholds applied to every replica.
    pub health: HealthPolicy,
    /// Replica-scoring weights for dispatch (see [`DispatchPolicy`]).
    pub dispatch: DispatchPolicy,
    /// Span sink for request tracing: when set, every routed request
    /// records a `request → attempt → …` span tree (the serving layers
    /// below add queue-wait / batch / execute / shard children). Share the
    /// same tracer with every replica's [`super::ServeConfig`]. `None`
    /// (the default) records nothing; tracing is bitwise-invisible either
    /// way.
    pub tracer: Option<Arc<Tracer>>,
}

/// Spawns one more replica server for a model — registered via
/// [`Router::set_replica_factory`] so [`Router::scale_up`] (and through
/// it the autoscaler) can grow the replica set. Spawning re-hits the
/// compile-once program caches, so factories are cheap to call.
pub type ReplicaFactory = Box<dyn Fn() -> ModelServer + Send>;

/// Per-model routing counters (shared between the router and its clients).
#[derive(Default)]
struct Counters {
    /// Requests routed to the model (== completed + failed + in flight).
    dispatched: AtomicU64,
    /// Requests answered successfully.
    completed: AtomicU64,
    /// Requests answered with an error.
    failed: AtomicU64,
    /// Failed requests whose final error was `Overloaded`.
    shed: AtomicU64,
    /// Failed requests whose final error was `DeadlineExceeded`.
    deadline_expired: AtomicU64,
    /// Failed requests whose final error was `InvalidRequest`.
    invalid: AtomicU64,
    /// Engine-fault *attempts* (counted per attempt, so with failover this
    /// can exceed `failed`).
    engine_faults: AtomicU64,
    /// Failover attempts beyond the first (attempt 2, 3, … of a request).
    retries: AtomicU64,
    /// Requests currently inside the router (queued or executing).
    queue_depth: AtomicUsize,
    /// High-water mark of `queue_depth`.
    peak_queue_depth: AtomicUsize,
    /// High-water mark of `queue_depth` since the last autoscaler
    /// observation (swap-reset by `Router::scaling_snapshot`).
    interval_peak_queue_depth: AtomicUsize,
}

/// Shared per-replica routing state (health + exact attempt accounting).
struct ReplicaState {
    health: Mutex<HealthTracker>,
    attempts: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
}

impl ReplicaState {
    fn new(policy: HealthPolicy) -> Self {
        Self {
            health: Mutex::new(HealthTracker::new(policy)),
            attempts: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
        }
    }
}

struct ReplicaSlot {
    server: ModelServer,
    state: Arc<ReplicaState>,
}

/// The dispatch view of a replica set, read once per routed request.
type ReplicaSet = Arc<Vec<(ServerHandle, Arc<ReplicaState>)>>;

/// The epoch-versioned dispatch list shared between the router (writer,
/// on scale-up / retire) and every `RouterClient` (readers). Clients
/// clone the current `Arc` per request, so a published change is visible
/// to all of them on their very next request.
struct SharedReplicas {
    epoch: AtomicU64,
    list: Mutex<ReplicaSet>,
}

impl SharedReplicas {
    fn new(list: Vec<(ServerHandle, Arc<ReplicaState>)>) -> Self {
        Self {
            epoch: AtomicU64::new(1),
            list: Mutex::new(Arc::new(list)),
        }
    }

    fn current(&self) -> ReplicaSet {
        plock(&self.list).clone()
    }

    fn publish(&self, list: Vec<(ServerHandle, Arc<ReplicaState>)>) {
        *plock(&self.list) = Arc::new(list);
        self.epoch.fetch_add(1, Ordering::Release);
    }
}

struct Entry {
    name: String,
    /// Row width every replica of this model must share (recorded at
    /// registration so clients never depend on the mutable replica list).
    width: usize,
    replicas: Vec<ReplicaSlot>,
    shared: Arc<SharedReplicas>,
    counters: Arc<Counters>,
    factory: Option<ReplicaFactory>,
}

impl Entry {
    /// Rebuild the client-visible dispatch list from `replicas` and bump
    /// the epoch.
    fn publish(&self) {
        self.shared.publish(
            self.replicas
                .iter()
                .map(|r| (r.server.handle(), Arc::clone(&r.state)))
                .collect(),
        );
    }
}

/// The multi-model front door (see module docs).
#[derive(Default)]
pub struct Router {
    models: Vec<Entry>,
    cfg: RouterConfig,
}

/// A client for one registered model: routes requests across the model's
/// replicas and maintains the model's counters. Cloneable and `Send` —
/// hand one clone per client thread. Reads the model's epoch-versioned
/// replica list once per request, so autoscaler changes apply to existing
/// clients immediately.
#[derive(Clone)]
pub struct RouterClient {
    model: String,
    width: usize,
    shared: Arc<SharedReplicas>,
    counters: Arc<Counters>,
    cfg: RouterConfig,
}

/// Point-in-time health + accounting for one replica.
#[derive(Debug, Clone)]
pub struct ReplicaSnapshot {
    /// Position in the replica set (registration order).
    pub index: usize,
    pub state: HealthState,
    pub consecutive_failures: u32,
    /// Times this replica entered quarantine.
    pub quarantine_events: u64,
    /// Attempts routed to this replica (probes included).
    pub attempts: u64,
    pub completed: u64,
    pub failed: u64,
    /// Requests currently admitted and unanswered at this replica.
    pub inflight: usize,
    /// The replica server's own metrics.
    pub server: MetricsSnapshot,
}

/// Point-in-time routing metrics for one model.
#[derive(Debug, Clone)]
pub struct RouterModelSnapshot {
    pub model: String,
    /// Requests routed to this model.
    pub dispatched: u64,
    /// Requests answered successfully.
    pub completed: u64,
    /// Requests answered with an error.
    pub failed: u64,
    /// Failed requests shed with `Overloaded`.
    pub shed: u64,
    /// Failover attempts beyond each request's first.
    pub retries: u64,
    /// Failed requests expired with `DeadlineExceeded`.
    pub deadline_expired: u64,
    /// Failed requests rejected with `InvalidRequest`.
    pub invalid: u64,
    /// Engine-fault attempts (per attempt, so ≥ the engine-fault share of
    /// `failed` when failover is on).
    pub engine_faults: u64,
    /// Total quarantine entries across the replica set.
    pub quarantine_events: u64,
    /// Requests currently inside the router (queued or executing).
    pub queue_depth: usize,
    /// High-water mark of `queue_depth` since registration.
    pub peak_queue_depth: usize,
    /// High-water mark of `queue_depth` since the last autoscaler
    /// observation (the autoscaler swap-resets it each step; plain
    /// `snapshot()` reads it non-destructively).
    pub interval_peak_queue_depth: usize,
    /// Replica-set epoch: bumped by every scale-up / retire. Existing
    /// clients pick up the new set on their next request.
    pub epoch: u64,
    /// Server metrics aggregated across **all** replicas
    /// ([`Metrics::aggregate`]): counts summed, latency histograms
    /// merged, `parallel_occupancy` weighted by sharded wall seconds.
    /// Per-replica metrics live in `replicas`.
    pub server: MetricsSnapshot,
    /// Per-replica health + accounting, in registration order.
    pub replicas: Vec<ReplicaSnapshot>,
}

impl Router {
    pub fn new() -> Self {
        Self::with_config(RouterConfig::default())
    }

    /// A router with deadlines / retry / health / dispatch policy.
    pub fn with_config(cfg: RouterConfig) -> Self {
        Self {
            models: Vec::new(),
            cfg,
        }
    }

    /// The router's logical clock (advance it from the traffic driver when
    /// using deadlines or quarantine probes).
    pub fn clock(&self) -> &TickClock {
        &self.cfg.clock
    }

    /// Register a model server under `name` (replica 0). Panics on a
    /// duplicate name (two entries answering one tag would split the
    /// metrics and make routing ambiguous).
    pub fn register(&mut self, name: &str, server: ModelServer) {
        assert!(
            self.models.iter().all(|e| e.name != name),
            "router already has a model named {name:?}"
        );
        let width = server.handle().width();
        let state = Arc::new(ReplicaState::new(self.cfg.health));
        let shared = Arc::new(SharedReplicas::new(vec![(
            server.handle(),
            Arc::clone(&state),
        )]));
        self.models.push(Entry {
            name: name.to_string(),
            width,
            replicas: vec![ReplicaSlot { server, state }],
            shared,
            counters: Arc::new(Counters::default()),
            factory: None,
        });
    }

    fn entry_mut(&mut self, name: &str) -> Result<&mut Entry> {
        self.models
            .iter_mut()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("router has no model named {name:?}"))
    }

    /// Attach another replica to an existing model name (failover target;
    /// width must match the model's existing replicas). Existing clients
    /// see it on their next request.
    pub fn add_replica(&mut self, name: &str, server: ModelServer) -> Result<()> {
        let cfg_health = self.cfg.health;
        let entry = self.entry_mut(name)?;
        let got = server.handle().width();
        if got != entry.width {
            return Err(anyhow!(
                "replica width {got} does not match model {name:?} width {}",
                entry.width
            ));
        }
        entry.replicas.push(ReplicaSlot {
            server,
            state: Arc::new(ReplicaState::new(cfg_health)),
        });
        entry.publish();
        Ok(())
    }

    /// Register the spawn factory [`Router::scale_up`] uses for `name`.
    pub fn set_replica_factory(&mut self, name: &str, factory: ReplicaFactory) -> Result<()> {
        self.entry_mut(name)?.factory = Some(factory);
        Ok(())
    }

    /// Spawn one more replica for `name` via its registered factory and
    /// publish it to clients. Returns the new replica count.
    pub fn scale_up(&mut self, name: &str) -> Result<usize> {
        let cfg_health = self.cfg.health;
        let entry = self.entry_mut(name)?;
        let server = match &entry.factory {
            Some(f) => f(),
            None => return Err(anyhow!("model {name:?} has no replica factory")),
        };
        let got = server.handle().width();
        if got != entry.width {
            return Err(anyhow!(
                "factory produced width {got}, model {name:?} expects width {}",
                entry.width
            ));
        }
        entry.replicas.push(ReplicaSlot {
            server,
            state: Arc::new(ReplicaState::new(cfg_health)),
        });
        entry.publish();
        Ok(entry.replicas.len())
    }

    /// Retire the highest-index replica of `name`: publish the shrunken
    /// dispatch list first (no new request can pick the retiring replica),
    /// then drain it via the graceful shutdown path — every request
    /// admitted before the publish is answered, so nothing is lost.
    /// Refuses to drop the last replica. Returns the new replica count.
    pub fn retire_replica(&mut self, name: &str) -> Result<usize> {
        let entry = self.entry_mut(name)?;
        if entry.replicas.len() <= 1 {
            return Err(anyhow!("model {name:?} is already at its last replica"));
        }
        let slot = match entry.replicas.pop() {
            Some(s) => s,
            None => return Err(anyhow!("model {name:?} has no replicas")),
        };
        entry.publish();
        let remaining = entry.replicas.len();
        slot.server.shutdown();
        Ok(remaining)
    }

    /// Current replica count for `name` (`None` for an unknown model).
    pub fn replica_count(&self, name: &str) -> Option<usize> {
        self.models
            .iter()
            .find(|e| e.name == name)
            .map(|e| e.replicas.len())
    }

    /// Registered model names, in registration order.
    pub fn models(&self) -> Vec<&str> {
        self.models.iter().map(|e| e.name.as_str()).collect()
    }

    /// A routing client for `model` (error on an unknown tag).
    pub fn client(&self, model: &str) -> Result<RouterClient> {
        let entry = self
            .models
            .iter()
            .find(|e| e.name == model)
            .ok_or_else(|| anyhow!("router has no model named {model:?}"))?;
        Ok(RouterClient {
            model: entry.name.clone(),
            width: entry.width,
            shared: Arc::clone(&entry.shared),
            counters: Arc::clone(&entry.counters),
            cfg: self.cfg.clone(),
        })
    }

    /// Route one request to `model` and block for the response.
    pub fn eval_blocking(&self, model: &str, points: Vec<f32>) -> Result<EvalResponse> {
        Ok(self.client(model)?.eval_blocking(points)?)
    }

    /// Routing + health + server metrics for every model, in registration
    /// order. Non-destructive (see `scaling_snapshot` for the autoscaler's
    /// interval-resetting variant).
    pub fn snapshot(&self) -> Vec<RouterModelSnapshot> {
        self.snapshot_impl(false)
    }

    /// The autoscaler's observation: identical to [`Router::snapshot`]
    /// except `interval_peak_queue_depth` is swap-reset to the current
    /// depth, so each step sees the high-water mark since the previous
    /// step.
    pub(crate) fn scaling_snapshot(&self) -> Vec<RouterModelSnapshot> {
        self.snapshot_impl(true)
    }

    fn snapshot_impl(&self, reset_interval: bool) -> Vec<RouterModelSnapshot> {
        self.models
            .iter()
            .map(|e| {
                let replicas: Vec<ReplicaSnapshot> = e
                    .replicas
                    .iter()
                    .enumerate()
                    .map(|(i, r)| {
                        let h = plock(&r.state.health);
                        let handle = r.server.handle();
                        ReplicaSnapshot {
                            index: i,
                            state: h.state(),
                            consecutive_failures: h.consecutive_failures(),
                            quarantine_events: h.quarantine_events(),
                            attempts: r.state.attempts.load(Ordering::Relaxed),
                            completed: r.state.completed.load(Ordering::Relaxed),
                            failed: r.state.failed.load(Ordering::Relaxed),
                            inflight: handle.inflight(),
                            server: handle.metrics.snapshot(),
                        }
                    })
                    .collect();
                let metrics: Vec<Arc<Metrics>> = e
                    .replicas
                    .iter()
                    .map(|r| Arc::clone(&r.server.handle().metrics))
                    .collect();
                let server = Metrics::aggregate(metrics.iter().map(|m| m.as_ref()));
                let interval_peak = if reset_interval {
                    let depth = e.counters.queue_depth.load(Ordering::Relaxed);
                    e.counters
                        .interval_peak_queue_depth
                        .swap(depth, Ordering::Relaxed)
                } else {
                    e.counters.interval_peak_queue_depth.load(Ordering::Relaxed)
                };
                RouterModelSnapshot {
                    model: e.name.clone(),
                    dispatched: e.counters.dispatched.load(Ordering::Relaxed),
                    completed: e.counters.completed.load(Ordering::Relaxed),
                    failed: e.counters.failed.load(Ordering::Relaxed),
                    shed: e.counters.shed.load(Ordering::Relaxed),
                    retries: e.counters.retries.load(Ordering::Relaxed),
                    deadline_expired: e.counters.deadline_expired.load(Ordering::Relaxed),
                    invalid: e.counters.invalid.load(Ordering::Relaxed),
                    engine_faults: e.counters.engine_faults.load(Ordering::Relaxed),
                    quarantine_events: replicas.iter().map(|r| r.quarantine_events).sum(),
                    queue_depth: e.counters.queue_depth.load(Ordering::Relaxed),
                    peak_queue_depth: e.counters.peak_queue_depth.load(Ordering::Relaxed),
                    interval_peak_queue_depth: interval_peak,
                    epoch: e.shared.epoch.load(Ordering::Acquire),
                    server,
                    replicas,
                }
            })
            .collect()
    }

    /// Graceful stop: every worker — quarantined replicas included —
    /// flushes its partial batch and answers all in-flight requests before
    /// exiting (no request is lost; asserted by
    /// `rust/tests/router_serving.rs`).
    pub fn shutdown(self) {
        for e in self.models {
            for r in e.replicas {
                r.server.shutdown();
            }
        }
    }
}

impl RouterClient {
    /// The model this client routes to.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Row width (input dimension) the model expects.
    pub fn width(&self) -> usize {
        self.width
    }

    /// The replica-set epoch this client currently observes (bumped by
    /// every scale-up / retire).
    pub fn epoch(&self) -> u64 {
        self.shared.epoch.load(Ordering::Acquire)
    }

    /// Route one request and block for the response, maintaining the
    /// model's dispatch and queue-depth counters exactly (one dispatched
    /// per call; depth incremented for the duration of the round trip,
    /// retries included).
    pub fn eval_blocking(&self, points: Vec<f32>) -> std::result::Result<EvalResponse, ServeError> {
        self.eval_blocking_with_samples(points, None)
    }

    /// [`Self::eval_blocking`] with a per-request sample-count override
    /// (stochastic/STDE models only — see
    /// [`super::ServerHandle::eval_with_samples`]; other models ignore
    /// it). The override survives failover: every retry attempt carries
    /// the same `samples`.
    pub fn eval_blocking_with_samples(
        &self,
        points: Vec<f32>,
        samples: Option<u32>,
    ) -> std::result::Result<EvalResponse, ServeError> {
        let c = &*self.counters;
        c.dispatched.fetch_add(1, Ordering::Relaxed);
        let depth = c.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        c.peak_queue_depth.fetch_max(depth, Ordering::Relaxed);
        c.interval_peak_queue_depth
            .fetch_max(depth, Ordering::Relaxed);
        let out = self.route(&points, samples);
        // Outcome before depth: a snapshot must never observe a request
        // missing from dispatched == completed + failed + queue_depth.
        match &out {
            Ok(_) => {
                c.completed.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                c.failed.fetch_add(1, Ordering::Relaxed);
                match e {
                    ServeError::Overloaded { .. } => {
                        c.shed.fetch_add(1, Ordering::Relaxed);
                    }
                    ServeError::DeadlineExceeded { .. } => {
                        c.deadline_expired.fetch_add(1, Ordering::Relaxed);
                    }
                    ServeError::InvalidRequest { .. } => {
                        c.invalid.fetch_add(1, Ordering::Relaxed);
                    }
                    // Engine faults are counted per attempt inside route().
                    ServeError::EngineFault { .. } => {}
                }
            }
        };
        c.queue_depth.fetch_sub(1, Ordering::Relaxed);
        out
    }

    /// Trace boundary: with a tracer configured, allocate the request's
    /// root span id up front (attempts parent under it) and record the
    /// root span once the attempt loop resolves. Without one, this is a
    /// direct call into the attempt loop — same bytes either way.
    fn route(
        &self,
        points: &[f32],
        samples: Option<u32>,
    ) -> std::result::Result<EvalResponse, ServeError> {
        let Some(tracer) = &self.cfg.tracer else {
            return self.route_inner(points, samples, None);
        };
        let root = tracer.next_id();
        let start_tick = self.cfg.clock.now();
        let t0 = Instant::now();
        let out = self.route_inner(points, samples, Some(root));
        let width = self.width().max(1);
        tracer.record(Span {
            id: root,
            parent: 0,
            request: root,
            kind: SpanKind::Request,
            label: self.model.clone(),
            start_tick,
            end_tick: self.cfg.clock.now(),
            seconds: t0.elapsed().as_secs_f64(),
            detail: (points.len() / width) as u64,
        });
        out
    }

    /// The attempt loop: pick a replica, dispatch, classify the outcome,
    /// fail over while the budget and deadline allow. The replica set is
    /// read once per request, so a concurrent scale-up/retire applies to
    /// the *next* request; a retired replica still drains everything this
    /// request managed to enqueue.
    fn route_inner(
        &self,
        points: &[f32],
        samples: Option<u32>,
        root: Option<u64>,
    ) -> std::result::Result<EvalResponse, ServeError> {
        let clock = &self.cfg.clock;
        let replicas = self.shared.current();
        let deadline = self
            .cfg
            .deadline_ticks
            .map(|d| clock.now().saturating_add(d));
        let mut last: Option<ServeError> = None;
        let mut tried = vec![false; replicas.len()];
        for attempt in 0..u64::from(self.cfg.retries) + 1 {
            if attempt > 0 {
                self.counters.retries.fetch_add(1, Ordering::Relaxed);
            }
            let now = clock.now();
            if let Some(dt) = deadline {
                // Deadline check between attempts: never burn the retry
                // budget on a request that already expired.
                if now >= dt {
                    return Err(ServeError::DeadlineExceeded {
                        model: self.model.clone(),
                        deadline_tick: dt,
                        now_tick: now,
                    });
                }
            }
            let Some((idx, is_probe)) = self.pick(&replicas, now, &tried) else {
                return Err(last.unwrap_or_else(|| ServeError::Overloaded {
                    model: self.model.clone(),
                    reason: "no replica available (all quarantined)".to_string(),
                }));
            };
            let (handle, state) = &replicas[idx];
            tried[idx] = true;
            state.attempts.fetch_add(1, Ordering::Relaxed);
            // Attempt span: allocated before dispatch so the replica's
            // queue/batch/execute spans can parent under it, recorded
            // after the attempt resolves.
            let trace = match (&self.cfg.tracer, root) {
                (Some(tracer), Some(root)) => Some((
                    tracer,
                    root,
                    TraceContext {
                        request: root,
                        parent: tracer.next_id(),
                    },
                    Instant::now(),
                )),
                _ => None,
            };
            let result =
                handle.eval_opts(points.to_vec(), deadline, trace.map(|t| t.2), samples);
            if let Some((tracer, root, tc, t_at)) = trace {
                tracer.record(Span {
                    id: tc.parent,
                    parent: root,
                    request: root,
                    kind: SpanKind::Attempt,
                    label: format!("replica{idx}"),
                    start_tick: now,
                    end_tick: clock.now(),
                    seconds: t_at.elapsed().as_secs_f64(),
                    detail: attempt,
                });
            }
            match result {
                Ok(resp) => {
                    state.completed.fetch_add(1, Ordering::Relaxed);
                    plock(&state.health).on_success();
                    return Ok(resp);
                }
                Err(e) => {
                    state.failed.fetch_add(1, Ordering::Relaxed);
                    if matches!(e, ServeError::EngineFault { .. }) {
                        self.counters.engine_faults.fetch_add(1, Ordering::Relaxed);
                        plock(&state.health).on_failure(clock.now());
                    } else if is_probe {
                        // A shed/expired probe judges nothing: clear the
                        // in-flight flag so the window can reopen.
                        plock(&state.health).abort_probe();
                    }
                    if !e.retryable() {
                        return Err(e);
                    }
                    last = Some(e);
                }
            }
        }
        Err(last.unwrap_or_else(|| ServeError::Overloaded {
            model: self.model.clone(),
            reason: "attempt budget exhausted".to_string(),
        }))
    }

    /// Replica choice at tick `now`: a quarantined replica whose probe
    /// window is open takes the request as its probe (health recovery
    /// rides on live traffic; `begin_probe` under the health mutex means
    /// concurrent callers consume the probe exactly once); otherwise the
    /// `Open` replica with the lowest [`DispatchPolicy`] score, ties to
    /// the lowest index. Replicas already `tried` by this request are
    /// deprioritised so a failover attempt actually moves — unless every
    /// open replica has been tried, in which case retrying one beats
    /// failing outright. `None` when every replica is gated.
    fn pick(
        &self,
        replicas: &[(ServerHandle, Arc<ReplicaState>)],
        now: u64,
        tried: &[bool],
    ) -> Option<(usize, bool)> {
        for (i, (_, state)) in replicas.iter().enumerate() {
            if tried[i] {
                continue;
            }
            let mut h = plock(&state.health);
            if h.gate(now) == Gate::ProbeDue {
                h.begin_probe();
                return Some((i, true));
            }
        }
        let policy = self.cfg.dispatch;
        let mut best: Option<(usize, f64)> = None;
        let mut best_untried = false;
        for (i, (handle, state)) in replicas.iter().enumerate() {
            if plock(&state.health).gate(now) != Gate::Open {
                continue;
            }
            let untried = !tried[i];
            // Unresolved routed attempts; saturating because a concurrent
            // resolution can land between the relaxed loads.
            let resolved = state.completed.load(Ordering::Relaxed)
                + state.failed.load(Ordering::Relaxed);
            let inflight = state
                .attempts
                .load(Ordering::Relaxed)
                .saturating_sub(resolved);
            let occupancy = if policy.occupancy_weight != 0.0 {
                handle.metrics.occupancy()
            } else {
                0.0
            };
            let score = policy.score(inflight, handle.inflight(), occupancy);
            let better = match (untried, best_untried) {
                (true, false) => true,
                (false, true) => false,
                _ => best.map_or(true, |(_, s)| score < s),
            };
            if better {
                best = Some((i, score));
                best_untried = untried;
            }
        }
        best.map(|(i, _)| (i, false))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::coordinator::{BatchFn, BatchPolicy, ServeConfig};
    use std::time::Duration;

    fn policy() -> BatchPolicy {
        BatchPolicy {
            capacity: 8,
            max_wait: Duration::from_millis(1),
            max_wait_ticks: None,
        }
    }

    fn scaled_sum_server(width: usize, scale: f32) -> ModelServer {
        let compute: BatchFn = Box::new(move |data: &[f32], w: usize| {
            let rows = data.len() / w;
            let mut phi = Vec::with_capacity(rows);
            let mut lphi = Vec::with_capacity(rows);
            for r in 0..rows {
                let s: f32 = data[r * w..(r + 1) * w].iter().sum();
                phi.push(s);
                lphi.push(scale * s);
            }
            Ok((phi, lphi))
        });
        ModelServer::spawn(width, policy(), compute)
    }

    fn failing_server(width: usize, msg: &'static str) -> ModelServer {
        let compute: BatchFn = Box::new(move |_, _| Err(anyhow!(msg)));
        ModelServer::spawn(width, policy(), compute)
    }

    #[test]
    fn routes_by_tag_and_counts_exactly() {
        let mut router = Router::new();
        router.register("double", scaled_sum_server(2, 2.0));
        router.register("triple", scaled_sum_server(3, 3.0));
        assert_eq!(router.models(), vec!["double", "triple"]);

        let d = router.eval_blocking("double", vec![1.0, 2.0]).unwrap();
        assert_eq!(d.lphi, vec![6.0]);
        let t = router.eval_blocking("triple", vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(t.lphi, vec![18.0]);
        let t2 = router.eval_blocking("triple", vec![0.0, 0.0, 1.0]).unwrap();
        assert_eq!(t2.lphi, vec![3.0]);

        let snap = router.snapshot();
        assert_eq!(snap[0].dispatched, 1);
        assert_eq!(snap[0].completed, 1);
        assert_eq!(snap[1].dispatched, 2);
        assert_eq!(snap[1].completed, 2);
        assert_eq!(snap[0].queue_depth, 0, "no request in flight");
        assert!(snap[1].peak_queue_depth >= 1);
        assert_eq!(snap[0].replicas.len(), 1);
        assert_eq!(snap[0].replicas[0].state, HealthState::Healthy);
        assert_eq!(snap[0].epoch, 1, "no scaling yet");
        assert!(router.eval_blocking("nope", vec![1.0]).is_err());
        router.shutdown();
    }

    #[test]
    #[should_panic(expected = "already has a model")]
    fn duplicate_names_rejected() {
        let mut router = Router::new();
        router.register("m", scaled_sum_server(1, 1.0));
        router.register("m", scaled_sum_server(1, 1.0));
    }

    #[test]
    fn clients_route_from_many_threads() {
        let mut router = Router::new();
        router.register("sum", scaled_sum_server(1, 2.0));
        let client = router.client("sum").unwrap();
        assert_eq!(client.width(), 1);
        let joins: Vec<_> = (0..6)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || {
                    let v = i as f32;
                    let resp = c.eval_blocking(vec![v]).unwrap();
                    assert_eq!(resp.lphi, vec![2.0 * v]);
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
        let snap = router.snapshot();
        assert_eq!(snap[0].dispatched, 6);
        assert_eq!(snap[0].completed, 6);
        assert_eq!(snap[0].queue_depth, 0);
        router.shutdown();
    }

    #[test]
    fn failures_counted_separately() {
        let mut router = Router::new();
        router.register("bad", failing_server(1, "backend exploded"));
        assert!(router.eval_blocking("bad", vec![1.0]).is_err());
        let snap = router.snapshot();
        assert_eq!((snap[0].dispatched, snap[0].completed, snap[0].failed), (1, 0, 1));
        assert_eq!(snap[0].engine_faults, 1);
        router.shutdown();
    }

    #[test]
    fn replica_width_mismatch_rejected() {
        let mut router = Router::new();
        router.register("m", scaled_sum_server(2, 1.0));
        let err = router.add_replica("m", scaled_sum_server(3, 1.0)).unwrap_err();
        assert!(err.to_string().contains("width"), "{err}");
        assert!(router.add_replica("ghost", scaled_sum_server(2, 1.0)).is_err());
        router.shutdown();
    }

    #[test]
    fn retry_fails_over_to_healthy_replica() {
        let mut router = Router::with_config(RouterConfig {
            retries: 1,
            ..RouterConfig::default()
        });
        router.register("m", failing_server(1, "replica 0 exploded"));
        router.add_replica("m", scaled_sum_server(1, 2.0)).unwrap();
        // Replica 0 is picked first (lowest index on equal score), faults,
        // and the retry lands on replica 1.
        let resp = router.eval_blocking("m", vec![3.0]).unwrap();
        assert_eq!(resp.lphi, vec![6.0]);
        let snap = router.snapshot();
        let m = &snap[0];
        assert_eq!((m.dispatched, m.completed, m.failed), (1, 1, 0));
        assert_eq!(m.retries, 1);
        assert_eq!(m.engine_faults, 1);
        assert_eq!(m.replicas[0].failed, 1);
        assert_eq!(m.replicas[1].completed, 1);
        router.shutdown();
    }

    #[test]
    fn idle_ties_break_to_lowest_index() {
        // Default dispatch weights on idle replicas reproduce classic
        // least-inflight with lowest-index ties: sequential traffic pins
        // to replica 0 and never wanders.
        let mut router = Router::new();
        router.register("m", scaled_sum_server(1, 2.0));
        router.add_replica("m", scaled_sum_server(1, 2.0)).unwrap();
        router.add_replica("m", scaled_sum_server(1, 2.0)).unwrap();
        let client = router.client("m").unwrap();
        for i in 0..4 {
            let resp = client.eval_blocking(vec![i as f32]).unwrap();
            assert_eq!(resp.lphi, vec![2.0 * i as f32]);
        }
        let snap = router.snapshot();
        assert_eq!(snap[0].replicas[0].completed, 4);
        assert_eq!(snap[0].replicas[1].attempts, 0);
        assert_eq!(snap[0].replicas[2].attempts, 0);
        router.shutdown();
    }

    #[test]
    fn occupancy_weight_steers_dispatch_away_from_busy_replica() {
        let mut router = Router::with_config(RouterConfig {
            dispatch: DispatchPolicy {
                inflight_weight: 0.0,
                queue_weight: 0.0,
                occupancy_weight: 1.0,
            },
            ..RouterConfig::default()
        });
        router.register("m", scaled_sum_server(1, 2.0));
        router.add_replica("m", scaled_sum_server(1, 2.0)).unwrap();
        // Seed the occupancy signal directly: replica 0 looks saturated
        // (4 shard-seconds per wall second), replica 1 light (1.0).
        router.models[0].replicas[0]
            .server
            .handle()
            .metrics
            .record_shards(&[2.0, 2.0], 1.0);
        router.models[0].replicas[1]
            .server
            .handle()
            .metrics
            .record_shards(&[0.5, 0.5], 1.0);
        let client = router.client("m").unwrap();
        let resp = client.eval_blocking(vec![3.0]).unwrap();
        assert_eq!(resp.lphi, vec![6.0]);
        let snap = router.snapshot();
        assert_eq!(snap[0].replicas[0].attempts, 0, "busy replica skipped");
        assert_eq!(snap[0].replicas[1].completed, 1);
        router.shutdown();
    }

    #[test]
    fn all_open_replicas_tried_falls_back_to_retry() {
        // A single replica that faults exactly once: attempt 1 marks it
        // tried, and with no untried replica left the retry must re-pick
        // it rather than fail outright.
        use std::sync::atomic::AtomicBool;
        let first = Arc::new(AtomicBool::new(true));
        let f = Arc::clone(&first);
        let compute: BatchFn = Box::new(move |data, _| {
            if f.swap(false, Ordering::SeqCst) {
                Err(anyhow!("transient fault"))
            } else {
                Ok((data.to_vec(), data.to_vec()))
            }
        });
        let mut router = Router::with_config(RouterConfig {
            retries: 1,
            ..RouterConfig::default()
        });
        router.register("m", ModelServer::spawn(1, policy(), compute));
        let resp = router.eval_blocking("m", vec![5.0]).unwrap();
        assert_eq!(resp.phi, vec![5.0]);
        let snap = router.snapshot();
        assert_eq!((snap[0].completed, snap[0].retries), (1, 1));
        assert_eq!(snap[0].replicas[0].attempts, 2, "same replica re-picked");
        router.shutdown();
    }

    #[test]
    fn probe_consumed_exactly_once_under_concurrent_callers() {
        let clock = TickClock::new();
        let mut router = Router::with_config(RouterConfig {
            retries: 1,
            clock: clock.clone(),
            health: HealthPolicy {
                degrade_after: 1,
                quarantine_after: 2,
                probe_after_ticks: 4,
                probe_successes: 1,
            },
            ..RouterConfig::default()
        });
        router.register("m", failing_server(1, "replica 0 is down"));
        router.add_replica("m", scaled_sum_server(1, 2.0)).unwrap();
        let client = router.client("m").unwrap();

        // Two failovers quarantine replica 0 (each request still succeeds
        // on replica 1 via the retry budget).
        for _ in 0..2 {
            assert!(client.eval_blocking(vec![1.0]).is_ok());
        }
        let snap = router.snapshot();
        assert_eq!(snap[0].replicas[0].state, HealthState::Quarantined);
        assert_eq!(snap[0].replicas[0].attempts, 2);

        // Open the probe window, then fire concurrent traffic: exactly one
        // request may consume the probe (begin_probe under the health
        // mutex); the rest see the window closed and go to replica 1. The
        // probe fails (backend still down) and re-quarantines with backoff,
        // so no second probe can slip in while the clock is frozen.
        clock.advance(5);
        let joins: Vec<_> = (0..8)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || {
                    let resp = c.eval_blocking(vec![i as f32]).unwrap();
                    assert_eq!(resp.lphi, vec![2.0 * i as f32]);
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
        let snap = router.snapshot();
        assert_eq!(
            snap[0].replicas[0].attempts,
            3,
            "probe consumed exactly once"
        );
        assert_eq!(snap[0].replicas[1].completed, 10);
        router.shutdown();
    }

    #[test]
    fn scale_up_is_visible_to_existing_clients() {
        // Quarantine the sole replica, then scale up through the factory:
        // a client created *before* the scale-up must route to the new
        // replica on its very next request (epoch-versioned replica list).
        let mut router = Router::with_config(RouterConfig {
            health: HealthPolicy {
                degrade_after: 1,
                quarantine_after: 1,
                probe_after_ticks: 1000,
                probe_successes: 1,
            },
            ..RouterConfig::default()
        });
        router.register("m", failing_server(1, "replica 0 is down"));
        router
            .set_replica_factory("m", Box::new(|| scaled_sum_server(1, 2.0)))
            .unwrap();
        let client = router.client("m").unwrap();
        assert_eq!(client.epoch(), 1);
        assert!(client.eval_blocking(vec![1.0]).is_err());

        assert_eq!(router.scale_up("m").unwrap(), 2);
        assert_eq!(router.replica_count("m"), Some(2));
        assert_eq!(client.epoch(), 2);
        let resp = client.eval_blocking(vec![3.0]).unwrap();
        assert_eq!(resp.lphi, vec![6.0]);
        let snap = router.snapshot();
        assert_eq!(snap[0].epoch, 2);
        assert_eq!(snap[0].replicas[1].completed, 1);
        router.shutdown();
    }

    #[test]
    fn retire_drops_highest_index_and_guards_the_last_replica() {
        let mut router = Router::new();
        router.register("m", scaled_sum_server(1, 2.0));
        router.add_replica("m", scaled_sum_server(1, 2.0)).unwrap();
        let client = router.client("m").unwrap();
        assert_eq!(client.epoch(), 2, "add_replica bumped the epoch");

        assert_eq!(router.retire_replica("m").unwrap(), 1);
        assert_eq!(router.replica_count("m"), Some(1));
        assert_eq!(client.epoch(), 3);
        // Traffic keeps flowing on the surviving replica.
        let resp = client.eval_blocking(vec![4.0]).unwrap();
        assert_eq!(resp.lphi, vec![8.0]);
        // The last replica cannot be retired.
        assert!(router.retire_replica("m").is_err());
        assert_eq!(router.replica_count("m"), Some(1));
        router.shutdown();
    }

    #[test]
    fn scale_up_requires_factory_and_matching_width() {
        let mut router = Router::new();
        router.register("m", scaled_sum_server(2, 1.0));
        let err = router.scale_up("m").unwrap_err();
        assert!(err.to_string().contains("factory"), "{err}");
        router
            .set_replica_factory("m", Box::new(|| scaled_sum_server(3, 1.0)))
            .unwrap();
        let err = router.scale_up("m").unwrap_err();
        assert!(err.to_string().contains("width"), "{err}");
        assert_eq!(router.replica_count("m"), Some(1));
        assert!(router.set_replica_factory("ghost", Box::new(|| scaled_sum_server(1, 1.0))).is_err());
        router.shutdown();
    }

    #[test]
    fn snapshot_server_field_aggregates_all_replicas() {
        // Replica 0 faults every request, replica 1 answers it on retry:
        // both replicas see every request, so the model-level `server`
        // metrics must be the cross-replica sum — not replica 0's alone.
        let mut router = Router::with_config(RouterConfig {
            retries: 1,
            ..RouterConfig::default()
        });
        router.register("m", failing_server(1, "replica 0 exploded"));
        router.add_replica("m", scaled_sum_server(1, 2.0)).unwrap();
        for i in 0..3 {
            assert!(router.eval_blocking("m", vec![i as f32]).is_ok());
        }
        let snap = router.snapshot();
        let m = &snap[0];
        let received_sum: u64 = m.replicas.iter().map(|r| r.server.received).sum();
        let requests_sum: u64 = m.replicas.iter().map(|r| r.server.requests).sum();
        let faults_sum: u64 = m.replicas.iter().map(|r| r.server.engine_faults).sum();
        assert_eq!(m.server.received, received_sum);
        assert_eq!(m.server.requests, requests_sum);
        assert_eq!(m.server.engine_faults, faults_sum);
        assert_eq!(m.server.received, 6, "both replicas saw all 3 requests");
        assert!(
            m.server.received > m.replicas[0].server.received,
            "aggregate is not replica 0's snapshot"
        );
        router.shutdown();
    }

    #[test]
    fn scaling_snapshot_resets_interval_peak() {
        let mut router = Router::new();
        router.register("m", scaled_sum_server(1, 2.0));
        for _ in 0..3 {
            assert!(router.eval_blocking("m", vec![1.0]).is_ok());
        }
        // Plain snapshot reads non-destructively.
        assert!(router.snapshot()[0].interval_peak_queue_depth >= 1);
        assert!(router.snapshot()[0].interval_peak_queue_depth >= 1);
        // The scaling snapshot consumes the interval peak...
        assert!(router.scaling_snapshot()[0].interval_peak_queue_depth >= 1);
        // ...so with no traffic since, the next interval is quiet.
        assert_eq!(router.scaling_snapshot()[0].interval_peak_queue_depth, 0);
        // Cumulative peak survives the resets.
        assert!(router.snapshot()[0].peak_queue_depth >= 1);
        router.shutdown();
    }

    #[test]
    fn engine_faults_quarantine_and_probe_readmits() {
        let clock = TickClock::new();
        let cfg = RouterConfig {
            retries: 0,
            clock: clock.clone(),
            health: HealthPolicy {
                degrade_after: 1,
                quarantine_after: 2,
                probe_after_ticks: 4,
                probe_successes: 1,
            },
            ..RouterConfig::default()
        };
        // A server that fails while `fail` is set, then recovers.
        use std::sync::atomic::AtomicBool;
        let fail = Arc::new(AtomicBool::new(true));
        let f = Arc::clone(&fail);
        let compute: BatchFn = Box::new(move |data, _| {
            if f.load(Ordering::SeqCst) {
                Err(anyhow!("transient fault"))
            } else {
                Ok((data.to_vec(), data.to_vec()))
            }
        });
        let mut router = Router::with_config(cfg);
        router.register("m", ModelServer::spawn(1, policy(), compute));
        let client = router.client("m").unwrap();

        // Two faults → quarantine.
        assert!(client.eval_blocking(vec![1.0]).is_err());
        assert!(client.eval_blocking(vec![1.0]).is_err());
        let snap = router.snapshot();
        assert_eq!(snap[0].replicas[0].state, HealthState::Quarantined);
        assert_eq!(snap[0].quarantine_events, 1);

        // Gated: no replica available while the window is closed.
        let err = client.eval_blocking(vec![1.0]).unwrap_err();
        assert!(matches!(err, ServeError::Overloaded { .. }), "{err}");

        // Window opens on the logical clock; the backend has recovered, so
        // the probe succeeds and re-admits the replica.
        fail.store(false, Ordering::SeqCst);
        clock.advance(10);
        let resp = client.eval_blocking(vec![7.0]).unwrap();
        assert_eq!(resp.phi, vec![7.0]);
        let snap = router.snapshot();
        assert_eq!(snap[0].replicas[0].state, HealthState::Healthy);
        router.shutdown();
    }

    #[test]
    fn router_deadline_expires_on_logical_clock() {
        let clock = TickClock::new();
        let mut router = Router::with_config(RouterConfig {
            deadline_ticks: Some(5),
            retries: 3,
            clock: clock.clone(),
            ..RouterConfig::default()
        });
        // A faulting replica whose batch consumes 100 logical ticks: the
        // first attempt faults, and by the pre-check of attempt 2 the
        // deadline (submit + 5) has long expired — so the request fails
        // with DeadlineExceeded instead of burning the retry budget.
        let c2 = clock.clone();
        let compute: BatchFn = Box::new(move |_, _| {
            c2.advance(100);
            Err(anyhow!("slow fault"))
        });
        router.register(
            "m",
            ModelServer::spawn_cfg(
                1,
                policy(),
                ServeConfig {
                    clock: clock.clone(),
                    ..ServeConfig::labeled("m")
                },
                compute,
            ),
        );
        let client = router.client("m").unwrap();
        let err = client.eval_blocking(vec![1.0]).unwrap_err();
        match &err {
            ServeError::DeadlineExceeded {
                deadline_tick,
                now_tick,
                ..
            } => {
                assert_eq!(*deadline_tick, 5);
                assert_eq!(*now_tick, 100);
            }
            other => panic!("expected DeadlineExceeded, got {other}"),
        }
        let snap = router.snapshot();
        assert_eq!((snap[0].failed, snap[0].deadline_expired), (1, 1));
        assert_eq!(snap[0].retries, 1, "only one retry attempted before expiry");
        assert_eq!(snap[0].engine_faults, 1);
        router.shutdown();
    }
}
