//! Multi-model serving router: one front door over per-model
//! [`ModelServer`] workers.
//!
//! `ModelServer` instances already compose — each owns its worker thread,
//! batcher, and metrics — but before the router every client had to hold
//! the right `ServerHandle` itself. The router closes that gap for
//! multi-model traffic (the ROADMAP serving follow-up):
//!
//! * **Registration** — each model (DOF / Hessian-baseline / jet engines
//!   mixed, or an XLA artifact worker) is registered once under a name;
//!   widths may differ per model.
//! * **Tagged dispatch** — a request names its model;
//!   [`RouterClient::eval_blocking`] routes it to that model's worker and
//!   blocks for the response. Routing adds counters only — the bytes flow
//!   through the same `ServerHandle` path as a direct caller, so routed
//!   results are **bitwise identical** to direct engine calls (asserted by
//!   `rust/tests/router_serving.rs`).
//! * **Autoscaling signals** — per-model [`RouterModelSnapshot`]s expose
//!   exact dispatch/completion counters, the instantaneous and peak
//!   **queue depth** (requests currently inside the worker, i.e. queued or
//!   executing), and the underlying server metrics including
//!   `parallel_occupancy` — the two numbers an autoscaler needs to decide
//!   when a model wants more shards or another replica.
//! * **Draining shutdown** — [`Router::shutdown`] stops every worker via
//!   its graceful path: partial batches are flushed and every in-flight
//!   request receives its response before the worker exits.
//!
//! Concurrency model: the router itself is registration-then-read-only;
//! clients obtain a cheap [`RouterClient`] per model (cloneable, `Send`)
//! and submit from as many threads as they like — all counters are
//! atomics.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::metrics::MetricsSnapshot;
use super::server::{ModelServer, ServerHandle};
use super::EvalResponse;

/// Per-model routing counters (shared between the router and its clients).
#[derive(Default)]
struct Counters {
    /// Requests routed to the model (== completed + failed + in flight).
    dispatched: AtomicU64,
    /// Requests answered successfully.
    completed: AtomicU64,
    /// Requests answered with an error.
    failed: AtomicU64,
    /// Requests currently inside the worker (queued or executing).
    queue_depth: AtomicUsize,
    /// High-water mark of `queue_depth`.
    peak_queue_depth: AtomicUsize,
}

struct Entry {
    name: String,
    server: ModelServer,
    counters: Arc<Counters>,
}

/// The multi-model front door (see module docs).
#[derive(Default)]
pub struct Router {
    models: Vec<Entry>,
}

/// A client for one registered model: routes requests and maintains the
/// model's queue-depth and dispatch counters. Cloneable and `Send` — hand
/// one clone per client thread.
#[derive(Clone)]
pub struct RouterClient {
    model: String,
    handle: ServerHandle,
    counters: Arc<Counters>,
}

/// Point-in-time routing metrics for one model.
#[derive(Debug, Clone)]
pub struct RouterModelSnapshot {
    pub model: String,
    /// Requests routed to this model.
    pub dispatched: u64,
    /// Requests answered successfully.
    pub completed: u64,
    /// Requests answered with an error.
    pub failed: u64,
    /// Requests currently inside the worker (queued or executing).
    pub queue_depth: usize,
    /// High-water mark of `queue_depth` since registration.
    pub peak_queue_depth: usize,
    /// The model server's own metrics (latency, batching efficiency,
    /// shards, `parallel_occupancy`).
    pub server: MetricsSnapshot,
}

impl Router {
    pub fn new() -> Self {
        Self { models: Vec::new() }
    }

    /// Register a model server under `name`. Panics on a duplicate name
    /// (two workers answering one tag would split the metrics and make
    /// routing ambiguous).
    pub fn register(&mut self, name: &str, server: ModelServer) {
        assert!(
            self.models.iter().all(|e| e.name != name),
            "router already has a model named {name:?}"
        );
        self.models.push(Entry {
            name: name.to_string(),
            server,
            counters: Arc::new(Counters::default()),
        });
    }

    /// Registered model names, in registration order.
    pub fn models(&self) -> Vec<&str> {
        self.models.iter().map(|e| e.name.as_str()).collect()
    }

    /// A routing client for `model` (error on an unknown tag).
    pub fn client(&self, model: &str) -> Result<RouterClient> {
        let entry = self
            .models
            .iter()
            .find(|e| e.name == model)
            .ok_or_else(|| anyhow!("router has no model named {model:?}"))?;
        Ok(RouterClient {
            model: entry.name.clone(),
            handle: entry.server.handle(),
            counters: Arc::clone(&entry.counters),
        })
    }

    /// Route one request to `model` and block for the response.
    pub fn eval_blocking(&self, model: &str, points: Vec<f32>) -> Result<EvalResponse> {
        self.client(model)?.eval_blocking(points)
    }

    /// Routing + server metrics for every model, in registration order.
    pub fn snapshot(&self) -> Vec<RouterModelSnapshot> {
        self.models
            .iter()
            .map(|e| RouterModelSnapshot {
                model: e.name.clone(),
                dispatched: e.counters.dispatched.load(Ordering::Relaxed),
                completed: e.counters.completed.load(Ordering::Relaxed),
                failed: e.counters.failed.load(Ordering::Relaxed),
                queue_depth: e.counters.queue_depth.load(Ordering::Relaxed),
                peak_queue_depth: e.counters.peak_queue_depth.load(Ordering::Relaxed),
                server: e.server.handle().metrics.snapshot(),
            })
            .collect()
    }

    /// Graceful stop: every worker flushes its partial batch and answers
    /// all in-flight requests before exiting (no request is lost; asserted
    /// by `rust/tests/router_serving.rs`).
    pub fn shutdown(self) {
        for e in self.models {
            e.server.shutdown();
        }
    }
}

impl RouterClient {
    /// The model this client routes to.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Row width (input dimension) the model expects.
    pub fn width(&self) -> usize {
        self.handle.width()
    }

    /// Route one request and block for the response, maintaining the
    /// model's dispatch and queue-depth counters exactly (one dispatched
    /// per call; depth incremented for the duration of the round trip).
    pub fn eval_blocking(&self, points: Vec<f32>) -> Result<EvalResponse> {
        let c = &*self.counters;
        c.dispatched.fetch_add(1, Ordering::Relaxed);
        let depth = c.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        c.peak_queue_depth.fetch_max(depth, Ordering::Relaxed);
        let out = self.handle.eval_blocking(points);
        // Outcome before depth: a snapshot must never observe a request
        // missing from dispatched == completed + failed + queue_depth.
        match &out {
            Ok(_) => c.completed.fetch_add(1, Ordering::Relaxed),
            Err(_) => c.failed.fetch_add(1, Ordering::Relaxed),
        };
        c.queue_depth.fetch_sub(1, Ordering::Relaxed);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatchFn, BatchPolicy};
    use std::time::Duration;

    fn scaled_sum_server(width: usize, scale: f32) -> ModelServer {
        let compute: BatchFn = Box::new(move |data: &[f32], w: usize| {
            let rows = data.len() / w;
            let mut phi = Vec::with_capacity(rows);
            let mut lphi = Vec::with_capacity(rows);
            for r in 0..rows {
                let s: f32 = data[r * w..(r + 1) * w].iter().sum();
                phi.push(s);
                lphi.push(scale * s);
            }
            Ok((phi, lphi))
        });
        ModelServer::spawn(
            width,
            BatchPolicy {
                capacity: 8,
                max_wait: Duration::from_millis(1),
            },
            compute,
        )
    }

    #[test]
    fn routes_by_tag_and_counts_exactly() {
        let mut router = Router::new();
        router.register("double", scaled_sum_server(2, 2.0));
        router.register("triple", scaled_sum_server(3, 3.0));
        assert_eq!(router.models(), vec!["double", "triple"]);

        let d = router.eval_blocking("double", vec![1.0, 2.0]).unwrap();
        assert_eq!(d.lphi, vec![6.0]);
        let t = router.eval_blocking("triple", vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(t.lphi, vec![18.0]);
        let t2 = router.eval_blocking("triple", vec![0.0, 0.0, 1.0]).unwrap();
        assert_eq!(t2.lphi, vec![3.0]);

        let snap = router.snapshot();
        assert_eq!(snap[0].dispatched, 1);
        assert_eq!(snap[0].completed, 1);
        assert_eq!(snap[1].dispatched, 2);
        assert_eq!(snap[1].completed, 2);
        assert_eq!(snap[0].queue_depth, 0, "no request in flight");
        assert!(snap[1].peak_queue_depth >= 1);
        assert!(router.eval_blocking("nope", vec![1.0]).is_err());
        router.shutdown();
    }

    #[test]
    #[should_panic(expected = "already has a model")]
    fn duplicate_names_rejected() {
        let mut router = Router::new();
        router.register("m", scaled_sum_server(1, 1.0));
        router.register("m", scaled_sum_server(1, 1.0));
    }

    #[test]
    fn clients_route_from_many_threads() {
        let mut router = Router::new();
        router.register("sum", scaled_sum_server(1, 2.0));
        let client = router.client("sum").unwrap();
        assert_eq!(client.width(), 1);
        let joins: Vec<_> = (0..6)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || {
                    let v = i as f32;
                    let resp = c.eval_blocking(vec![v]).unwrap();
                    assert_eq!(resp.lphi, vec![2.0 * v]);
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
        let snap = router.snapshot();
        assert_eq!(snap[0].dispatched, 6);
        assert_eq!(snap[0].completed, 6);
        assert_eq!(snap[0].queue_depth, 0);
        router.shutdown();
    }

    #[test]
    fn failures_counted_separately() {
        let failing: BatchFn = Box::new(|_, _| Err(anyhow!("backend exploded")));
        let mut router = Router::new();
        router.register(
            "bad",
            ModelServer::spawn(
                1,
                BatchPolicy {
                    capacity: 2,
                    max_wait: Duration::from_millis(1),
                },
                failing,
            ),
        );
        assert!(router.eval_blocking("bad", vec![1.0]).is_err());
        let snap = router.snapshot();
        assert_eq!((snap[0].dispatched, snap[0].completed, snap[0].failed), (1, 0, 1));
        router.shutdown();
    }
}
