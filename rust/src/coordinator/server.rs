//! Model server: a worker thread that owns the compute (PJRT executable or
//! a Rust-engine closure), batches incoming requests, and routes results.
//!
//! PJRT handles are **not** `Send`, so the XLA executor is constructed
//! *inside* its worker thread; only the request channel crosses threads.
//!
//! ## Fault boundary
//!
//! The handle side is the serving **front door**: requests are validated
//! (shape + finiteness, [`ServeError::InvalidRequest`]) and pass admission
//! control (bounded in-flight cap, [`ServeError::Overloaded`]) before
//! anything is enqueued. The worker side checks logical-tick deadlines at
//! dequeue ([`ServeError::DeadlineExceeded`]), wraps every batch compute
//! in `catch_unwind` (a panicking batch fails its member requests with
//! [`ServeError::EngineFault`] — payload and pool shard context preserved
//! — while the worker thread survives), and withholds non-finite outputs
//! at the boundary so a NaN produced inside an engine can never reach a
//! client as a "successful" response. The optional seeded
//! [`FaultInjector`] hook drives all of these paths deterministically in
//! `rust/tests/fault_injection.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use std::collections::HashMap;

use crate::autodiff::arena::{with_program_slab, SlabKey};
use crate::autodiff::{DofEngine, HessianEngine};
use crate::graph::Graph;
use crate::jet::{self, JetEngine, StochasticJetEngine};
use crate::obs::{Span, SpanKind, TraceContext, Tracer};
use crate::parallel::{split_rows, Pool};
use crate::plan;
use crate::plan::hessian::global_hessian_cache;
use crate::tensor::ops::first_non_finite_f32;
use crate::tensor::Tensor;

use super::batcher::{BatchPolicy, Batcher, CutBatch};
use super::fault::{FaultInjector, ServeError, TickClock};
use super::metrics::Metrics;
use super::{EvalRequest, EvalResponse};

/// Batch compute signature: padded flat batch + width → `(phi, lphi)` flat
/// over the full padded batch.
pub type BatchFn = Box<dyn FnMut(&[f32], usize) -> Result<(Vec<f32>, Vec<f32>)> + Send>;

type RespTx = mpsc::Sender<Result<EvalResponse, ServeError>>;

/// Per-request payload the handle ships alongside the [`EvalRequest`]:
/// the response channel plus queue-wait provenance (captured at enqueue,
/// on the submitting thread) and the optional trace identity. The batcher
/// clones it per fragment, so every cut member can account its own wait.
#[derive(Clone)]
struct ReqTag {
    tx: RespTx,
    enqueued: Instant,
    enqueue_tick: u64,
    trace: Option<TraceContext>,
}

enum Msg {
    Eval(EvalRequest, ReqTag),
    Shutdown,
}

/// Trace identity of one in-flight batch execution, handed to the compute
/// closure so backend shards can parent their spans under the batch's
/// pre-allocated `execute` span id.
pub(crate) struct ExecTrace {
    pub(crate) tracer: Arc<Tracer>,
    pub(crate) request: u64,
    /// Parent (`batch_form`) span id of the execute span.
    pub(crate) parent: u64,
    /// Pre-allocated `execute` span id (recorded after compute returns).
    pub(crate) execute: u64,
    /// Control-plane tick at batch formation.
    pub(crate) tick: u64,
}

/// Robustness knobs for one [`ModelServer`] (the PR 5 spawn signatures are
/// preserved and use [`ServeConfig::default`]).
#[derive(Clone)]
pub struct ServeConfig {
    /// Max requests in flight (admitted, not yet answered) before the
    /// front door sheds with [`ServeError::Overloaded`]. `0` = unbounded.
    pub queue_cap: usize,
    /// Logical clock for deadline checks. Share one clock with the router
    /// (and advance it from the traffic driver) when using deadlines —
    /// a never-advanced clock simply never expires anything.
    pub clock: TickClock,
    /// Model label stamped into every [`ServeError`] this server emits.
    pub label: String,
    /// Deterministic fault injection (test/harness hook; `None` in
    /// production).
    pub injector: Option<Arc<FaultInjector>>,
    /// Span sink for request tracing. `None` (the default) keeps the
    /// serving path span-free; tracing is bitwise-invisible either way.
    pub tracer: Option<Arc<Tracer>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            queue_cap: 0,
            clock: TickClock::new(),
            label: "model".to_string(),
            injector: None,
            tracer: None,
        }
    }
}

impl ServeConfig {
    /// Default config with a model label.
    pub fn labeled(label: &str) -> Self {
        Self {
            label: label.to_string(),
            ..Self::default()
        }
    }
}

/// Bounded in-flight gate (0 = unbounded). Shared between the handle
/// (admission) and the worker (artificial queue-pressure injection).
#[derive(Debug)]
struct Admission {
    cap: usize,
    inflight: AtomicUsize,
}

impl Admission {
    fn new(cap: usize) -> Self {
        Self {
            cap,
            inflight: AtomicUsize::new(0),
        }
    }

    /// Take one slot; `Err(depth)` when the gate is at cap.
    fn try_enter(&self) -> std::result::Result<usize, usize> {
        let mut cur = self.inflight.load(Ordering::Relaxed);
        loop {
            if self.cap != 0 && cur >= self.cap {
                return Err(cur);
            }
            match self.inflight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Ok(cur + 1),
                Err(seen) => cur = seen,
            }
        }
    }

    fn leave(&self) {
        self.inflight.fetch_sub(1, Ordering::AcqRel);
    }

    /// Artificial queue pressure (fault injection): hold `n` slots.
    fn occupy(&self, n: usize) {
        self.inflight.fetch_add(n, Ordering::AcqRel);
    }

    fn release(&self, n: usize) {
        self.inflight.fetch_sub(n, Ordering::AcqRel);
    }

    fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }
}

/// Handle for submitting requests to a running [`ModelServer`].
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
    width: usize,
    pub metrics: Arc<Metrics>,
    admission: Arc<Admission>,
    clock: TickClock,
    label: Arc<str>,
}

impl ServerHandle {
    /// Row width (model input dimension) this server expects.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Requests currently admitted and unanswered (includes injected
    /// occupancy). The router's least-depth replica pick reads this.
    pub fn inflight(&self) -> usize {
        self.admission.inflight()
    }

    /// The server's logical clock.
    pub fn clock(&self) -> &TickClock {
        &self.clock
    }

    /// Submit a request with no deadline; blocks until the response is
    /// ready.
    pub fn eval_blocking(&self, points: Vec<f32>) -> std::result::Result<EvalResponse, ServeError> {
        self.eval_with_deadline(points, None)
    }

    /// Submit a request with an optional absolute logical-tick deadline;
    /// blocks until the response is ready. The front door validates and
    /// admits (or sheds) *before* enqueueing; requests larger than the
    /// batch capacity are split and reassembled here.
    pub fn eval_with_deadline(
        &self,
        points: Vec<f32>,
        deadline_tick: Option<u64>,
    ) -> std::result::Result<EvalResponse, ServeError> {
        self.eval_with_deadline_traced(points, deadline_tick, None)
    }

    /// Submit a request with a per-request **sample-count override**
    /// (stochastic/STDE backends only — other backends ignore it): the
    /// batcher never mixes different `samples` values in one cut, and the
    /// stochastic worker runs the whole cut at this count. `None` = the
    /// backend's spawn-time default; `Some(0)` is rejected as invalid.
    pub fn eval_with_samples(
        &self,
        points: Vec<f32>,
        samples: Option<u32>,
    ) -> std::result::Result<EvalResponse, ServeError> {
        self.eval_opts(points, None, None, samples)
    }

    /// [`Self::eval_with_deadline`] carrying a [`TraceContext`]: spans for
    /// this request's queue wait, batch formation, execution, and shards
    /// are recorded under `trace.parent` (a no-op when the server has no
    /// tracer). Tracing changes no computed value.
    pub fn eval_with_deadline_traced(
        &self,
        points: Vec<f32>,
        deadline_tick: Option<u64>,
        trace: Option<TraceContext>,
    ) -> std::result::Result<EvalResponse, ServeError> {
        self.eval_opts(points, deadline_tick, trace, None)
    }

    /// The full submit path: deadline + trace + sample-count override in
    /// one call (every other `eval_*` method delegates here).
    pub fn eval_opts(
        &self,
        points: Vec<f32>,
        deadline_tick: Option<u64>,
        trace: Option<TraceContext>,
        samples: Option<u32>,
    ) -> std::result::Result<EvalResponse, ServeError> {
        // Front door: structured validation instead of the legacy asserts.
        if samples == Some(0) {
            self.metrics.record_invalid();
            return Err(ServeError::InvalidRequest {
                reason: "sample-count override must be ≥ 1".to_string(),
            });
        }
        if self.width == 0 || points.is_empty() || points.len() % self.width != 0 {
            self.metrics.record_invalid();
            return Err(ServeError::InvalidRequest {
                reason: format!(
                    "ragged request: {} values is not a positive multiple of width {}",
                    points.len(),
                    self.width
                ),
            });
        }
        if let Some(i) = first_non_finite_f32(&points) {
            self.metrics.record_invalid();
            return Err(ServeError::InvalidRequest {
                reason: format!(
                    "non-finite input at row {}, column {}: {}",
                    i / self.width,
                    i % self.width,
                    points[i]
                ),
            });
        }
        // Admission control: bounded in-flight requests.
        if let Err(depth) = self.admission.try_enter() {
            self.metrics.record_shed();
            return Err(ServeError::Overloaded {
                model: self.label.to_string(),
                reason: format!("queue depth {depth} at cap {}", self.admission.cap),
            });
        }
        self.metrics.record_accepted();
        let out = self.eval_admitted(points, deadline_tick, trace, samples);
        self.admission.leave();
        out
    }

    fn eval_admitted(
        &self,
        points: Vec<f32>,
        deadline_tick: Option<u64>,
        trace: Option<TraceContext>,
        samples: Option<u32>,
    ) -> std::result::Result<EvalResponse, ServeError> {
        let rows = points.len() / self.width;
        let req = EvalRequest {
            points,
            rows,
            width: self.width,
            deadline_tick,
            samples,
        };
        let t0 = Instant::now();
        let (rtx, rrx) = mpsc::channel();
        let tag = ReqTag {
            tx: rtx,
            enqueued: t0,
            enqueue_tick: self.clock.now(),
            trace,
        };
        self.tx
            .send(Msg::Eval(req, tag))
            .map_err(|_| self.stopped())?;
        let mut phi = Vec::with_capacity(rows);
        let mut lphi = Vec::with_capacity(rows);
        while phi.len() < rows {
            let part = rrx.recv().map_err(|_| self.stopped())??;
            phi.extend(part.phi);
            lphi.extend(part.lphi);
        }
        self.metrics.record_request(rows, t0.elapsed().as_secs_f64());
        Ok(EvalResponse { phi, lphi })
    }

    /// A dead worker is a retryable engine fault: failover to another
    /// replica is exactly the right response.
    fn stopped(&self) -> ServeError {
        ServeError::EngineFault {
            model: self.label.to_string(),
            shard: None,
            payload: "server stopped".to_string(),
        }
    }
}

/// Worker-side context shared by every batch.
struct WorkerCtx {
    width: usize,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    clock: TickClock,
    injector: Option<Arc<FaultInjector>>,
    admission: Arc<Admission>,
    label: Arc<str>,
    tracer: Option<Arc<Tracer>>,
}

/// The worker event loop — runs on the worker thread; `compute` need not
/// be `Send` because it never leaves this thread.
///
/// `compute` receives `(padded_data, width, rows_used, samples)`:
/// fixed-shape backends (XLA artifacts) consume the whole padded buffer,
/// while shape-flexible backends may compute only the first `rows_used`
/// rows — response routing reads nothing past them. `samples` is the
/// cut's sample-count group (stochastic backends honor it; all others
/// ignore it).
fn worker_loop<F>(rx: mpsc::Receiver<Msg>, ctx: WorkerCtx, mut compute: F)
where
    F: FnMut(&[f32], usize, usize, Option<u32>, Option<&ExecTrace>) -> Result<(Vec<f32>, Vec<f32>)>,
{
    let width = ctx.width;
    let mut batcher: Batcher<ReqTag> = Batcher::new(width, ctx.policy);
    // Legacy wall-clock wait (`policy.max_wait_ticks == None`): the batcher
    // never reads wall time, so the worker tracks the oldest pending row's
    // enqueue time on its side of the channel. Under the tick policy the
    // batcher itself owns the deadline and this stays `None`.
    let mut oldest_wall: Option<Instant> = None;
    // Runs one cut and returns its buffer so the caller can recycle it
    // back into the batcher (two-buffer swap — no per-cut allocation).
    let run_batch = |cut: CutBatch<ReqTag>, compute: &mut F| -> Vec<f32> {
        let cut_tick = ctx.clock.now();
        // Queue-wait accounting: the split latency metric fires for every
        // member; spans only for traced ones (and only when this server
        // has a tracer).
        for m in &cut.members {
            let wait_s = m.tag.enqueued.elapsed().as_secs_f64();
            ctx.metrics.record_queue_wait(wait_s);
            if let (Some(tracer), Some(tc)) = (&ctx.tracer, m.tag.trace) {
                tracer.record(Span {
                    id: tracer.next_id(),
                    parent: tc.parent,
                    request: tc.request,
                    kind: SpanKind::QueueWait,
                    label: ctx.label.to_string(),
                    start_tick: m.tag.enqueue_tick,
                    end_tick: cut_tick,
                    seconds: wait_s,
                    detail: m.span.1 as u64,
                });
            }
        }
        // Batch-level spans attach to the first traced member's tree; the
        // execute span id is allocated *before* compute so backend shards
        // can parent under it.
        let first_trace = cut.members.iter().find_map(|m| m.tag.trace);
        let exec_trace = match (&ctx.tracer, first_trace) {
            (Some(tracer), Some(tc)) => {
                let form_id = tracer.next_id();
                tracer.record(Span {
                    id: form_id,
                    parent: tc.parent,
                    request: tc.request,
                    kind: SpanKind::BatchForm,
                    label: ctx.label.to_string(),
                    start_tick: cut_tick,
                    end_tick: cut_tick,
                    seconds: 0.0,
                    detail: cut.rows_used as u64,
                });
                Some(ExecTrace {
                    tracer: Arc::clone(tracer),
                    request: tc.request,
                    parent: form_id,
                    execute: tracer.next_id(),
                    tick: cut_tick,
                })
            }
            _ => None,
        };
        let plan = match &ctx.injector {
            Some(inj) => inj.next(),
            None => super::fault::FaultPlan::default(),
        };
        if plan.occupy_slots > 0 {
            ctx.admission.occupy(plan.occupy_slots);
        }
        if plan.latency_ticks > 0 {
            // Injected latency is *logical*: the batch consumes ticks, so
            // queued requests behind it can expire deterministically.
            ctx.clock.advance(plan.latency_ticks);
        }
        let exec_start_tick = ctx.clock.now();
        let t0 = Instant::now();
        // Panic isolation: a panicking engine (or injected panic) fails
        // this batch's requests with EngineFault; the worker — and every
        // other request — survives. The pool already contains shard panics
        // and re-raises them with shard context, which lands in `payload`.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if plan.panic {
                panic!("injected panic (fault injection)");
            }
            compute(&cut.data, width, cut.rows_used, cut.samples, exec_trace.as_ref())
        }));
        let exec_s = t0.elapsed().as_secs_f64();
        ctx.metrics.record_batch(cut.rows_used, cut.padded_rows(width), exec_s);
        if let Some(et) = &exec_trace {
            et.tracer.record(Span {
                id: et.execute,
                parent: et.parent,
                request: et.request,
                kind: SpanKind::Execute,
                label: ctx.label.to_string(),
                start_tick: exec_start_tick,
                end_tick: ctx.clock.now(),
                seconds: exec_s,
                detail: cut.rows_used as u64,
            });
        }
        if plan.occupy_slots > 0 {
            ctx.admission.release(plan.occupy_slots);
        }
        let result = match result {
            Ok(computed) => computed.map_err(|e| {
                ServeError::engine_fault(&ctx.label, format!("batch compute failed: {e:#}"))
            }),
            Err(payload) => Err(ServeError::engine_fault(
                &ctx.label,
                crate::util::panic_message(payload),
            )),
        };
        // Output gate: a non-finite value in the used rows (engine bug or
        // injected poison) must fail loudly, never flow to a client.
        let result = result.and_then(|(phi, mut lphi)| {
            if plan.nan_output {
                if let Some(v) = lphi.first_mut() {
                    *v = f32::NAN;
                }
            }
            let used_phi = cut.rows_used.min(phi.len());
            let used_lphi = cut.rows_used.min(lphi.len());
            if first_non_finite_f32(&phi[..used_phi]).is_some()
                || first_non_finite_f32(&lphi[..used_lphi]).is_some()
            {
                return Err(ServeError::engine_fault(
                    &ctx.label,
                    "non-finite engine output (batch withheld at the boundary)".to_string(),
                ));
            }
            Ok((phi, lphi))
        });
        match result {
            Ok((phi, lphi)) => {
                for m in cut.members {
                    let (start, rows) = m.span;
                    let _ = m.tag.tx.send(Ok(EvalResponse {
                        phi: phi[start..start + rows].to_vec(),
                        lphi: lphi[start..start + rows].to_vec(),
                    }));
                }
            }
            Err(e) => {
                ctx.metrics.record_engine_fault();
                for m in cut.members {
                    let _ = m.tag.tx.send(Err(e.clone()));
                }
            }
        }
        cut.data
    };
    loop {
        match rx.recv_timeout(ctx.policy.max_wait) {
            Ok(Msg::Eval(req, tag)) => {
                ctx.metrics.record_received();
                // Deadline check at dequeue: an expired request is
                // answered immediately instead of entering a batch.
                if let Some(dt) = req.deadline_tick {
                    let now = ctx.clock.now();
                    if now >= dt {
                        ctx.metrics.record_deadline_expired();
                        let _ = tag.tx.send(Err(ServeError::DeadlineExceeded {
                            model: ctx.label.to_string(),
                            deadline_tick: dt,
                            now_tick: now,
                        }));
                        continue;
                    }
                }
                let cuts = batcher.push(req, ctx.clock.now(), |_frag| tag.clone());
                let had_cuts = !cuts.is_empty();
                for cut in cuts {
                    let used = cut.rows_used;
                    let buf = run_batch(cut, &mut compute);
                    batcher.recycle(buf, used);
                }
                // Tick-mode starvation guard: a steady arrival stream must
                // not carry a partial batch past its tick deadline (the
                // legacy wall policy always returns false here).
                if batcher.deadline_expired(ctx.clock.now()) {
                    let cut = batcher.cut();
                    let used = cut.rows_used;
                    let buf = run_batch(cut, &mut compute);
                    batcher.recycle(buf, used);
                    oldest_wall = None;
                } else if batcher.is_empty() {
                    oldest_wall = None;
                } else if had_cuts || oldest_wall.is_none() {
                    // The oldest remaining row arrived during this push.
                    oldest_wall = Some(Instant::now());
                }
            }
            Ok(Msg::Shutdown) => {
                if !batcher.is_empty() {
                    let _ = run_batch(batcher.cut(), &mut compute);
                }
                break;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let tick_due = batcher.deadline_expired(ctx.clock.now());
                let wall_due = ctx.policy.max_wait_ticks.is_none()
                    && !batcher.is_empty()
                    && oldest_wall.is_some_and(|t| t.elapsed() >= ctx.policy.max_wait);
                if tick_due || wall_due {
                    let cut = batcher.cut();
                    let used = cut.rows_used;
                    let buf = run_batch(cut, &mut compute);
                    batcher.recycle(buf, used);
                    oldest_wall = None;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                if !batcher.is_empty() {
                    let _ = run_batch(batcher.cut(), &mut compute);
                }
                break;
            }
        }
    }
}

/// A running worker.
pub struct ModelServer {
    handle: ServerHandle,
    join: Option<JoinHandle<()>>,
    tx: mpsc::Sender<Msg>,
}

impl ModelServer {
    /// Shared wiring: channel, worker thread around [`worker_loop`], handle.
    fn spawn_with<F>(
        width: usize,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
        cfg: ServeConfig,
        compute: F,
    ) -> Self
    where
        F: FnMut(&[f32], usize, usize, Option<u32>, Option<&ExecTrace>) -> Result<(Vec<f32>, Vec<f32>)>
            + Send
            + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let admission = Arc::new(Admission::new(cfg.queue_cap));
        let label: Arc<str> = Arc::from(cfg.label.as_str());
        let ctx = WorkerCtx {
            width,
            policy,
            metrics: Arc::clone(&metrics),
            clock: cfg.clock.clone(),
            injector: cfg.injector,
            admission: Arc::clone(&admission),
            label: Arc::clone(&label),
            tracer: cfg.tracer.clone(),
        };
        let join = std::thread::spawn(move || {
            worker_loop(rx, ctx, compute);
        });
        let handle = ServerHandle {
            tx: tx.clone(),
            width,
            metrics,
            admission,
            clock: cfg.clock,
            label,
        };
        Self {
            handle,
            join: Some(join),
            tx,
        }
    }

    /// Spawn a worker around an arbitrary (Send) batch compute.
    pub fn spawn(width: usize, policy: BatchPolicy, compute: BatchFn) -> Self {
        Self::spawn_cfg(width, policy, ServeConfig::default(), compute)
    }

    /// [`Self::spawn`] with robustness knobs.
    pub fn spawn_cfg(
        width: usize,
        policy: BatchPolicy,
        cfg: ServeConfig,
        compute: BatchFn,
    ) -> Self {
        let mut compute = compute;
        Self::spawn_with(
            width,
            policy,
            Arc::new(Metrics::new()),
            cfg,
            move |data, w, _rows, _samples, _trace| compute(data, w),
        )
    }

    /// Spawn a worker whose batches are **row-sharded across a thread
    /// pool**: each cut batch is split into `shard_rows`-row chunks, `inner`
    /// runs per chunk on the pool's workers, and the chunk outputs are
    /// reassembled in shard order (same determinism contract as the
    /// engines' `compute_sharded`). Per-shard compute seconds land in the
    /// server's [`Metrics`] (`shards` / `parallel_occupancy`).
    pub fn spawn_sharded<F>(
        width: usize,
        policy: BatchPolicy,
        pool: Pool,
        shard_rows: usize,
        inner: F,
    ) -> Self
    where
        F: Fn(&[f32], usize) -> Result<(Vec<f32>, Vec<f32>)> + Send + Sync + 'static,
    {
        Self::spawn_sharded_cfg(width, policy, pool, shard_rows, ServeConfig::default(), inner)
    }

    /// [`Self::spawn_sharded`] with robustness knobs. The serve label also
    /// names the pool region, so a shard panic's re-raised payload carries
    /// `pool region "<label>" shard i (rows s..e)` context into the
    /// resulting [`ServeError::EngineFault`].
    pub fn spawn_sharded_cfg<F>(
        width: usize,
        policy: BatchPolicy,
        pool: Pool,
        shard_rows: usize,
        cfg: ServeConfig,
        inner: F,
    ) -> Self
    where
        F: Fn(&[f32], usize) -> Result<(Vec<f32>, Vec<f32>)> + Send + Sync + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let shard_metrics = Arc::clone(&metrics);
        let region_label = cfg.label.clone();
        let compute = move |data: &[f32],
                            w: usize,
                            rows_used: usize,
                            _samples: Option<u32>,
                            trace: Option<&ExecTrace>|
              -> Result<(Vec<f32>, Vec<f32>)> {
            // The Rust engines have no fixed-batch constraint, so padding
            // rows (zeros nobody reads) are skipped entirely.
            let rows = rows_used.min(data.len() / w);
            let ranges = split_rows(rows, shard_rows.max(1));
            let t0 = Instant::now();
            let shard_out = pool.run_sharded_labeled(&region_label, ranges, |_, r| {
                let ts = Instant::now();
                let res = inner(&data[r.start * w..r.end * w], w);
                (res, ts.elapsed().as_secs_f64())
            });
            let mut phi = Vec::with_capacity(rows);
            let mut lphi = Vec::with_capacity(rows);
            let mut shard_secs = Vec::with_capacity(shard_out.len());
            for (i, (res, secs)) in shard_out.into_iter().enumerate() {
                let (p, l) = res?;
                phi.extend(p);
                lphi.extend(l);
                // Shard spans are recorded after the parallel region (in
                // shard order, on the worker thread): recording can never
                // perturb the pool's scheduling or the shard outputs.
                if let Some(et) = trace {
                    et.tracer.record(Span {
                        id: et.tracer.next_id(),
                        parent: et.execute,
                        request: et.request,
                        kind: SpanKind::Shard,
                        label: region_label.clone(),
                        start_tick: et.tick,
                        end_tick: et.tick,
                        seconds: secs,
                        detail: i as u64,
                    });
                }
                shard_secs.push(secs);
            }
            shard_metrics.record_shards(&shard_secs, t0.elapsed().as_secs_f64());
            Ok((phi, lphi))
        };
        Self::spawn_with(width, policy, metrics, cfg, compute)
    }

    /// Spawn a sharded worker around the pure-Rust DOF engine with
    /// **compile-once execution**: the operator program is fetched from
    /// the keyed global plan cache at spawn (so respawning a server for
    /// the same `(model, operator)` pair — rolling restarts, per-model
    /// router instances — reuses the compiled program), and every batch
    /// the coordinator cuts executes that precompiled program per shard
    /// with a depot-checked slab. Width is the model input dimension.
    pub fn spawn_dof(
        graph: Graph,
        engine: DofEngine,
        policy: BatchPolicy,
        pool: Pool,
        shard_rows: usize,
    ) -> Self {
        Self::spawn_dof_cfg(graph, engine, policy, pool, shard_rows, ServeConfig::labeled("dof"))
    }

    /// [`Self::spawn_dof`] with robustness knobs.
    pub fn spawn_dof_cfg(
        graph: Graph,
        engine: DofEngine,
        policy: BatchPolicy,
        pool: Pool,
        shard_rows: usize,
        cfg: ServeConfig,
    ) -> Self {
        let width = graph.input_dim();
        let program =
            plan::global_cache().get_or_compile(&graph, &engine.ldl, engine.plan_options());
        // Weight values are fixed for the server's lifetime (the graph is
        // moved into the closure), so the packed panels are too — pack once
        // at spawn instead of per batch.
        let panels = plan::pack_panels(program.steps(), &graph);
        let compute = move |data: &[f32], w: usize| -> Result<(Vec<f32>, Vec<f32>)> {
            let rows = data.len() / w;
            let x = Tensor::from_vec(
                &[rows, w],
                data.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
            );
            // Engine-entry validation (belt over the front door's braces:
            // the shared gate also guards direct in-process callers).
            engine.validate_input(&graph, &x).map_err(anyhow::Error::msg)?;
            // Program-keyed pool slabs: this closure runs on scoped pool
            // workers whose thread-locals die with each batch's parallel
            // region; the pool returns the warmed exact-fit slab for this
            // (program, shard rows) pair.
            let key = SlabKey {
                program: program.key().fingerprint,
                rows,
            };
            let res = with_program_slab(key, |slab| {
                engine.execute_with_slab(&program, &graph, &x, &panels, slab)
            });
            Ok((
                res.values.data().iter().map(|&v| v as f32).collect(),
                res.operator_values.data().iter().map(|&v| v as f32).collect(),
            ))
        };
        Self::spawn_sharded_cfg(width, policy, pool, shard_rows, cfg, compute)
    }

    /// Spawn a sharded worker around the Taylor-mode **jet engine**
    /// ([`crate::jet`]) with compile-once execution: the [`crate::jet::JetProgram`]
    /// is fetched from the keyed global jet cache at spawn, and every batch
    /// the coordinator cuts executes that precompiled program per shard
    /// with an exact-fit slab from the program-keyed pool. `lphi` carries
    /// the higher-order operator values (e.g. `Δ²φ` for the biharmonic).
    pub fn spawn_jet(
        graph: Graph,
        engine: JetEngine,
        policy: BatchPolicy,
        pool: Pool,
        shard_rows: usize,
    ) -> Self {
        Self::spawn_jet_cfg(graph, engine, policy, pool, shard_rows, ServeConfig::labeled("jet"))
    }

    /// [`Self::spawn_jet`] with robustness knobs.
    pub fn spawn_jet_cfg(
        graph: Graph,
        engine: JetEngine,
        policy: BatchPolicy,
        pool: Pool,
        shard_rows: usize,
        cfg: ServeConfig,
    ) -> Self {
        let width = graph.input_dim();
        let program = jet::global_jet_cache().get_or_compile(
            &graph,
            engine.basis(),
            engine.constant().is_some(),
        );
        // Same spawn-time packing as the DOF backend: weights are fixed
        // for the server's lifetime.
        let panels = plan::pack_panels(program.steps(), &graph);
        let compute = move |data: &[f32], w: usize| -> Result<(Vec<f32>, Vec<f32>)> {
            let rows = data.len() / w;
            let x = Tensor::from_vec(
                &[rows, w],
                data.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
            );
            engine.validate_input(&graph, &x).map_err(anyhow::Error::msg)?;
            let key = SlabKey {
                program: program.key().fingerprint,
                rows,
            };
            let res = with_program_slab(key, |slab| {
                engine.execute_with_slab(&program, &graph, &x, &panels, slab)
            });
            Ok((
                res.values.data().iter().map(|&v| v as f32).collect(),
                res.operator_values.data().iter().map(|&v| v as f32).collect(),
            ))
        };
        Self::spawn_sharded_cfg(width, policy, pool, shard_rows, cfg, compute)
    }

    /// Spawn a worker around the **stochastic Taylor jet engine** (STDE,
    /// [`crate::jet::StochasticJetEngine`]): `lphi` carries the unbiased
    /// sampled estimate of the operator, `phi` the exact model values.
    /// Sharding happens *inside* the engine's `compute_sharded` — its
    /// per-point direction streams are keyed by the point's global index
    /// within the cut batch, so a batch's bytes are independent of the
    /// thread count and shard decomposition (the PR 1 determinism
    /// contract; estimates do depend on how the coordinator composed the
    /// batch, which is inherent to per-point counter-based streams).
    ///
    /// The per-request [`ServerHandle::eval_with_samples`] override is
    /// honored here: each distinct sample count gets its own engine
    /// (lazily built from the spawn-time engine and cached for the
    /// worker's lifetime; the underlying jet program is shared through
    /// the global jet cache whenever the direction structure matches).
    pub fn spawn_stochastic(
        graph: Graph,
        engine: StochasticJetEngine,
        policy: BatchPolicy,
        pool: Pool,
        shard_rows: usize,
    ) -> Self {
        Self::spawn_stochastic_cfg(
            graph,
            engine,
            policy,
            pool,
            shard_rows,
            ServeConfig::labeled("stochastic"),
        )
    }

    /// [`Self::spawn_stochastic`] with robustness knobs.
    pub fn spawn_stochastic_cfg(
        graph: Graph,
        engine: StochasticJetEngine,
        policy: BatchPolicy,
        pool: Pool,
        shard_rows: usize,
        cfg: ServeConfig,
    ) -> Self {
        let width = graph.input_dim();
        // Warm the compile-once program cache for the default sample count.
        let _ = engine.program(&graph);
        let default_samples = engine.samples();
        let mut engines: HashMap<u32, StochasticJetEngine> = HashMap::new();
        engines.insert(default_samples, engine);
        let compute = move |data: &[f32],
                            w: usize,
                            rows_used: usize,
                            samples: Option<u32>,
                            _trace: Option<&ExecTrace>|
              -> Result<(Vec<f32>, Vec<f32>)> {
            // Shape-flexible backend: padding rows are skipped entirely.
            let rows = rows_used.min(data.len() / w);
            if rows == 0 {
                return Ok((Vec::new(), Vec::new()));
            }
            let s = samples.unwrap_or(default_samples);
            if !engines.contains_key(&s) {
                let base = engines
                    .get(&default_samples)
                    .ok_or_else(|| anyhow!("default stochastic engine missing"))?
                    .clone();
                engines.insert(s, base.with_samples(s));
            }
            let eng = engines
                .get(&s)
                .ok_or_else(|| anyhow!("stochastic engine for {s} samples missing"))?;
            let x = Tensor::from_vec(
                &[rows, w],
                data[..rows * w]
                    .iter()
                    .map(|&v| v as f64)
                    .collect::<Vec<f64>>(),
            );
            eng.validate_input(&graph, &x).map_err(anyhow::Error::msg)?;
            let res = eng.compute_sharded(&graph, &x, &pool, shard_rows);
            Ok((
                res.values.data().iter().map(|&v| v as f32).collect(),
                res.operator_values.data().iter().map(|&v| v as f32).collect(),
            ))
        };
        Self::spawn_with(width, policy, Arc::new(Metrics::new()), cfg, compute)
    }

    /// Spawn a sharded worker around the **Hessian baseline engine** with
    /// compile-once execution: the structure-keyed
    /// [`crate::plan::hessian::HessianPlan`] is fetched from the global
    /// Hessian-plan cache at spawn, and every batch
    /// the coordinator cuts executes it per shard with an exact-fit slab
    /// from the program-keyed pool (domain-tagged key — Hessian slabs never
    /// alias DOF or jet slabs). `lphi` carries `L[φ]` exactly like the DOF
    /// backend, so a router can mix the two behind one traffic stream
    /// (useful for serving-scale baseline comparisons).
    pub fn spawn_hessian(
        graph: Graph,
        engine: HessianEngine,
        policy: BatchPolicy,
        pool: Pool,
        shard_rows: usize,
    ) -> Self {
        Self::spawn_hessian_cfg(
            graph,
            engine,
            policy,
            pool,
            shard_rows,
            ServeConfig::labeled("hessian"),
        )
    }

    /// [`Self::spawn_hessian`] with robustness knobs.
    pub fn spawn_hessian_cfg(
        graph: Graph,
        engine: HessianEngine,
        policy: BatchPolicy,
        pool: Pool,
        shard_rows: usize,
        cfg: ServeConfig,
    ) -> Self {
        let width = graph.input_dim();
        let plan = global_hessian_cache().get_or_compile(&graph);
        let compute = move |data: &[f32], w: usize| -> Result<(Vec<f32>, Vec<f32>)> {
            let rows = data.len() / w;
            let x = Tensor::from_vec(
                &[rows, w],
                data.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
            );
            engine.validate_input(&graph, &x).map_err(anyhow::Error::msg)?;
            let res = engine.execute(&plan, &graph, &x);
            Ok((
                res.values.data().iter().map(|&v| v as f32).collect(),
                res.operator_values.data().iter().map(|&v| v as f32).collect(),
            ))
        };
        Self::spawn_sharded_cfg(width, policy, pool, shard_rows, cfg, compute)
    }

    /// Spawn a worker that executes a PJRT artifact. The executor is
    /// created inside the worker thread (PJRT handles are not `Send`);
    /// load/compile errors are surfaced synchronously.
    pub fn spawn_xla(
        artifact_dir: std::path::PathBuf,
        artifact: String,
        width: usize,
        batch: usize,
        policy_wait: std::time::Duration,
    ) -> Result<Self> {
        let policy = BatchPolicy {
            capacity: batch,
            max_wait: policy_wait,
            max_wait_ticks: None,
        };
        let cfg = ServeConfig::labeled(&artifact);
        let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
        let (tx, rx) = mpsc::channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let admission = Arc::new(Admission::new(cfg.queue_cap));
        let label: Arc<str> = Arc::from(cfg.label.as_str());
        let ctx = WorkerCtx {
            width,
            policy,
            metrics: Arc::clone(&metrics),
            clock: cfg.clock.clone(),
            injector: cfg.injector,
            admission: Arc::clone(&admission),
            label: Arc::clone(&label),
            tracer: cfg.tracer.clone(),
        };
        let art = artifact.clone();
        let join = std::thread::spawn(move || {
            use crate::runtime::{ArtifactRegistry, Executor};
            let exec = (|| -> Result<Executor> {
                let reg = ArtifactRegistry::open(&artifact_dir)?;
                let mut e = Executor::cpu()?;
                e.load(&art, &reg.path(&art)?)?;
                Ok(e)
            })();
            let exec = match exec {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            // Non-Send closure is fine: it stays on this thread. The
            // artifact has a fixed batch shape, so the padded rows are
            // executed regardless of rows_used.
            let compute = move |data: &[f32],
                                w: usize,
                                _rows_used: usize,
                                _samples: Option<u32>,
                                _trace: Option<&ExecTrace>| {
                let rows = data.len() / w;
                let outs = exec.run_f32(&art, &[(data, &[rows, w])])?;
                Ok((outs[0].clone(), outs[1].clone()))
            };
            worker_loop(rx, ctx, compute);
        });
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(anyhow!("worker failed to load {artifact}: {e}")),
            Err(_) => return Err(anyhow!("worker died during startup")),
        }
        let handle = ServerHandle {
            tx: tx.clone(),
            width,
            metrics,
            admission,
            clock: cfg.clock,
            label,
        };
        Ok(Self {
            handle,
            join: Some(join),
            tx,
        })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Graceful stop (flushes the partial batch).
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ModelServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn mock_compute() -> BatchFn {
        // phi = sum of row; lphi = 2 * sum of row.
        Box::new(|data: &[f32], width: usize| {
            let rows = data.len() / width;
            let mut phi = Vec::with_capacity(rows);
            let mut lphi = Vec::with_capacity(rows);
            for r in 0..rows {
                let s: f32 = data[r * width..(r + 1) * width].iter().sum();
                phi.push(s);
                lphi.push(2.0 * s);
            }
            Ok((phi, lphi))
        })
    }

    #[test]
    fn serves_single_request() {
        let server = ModelServer::spawn(
            3,
            BatchPolicy {
                capacity: 8,
                max_wait: Duration::from_millis(1),
                max_wait_ticks: None,
            },
            mock_compute(),
        );
        let h = server.handle();
        let resp = h.eval_blocking(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(resp.phi, vec![6.0, 15.0]);
        assert_eq!(resp.lphi, vec![12.0, 30.0]);
        server.shutdown();
    }

    #[test]
    fn serves_concurrent_requests_with_batching() {
        let server = ModelServer::spawn(
            2,
            BatchPolicy {
                capacity: 16,
                max_wait: Duration::from_millis(2),
                max_wait_ticks: None,
            },
            mock_compute(),
        );
        let h = server.handle();
        let mut joins = Vec::new();
        for i in 0..8 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                let v = i as f32;
                let resp = h.eval_blocking(vec![v, v + 1.0]).unwrap();
                assert_eq!(resp.phi, vec![2.0 * v + 1.0]);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = h.metrics.snapshot();
        assert_eq!(snap.requests, 8);
        assert_eq!(snap.accepted, 8);
        assert!(snap.batches >= 1);
        server.shutdown();
    }

    #[test]
    fn oversize_request_reassembled() {
        let server = ModelServer::spawn(
            1,
            BatchPolicy {
                capacity: 4,
                max_wait: Duration::from_millis(1),
                max_wait_ticks: None,
            },
            mock_compute(),
        );
        let h = server.handle();
        let pts: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let resp = h.eval_blocking(pts.clone()).unwrap();
        assert_eq!(resp.phi, pts);
        server.shutdown();
    }

    #[test]
    fn sharded_backend_matches_serial_and_records_shards() {
        let row_sum = |data: &[f32], width: usize| -> Result<(Vec<f32>, Vec<f32>)> {
            let rows = data.len() / width;
            let mut phi = Vec::with_capacity(rows);
            let mut lphi = Vec::with_capacity(rows);
            for r in 0..rows {
                let s: f32 = data[r * width..(r + 1) * width].iter().sum();
                phi.push(s);
                lphi.push(2.0 * s);
            }
            Ok((phi, lphi))
        };
        let server = ModelServer::spawn_sharded(
            3,
            BatchPolicy {
                capacity: 8,
                max_wait: Duration::from_millis(1),
                max_wait_ticks: None,
            },
            Pool::new(4),
            2,
            row_sum,
        );
        let h = server.handle();
        let pts: Vec<f32> = (0..7 * 3).map(|i| i as f32).collect();
        let resp = h.eval_blocking(pts.clone()).unwrap();
        // Same answers as the serial mock backend.
        for r in 0..7 {
            let want: f32 = pts[r * 3..(r + 1) * 3].iter().sum();
            assert_eq!(resp.phi[r], want);
            assert_eq!(resp.lphi[r], 2.0 * want);
        }
        let snap = h.metrics.snapshot();
        assert!(snap.shards >= 4, "expected ≥4 shards, got {}", snap.shards);
        assert!(snap.sharded_batches >= 1);
        server.shutdown();
    }

    #[test]
    fn sharded_backend_propagates_errors() {
        let failing = |_: &[f32], _: usize| -> Result<(Vec<f32>, Vec<f32>)> {
            Err(anyhow!("shard exploded"))
        };
        let server = ModelServer::spawn_sharded(
            1,
            BatchPolicy {
                capacity: 4,
                max_wait: Duration::from_millis(1),
                max_wait_ticks: None,
            },
            Pool::new(2),
            1,
            failing,
        );
        let h = server.handle();
        let err = h.eval_blocking(vec![1.0, 2.0]).unwrap_err();
        assert!(err.to_string().contains("shard exploded"));
        assert!(matches!(err, ServeError::EngineFault { .. }));
        server.shutdown();
    }

    #[test]
    fn dof_backend_serves_with_compiled_program() {
        use crate::graph::{builder::random_layers, mlp_graph, Act};
        use crate::operators::{CoeffSpec, Operator};
        use crate::util::Xoshiro256;
        let mut rng = Xoshiro256::new(77);
        let n = 4;
        let graph = mlp_graph(&random_layers(&[n, 8, 1], &mut rng), Act::Tanh);
        let op = Operator::from_spec(CoeffSpec::EllipticGram {
            n,
            rank: n,
            seed: 1,
        });
        let server = ModelServer::spawn_dof(
            graph.clone(),
            op.dof_engine(),
            BatchPolicy {
                capacity: 8,
                max_wait: Duration::from_millis(1),
                max_wait_ticks: None,
            },
            Pool::new(2),
            2,
        );
        let h = server.handle();
        let pts: Vec<f32> = (0..5 * n).map(|i| (i as f32) * 0.1).collect();
        let resp = h.eval_blocking(pts.clone()).unwrap();
        assert_eq!(resp.phi.len(), 5);
        assert_eq!(resp.lphi.len(), 5);
        // Cross-check against a direct engine evaluation (serving casts
        // through f32, so compare loosely).
        let x = Tensor::from_vec(&[5, n], pts.iter().map(|&v| v as f64).collect::<Vec<f64>>());
        let direct = op.dof_engine().compute(&graph, &x);
        for b in 0..5 {
            assert!(
                (resp.lphi[b] as f64 - direct.operator_values.at(b, 0)).abs() < 1e-3,
                "row {b}: served {} vs direct {}",
                resp.lphi[b],
                direct.operator_values.at(b, 0)
            );
        }
        server.shutdown();
    }

    #[test]
    fn jet_backend_serves_biharmonic_with_compiled_program() {
        use crate::graph::{builder::random_layers, mlp_graph, Act};
        use crate::operators::{HigherOrderOperator, HigherOrderSpec};
        use crate::util::Xoshiro256;
        let mut rng = Xoshiro256::new(78);
        let n = 3;
        let graph = mlp_graph(&random_layers(&[n, 8, 1], &mut rng), Act::Tanh);
        let op = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: n });
        let server = ModelServer::spawn_jet(
            graph.clone(),
            op.jet_engine(),
            BatchPolicy {
                capacity: 8,
                max_wait: Duration::from_millis(1),
                max_wait_ticks: None,
            },
            Pool::new(2),
            2,
        );
        let h = server.handle();
        let pts: Vec<f32> = (0..4 * n).map(|i| (i as f32) * 0.1).collect();
        let resp = h.eval_blocking(pts.clone()).unwrap();
        assert_eq!(resp.phi.len(), 4);
        assert_eq!(resp.lphi.len(), 4);
        // Cross-check against a direct jet evaluation (serving casts
        // through f32, so compare loosely).
        let x = Tensor::from_vec(&[4, n], pts.iter().map(|&v| v as f64).collect::<Vec<f64>>());
        let direct = op.jet_engine().compute(&graph, &x);
        for b in 0..4 {
            assert!(
                (resp.lphi[b] as f64 - direct.operator_values.at(b, 0)).abs()
                    < 1e-2 * direct.operator_values.at(b, 0).abs().max(1.0),
                "row {b}: served {} vs direct {}",
                resp.lphi[b],
                direct.operator_values.at(b, 0)
            );
        }
        server.shutdown();
    }

    #[test]
    fn stochastic_backend_serves_estimates_and_honors_samples_override() {
        use crate::graph::{builder::random_layers, mlp_graph, Act};
        use crate::jet::DirectionSampling;
        use crate::operators::{HigherOrderOperator, HigherOrderSpec};
        use crate::util::Xoshiro256;
        let mut rng = Xoshiro256::new(79);
        let n = 3;
        let graph = mlp_graph(&random_layers(&[n, 8, 1], &mut rng), Act::Tanh);
        let op = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: n });
        let engine = op.stochastic_engine(DirectionSampling::Gaussian, 8, 42);
        let server = ModelServer::spawn_stochastic(
            graph.clone(),
            engine,
            BatchPolicy {
                capacity: 8,
                max_wait: Duration::from_millis(1),
                max_wait_ticks: None,
            },
            Pool::new(2),
            2,
        );
        let h = server.handle();
        let pts: Vec<f32> = (0..4 * n).map(|i| (i as f32) * 0.1).collect();
        let resp = h.eval_blocking(pts.clone()).unwrap();
        assert_eq!(resp.phi.len(), 4);
        assert_eq!(resp.lphi.len(), 4);
        // Served bytes match a direct engine call with the same point
        // indices (serving casts through f32).
        let x = Tensor::from_vec(&[4, n], pts.iter().map(|&v| v as f64).collect::<Vec<f64>>());
        let direct = op
            .stochastic_engine(DirectionSampling::Gaussian, 8, 42)
            .compute(&graph, &x);
        for b in 0..4 {
            assert_eq!(resp.phi[b], direct.values.at(b, 0) as f32, "phi exact");
            assert_eq!(
                resp.lphi[b],
                direct.operator_values.at(b, 0) as f32,
                "row {b}: served estimate must be the engine's bytes"
            );
        }
        // Per-request override: same request at 32 samples matches a
        // 32-sample engine, not the spawn default.
        let resp32 = h.eval_with_samples(pts.clone(), Some(32)).unwrap();
        let direct32 = op
            .stochastic_engine(DirectionSampling::Gaussian, 32, 42)
            .compute(&graph, &x);
        for b in 0..4 {
            assert_eq!(resp32.lphi[b], direct32.operator_values.at(b, 0) as f32);
        }
        assert_ne!(resp.lphi, resp32.lphi, "different sample counts differ");
        // samples = 0 is rejected at the front door.
        let err = h.eval_with_samples(pts, Some(0)).unwrap_err();
        assert!(matches!(err, ServeError::InvalidRequest { .. }), "{err}");
        server.shutdown();
    }

    #[test]
    fn compute_error_propagates() {
        let failing: BatchFn = Box::new(|_, _| Err(anyhow!("backend exploded")));
        let server = ModelServer::spawn(
            1,
            BatchPolicy {
                capacity: 2,
                max_wait: Duration::from_millis(1),
                max_wait_ticks: None,
            },
            failing,
        );
        let h = server.handle();
        let err = h.eval_blocking(vec![1.0]).unwrap_err();
        assert!(err.to_string().contains("backend exploded"));
        server.shutdown();
    }

    #[test]
    fn front_door_rejects_invalid_requests() {
        let server = ModelServer::spawn(
            3,
            BatchPolicy {
                capacity: 8,
                max_wait: Duration::from_millis(1),
                max_wait_ticks: None,
            },
            mock_compute(),
        );
        let h = server.handle();
        // Ragged.
        let err = h.eval_blocking(vec![1.0, 2.0]).unwrap_err();
        assert!(matches!(err, ServeError::InvalidRequest { .. }), "{err}");
        // Empty.
        assert!(h.eval_blocking(vec![]).is_err());
        // Non-finite, position reported.
        let err = h
            .eval_blocking(vec![1.0, 2.0, 3.0, 4.0, f32::NAN, 6.0])
            .unwrap_err();
        assert!(err.to_string().contains("row 1, column 1"), "{err}");
        // Nothing was dispatched; the worker never saw them.
        let snap = h.metrics.snapshot();
        assert_eq!(snap.invalid, 3);
        assert_eq!(snap.accepted, 0);
        assert_eq!(snap.received, 0);
        server.shutdown();
    }

    #[test]
    fn panicking_compute_is_contained_and_server_survives() {
        let panicking: BatchFn = Box::new(|data, _| {
            if data[0] < 0.0 {
                panic!("negative input blew up the engine");
            }
            Ok((vec![data[0]], vec![data[0]]))
        });
        let server = ModelServer::spawn(
            1,
            BatchPolicy {
                capacity: 1,
                max_wait: Duration::from_millis(1),
                max_wait_ticks: None,
            },
            panicking,
        );
        let h = server.handle();
        let err = h.eval_blocking(vec![-1.0]).unwrap_err();
        match &err {
            ServeError::EngineFault { payload, .. } => {
                assert!(payload.contains("negative input blew up"), "{payload}");
            }
            other => panic!("expected EngineFault, got {other}"),
        }
        // The worker survived the panic: the next request is served.
        let resp = h.eval_blocking(vec![2.0]).unwrap();
        assert_eq!(resp.phi, vec![2.0]);
        assert_eq!(h.metrics.snapshot().engine_faults, 1);
        server.shutdown();
    }

    #[test]
    fn non_finite_output_is_withheld() {
        let nan_compute: BatchFn = Box::new(|data, _| {
            Ok((vec![f32::NAN; data.len()], vec![0.0; data.len()]))
        });
        let server = ModelServer::spawn(
            1,
            BatchPolicy {
                capacity: 2,
                max_wait: Duration::from_millis(1),
                max_wait_ticks: None,
            },
            nan_compute,
        );
        let h = server.handle();
        let err = h.eval_blocking(vec![1.0]).unwrap_err();
        assert!(err.to_string().contains("non-finite engine output"), "{err}");
        assert_eq!(h.metrics.snapshot().engine_faults, 1);
        server.shutdown();
    }

    #[test]
    fn admission_cap_sheds_with_overloaded() {
        // Park requests in a long-wait batcher to hold the gate open.
        let server = ModelServer::spawn_cfg(
            1,
            BatchPolicy {
                capacity: 64,
                max_wait: Duration::from_secs(30),
                max_wait_ticks: None,
            },
            ServeConfig {
                queue_cap: 2,
                ..ServeConfig::labeled("capped")
            },
            mock_compute(),
        );
        let h = server.handle();
        let parked: Vec<_> = (0..2)
            .map(|i| {
                let h = h.clone();
                std::thread::spawn(move || h.eval_blocking(vec![i as f32]))
            })
            .collect();
        // Race-free gate: admission happens on the submitting thread
        // before enqueue, so wait until both slots are held.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while h.inflight() < 2 {
            assert!(std::time::Instant::now() < deadline, "parked requests not admitted");
            std::thread::sleep(Duration::from_millis(1));
        }
        let err = h.eval_blocking(vec![9.0]).unwrap_err();
        match &err {
            ServeError::Overloaded { model, reason } => {
                assert_eq!(model, "capped");
                assert!(reason.contains("cap 2"), "{reason}");
            }
            other => panic!("expected Overloaded, got {other}"),
        }
        let snap = h.metrics.snapshot();
        assert_eq!(snap.shed, 1);
        assert_eq!(snap.accepted, 2);
        server.shutdown();
        for p in parked {
            p.join().unwrap().unwrap();
        }
    }

    #[test]
    fn deadline_checked_on_logical_clock_only() {
        let clock = TickClock::new();
        let server = ModelServer::spawn_cfg(
            1,
            BatchPolicy {
                capacity: 2,
                max_wait: Duration::from_millis(1),
                max_wait_ticks: None,
            },
            ServeConfig {
                clock: clock.clone(),
                ..ServeConfig::labeled("ticked")
            },
            mock_compute(),
        );
        let h = server.handle();
        // Wall time passes, logical time does not: the deadline holds.
        std::thread::sleep(Duration::from_millis(20));
        let resp = h.eval_with_deadline(vec![1.0], Some(1)).unwrap();
        assert_eq!(resp.phi, vec![1.0]);
        // Advance past the deadline: expired at dequeue.
        clock.advance(5);
        let err = h.eval_with_deadline(vec![1.0], Some(3)).unwrap_err();
        match &err {
            ServeError::DeadlineExceeded {
                deadline_tick,
                now_tick,
                ..
            } => {
                assert_eq!((*deadline_tick, *now_tick), (3, 5));
            }
            other => panic!("expected DeadlineExceeded, got {other}"),
        }
        assert_eq!(h.metrics.snapshot().deadline_expired, 1);
        server.shutdown();
    }
}
