//! Model server: a worker thread that owns the compute (PJRT executable or
//! a Rust-engine closure), batches incoming requests, and routes results.
//!
//! PJRT handles are **not** `Send`, so the XLA executor is constructed
//! *inside* its worker thread; only the request channel crosses threads.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::autodiff::arena::{with_program_slab, SlabKey};
use crate::autodiff::{DofEngine, HessianEngine};
use crate::graph::Graph;
use crate::jet::{self, JetEngine};
use crate::parallel::{split_rows, Pool};
use crate::plan;
use crate::plan::hessian::global_hessian_cache;
use crate::tensor::Tensor;

use super::batcher::{BatchPolicy, Batcher, CutBatch};
use super::metrics::Metrics;
use super::{EvalRequest, EvalResponse};

/// Batch compute signature: padded flat batch + width → `(phi, lphi)` flat
/// over the full padded batch.
pub type BatchFn = Box<dyn FnMut(&[f32], usize) -> Result<(Vec<f32>, Vec<f32>)> + Send>;

type RespTx = mpsc::Sender<Result<EvalResponse, String>>;

enum Msg {
    Eval(EvalRequest, RespTx),
    Shutdown,
}

/// Handle for submitting requests to a running [`ModelServer`].
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
    width: usize,
    pub metrics: Arc<Metrics>,
}

impl ServerHandle {
    /// Row width (model input dimension) this server expects.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Submit a request; blocks until the response is ready. Requests
    /// larger than the batch capacity are split and reassembled here.
    pub fn eval_blocking(&self, points: Vec<f32>) -> Result<EvalResponse> {
        let req = EvalRequest::new(points, self.width);
        let rows = req.rows;
        let t0 = Instant::now();
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Eval(req, rtx))
            .map_err(|_| anyhow!("server stopped"))?;
        let mut phi = Vec::with_capacity(rows);
        let mut lphi = Vec::with_capacity(rows);
        while phi.len() < rows {
            let part = rrx
                .recv()
                .map_err(|_| anyhow!("server dropped response channel"))?
                .map_err(|e| anyhow!(e))?;
            phi.extend(part.phi);
            lphi.extend(part.lphi);
        }
        self.metrics.record_request(rows, t0.elapsed().as_secs_f64());
        Ok(EvalResponse { phi, lphi })
    }
}

/// The worker event loop — runs on the worker thread; `compute` need not
/// be `Send` because it never leaves this thread.
///
/// `compute` receives `(padded_data, width, rows_used)`: fixed-shape
/// backends (XLA artifacts) consume the whole padded buffer, while
/// shape-flexible backends may compute only the first `rows_used` rows —
/// response routing reads nothing past them.
fn worker_loop<F>(
    rx: mpsc::Receiver<Msg>,
    width: usize,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    mut compute: F,
) where
    F: FnMut(&[f32], usize, usize) -> Result<(Vec<f32>, Vec<f32>)>,
{
    let mut batcher: Batcher<RespTx> = Batcher::new(width, policy);
    let run_batch = |cut: CutBatch<RespTx>, compute: &mut F| {
        let t0 = Instant::now();
        let result = compute(&cut.data, width, cut.rows_used);
        let exec_s = t0.elapsed().as_secs_f64();
        metrics.record_batch(cut.rows_used, cut.padded_rows(width), exec_s);
        match result {
            Ok((phi, lphi)) => {
                for m in cut.members {
                    let (start, rows) = m.span;
                    let _ = m.tag.send(Ok(EvalResponse {
                        phi: phi[start..start + rows].to_vec(),
                        lphi: lphi[start..start + rows].to_vec(),
                    }));
                }
            }
            Err(e) => {
                let msg = format!("batch compute failed: {e:#}");
                for m in cut.members {
                    let _ = m.tag.send(Err(msg.clone()));
                }
            }
        }
    };
    loop {
        match rx.recv_timeout(policy.max_wait) {
            Ok(Msg::Eval(req, rtx)) => {
                metrics.record_received();
                let cuts = batcher.push(req, |_frag| rtx.clone());
                for cut in cuts {
                    run_batch(cut, &mut compute);
                }
            }
            Ok(Msg::Shutdown) => {
                if !batcher.is_empty() {
                    run_batch(batcher.cut(), &mut compute);
                }
                break;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if batcher.deadline_expired() {
                    run_batch(batcher.cut(), &mut compute);
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                if !batcher.is_empty() {
                    run_batch(batcher.cut(), &mut compute);
                }
                break;
            }
        }
    }
}

/// A running worker.
pub struct ModelServer {
    handle: ServerHandle,
    join: Option<JoinHandle<()>>,
    tx: mpsc::Sender<Msg>,
}

impl ModelServer {
    /// Shared wiring: channel, worker thread around [`worker_loop`], handle.
    fn spawn_with<F>(width: usize, policy: BatchPolicy, metrics: Arc<Metrics>, compute: F) -> Self
    where
        F: FnMut(&[f32], usize, usize) -> Result<(Vec<f32>, Vec<f32>)> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker_metrics = Arc::clone(&metrics);
        let join = std::thread::spawn(move || {
            worker_loop(rx, width, policy, worker_metrics, compute);
        });
        let handle = ServerHandle {
            tx: tx.clone(),
            width,
            metrics,
        };
        Self {
            handle,
            join: Some(join),
            tx,
        }
    }

    /// Spawn a worker around an arbitrary (Send) batch compute.
    pub fn spawn(width: usize, policy: BatchPolicy, compute: BatchFn) -> Self {
        let mut compute = compute;
        Self::spawn_with(width, policy, Arc::new(Metrics::new()), move |data, w, _rows| {
            compute(data, w)
        })
    }

    /// Spawn a worker whose batches are **row-sharded across a thread
    /// pool**: each cut batch is split into `shard_rows`-row chunks, `inner`
    /// runs per chunk on the pool's workers, and the chunk outputs are
    /// reassembled in shard order (same determinism contract as the
    /// engines' `compute_sharded`). Per-shard compute seconds land in the
    /// server's [`Metrics`] (`shards` / `parallel_occupancy`).
    pub fn spawn_sharded<F>(
        width: usize,
        policy: BatchPolicy,
        pool: Pool,
        shard_rows: usize,
        inner: F,
    ) -> Self
    where
        F: Fn(&[f32], usize) -> Result<(Vec<f32>, Vec<f32>)> + Send + Sync + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let shard_metrics = Arc::clone(&metrics);
        let compute = move |data: &[f32],
                            w: usize,
                            rows_used: usize|
              -> Result<(Vec<f32>, Vec<f32>)> {
            // The Rust engines have no fixed-batch constraint, so padding
            // rows (zeros nobody reads) are skipped entirely.
            let rows = rows_used.min(data.len() / w);
            let ranges = split_rows(rows, shard_rows.max(1));
            let t0 = Instant::now();
            let shard_out = pool.run_sharded(ranges, |_, r| {
                let ts = Instant::now();
                let res = inner(&data[r.start * w..r.end * w], w);
                (res, ts.elapsed().as_secs_f64())
            });
            let mut phi = Vec::with_capacity(rows);
            let mut lphi = Vec::with_capacity(rows);
            let mut shard_secs = Vec::with_capacity(shard_out.len());
            for (res, secs) in shard_out {
                let (p, l) = res?;
                phi.extend(p);
                lphi.extend(l);
                shard_secs.push(secs);
            }
            shard_metrics.record_shards(&shard_secs, t0.elapsed().as_secs_f64());
            Ok((phi, lphi))
        };
        Self::spawn_with(width, policy, metrics, compute)
    }

    /// Spawn a sharded worker around the pure-Rust DOF engine with
    /// **compile-once execution**: the operator program is fetched from
    /// the keyed global plan cache at spawn (so respawning a server for
    /// the same `(model, operator)` pair — rolling restarts, per-model
    /// router instances — reuses the compiled program), and every batch
    /// the coordinator cuts executes that precompiled program per shard
    /// with a depot-checked slab. Width is the model input dimension.
    pub fn spawn_dof(
        graph: Graph,
        engine: DofEngine,
        policy: BatchPolicy,
        pool: Pool,
        shard_rows: usize,
    ) -> Self {
        let width = graph.input_dim();
        let program =
            plan::global_cache().get_or_compile(&graph, &engine.ldl, engine.plan_options());
        let compute = move |data: &[f32], w: usize| -> Result<(Vec<f32>, Vec<f32>)> {
            let rows = data.len() / w;
            let x = Tensor::from_vec(
                &[rows, w],
                data.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
            );
            // Program-keyed pool slabs: this closure runs on scoped pool
            // workers whose thread-locals die with each batch's parallel
            // region; the pool returns the warmed exact-fit slab for this
            // (program, shard rows) pair.
            let key = SlabKey {
                program: program.key().fingerprint,
                rows,
            };
            let res = with_program_slab(key, |slab| {
                engine.execute_with_slab(&program, &graph, &x, slab)
            });
            Ok((
                res.values.data().iter().map(|&v| v as f32).collect(),
                res.operator_values.data().iter().map(|&v| v as f32).collect(),
            ))
        };
        Self::spawn_sharded(width, policy, pool, shard_rows, compute)
    }

    /// Spawn a sharded worker around the Taylor-mode **jet engine**
    /// ([`crate::jet`]) with compile-once execution: the [`crate::jet::JetProgram`]
    /// is fetched from the keyed global jet cache at spawn, and every batch
    /// the coordinator cuts executes that precompiled program per shard
    /// with an exact-fit slab from the program-keyed pool. `lphi` carries
    /// the higher-order operator values (e.g. `Δ²φ` for the biharmonic).
    pub fn spawn_jet(
        graph: Graph,
        engine: JetEngine,
        policy: BatchPolicy,
        pool: Pool,
        shard_rows: usize,
    ) -> Self {
        let width = graph.input_dim();
        let program = jet::global_jet_cache().get_or_compile(
            &graph,
            engine.basis(),
            engine.constant().is_some(),
        );
        let compute = move |data: &[f32], w: usize| -> Result<(Vec<f32>, Vec<f32>)> {
            let rows = data.len() / w;
            let x = Tensor::from_vec(
                &[rows, w],
                data.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
            );
            let key = SlabKey {
                program: program.key().fingerprint,
                rows,
            };
            let res = with_program_slab(key, |slab| {
                engine.execute_with_slab(&program, &graph, &x, slab)
            });
            Ok((
                res.values.data().iter().map(|&v| v as f32).collect(),
                res.operator_values.data().iter().map(|&v| v as f32).collect(),
            ))
        };
        Self::spawn_sharded(width, policy, pool, shard_rows, compute)
    }

    /// Spawn a sharded worker around the **Hessian baseline engine** with
    /// compile-once execution: the structure-keyed
    /// [`crate::plan::hessian::HessianPlan`] is fetched from the global
    /// Hessian-plan cache at spawn, and every batch
    /// the coordinator cuts executes it per shard with an exact-fit slab
    /// from the program-keyed pool (domain-tagged key — Hessian slabs never
    /// alias DOF or jet slabs). `lphi` carries `L[φ]` exactly like the DOF
    /// backend, so a router can mix the two behind one traffic stream
    /// (useful for serving-scale baseline comparisons).
    pub fn spawn_hessian(
        graph: Graph,
        engine: HessianEngine,
        policy: BatchPolicy,
        pool: Pool,
        shard_rows: usize,
    ) -> Self {
        let width = graph.input_dim();
        let plan = global_hessian_cache().get_or_compile(&graph);
        let compute = move |data: &[f32], w: usize| -> Result<(Vec<f32>, Vec<f32>)> {
            let rows = data.len() / w;
            let x = Tensor::from_vec(
                &[rows, w],
                data.iter().map(|&v| v as f64).collect::<Vec<f64>>(),
            );
            let res = engine.execute(&plan, &graph, &x);
            Ok((
                res.values.data().iter().map(|&v| v as f32).collect(),
                res.operator_values.data().iter().map(|&v| v as f32).collect(),
            ))
        };
        Self::spawn_sharded(width, policy, pool, shard_rows, compute)
    }

    /// Spawn a worker that executes a PJRT artifact. The executor is
    /// created inside the worker thread (PJRT handles are not `Send`);
    /// load/compile errors are surfaced synchronously.
    pub fn spawn_xla(
        artifact_dir: std::path::PathBuf,
        artifact: String,
        width: usize,
        batch: usize,
        policy_wait: std::time::Duration,
    ) -> Result<Self> {
        let policy = BatchPolicy {
            capacity: batch,
            max_wait: policy_wait,
        };
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let (tx, rx) = mpsc::channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let worker_metrics = Arc::clone(&metrics);
        let art = artifact.clone();
        let join = std::thread::spawn(move || {
            use crate::runtime::{ArtifactRegistry, Executor};
            let exec = (|| -> Result<Executor> {
                let reg = ArtifactRegistry::open(&artifact_dir)?;
                let mut e = Executor::cpu()?;
                e.load(&art, &reg.path(&art)?)?;
                Ok(e)
            })();
            let exec = match exec {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            // Non-Send closure is fine: it stays on this thread. The
            // artifact has a fixed batch shape, so the padded rows are
            // executed regardless of rows_used.
            let compute = move |data: &[f32], w: usize, _rows_used: usize| {
                let rows = data.len() / w;
                let outs = exec.run_f32(&art, &[(data, &[rows, w])])?;
                Ok((outs[0].clone(), outs[1].clone()))
            };
            worker_loop(rx, width, policy, worker_metrics, compute);
        });
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(anyhow!("worker failed to load {artifact}: {e}")),
            Err(_) => return Err(anyhow!("worker died during startup")),
        }
        let handle = ServerHandle {
            tx: tx.clone(),
            width,
            metrics,
        };
        Ok(Self {
            handle,
            join: Some(join),
            tx,
        })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Graceful stop (flushes the partial batch).
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ModelServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn mock_compute() -> BatchFn {
        // phi = sum of row; lphi = 2 * sum of row.
        Box::new(|data: &[f32], width: usize| {
            let rows = data.len() / width;
            let mut phi = Vec::with_capacity(rows);
            let mut lphi = Vec::with_capacity(rows);
            for r in 0..rows {
                let s: f32 = data[r * width..(r + 1) * width].iter().sum();
                phi.push(s);
                lphi.push(2.0 * s);
            }
            Ok((phi, lphi))
        })
    }

    #[test]
    fn serves_single_request() {
        let server = ModelServer::spawn(
            3,
            BatchPolicy {
                capacity: 8,
                max_wait: Duration::from_millis(1),
            },
            mock_compute(),
        );
        let h = server.handle();
        let resp = h.eval_blocking(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(resp.phi, vec![6.0, 15.0]);
        assert_eq!(resp.lphi, vec![12.0, 30.0]);
        server.shutdown();
    }

    #[test]
    fn serves_concurrent_requests_with_batching() {
        let server = ModelServer::spawn(
            2,
            BatchPolicy {
                capacity: 16,
                max_wait: Duration::from_millis(2),
            },
            mock_compute(),
        );
        let h = server.handle();
        let mut joins = Vec::new();
        for i in 0..8 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                let v = i as f32;
                let resp = h.eval_blocking(vec![v, v + 1.0]).unwrap();
                assert_eq!(resp.phi, vec![2.0 * v + 1.0]);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = h.metrics.snapshot();
        assert_eq!(snap.requests, 8);
        assert!(snap.batches >= 1);
        server.shutdown();
    }

    #[test]
    fn oversize_request_reassembled() {
        let server = ModelServer::spawn(
            1,
            BatchPolicy {
                capacity: 4,
                max_wait: Duration::from_millis(1),
            },
            mock_compute(),
        );
        let h = server.handle();
        let pts: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let resp = h.eval_blocking(pts.clone()).unwrap();
        assert_eq!(resp.phi, pts);
        server.shutdown();
    }

    #[test]
    fn sharded_backend_matches_serial_and_records_shards() {
        let row_sum = |data: &[f32], width: usize| -> Result<(Vec<f32>, Vec<f32>)> {
            let rows = data.len() / width;
            let mut phi = Vec::with_capacity(rows);
            let mut lphi = Vec::with_capacity(rows);
            for r in 0..rows {
                let s: f32 = data[r * width..(r + 1) * width].iter().sum();
                phi.push(s);
                lphi.push(2.0 * s);
            }
            Ok((phi, lphi))
        };
        let server = ModelServer::spawn_sharded(
            3,
            BatchPolicy {
                capacity: 8,
                max_wait: Duration::from_millis(1),
            },
            Pool::new(4),
            2,
            row_sum,
        );
        let h = server.handle();
        let pts: Vec<f32> = (0..7 * 3).map(|i| i as f32).collect();
        let resp = h.eval_blocking(pts.clone()).unwrap();
        // Same answers as the serial mock backend.
        for r in 0..7 {
            let want: f32 = pts[r * 3..(r + 1) * 3].iter().sum();
            assert_eq!(resp.phi[r], want);
            assert_eq!(resp.lphi[r], 2.0 * want);
        }
        let snap = h.metrics.snapshot();
        assert!(snap.shards >= 4, "expected ≥4 shards, got {}", snap.shards);
        assert!(snap.sharded_batches >= 1);
        server.shutdown();
    }

    #[test]
    fn sharded_backend_propagates_errors() {
        let failing = |_: &[f32], _: usize| -> Result<(Vec<f32>, Vec<f32>)> {
            Err(anyhow!("shard exploded"))
        };
        let server = ModelServer::spawn_sharded(
            1,
            BatchPolicy {
                capacity: 4,
                max_wait: Duration::from_millis(1),
            },
            Pool::new(2),
            1,
            failing,
        );
        let h = server.handle();
        let err = h.eval_blocking(vec![1.0, 2.0]).unwrap_err();
        assert!(err.to_string().contains("shard exploded"));
        server.shutdown();
    }

    #[test]
    fn dof_backend_serves_with_compiled_program() {
        use crate::graph::{builder::random_layers, mlp_graph, Act};
        use crate::operators::{CoeffSpec, Operator};
        use crate::util::Xoshiro256;
        let mut rng = Xoshiro256::new(77);
        let n = 4;
        let graph = mlp_graph(&random_layers(&[n, 8, 1], &mut rng), Act::Tanh);
        let op = Operator::from_spec(CoeffSpec::EllipticGram {
            n,
            rank: n,
            seed: 1,
        });
        let server = ModelServer::spawn_dof(
            graph.clone(),
            op.dof_engine(),
            BatchPolicy {
                capacity: 8,
                max_wait: Duration::from_millis(1),
            },
            Pool::new(2),
            2,
        );
        let h = server.handle();
        let pts: Vec<f32> = (0..5 * n).map(|i| (i as f32) * 0.1).collect();
        let resp = h.eval_blocking(pts.clone()).unwrap();
        assert_eq!(resp.phi.len(), 5);
        assert_eq!(resp.lphi.len(), 5);
        // Cross-check against a direct engine evaluation (serving casts
        // through f32, so compare loosely).
        let x = Tensor::from_vec(&[5, n], pts.iter().map(|&v| v as f64).collect::<Vec<f64>>());
        let direct = op.dof_engine().compute(&graph, &x);
        for b in 0..5 {
            assert!(
                (resp.lphi[b] as f64 - direct.operator_values.at(b, 0)).abs() < 1e-3,
                "row {b}: served {} vs direct {}",
                resp.lphi[b],
                direct.operator_values.at(b, 0)
            );
        }
        server.shutdown();
    }

    #[test]
    fn jet_backend_serves_biharmonic_with_compiled_program() {
        use crate::graph::{builder::random_layers, mlp_graph, Act};
        use crate::operators::{HigherOrderOperator, HigherOrderSpec};
        use crate::util::Xoshiro256;
        let mut rng = Xoshiro256::new(78);
        let n = 3;
        let graph = mlp_graph(&random_layers(&[n, 8, 1], &mut rng), Act::Tanh);
        let op = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: n });
        let server = ModelServer::spawn_jet(
            graph.clone(),
            op.jet_engine(),
            BatchPolicy {
                capacity: 8,
                max_wait: Duration::from_millis(1),
            },
            Pool::new(2),
            2,
        );
        let h = server.handle();
        let pts: Vec<f32> = (0..4 * n).map(|i| (i as f32) * 0.1).collect();
        let resp = h.eval_blocking(pts.clone()).unwrap();
        assert_eq!(resp.phi.len(), 4);
        assert_eq!(resp.lphi.len(), 4);
        // Cross-check against a direct jet evaluation (serving casts
        // through f32, so compare loosely).
        let x = Tensor::from_vec(&[4, n], pts.iter().map(|&v| v as f64).collect::<Vec<f64>>());
        let direct = op.jet_engine().compute(&graph, &x);
        for b in 0..4 {
            assert!(
                (resp.lphi[b] as f64 - direct.operator_values.at(b, 0)).abs()
                    < 1e-2 * direct.operator_values.at(b, 0).abs().max(1.0),
                "row {b}: served {} vs direct {}",
                resp.lphi[b],
                direct.operator_values.at(b, 0)
            );
        }
        server.shutdown();
    }

    #[test]
    fn compute_error_propagates() {
        let failing: BatchFn = Box::new(|_, _| Err(anyhow!("backend exploded")));
        let server = ModelServer::spawn(
            1,
            BatchPolicy {
                capacity: 2,
                max_wait: Duration::from_millis(1),
            },
            failing,
        );
        let h = server.handle();
        let err = h.eval_blocking(vec![1.0]).unwrap_err();
        assert!(err.to_string().contains("backend exploded"));
        server.shutdown();
    }
}
