//! Graph constructors for the paper's two benchmark architectures
//! (Appendix E): the plain MLP and the "MLP with Jacobian sparsity"
//! (block-split input, per-block MLPs, product-sum head — the separable-PINN
//! style architecture of Cho et al. 2023).

use super::{Act, Graph, NodeId};
use crate::tensor::Tensor;

/// Per-layer weights of an MLP: `(W_l, b_l)` with `W_l: N_{l+1}×N_l`.
pub type LayerWeights = Vec<(Tensor, Vec<f64>)>;

/// Build the plain-MLP graph: alternating Linear / Activation nodes, with a
/// final Linear (no activation on the last layer, matching Example A.1's
/// `u^{L+1} = φ(x)` scalar head).
pub fn mlp_graph(layers: &LayerWeights, act: Act) -> Graph {
    assert!(!layers.is_empty());
    let in_dim = layers[0].0.dims()[1];
    let mut g = Graph::new();
    let x = g.input(in_dim);
    append_mlp(&mut g, x, layers, act);
    g
}

/// Append an MLP chain starting from `parent`; returns the output node.
/// Activation is applied after every layer except the last.
pub fn append_mlp(g: &mut Graph, parent: NodeId, layers: &LayerWeights, act: Act) -> NodeId {
    let mut cur = parent;
    let last = layers.len() - 1;
    for (i, (w, b)) in layers.iter().enumerate() {
        cur = g.linear(cur, w.clone(), b.clone());
        if i != last {
            cur = g.activation(cur, act);
        }
    }
    cur
}

/// Build the Jacobian-sparse architecture (Appendix E):
///
/// ```text
/// x = (x_1 … x_k)  (each block of dim N/k)
/// output = Σ_d Π_i [MLP^i(x_i)]_d
/// ```
///
/// Each block MLP maps `N/k → hidden → … → out_dim`; block outputs are
/// multiplied elementwise across blocks and summed over `d`. The Jacobian of
/// every intermediate neuron w.r.t. the input is supported on its own block,
/// which is exactly the sparsity DOF exploits (§3.2).
pub fn sparse_mlp_graph(block_layers: &[LayerWeights], act: Act) -> Graph {
    let k = block_layers.len();
    assert!(k >= 2, "sparse MLP needs ≥2 blocks");
    let block_in: usize = block_layers[0][0].0.dims()[1];
    let out_dim = block_layers[0].last().unwrap().0.dims()[0];
    for bl in block_layers {
        assert_eq!(bl[0].0.dims()[1], block_in, "uniform block input dims");
        assert_eq!(
            bl.last().unwrap().0.dims()[0],
            out_dim,
            "uniform block output dims"
        );
    }
    let mut g = Graph::new();
    let x = g.input(block_in * k);
    let mut heads = Vec::with_capacity(k);
    for (i, bl) in block_layers.iter().enumerate() {
        let xi = g.slice(x, i * block_in, block_in);
        heads.push(append_mlp(&mut g, xi, bl, act));
    }
    let prod = g.mul(heads);
    g.sum_reduce(prod);
    g
}

/// Random layer stack `dims[0] → dims[1] → …` with N(0, 1/fan_in) init
/// (the init used in the paper's benchmarks is unspecified; Lecun-style
/// keeps tanh pre-activations O(1) so σ'' terms are exercised).
pub fn random_layers(dims: &[usize], rng: &mut crate::util::Xoshiro256) -> LayerWeights {
    dims.windows(2)
        .map(|w| {
            let (n_in, n_out) = (w[0], w[1]);
            let scale = 1.0 / (n_in as f64).sqrt();
            let wt = Tensor::randn(&[n_out, n_in], rng).scale(scale);
            let b = (0..n_out).map(|_| 0.1 * rng.normal()).collect();
            (wt, b)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    #[test]
    fn mlp_graph_shape() {
        let mut rng = Xoshiro256::new(1);
        let layers = random_layers(&[4, 8, 8, 1], &mut rng);
        let g = mlp_graph(&layers, Act::Tanh);
        // input + 3 linear + 2 activation = 6 nodes
        assert_eq!(g.len(), 6);
        assert_eq!(g.node(g.output()).dim, 1);
        let x = Tensor::randn(&[3, 4], &mut rng);
        let y = g.eval(&x);
        assert_eq!(y.dims(), &[3, 1]);
        assert!(y.all_finite());
    }

    #[test]
    fn sparse_mlp_matches_manual_product_sum() {
        let mut rng = Xoshiro256::new(2);
        let k = 3;
        let blocks: Vec<LayerWeights> = (0..k)
            .map(|_| random_layers(&[2, 5, 4], &mut rng))
            .collect();
        let g = sparse_mlp_graph(&blocks, Act::Tanh);
        let x = Tensor::randn(&[2, 6], &mut rng);
        let y = g.eval(&x);

        // Manual: per-block MLP eval then product-sum.
        for b in 0..2 {
            let mut expected = 0.0;
            let mut prod = vec![1.0; 4];
            for (i, bl) in blocks.iter().enumerate() {
                let xi = Tensor::from_vec(&[1, 2], x.row(b)[2 * i..2 * i + 2].to_vec());
                let gi = mlp_graph(bl, Act::Tanh);
                let oi = gi.eval(&xi);
                for d in 0..4 {
                    prod[d] *= oi.at(0, d);
                }
            }
            for d in 0..4 {
                expected += prod[d];
            }
            assert!((y.at(b, 0) - expected).abs() < 1e-10);
        }
    }

    #[test]
    fn paper_table3_shapes_build() {
        // MLP: in 64, hidden 256, 8 layers. Sparse: 16 blocks × 4, out 8.
        let mut rng = Xoshiro256::new(3);
        let dims: Vec<usize> =
            std::iter::once(64).chain(std::iter::repeat(256).take(8)).chain(std::iter::once(1)).collect();
        let g = mlp_graph(&random_layers(&dims, &mut rng), Act::Tanh);
        assert_eq!(g.input_dim(), 64);

        let bdims: Vec<usize> =
            std::iter::once(4).chain(std::iter::repeat(256).take(8)).chain(std::iter::once(8)).collect();
        let blocks: Vec<LayerWeights> =
            (0..16).map(|_| random_layers(&bdims, &mut rng)).collect();
        let sg = sparse_mlp_graph(&blocks, Act::Tanh);
        assert_eq!(sg.input_dim(), 64);
        assert_eq!(sg.node(sg.output()).dim, 1);
    }
}
