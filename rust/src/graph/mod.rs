//! Computation-graph engine.
//!
//! A directed acyclic graph of vector-valued nodes (Appendix A of the
//! paper), constructed in topological order. The autodiff engines
//! ([`crate::autodiff`]) walk this structure; this module owns construction,
//! validation, plain forward evaluation (batched), and the liveness
//! analysis `τ(i) = max{j : i → j}` (eq. 24) that drives the
//! peak-memory accounting of Theorem 2.2.

pub mod builder;
pub mod node;

pub use builder::{mlp_graph, sparse_mlp_graph};
pub use node::{Act, Node, NodeId, Op};

use crate::tensor::{matmul_nt, Tensor};

/// A computation graph. Node ids are indices into `nodes` and are
/// guaranteed topological (an op may only reference earlier ids).
#[derive(Debug, Clone, Default)]
pub struct Graph {
    nodes: Vec<Node>,
    inputs: Vec<NodeId>,
}

impl Graph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an input node of the given dimension.
    pub fn input(&mut self, dim: usize) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node {
            op: Op::Input { dim },
            inputs: vec![],
            dim,
        });
        self.inputs.push(id);
        id
    }

    /// Add a generic op node; validates parent ids and dimensions.
    pub fn push(&mut self, op: Op, inputs: Vec<NodeId>) -> NodeId {
        let id = self.nodes.len();
        for &p in &inputs {
            assert!(p < id, "inputs must be earlier nodes (topological order)");
        }
        let dim = self.infer_dim(&op, &inputs);
        self.nodes.push(Node { op, inputs, dim });
        id
    }

    fn infer_dim(&self, op: &Op, inputs: &[NodeId]) -> usize {
        match op {
            Op::Input { dim } => *dim,
            Op::Linear { weight, bias } => {
                assert_eq!(inputs.len(), 1, "linear takes one parent");
                let in_dim = self.nodes[inputs[0]].dim;
                assert_eq!(
                    weight.dims()[1],
                    in_dim,
                    "linear weight in-dim {} != parent dim {}",
                    weight.dims()[1],
                    in_dim
                );
                assert_eq!(weight.dims()[0], bias.len(), "bias length mismatch");
                weight.dims()[0]
            }
            Op::Activation { .. } => {
                assert_eq!(inputs.len(), 1, "activation takes one parent");
                self.nodes[inputs[0]].dim
            }
            Op::Slice { start, len } => {
                assert_eq!(inputs.len(), 1, "slice takes one parent");
                let d = self.nodes[inputs[0]].dim;
                assert!(start + len <= d, "slice [{start}, {start}+{len}) out of dim {d}");
                *len
            }
            Op::Add | Op::Mul => {
                assert!(inputs.len() >= 2, "add/mul take ≥2 parents");
                let d = self.nodes[inputs[0]].dim;
                for &p in inputs {
                    assert_eq!(self.nodes[p].dim, d, "add/mul dims must match");
                }
                d
            }
            Op::SumReduce => {
                assert_eq!(inputs.len(), 1, "sum_reduce takes one parent");
                1
            }
            Op::Concat => {
                assert!(!inputs.is_empty(), "concat needs ≥1 parent");
                inputs.iter().map(|&p| self.nodes[p].dim).sum()
            }
        }
    }

    // ---- convenience builders --------------------------------------------

    pub fn linear(&mut self, parent: NodeId, weight: Tensor, bias: Vec<f64>) -> NodeId {
        self.push(Op::Linear { weight, bias }, vec![parent])
    }

    pub fn activation(&mut self, parent: NodeId, act: Act) -> NodeId {
        self.push(Op::Activation { act }, vec![parent])
    }

    pub fn slice(&mut self, parent: NodeId, start: usize, len: usize) -> NodeId {
        self.push(Op::Slice { start, len }, vec![parent])
    }

    pub fn add(&mut self, parents: Vec<NodeId>) -> NodeId {
        self.push(Op::Add, parents)
    }

    pub fn mul(&mut self, parents: Vec<NodeId>) -> NodeId {
        self.push(Op::Mul, parents)
    }

    pub fn sum_reduce(&mut self, parent: NodeId) -> NodeId {
        self.push(Op::SumReduce, vec![parent])
    }

    // ---- accessors --------------------------------------------------------

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn input_ids(&self) -> &[NodeId] {
        &self.inputs
    }

    /// The output node (by convention, the last node).
    pub fn output(&self) -> NodeId {
        assert!(!self.nodes.is_empty());
        self.nodes.len() - 1
    }

    /// Total input dimension `N` (sum over input nodes).
    pub fn input_dim(&self) -> usize {
        self.inputs.iter().map(|&i| self.nodes[i].dim).sum()
    }

    /// For each node, the list of consumer node ids (`{j : i → j}`).
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut cons = vec![Vec::new(); self.nodes.len()];
        for (j, n) in self.nodes.iter().enumerate() {
            for &i in &n.inputs {
                cons[i].push(j);
            }
        }
        cons
    }

    /// Liveness horizon `τ(i) = max{j : i → j}` (eq. 24); `i` itself if the
    /// node has no consumers (its buffer dies immediately after creation,
    /// except the output which the caller holds).
    pub fn tau(&self) -> Vec<NodeId> {
        let mut tau: Vec<NodeId> = (0..self.nodes.len()).collect();
        for (j, n) in self.nodes.iter().enumerate() {
            for &i in &n.inputs {
                if j > tau[i] {
                    tau[i] = j;
                }
            }
        }
        tau
    }

    /// Total scalar neuron count `|V|` (Appendix D counts scalar nodes).
    pub fn scalar_node_count(&self) -> usize {
        self.nodes.iter().map(|n| n.dim).sum()
    }

    /// Batched forward evaluation of every node. `x` is `[batch, N]`.
    /// Returns per-node value tensors `[batch, dim]`.
    pub fn eval_all(&self, x: &Tensor) -> Vec<Tensor> {
        assert_eq!(x.rank(), 2, "input must be [batch, N]");
        let batch = x.dims()[0];
        assert_eq!(x.dims()[1], self.input_dim(), "input dim mismatch");
        let mut vals: Vec<Tensor> = Vec::with_capacity(self.nodes.len());
        // Split the flat input across input nodes in declaration order.
        let mut in_off = 0usize;
        for (id, n) in self.nodes.iter().enumerate() {
            let v = match &n.op {
                Op::Input { dim } => {
                    let mut t = Tensor::zeros(&[batch, *dim]);
                    for b in 0..batch {
                        t.row_mut(b).copy_from_slice(&x.row(b)[in_off..in_off + dim]);
                    }
                    in_off += dim;
                    t
                }
                Op::Linear { weight, bias } => {
                    // [batch, in] · Wᵀ → [batch, out]; then add bias.
                    let mut out = matmul_nt(&vals[n.inputs[0]], weight);
                    for b in 0..batch {
                        for (o, &bi) in out.row_mut(b).iter_mut().zip(bias.iter()) {
                            *o += bi;
                        }
                    }
                    out
                }
                Op::Activation { act } => vals[n.inputs[0]].map(|v| act.f(v)),
                Op::Slice { start, len } => {
                    let p = &vals[n.inputs[0]];
                    let mut t = Tensor::zeros(&[batch, *len]);
                    for b in 0..batch {
                        t.row_mut(b).copy_from_slice(&p.row(b)[*start..*start + *len]);
                    }
                    t
                }
                Op::Add => {
                    let mut acc = vals[n.inputs[0]].clone();
                    for &p in &n.inputs[1..] {
                        acc = acc.add(&vals[p]);
                    }
                    acc
                }
                Op::Mul => {
                    let mut acc = vals[n.inputs[0]].clone();
                    for &p in &n.inputs[1..] {
                        acc = acc.mul(&vals[p]);
                    }
                    acc
                }
                Op::SumReduce => {
                    let p = &vals[n.inputs[0]];
                    let mut t = Tensor::zeros(&[batch, 1]);
                    for b in 0..batch {
                        t.set(b, 0, p.row(b).iter().sum());
                    }
                    t
                }
                Op::Concat => {
                    let mut t = Tensor::zeros(&[batch, n.dim]);
                    for b in 0..batch {
                        let mut off = 0;
                        for &p in &n.inputs {
                            let pr = vals[p].row(b);
                            t.row_mut(b)[off..off + pr.len()].copy_from_slice(pr);
                            off += pr.len();
                        }
                    }
                    t
                }
            };
            debug_assert_eq!(v.dims(), &[batch, n.dim], "node {id} dim mismatch");
            vals.push(v);
        }
        vals
    }

    /// Forward evaluation returning only the output node value `[batch, out]`.
    pub fn eval(&self, x: &Tensor) -> Tensor {
        self.eval_all(x).pop().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    /// Build  φ(x) = sum( tanh(W x + b) )  for quick checks.
    fn tiny_graph(n_in: usize, n_hid: usize, seed: u64) -> Graph {
        let mut rng = Xoshiro256::new(seed);
        let mut g = Graph::new();
        let x = g.input(n_in);
        let w = Tensor::randn(&[n_hid, n_in], &mut rng);
        let b = (0..n_hid).map(|_| rng.normal()).collect();
        let lin = g.linear(x, w, b);
        let act = g.activation(lin, Act::Tanh);
        g.sum_reduce(act);
        g
    }

    #[test]
    fn topology_and_dims() {
        let g = tiny_graph(3, 5, 1);
        assert_eq!(g.len(), 4);
        assert_eq!(g.input_dim(), 3);
        assert_eq!(g.node(1).dim, 5);
        assert_eq!(g.node(g.output()).dim, 1);
    }

    #[test]
    fn eval_matches_manual() {
        let mut g = Graph::new();
        let x = g.input(2);
        let w = Tensor::matrix(&[vec![1.0, 2.0], vec![-1.0, 0.5]]);
        let lin = g.linear(x, w, vec![0.1, -0.2]);
        let act = g.activation(lin, Act::Square);
        g.sum_reduce(act);
        let input = Tensor::from_vec(&[1, 2], vec![3.0, -1.0]);
        let out = g.eval(&input);
        // Wx+b = [3-2+0.1, -3-0.5-0.2] = [1.1, -3.7]; squares: 1.21, 13.69
        assert!((out.item() - (1.21 + 13.69)).abs() < 1e-12);
    }

    #[test]
    fn batch_eval_is_rowwise() {
        let g = tiny_graph(4, 6, 2);
        let mut rng = Xoshiro256::new(3);
        let x = Tensor::randn(&[5, 4], &mut rng);
        let batch_out = g.eval(&x);
        for b in 0..5 {
            let single = Tensor::from_vec(&[1, 4], x.row(b).to_vec());
            let so = g.eval(&single);
            assert!((batch_out.at(b, 0) - so.item()).abs() < 1e-12);
        }
    }

    #[test]
    fn tau_liveness() {
        // x → lin → act → out; also x reused by a second lin consumed last.
        let mut g = Graph::new();
        let x = g.input(2);
        let l1 = g.linear(x, Tensor::eye(2), vec![0.0; 2]);
        let a1 = g.activation(l1, Act::Tanh);
        let l2 = g.linear(x, Tensor::eye(2), vec![0.0; 2]);
        let out = g.add(vec![a1, l2]);
        let tau = g.tau();
        assert_eq!(tau[x], l2); // x last used by l2
        assert_eq!(tau[a1], out);
        assert_eq!(tau[out], out); // no consumers
    }

    #[test]
    fn slice_concat_mul() {
        let mut g = Graph::new();
        let x = g.input(4);
        let a = g.slice(x, 0, 2);
        let b = g.slice(x, 2, 2);
        let m = g.mul(vec![a, b]);
        let c = g.push(Op::Concat, vec![m, a]);
        assert_eq!(g.node(c).dim, 4);
        let input = Tensor::from_vec(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let vals = g.eval_all(&input);
        assert_eq!(vals[m].row(0), &[3.0, 8.0]);
        assert_eq!(vals[c].row(0), &[3.0, 8.0, 1.0, 2.0]);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let mut g = Graph::new();
        let x = g.input(3);
        let _ = g.linear(x, Tensor::eye(2), vec![0.0; 2]); // 2×2 weight on dim-3 parent
    }
}
