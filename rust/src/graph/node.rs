//! Graph node operations.
//!
//! Nodes are *vector-valued* (a node holds a whole layer's worth of neurons),
//! matching how the paper's cost analysis groups the MLP computation graph
//! (Appendix A, Example A.1). Scalar-level quantities (`|E|`, `|R|`, `|T|`
//! from Appendix B) are derived analytically per op in
//! [`crate::autodiff::flops`].

use crate::tensor::Tensor;

/// Elementwise activation functions with first and second derivatives —
/// both are needed by the DOF propagation rule (eq. 9 uses `∂²F`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Act {
    Tanh,
    Sin,
    /// Gaussian error linear unit (tanh approximation).
    Gelu,
    Softplus,
    /// `x ↦ x²`, used in tests for its trivial second derivative.
    Square,
    Identity,
}

impl Act {
    /// σ(x)
    pub fn f(self, x: f64) -> f64 {
        match self {
            Act::Tanh => x.tanh(),
            Act::Sin => x.sin(),
            Act::Gelu => {
                let c = (2.0 / std::f64::consts::PI).sqrt();
                0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
            }
            Act::Softplus => {
                // Numerically stable log(1+e^x).
                if x > 30.0 {
                    x
                } else {
                    x.exp().ln_1p()
                }
            }
            Act::Square => x * x,
            Act::Identity => x,
        }
    }

    /// σ'(x)
    pub fn df(self, x: f64) -> f64 {
        match self {
            Act::Tanh => {
                let t = x.tanh();
                1.0 - t * t
            }
            Act::Sin => x.cos(),
            Act::Gelu => {
                let c = (2.0 / std::f64::consts::PI).sqrt();
                let u = c * (x + 0.044715 * x * x * x);
                let t = u.tanh();
                let du = c * (1.0 + 3.0 * 0.044715 * x * x);
                0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
            }
            Act::Softplus => 1.0 / (1.0 + (-x).exp()),
            Act::Square => 2.0 * x,
            Act::Identity => 1.0,
        }
    }

    /// σ''(x)
    pub fn d2f(self, x: f64) -> f64 {
        match self {
            Act::Tanh => {
                let t = x.tanh();
                -2.0 * t * (1.0 - t * t)
            }
            Act::Sin => -x.sin(),
            Act::Gelu => {
                let c = (2.0 / std::f64::consts::PI).sqrt();
                let u = c * (x + 0.044715 * x * x * x);
                let t = u.tanh();
                let sech2 = 1.0 - t * t;
                let du = c * (1.0 + 3.0 * 0.044715 * x * x);
                let d2u = c * 6.0 * 0.044715 * x;
                // d/dx [0.5(1+t) + 0.5 x sech2 du]
                0.5 * sech2 * du
                    + 0.5 * (sech2 * du + x * (-2.0 * t * sech2 * du * du + sech2 * d2u))
            }
            Act::Softplus => {
                let s = 1.0 / (1.0 + (-x).exp());
                s * (1.0 - s)
            }
            Act::Square => 2.0,
            Act::Identity => 0.0,
        }
    }

    /// σ'''(x) — needed only when *training through* the DOF operator
    /// (the eq. 9 term `σ''·|g|²` differentiates to `σ'''`). Returns `None`
    /// for activations where we have not implemented the closed form; the
    /// training tape rejects those with a clear error.
    pub fn d3f(self, x: f64) -> Option<f64> {
        match self {
            Act::Tanh => {
                let t = x.tanh();
                let s = 1.0 - t * t; // sech²
                // d/dx(-2 t s) = -2 s² + 4 t² s = s·(4t² − 2s)
                Some(s * (4.0 * t * t - 2.0 * s))
            }
            Act::Sin => Some(-x.cos()),
            Act::Softplus => {
                let s = 1.0 / (1.0 + (-x).exp());
                Some(s * (1.0 - s) * (1.0 - 2.0 * s))
            }
            Act::Square => Some(0.0),
            Act::Identity => Some(0.0),
            // The tanh-approximated GELU third derivative is unwieldy;
            // PINN training uses tanh/sin in this release.
            Act::Gelu => None,
        }
    }

    /// σ''''(x) — needed by order-4 Taylor-mode jet propagation
    /// ([`crate::jet`]): the Faà di Bruno composition of a fourth-order jet
    /// through σ carries a `σ''''·a₁⁴/24` term. Like [`Self::d3f`], returns
    /// `None` where the closed form is not implemented (tanh-approximated
    /// GELU); the jet compiler rejects those with a clear error instead of
    /// silently truncating.
    pub fn d4f(self, x: f64) -> Option<f64> {
        match self {
            Act::Tanh => {
                let t = x.tanh();
                let s = 1.0 - t * t; // sech²
                // d/dx [s·(4t² − 2s)] = −2ts·(4t²−2s) + s·(8ts + 4ts)
                //                     = 8ts² − 8t³s + 8ts² = 8ts(2s − t²)
                Some(8.0 * t * s * (2.0 * s - t * t))
            }
            Act::Sin => Some(x.sin()),
            Act::Softplus => {
                let s = 1.0 / (1.0 + (-x).exp());
                // d/dx [s(1−s)(1−2s)] = s(1−s)·(1 − 6s + 6s²)
                Some(s * (1.0 - s) * (1.0 - 6.0 * s + 6.0 * s * s))
            }
            Act::Square => Some(0.0),
            Act::Identity => Some(0.0),
            Act::Gelu => None,
        }
    }

    /// Is σ linear (zero second derivative everywhere)?
    pub fn is_linear(self) -> bool {
        matches!(self, Act::Identity)
    }
}

/// Node identifier (index into the graph's arena, topological by
/// construction).
pub type NodeId = usize;

/// Vector-valued operation.
#[derive(Debug, Clone)]
pub enum Op {
    /// Graph input of dimension `dim` (the PDE coordinate block).
    Input { dim: usize },
    /// Affine map `W x + b`, `W: out×in`.
    Linear { weight: Tensor, bias: Vec<f64> },
    /// Elementwise activation.
    Activation { act: Act },
    /// Contiguous slice `x[start .. start+len]` of a single parent.
    Slice { start: usize, len: usize },
    /// Elementwise sum of ≥2 same-dimension parents.
    Add,
    /// Elementwise (Hadamard) product of ≥2 same-dimension parents — the
    /// sparse-MLP head multiplies per-block outputs elementwise.
    Mul,
    /// Sum all components of a single parent to a scalar (dim-1) output —
    /// the sparse-MLP head reduces `Σ_d Π_i [MLP^i]_d`.
    SumReduce,
    /// Concatenate parents along the feature axis.
    Concat,
}

impl Op {
    /// Short name for diagnostics.
    pub fn name(&self) -> &'static str {
        match self {
            Op::Input { .. } => "input",
            Op::Linear { .. } => "linear",
            Op::Activation { .. } => "activation",
            Op::Slice { .. } => "slice",
            Op::Add => "add",
            Op::Mul => "mul",
            Op::SumReduce => "sum_reduce",
            Op::Concat => "concat",
        }
    }

    /// Does this op have a nonzero second derivative in any argument pair?
    /// (Determines whether it contributes to the `|T|` term of eq. 9/14.)
    pub fn is_nonlinear(&self) -> bool {
        match self {
            Op::Activation { act } => !act.is_linear(),
            Op::Mul => true,
            _ => false,
        }
    }
}

/// A node: an op applied to parent nodes, with a known output dimension.
#[derive(Debug, Clone)]
pub struct Node {
    pub op: Op,
    pub inputs: Vec<NodeId>,
    pub dim: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Check df/d2f against central finite differences.
    fn check_derivs(act: Act, xs: &[f64], tol: f64) {
        let h = 1e-5;
        for &x in xs {
            let fd1 = (act.f(x + h) - act.f(x - h)) / (2.0 * h);
            let fd2 = (act.f(x + h) - 2.0 * act.f(x) + act.f(x - h)) / (h * h);
            assert!(
                (act.df(x) - fd1).abs() < tol,
                "{act:?} df({x}) = {} vs fd {}",
                act.df(x),
                fd1
            );
            // Central second differences have ~ε/h² ≈ 1e-6 roundoff floor.
            assert!(
                (act.d2f(x) - fd2).abs() < (tol * 10.0).max(5e-5),
                "{act:?} d2f({x}) = {} vs fd {}",
                act.d2f(x),
                fd2
            );
        }
    }

    #[test]
    fn activation_derivatives_match_finite_difference() {
        let xs = [-2.0, -0.7, -0.1, 0.0, 0.3, 1.1, 2.5];
        check_derivs(Act::Tanh, &xs, 1e-8);
        check_derivs(Act::Sin, &xs, 1e-8);
        check_derivs(Act::Gelu, &xs, 1e-6);
        check_derivs(Act::Softplus, &xs, 1e-8);
        check_derivs(Act::Square, &xs, 1e-6);
        check_derivs(Act::Identity, &xs, 1e-9);
    }

    #[test]
    fn third_derivatives_match_finite_difference() {
        let xs = [-1.5, -0.4, 0.0, 0.6, 1.8];
        let h = 1e-4;
        for act in [Act::Tanh, Act::Sin, Act::Softplus, Act::Square, Act::Identity] {
            for &x in &xs {
                let fd3 = (act.d2f(x + h) - act.d2f(x - h)) / (2.0 * h);
                let got = act.d3f(x).unwrap();
                assert!(
                    (got - fd3).abs() < 1e-5,
                    "{act:?} d3f({x}) = {got} vs fd {fd3}"
                );
            }
        }
        assert!(Act::Gelu.d3f(0.5).is_none());
    }

    #[test]
    fn fourth_derivatives_match_finite_difference() {
        let xs = [-1.5, -0.4, 0.0, 0.6, 1.8];
        let h = 1e-4;
        for act in [Act::Tanh, Act::Sin, Act::Softplus, Act::Square, Act::Identity] {
            for &x in &xs {
                let fd4 = (act.d3f(x + h).unwrap() - act.d3f(x - h).unwrap()) / (2.0 * h);
                let got = act.d4f(x).unwrap();
                assert!(
                    (got - fd4).abs() < 1e-5,
                    "{act:?} d4f({x}) = {got} vs fd {fd4}"
                );
            }
        }
        assert!(Act::Gelu.d4f(0.5).is_none());
    }

    #[test]
    fn linearity_flags() {
        assert!(Act::Identity.is_linear());
        assert!(!Act::Tanh.is_linear());
        assert!(Op::Mul.is_nonlinear());
        assert!(!Op::Add.is_nonlinear());
        assert!(!Op::Linear {
            weight: Tensor::eye(2),
            bias: vec![0.0; 2]
        }
        .is_nonlinear());
    }
}
