//! Direction basis: turn a symbolic constant-coefficient operator of order
//! ≤ 4 into a set of jet directions plus contraction weights.
//!
//! The m-th differential of `φ` at `x` is a symmetric m-linear form `Tₘ`;
//! an order-k jet along direction `u` yields its diagonal values
//! `Tₘ(u,…,u) = m!·cₘ` for every `m ≤ k` in one propagation. Off-diagonal
//! entries (mixed partials like `∂⁴/∂xᵢ²∂xⱼ²`) are recovered by
//! **polarization** — signed combinations of diagonal evaluations along
//! `{eᵢ, eᵢ±eⱼ, …}`:
//!
//! ```text
//! ∂²ᵢⱼ       =  c₂(eᵢ+eⱼ) − c₂(eᵢ) − c₂(eⱼ)
//! ∂³ᵢᵢⱼ      =  c₃(eᵢ+eⱼ) − c₃(eᵢ−eⱼ) − 2c₃(eⱼ)
//! ∂⁴ᵢᵢⱼⱼ     =  2[c₄(eᵢ+eⱼ) + c₄(eᵢ−eⱼ) − 2c₄(eᵢ) − 2c₄(eⱼ)]
//! ```
//!
//! (`cₘ(u)` is the m-th normalized Taylor coefficient of `τ ↦ φ(x+τu)`.)
//! Terms with at most two distinct axes use these shared identities, so the
//! biharmonic `Δ² = Σᵢ∂⁴ᵢ + 2Σ_{i<j}∂⁴ᵢᵢⱼⱼ` needs exactly the `d²`
//! directions `{eᵢ} ∪ {eᵢ±eⱼ}`. Anything rarer (≥3 distinct axes, `iiij`
//! patterns) falls back to the general polarization identity
//! `T(u₁…uₘ) = 2⁻ᵐ Σ_{ε∈{±1}ᵐ} (Πε)·cₘ(Σεₗuₗ)`, exact for any multi-index.
//!
//! Directions are integer vectors, deduplicated exactly across terms (with
//! sign canonicalization: `cₘ(−u) = (−1)ᵐ cₘ(u)`), and weights are dyadic
//! rationals accumulated exactly — the assembly introduces no rounding of
//! its own. An optional first-order `b·∇` term rides along as one extra
//! (float) direction with a weight on `c₁`.

use std::collections::BTreeMap;

use crate::tensor::Tensor;

/// One constant-coefficient derivative term `coef · ∂^m φ / ∂x_{axes}`.
///
/// `axes` is the multi-index as a list of (repeatable) coordinate axes;
/// its length is the derivative order `m ∈ 1..=4`. `∂⁴/∂xᵢ²∂xⱼ²` is
/// `axes = [i, i, j, j]`.
#[derive(Debug, Clone, PartialEq)]
pub struct JetTerm {
    /// Sorted multi-index (length = derivative order, 1..=4).
    pub axes: Vec<usize>,
    /// Constant coefficient.
    pub coef: f64,
}

impl JetTerm {
    /// A term `coef · ∂^{|axes|} / ∂x_axes`; axes are sorted internally.
    pub fn new(axes: &[usize], coef: f64) -> Self {
        assert!(
            (1..=4).contains(&axes.len()),
            "jet terms support derivative orders 1..=4, got {}",
            axes.len()
        );
        assert!(coef.is_finite(), "non-finite term coefficient");
        let mut axes = axes.to_vec();
        axes.sort_unstable();
        Self { axes, coef }
    }

    /// Derivative order `m = |axes|`.
    pub fn order(&self) -> usize {
        self.axes.len()
    }
}

/// Second-order terms `Σ a_ij ∂²_ij` from a symmetric matrix (diagonal
/// terms once, off-diagonal pairs with coefficient `2·a_ij`) — the bridge
/// between the [`crate::operators::Operator`] world and the jet basis,
/// used by the order-2 cross-check tests.
pub fn terms_from_symmetric(a: &Tensor) -> Vec<JetTerm> {
    let n = a.dims()[0];
    assert_eq!(a.dims(), &[n, n], "coefficient matrix must be square");
    let mut terms = Vec::new();
    for i in 0..n {
        if a.at(i, i) != 0.0 {
            terms.push(JetTerm::new(&[i, i], a.at(i, i)));
        }
        for j in (i + 1)..n {
            let v = a.at(i, j);
            if v != 0.0 {
                terms.push(JetTerm::new(&[i, j], 2.0 * v));
            }
        }
    }
    terms
}

/// Laplacian terms `Σᵢ ∂²ᵢ` scaled by `coef`.
pub fn laplacian_terms(d: usize, coef: f64) -> Vec<JetTerm> {
    (0..d).map(|i| JetTerm::new(&[i, i], coef)).collect()
}

/// Biharmonic terms `coef·Δ² = coef·(Σᵢ ∂⁴ᵢ + 2Σ_{i<j} ∂⁴ᵢᵢⱼⱼ)`.
pub fn biharmonic_terms(d: usize, coef: f64) -> Vec<JetTerm> {
    let mut terms = Vec::new();
    for i in 0..d {
        terms.push(JetTerm::new(&[i, i, i, i], coef));
    }
    for i in 0..d {
        for j in (i + 1)..d {
            terms.push(JetTerm::new(&[i, i, j, j], 2.0 * coef));
        }
    }
    terms
}

/// A compiled direction basis: `t` jet directions (rows of `dirs`) and the
/// contraction `L[φ] = Σ weights (dir, m, w) → w · cₘ^{(dir)}[φ]` (each
/// weight already folds in the `m!` and the polarization factors).
#[derive(Debug, Clone)]
pub struct DirectionBasis {
    /// Input dimension `N`.
    pub n: usize,
    /// Jet order `k` (max derivative order over the terms; ≥ 1).
    pub order: usize,
    /// Direction matrix `[t, N]` — the jet seed.
    pub dirs: Tensor,
    /// Contraction weights `(direction index, coefficient order m, weight)`,
    /// sorted by `(direction, m)`, zero entries dropped.
    pub weights: Vec<(usize, usize, f64)>,
}

impl DirectionBasis {
    /// Number of jet directions `t`.
    pub fn directions(&self) -> usize {
        self.dirs.dims()[0]
    }

    /// Assemble a basis for `Σ terms + b·∇` on `R^n` by polarization.
    pub fn from_terms(n: usize, terms: &[JetTerm], b: Option<&[f64]>) -> Self {
        assert!(
            !terms.is_empty() || b.is_some(),
            "operator needs at least one term"
        );
        let mut order = terms.iter().map(JetTerm::order).max().unwrap_or(0);
        if b.is_some() {
            order = order.max(1);
        }
        let mut builder = Builder::new(n);
        for t in terms {
            assert!(
                t.axes.iter().all(|&a| a < n),
                "term axis out of range: {:?} for N = {n}",
                t.axes
            );
            builder.push_term(t);
        }
        if let Some(bv) = b {
            assert_eq!(bv.len(), n, "b length must be N");
            builder.push_float_direction(bv, 1, 1.0);
        }
        builder.finish(order)
    }
}

/// Incremental basis assembly: exact integer-direction dedup plus exact
/// (dyadic-rational) weight accumulation.
struct Builder {
    n: usize,
    /// Canonicalized integer direction → index.
    int_dirs: BTreeMap<Vec<i64>, usize>,
    /// Direction rows in insertion order (floats, ready for the seed).
    rows: Vec<Vec<f64>>,
    /// (direction, m) → accumulated weight.
    weights: BTreeMap<(usize, usize), f64>,
}

impl Builder {
    fn new(n: usize) -> Self {
        Self {
            n,
            int_dirs: BTreeMap::new(),
            rows: Vec::new(),
            weights: BTreeMap::new(),
        }
    }

    /// Intern an integer direction, canonicalizing the sign so `u` and `−u`
    /// share one row. Returns `(index, flipped)`.
    fn intern(&mut self, mut u: Vec<i64>) -> (usize, bool) {
        let first = u.iter().find(|&&v| v != 0).copied().unwrap_or(0);
        debug_assert!(first != 0, "zero direction must be skipped by callers");
        let flipped = first < 0;
        if flipped {
            for v in u.iter_mut() {
                *v = -*v;
            }
        }
        if let Some(&idx) = self.int_dirs.get(&u) {
            return (idx, flipped);
        }
        let idx = self.rows.len();
        self.rows.push(u.iter().map(|&v| v as f64).collect());
        self.int_dirs.insert(u, idx);
        (idx, flipped)
    }

    /// Add `w · cₘ(u)` for an integer direction (sign-folded through the
    /// parity `cₘ(−u) = (−1)ᵐ cₘ(u)`).
    fn add(&mut self, u: Vec<i64>, m: usize, w: f64) {
        if u.iter().all(|&v| v == 0) || w == 0.0 {
            return;
        }
        let (idx, flipped) = self.intern(u);
        let w = if flipped && m % 2 == 1 { -w } else { w };
        *self.weights.entry((idx, m)).or_insert(0.0) += w;
    }

    /// Add `w · cₘ(u)` for an arbitrary float direction (no dedup — used
    /// for the `b·∇` row).
    fn push_float_direction(&mut self, u: &[f64], m: usize, w: f64) {
        let idx = self.rows.len();
        self.rows.push(u.to_vec());
        *self.weights.entry((idx, m)).or_insert(0.0) += w;
    }

    fn axis(&self, i: usize) -> Vec<i64> {
        let mut u = vec![0i64; self.n];
        u[i] = 1;
        u
    }

    fn pair(&self, i: usize, j: usize, sign: i64) -> Vec<i64> {
        let mut u = vec![0i64; self.n];
        u[i] = 1;
        u[j] = sign;
        u
    }

    /// Expand one term into weighted diagonal evaluations.
    fn push_term(&mut self, term: &JetTerm) {
        let m = term.order();
        let coef = term.coef;
        // Distinct axes with multiplicities (axes are sorted).
        let mut counts: Vec<(usize, usize)> = Vec::new();
        for &a in &term.axes {
            match counts.last_mut() {
                Some((ax, c)) if *ax == a => *c += 1,
                _ => counts.push((a, 1)),
            }
        }
        match counts.as_slice() {
            // Pure power ∂ᵐᵢ = m!·cₘ(eᵢ).
            [(i, _)] => {
                let fact = [1.0, 1.0, 2.0, 6.0, 24.0][m];
                let ei = self.axis(*i);
                self.add(ei, m, coef * fact);
            }
            // ∂²ᵢⱼ = c₂(eᵢ+eⱼ) − c₂(eᵢ) − c₂(eⱼ).
            [(i, 1), (j, 1)] if m == 2 => {
                let (i, j) = (*i, *j);
                let (pij, ei, ej) = (self.pair(i, j, 1), self.axis(i), self.axis(j));
                self.add(pij, 2, coef);
                self.add(ei, 2, -coef);
                self.add(ej, 2, -coef);
            }
            // ∂³ₚₚᵩ = c₃(eₚ+eᵩ) − c₃(eₚ−eᵩ) − 2c₃(eᵩ), p the doubled axis.
            [(p, 2), (q, 1)] | [(q, 1), (p, 2)] if m == 3 => {
                let (p, q) = (*p, *q);
                // pair(p, q, −1) is eₚ−eᵩ regardless of p<q ordering; the
                // intern step canonicalizes the sign with odd-m parity.
                let (plus, minus, eq) =
                    (self.pair(p, q, 1), self.pair(p, q, -1), self.axis(q));
                self.add(plus, 3, coef);
                self.add(minus, 3, -coef);
                self.add(eq, 3, -2.0 * coef);
            }
            // ∂⁴ᵢᵢⱼⱼ = 2[c₄(eᵢ+eⱼ) + c₄(eᵢ−eⱼ) − 2c₄(eᵢ) − 2c₄(eⱼ)].
            [(i, 2), (j, 2)] if m == 4 => {
                let (i, j) = (*i, *j);
                let (plus, minus, ei, ej) = (
                    self.pair(i, j, 1),
                    self.pair(i, j, -1),
                    self.axis(i),
                    self.axis(j),
                );
                self.add(plus, 4, 2.0 * coef);
                self.add(minus, 4, 2.0 * coef);
                self.add(ei, 4, -4.0 * coef);
                self.add(ej, 4, -4.0 * coef);
            }
            // General polarization: T(u₁…uₘ) = 2⁻ᵐ Σ_ε (Πε)·cₘ(Σ εₗuₗ).
            _ => {
                let scale = coef / (1u64 << m) as f64;
                for eps in 0..(1u32 << m) {
                    let mut u = vec![0i64; self.n];
                    let mut parity = 1.0;
                    for (l, &a) in term.axes.iter().enumerate() {
                        if eps & (1 << l) != 0 {
                            u[a] += 1;
                        } else {
                            u[a] -= 1;
                            parity = -parity;
                        }
                    }
                    self.add(u, m, scale * parity);
                }
            }
        }
    }

    fn finish(self, order: usize) -> DirectionBasis {
        let n = self.n;
        let t = self.rows.len();
        assert!(t > 0, "basis assembled zero directions");
        let mut data = Vec::with_capacity(t * n);
        for row in &self.rows {
            data.extend_from_slice(row);
        }
        let mut weights: Vec<(usize, usize, f64)> = self
            .weights
            .into_iter()
            .filter(|&(_, w)| w != 0.0)
            .map(|((d, m), w)| (d, m, w))
            .collect();
        weights.sort_by_key(|&(d, m, _)| (d, m));
        DirectionBasis {
            n,
            order,
            dirs: Tensor::from_vec(&[t, n], data),
            weights,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Evaluate the basis contraction on a function with known derivatives:
    /// φ(x) = Π xᵢ^{pᵢ} — every directional Taylor coefficient is computable
    /// in closed form, so the assembled weights can be checked exactly.
    fn contract_on_monomial(basis: &DirectionBasis, pows: &[usize], x: &[f64]) -> f64 {
        // cₘ(u) at x for φ = Π xᵢ^{pᵢ}: coefficient of τᵐ in Π (xᵢ+τuᵢ)^{pᵢ}.
        let t = basis.directions();
        let k = basis.order;
        let mut c = vec![vec![0.0; k + 1]; t];
        for (ti, cm) in c.iter_mut().enumerate() {
            let u = basis.dirs.row(ti);
            // Polynomial multiply of per-axis binomial expansions.
            let mut poly = vec![1.0];
            for (i, &p) in pows.iter().enumerate() {
                for _ in 0..p {
                    // multiply by (xᵢ + τ uᵢ)
                    let mut next = vec![0.0; poly.len() + 1];
                    for (deg, &pc) in poly.iter().enumerate() {
                        next[deg] += pc * x[i];
                        next[deg + 1] += pc * u[i];
                    }
                    poly = next;
                }
            }
            for m in 0..=k.min(poly.len() - 1) {
                cm[m] = poly[m];
            }
        }
        let mut out = 0.0;
        for &(d, m, w) in &basis.weights {
            out += w * c[d][m];
        }
        out
    }

    /// Exact partial derivative of the monomial Π xᵢ^{pᵢ}.
    fn monomial_partial(pows: &[usize], axes: &[usize], x: &[f64]) -> f64 {
        let mut p: Vec<i64> = pows.iter().map(|&v| v as i64).collect();
        let mut coef = 1.0;
        for &a in axes {
            coef *= p[a] as f64;
            p[a] -= 1;
            if p[a] < 0 {
                return 0.0;
            }
        }
        let mut v = coef;
        for (i, &pi) in p.iter().enumerate() {
            v *= x[i].powi(pi as i32);
        }
        v
    }

    fn check_term(axes: &[usize], n: usize) {
        let term = JetTerm::new(axes, 1.0);
        let basis = DirectionBasis::from_terms(n, &[term], None);
        // Check against several monomials of total degree ≥ the order.
        let x = [1.3, -0.7, 0.9, 1.1];
        for pows in [
            vec![4, 0, 0, 0],
            vec![2, 2, 0, 0],
            vec![1, 1, 1, 1],
            vec![2, 1, 1, 0],
            vec![3, 1, 0, 0],
            vec![0, 2, 1, 1],
        ] {
            let got = contract_on_monomial(&basis, &pows[..n], &x[..n]);
            let want = monomial_partial(&pows[..n], axes, &x[..n]);
            assert!(
                (got - want).abs() < 1e-9 * want.abs().max(1.0),
                "∂{axes:?} on x^{pows:?}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn pure_powers_exact() {
        check_term(&[0], 3);
        check_term(&[1, 1], 3);
        check_term(&[2, 2, 2], 3);
        check_term(&[0, 0, 0, 0], 3);
    }

    #[test]
    fn two_axis_identities_exact() {
        check_term(&[0, 1], 3); // ∂²ᵢⱼ
        check_term(&[0, 0, 1], 3); // ∂³ᵢᵢⱼ
        check_term(&[0, 2, 2], 3); // ∂³ᵢⱼⱼ (doubled axis second)
        check_term(&[1, 1, 2, 2], 3); // ∂⁴ᵢᵢⱼⱼ
    }

    #[test]
    fn general_polarization_exact() {
        check_term(&[0, 1, 2], 3); // three distinct axes, order 3
        check_term(&[0, 0, 0, 1], 3); // iiij pattern
        check_term(&[0, 1, 2, 3], 4); // four distinct axes
        check_term(&[0, 0, 1, 2], 3); // iijl pattern
    }

    #[test]
    fn biharmonic_directions_are_d_squared() {
        for d in [2usize, 3, 5] {
            let basis = DirectionBasis::from_terms(d, &biharmonic_terms(d, 1.0), None);
            assert_eq!(basis.directions(), d * d, "d = {d}");
            assert_eq!(basis.order, 4);
        }
    }

    #[test]
    fn biharmonic_of_radial_quartic() {
        // φ = (Σ xᵢ²)² has Δ²φ = 8d + 16·d... compute exactly instead via
        // monomials: Δ²(x₀⁴) = 24; Δ²(x₀²x₁²) = 8. φ = Σᵢ xᵢ⁴ + Σ_{i≠j} xᵢ²xⱼ².
        let d = 3;
        let basis = DirectionBasis::from_terms(d, &biharmonic_terms(d, 1.0), None);
        let x = [0.4, -1.2, 0.8];
        let mut got = 0.0;
        let mut want = 0.0;
        for i in 0..d {
            let mut pows = vec![0usize; d];
            pows[i] = 4;
            got += contract_on_monomial(&basis, &pows, &x);
            want += 24.0;
            for j in 0..d {
                if j != i {
                    let mut pw = vec![0usize; d];
                    pw[i] = 2;
                    pw[j] = 2;
                    got += contract_on_monomial(&basis, &pw, &x);
                    want += 8.0;
                }
            }
        }
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }

    #[test]
    fn symmetric_matrix_terms_match_quadratic_form() {
        // L = Σ a_ij ∂²_ij on φ = xᵀMx has L[φ] = Σ a_ij (M + Mᵀ)_ij.
        let a = Tensor::matrix(&[
            vec![2.0, 0.5, 0.0],
            vec![0.5, -1.0, 1.5],
            vec![0.0, 1.5, 3.0],
        ]);
        let terms = terms_from_symmetric(&a);
        let basis = DirectionBasis::from_terms(3, &terms, None);
        // φ = x₀² + x₀x₁ + 2x₁x₂: Hessian H = [[2,1,0],[1,0,2],[0,2,0]].
        let x = [0.3, 0.7, -0.2];
        let got = contract_on_monomial(&basis, &[2, 0, 0], &x)
            + contract_on_monomial2(&basis, &[(0, 1), (1, 1)], &x)
            + 2.0 * contract_on_monomial2(&basis, &[(1, 1), (2, 1)], &x);
        let h = [[2.0, 1.0, 0.0], [1.0, 0.0, 2.0], [0.0, 2.0, 0.0]];
        let mut want = 0.0;
        for i in 0..3 {
            for j in 0..3 {
                want += a.at(i, j) * h[i][j];
            }
        }
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }

    /// contract_on_monomial with sparse (axis, power) spec.
    fn contract_on_monomial2(
        basis: &DirectionBasis,
        spec: &[(usize, usize)],
        x: &[f64],
    ) -> f64 {
        let mut pows = vec![0usize; basis.n];
        for &(a, p) in spec {
            pows[a] = p;
        }
        contract_on_monomial(basis, &pows, x)
    }

    #[test]
    fn b_direction_rides_along() {
        let b = [0.5, -1.0];
        let basis =
            DirectionBasis::from_terms(2, &laplacian_terms(2, 1.0), Some(&b[..]));
        assert_eq!(basis.order, 2);
        assert_eq!(basis.directions(), 3); // e₀, e₁, b
        // On φ = x₀ (pows [1,0]): L = Δφ + b·∇φ = 0 + 0.5.
        let got = contract_on_monomial(&basis, &[1, 0], &[0.9, 0.1]);
        assert!((got - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn order_five_rejected() {
        let _ = JetTerm::new(&[0, 0, 0, 0, 0], 1.0);
    }
}
