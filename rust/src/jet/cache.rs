//! Keyed cache of compiled [`JetProgram`]s — the jet-side twin of
//! [`crate::plan::PlanCache`].
//!
//! Keys are value-independent ([`super::program::jet_key`] hashes graph
//! structure, the direction-matrix zero pattern, `(t, k)`, and the
//! zeroth-order flag — never weight or direction *values*), so serving and
//! repeated evaluation of the same `(architecture, operator)` pair compile
//! once and execute thereafter. The double-checked mechanism is the shared
//! [`KeyedCache`] ([`crate::util::keyed_cache`]); this module only
//! contributes the key derivation and the compile closure.

use std::sync::Arc;

use crate::graph::Graph;
use crate::util::keyed_cache::KeyedCache;

use super::basis::DirectionBasis;
use super::program::{jet_key, JetKey, JetProgram};

/// Bound on retained programs (oldest evicted past this).
pub const JET_CACHE_CAP: usize = 32;

/// Hit/miss counters plus current occupancy (the shared
/// [`crate::util::CacheStats`] shape).
pub type JetCacheStats = crate::util::CacheStats;

/// A keyed jet-program cache (see module docs).
pub struct JetCache {
    inner: KeyedCache<JetKey, JetProgram>,
}

impl JetCache {
    pub const fn new() -> Self {
        Self {
            inner: KeyedCache::new(JET_CACHE_CAP),
        }
    }

    /// Fetch the program for `(graph, basis, has_c)`, compiling on first
    /// use.
    pub fn get_or_compile(
        &self,
        graph: &Graph,
        basis: &DirectionBasis,
        has_c: bool,
    ) -> Arc<JetProgram> {
        let key = jet_key(graph, basis, has_c);
        self.inner
            .get_or_insert_with(key, || JetProgram::compile(graph, basis, has_c))
    }

    pub fn stats(&self) -> JetCacheStats {
        self.inner.stats()
    }

    /// Drop every retained program (counters are kept).
    pub fn clear(&self) {
        self.inner.clear()
    }
}

impl Default for JetCache {
    fn default() -> Self {
        Self::new()
    }
}

static GLOBAL: JetCache = JetCache::new();

/// The process-wide jet-program cache used by
/// [`super::JetEngine::compute*`](super::JetEngine) and the serving
/// backend.
pub fn global_jet_cache() -> &'static JetCache {
    &GLOBAL
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder::random_layers, mlp_graph, Act};
    use crate::jet::basis::{biharmonic_terms, laplacian_terms};
    use crate::util::Xoshiro256;

    #[test]
    fn second_lookup_hits_and_orders_partition() {
        let cache = JetCache::new();
        let mut rng = Xoshiro256::new(71);
        let g = mlp_graph(&random_layers(&[3, 7, 1], &mut rng), Act::Tanh);
        let b4 = DirectionBasis::from_terms(3, &biharmonic_terms(3, 1.0), None);
        let b2 = DirectionBasis::from_terms(3, &laplacian_terms(3, 1.0), None);
        let p1 = cache.get_or_compile(&g, &b4, false);
        let p2 = cache.get_or_compile(&g, &b4, false);
        assert!(Arc::ptr_eq(&p1, &p2), "same key must reuse the program");
        let p3 = cache.get_or_compile(&g, &b2, false);
        assert!(!Arc::ptr_eq(&p1, &p3), "different order must recompile");
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 2, 2));
    }
}
