//! The jet operator engine: compile-then-run evaluation of higher-order
//! constant-coefficient operators, on the same rails as
//! [`crate::autodiff::DofEngine`] — keyed program cache, program-keyed
//! slab pool, deterministic batch sharding, and a retained reference
//! interpreter for differential testing.

use crate::autodiff::arena::{with_program_slab, SlabKey, TangentArena};
use crate::autodiff::{Cost, PeakTracker};
use crate::graph::{Graph, Op};
use crate::parallel::{self, Pool};
use crate::plan::{self, PanelSet};
use crate::tensor::{matmul_nt, Tensor};

use super::basis::DirectionBasis;
use super::cache::global_jet_cache;
use super::program::{execute_jet, JetProgram};
use super::{
    cauchy5, compose5, contract_output, extract_values, jet_bytes, validate_graph, JetBatch,
};
use std::sync::Arc;

/// Output of [`JetEngine::compute`].
pub struct JetResult {
    /// `φ(x)`, `[batch, out]`.
    pub values: Tensor,
    /// `L[φ](x)`, `[batch, out]` — the contracted higher-order operator.
    pub operator_values: Tensor,
    /// The full output jet, `[batch·t·(k+1), out]`.
    pub out_jet: JetBatch,
    /// Exact FLOP count of the run.
    pub cost: Cost,
    /// Peak live jet bytes (the jet analogue of Theorem 2.2's `M₁`;
    /// `m = 0` value rows included).
    pub peak_jet_bytes: u64,
}

/// The Taylor-mode jet engine, seeded by a direction basis.
pub struct JetEngine {
    basis: DirectionBasis,
    /// Optional zeroth-order coefficient `c` (adds `c·φ` at the output).
    c: Option<f64>,
}

impl JetEngine {
    pub fn new(basis: DirectionBasis) -> Self {
        Self { basis, c: None }
    }

    /// Add a zeroth-order `c·φ` term.
    pub fn with_constant(mut self, c: Option<f64>) -> Self {
        self.c = c;
        self
    }

    pub fn basis(&self) -> &DirectionBasis {
        &self.basis
    }

    pub fn constant(&self) -> Option<f64> {
        self.c
    }

    /// Input dimension `N`.
    pub fn n(&self) -> usize {
        self.basis.n
    }

    /// Jet order `k`.
    pub fn order(&self) -> usize {
        self.basis.order
    }

    /// Direction count `t` (the jet tangent width).
    pub fn directions(&self) -> usize {
        self.basis.directions()
    }

    /// Compile the jet program for `graph` — uncached; the `compute*`
    /// wrappers go through [`global_jet_cache`] instead.
    pub fn plan(&self, graph: &Graph) -> JetProgram {
        JetProgram::compile(graph, &self.basis, self.c.is_some())
    }

    /// The cached program for `graph` (compiled on first use).
    pub fn program(&self, graph: &Graph) -> Arc<JetProgram> {
        global_jet_cache().get_or_compile(graph, &self.basis, self.c.is_some())
    }

    /// Structured batch-input validation against `graph`'s input
    /// dimension (shared [`crate::tensor::ops::validate_batch_input`]
    /// gate — identical rejection message across every engine).
    pub fn validate_input(&self, graph: &Graph, x: &Tensor) -> Result<(), String> {
        crate::tensor::ops::validate_batch_input(graph.input_dim(), x)
    }

    /// Evaluate `L[φ]` on a batch `x: [batch, N]` in one forward jet pass
    /// (compile-then-run wrapper over the keyed global cache).
    pub fn compute(&self, graph: &Graph, x: &Tensor) -> JetResult {
        let program = self.program(graph);
        self.execute(&program, graph, x)
    }

    /// Execute a precompiled program with an exact-fit slab from the
    /// program-keyed pool.
    pub fn execute(&self, program: &JetProgram, graph: &Graph, x: &Tensor) -> JetResult {
        let key = SlabKey {
            program: program.key().fingerprint,
            rows: x.dims()[0],
        };
        let panels = plan::pack_panels(program.steps(), graph);
        with_program_slab(key, |slab| {
            self.execute_with_slab(program, graph, x, &panels, slab)
        })
    }

    /// Execute a precompiled program with caller-supplied slab storage and
    /// pre-packed weight panels (an all-`None` set is always valid and
    /// bit-identical).
    pub fn execute_with_slab(
        &self,
        program: &JetProgram,
        graph: &Graph,
        x: &Tensor,
        panels: &PanelSet,
        slab: &mut Vec<f64>,
    ) -> JetResult {
        execute_jet(program, graph, &self.basis, self.c, x, panels, slab)
    }

    /// [`Self::compute`] sharded across the process-wide pool
    /// (`--threads` / `DOF_THREADS`) in
    /// [`parallel::DEFAULT_SHARD_ROWS`]-row chunks.
    pub fn compute_parallel(&self, graph: &Graph, x: &Tensor) -> JetResult {
        self.compute_sharded(graph, x, &parallel::global(), parallel::DEFAULT_SHARD_ROWS)
    }

    /// Evaluate with the batch partitioned into fixed `shard_rows`-row
    /// chunks executed across `pool`.
    ///
    /// Determinism contract (same as the DOF engines): chunk boundaries
    /// depend only on the batch size and `shard_rows` — never on the pool
    /// width — and shard results are reduced in shard order, so `values`,
    /// `operator_values`, the output jet, `cost`, and `peak_jet_bytes`
    /// (the per-shard maximum) are bit-identical across thread counts, and
    /// per-row arithmetic is row-independent so they also match the
    /// unsharded [`Self::compute`] exactly.
    pub fn compute_sharded(
        &self,
        graph: &Graph,
        x: &Tensor,
        pool: &Pool,
        shard_rows: usize,
    ) -> JetResult {
        let program = self.program(graph);
        self.execute_sharded(&program, graph, x, pool, shard_rows)
    }

    /// [`Self::compute_sharded`] over a precompiled program.
    pub fn execute_sharded(
        &self,
        program: &JetProgram,
        graph: &Graph,
        x: &Tensor,
        pool: &Pool,
        shard_rows: usize,
    ) -> JetResult {
        let batch = x.dims()[0];
        let n = x.dims()[1];
        let ranges = parallel::split_rows(batch, shard_rows);
        if ranges.len() <= 1 {
            let serial = || self.execute(program, graph, x);
            // A 1-thread pool means genuinely serial, including the GEMMs.
            if pool.threads() == 1 {
                return parallel::with_serial_guard(serial);
            }
            return serial();
        }
        // Pack weight panels ONCE for the whole call and share them
        // read-only across shards — repacking per shard would undo the
        // point of packing.
        let panels = plan::pack_panels(program.steps(), graph);
        let shards = pool.run_sharded(ranges, |_, r| {
            let rows = r.end - r.start;
            let xs = Tensor::from_vec(&[rows, n], x.data()[r.start * n..r.end * n].to_vec());
            let key = SlabKey {
                program: program.key().fingerprint,
                rows,
            };
            with_program_slab(key, |slab| {
                self.execute_with_slab(program, graph, &xs, &panels, slab)
            })
        });
        merge_jet_shards(shards, batch)
    }

    /// The **reference interpreter**: a per-call graph walk with
    /// arena-recycled jet storage and runtime liveness bookkeeping. The
    /// planned executor replicates this pass operation for operation
    /// (sharing the [`compose5`]/[`cauchy5`] kernels), so
    /// `rust/tests/jet_equivalence.rs` asserts the two agree bit for bit on
    /// values, `L[φ]`, the output jet, FLOP counts, and peak jet bytes.
    pub fn compute_with_arena(
        &self,
        graph: &Graph,
        x: &Tensor,
        arena: &mut TangentArena,
    ) -> JetResult {
        let n = graph.input_dim();
        assert_eq!(self.basis.n, n, "basis N != graph input dim");
        let batch = x.dims()[0];
        let t = self.basis.directions();
        let k = self.basis.order;
        validate_graph(graph, k);
        let mut cost = Cost::zero();
        let mut peak = PeakTracker::new();

        let tau = graph.tau();
        let mut frees_at: Vec<Vec<usize>> = vec![Vec::new(); graph.len()];
        for i in 0..graph.len() {
            frees_at[tau[i]].push(i);
        }

        let mut jets: Vec<Option<JetBatch>> = (0..graph.len()).map(|_| None).collect();
        let mut in_off = 0usize;
        let out_id = graph.output();

        for j in 0..graph.len() {
            let node = graph.node(j);
            let jet = match &node.op {
                Op::Input { dim } => {
                    let d = *dim;
                    let mut g = arena_jet(arena, batch, t, k, d);
                    for b in 0..batch {
                        let xrow = &x.row(b)[in_off..in_off + d];
                        for dj in 0..t {
                            g.row_mut(b, dj, 0).copy_from_slice(xrow);
                            g.row_mut(b, dj, 1)
                                .copy_from_slice(&self.basis.dirs.row(dj)[in_off..in_off + d]);
                            // Orders ≥ 2 stay zero (arena jets are zeroed).
                        }
                    }
                    in_off += d;
                    g
                }
                Op::Linear { weight, bias } => {
                    let p = jets[node.inputs[0]].as_ref().unwrap();
                    let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
                    let rows = batch * t * (k + 1);
                    let data = matmul_nt(&p.data, weight);
                    cost.muls += (rows * out_d * in_d) as u64;
                    cost.adds += (rows * out_d * in_d) as u64;
                    let mut g = JetBatch { data, batch, t, k };
                    for b in 0..batch {
                        for dj in 0..t {
                            for (dst, &bi) in
                                g.row_mut(b, dj, 0).iter_mut().zip(bias.iter())
                            {
                                *dst += bi;
                            }
                        }
                    }
                    cost.adds += (batch * t * out_d) as u64;
                    g
                }
                Op::Activation { act } => {
                    let p = jets[node.inputs[0]].as_ref().unwrap();
                    let d = node.dim;
                    let mut g = arena_jet_scratch(arena, batch, t, k, d);
                    let mut a = [0.0; 5];
                    for b in 0..batch {
                        for dj in 0..t {
                            for c in 0..d {
                                for (m, am) in a.iter_mut().enumerate().take(k + 1) {
                                    *am = p.row(b, dj, m)[c];
                                }
                                let y = compose5(*act, k, &a);
                                for m in 0..=k {
                                    g.row_mut(b, dj, m)[c] = y[m];
                                }
                            }
                        }
                    }
                    let (cm, ca) = super::compose_flops(k);
                    cost.muls += (batch * t * d) as u64 * cm;
                    cost.adds += (batch * t * d) as u64 * ca;
                    g
                }
                Op::Slice { start, len } => {
                    let p = jets[node.inputs[0]].as_ref().unwrap();
                    let pd = p.dim();
                    let mut g = arena_jet_scratch(arena, batch, t, k, *len);
                    for r in 0..batch * t * (k + 1) {
                        g.data
                            .row_mut(r)
                            .copy_from_slice(&p.data.row(r)[*start..*start + *len]);
                    }
                    debug_assert_eq!(pd, graph.node(node.inputs[0]).dim);
                    g
                }
                Op::Add => {
                    let p0 = jets[node.inputs[0]].as_ref().unwrap();
                    let mut g = arena_jet_scratch(arena, batch, t, k, node.dim);
                    g.data.data_mut().copy_from_slice(p0.data.data());
                    for &p in &node.inputs[1..] {
                        let pj = jets[p].as_ref().unwrap();
                        for (dst, &sv) in
                            g.data.data_mut().iter_mut().zip(pj.data.data().iter())
                        {
                            *dst += sv;
                        }
                        cost.adds += g.data.numel() as u64;
                    }
                    g
                }
                Op::Mul => {
                    let d = node.dim;
                    let p0 = jets[node.inputs[0]].as_ref().unwrap();
                    let mut g = arena_jet_scratch(arena, batch, t, k, d);
                    g.data.data_mut().copy_from_slice(p0.data.data());
                    let mut a = [0.0; 5];
                    let mut q = [0.0; 5];
                    let (cm, ca) = super::cauchy_flops(k);
                    for &p in &node.inputs[1..] {
                        let pj = jets[p].as_ref().unwrap();
                        for b in 0..batch {
                            for dj in 0..t {
                                for c in 0..d {
                                    for m in 0..=k {
                                        a[m] = g.row(b, dj, m)[c];
                                        q[m] = pj.row(b, dj, m)[c];
                                    }
                                    let y = cauchy5(k, &a, &q);
                                    for m in 0..=k {
                                        g.row_mut(b, dj, m)[c] = y[m];
                                    }
                                }
                            }
                        }
                        cost.muls += (batch * t * d) as u64 * cm;
                        cost.adds += (batch * t * d) as u64 * ca;
                    }
                    g
                }
                Op::SumReduce => {
                    let p = jets[node.inputs[0]].as_ref().unwrap();
                    let pd = p.dim();
                    let mut g = arena_jet_scratch(arena, batch, t, k, 1);
                    for r in 0..batch * t * (k + 1) {
                        g.data.data_mut()[r] = p.data.row(r)[..pd].iter().sum::<f64>();
                    }
                    cost.adds += (batch * t * (k + 1) * pd) as u64;
                    g
                }
                Op::Concat => {
                    let mut g = arena_jet_scratch(arena, batch, t, k, node.dim);
                    let d = node.dim;
                    let mut off = 0usize;
                    for &p in &node.inputs {
                        let pj = jets[p].as_ref().unwrap();
                        let pd = pj.dim();
                        for r in 0..batch * t * (k + 1) {
                            g.data.row_mut(r)[off..off + pd]
                                .copy_from_slice(pj.data.row(r));
                        }
                        off += pd;
                    }
                    debug_assert_eq!(off, d);
                    g
                }
            };

            peak.alloc(jet.bytes());
            jets[j] = Some(jet);

            for &i in &frees_at[j] {
                if i == out_id {
                    continue;
                }
                if let Some(g) = jets[i].take() {
                    peak.free(g.bytes());
                    arena.put_tensor(g.data);
                }
            }
        }

        let out_jet = jets[out_id].take().expect("graph has an output node");
        let d = out_jet.dim();
        let values = extract_values(out_jet.data.data(), batch, t, k, d);
        let operator_values =
            contract_output(&self.basis, self.c, out_jet.data.data(), &values, batch, d);
        // Contraction cost is batch-linear (the helper charges one row).
        let one = super::contract_flops(self.basis.weights.len(), self.c.is_some(), d);
        cost.muls += one.muls * batch as u64;
        cost.adds += one.adds * batch as u64;
        debug_assert_eq!(jet_bytes(batch, t, k, d), out_jet.bytes());
        JetResult {
            values,
            operator_values,
            out_jet,
            cost,
            peak_jet_bytes: peak.peak(),
        }
    }
}

/// Zeroed jet block backed by recycled arena storage.
fn arena_jet(arena: &mut TangentArena, batch: usize, t: usize, k: usize, dim: usize) -> JetBatch {
    JetBatch {
        data: arena.tensor(&[batch * t * (k + 1), dim]),
        batch,
        t,
        k,
    }
}

/// Non-zeroed jet block (every row fully assigned before reads).
fn arena_jet_scratch(
    arena: &mut TangentArena,
    batch: usize,
    t: usize,
    k: usize,
    dim: usize,
) -> JetBatch {
    JetBatch {
        data: arena.tensor_scratch(&[batch * t * (k + 1), dim]),
        batch,
        t,
        k,
    }
}

/// Stitch per-shard results back into one batch-ordered [`JetResult`]:
/// shard order is batch order, every node carries the full direction set,
/// so merging is pure concatenation (values, operator values, jet rows);
/// cost is the exact sum and the peak the per-shard maximum.
fn merge_jet_shards(shards: Vec<JetResult>, batch: usize) -> JetResult {
    let d = shards[0].values.dims()[1];
    let t = shards[0].out_jet.t;
    let k = shards[0].out_jet.k;
    let mut values = Tensor::zeros(&[batch, d]);
    let mut op_vals = Tensor::zeros(&[batch, d]);
    let mut out_jet = JetBatch::zeros(batch, t, k, d);
    let mut cost = Cost::zero();
    let mut peak = 0u64;
    let mut row = 0usize;
    let mut jrow = 0usize;
    for s in shards {
        let rows = s.values.dims()[0];
        values.data_mut()[row * d..(row + rows) * d].copy_from_slice(s.values.data());
        op_vals.data_mut()[row * d..(row + rows) * d]
            .copy_from_slice(s.operator_values.data());
        let jn = rows * t * (k + 1) * d;
        out_jet.data.data_mut()[jrow..jrow + jn].copy_from_slice(s.out_jet.data.data());
        cost += s.cost;
        peak = peak.max(s.peak_jet_bytes);
        row += rows;
        jrow += jn;
    }
    JetResult {
        values,
        operator_values: op_vals,
        out_jet,
        cost,
        peak_jet_bytes: peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder::random_layers, mlp_graph, sparse_mlp_graph, Act};
    use crate::jet::basis::{biharmonic_terms, laplacian_terms};
    use crate::util::Xoshiro256;

    #[test]
    fn values_match_plain_eval() {
        let mut rng = Xoshiro256::new(81);
        let g = mlp_graph(&random_layers(&[3, 8, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[4, 3], &mut rng);
        let basis = DirectionBasis::from_terms(3, &laplacian_terms(3, 1.0), None);
        let res = JetEngine::new(basis).compute(&g, &x);
        let eval = g.eval(&x);
        for b in 0..4 {
            assert_eq!(res.values.at(b, 0), eval.at(b, 0), "row {b}");
        }
    }

    #[test]
    fn biharmonic_of_quadratic_is_zero() {
        // φ = (w·x + b)² has all third and fourth derivatives ≡ 0.
        let mut g = crate::graph::Graph::new();
        let x = g.input(3);
        let lin = g.linear(
            x,
            Tensor::matrix(&[vec![0.7, -1.2, 0.4]]),
            vec![0.3],
        );
        g.activation(lin, Act::Square);
        let basis = DirectionBasis::from_terms(3, &biharmonic_terms(3, 1.0), None);
        let xs = Tensor::matrix(&[vec![0.2, 0.5, -0.8], vec![1.0, -0.3, 0.6]]);
        let res = JetEngine::new(basis).compute(&g, &xs);
        for b in 0..2 {
            assert!(
                res.operator_values.at(b, 0).abs() < 1e-9,
                "Δ² of a quadratic must vanish, got {}",
                res.operator_values.at(b, 0)
            );
        }
    }

    #[test]
    fn laplacian_of_quadratic_matches_closed_form() {
        // φ = (w·x)²: Δφ = 2|w|².
        let w = [0.7, -1.2, 0.4];
        let mut g = crate::graph::Graph::new();
        let x = g.input(3);
        let lin = g.linear(x, Tensor::matrix(&[w.to_vec()]), vec![0.0]);
        g.activation(lin, Act::Square);
        let basis = DirectionBasis::from_terms(3, &laplacian_terms(3, 1.0), None);
        let xs = Tensor::matrix(&[vec![0.3, 0.9, -0.2]]);
        let res = JetEngine::new(basis).compute(&g, &xs);
        let want = 2.0 * w.iter().map(|v| v * v).sum::<f64>();
        assert!(
            (res.operator_values.at(0, 0) - want).abs() < 1e-12,
            "{} vs {want}",
            res.operator_values.at(0, 0)
        );
    }

    #[test]
    fn interpreter_matches_planned_bitwise_on_sparse_arch() {
        let mut rng = Xoshiro256::new(82);
        let blocks: Vec<_> = (0..3)
            .map(|_| random_layers(&[2, 6, 3], &mut rng))
            .collect();
        let g = sparse_mlp_graph(&blocks, Act::Sin);
        let x = Tensor::randn(&[3, 6], &mut rng).scale(0.4);
        let basis = DirectionBasis::from_terms(6, &biharmonic_terms(6, 1.0), None);
        let eng = JetEngine::new(basis).with_constant(Some(0.7));
        let planned = eng.compute(&g, &x);
        let reference = eng.compute_with_arena(&g, &x, &mut TangentArena::new());
        assert_eq!(planned.values, reference.values);
        assert_eq!(planned.operator_values, reference.operator_values);
        assert_eq!(planned.out_jet.data, reference.out_jet.data);
        assert_eq!(planned.cost, reference.cost);
        assert_eq!(planned.peak_jet_bytes, reference.peak_jet_bytes);
    }
}
