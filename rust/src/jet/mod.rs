//! **Jet subsystem** — deterministic, exact Taylor-mode forward propagation
//! for third- and fourth-order differential operators.
//!
//! DOF (eqs. 7–9) pushes the order-2 tuple `(v, L∇v, L[v])` through the
//! graph. The same amortization extends to higher order: an **order-k
//! univariate jet** along direction `u` is the truncated Taylor expansion
//! of `τ ↦ φ(x + τu)`, carried as `k+1` normalized coefficients
//! `(c₀, c₁, …, c_k)` per node — `c₀` is the value itself and
//! `m!·c_m = ∂ᵐ/∂τᵐ φ(x+τu)`. Every graph op has an exact propagation
//! rule:
//!
//! * **Linear** — coefficient-wise affine map: one GEMM over all
//!   `t·(k+1)` folded rows (bias on the `m = 0` rows only), the same
//!   GEMM-shaped hot path as [`crate::autodiff::forward_jacobian`];
//! * **Activation** — Faà di Bruno composition through σ using
//!   `σ' … σ''''` ([`crate::graph::Act::d4f`]);
//! * **Mul** — the Cauchy (Leibniz) product of parent jets, folded
//!   pairwise in place;
//! * **Add / Slice / Concat / SumReduce** — coefficient-wise.
//!
//! Mixed derivatives (`∂⁴/∂xᵢ²∂xⱼ²` and friends) are assembled from
//! *diagonal* jet evaluations by polarization ([`basis`]): the biharmonic
//! `Δ²` needs exactly `d²` directions `{eᵢ} ∪ {eᵢ±eⱼ}`. A
//! [`basis::DirectionBasis`] holds the seed directions and the contraction
//! weights; [`engine::JetEngine`] runs the pass; [`JetProgram`] is the
//! compile-once plan (schedule with fused `Linear→Activation` steps,
//! static slab layout, exact analytic FLOP/peak), cached in
//! [`cache::global_jet_cache`] and executed shard-parallel under the PR 1
//! determinism contract (shard boundaries batch-only, shard-ordered
//! reduction — bit-identical across 1/2/4/8 threads;
//! `rust/tests/jet_equivalence.rs`).
//!
//! Storage folds batch, direction, and order into rows:
//! `[batch·t·(k+1), d]` with row index `(b·t + j)·(k+1) + m` — see
//! [`JetBatch`].
//!
//! At `k = 2` with directions `{rows of L}` and weights `2·sign` on `c₂`,
//! the jet pass computes exactly the DOF operator (the order-2 cross-check
//! asserts value bit-identity and `L[φ]` agreement to float-summation
//! order); at `k = 4` it reaches the biharmonic / Swift–Hohenberg /
//! Kuramoto–Sivashinsky class that the second-order engines cannot.

pub mod basis;
pub mod cache;
pub mod engine;
pub mod program;
pub mod stochastic;

pub use basis::{biharmonic_terms, laplacian_terms, terms_from_symmetric, DirectionBasis, JetTerm};
pub use cache::global_jet_cache;
pub use engine::{JetEngine, JetResult};
pub use program::JetProgram;
pub use stochastic::{DirectionSampling, StochasticJetEngine, StochasticJetResult};

use crate::autodiff::Cost;
use crate::graph::{Graph, Op};
use crate::tensor::Tensor;

/// Maximum supported jet order.
pub const MAX_ORDER: usize = 4;

/// Batched jet block for one node: rows are `(batch, direction, order)`
/// triples — row index `(b·t + j)·(k+1) + m` — columns are node
/// components. The `m = 0` rows carry the node *value* (replicated per
/// direction), which is what lets every op propagate the whole jet in one
/// uniform sweep (and the Linear op in one GEMM).
#[derive(Debug, Clone)]
pub struct JetBatch {
    /// `[batch·t·(k+1), d]`.
    pub data: Tensor,
    pub batch: usize,
    /// Direction count `t`.
    pub t: usize,
    /// Jet order `k` (each direction carries `k+1` coefficient rows).
    pub k: usize,
}

impl JetBatch {
    pub fn zeros(batch: usize, t: usize, k: usize, dim: usize) -> Self {
        Self {
            data: Tensor::zeros(&[batch * t * (k + 1), dim]),
            batch,
            t,
            k,
        }
    }

    /// Node dimension `d`.
    pub fn dim(&self) -> usize {
        self.data.dims()[1]
    }

    /// Bytes of the underlying buffer (f64).
    pub fn bytes(&self) -> u64 {
        (self.data.numel() * std::mem::size_of::<f64>()) as u64
    }

    /// Flat row index of `(b, j, m)`.
    #[inline]
    pub fn row_index(&self, b: usize, j: usize, m: usize) -> usize {
        (b * self.t + j) * (self.k + 1) + m
    }

    /// Coefficient row `c_m` of direction `j` at batch point `b`.
    pub fn row(&self, b: usize, j: usize, m: usize) -> &[f64] {
        self.data.row(self.row_index(b, j, m))
    }

    pub fn row_mut(&mut self, b: usize, j: usize, m: usize) -> &mut [f64] {
        let r = self.row_index(b, j, m);
        self.data.row_mut(r)
    }
}

/// Jet bytes of a node: `batch·t·(k+1)·d` f64 scalars. The `m = 0` value
/// rows are counted too — they live in the same buffer (unlike DOF, jets
/// carry no separate value stream).
pub fn jet_bytes(batch: usize, t: usize, k: usize, dim: usize) -> u64 {
    (batch * t * (k + 1) * dim * std::mem::size_of::<f64>()) as u64
}

// ---- shared arithmetic kernels -------------------------------------------
//
// Both execution paths — the reference interpreter
// (`JetEngine::compute_with_arena`) and the planned slab executor
// (`program::execute_jet`) — call the exact same per-(batch, direction,
// component) kernels, which is what makes them bit-identical by
// construction. The kernels themselves live in the crate-wide shared
// op-kernel module ([`crate::plan::kernels`]), alongside the DOF tuple and
// Hessian kernels; this module re-exports them and keeps the jet-side FLOP
// accounting.

pub(crate) use crate::plan::kernels::{cauchy5, compose5};

/// Exact per-component FLOP charge of [`compose5`] (multiplications,
/// additions), counted off its expression tree. σ, σ', … evaluations are
/// not charged (they are shared with the value pass, matching the DOF
/// engines' convention).
pub(crate) fn compose_flops(k: usize) -> (u64, u64) {
    match k {
        0 => (0, 0),
        1 => (1, 0),
        2 => (5, 1),   // + d1·a2, 0.5·d2·a1·a1
        3 => (12, 3),  // + d1·a3, d2·a1·a2, (d3/6)·a1³
        _ => (26, 7),  // + d1·a4, d2·(a1a3 + ½a2²), ½d3·a1²a2, (d4/24)·a1⁴
    }
}

/// Exact per-component FLOP charge of one [`cauchy5`] fold:
/// `Σ_{m≤k} (m+1)` muls, `Σ_{m≤k} m` adds.
pub(crate) fn cauchy_flops(k: usize) -> (u64, u64) {
    let k = k as u64;
    ((k + 1) * (k + 2) / 2, k * (k + 1) / 2)
}

/// Per-batch-row FLOP cost of the contraction
/// `L[φ] = Σ weights w·c_m + c·φ` over an `out_d`-dim output.
pub(crate) fn contract_flops(n_weights: usize, has_c: bool, out_d: usize) -> Cost {
    let mut c = Cost::zero();
    c.muls += (n_weights * out_d) as u64;
    c.adds += (n_weights * out_d) as u64;
    if has_c {
        c.muls += out_d as u64;
        c.adds += out_d as u64;
    }
    c
}

/// Contract an output jet (flat `[batch·t·(k+1), d]` slice) against the
/// basis weights: `L[φ][b, o] = Σ_{(j,m,w)} w·c_m^{(j)}[o] (+ c·φ[b, o])`.
/// `values` must be the `[batch, d]` output values (for the `c` term).
/// Shared by the interpreter and the planned executor.
pub(crate) fn contract_output(
    basis: &DirectionBasis,
    c_coef: Option<f64>,
    jet: &[f64],
    values: &Tensor,
    batch: usize,
    d: usize,
) -> Tensor {
    let t = basis.directions();
    let k = basis.order;
    debug_assert_eq!(jet.len(), batch * t * (k + 1) * d);
    let mut out = Tensor::zeros(&[batch, d]);
    for b in 0..batch {
        let orow = out.row_mut(b);
        for &(j, m, w) in &basis.weights {
            let r = (b * t + j) * (k + 1) + m;
            let src = &jet[r * d..(r + 1) * d];
            for (o, &s) in orow.iter_mut().zip(src.iter()) {
                *o += w * s;
            }
        }
        if let Some(c) = c_coef {
            for (o, &v) in orow.iter_mut().zip(values.row(b).iter()) {
                *o += c * v;
            }
        }
    }
    out
}

/// Extract the `[batch, d]` output values (direction 0, order 0 rows) from
/// a flat jet slice.
pub(crate) fn extract_values(jet: &[f64], batch: usize, t: usize, k: usize, d: usize) -> Tensor {
    let mut v = Tensor::zeros(&[batch, d]);
    for b in 0..batch {
        let r = b * t * (k + 1);
        v.row_mut(b).copy_from_slice(&jet[r * d..r * d + d]);
    }
    v
}

/// Reject graphs whose activations lack the σ-derivatives an order-`k` jet
/// needs (e.g. GELU above order 2) with a clear error, instead of failing
/// deep inside a propagation sweep.
pub(crate) fn validate_graph(graph: &Graph, k: usize) {
    assert!(
        (1..=MAX_ORDER).contains(&k),
        "jet order must be in 1..={MAX_ORDER}, got {k}"
    );
    for (id, node) in graph.nodes().iter().enumerate() {
        if let Op::Activation { act } = &node.op {
            if k >= 3 && act.d3f(0.0).is_none() {
                panic!(
                    "order-{k} jets need σ''' but {act:?} (node {id}) has no \
                     closed form; use tanh/sin/softplus or lower the order"
                );
            }
            if k >= 4 && act.d4f(0.0).is_none() {
                panic!(
                    "order-{k} jets need σ'''' but {act:?} (node {id}) has no \
                     closed form; use tanh/sin/softplus or lower the order"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Act;

    /// compose5 must reproduce the Taylor coefficients of σ(g(τ)) for a
    /// concrete polynomial g, checked against finite differences of the
    /// composed scalar function.
    #[test]
    fn compose_matches_taylor_of_composition() {
        let a = [0.3, 0.8, -0.5, 0.25, -0.1];
        let g = |tau: f64| {
            a[0] + a[1] * tau + a[2] * tau * tau + a[3] * tau.powi(3) + a[4] * tau.powi(4)
        };
        for act in [Act::Tanh, Act::Sin, Act::Softplus, Act::Square] {
            let y = compose5(act, 4, &a);
            let f = |tau: f64| act.f(g(tau));
            // Central finite differences of f at 0, each order at its own
            // sweet-spot step (truncation vs roundoff).
            let f0 = f(0.0);
            let d1 = {
                let h = 1e-6;
                (f(h) - f(-h)) / (2.0 * h)
            };
            let d2 = {
                let h = 1e-4;
                (f(h) - 2.0 * f0 + f(-h)) / (h * h)
            };
            let d3 = {
                let h = 1e-3;
                (f(2.0 * h) - 2.0 * f(h) + 2.0 * f(-h) - f(-2.0 * h)) / (2.0 * h * h * h)
            };
            let d4 = {
                let h = 5e-3;
                (f(2.0 * h) - 4.0 * f(h) + 6.0 * f0 - 4.0 * f(-h) + f(-2.0 * h)) / h.powi(4)
            };
            let fd = [f0, d1, d2 / 2.0, d3 / 6.0, d4 / 24.0];
            for (m, (&got, &want)) in y.iter().zip(fd.iter()).enumerate() {
                let tol = [1e-12, 1e-7, 1e-6, 1e-4, 2e-3][m];
                assert!(
                    (got - want).abs() < tol * want.abs().max(1.0),
                    "{act:?} c{m}: {got} vs fd {want}"
                );
            }
        }
    }

    #[test]
    fn cauchy_matches_polynomial_product() {
        let a = [1.0, 2.0, -1.0, 0.5, 0.0];
        let b = [3.0, -1.0, 0.25, 0.0, 1.0];
        let y = cauchy5(4, &a, &b);
        // Direct convolution.
        for m in 0..=4 {
            let mut want = 0.0;
            for i in 0..=m {
                want += a[i] * b[m - i];
            }
            assert_eq!(y[m], want);
        }
        // Truncation: k = 2 leaves higher entries zero.
        let y2 = cauchy5(2, &a, &b);
        assert_eq!(y2[3], 0.0);
        assert_eq!(y2[4], 0.0);
    }

    #[test]
    fn jet_batch_indexing_roundtrip() {
        let mut jb = JetBatch::zeros(2, 3, 4, 5);
        jb.row_mut(1, 2, 3)[4] = 7.0;
        assert_eq!(jb.row(1, 2, 3)[4], 7.0);
        assert_eq!(jb.data.dims(), &[2 * 3 * 5, 5]);
        assert_eq!(jb.bytes(), (2 * 3 * 5 * 5 * 8) as u64);
    }

    #[test]
    #[should_panic(expected = "σ'''")]
    fn gelu_rejected_at_order_three() {
        let mut g = Graph::new();
        let x = g.input(2);
        let l = g.linear(x, Tensor::eye(2), vec![0.0; 2]);
        g.activation(l, Act::Gelu);
        validate_graph(&g, 3);
    }
}
