//! Compile-once jet programs: the planned execution layer under the
//! [`crate::jet::JetEngine`], mirroring [`crate::plan::OperatorProgram`] on
//! the same rails.
//!
//! A [`JetProgram`] is compiled once per `(graph structure, direction
//! count, order)` and reused for every batch. It carries:
//!
//! * the **schedule** — the shared [`crate::plan`] step walk with
//!   `Linear → Activation` pairs fused;
//! * a **static slab layout** — every node's jet block
//!   (`t·(k+1)·dim` per-row scalars) at a fixed offset, assigned by
//!   replaying the liveness table (eq. 24) through the same first-fit
//!   [`crate::plan::layout::SlabLayout`]; no step needs scratch (the
//!   Linear GEMM reads the parent block directly and the Mul fold is
//!   in-place descending);
//! * **exact analytic costs** — per-row FLOPs and peak jet bytes, both
//!   linear in the batch, identical to what the reference interpreter
//!   accumulates at runtime.
//!
//! Programs are **shard-invariant** (they depend on neither batch size nor
//! thread count) and value-independent (weight values and direction values
//! are execution inputs; only zero patterns key the cache), so
//! `compute_sharded` compiles once and every shard executes the same plan —
//! the PR 1 determinism contract holds by construction.

use std::ops::Range;

use crate::autodiff::Cost;
use crate::graph::{Graph, Op};
use crate::plan::layout::SlabLayout;
use crate::plan::{self, PanelSet, Step, StepKind};
use crate::tensor::{matmul_nt_planned, GemmPlan, PackedPanel, Tensor};

use super::basis::DirectionBasis;
use super::{
    cauchy_flops, cauchy5, compose_flops, compose5, contract_flops, contract_output,
    extract_values, validate_graph,
};
use super::engine::JetResult;
use super::JetBatch;

/// Cache key for a compiled jet program: graph structure, direction-matrix
/// zero pattern, `(t, k)`, the contraction-weight *structure* (the
/// `(direction, order)` pairs — their count feeds the program's exact
/// contraction FLOPs, and two operators can share a direction set while
/// weighting different orders, e.g. biharmonic vs Kuramoto–Sivashinsky),
/// and whether a zeroth-order `c·φ` term participates. Direction and
/// weight *values* are execution inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct JetKey {
    pub fingerprint: u64,
    pub nodes: usize,
    pub n: usize,
    /// Direction count.
    pub t: usize,
    /// Jet order.
    pub k: usize,
    /// Contraction weight-entry count (part of the exact cost).
    pub weights: usize,
    pub has_c: bool,
}

/// Value-independent fingerprint of `(graph, basis, has_c)`.
pub fn jet_key(graph: &Graph, basis: &DirectionBasis, has_c: bool) -> JetKey {
    let mut h = plan::Fnv::new();
    plan::hash_graph_structure(&mut h, graph);
    h.u64(basis.n as u64);
    h.u64(basis.directions() as u64);
    h.u64(basis.order as u64);
    h.bits(basis.dirs.data().iter().map(|&v| v != 0.0));
    h.u64(basis.weights.len() as u64);
    for &(d, m, _) in &basis.weights {
        h.u64(d as u64);
        h.u64(m as u64);
    }
    h.u64(has_c as u64);
    JetKey {
        fingerprint: h.0,
        nodes: graph.len(),
        n: graph.input_dim(),
        t: basis.directions(),
        k: basis.order,
        weights: basis.weights.len(),
        has_c,
    }
}

/// Per-node compiled facts.
#[derive(Debug, Clone)]
pub struct JetNodePlan {
    /// Node output dimension.
    pub dim: usize,
    /// Per-row slab offset of the node's jet block (`t·(k+1)·dim` per-row
    /// scalars).
    pub slot: usize,
}

/// A compiled, reusable jet execution program for one
/// `(graph, direction basis)` pair.
pub struct JetProgram {
    steps: Vec<Step>,
    nodes: Vec<JetNodePlan>,
    out_id: usize,
    n: usize,
    t: usize,
    k: usize,
    has_c: bool,
    slab_per_row: usize,
    cost_per_row: Cost,
    /// Per-row cost of each schedule step (fused activation folded into its
    /// Linear step); sums with the contraction to `cost_per_row`.
    step_costs_per_row: Vec<Cost>,
    /// Per-row cost of the output extraction + contraction phase.
    contract_cost_per_row: Cost,
    peak_per_row_scalars: u64,
    key: JetKey,
}

impl JetProgram {
    /// Compile a program. Cost is O(nodes); no batch-data arithmetic.
    pub fn compile(graph: &Graph, basis: &DirectionBasis, has_c: bool) -> Self {
        let n = graph.input_dim();
        assert_eq!(basis.n, n, "basis N != graph input dim");
        assert!(!graph.is_empty(), "cannot compile an empty graph");
        let t = basis.directions();
        let k = basis.order;
        validate_graph(graph, k);
        let out_id = graph.output();

        let tau = graph.tau();
        let mut frees_at: Vec<Vec<usize>> = vec![Vec::new(); graph.len()];
        for i in 0..graph.len() {
            frees_at[tau[i]].push(i);
        }
        let mut steps = plan::build_schedule(graph, &tau);

        // Plan-time micro-kernel selection: every (batch, direction, order)
        // row goes through the Linear GEMM, so the batch-invariant per-item
        // row count is `t·(k+1)`.
        for step in steps.iter_mut() {
            if let StepKind::Linear { gemm, .. } = &mut step.kind {
                if let Op::Linear { weight, .. } = &graph.node(step.node).op {
                    let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
                    *gemm = GemmPlan::choose(t * (k + 1), in_d, out_d);
                }
            }
        }

        // ---- static slot assignment (per-row scalar units) --------------
        let mut nodes: Vec<JetNodePlan> = graph
            .nodes()
            .iter()
            .map(|nd| JetNodePlan { dim: nd.dim, slot: 0 })
            .collect();
        let node_size = |dim: usize| t * (k + 1) * dim;
        let mut lay = SlabLayout::new();
        for step in &steps {
            let id = step.node;
            nodes[id].slot = lay.alloc(node_size(nodes[id].dim));
            for &i in &frees_at[id] {
                if i != out_id {
                    lay.free(nodes[i].slot, node_size(nodes[i].dim));
                }
            }
            if let StepKind::Linear {
                fused_act: Some(a), ..
            } = &step.kind
            {
                let a = *a;
                nodes[a].slot = lay.alloc(node_size(nodes[a].dim));
                for &i in &frees_at[a] {
                    if i != out_id {
                        lay.free(nodes[i].slot, node_size(nodes[i].dim));
                    }
                }
            }
        }
        let slab_per_row = lay.high_water();

        // ---- exact per-row cost (mirrors the executor term by term),
        // stored per step so the profiler's analytic column sums to the
        // program total by construction.
        let mut node_costs = vec![Cost::zero(); graph.len()];
        for (j, node) in graph.nodes().iter().enumerate() {
            let nc = &mut node_costs[j];
            match &node.op {
                Op::Input { .. } | Op::Slice { .. } | Op::Concat => {}
                Op::Linear { weight, .. } => {
                    let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
                    let rows = (t * (k + 1)) as u64;
                    nc.muls += rows * (out_d * in_d) as u64;
                    nc.adds += rows * (out_d * in_d) as u64;
                    nc.adds += (t * out_d) as u64; // bias on m = 0 rows
                }
                Op::Activation { .. } => {
                    let (cm, ca) = compose_flops(k);
                    nc.muls += (t * node.dim) as u64 * cm;
                    nc.adds += (t * node.dim) as u64 * ca;
                }
                Op::Add => {
                    let extra = (node.inputs.len() - 1) as u64;
                    nc.adds += extra * (t * (k + 1) * node.dim) as u64;
                }
                Op::Mul => {
                    let (cm, ca) = cauchy_flops(k);
                    let folds = (node.inputs.len() - 1) as u64;
                    nc.muls += folds * (t * node.dim) as u64 * cm;
                    nc.adds += folds * (t * node.dim) as u64 * ca;
                }
                Op::SumReduce => {
                    let pd = graph.node(node.inputs[0]).dim;
                    nc.adds += (t * (k + 1) * pd) as u64;
                }
            }
        }
        let step_costs_per_row: Vec<Cost> = steps
            .iter()
            .map(|step| {
                let mut c = node_costs[step.node];
                if let StepKind::Linear {
                    fused_act: Some(a), ..
                } = &step.kind
                {
                    let ac = node_costs[*a];
                    c.muls += ac.muls;
                    c.adds += ac.adds;
                }
                c
            })
            .collect();
        let contract_cost_per_row =
            contract_flops(basis.weights.len(), has_c, graph.node(out_id).dim);
        let mut cost = contract_cost_per_row;
        for c in &step_costs_per_row {
            cost.muls += c.muls;
            cost.adds += c.adds;
        }

        // ---- peak replay (same alloc/free event order as the arena) -----
        let mut live = 0u64;
        let mut peak = 0u64;
        for j in 0..graph.len() {
            live += node_size(nodes[j].dim) as u64;
            if live > peak {
                peak = live;
            }
            for &i in &frees_at[j] {
                if i != out_id {
                    live -= node_size(nodes[i].dim) as u64;
                }
            }
        }

        let key = jet_key(graph, basis, has_c);
        JetProgram {
            steps,
            nodes,
            out_id,
            n,
            t,
            k,
            has_c,
            slab_per_row,
            cost_per_row: cost,
            step_costs_per_row,
            contract_cost_per_row,
            peak_per_row_scalars: peak,
            key,
        }
    }

    // ---- accessors -------------------------------------------------------

    pub fn steps(&self) -> &[Step] {
        &self.steps
    }

    pub fn node_plan(&self, id: usize) -> &JetNodePlan {
        &self.nodes[id]
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn output(&self) -> usize {
        self.out_id
    }

    pub fn input_dim(&self) -> usize {
        self.n
    }

    /// Direction count `t`.
    pub fn directions(&self) -> usize {
        self.t
    }

    /// Jet order `k`.
    pub fn order(&self) -> usize {
        self.k
    }

    pub fn has_c(&self) -> bool {
        self.has_c
    }

    pub fn key(&self) -> JetKey {
        self.key
    }

    /// Number of fused `Linear→Activation` steps in the schedule.
    pub fn fused_steps(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| matches!(s.kind, StepKind::Linear { fused_act: Some(_), .. }))
            .count()
    }

    /// Per-row slab scalars; one shard's slab is `slab_per_row · rows`.
    pub fn slab_per_row(&self) -> usize {
        self.slab_per_row
    }

    /// Slab length (f64 scalars) for a `batch`-row execution.
    pub fn slab_len(&self, batch: usize) -> usize {
        self.slab_per_row * batch
    }

    /// Exact FLOP count of executing `batch` rows — identical to the
    /// reference interpreter's runtime accumulation (every term of the jet
    /// pass is linear in the batch).
    pub fn cost(&self, batch: usize) -> Cost {
        Cost {
            muls: self.cost_per_row.muls * batch as u64,
            adds: self.cost_per_row.adds * batch as u64,
        }
    }

    /// Exact FLOP count of schedule step `idx` at `batch` rows (a fused
    /// `Linear→Activation` step carries both nodes' charges). Step costs
    /// plus [`JetProgram::contract_cost`] sum to [`JetProgram::cost`].
    pub fn step_cost(&self, idx: usize, batch: usize) -> Cost {
        let c = self.step_costs_per_row[idx];
        Cost {
            muls: c.muls * batch as u64,
            adds: c.adds * batch as u64,
        }
    }

    /// Exact FLOP count of the output extraction + contraction at `batch`
    /// rows.
    pub fn contract_cost(&self, batch: usize) -> Cost {
        Cost {
            muls: self.contract_cost_per_row.muls * batch as u64,
            adds: self.contract_cost_per_row.adds * batch as u64,
        }
    }

    /// Exact peak live jet bytes of a `batch`-row execution (the jet
    /// analogue of the Theorem 2.2 `M₁` measurement; `m = 0` value rows
    /// included — jets carry no separate value stream).
    pub fn peak_jet_bytes(&self, batch: usize) -> u64 {
        self.peak_per_row_scalars * 8 * batch as u64
    }
}

// ---- slab addressing -----------------------------------------------------

fn block_rng(np: &JetNodePlan, batch: usize, t: usize, k: usize) -> Range<usize> {
    let lo = np.slot * batch;
    lo..lo + batch * t * (k + 1) * np.dim
}

/// Split the slab around the write window `w`: `(prefix, window, suffix)`.
fn split3<'a>(slab: &'a mut [f64], w: &Range<usize>) -> (&'a [f64], &'a mut [f64], &'a [f64]) {
    let (pre, rest) = slab.split_at_mut(w.start);
    let (win, post) = rest.split_at_mut(w.end - w.start);
    (&*pre, win, &*post)
}

/// Read a slab range the layout guarantees is disjoint from the write
/// window `w` (addresses are absolute slab offsets).
fn rd<'a>(pre: &'a [f64], post: &'a [f64], w: &Range<usize>, r: Range<usize>) -> &'a [f64] {
    if r.end <= w.start {
        &pre[r]
    } else {
        debug_assert!(r.start >= w.end, "overlapping slab access");
        &post[r.start - w.end..r.end - w.end]
    }
}

// ---- the planned jet pass ------------------------------------------------

/// Execute the compiled program on `x: [batch, N]` with `slab` as the only
/// jet storage (grown on first use, reused verbatim afterwards). The
/// arithmetic shares its per-component kernels ([`compose5`], [`cauchy5`])
/// with the reference interpreter, so the two paths are bit-identical.
///
/// `panels` is the per-call [`PanelSet`] from [`plan::pack_panels`] —
/// packed once per top-level execution, shared read-only across shards,
/// never cached with the program. An all-`None` set is always valid and
/// bit-identical.
pub fn execute_jet(
    program: &JetProgram,
    graph: &Graph,
    basis: &DirectionBasis,
    c_coef: Option<f64>,
    x: &Tensor,
    panels: &PanelSet,
    slab: &mut Vec<f64>,
) -> JetResult {
    execute_jet_profiled(program, graph, basis, c_coef, x, panels, slab, None)
}

/// [`execute_jet`] with optional per-step profiling. With `profiler: None`
/// the extra cost is one `is_some()` branch per step and zero allocation;
/// the arithmetic (and thus the result bits) is identical either way. When
/// profiling, each step records measured seconds beside the program's
/// analytic per-step charge, so the records sum exactly to
/// [`JetProgram::cost`] — asserted by `rust/tests/observability.rs`.
#[allow(clippy::too_many_arguments)]
pub fn execute_jet_profiled(
    program: &JetProgram,
    graph: &Graph,
    basis: &DirectionBasis,
    c_coef: Option<f64>,
    x: &Tensor,
    panels: &PanelSet,
    slab: &mut Vec<f64>,
    mut profiler: Option<&mut crate::obs::StepProfiler>,
) -> JetResult {
    assert_eq!(x.rank(), 2, "input must be [batch, N]");
    let batch = x.dims()[0];
    assert_eq!(x.dims()[1], program.input_dim(), "input dim mismatch");
    assert_eq!(basis.directions(), program.directions(), "basis/program t mismatch");
    assert_eq!(basis.order, program.order(), "basis/program order mismatch");
    assert_eq!(graph.len(), program.node_count(), "program/graph mismatch");
    assert_eq!(
        program.has_c(),
        c_coef.is_some(),
        "program compiled with different zeroth-order options"
    );
    let (t, k) = (program.directions(), program.order());
    let need = program.slab_len(batch);
    if slab.len() < need {
        slab.resize(need, 0.0);
    }
    let slab = &mut slab[..need];

    for (si, step) in program.steps.iter().enumerate() {
        let t0 = profiler.is_some().then(std::time::Instant::now);
        match &step.kind {
            StepKind::Input { in_off } => {
                input_step(program, basis, x, batch, slab, step.node, *in_off)
            }
            StepKind::Linear { fused_act, gemm } => {
                let panel = panels.get(step.node).and_then(|p| p.as_ref());
                linear_step(program, graph, batch, slab, step.node, *gemm, panel);
                if let Some(a) = fused_act {
                    activation_step(program, graph, batch, slab, *a);
                }
            }
            StepKind::Activation => activation_step(program, graph, batch, slab, step.node),
            StepKind::Slice => slice_step(program, graph, batch, slab, step.node),
            StepKind::Add => add_step(program, graph, batch, slab, step.node),
            StepKind::Mul => mul_step(program, graph, batch, slab, step.node),
            StepKind::SumReduce => sum_reduce_step(program, graph, batch, slab, step.node),
            StepKind::Concat => concat_step(program, graph, batch, slab, step.node),
        }
        if let (Some(p), Some(t0)) = (profiler.as_deref_mut(), t0) {
            let c = program.step_cost(si, batch);
            p.record(
                step.node,
                crate::plan::exec::step_label(&step.kind),
                t0.elapsed().as_secs_f64(),
                c.muls,
                c.adds,
            );
        }
    }

    // Extract the output jet, values, and the contraction.
    let t_fin = profiler.is_some().then(std::time::Instant::now);
    let np = program.node_plan(program.output());
    let d = np.dim;
    let jet = &slab[block_rng(np, batch, t, k)];
    let values = extract_values(jet, batch, t, k, d);
    let operator_values = contract_output(basis, c_coef, jet, &values, batch, d);
    let out_jet = JetBatch {
        data: Tensor::from_vec(&[batch * t * (k + 1), d], jet.to_vec()),
        batch,
        t,
        k,
    };
    if let (Some(p), Some(t0)) = (profiler.as_deref_mut(), t_fin) {
        let c = program.contract_cost(batch);
        p.record(
            usize::MAX,
            "contract",
            t0.elapsed().as_secs_f64(),
            c.muls,
            c.adds,
        );
    }
    JetResult {
        values,
        operator_values,
        out_jet,
        cost: program.cost(batch),
        peak_jet_bytes: program.peak_jet_bytes(batch),
    }
}

fn input_step(
    program: &JetProgram,
    basis: &DirectionBasis,
    x: &Tensor,
    batch: usize,
    slab: &mut [f64],
    id: usize,
    in_off: usize,
) {
    let (t, k) = (program.directions(), program.order());
    let np = program.node_plan(id);
    let d = np.dim;
    let w = block_rng(np, batch, t, k);
    let (_pre, win, _post) = split3(slab, &w);
    for b in 0..batch {
        let xrow = &x.row(b)[in_off..in_off + d];
        for j in 0..t {
            let base = ((b * t + j) * (k + 1)) * d;
            win[base..base + d].copy_from_slice(xrow);
            win[base + d..base + 2 * d]
                .copy_from_slice(&basis.dirs.row(j)[in_off..in_off + d]);
            win[base + 2 * d..base + (k + 1) * d].fill(0.0);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn linear_step(
    program: &JetProgram,
    graph: &Graph,
    batch: usize,
    slab: &mut [f64],
    id: usize,
    gemm: GemmPlan,
    panel: Option<&PackedPanel>,
) {
    let node = graph.node(id);
    let (weight, bias) = match &node.op {
        Op::Linear { weight, bias } => (weight, bias),
        _ => unreachable!("linear step on non-linear node"),
    };
    let (t, k) = (program.directions(), program.order());
    let p = node.inputs[0];
    let np = program.node_plan(id);
    let pp = program.node_plan(p);
    let (out_d, in_d) = (weight.dims()[0], weight.dims()[1]);
    let rows = batch * t * (k + 1);
    let w = block_rng(np, batch, t, k);
    let (pre, win, post) = split3(slab, &w);
    let pg = rd(pre, post, &w, block_rng(pp, batch, t, k));
    // One GEMM over every (batch, direction, order) row, on the plan-time
    // micro-kernel; the GEMM accumulates, so the destination is zeroed
    // first.
    win.fill(0.0);
    matmul_nt_planned(pg, weight.data(), panel, gemm, win, rows, in_d, out_d);
    // Bias on the m = 0 (value) rows only.
    for b in 0..batch {
        for j in 0..t {
            let o = ((b * t + j) * (k + 1)) * out_d;
            for (dst, &bi) in win[o..o + out_d].iter_mut().zip(bias.iter()) {
                *dst += bi;
            }
        }
    }
}

fn activation_step(program: &JetProgram, graph: &Graph, batch: usize, slab: &mut [f64], id: usize) {
    let node = graph.node(id);
    let act = match &node.op {
        Op::Activation { act } => *act,
        _ => unreachable!("activation step on non-activation node"),
    };
    let (t, k) = (program.directions(), program.order());
    let p = node.inputs[0];
    let np = program.node_plan(id);
    let pp = program.node_plan(p);
    let d = np.dim;
    let w = block_rng(np, batch, t, k);
    let (pre, win, post) = split3(slab, &w);
    let pg = rd(pre, post, &w, block_rng(pp, batch, t, k));
    let mut a = [0.0; 5];
    for bj in 0..batch * t {
        let base = bj * (k + 1) * d;
        for c in 0..d {
            for (m, am) in a.iter_mut().enumerate().take(k + 1) {
                *am = pg[base + m * d + c];
            }
            let y = compose5(act, k, &a);
            for (m, &ym) in y.iter().enumerate().take(k + 1) {
                win[base + m * d + c] = ym;
            }
        }
    }
}

fn slice_step(program: &JetProgram, graph: &Graph, batch: usize, slab: &mut [f64], id: usize) {
    let node = graph.node(id);
    let (start, len) = match &node.op {
        Op::Slice { start, len } => (*start, *len),
        _ => unreachable!("slice step on non-slice node"),
    };
    let (t, k) = (program.directions(), program.order());
    let p = node.inputs[0];
    let np = program.node_plan(id);
    let pp = program.node_plan(p);
    let pd = pp.dim;
    let w = block_rng(np, batch, t, k);
    let (pre, win, post) = split3(slab, &w);
    let pg = rd(pre, post, &w, block_rng(pp, batch, t, k));
    for r in 0..batch * t * (k + 1) {
        win[r * len..(r + 1) * len].copy_from_slice(&pg[r * pd + start..r * pd + start + len]);
    }
}

fn add_step(program: &JetProgram, graph: &Graph, batch: usize, slab: &mut [f64], id: usize) {
    let node = graph.node(id);
    let (t, k) = (program.directions(), program.order());
    let np = program.node_plan(id);
    let w = block_rng(np, batch, t, k);
    let (pre, win, post) = split3(slab, &w);
    for (pi, &p) in node.inputs.iter().enumerate() {
        let pp = program.node_plan(p);
        let pg = rd(pre, post, &w, block_rng(pp, batch, t, k));
        if pi == 0 {
            win.copy_from_slice(pg);
        } else {
            for (dst, &sv) in win.iter_mut().zip(pg.iter()) {
                *dst += sv;
            }
        }
    }
}

fn concat_step(program: &JetProgram, graph: &Graph, batch: usize, slab: &mut [f64], id: usize) {
    let node = graph.node(id);
    let (t, k) = (program.directions(), program.order());
    let np = program.node_plan(id);
    let d = np.dim;
    let w = block_rng(np, batch, t, k);
    let (pre, win, post) = split3(slab, &w);
    let mut off = 0usize;
    for &p in &node.inputs {
        let pp = program.node_plan(p);
        let pd = pp.dim;
        let pg = rd(pre, post, &w, block_rng(pp, batch, t, k));
        for r in 0..batch * t * (k + 1) {
            win[r * d + off..r * d + off + pd].copy_from_slice(&pg[r * pd..(r + 1) * pd]);
        }
        off += pd;
    }
}

fn mul_step(program: &JetProgram, graph: &Graph, batch: usize, slab: &mut [f64], id: usize) {
    let node = graph.node(id);
    let (t, k) = (program.directions(), program.order());
    let np = program.node_plan(id);
    let d = np.dim;
    let w = block_rng(np, batch, t, k);
    let (pre, win, post) = split3(slab, &w);
    // Fold parents pairwise with the Cauchy product. The accumulator lives
    // in the node's own block (seeded from parent 0).
    let mut a = [0.0; 5];
    let mut q = [0.0; 5];
    for (pi, &p) in node.inputs.iter().enumerate() {
        let pp = program.node_plan(p);
        let pg = rd(pre, post, &w, block_rng(pp, batch, t, k));
        if pi == 0 {
            win.copy_from_slice(pg);
            continue;
        }
        for bj in 0..batch * t {
            let base = bj * (k + 1) * d;
            for c in 0..d {
                for m in 0..=k {
                    a[m] = win[base + m * d + c];
                    q[m] = pg[base + m * d + c];
                }
                let y = cauchy5(k, &a, &q);
                for (m, &ym) in y.iter().enumerate().take(k + 1) {
                    win[base + m * d + c] = ym;
                }
            }
        }
    }
}

fn sum_reduce_step(program: &JetProgram, graph: &Graph, batch: usize, slab: &mut [f64], id: usize) {
    let node = graph.node(id);
    let (t, k) = (program.directions(), program.order());
    let p = node.inputs[0];
    let np = program.node_plan(id);
    let pp = program.node_plan(p);
    let pd = pp.dim;
    let w = block_rng(np, batch, t, k);
    let (pre, win, post) = split3(slab, &w);
    let pg = rd(pre, post, &w, block_rng(pp, batch, t, k));
    for r in 0..batch * t * (k + 1) {
        win[r] = pg[r * pd..(r + 1) * pd].iter().sum::<f64>();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder::random_layers, mlp_graph, Act};
    use crate::jet::basis::biharmonic_terms;
    use crate::util::Xoshiro256;

    fn fixture() -> (Graph, DirectionBasis) {
        let mut rng = Xoshiro256::new(31);
        let g = mlp_graph(&random_layers(&[4, 9, 9, 1], &mut rng), Act::Tanh);
        let basis = DirectionBasis::from_terms(4, &biharmonic_terms(4, 1.0), None);
        (g, basis)
    }

    #[test]
    fn schedule_fuses_and_layout_is_positive() {
        let (g, basis) = fixture();
        let p = JetProgram::compile(&g, &basis, false);
        assert_eq!(p.order(), 4);
        assert_eq!(p.directions(), 16);
        assert_eq!(p.fused_steps(), 2);
        assert!(p.slab_per_row() > 0);
        assert!(p.cost(1).muls > 0);
        assert!(p.peak_jet_bytes(1) > 0);
    }

    #[test]
    fn cost_and_peak_scale_exactly_with_batch() {
        let (g, basis) = fixture();
        let p = JetProgram::compile(&g, &basis, true);
        let c1 = p.cost(1);
        let c5 = p.cost(5);
        assert_eq!(c5.muls, 5 * c1.muls);
        assert_eq!(c5.adds, 5 * c1.adds);
        assert_eq!(p.peak_jet_bytes(5), 5 * p.peak_jet_bytes(1));
        assert_eq!(p.slab_len(5), 5 * p.slab_per_row());
    }

    #[test]
    fn step_costs_sum_to_program_cost() {
        let (g, basis) = fixture();
        for has_c in [false, true] {
            let p = JetProgram::compile(&g, &basis, has_c);
            for batch in [1usize, 4, 9] {
                let mut sum = p.contract_cost(batch);
                for si in 0..p.steps().len() {
                    let c = p.step_cost(si, batch);
                    sum.muls += c.muls;
                    sum.adds += c.adds;
                }
                assert_eq!(sum, p.cost(batch));
            }
        }
    }

    #[test]
    fn key_ignores_weight_values_but_not_structure_or_order() {
        let mut rng = Xoshiro256::new(32);
        let layers = random_layers(&[3, 6, 1], &mut rng);
        let layers2 = random_layers(&[3, 6, 1], &mut rng);
        let g1 = mlp_graph(&layers, Act::Tanh);
        let g2 = mlp_graph(&layers2, Act::Tanh);
        let b4 = DirectionBasis::from_terms(3, &biharmonic_terms(3, 1.0), None);
        let b2 = DirectionBasis::from_terms(3, &crate::jet::laplacian_terms(3, 1.0), None);
        assert_eq!(jet_key(&g1, &b4, false), jet_key(&g2, &b4, false));
        assert_ne!(jet_key(&g1, &b4, false), jet_key(&g1, &b2, false));
        assert_ne!(jet_key(&g1, &b4, false), jet_key(&g1, &b4, true));
    }

    #[test]
    fn key_separates_same_directions_different_weight_structure() {
        // Biharmonic and the KS linear part share the exact same direction
        // set, order, and has_c — but KS weights the `c₂` coefficients too,
        // so its contraction cost differs; the keys must not collide.
        let mut rng = Xoshiro256::new(33);
        let g = mlp_graph(&random_layers(&[3, 6, 1], &mut rng), Act::Tanh);
        let bih = DirectionBasis::from_terms(3, &biharmonic_terms(3, 1.0), None);
        let mut ks_terms = biharmonic_terms(3, -1.0);
        ks_terms.extend(crate::jet::laplacian_terms(3, -1.0));
        let ks = DirectionBasis::from_terms(3, &ks_terms, None);
        assert_eq!(bih.directions(), ks.directions(), "same direction set");
        let kb = jet_key(&g, &bih, false);
        let kk = jet_key(&g, &ks, false);
        assert_ne!(kb, kk, "weight structure must partition the key space");
        // And the compiled programs carry different exact contraction costs.
        let pb = JetProgram::compile(&g, &bih, false);
        let pk = JetProgram::compile(&g, &ks, false);
        assert_ne!(pb.cost(1).muls, pk.cost(1).muls);
    }
}
