//! **Stochastic Taylor jet engine (STDE)** — unbiased Monte-Carlo
//! estimation of arbitrary order-≤4 constant-coefficient operators on the
//! exact jet rails, for the regime where the exact polarization basis is
//! the scaling wall (`Δ²` at dimension `d` needs `d²` exact directions;
//! the estimator's direction count is independent of `d`).
//!
//! ## Estimator
//!
//! For an order-`m` term group `Σ coef·∂^α φ = Σ coef·Tₘ(e_{α₁},…,e_{αₘ})`
//! (`Tₘ` the symmetric m-linear differential form), draw `m` **independent
//! isotropic** vectors `u₁…uₘ` with `E[u uᵀ] = I` and form
//!
//! ```text
//! R = Tₘ(u₁,…,uₘ) · Aₘ,    Aₘ = Σ_terms coef · Π_l u_l[α_l]
//! ```
//!
//! Independence gives `E[Π_l u_l[i_l]·u_l[α_l]] = Π_l δ_{i_l α_l}`, so
//! `E[R] = Σ coef·∂^α φ` exactly — **unbiased** for any term list, both
//! sampling families. `Tₘ(u₁…uₘ)` itself is read off one jet propagation
//! by the polarization identity (`2⁻ᵐ Σ_ε (Πε)·cₘ(Σεₗuₗ)`, sign-
//! canonicalized to `2^{m−1}` directions per order per sample). First-order
//! terms and `b·∇` are carried **exactly** as one extra deterministic
//! direction (zero variance contribution), and `c·φ` exactly at the output.
//!
//! ## Single-kernel invariant
//!
//! This module introduces **no new arithmetic**: sampled directions are
//! packed into a [`DirectionBasis`] and pushed through the compiled
//! [`JetProgram`] executor — the same `compose5`/`cauchy5` kernels, slab
//! layout, and GEMM plans as the exact engine. The program is compiled
//! **once** per `(graph, structure)` from a canonical all-ones pattern
//! basis (direction *values* are execution inputs; only the structure keys
//! the cache), so per-point random bases cause no plan-cache thrash.
//!
//! ## Determinism contract (PR 1)
//!
//! Per-point direction streams are derived counter-style from
//! `(seed, point index, sample index)` — every `(point, sample)` pair owns
//! an independent [`Xoshiro256`] stream, so results are a pure function of
//! the seed and the point's **global** batch index: bit-identical across
//! 1/2/4/8 threads and independent of the shard decomposition
//! (`rust/tests/stochastic_convergence.rs`).

use std::sync::Arc;

use crate::autodiff::arena::{with_program_slab, SlabKey};
use crate::autodiff::Cost;
use crate::graph::Graph;
use crate::parallel::{self, Pool};
use crate::plan::{self, PanelSet};
use crate::tensor::Tensor;
use crate::util::Xoshiro256;

use super::basis::{DirectionBasis, JetTerm};
use super::cache::global_jet_cache;
use super::program::{execute_jet, JetProgram};

/// Direction sampling family. Both are isotropic (`E[u uᵀ] = I`), which is
/// all the unbiasedness argument needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DirectionSampling {
    /// Dense standard normal `u ~ N(0, I)`.
    Gaussian,
    /// Sparse Rademacher: `nnz` distinct coordinates set to
    /// `±sqrt(n/nnz)`, the rest zero. `E[uᵢ²] = (nnz/n)·(n/nnz) = 1`,
    /// off-diagonals vanish by sign symmetry.
    SparseRademacher {
        /// Non-zeros per direction (clamped to `1..=n` at engine build).
        nnz: usize,
    },
}

/// Output of [`StochasticJetEngine::compute`].
pub struct StochasticJetResult {
    /// `φ(x)`, `[batch, out]` — exact (the value rows of the jet).
    pub values: Tensor,
    /// Unbiased estimate of `L[φ](x)`, `[batch, out]`.
    pub operator_values: Tensor,
    /// Bessel-corrected sample variance of the per-sample estimates,
    /// `[batch, out]` (zero when the operator has no stochastic part or
    /// `samples == 1`).
    pub variance: Tensor,
    /// Standard error `sqrt(variance / samples)`, `[batch, out]`.
    pub std_error: Tensor,
    /// Sample count the estimate used.
    pub samples: u32,
    /// Exact FLOP count of the run (program cost; batch-linear).
    pub cost: Cost,
    /// Peak live jet bytes of any single-point execution.
    pub peak_jet_bytes: u64,
}

/// One order-`m ≥ 2` term group: `(m, [(axes, coef)])`.
type OrderGroup = (usize, Vec<(Vec<usize>, f64)>);

/// The stochastic Taylor jet engine.
#[derive(Clone)]
pub struct StochasticJetEngine {
    n: usize,
    /// Order-≥2 term groups, ascending by order.
    orders: Vec<OrderGroup>,
    /// Combined exact first-order direction (order-1 terms + `b`), if any.
    exact_dir: Option<Vec<f64>>,
    /// Zeroth-order coefficient (`c·φ` at the output, exact).
    c: Option<f64>,
    /// Jet order `k` (max term order, ≥ 1).
    k: usize,
    samples: u32,
    seed: u64,
    sampling: DirectionSampling,
    /// Canonical all-ones pattern basis the program compiles from.
    pattern: DirectionBasis,
    /// Kept for re-assembly in the builder methods.
    terms: Vec<JetTerm>,
    b: Option<Vec<f64>>,
}

/// Counter-style per-`(point, sample)` stream seed: sequential multiply-mix
/// (repo idiom, cf. `prop::run_prop`), then [`Xoshiro256::new`]'s SplitMix
/// expansion finishes the avalanche.
fn stream_seed(seed: u64, point: u64, sample: u64) -> u64 {
    let h = (seed ^ point.wrapping_mul(0x9E3779B97F4A7C15))
        .wrapping_mul(0xD1B54A32D192ED03);
    (h ^ sample).wrapping_mul(0x94D049BB133111EB)
}

impl StochasticJetEngine {
    /// Build from explicit terms on `R^n`.
    pub fn from_terms(
        n: usize,
        terms: Vec<JetTerm>,
        sampling: DirectionSampling,
        samples: u32,
        seed: u64,
    ) -> Self {
        Self::assemble(n, terms, None, None, sampling, samples, seed)
    }

    /// Attach lower-order terms (`b·∇` merges into the exact first-order
    /// direction; `c·φ` applies at the output).
    pub fn with_lower_order(self, b: Option<Vec<f64>>, c: Option<f64>) -> Self {
        Self::assemble(
            self.n,
            self.terms,
            b,
            c,
            self.sampling,
            self.samples,
            self.seed,
        )
    }

    /// Override the sample count (the accuracy↔latency dial; the
    /// per-request serving knob lands here).
    pub fn with_samples(self, samples: u32) -> Self {
        Self::assemble(
            self.n,
            self.terms,
            self.b,
            self.c,
            self.sampling,
            samples,
            self.seed,
        )
    }

    fn assemble(
        n: usize,
        terms: Vec<JetTerm>,
        b: Option<Vec<f64>>,
        c: Option<f64>,
        sampling: DirectionSampling,
        samples: u32,
        seed: u64,
    ) -> Self {
        assert!(n > 0, "input dimension must be positive");
        assert!(samples >= 1, "sample count must be ≥ 1");
        assert!(
            !terms.is_empty() || b.is_some(),
            "operator needs at least one term"
        );
        for t in &terms {
            assert!(
                t.axes.iter().all(|&a| a < n),
                "term axis out of range: {:?} for N = {n}",
                t.axes
            );
        }
        let sampling = match sampling {
            DirectionSampling::SparseRademacher { nnz } => DirectionSampling::SparseRademacher {
                nnz: nnz.clamp(1, n),
            },
            s => s,
        };
        // Exact first-order carry: Σ order-1 coef·e_a + b in one direction.
        let mut g = vec![0.0; n];
        let mut has_first = false;
        for t in terms.iter().filter(|t| t.order() == 1) {
            g[t.axes[0]] += t.coef;
            has_first = true;
        }
        if let Some(bv) = &b {
            assert_eq!(bv.len(), n, "b length must be N");
            for (gi, &bi) in g.iter_mut().zip(bv.iter()) {
                *gi += bi;
            }
            has_first = true;
        }
        let exact_dir = has_first.then_some(g);
        // Order-≥2 groups, ascending.
        let mut orders: Vec<OrderGroup> = Vec::new();
        for m in 2..=4 {
            let group: Vec<(Vec<usize>, f64)> = terms
                .iter()
                .filter(|t| t.order() == m)
                .map(|t| (t.axes.clone(), t.coef))
                .collect();
            if !group.is_empty() {
                orders.push((m, group));
            }
        }
        let mut k = orders.last().map(|&(m, _)| m).unwrap_or(0);
        if exact_dir.is_some() {
            k = k.max(1);
        }
        assert!(k >= 1, "operator needs at least one differential term");
        let pattern = Self::pattern_basis(n, k, exact_dir.is_some(), &orders, samples);
        Self {
            n,
            orders,
            exact_dir,
            c,
            k,
            samples,
            seed,
            sampling,
            pattern,
            terms,
            b,
        }
    }

    // ---- accessors -------------------------------------------------------

    pub fn n(&self) -> usize {
        self.n
    }

    /// Jet order `k`.
    pub fn order(&self) -> usize {
        self.k
    }

    pub fn samples(&self) -> u32 {
        self.samples
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn sampling(&self) -> DirectionSampling {
        self.sampling
    }

    pub fn constant(&self) -> Option<f64> {
        self.c
    }

    /// Sampled polarization directions per sample
    /// (`Σ_{orders m} 2^{m−1}`; zero for a purely first-order operator).
    pub fn dirs_per_sample(&self) -> usize {
        self.orders.iter().map(|&(m, _)| 1usize << (m - 1)).sum()
    }

    /// Total jet directions per point
    /// (`exact carry + samples · dirs_per_sample`).
    pub fn directions_per_point(&self) -> usize {
        self.exact_dir.is_some() as usize + self.samples as usize * self.dirs_per_sample()
    }

    /// Structured batch-input validation (shared engine-wide gate).
    pub fn validate_input(&self, graph: &Graph, x: &Tensor) -> Result<(), String> {
        crate::tensor::ops::validate_batch_input(graph.input_dim(), x)
    }

    /// The cached jet program (compiled on first use from the pattern
    /// basis; shared across every point and sample).
    pub fn program(&self, graph: &Graph) -> Arc<JetProgram> {
        global_jet_cache().get_or_compile(graph, &self.pattern, self.c.is_some())
    }

    // ---- basis assembly --------------------------------------------------

    /// The canonical compile-time basis: all-ones directions, unit weights,
    /// same `(t, k, weight-structure, has_c)` as every sampled per-point
    /// basis — so one cache entry serves all points and samples.
    fn pattern_basis(
        n: usize,
        k: usize,
        has_exact: bool,
        orders: &[OrderGroup],
        samples: u32,
    ) -> DirectionBasis {
        let dirs_per_sample: usize = orders.iter().map(|&(m, _)| 1usize << (m - 1)).sum();
        let t = has_exact as usize + samples as usize * dirs_per_sample;
        let mut weights = Vec::with_capacity(t);
        let mut row = 0usize;
        if has_exact {
            weights.push((row, 1usize, 1.0));
            row += 1;
        }
        for _ in 0..samples {
            for &(m, _) in orders {
                for _ in 0..(1usize << (m - 1)) {
                    weights.push((row, m, 1.0));
                    row += 1;
                }
            }
        }
        DirectionBasis {
            n,
            order: k,
            dirs: Tensor::from_vec(&[t, n], vec![1.0; t * n]),
            weights,
        }
    }

    /// Draw one isotropic direction from `rng`.
    fn draw(&self, rng: &mut Xoshiro256) -> Vec<f64> {
        let n = self.n;
        match self.sampling {
            DirectionSampling::Gaussian => (0..n).map(|_| rng.normal()).collect(),
            DirectionSampling::SparseRademacher { nnz } => {
                let mut u = vec![0.0; n];
                let v = (n as f64 / nnz as f64).sqrt();
                let mut chosen: Vec<usize> = Vec::with_capacity(nnz);
                while chosen.len() < nnz {
                    let i = rng.below(n);
                    if !chosen.contains(&i) {
                        chosen.push(i);
                        u[i] = if rng.bernoulli(0.5) { v } else { -v };
                    }
                }
                u
            }
        }
    }

    /// Sampled per-point basis. The weight list has the exact same
    /// `(direction, order)` structure as the pattern basis (zero-valued
    /// entries retained), so the compiled program's contraction cost stays
    /// exact. Pure function of `(seed, point_index)`.
    fn point_basis(&self, point_index: u64) -> DirectionBasis {
        let n = self.n;
        let t = self.directions_per_point();
        let s_count = self.samples as usize;
        let inv_s = 1.0 / s_count as f64;
        let mut dirs = vec![0.0; t * n];
        let mut weights = Vec::with_capacity(t);
        let mut row = 0usize;
        if let Some(g) = &self.exact_dir {
            dirs[..n].copy_from_slice(g);
            weights.push((0, 1usize, 1.0));
            row = 1;
        }
        for s in 0..s_count {
            let mut rng = Xoshiro256::new(stream_seed(self.seed, point_index, s as u64));
            for (m, group) in &self.orders {
                let m = *m;
                let u: Vec<Vec<f64>> = (0..m).map(|_| self.draw(&mut rng)).collect();
                // Aₘ = Σ coef·Π_l u_l[α_l] (raw axis assignment is valid
                // because Tₘ is symmetric).
                let mut a_m = 0.0;
                for (axes, coef) in group {
                    let mut p = *coef;
                    for (l, &ax) in axes.iter().enumerate() {
                        p *= u[l][ax];
                    }
                    a_m += p;
                }
                // Sign-canonicalized polarization: ε₁ = +1 fixed, the two
                // half-orbits contribute equally, so each of the 2^{m−1}
                // directions carries 2·2⁻ᵐ·(Πε)·Aₘ / S.
                let scale = a_m * inv_s * (2f64).powi(1 - m as i32);
                for eps in 0..(1usize << (m - 1)) {
                    let d = &mut dirs[row * n..(row + 1) * n];
                    d.copy_from_slice(&u[0]);
                    let mut parity = 1.0;
                    for (l, ul) in u.iter().enumerate().skip(1) {
                        if (eps >> (l - 1)) & 1 == 1 {
                            parity = -parity;
                            for (di, &vi) in d.iter_mut().zip(ul.iter()) {
                                *di -= vi;
                            }
                        } else {
                            for (di, &vi) in d.iter_mut().zip(ul.iter()) {
                                *di += vi;
                            }
                        }
                    }
                    weights.push((row, m, parity * scale));
                    row += 1;
                }
            }
        }
        debug_assert_eq!(row, t);
        DirectionBasis {
            n,
            order: self.k,
            dirs: Tensor::from_vec(&[t, n], dirs),
            weights,
        }
    }

    // ---- execution -------------------------------------------------------

    /// Estimate `L[φ]` on `x: [batch, N]` (serial point loop; point `b`
    /// uses global index `b`).
    pub fn compute(&self, graph: &Graph, x: &Tensor) -> StochasticJetResult {
        let program = self.program(graph);
        let panels = plan::pack_panels(program.steps(), graph);
        self.compute_points(&program, graph, x, &panels, 0)
    }

    /// [`Self::compute`] sharded across `pool` in `shard_rows`-row chunks.
    ///
    /// Determinism contract: shard boundaries depend only on the batch size
    /// and `shard_rows`; each point's direction streams are keyed by its
    /// **global** index (`range.start + i`); shard results concatenate in
    /// shard order — so the result is bit-identical across thread counts
    /// and shard decompositions, and matches the unsharded [`Self::compute`].
    pub fn compute_sharded(
        &self,
        graph: &Graph,
        x: &Tensor,
        pool: &Pool,
        shard_rows: usize,
    ) -> StochasticJetResult {
        let batch = x.dims()[0];
        let n = x.dims()[1];
        let program = self.program(graph);
        let ranges = parallel::split_rows(batch, shard_rows);
        let panels = plan::pack_panels(program.steps(), graph);
        if ranges.len() <= 1 {
            let serial = || self.compute_points(&program, graph, x, &panels, 0);
            if pool.threads() == 1 {
                return parallel::with_serial_guard(serial);
            }
            return serial();
        }
        let shards = pool.run_sharded(ranges, |_, r| {
            let rows = r.end - r.start;
            let xs = Tensor::from_vec(&[rows, n], x.data()[r.start * n..r.end * n].to_vec());
            self.compute_points(&program, graph, &xs, &panels, r.start as u64)
        });
        merge_stochastic_shards(shards, batch)
    }

    /// Serial per-point loop: each point gets its own sampled basis and a
    /// `rows = 1` execution of the shared program (the program's
    /// `input_step` seeds one basis for all batch rows, so per-point random
    /// directions require per-point execution).
    fn compute_points(
        &self,
        program: &JetProgram,
        graph: &Graph,
        x: &Tensor,
        panels: &PanelSet,
        base_index: u64,
    ) -> StochasticJetResult {
        assert_eq!(x.rank(), 2, "input must be [batch, N]");
        let batch = x.dims()[0];
        let n = x.dims()[1];
        assert_eq!(n, self.n, "input dim mismatch");
        let s_count = self.samples as usize;
        let k = self.k;
        let d_w = self.dirs_per_sample();
        let out_d = graph.node(graph.output()).dim;

        let mut values = Tensor::zeros(&[batch, out_d]);
        let mut estimates = Tensor::zeros(&[batch, out_d]);
        let mut variance = Tensor::zeros(&[batch, out_d]);
        let mut std_error = Tensor::zeros(&[batch, out_d]);
        let mut cost = Cost::zero();
        let mut peak = 0u64;
        let mut x_s = vec![0.0; out_d];
        let key = SlabKey {
            program: program.key().fingerprint,
            rows: 1,
        };

        for b in 0..batch {
            let basis = self.point_basis(base_index + b as u64);
            let xs = Tensor::from_vec(&[1, n], x.row(b).to_vec());
            let res = with_program_slab(key, |slab| {
                execute_jet(program, graph, &basis, self.c, &xs, panels, slab)
            });
            values.row_mut(b).copy_from_slice(res.values.row(0));
            estimates
                .row_mut(b)
                .copy_from_slice(res.operator_values.row(0));
            cost += res.cost;
            peak = peak.max(res.peak_jet_bytes);

            // Per-sample estimates Xₛ = Rₛ + exact part, recomputed from
            // the output jet: the mean of the Xₛ is the estimate (up to
            // float-summation order) and their Bessel-corrected spread is
            // the variance report.
            if s_count > 1 && d_w > 0 {
                let jet = res.out_jet.data.data();
                let base_w = self.exact_dir.is_some() as usize;
                // Exact contribution shared by every sample.
                let mut exact = vec![0.0; out_d];
                if self.exact_dir.is_some() {
                    let (row, m, w) = basis.weights[0];
                    let r = row * (k + 1) + m;
                    for (e, &j) in exact.iter_mut().zip(jet[r * out_d..(r + 1) * out_d].iter())
                    {
                        *e += w * j;
                    }
                }
                if let Some(c) = self.c {
                    for (e, &v) in exact.iter_mut().zip(res.values.row(0).iter()) {
                        *e += c * v;
                    }
                }
                let mut mean = vec![0.0; out_d];
                let mut m2 = vec![0.0; out_d];
                let est = estimates.row(b);
                for s in 0..s_count {
                    x_s.copy_from_slice(&exact);
                    for &(row, m, w) in &basis.weights[base_w + s * d_w..base_w + (s + 1) * d_w]
                    {
                        let r = row * (k + 1) + m;
                        // Weights carry 1/S; the per-sample value undoes it.
                        let ws = w * s_count as f64;
                        for (xo, &j) in
                            x_s.iter_mut().zip(jet[r * out_d..(r + 1) * out_d].iter())
                        {
                            *xo += ws * j;
                        }
                    }
                    for o in 0..out_d {
                        mean[o] += x_s[o];
                        let dev = x_s[o] - est[o];
                        m2[o] += dev * dev;
                    }
                }
                let var_row = variance.row_mut(b);
                for o in 0..out_d {
                    var_row[o] = m2[o] / (s_count - 1) as f64;
                }
                let se_row = std_error.row_mut(b);
                for o in 0..out_d {
                    se_row[o] = (var_row[o] / s_count as f64).sqrt();
                }
            }
        }
        StochasticJetResult {
            values,
            operator_values: estimates,
            variance,
            std_error,
            samples: self.samples,
            cost,
            peak_jet_bytes: peak,
        }
    }
}

/// Concatenate per-shard results in shard (= batch) order; cost sums, peak
/// is the per-shard maximum.
fn merge_stochastic_shards(
    shards: Vec<StochasticJetResult>,
    batch: usize,
) -> StochasticJetResult {
    let d = shards[0].values.dims()[1];
    let samples = shards[0].samples;
    let mut values = Tensor::zeros(&[batch, d]);
    let mut est = Tensor::zeros(&[batch, d]);
    let mut var = Tensor::zeros(&[batch, d]);
    let mut se = Tensor::zeros(&[batch, d]);
    let mut cost = Cost::zero();
    let mut peak = 0u64;
    let mut row = 0usize;
    for s in shards {
        let rows = s.values.dims()[0];
        values.data_mut()[row * d..(row + rows) * d].copy_from_slice(s.values.data());
        est.data_mut()[row * d..(row + rows) * d].copy_from_slice(s.operator_values.data());
        var.data_mut()[row * d..(row + rows) * d].copy_from_slice(s.variance.data());
        se.data_mut()[row * d..(row + rows) * d].copy_from_slice(s.std_error.data());
        cost += s.cost;
        peak = peak.max(s.peak_jet_bytes);
        row += rows;
    }
    StochasticJetResult {
        values,
        operator_values: est,
        variance: var,
        std_error: se,
        samples,
        cost,
        peak_jet_bytes: peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder::random_layers, mlp_graph, Act};
    use crate::jet::basis::laplacian_terms;
    use crate::jet::JetEngine;

    fn fixture(d: usize) -> (Graph, Tensor) {
        let mut rng = Xoshiro256::new(71);
        let g = mlp_graph(&random_layers(&[d, 8, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[3, d], &mut rng).scale(0.5);
        (g, x)
    }

    #[test]
    fn first_order_only_is_exact_with_zero_variance() {
        let (g, x) = fixture(3);
        let terms = vec![JetTerm::new(&[0], 0.7), JetTerm::new(&[2], -1.1)];
        let eng = StochasticJetEngine::from_terms(
            3,
            terms.clone(),
            DirectionSampling::Gaussian,
            4,
            9,
        );
        let got = eng.compute(&g, &x);
        let exact = JetEngine::new(DirectionBasis::from_terms(3, &terms, None)).compute(&g, &x);
        for b in 0..3 {
            assert!(
                (got.operator_values.at(b, 0) - exact.operator_values.at(b, 0)).abs() < 1e-12
            );
            assert_eq!(got.variance.at(b, 0), 0.0);
            assert_eq!(got.std_error.at(b, 0), 0.0);
        }
    }

    #[test]
    fn laplacian_estimate_converges_with_samples() {
        let (g, x) = fixture(4);
        let terms = laplacian_terms(4, 1.0);
        let exact = JetEngine::new(DirectionBasis::from_terms(4, &terms, None)).compute(&g, &x);
        for sampling in [
            DirectionSampling::Gaussian,
            DirectionSampling::SparseRademacher { nnz: 2 },
        ] {
            let eng =
                StochasticJetEngine::from_terms(4, terms.clone(), sampling, 4096, 17);
            let got = eng.compute(&g, &x);
            for b in 0..3 {
                let want = exact.operator_values.at(b, 0);
                let se = got.std_error.at(b, 0);
                assert!(
                    (got.operator_values.at(b, 0) - want).abs() < 6.0 * se + 1e-6,
                    "{sampling:?} row {b}: {} vs {want} (se {se})",
                    got.operator_values.at(b, 0)
                );
            }
        }
    }

    #[test]
    fn sharded_is_bitwise_identical_across_threads_and_shard_rows() {
        let (g, x) = fixture(3);
        let eng = StochasticJetEngine::from_terms(
            3,
            laplacian_terms(3, 1.0),
            DirectionSampling::SparseRademacher { nnz: 2 },
            16,
            5,
        )
        .with_lower_order(Some(vec![0.3, -0.2, 0.1]), Some(0.5));
        let base = eng.compute(&g, &x);
        for threads in [1usize, 2, 4, 8] {
            for shard_rows in [1usize, 2, 64] {
                let pool = Pool::new(threads);
                let got = eng.compute_sharded(&g, &x, &pool, shard_rows);
                assert_eq!(got.operator_values.data(), base.operator_values.data());
                assert_eq!(got.variance.data(), base.variance.data());
                assert_eq!(got.values.data(), base.values.data());
            }
        }
    }

    #[test]
    fn values_are_exact_not_estimated() {
        let (g, x) = fixture(3);
        let eng = StochasticJetEngine::from_terms(
            3,
            laplacian_terms(3, 1.0),
            DirectionSampling::Gaussian,
            2,
            1,
        );
        let got = eng.compute(&g, &x);
        let eval = g.eval(&x);
        for b in 0..3 {
            assert_eq!(got.values.at(b, 0), eval.at(b, 0));
        }
    }

    #[test]
    fn pattern_basis_structure_matches_point_basis() {
        let eng = StochasticJetEngine::from_terms(
            3,
            crate::jet::biharmonic_terms(3, 1.0),
            DirectionSampling::Gaussian,
            3,
            2,
        );
        let p = eng.point_basis(0);
        assert_eq!(p.dirs.dims(), eng.pattern.dirs.dims());
        assert_eq!(p.order, eng.pattern.order);
        assert_eq!(p.weights.len(), eng.pattern.weights.len());
        for (a, b) in p.weights.iter().zip(eng.pattern.weights.iter()) {
            assert_eq!((a.0, a.1), (b.0, b.1), "weight structure must match");
        }
        // Different points draw different directions.
        let q = eng.point_basis(1);
        assert_ne!(p.dirs.data(), q.dirs.data());
    }
}
