//! # DOF — Differential Operators with Forward propagation
//!
//! A full-system reproduction of *"DOF: Accelerating High-order Differential
//! Operators with Forward Propagation"* (Li, Wang, Ye, He, Wang, 2024).
//!
//! DOF computes arbitrary second-order differential operators
//! `L[φ] = Σ a_ij ∂²_ij φ + Σ b_i ∂_i φ + c φ` of a neural network `φ` in a
//! **single forward pass**, by decomposing the symmetric coefficient matrix
//! `A = Lᵀ D L` and propagating the tuple `(v, L∇v, L[v])` through the
//! computation graph — exactly, with provably ≤½ the FLOPs and lower peak
//! memory than Hessian-based AutoDiff (Theorems 2.1/2.2 of the paper).
//!
//! ## Crate layout
//!
//! * substrates: [`util`], [`prop`], [`tensor`], [`linalg`], [`graph`]
//! * the contribution: [`autodiff`] (DOF + the Hessian-based baseline,
//!   both instrumented with exact FLOP and peak-memory accounting)
//! * applications: [`operators`], [`nn`], [`pde`], [`train`]
//! * infrastructure: [`runtime`] (XLA-PJRT artifact execution),
//!   [`coordinator`] (batching / serving), [`bench_harness`]

pub mod autodiff;
pub mod bench_harness;
pub mod coordinator;
pub mod graph;
pub mod linalg;
pub mod nn;
pub mod operators;
pub mod pde;
pub mod prop;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;
