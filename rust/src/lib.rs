//! # DOF — Differential Operators with Forward propagation
//!
//! A full-system reproduction of *"DOF: Accelerating High-order Differential
//! Operators with Forward Propagation"* (Li, Wang, Ye, He, Wang, 2024).
//!
//! DOF computes arbitrary second-order differential operators
//! `L[φ] = Σ a_ij ∂²_ij φ + Σ b_i ∂_i φ + c φ` of a neural network `φ` in a
//! **single forward pass**, by decomposing the symmetric coefficient matrix
//! `A = Lᵀ D L` and propagating the tuple `(v, L∇v, L[v])` through the
//! computation graph — exactly, with provably ≤½ the FLOPs and lower peak
//! memory than Hessian-based AutoDiff (Theorems 2.1/2.2 of the paper).
//!
//! ## Crate layout
//!
//! * substrates: [`util`], [`prop`], [`tensor`], [`linalg`], [`graph`],
//!   [`parallel`]
//! * the contribution: [`autodiff`] (DOF + the Hessian-based baseline,
//!   both instrumented with exact FLOP and peak-memory accounting)
//! * the planned execution layer: [`plan`] (compile-once operator
//!   programs under every engine)
//! * higher order: [`jet`] (deterministic Taylor-mode forward propagation
//!   for order-3/4 operators — biharmonic, Swift–Hohenberg,
//!   Kuramoto–Sivashinsky — on the same plan/parallel rails)
//! * applications: [`operators`], [`nn`], [`pde`], [`train`]
//! * infrastructure: [`runtime`] (XLA-PJRT artifact execution),
//!   [`coordinator`] (batching / serving), [`obs`] (tracing / profiling /
//!   telemetry export), [`bench_harness`]
//!
//! ## Compile-once operator programs
//!
//! Everything about the eq. 7–9 pass that is static per
//! `(architecture, operator)` is compiled **once** into a
//! [`plan::OperatorProgram`] and reused for every batch:
//!
//! * the node schedule with fused `Linear→Activation` steps;
//! * the liveness table (eq. 24) and a **static slab slot assignment** —
//!   each node's `(v, s, g)` tuple lives at a fixed offset in one
//!   contiguous per-shard slab, so the hot path performs no arena lookups
//!   and no per-node allocation (the `PeakTracker` numbers are replayed
//!   from the identical alloc/free event order, so Theorem 2.2
//!   measurements are unchanged);
//! * the §3.2 active-tangent-row sets, precomputed structurally instead of
//!   rescanned from `L` per call;
//! * exact analytic FLOP and peak-byte costs (both linear in the batch),
//!   so benches report them without executing.
//!
//! `DofEngine::compute*` are compile-then-run wrappers over the keyed
//! [`plan::global_cache`]; cache keys are **weight-value independent**
//! (structure + zero patterns), so serving and the PINN trainer compile on
//! the first batch and execute thereafter. Programs are shard-invariant —
//! they depend on neither batch size nor thread count — which is how the
//! planned path upholds the determinism contract below by construction.
//! The pre-plan interpreter survives as `DofEngine::compute_with_arena`,
//! the differential-testing reference (`rust/tests/plan_equivalence.rs`
//! asserts bit-identical values, `L[φ]`, FLOP counts, and peak bytes).
//!
//! The **Hessian baseline runs on the same compiled machinery**: every
//! `HessianEngine::compute*` entry point executes a structure-keyed
//! [`plan::hessian::HessianPlan`] (shared schedule, static slab layout for
//! the forward tangents and the eq. 14 reverse pass, program-keyed slab
//! pooling, exact analytic FLOP/peak replays), with the original graph
//! walk retained as `HessianEngine::compute_reference` — so the Table 1
//! comparison's two sides are produced by the same planned execution
//! stack.
//!
//! ## One kernel definition, N storage policies
//!
//! Every numeric propagation rule — the eq. 7–9 DOF tuple ops (including
//! the `Mul` cross term and the fused `Linear→Activation` pair), the
//! forward-Jacobian ops, the eq. 14 Hessian reverse ops, and the jet
//! `compose5`/`cauchy5` kernels — is defined **exactly once**, in
//! `plan::kernels`. The slab executors, the retain-all training tape, and
//! the reference interpreters are thin storage policies over those
//! kernels: they resolve where each buffer lives and hand the kernels flat
//! slices. A numeric fix lands in one place; future PRs must preserve this
//! single-kernel invariant (add a storage policy, never a second copy of
//! the arithmetic).
//!
//! ## Kernel specialization
//!
//! Below the shared kernels sits a vectorization and dispatch layer, all
//! stable Rust (no nightly `std::simd` — CI greps it out):
//!
//! * **Chunked lane sweeps** ([`tensor::lanes`]) — every elementwise hot
//!   loop walks `chunks_exact(8)` over fixed `[f64; 8]` arrays (which the
//!   autovectorizer turns into vector code on any ISA) followed by a
//!   scalar tail. Chunking never touches a *reduction*: sums and dots keep
//!   their single-accumulator ascending-`k` loops, because reordering a
//!   reduction tree changes float results and would break every bitwise
//!   oracle.
//! * **Plan-time micro-kernel selection** — each compiled Linear step
//!   records a [`tensor::GemmPlan`] chosen once at compile time from the
//!   **batch-invariant** per-item tangent-row count (DOF `t+2`, jet
//!   `t·(k+1)`, Hessian forward `N`) and the weight dims: below
//!   [`tensor::GEMM_DOT_MAX_MACS`] per-item MACs the serial dot form runs;
//!   above it, the blocked-AXPY form with row-parallel dispatch. The
//!   executors just read the recorded plan — no per-call branching.
//! * **Packed weight panels** ([`tensor::PackedPanel`]) — engines
//!   pre-transpose each AXPY-form Linear's weights once per top-level call
//!   ([`plan::pack_panels`]) and share the panels read-only across shards.
//!   Panels hold weight *values*, so they are never stored in the
//!   structure-keyed program caches (the `cache_soundness` pins).
//!
//! All of this is safe because of one stated invariant, the
//! **bitwise-summation-order contract**: every NT-GEMM output element is a
//! single-accumulator dot over `k` ascending from `+0.0`, in every form —
//! dot, ad-hoc transpose, packed panel. Forms are therefore `==`-identical
//! for every shape, and plans may record either freely without perturbing
//! the oracle hierarchy. `rust/tests/simd_tails.rs` pins the contract at
//! awkward lengths (dims 1/3/5/7/9, non-multiple-of-8 widths, scalar-tail
//! boundaries) across 1/2/4/8 threads, and `dof bench kernels` reports the
//! per-helper and packed-vs-unpacked throughput trajectory.
//!
//! ## Testing strategy: the oracle hierarchy
//!
//! Correctness rests on three independent layers, each checked in CI:
//!
//! 1. **Interpreter oracles (bitwise).** Every planned/slab path is
//!    asserted *bit-identical* — values, operator values, tangents/jets,
//!    exact FLOP counts, peak bytes — to a retained per-call interpreter
//!    with runtime accounting (`plan_equivalence.rs`,
//!    `jet_equivalence.rs`, the Hessian half of
//!    `parallel_determinism.rs`). Shared kernels make agreement
//!    by-construction; the asserts catch storage-policy bugs (slab
//!    aliasing, stale scratch, layout drift) and analytic-replay drift.
//! 2. **Cross-engine agreement (tolerance).** DOF ≡ Hessian baseline and
//!    order-2 jets ≡ DOF on the same operator: three different exact
//!    algorithms summing the same real terms in different orders.
//! 3. **Finite differences (independent).** Central differences of the
//!    plain forward evaluation — the only oracle sharing no code with any
//!    engine — bound everything at FD accuracy.
//!
//! `rust/tests/cross_engine_fuzz.rs` drives all three layers over ≥200
//! seeded random `(architecture, operator)` cases per run
//! ([`prop::generator`]; `DOF_FUZZ_CASES` scales the scheduled CI job),
//! printing the reproducing seed on failure. `cache_soundness.rs` pins the
//! compile-once caches' contract — through all three consumers of the one
//! generic [`util::KeyedCache`]: weight-value moves hit by pointer
//! identity; zero-pattern, topology, or `L`-pattern changes recompile;
//! recompiled plans are re-verified against a fresh interpreter run; and
//! eviction/stat exactness is pinned at the generic layer. The runtime
//! layer has its own battery: `concurrency_stress.rs` (slab-pool hammer +
//! worker-pool lifecycle vs the scoped baseline) and `router_serving.rs`
//! (routed ≡ direct bitwise, exact metrics, draining shutdown).
//!
//! ## Taylor-mode jets (third/fourth order)
//!
//! The second-order engines stop at `Σ a_ij ∂²_ij + Σ b_i ∂_i + c`. The
//! [`jet`] subsystem extends the forward-propagation trick to order 3/4:
//! order-k univariate jets (`k+1` Taylor coefficients per direction,
//! folded `[batch·t·(k+1), d]` so the Linear hot path stays one GEMM) are
//! pushed through exact per-op rules (Faà di Bruno through σ, Cauchy
//! products at `Mul`), and mixed derivatives are assembled by
//! **polarization** over `O(d²)` integer directions — `Δ²` needs exactly
//! `d²` of them. `jet::JetEngine` mirrors `DofEngine` end to end: keyed
//! program cache (`jet::JetProgram`), exact-fit program-keyed slab pool,
//! `compute_sharded` under the same determinism contract (bit-identical
//! across 1/2/4/8 threads — `rust/tests/jet_equivalence.rs`), serving via
//! `ModelServer::spawn_jet`, and `dof bench grid --order 4`.
//!
//! ## Stochastic Taylor jets (STDE)
//!
//! The exact engines pay `O(N)` (DOF) or `O(d²)` (polarized order-4 jets)
//! directions per point. For high-dimensional operators the
//! [`jet::StochasticJetEngine`] trades exactness for dimension-free cost:
//! it pushes `S` *sampled* direction groups per point through the **same
//! compiled jet programs** (a direction-seeding and contraction policy
//! over the existing rails — no new arithmetic, preserving the
//! single-kernel invariant) and returns an **unbiased estimate** of the
//! contraction.
//!
//! * **Estimator.** For each order-`m` term group `Tₘ·Aₘ` (the `m`-th
//!   directional-derivative tensor contracted with the operator's
//!   coefficient tensor), draw `m` independent isotropic directions
//!   `u₁..uₘ` (`E[u uᵀ] = I`; Gaussian or sparse-Rademacher — see
//!   [`jet::DirectionSampling`]) and evaluate `Tₘ(u₁,…,uₘ) · Aₘ(u₁,…,uₘ)`
//!   via polarization over `2^{m−1}` signed combinations. Independence of
//!   the `uₗ` makes `E[Tₘ(u₁..uₘ)·Aₘ(u₁..uₘ)] = Tₘ·Aₘ` exactly; averaging
//!   `S` i.i.d. samples gives the estimate, and their Bessel-corrected
//!   spread gives an exact per-point `variance` / `std_error` report.
//!   First-order terms and `c·φ` are carried **exactly** (one
//!   deterministic direction), and `φ` itself is never estimated — the
//!   value row is bitwise identical to the exact engines.
//! * **Determinism.** Direction streams are counter-derived from
//!   `(seed, global point index, sample index)` — no shared mutable RNG —
//!   so a fixed seed is bit-identical across 1/2/4/8 threads and every
//!   shard decomposition (`compute_sharded` keys each point by its global
//!   batch index), and estimates replay exactly from the telemetry-logged
//!   seed. `rust/tests/stochastic_convergence.rs` pins unbiasedness over
//!   the fuzz families, the ~1/√S error law, stream determinism, and
//!   variance honesty; the engine is the *fourth participant* in
//!   `cross_engine_fuzz.rs` (`DOF_STDE_SAMPLES` scales the scheduled job).
//! * **Serving & bench.** `ModelServer::spawn_stochastic` serves estimates
//!   behind the router with a per-request `samples` override
//!   ([`coordinator::EvalRequest::samples`]; the batcher never mixes
//!   sample groups in one cut); `dof serve --stochastic` registers the
//!   backend, and the schema-v7 `dof bench grid` report carries a
//!   variance-vs-samples sweep against the exact DOF engine.
//!
//! ## Parallel execution & the serving runtime
//!
//! The hot path scales across cores without giving up exactness, and the
//! runtime layer amortizes threads, slabs, and routing across requests:
//!
//! * [`parallel`] — a std-only **persistent worker pool**
//!   ([`parallel::pool`]): OS threads are spawned exactly once per process
//!   (lazily, on the first parallel region — a spawn counter proves zero
//!   thread creation after warmup) and parked on a condvar between
//!   regions. A `Pool::new(t)` region runs on the calling thread plus at
//!   most `t − 1` warm helpers; concurrent regions from different caller
//!   threads (several model servers, say) coexist in the shared queue.
//!   The PR 1 scoped-spawn implementation survives as
//!   `Pool::run_sharded_scoped`, the differential baseline
//!   `rust/tests/concurrency_stress.rs` pins the pooled runtime against,
//!   bit for bit.
//! * **Batch sharding** — `DofEngine::compute_sharded` /
//!   `HessianEngine::compute_sharded` split `[batch, N]` into fixed
//!   8-row shards ([`parallel::DEFAULT_SHARD_ROWS`]); each worker runs the
//!   full tuple propagation on its shard with a slab from the
//!   program-keyed pool, and results are reduced in shard order.
//! * **Row-parallel GEMM** — [`tensor::matmul_into`] splits output rows
//!   (4-aligned, matching the micro-kernel grouping) across the persistent
//!   team for large single-shard products; nested parallelism inside pool
//!   workers is suppressed.
//! * **Sharded slab pool** — [`autodiff::arena::with_program_slab`] keys
//!   slabs by `(program fingerprint, rows)` with exact-fit checkout, and
//!   the pool is lock-sharded by key hash (16 mutexes), so concurrent
//!   unsharded `execute()` calls from caller-owned threads no longer
//!   serialize on one global lock. Program fingerprints are domain-tagged
//!   (DOF / Hessian / jet), so engines never alias each other's slabs.
//! * **Serving** — `coordinator::ModelServer::spawn_dof` /
//!   `spawn_hessian` / `spawn_jet` each own a worker thread executing a
//!   precompiled program per shard; the multi-model
//!   [`coordinator::Router`] registers them under names and picks a
//!   replica per request by [`coordinator::DispatchPolicy`] score
//!   (`inflight_weight · router inflight + queue_weight · admission
//!   depth + occupancy_weight · parallel_occupancy`; lower wins, lowest
//!   index breaks exact ties, untried replicas beat already-tried ones
//!   on failover). The default weights (1, 1, 0) read exact counters
//!   only; `occupancy_weight > 0` opts into the measured-seconds
//!   signal. Replica sets are **elastic**: the dispatch list is
//!   epoch-versioned, so `Router::scale_up` (via a registered
//!   `ReplicaFactory`) and `Router::retire_replica` (publish the
//!   shrunken list first, then drain — no request lost) reach existing
//!   clients on their very next request, and the deterministic
//!   [`coordinator::Autoscaler`] steps on the shared logical clock:
//!   interval queue-depth peaks against dead-band thresholds, cooldown
//!   hysteresis, min/max replica bounds, at most one change per model
//!   per step, zero wall-clock reads in the decision path
//!   (CI-enforced). Per-model snapshots aggregate server metrics across
//!   the whole replica set — counts summed, latency histograms merged,
//!   occupancy weighted by sharded wall seconds. Routed results are
//!   bitwise identical to direct engine calls before, during, and after
//!   scaling (`rust/tests/router_serving.rs`,
//!   `rust/tests/autoscaler.rs`), and shutdown drains every queued
//!   request.
//!
//! **Determinism contract:** shard boundaries are a function of the batch
//! size alone (never the thread count) and every reduction is shard-ordered
//! with no atomics-based float accumulation, so values, `L[φ]`, FLOP counts,
//! and per-shard peak-tangent bytes are bit-identical across
//! `--threads 1/2/4/8` — and per-row arithmetic is row-independent, so
//! sharded values match the unsharded engines exactly. Peak-memory
//! measurements are reported per shard, which is what Theorem 2.2 bounds at
//! the shard's batch size.
//!
//! **Choosing thread counts for benches:** physical cores is the right
//! ceiling (the engines are compute-bound); batches below one shard run
//! inline. `dof bench table1 --threads N` and `dof bench grid` sweep the
//! knob and emit `BENCH_table1.json` for trend tracking.
//!
//! ## Observability
//!
//! The [`obs`] subsystem makes the serving stack inspectable without
//! perturbing it — **observation is bitwise invisible** (traced ≡ untraced
//! results across 1/2/4/8 threads, pinned by
//! `rust/tests/observability.rs`):
//!
//! * **Request tracing** — an [`obs::TraceContext`] (request id + parent
//!   span id) rides each request through
//!   `RouterClient → dispatch → admission/queue/batch → engine → shards`;
//!   each layer records spans (request, attempt, queue wait, batch
//!   formation, execute, per-shard) into the bounded lock-sharded
//!   [`obs::Tracer`] ring. Span *timestamps* are logical
//!   [`coordinator::TickClock`] ticks (the control-plane no-wall-clock
//!   rule, CI-greps enforced); *durations* are real seconds measured by
//!   the layer owning the execution. Under ring pressure the oldest spans
//!   are evicted, counted exactly in `dropped_spans`.
//! * **Per-step profiling** — the planned executors accept an optional
//!   [`obs::StepProfiler`] (`Option<&mut _>`, one branch per step, zero
//!   allocation when absent) recording measured seconds per program step
//!   beside the step's exact analytic FLOPs — the same per-step costs the
//!   programs sum into `cost(batch)`, so the efficiency table's two
//!   columns are mutually consistent by construction.
//! * **Telemetry export** — [`obs::Registry`] aggregates metrics
//!   snapshots, router/replica snapshots, program-cache + slab-pool +
//!   worker-pool counters, span logs, and profile summaries into one
//!   `"telemetry_schema"`-tagged JSON document (spans one-per-line) plus a
//!   Prometheus text exposition. `dof serve --telemetry <path>` dumps it
//!   periodically and on drain; `dof trace --dump <path>` pretty-prints a
//!   request's span tree from a dump.
//!
//! ## Error taxonomy & failure semantics
//!
//! The serving tier never panics across a request boundary: every failure
//! a client can observe is a structured
//! [`coordinator::ServeError`], and every control-plane decision reads the
//! **logical tick clock** ([`coordinator::TickClock`]) — never wall time —
//! so failure schedules are replayable bit for bit.
//!
//! * `InvalidRequest` — raised at the front door: malformed input
//!   (empty, not a multiple of the model width, non-finite values).
//!   Never retried, never counted against replica health.
//! * `Overloaded` — raised by the admission gate when the replica's
//!   bounded in-flight queue (`ServeConfig::queue_cap`) is full. The
//!   router fails over to another replica if the retry budget allows;
//!   counted in `shed`.
//! * `DeadlineExceeded` — raised by router or server when the request's
//!   logical-tick deadline (`RouterConfig::deadline_ticks`) expired
//!   before compute started. Not retried (the deadline has passed by
//!   definition).
//! * `EngineFault` — raised on the compute path: the engine panicked
//!   (payload captured via `catch_unwind`, with model label and — on the
//!   sharded path — the faulting shard index and row range from
//!   [`parallel::pool`]) or produced non-finite outputs. Retried on
//!   another replica; counts against the replica's health.
//!
//! Replica health walks `Healthy → Degraded → Quarantined`
//! ([`coordinator::HealthState`], thresholds in
//! [`coordinator::HealthPolicy`]): only `EngineFault`s advance the
//! consecutive-failure count, quarantined replicas stop receiving traffic,
//! and after a tick-based backoff window (doubling per failed probe) live
//! requests double as **re-admission probes**. Failure accounting is
//! exact, not sampled: the router classifies each failed request by its
//! *final* error (`shed` / `deadline_expired` / `invalid`), counts
//! `engine_faults` per attempt, and `retries` per failover hop — the
//! fault-injection battery (`rust/tests/fault_injection.rs`) replays
//! seeded fault schedules via [`coordinator::FaultInjector::plan_for`] and
//! asserts these counters equal the schedule, while every successful
//! response stays bitwise identical to its fault-free twin.

pub mod autodiff;
pub mod bench_harness;
pub mod coordinator;
pub mod graph;
pub mod jet;
pub mod linalg;
pub mod nn;
pub mod obs;
pub mod operators;
pub mod parallel;
pub mod pde;
pub mod plan;
pub mod prop;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;
