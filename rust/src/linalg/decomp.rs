//! The DOF coefficient-matrix decomposition `A = Lᵀ D L` (paper §2.2).
//!
//! Given the symmetric coefficient matrix `A` of a second-order operator
//! `Σ a_ij ∂²_ij`, DOF seeds its tangent with `g⁰ = L` and contracts pairs of
//! tangents through `D`. The paper's construction: eigendecompose
//! `A = Sᵀ Σ S`, take `L = |Σ|^{1/2} S` and `D = sgn(Σ)`; rows of `L`
//! associated with zero eigenvalues are dropped, so for a rank-`r` operator
//! `L ∈ R^{r×N}` and the tangent dimension shrinks from `N` to `r` — the
//! source of the paper's low-rank speedup (§2.2 "Low-rank Coefficient
//! Matrix").

use super::eigen::eigh;
use crate::tensor::{matmul, Tensor};

/// Relative eigenvalue threshold below which a direction is treated as rank
/// deficient and dropped from `L`.
pub const RANK_TOL: f64 = 1e-10;

/// `A = Lᵀ D L` with `L ∈ R^{r×N}` and `D = diag(±1) ∈ R^{r×r}`.
#[derive(Debug, Clone)]
pub struct LdlDecomposition {
    /// `r × N` factor; row `k` is `|λ_k|^{1/2} · s_kᵀ`.
    pub l: Tensor,
    /// Signs of the retained eigenvalues, each ±1.
    pub d: Vec<f64>,
    /// Input dimension `N`.
    pub n: usize,
}

impl LdlDecomposition {
    /// Decompose a symmetric matrix. `a` is symmetrized (`(A+Aᵀ)/2`) first —
    /// the operator `Σ a_ij ∂²_ij` only sees the symmetric part anyway.
    pub fn of(a: &Tensor) -> Self {
        assert_eq!(a.rank(), 2);
        let n = a.dims()[0];
        assert_eq!(n, a.dims()[1]);
        let sym = a.add(&a.transpose()).scale(0.5);
        let e = eigh(&sym);
        let max_abs = e.values.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        let tol = max_abs * RANK_TOL;

        let kept: Vec<usize> = (0..n).filter(|&i| e.values[i].abs() > tol).collect();
        let r = kept.len();
        let mut l = Tensor::zeros(&[r, n]);
        let mut d = Vec::with_capacity(r);
        for (row, &i) in kept.iter().enumerate() {
            let lam = e.values[i];
            let scale = lam.abs().sqrt();
            d.push(if lam >= 0.0 { 1.0 } else { -1.0 });
            for col in 0..n {
                // Eigenvectors are columns of `vectors`; row of L is the
                // scaled transposed eigenvector.
                l.set(row, col, scale * e.vectors.at(col, i));
            }
        }
        Self { l, d, n }
    }

    /// Rank `r` of the retained decomposition.
    pub fn rank(&self) -> usize {
        self.d.len()
    }

    /// `rank(D)` restricted to +1 entries (number of positive directions).
    pub fn positive_directions(&self) -> usize {
        self.d.iter().filter(|&&s| s > 0.0).count()
    }

    /// Is the operator elliptic-definite (all retained signs +1)?
    pub fn is_elliptic(&self) -> bool {
        self.d.iter().all(|&s| s > 0.0)
    }

    /// Reconstruct `Lᵀ D L` (test/diagnostic helper).
    pub fn reconstruct(&self) -> Tensor {
        let r = self.rank();
        let mut dl = self.l.clone();
        for i in 0..r {
            let s = self.d[i];
            for v in dl.row_mut(i) {
                *v *= s;
            }
        }
        matmul_t_first(&self.l, &dl)
    }

    /// Contract a pair of tangent vectors through `D`:
    /// `⟨u, v⟩_D = Σ_k d_k u_k v_k`. This is the inner product appearing in
    /// eq. (9)'s first term.
    pub fn d_inner(&self, u: &[f64], v: &[f64]) -> f64 {
        debug_assert_eq!(u.len(), self.rank());
        debug_assert_eq!(v.len(), self.rank());
        self.d
            .iter()
            .zip(u.iter().zip(v.iter()))
            .map(|(&s, (&a, &b))| s * a * b)
            .sum()
    }
}

/// `Aᵀ · B` helper (A: r×n, B: r×n → n×n).
fn matmul_t_first(a: &Tensor, b: &Tensor) -> Tensor {
    matmul(&a.transpose(), b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn random_symmetric(n: usize, seed: u64) -> Tensor {
        let mut rng = Xoshiro256::new(seed);
        let b = Tensor::randn(&[n, n], &mut rng);
        b.add(&b.transpose()).scale(0.5)
    }

    #[test]
    fn reconstructs_full_rank() {
        for seed in [1, 5, 9] {
            let a = random_symmetric(10, seed);
            let dec = LdlDecomposition::of(&a);
            assert_eq!(dec.rank(), 10);
            assert!(dec.reconstruct().max_abs_diff(&a) < 1e-9);
        }
    }

    #[test]
    fn identity_gives_orthogonal_l_and_unit_d() {
        let a = Tensor::eye(6);
        let dec = LdlDecomposition::of(&a);
        assert_eq!(dec.rank(), 6);
        assert!(dec.is_elliptic());
        assert!(dec.reconstruct().max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn low_rank_gram_truncates() {
        // A = B Bᵀ with B: 8×3 → rank 3, elliptic.
        let mut rng = Xoshiro256::new(3);
        let b = Tensor::randn(&[8, 3], &mut rng);
        let a = matmul(&b, &b.transpose());
        let dec = LdlDecomposition::of(&a);
        assert_eq!(dec.rank(), 3, "rank should be 3, got {}", dec.rank());
        assert!(dec.is_elliptic());
        assert!(dec.reconstruct().max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn indefinite_signs() {
        // diag(1, -1, 0, 2): rank 3, one negative direction.
        let mut a = Tensor::zeros(&[4, 4]);
        a.set(0, 0, 1.0);
        a.set(1, 1, -1.0);
        a.set(3, 3, 2.0);
        let dec = LdlDecomposition::of(&a);
        assert_eq!(dec.rank(), 3);
        assert!(!dec.is_elliptic());
        assert_eq!(dec.d.iter().filter(|&&s| s < 0.0).count(), 1);
        assert!(dec.reconstruct().max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn d_inner_matches_quadratic_form() {
        // For any x: xᵀ A x == (Lx)ᵀ D (Lx).
        let a = random_symmetric(7, 11);
        let dec = LdlDecomposition::of(&a);
        let mut rng = Xoshiro256::new(12);
        for _ in 0..10 {
            let x = Tensor::randn(&[7, 1], &mut rng);
            let lx = matmul(&dec.l, &x);
            let quad_ldl = dec.d_inner(lx.data(), lx.data());
            let ax = matmul(&a, &x);
            let quad_direct = x.data().iter().zip(ax.data()).map(|(&u, &v)| u * v).sum::<f64>();
            assert!((quad_ldl - quad_direct).abs() < 1e-9);
        }
    }

    #[test]
    fn asymmetric_input_uses_symmetric_part() {
        let mut rng = Xoshiro256::new(20);
        let a = Tensor::randn(&[5, 5], &mut rng);
        let sym = a.add(&a.transpose()).scale(0.5);
        let dec = LdlDecomposition::of(&a);
        assert!(dec.reconstruct().max_abs_diff(&sym) < 1e-9);
    }
}
