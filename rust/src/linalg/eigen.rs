//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! The DOF decomposition `A = Lᵀ D L` (paper §2.2) needs the full spectrum of
//! the symmetric coefficient matrix `A`. Matrices here are small (`N ≤ a few
//! hundred` — the PDE input dimension), so cyclic Jacobi is simple, robust,
//! and accurate (it converges quadratically and keeps eigenvectors
//! orthogonal to machine precision).

use crate::tensor::Tensor;

/// Result of a symmetric eigendecomposition `A = V · diag(λ) · Vᵀ`.
#[derive(Debug, Clone)]
pub struct EigenDecomposition {
    /// Eigenvalues, sorted descending by absolute value.
    pub values: Vec<f64>,
    /// Orthogonal matrix whose *columns* are the corresponding eigenvectors.
    pub vectors: Tensor,
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
///
/// Panics if `a` is not square; asymmetry beyond `1e-9` relative is treated
/// as a caller bug (the operator layer symmetrizes first).
pub fn eigh(a: &Tensor) -> EigenDecomposition {
    assert_eq!(a.rank(), 2, "eigh expects a matrix");
    let n = a.dims()[0];
    assert_eq!(n, a.dims()[1], "eigh expects a square matrix");
    // Work on a copy; accumulate rotations into V.
    let mut m = a.clone();
    let mut v = Tensor::eye(n);

    let max_sweeps = 100;
    for _sweep in 0..max_sweeps {
        let off = off_diagonal_norm(&m);
        let scale = m.max_abs().max(1e-300);
        if off / scale < 1e-14 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.at(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.at(p, p);
                let aqq = m.at(q, q);
                // Stable computation of the rotation angle.
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                apply_rotation(&mut m, p, q, c, s);
                rotate_columns(&mut v, p, q, c, s);
            }
        }
    }

    // Extract and sort by |λ| descending (the paper truncates zero
    // eigenvalues for low-rank A; putting large |λ| first makes the
    // truncation a prefix).
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m.at(i, i), i)).collect();
    pairs.sort_by(|a, b| b.0.abs().partial_cmp(&a.0.abs()).unwrap());
    let values: Vec<f64> = pairs.iter().map(|&(l, _)| l).collect();
    let mut vectors = Tensor::zeros(&[n, n]);
    for (new_col, &(_, old_col)) in pairs.iter().enumerate() {
        for r in 0..n {
            vectors.set(r, new_col, v.at(r, old_col));
        }
    }
    EigenDecomposition { values, vectors }
}

/// Frobenius norm of the strictly-off-diagonal part.
fn off_diagonal_norm(m: &Tensor) -> f64 {
    let n = m.dims()[0];
    let mut s = 0.0;
    for i in 0..n {
        for j in 0..n {
            if i != j {
                s += m.at(i, j) * m.at(i, j);
            }
        }
    }
    s.sqrt()
}

/// Two-sided Jacobi rotation `m ← Jᵀ m J` on rows/cols p, q.
fn apply_rotation(m: &mut Tensor, p: usize, q: usize, c: f64, s: f64) {
    let n = m.dims()[0];
    for k in 0..n {
        let mkp = m.at(k, p);
        let mkq = m.at(k, q);
        m.set(k, p, c * mkp - s * mkq);
        m.set(k, q, s * mkp + c * mkq);
    }
    for k in 0..n {
        let mpk = m.at(p, k);
        let mqk = m.at(q, k);
        m.set(p, k, c * mpk - s * mqk);
        m.set(q, k, s * mpk + c * mqk);
    }
}

/// Right-multiply `v` by the rotation (accumulates eigenvectors).
fn rotate_columns(v: &mut Tensor, p: usize, q: usize, c: f64, s: f64) {
    let n = v.dims()[0];
    for k in 0..n {
        let vkp = v.at(k, p);
        let vkq = v.at(k, q);
        v.set(k, p, c * vkp - s * vkq);
        v.set(k, q, s * vkp + c * vkq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::Xoshiro256;

    fn reconstruct(e: &EigenDecomposition) -> Tensor {
        let n = e.values.len();
        let mut lam = Tensor::zeros(&[n, n]);
        for i in 0..n {
            lam.set(i, i, e.values[i]);
        }
        let vl = matmul(&e.vectors, &lam);
        matmul(&vl, &e.vectors.transpose())
    }

    fn random_symmetric(n: usize, seed: u64) -> Tensor {
        let mut rng = Xoshiro256::new(seed);
        let b = Tensor::randn(&[n, n], &mut rng);
        let bt = b.transpose();
        b.add(&bt).scale(0.5)
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let mut a = Tensor::zeros(&[3, 3]);
        a.set(0, 0, 2.0);
        a.set(1, 1, -5.0);
        a.set(2, 2, 1.0);
        let e = eigh(&a);
        // Sorted by |λ| desc: -5, 2, 1
        assert!((e.values[0] + 5.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_random() {
        for seed in [1, 2, 3] {
            let a = random_symmetric(16, seed);
            let e = eigh(&a);
            let r = reconstruct(&e);
            assert!(a.max_abs_diff(&r) < 1e-9, "seed {seed}: {}", a.max_abs_diff(&r));
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let a = random_symmetric(20, 7);
        let e = eigh(&a);
        let vtv = matmul(&e.vectors.transpose(), &e.vectors);
        assert!(vtv.max_abs_diff(&Tensor::eye(20)) < 1e-10);
    }

    #[test]
    fn psd_gram_matrix_nonnegative_spectrum() {
        let mut rng = Xoshiro256::new(9);
        let b = Tensor::randn(&[12, 12], &mut rng);
        let a = matmul(&b, &b.transpose());
        let e = eigh(&a);
        for &l in &e.values {
            assert!(l > -1e-9, "negative eigenvalue {l} for PSD matrix");
        }
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Tensor::matrix(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let e = eigh(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 1.0).abs() < 1e-12);
    }
}
