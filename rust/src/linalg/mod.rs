//! Linear-algebra substrate: symmetric eigendecomposition (cyclic Jacobi)
//! and the paper's `A = Lᵀ D L` coefficient decomposition with rank
//! truncation.

pub mod decomp;
pub mod eigen;

pub use decomp::{LdlDecomposition, RANK_TOL};
pub use eigen::{eigh, EigenDecomposition};
