//! `dof` — CLI for the DOF reproduction.
//!
//! ```text
//! dof bench table1 [--batch 8 --reps 10 --n 64 --hidden 256 --layers 8 --threads 8]
//! dof bench table2 [--batch 8 --reps 10 --threads 8]
//! dof bench grid   [--batches 8,64,256 --threads-grid 1,2,4,8 --out BENCH_table1.json]
//! dof bench xla    [--artifact dof_mlp_elliptic --reps 20]
//! dof bench kernels [--len 8195 --gemm-shapes 10x16x16,66x64x64 --out BENCH_kernels.json]
//! dof train  [--pde heat|klein-gordon|poisson|fokker-planck --steps 300 ...]
//! dof decompose [--spec elliptic|lowrank|general --n 64]
//! dof inspect [--artifacts artifacts]
//! dof serve  [--engine rust|xla --artifact dof_mlp_elliptic --requests 64 --rows 8]
//! dof trace  [--dump TELEMETRY.json --request N]
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};
use dof::bench_harness::jet_grid::{run_jet_grid, write_jet_grid_json, JetGridConfig};
use dof::bench_harness::kernels::{run_kernel_bench, write_kernels_json, KernelsConfig};
use dof::bench_harness::report::{run_table1_grid, write_grid_json};
use dof::bench_harness::table1::{run_table1, Table1Config};
use dof::bench_harness::table2::{run_table2, Table2Config};
use dof::bench_harness::{render_table, BenchConfig};
use dof::coordinator::{
    Autoscaler, AutoscalerConfig, BatchPolicy, HealthPolicy, ModelServer, Router, RouterConfig,
    ScaleDirection, ServeConfig, TickClock,
};
use dof::graph::{Act, Graph};
use dof::jet::DirectionSampling;
use dof::nn::{Mlp, MlpSpec};
use dof::obs::{parse_spans, render_tree, Registry, StochasticConfig, Tracer};
use dof::operators::{CoeffSpec, HigherOrderOperator, HigherOrderSpec, Operator};
use dof::parallel::{self, Pool};
use dof::pde::trainer::{PinnConfig, PinnTrainer};
use dof::pde::{fokker_planck, heat_equation, klein_gordon, poisson};
use dof::runtime::{ArtifactRegistry, Executor};
use dof::train::AdamConfig;
use dof::util::{fmt_duration, Args, Xoshiro256};

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    // Process-wide thread knob (also drives the row-parallel GEMM); the
    // `DOF_THREADS` env var is the non-CLI equivalent. Both are validated
    // up front — unconditionally, so a malformed `DOF_THREADS` is a hard
    // error naming the offending value even when `--threads` would win —
    // never a panic or a silent fall-back to all cores.
    let env_threads = parallel::env_threads_checked().map_err(|e| anyhow!(e))?;
    match args.thread_count("threads").map_err(|e| anyhow!(e))? {
        Some(t) => parallel::set_global_threads(t),
        None => {
            if let Some(t) = env_threads {
                parallel::set_global_threads(t);
            }
        }
    }
    match args.command.as_deref() {
        Some("bench") => cmd_bench(args),
        Some("train") => cmd_train(args),
        Some("decompose") => cmd_decompose(args),
        Some("inspect") => cmd_inspect(args),
        Some("serve") => cmd_serve(args),
        Some("trace") => cmd_trace(args),
        Some(other) => Err(anyhow!("unknown command {other:?}\n{USAGE}")),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "dof — Differential Operators with Forward propagation

USAGE:
  dof bench table1|table2|xla [options]   regenerate the paper's tables
  dof bench kernels [--len 8195]          lane-helper ns/element + packed
            [--gemm-shapes 66x64x64,...]  vs unpacked NT-GEMM throughput
            [--out BENCH_kernels.json]    (schema-v6 kernels object)
  dof bench grid [--batches 8,64,256]     batch × threads sweep → BENCH_table1.json
            [--threads-grid 1,2,4,8]
            [--order 2|4]                 4 = biharmonic Δ² via the jet
                                          subsystem → BENCH_jet_grid.json
  dof train [--pde heat] [--steps 300]    train a PINN through DOF
  dof decompose [--spec elliptic --n 64]  show an A = LᵀDL decomposition
  dof inspect [--artifacts artifacts]     list AOT artifacts
  dof serve [--artifact dof_mlp_elliptic] run the multi-model router demo
            [--engine rust|xla]           (default: rust unless built with
                                           the pjrt feature; rust = sharded
                                           DOF engine backend)
            [--order 2|4]                 rust engine: 4 serves precompiled
                                          biharmonic jet programs
            [--multi]                     rust engine: DOF + Hessian + jet
                                          models behind one router (mixed
                                          tagged traffic)
            [--replicas N]                rust engine: N replicas per model
                                          (retry/failover targets)
            [--queue-cap N]               per-replica admission cap; past it
                                          requests shed with Overloaded
                                          (0 = unbounded)
            [--deadline-ticks N]          per-request deadline on the
                                          logical tick clock (one tick per
                                          completed request; 0 = none)
            [--retries N]                 failover attempts after the first
                                          on retryable errors
            [--autoscale]                 grow/drain replica sets from queue
                                          depth on the tick clock (rust
                                          engine; deterministic decisions)
            [--autoscale-min N]           replica floor (default 1)
            [--autoscale-max N]           replica ceiling (default 4)
            [--autoscale-up-depth N]      scale up at interval peak queue
                                          depth >= N (default 8)
            [--autoscale-down-depth N]    scale down at interval peak queue
                                          depth <= N (default 1)
            [--autoscale-cooldown N]      ticks between scale events per
                                          model (default 16)
            [--stochastic]                rust engine: also register the
                                          stochastic (STDE) backend — an
                                          unbiased sampled estimator of the
                                          same operator through the jet
                                          rails; O(samples) dirs per point
                                          instead of O(N) / O(N²)
            [--stde-samples N]            STDE default sample count per
                                          point (default 64)
            [--stde-nnz K]                K > 0: sparse-Rademacher sampling
                                          with K nonzero coords per
                                          direction (default 0 = Gaussian)
            [--stde-request-samples N]    clients override the sample count
                                          per request on the stochastic
                                          model (0 = use backend default)
            [--telemetry PATH]            trace every request and export the
                                          telemetry registry: PATH (JSON,
                                          periodic + final on drain) and
                                          PATH.prom (Prometheus text)
  dof trace --dump PATH [--request N]     pretty-print the span tree(s) of a
                                          telemetry dump (one request, or
                                          every retained request)

  --threads N (or DOF_THREADS=N) sizes the worker team for batch sharding
  and the row-parallel GEMM; OS threads spawn once per process and are
  reused across regions; results are bit-identical at any N.";

fn bench_config(args: &Args) -> BenchConfig {
    BenchConfig {
        warmup_iters: args.usize_or("warmup", 2),
        measure_iters: args.usize_or("reps", 10),
        max_seconds: args.f64_or("max-seconds", 60.0),
    }
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("table1");
    match which {
        "table1" => {
            let cfg = Table1Config {
                n: args.usize_or("n", 64),
                hidden: args.usize_or("hidden", 256),
                layers: args.usize_or("layers", 8),
                batch: args.usize_or("batch", 8),
                threads: args.usize_or("threads", parallel::env_threads().unwrap_or(1)),
                seed: args.u64_or("seed", 7),
                bench: bench_config(args),
            };
            eprintln!(
                "table1: MLP {}→{}×{}→1, batch {}, threads {} …",
                cfg.n, cfg.hidden, cfg.layers, cfg.batch, cfg.threads
            );
            let rows = run_table1(&cfg);
            println!(
                "{}",
                render_table(
                    &format!(
                        "Table 1 — MLP (N={}, hidden={}, layers={}, batch={}, threads={})",
                        cfg.n, cfg.hidden, cfg.layers, cfg.batch, cfg.threads
                    ),
                    &rows
                )
            );
        }
        "table2" => {
            let cfg = Table2Config {
                blocks: args.usize_or("blocks", 16),
                block_in: args.usize_or("block-in", 4),
                hidden: args.usize_or("hidden", 256),
                layers: args.usize_or("layers", 8),
                block_out: args.usize_or("block-out", 8),
                batch: args.usize_or("batch", 8),
                threads: args.usize_or("threads", parallel::env_threads().unwrap_or(1)),
                seed: args.u64_or("seed", 7),
                bench: bench_config(args),
            };
            eprintln!(
                "table2: sparse MLP {}×{}→{}×{}→{}, batch {}, threads {} …",
                cfg.blocks,
                cfg.block_in,
                cfg.hidden,
                cfg.layers,
                cfg.block_out,
                cfg.batch,
                cfg.threads
            );
            let rows = run_table2(&cfg);
            println!(
                "{}",
                render_table(
                    &format!(
                        "Table 2 — MLP with Jacobian sparsity ({}×{} blocks, batch {})",
                        cfg.blocks, cfg.block_in, cfg.batch
                    ),
                    &rows
                )
            );
        }
        "grid" => {
            match args.usize_or("order", 2) {
                2 => {}
                4 => return cmd_bench_jet_grid(args),
                other => {
                    return Err(anyhow!(
                        "unsupported --order {other} (2 = DOF grid, 4 = biharmonic jet grid)"
                    ))
                }
            }
            let cfg = Table1Config {
                n: args.usize_or("n", 64),
                hidden: args.usize_or("hidden", 256),
                layers: args.usize_or("layers", 8),
                batch: 0, // per-cell batches come from --batches
                threads: 1,
                seed: args.u64_or("seed", 7),
                bench: bench_config(args),
            };
            let batches = args.usize_list_or("batches", &[8, 64, 256]);
            let threads = args.usize_list_or("threads-grid", &[1, 2, 4, 8]);
            let out = args.get_or("out", "BENCH_table1.json");
            eprintln!(
                "grid: MLP {}→{}×{}→1, batches {batches:?} × threads {threads:?} …",
                cfg.n, cfg.hidden, cfg.layers
            );
            let report = run_table1_grid(&cfg, &batches, &threads);
            println!(
                "plan compile: {} once per (architecture, operator) — \
                 {} fused steps, {} slab scalars/row, {} muls/row analytic; \
                 per-batch rows below execute the reused program",
                fmt_duration(report.plan.compile_seconds),
                report.plan.fused_steps,
                report.plan.slab_per_row,
                report.plan.dof_muls_per_row
            );
            println!(
                "worker pool: cold region {} ({}), warm region {} — {} threads, \
                 {} spawn event(s) for the process",
                fmt_duration(report.pool.cold_region_seconds),
                if report.pool.cold_included_spawn {
                    "includes one-time spawn"
                } else {
                    "team already warm"
                },
                fmt_duration(report.pool.warm_region_seconds),
                report.pool.workers,
                report.pool.spawn_events
            );
            println!(
                "fault-tier probe: {}/{} requests completed | retries {} | \
                 engine faults {} | quarantine events {} | {}/{} replicas healthy",
                report.robustness.completed,
                report.robustness.requests,
                report.robustness.retries,
                report.robustness.engine_faults,
                report.robustness.quarantine_events,
                report.robustness.healthy_replicas,
                report.robustness.replicas
            );
            println!("| batch | threads | DOF exec | Hessian exec | H/D ratio |");
            println!("|-------|---------|----------|--------------|-----------|");
            for c in &report.cells {
                println!(
                    "| {} | {} | {} | {} | {:.2} |",
                    c.batch,
                    c.threads,
                    fmt_duration(c.dof_seconds),
                    fmt_duration(c.hessian_seconds),
                    c.time_ratio()
                );
            }
            write_grid_json(&out, &cfg, &report)?;
            eprintln!("grid written to {out}");
        }
        "xla" => cmd_bench_xla(args)?,
        "kernels" => cmd_bench_kernels(args)?,
        other => {
            return Err(anyhow!(
                "unknown bench {other:?} (table1|table2|grid|xla|kernels)"
            ))
        }
    }
    Ok(())
}

/// `dof bench grid --order 4`: the biharmonic jet operator swept over
/// batch × threads on both shipped architectures, reporting plan-compile vs
/// per-batch execute time plus the program's exact analytic FLOP/peak
/// columns (schema-v2 JSON).
fn cmd_bench_jet_grid(args: &Args) -> Result<()> {
    let cfg = JetGridConfig {
        n: args.usize_or("n", 8),
        hidden: args.usize_or("hidden", 32),
        layers: args.usize_or("layers", 3),
        seed: args.u64_or("seed", 7),
        bench: bench_config(args),
    };
    if cfg.n < 4 || cfg.n % 2 != 0 {
        return Err(anyhow!(
            "--order 4 grid needs an even --n ≥ 4 (sparse blocks of 2), got {}",
            cfg.n
        ));
    }
    let batches = args.usize_list_or("batches", &[8, 64]);
    let threads = args.usize_list_or("threads-grid", &[1, 2, 4, 8]);
    let out = args.get_or("out", "BENCH_jet_grid.json");
    eprintln!(
        "jet grid: biharmonic Δ² (N={}, {} directions), batches {batches:?} × threads {threads:?} …",
        cfg.n,
        cfg.n * cfg.n
    );
    let report = run_jet_grid(&cfg, &batches, &threads);
    for p in &report.plans {
        println!(
            "plan compile [{}]: {} once per (architecture, operator) — {} fused steps, \
             {} dirs × order 4, {} slab scalars/row, {} muls/row and {} peak bytes/row analytic",
            p.arch,
            fmt_duration(p.compile_seconds),
            p.fused_steps,
            p.dirs,
            p.slab_per_row,
            p.muls_per_row,
            p.peak_bytes_per_row
        );
    }
    println!("| arch | batch | threads | jet exec | muls (exact) | peak bytes |");
    println!("|------|-------|---------|----------|--------------|------------|");
    for c in &report.cells {
        println!(
            "| {} | {} | {} | {} | {} | {} |",
            c.arch,
            c.batch,
            c.threads,
            fmt_duration(c.jet_seconds),
            c.jet_muls,
            c.jet_peak_bytes
        );
    }
    write_jet_grid_json(&out, &cfg, &report)?;
    eprintln!("jet grid written to {out}");
    Ok(())
}

/// `dof bench kernels`: per-helper ns/element for the chunked lane sweeps
/// and dot vs unpacked-AXPY vs packed-panel NT-GEMM throughput, with the
/// analytic [`dof::tensor::GemmPlan`] choice per shape (schema-v6 JSON).
fn cmd_bench_kernels(args: &Args) -> Result<()> {
    let mut cfg = KernelsConfig {
        len: args.usize_or("len", KernelsConfig::default().len),
        seed: args.u64_or("seed", 17),
        bench: bench_config(args),
        ..Default::default()
    };
    if let Some(spec) = args.get("gemm-shapes") {
        // "10x16x16,66x64x64" → [(10,16,16), (66,64,64)]
        cfg.gemm_shapes = spec
            .split(',')
            .map(|shape| {
                let dims = shape
                    .split('x')
                    .map(|d| d.trim().parse::<usize>())
                    .collect::<std::result::Result<Vec<_>, _>>()
                    .map_err(|e| anyhow!("bad --gemm-shapes entry {shape:?}: {e}"))?;
                match dims[..] {
                    [m, k, n] if m > 0 && k > 0 && n > 0 => Ok((m, k, n)),
                    _ => Err(anyhow!("bad --gemm-shapes entry {shape:?} (want MxKxN)")),
                }
            })
            .collect::<Result<Vec<_>>>()?;
    }
    let out = args.get_or("out", "BENCH_kernels.json");
    eprintln!(
        "kernels: {} elements/helper, GEMM shapes {:?} …",
        cfg.len, cfg.gemm_shapes
    );
    let report = run_kernel_bench(&cfg);
    println!("| helper | elements | ns/element |");
    println!("|--------|----------|------------|");
    for c in &report.elementwise {
        println!("| {} | {} | {:.3} |", c.name, c.elements, c.ns_per_element);
    }
    println!("| m×k×n | plan | dot GF/s | unpacked GF/s | packed GF/s |");
    println!("|-------|------|----------|---------------|-------------|");
    for g in &report.gemm {
        println!(
            "| {}×{}×{} | {:?}{} | {:.2} | {:.2} | {:.2} |",
            g.m,
            g.k,
            g.n,
            g.plan.form,
            if g.plan.parallel { "∥" } else { "" },
            g.dot_gflops,
            g.unpacked_gflops,
            g.packed_gflops
        );
    }
    write_kernels_json(&out, &cfg, &report)?;
    eprintln!("kernels written to {out}");
    Ok(())
}

fn cmd_bench_xla(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let reg = ArtifactRegistry::open(&dir)?;
    let reps = args.usize_or("reps", 20);
    let pairs = [
        ("dof_mlp_elliptic", "hessian_mlp_elliptic"),
        ("dof_mlp_lowrank", "hessian_mlp_lowrank"),
        ("dof_mlp_general", "hessian_mlp_general"),
    ];
    let mut exec = Executor::cpu()?;
    println!("platform: {}", exec.platform());
    println!("| pair | DOF median | Hessian median | ratio |");
    println!("|------|------------|----------------|-------|");
    let mut rng = Xoshiro256::new(11);
    for (dof_name, hes_name) in pairs {
        let batch = reg.batch_of(dof_name).unwrap_or(32);
        exec.load(dof_name, &reg.path(dof_name)?)?;
        exec.load(hes_name, &reg.path(hes_name)?)?;
        let x: Vec<f32> = (0..batch * 64).map(|_| rng.normal() as f32).collect();
        let time_it = |exec: &Executor, name: &str| -> Result<f64> {
            // warmup
            exec.run_f32(name, &[(&x, &[batch, 64])])?;
            let mut times = Vec::with_capacity(reps);
            for _ in 0..reps {
                let t0 = std::time::Instant::now();
                let out = exec.run_f32(name, &[(&x, &[batch, 64])])?;
                std::hint::black_box(&out);
                times.push(t0.elapsed().as_secs_f64());
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            Ok(times[times.len() / 2])
        };
        let td = time_it(&exec, dof_name)?;
        let th = time_it(&exec, hes_name)?;
        println!(
            "| {dof_name} | {} | {} | {:.2} |",
            fmt_duration(td),
            fmt_duration(th),
            th / td
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let pde = args.get_or("pde", "heat");
    let d = args.usize_or("dim", 2);
    let problem = match pde.as_str() {
        "heat" => heat_equation(d),
        "klein-gordon" | "kg" => klein_gordon(d, args.f64_or("mass", 1.0)),
        "poisson" => poisson(d),
        "fokker-planck" | "fp" => fokker_planck(d, args.u64_or("seed", 3)),
        other => return Err(anyhow!("unknown pde {other:?}")),
    };
    let n = problem.operator.n();
    let model = Mlp::init(
        MlpSpec {
            in_dim: n,
            hidden: args.usize_or("hidden", 64),
            layers: args.usize_or("layers", 3),
            out_dim: 1,
            act: Act::Tanh,
        },
        args.u64_or("seed", 0),
    );
    let steps = args.usize_or("steps", 300);
    let cfg = PinnConfig {
        interior_batch: args.usize_or("batch", 128),
        boundary_batch: args.usize_or("boundary-batch", 64),
        boundary_weight: args.f64_or("boundary-weight", 10.0),
        adam: AdamConfig {
            lr: args.f64_or("lr", 2e-3),
            ..Default::default()
        },
        seed: args.u64_or("seed", 0),
    };
    println!(
        "training {} (N={n}) for {steps} steps, DOF tangent width {}",
        problem.name,
        problem.operator.rank()
    );
    let mut tr = PinnTrainer::new(problem, model, cfg);
    let log_every = args.usize_or("log-every", 25.max(steps / 20));
    for step in 0..steps {
        let rep = tr.train_step();
        if step % log_every == 0 || step + 1 == steps {
            println!(
                "step {:>5}  residual {:.6e}  boundary {:.6e}  total {:.6e}",
                rep.step, rep.residual_loss, rep.boundary_loss, rep.total_loss
            );
        }
    }
    let err = tr.rel_l2_error(2048);
    println!("final relative L2 error vs exact solution: {err:.4e}");
    Ok(())
}

fn cmd_decompose(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 64);
    let spec = match args.get_or("spec", "elliptic").as_str() {
        "elliptic" => CoeffSpec::EllipticGram { n, rank: n, seed: args.u64_or("seed", 7) },
        "lowrank" => CoeffSpec::EllipticGram { n, rank: n / 2, seed: args.u64_or("seed", 7) },
        "general" => CoeffSpec::SignedDiag { n },
        "identity" => CoeffSpec::Identity { n },
        other => return Err(anyhow!("unknown spec {other:?}")),
    };
    let op = Operator::from_spec(spec);
    println!("operator: {} (N = {})", op.label, op.n());
    println!("rank(A)  = {} → DOF tangent width", op.rank());
    println!("elliptic = {}", op.ldl.is_elliptic());
    println!(
        "D signs  = +{} / −{}",
        op.ldl.positive_directions(),
        op.rank() - op.ldl.positive_directions()
    );
    let recon_err = op.ldl.reconstruct().max_abs_diff(&op.a);
    println!("‖LᵀDL − A‖∞ = {recon_err:.3e}");
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let reg = ArtifactRegistry::open(&dir)?;
    println!("artifacts in {}:", reg.dir.display());
    for (group, specs) in reg.grouped() {
        println!("  [{group}]");
        for s in specs {
            println!("    {:<32} {}", s.name, s.detail);
        }
    }
    if args.flag("compile") {
        let mut exec = Executor::cpu()?;
        for name in reg.names().into_iter().map(String::from).collect::<Vec<_>>() {
            let t0 = std::time::Instant::now();
            exec.load(&name, &reg.path(&name)?)?;
            println!(
                "  compiled {name} in {}",
                fmt_duration(t0.elapsed().as_secs_f64())
            );
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let requests = args.usize_or("requests", 64);
    let rows = args.usize_or("rows", 8);
    let clients = args.usize_or("clients", 4);
    // Default to the engine that can actually run in this build: the XLA
    // executor is a stub unless the `pjrt` feature (plus the xla crate) is
    // compiled in, so the out-of-the-box demo uses the Rust backend.
    let default_engine = if cfg!(feature = "pjrt") { "xla" } else { "rust" };
    // Robustness knobs: a bounded per-replica admission queue, a logical
    // tick deadline per routed request, and a retry/failover budget. The
    // tick clock is shared between the router and every replica and
    // advanced by the traffic drivers (one tick per finished request) —
    // the control plane never reads wall clock.
    let clock = TickClock::new();
    let deadline_ticks = args.u64_or("deadline-ticks", 0);
    // `--telemetry PATH` turns on request tracing (router + every replica
    // share one span log) and exports the telemetry registry to PATH —
    // periodically while serving, and once more on drain. Tracing is
    // bitwise-invisible: responses are identical with or without it.
    let telemetry_path = args.get("telemetry").map(String::from);
    let tracer = telemetry_path.as_ref().map(|_| Arc::new(Tracer::new()));
    let router_cfg = RouterConfig {
        deadline_ticks: (deadline_ticks > 0).then_some(deadline_ticks),
        retries: args.u64_or("retries", 0) as u32,
        clock: clock.clone(),
        health: HealthPolicy::default(),
        tracer: tracer.clone(),
    };
    // All traffic flows through the multi-model Router: each backend is a
    // registered per-model worker, clients dispatch tagged requests, and
    // the router's per-model queue-depth/occupancy/robustness metrics are
    // reported at the end (the autoscaling signals).
    let mut router = Router::with_config(router_cfg);
    let mut stochastic_cfgs = Vec::new();
    match args.get_or("engine", default_engine).as_str() {
        "rust" => stochastic_cfgs = register_rust_models(args, &mut router, &clock, &tracer)?,
        "xla" => {
            let dir = args.get_or("artifacts", "artifacts");
            let artifact = args.get_or("artifact", "dof_mlp_elliptic");
            let reg = ArtifactRegistry::open(&dir)?;
            let batch = reg
                .batch_of(&artifact)
                .ok_or_else(|| anyhow!("no batch in manifest for {artifact}"))?;
            let width = 64;
            println!("serving {artifact} (batch {batch}, width {width})");
            let server = ModelServer::spawn_xla(
                reg.dir.clone(),
                artifact.clone(),
                width,
                batch,
                Duration::from_millis(args.u64_or("max-wait-ms", 2)),
            )?;
            router.register("xla", server);
        }
        other => return Err(anyhow!("unknown engine {other:?} (rust|xla)")),
    }
    // `--autoscale` turns on the deterministic autoscaler: decisions use
    // exact counters and the shared tick clock only (the wall-clock sleep
    // below just paces how often the step runs while clients drive load;
    // the scripted-tick test suite calls `step` explicitly instead).
    let mut scaler = args.flag("autoscale").then(|| {
        Autoscaler::new(AutoscalerConfig {
            min_replicas: args.usize_or("autoscale-min", 1).max(1),
            max_replicas: args.usize_or("autoscale-max", 4),
            up_queue_depth: args.usize_or("autoscale-up-depth", 8),
            down_queue_depth: args.usize_or("autoscale-down-depth", 1),
            cooldown_ticks: args.u64_or("autoscale-cooldown", 16),
            ..AutoscalerConfig::default()
        })
    });
    let model_clients = router
        .models()
        .into_iter()
        .map(|m| router.client(m))
        .collect::<Result<Vec<_>>>()?;
    println!(
        "router serving {} model(s): {}",
        model_clients.len(),
        router.models().join(", ")
    );
    // Periodic telemetry dumps while traffic runs: the span log (and its
    // exact drop counter) refresh on an interval so an operator can tail
    // the dump mid-run; the final dump below adds the full registry.
    let dump_stop = Arc::new(AtomicBool::new(false));
    let dumper = match (&telemetry_path, &tracer) {
        (Some(path), Some(tracer)) => {
            let path = path.clone();
            let tracer = Arc::clone(tracer);
            let stop = Arc::clone(&dump_stop);
            Some(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(200));
                    let mut reg = Registry::new();
                    reg.set_spans(&tracer);
                    let _ = std::fs::write(&path, reg.to_json());
                }
            }))
        }
        _ => None,
    };
    // Per-request sample override, exercised against the stochastic model
    // only: the router forwards it through retry/failover unchanged.
    let stde_request_samples = args.u64_or("stde-request-samples", 0) as u32;
    let t0 = std::time::Instant::now();
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            // Clients round-robin over the registered models (tagged
            // dispatch; widths may differ per model).
            let rc = model_clients[c % model_clients.len()].clone();
            let per_client = requests / clients.max(1);
            let clock = clock.clone();
            std::thread::spawn(move || -> Result<(usize, usize)> {
                let mut rng = Xoshiro256::new(100 + c as u64);
                let width = rc.width();
                let samples = (stde_request_samples > 0 && rc.model() == "stochastic")
                    .then_some(stde_request_samples);
                let (mut done, mut failed) = (0, 0);
                for _ in 0..per_client {
                    let pts: Vec<f32> =
                        (0..rows * width).map(|_| rng.normal() as f32).collect();
                    // With shedding/deadline knobs on, per-request failures
                    // are expected operation, not demo failure: count them,
                    // the router snapshot classifies them exactly.
                    match rc.eval_blocking_with_samples(pts, samples) {
                        Ok(resp) => {
                            anyhow::ensure!(resp.phi.len() == rows, "short response");
                            done += 1;
                        }
                        Err(_) => failed += 1,
                    }
                    // The traffic driver owns logical time: one tick per
                    // finished request.
                    clock.advance(1);
                }
                Ok((done, failed))
            })
        })
        .collect();
    if let Some(scaler) = scaler.as_mut() {
        // Step the scaler while the clients drive load; each fired event
        // is printed as it happens and kept in the cumulative log for the
        // final telemetry dump.
        while !threads.iter().all(|t| t.is_finished()) {
            for ev in scaler.step(&mut router) {
                let dir = match ev.direction {
                    ScaleDirection::Up => "up",
                    ScaleDirection::Down => "down",
                };
                println!(
                    "[autoscale] {} {}: {} -> {} replicas at tick {} (interval peak {})",
                    ev.model,
                    dir,
                    ev.replicas_before,
                    ev.replicas_after,
                    ev.tick,
                    ev.interval_peak_queue_depth
                );
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        // One more step after drain so an idle tail can record its
        // scale-down signal before the final report.
        let _ = scaler.step(&mut router);
    }
    let (mut total, mut total_failed) = (0, 0);
    for t in threads {
        let (done, failed) = t.join().map_err(|_| anyhow!("client panicked"))??;
        total += done;
        total_failed += failed;
    }
    let wall = t0.elapsed().as_secs_f64();
    let mut total_rows = 0u64;
    for m in router.snapshot() {
        let snap = &m.server;
        total_rows += snap.rows;
        println!(
            "[{}] {} requests routed ({} rows) | queue depth peak {} (now {}) | \
             mean latency {} | p95 {} | batches {} | efficiency {:.0}%",
            m.model,
            m.dispatched,
            snap.rows,
            m.peak_queue_depth,
            m.queue_depth,
            fmt_duration(snap.mean_latency),
            fmt_duration(snap.p95_latency),
            snap.batches,
            snap.batch_efficiency * 100.0
        );
        if snap.sharded_batches > 0 {
            println!(
                "[{}] parallel path: {} shards over {} batches | occupancy {:.2}× threads busy",
                m.model, snap.shards, snap.sharded_batches, snap.parallel_occupancy
            );
        }
        // The fault-tier counters (exact, final-error classified): what was
        // shed at admission, expired on the tick clock, failed in an
        // engine, retried to another replica, or quarantined.
        println!(
            "[{}] robustness: shed {} | deadline-expired {} | engine-faults {} | \
             retries {} | quarantine events {} | replicas {}",
            m.model,
            m.shed,
            m.deadline_expired,
            m.engine_faults,
            m.retries,
            m.quarantine_events,
            m.replicas.len()
        );
        for r in &m.replicas {
            if m.replicas.len() > 1 {
                println!(
                    "[{}]   replica {}: {} | attempts {} (ok {}, failed {})",
                    m.model, r.index, r.state, r.attempts, r.completed, r.failed
                );
            }
        }
    }
    println!(
        "served {total} requests ({total_rows} rows) in {} | {:.0} rows/s across models \
         | {total_failed} failed (classified above) | final tick {}",
        fmt_duration(wall),
        total_rows as f64 / wall,
        clock.now()
    );
    if let Some(scaler) = &scaler {
        let s = scaler.snapshot();
        println!(
            "autoscaler: {} scale-up(s), {} scale-down(s), {} event(s) logged",
            s.scale_ups,
            s.scale_downs,
            s.events.len()
        );
    }
    let pstats = parallel::pool::stats();
    println!(
        "worker pool: {} warm threads, {} spawn event(s), {} parallel regions",
        pstats.workers, pstats.spawn_events, pstats.regions
    );
    // Final telemetry dump on drain: the full registry — per-model metrics,
    // router/replica snapshots, compile caches, slab pool, worker pool, and
    // the span log — as schema-tagged JSON plus a Prometheus exposition.
    dump_stop.store(true, Ordering::Relaxed);
    if let Some(d) = dumper {
        let _ = d.join();
    }
    if let Some(path) = &telemetry_path {
        let mut reg = Registry::new();
        for m in router.snapshot() {
            reg.add_model(&m.model, m.server.clone());
            reg.add_router(m);
        }
        for cfg in &stochastic_cfgs {
            reg.add_stochastic(cfg.clone());
        }
        reg.add_cache("plan", dof::plan::global_cache().stats());
        reg.add_cache("jet", dof::jet::global_jet_cache().stats());
        reg.add_cache("hessian", dof::plan::hessian::global_hessian_cache().stats());
        reg.set_slab_pool(dof::autodiff::arena::slab_pool_stats());
        reg.set_pool(pstats);
        if let Some(scaler) = &scaler {
            reg.set_autoscaler(scaler.snapshot());
        }
        if let Some(tracer) = &tracer {
            reg.set_spans(tracer);
            println!(
                "telemetry: {} spans retained ({} dropped) → {path} (+ .prom)",
                reg.spans().len(),
                tracer.dropped_spans()
            );
        }
        std::fs::write(path, reg.to_json())?;
        std::fs::write(format!("{path}.prom"), reg.to_prometheus())?;
    }
    router.shutdown();
    Ok(())
}

/// `dof trace`: re-parse a telemetry dump's span lines and pretty-print the
/// span tree of one request (`--request N`) or of every retained request.
fn cmd_trace(args: &Args) -> Result<()> {
    let path = args
        .get("dump")
        .ok_or_else(|| anyhow!("dof trace needs --dump <telemetry.json>"))?;
    let dump = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("cannot read telemetry dump {path:?}: {e}"))?;
    let spans = parse_spans(&dump);
    if spans.is_empty() {
        return Err(anyhow!(
            "no spans in {path:?} — was the dump produced by `dof serve --telemetry`?"
        ));
    }
    let request = args.get("request").map(|r| {
        r.parse::<u64>()
            .map_err(|e| anyhow!("bad --request {r:?}: {e}"))
    });
    let request = match request {
        Some(r) => Some(r?),
        None => None,
    };
    print!("{}", render_tree(&spans, request));
    Ok(())
}

/// `dof serve --engine rust`: the pure-Rust engines as sharded serving
/// backends with **compile-once execution** — each model's program/plan is
/// keyed into the global caches at spawn, and every batch the coordinator
/// cuts executes it per shard (exact-fit slabs from the hash-sharded
/// program-keyed pool). `--order 4` serves the biharmonic jet operator
/// instead of the second-order DOF elliptic; `--multi` registers the DOF,
/// Hessian-baseline, and jet models together so the router carries mixed
/// traffic.
fn register_rust_models(
    args: &Args,
    router: &mut Router,
    clock: &TickClock,
    tracer: &Option<Arc<Tracer>>,
) -> Result<Vec<StochasticConfig>> {
    let order = args.usize_or("order", 2);
    let multi = args.flag("multi");
    let n = args.usize_or("n", if order == 4 { 8 } else { 64 });
    let seed = args.u64_or("seed", 0);
    // Robustness knobs shared by every replica: a bounded admission queue
    // and the router's tick clock (deadline checks at the replica front
    // door use the same logical time as the router).
    let queue_cap = args.usize_or("queue-cap", 0);
    let replicas = args.usize_or("replicas", 1).max(1);
    let serve_cfg = |label: &str| ServeConfig {
        queue_cap,
        clock: clock.clone(),
        label: label.to_string(),
        injector: None,
        tracer: tracer.clone(),
    };
    let mlp = |in_dim: usize| {
        Mlp::init(
            MlpSpec {
                in_dim,
                hidden: args.usize_or("hidden", 64),
                layers: args.usize_or("layers", 3),
                out_dim: 1,
                act: Act::Tanh,
            },
            seed,
        )
    };
    let pool = Pool::from_env();
    let batch = args.usize_or("batch", 32);
    let policy = BatchPolicy {
        capacity: batch,
        max_wait: Duration::from_millis(args.u64_or("max-wait-ms", 2)),
        max_wait_ticks: None,
    };
    if order != 2 && order != 4 {
        return Err(anyhow!(
            "unsupported --order {order} for serve (2 = DOF, 4 = biharmonic jets)"
        ));
    }
    if order == 2 || multi {
        let graph = mlp(n).to_graph();
        let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed });
        let t0 = std::time::Instant::now();
        let program = op.dof_program(&graph);
        println!(
            "[dof] rust DOF engine (N={n}, rank {}, batch {batch}, {} threads)",
            op.rank(),
            pool.threads()
        );
        println!(
            "[dof] compiled operator program in {}: {} steps ({} fused), \
             {} slab scalars/row, {} muls/row analytic",
            fmt_duration(t0.elapsed().as_secs_f64()),
            program.steps().len(),
            program.fused_steps(),
            program.slab_per_row(),
            program.cost(1).muls
        );
        let spawn = |graph: Graph| {
            ModelServer::spawn_dof_cfg(
                graph,
                op.dof_engine(),
                policy,
                pool,
                parallel::DEFAULT_SHARD_ROWS,
                serve_cfg("dof"),
            )
        };
        router.register("dof", spawn(graph.clone()));
        for _ in 1..replicas {
            // Extra replicas are independent failover targets behind the
            // same model name; the compile-once caches make each spawn a
            // cache hit, not a recompile.
            router.add_replica("dof", spawn(graph.clone()))?;
        }
        // Autoscaler spawn factory: rebuilds the engine from its spec
        // (same seed → identical decomposition → identical bytes; the
        // compile-once caches make each spawn a cache hit).
        let fgraph = graph.clone();
        let fcfg = serve_cfg("dof");
        let factory = move || {
            let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed });
            ModelServer::spawn_dof_cfg(
                fgraph.clone(),
                op.dof_engine(),
                policy,
                pool,
                parallel::DEFAULT_SHARD_ROWS,
                fcfg.clone(),
            )
        };
        router.set_replica_factory("dof", Box::new(factory))?;
        if multi {
            // The Table-1 baseline behind the same front door: mixed
            // DOF/Hessian traffic exercises the serving-scale comparison.
            let graph = mlp(n).to_graph();
            let spawn = |graph: Graph| {
                ModelServer::spawn_hessian_cfg(
                    graph,
                    op.hessian_engine(),
                    policy,
                    pool,
                    parallel::DEFAULT_SHARD_ROWS,
                    serve_cfg("hessian"),
                )
            };
            router.register("hessian", spawn(graph.clone()));
            for _ in 1..replicas {
                router.add_replica("hessian", spawn(graph.clone()))?;
            }
            let fgraph = graph.clone();
            let fcfg = serve_cfg("hessian");
            let factory = move || {
                let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed });
                ModelServer::spawn_hessian_cfg(
                    fgraph.clone(),
                    op.hessian_engine(),
                    policy,
                    pool,
                    parallel::DEFAULT_SHARD_ROWS,
                    fcfg.clone(),
                )
            };
            router.set_replica_factory("hessian", Box::new(factory))?;
            println!("[hessian] rust Hessian baseline (N={n}, batch {batch})");
        }
    }
    if order == 4 || multi {
        // Jet width stays modest under --multi (Δ² needs d² directions).
        let jn = if order == 4 { n } else { args.usize_or("jet-n", 8) };
        let graph = mlp(jn).to_graph();
        let op = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: jn });
        let t0 = std::time::Instant::now();
        let program = op.jet_program(&graph);
        println!(
            "[jet] rust jet engine (N={jn}, Δ² with {} directions × order 4, \
             batch {batch}, {} threads)",
            op.directions(),
            pool.threads()
        );
        println!(
            "[jet] compiled jet program in {}: {} steps ({} fused), \
             {} slab scalars/row, {} muls/row analytic",
            fmt_duration(t0.elapsed().as_secs_f64()),
            program.steps().len(),
            program.fused_steps(),
            program.slab_per_row(),
            program.cost(1).muls
        );
        let spawn = |graph: Graph| {
            ModelServer::spawn_jet_cfg(
                graph,
                op.jet_engine(),
                policy,
                pool,
                parallel::DEFAULT_SHARD_ROWS,
                serve_cfg("jet"),
            )
        };
        router.register("jet", spawn(graph.clone()));
        for _ in 1..replicas {
            router.add_replica("jet", spawn(graph.clone()))?;
        }
        let fgraph = graph.clone();
        let fcfg = serve_cfg("jet");
        let factory = move || {
            let op = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: jn });
            ModelServer::spawn_jet_cfg(
                fgraph.clone(),
                op.jet_engine(),
                policy,
                pool,
                parallel::DEFAULT_SHARD_ROWS,
                fcfg.clone(),
            )
        };
        router.set_replica_factory("jet", Box::new(factory))?;
    }
    let mut stochastic_cfgs = Vec::new();
    if args.flag("stochastic") {
        // The STDE backend: the same contraction family as the exact
        // engines above, but estimated from `samples` random direction
        // groups per point — jet cost scales with the sample count, not
        // with N (order 2) or N² (order 4). Per-point direction streams
        // are counter-derived from (seed, point index, sample index), so
        // responses are bit-identical at any thread count.
        let samples = args.u64_or("stde-samples", 64) as u32;
        if samples == 0 {
            return Err(anyhow!("--stde-samples must be >= 1"));
        }
        let nnz = args.usize_or("stde-nnz", 0);
        let sampling = if nnz > 0 {
            DirectionSampling::SparseRademacher { nnz }
        } else {
            DirectionSampling::Gaussian
        };
        let (sn, engine, what) = if order == 4 {
            let op = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: n });
            (n, op.stochastic_engine(sampling, samples, seed), "Δ² (biharmonic)")
        } else {
            let op = Operator::from_spec(CoeffSpec::EllipticGram { n, rank: n, seed });
            (n, op.stochastic_engine(sampling, samples, seed), "elliptic Σ aᵢⱼ ∂ᵢ∂ⱼ")
        };
        let graph = mlp(sn).to_graph();
        let t0 = std::time::Instant::now();
        let program = engine.program(&graph);
        let sampling_desc = match sampling {
            DirectionSampling::Gaussian => "gaussian".to_string(),
            DirectionSampling::SparseRademacher { nnz } => {
                format!("sparse-rademacher({nnz})")
            }
        };
        println!(
            "[stochastic] rust STDE engine for {what}: N={sn}, {} samples × {} \
             dirs/sample ({} dirs/point total, {sampling_desc}), seed {seed}",
            engine.samples(),
            engine.dirs_per_sample(),
            engine.directions_per_point(),
        );
        println!(
            "[stochastic] compiled pattern program in {}: {} steps ({} fused), \
             {} slab scalars/row",
            fmt_duration(t0.elapsed().as_secs_f64()),
            program.steps().len(),
            program.fused_steps(),
            program.slab_per_row(),
        );
        stochastic_cfgs.push(StochasticConfig {
            model: "stochastic".to_string(),
            samples,
            seed,
            sampling: sampling_desc,
            dirs_per_point: engine.directions_per_point(),
        });
        let spawn = |graph: Graph, engine: dof::jet::StochasticJetEngine| {
            ModelServer::spawn_stochastic_cfg(
                graph,
                engine,
                policy,
                pool,
                parallel::DEFAULT_SHARD_ROWS,
                serve_cfg("stochastic"),
            )
        };
        router.register("stochastic", spawn(graph.clone(), engine.clone()));
        for _ in 1..replicas {
            router.add_replica("stochastic", spawn(graph.clone(), engine.clone()))?;
        }
        let fgraph = graph.clone();
        let fcfg = serve_cfg("stochastic");
        let fengine = engine.clone();
        let factory = move || {
            ModelServer::spawn_stochastic_cfg(
                fgraph.clone(),
                fengine.clone(),
                policy,
                pool,
                parallel::DEFAULT_SHARD_ROWS,
                fcfg.clone(),
            )
        };
        router.set_replica_factory("stochastic", Box::new(factory))?;
    }
    Ok(stochastic_cfgs)
}
