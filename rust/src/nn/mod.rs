//! Neural-network model definitions: the plain MLP and the Jacobian-sparse
//! block MLP of Appendix E, with flat-parameter views for the optimizer and
//! binary import/export for cross-language weight exchange with the
//! JAX/Pallas build path.

pub mod serialize;

use crate::graph::builder::{mlp_graph, sparse_mlp_graph, LayerWeights};
use crate::graph::{Act, Graph};
use crate::tensor::Tensor;
use crate::util::Xoshiro256;

/// Parse an activation name (shared with configs and the CLI).
pub fn act_from_str(s: &str) -> Option<Act> {
    match s.to_ascii_lowercase().as_str() {
        "tanh" => Some(Act::Tanh),
        "sin" => Some(Act::Sin),
        "gelu" => Some(Act::Gelu),
        "softplus" => Some(Act::Softplus),
        "square" => Some(Act::Square),
        "identity" | "linear" => Some(Act::Identity),
        _ => None,
    }
}

/// Activation name for serialization.
pub fn act_name(a: Act) -> &'static str {
    match a {
        Act::Tanh => "tanh",
        Act::Sin => "sin",
        Act::Gelu => "gelu",
        Act::Softplus => "softplus",
        Act::Square => "square",
        Act::Identity => "identity",
    }
}

/// Architecture of a plain MLP (Table 3 defaults: 64 → 256×8 → 1).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MlpSpec {
    pub in_dim: usize,
    pub hidden: usize,
    /// Number of hidden layers (Linear→act pairs before the head).
    pub layers: usize,
    pub out_dim: usize,
    pub act: Act,
}

impl MlpSpec {
    /// The paper's Table 3 MLP.
    pub fn table3() -> Self {
        Self {
            in_dim: 64,
            hidden: 256,
            layers: 8,
            out_dim: 1,
            act: Act::Tanh,
        }
    }

    /// Dimension sequence `in → hidden×layers → out`.
    pub fn dims(&self) -> Vec<usize> {
        let mut d = vec![self.in_dim];
        d.extend(std::iter::repeat(self.hidden).take(self.layers));
        d.push(self.out_dim);
        d
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.dims()
            .windows(2)
            .map(|w| w[0] * w[1] + w[1])
            .sum()
    }
}

/// A plain MLP with owned weights.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub spec: MlpSpec,
    pub layers: LayerWeights,
}

impl Mlp {
    /// Random initialization (Lecun-style 1/√fan_in).
    pub fn init(spec: MlpSpec, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        let layers = crate::graph::builder::random_layers(&spec.dims(), &mut rng);
        Self { spec, layers }
    }

    /// Build the computation graph for the current weights.
    pub fn to_graph(&self) -> Graph {
        mlp_graph(&self.layers, self.spec.act)
    }

    /// Flatten all parameters (layer-major, weights row-major then bias).
    pub fn flatten(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.spec.param_count());
        for (w, b) in &self.layers {
            out.extend_from_slice(w.data());
            out.extend_from_slice(b);
        }
        out
    }

    /// Overwrite parameters from a flat vector (inverse of `flatten`).
    pub fn unflatten(&mut self, flat: &[f64]) {
        assert_eq!(flat.len(), self.spec.param_count(), "param count mismatch");
        let mut off = 0;
        for (w, b) in &mut self.layers {
            let wn = w.numel();
            w.data_mut().copy_from_slice(&flat[off..off + wn]);
            off += wn;
            let bn = b.len();
            b.copy_from_slice(&flat[off..off + bn]);
            off += bn;
        }
    }

    /// Map per-Linear-node parameter gradients (from
    /// [`crate::autodiff::backward::backward`] or the DOF tape) into a flat
    /// gradient aligned with `flatten`. `grads` is `(linear_index, ∂W, ∂b)`
    /// where `linear_index` counts Linear nodes in graph order.
    pub fn flat_gradient(&self, grads: &[(usize, Tensor, Vec<f64>)]) -> Vec<f64> {
        let mut flat = vec![0.0; self.spec.param_count()];
        // Offsets of each layer in the flat vector.
        let mut offsets = Vec::with_capacity(self.layers.len());
        let mut off = 0;
        for (w, b) in &self.layers {
            offsets.push(off);
            off += w.numel() + b.len();
        }
        for (li, gw, gb) in grads {
            let base = offsets[*li];
            let wn = gw.numel();
            for (i, &v) in gw.data().iter().enumerate() {
                flat[base + i] += v;
            }
            for (i, &v) in gb.iter().enumerate() {
                flat[base + wn + i] += v;
            }
        }
        flat
    }
}

/// Architecture of the Jacobian-sparse block MLP (Table 3: 16 blocks × 4
/// input dims, hidden 256 × 8 layers, per-block output 8).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SparseMlpSpec {
    pub blocks: usize,
    pub block_in: usize,
    pub hidden: usize,
    pub layers: usize,
    /// Per-block MLP output dimension (`d` index in the product-sum head).
    pub block_out: usize,
    pub act: Act,
}

impl SparseMlpSpec {
    /// The paper's Table 3 sparse architecture.
    pub fn table3() -> Self {
        Self {
            blocks: 16,
            block_in: 4,
            hidden: 256,
            layers: 8,
            block_out: 8,
            act: Act::Tanh,
        }
    }

    pub fn in_dim(&self) -> usize {
        self.blocks * self.block_in
    }

    /// Per-block dimension sequence.
    pub fn block_dims(&self) -> Vec<usize> {
        let mut d = vec![self.block_in];
        d.extend(std::iter::repeat(self.hidden).take(self.layers));
        d.push(self.block_out);
        d
    }
}

/// Sparse block MLP with owned weights.
#[derive(Debug, Clone)]
pub struct SparseMlp {
    pub spec: SparseMlpSpec,
    pub blocks: Vec<LayerWeights>,
}

impl SparseMlp {
    pub fn init(spec: SparseMlpSpec, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        let dims = spec.block_dims();
        let blocks = (0..spec.blocks)
            .map(|_| crate::graph::builder::random_layers(&dims, &mut rng))
            .collect();
        Self { spec, blocks }
    }

    pub fn to_graph(&self) -> Graph {
        sparse_mlp_graph(&self.blocks, self.spec.act)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_specs() {
        let m = MlpSpec::table3();
        assert_eq!(m.dims(), vec![64, 256, 256, 256, 256, 256, 256, 256, 256, 1]);
        let s = SparseMlpSpec::table3();
        assert_eq!(s.in_dim(), 64);
        assert_eq!(s.block_dims().len(), 10);
    }

    #[test]
    fn flatten_roundtrip() {
        let spec = MlpSpec {
            in_dim: 3,
            hidden: 5,
            layers: 2,
            out_dim: 1,
            act: Act::Tanh,
        };
        let mut m = Mlp::init(spec.clone(), 7);
        let flat = m.flatten();
        assert_eq!(flat.len(), spec.param_count());
        let mut perturbed = flat.clone();
        perturbed[0] += 1.5;
        perturbed[flat.len() - 1] -= 2.0;
        m.unflatten(&perturbed);
        assert_eq!(m.flatten(), perturbed);
    }

    #[test]
    fn graph_agrees_with_weights() {
        let m = Mlp::init(
            MlpSpec {
                in_dim: 2,
                hidden: 4,
                layers: 1,
                out_dim: 1,
                act: Act::Square,
            },
            3,
        );
        let g = m.to_graph();
        let x = Tensor::from_vec(&[1, 2], vec![0.3, -0.7]);
        // Manual forward.
        let (w0, b0) = &m.layers[0];
        let (w1, b1) = &m.layers[1];
        let mut h = vec![0.0; 4];
        for i in 0..4 {
            h[i] = w0.at(i, 0) * 0.3 + w0.at(i, 1) * (-0.7) + b0[i];
            h[i] = h[i] * h[i];
        }
        let mut y = b1[0];
        for i in 0..4 {
            y += w1.at(0, i) * h[i];
        }
        assert!((g.eval(&x).item() - y).abs() < 1e-12);
    }

    #[test]
    fn act_parsing() {
        assert_eq!(act_from_str("Tanh"), Some(Act::Tanh));
        assert_eq!(act_from_str("SIN"), Some(Act::Sin));
        assert_eq!(act_from_str("nope"), None);
        assert_eq!(act_from_str(act_name(Act::Gelu)), Some(Act::Gelu));
    }

    #[test]
    fn flat_gradient_alignment() {
        let spec = MlpSpec {
            in_dim: 2,
            hidden: 3,
            layers: 1,
            out_dim: 1,
            act: Act::Tanh,
        };
        let m = Mlp::init(spec, 11);
        // Gradient only on layer 1 (the head): W [1×3], b [1].
        let gw = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let flat = m.flat_gradient(&[(1, gw, vec![4.0])]);
        let head_off = 2 * 3 + 3; // layer0: W(3×2) + b(3)
        assert_eq!(&flat[head_off..head_off + 4], &[1.0, 2.0, 3.0, 4.0]);
        assert!(flat[..head_off].iter().all(|&v| v == 0.0));
    }
}
