//! Cross-language weight exchange.
//!
//! Format (`.dofw`): a UTF-8 header terminated by a newline-`@`-newline
//! sentinel, followed by raw little-endian f64 data. The header lists
//! tensors as `name rows cols` lines so NumPy can read the payload with
//! `np.fromfile(..., offset=header_len)` and Rust without any JSON
//! dependency.
//!
//! ```text
//! dofw v1
//! tensors 4
//! w0 256 64
//! b0 256 1
//! w1 1 256
//! b1 1 1
//! @
//! <raw f64 LE data, concatenated in header order>
//! ```

use std::fs;
use std::io::{self, Write as _};
use std::path::Path;

use crate::tensor::Tensor;

/// A named 2-D tensor entry (biases are stored as `n×1`).
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    pub name: String,
    pub tensor: Tensor,
}

/// Write entries to a `.dofw` file.
pub fn write_dofw<P: AsRef<Path>>(path: P, entries: &[Entry]) -> io::Result<()> {
    let mut header = String::from("dofw v1\n");
    header.push_str(&format!("tensors {}\n", entries.len()));
    for e in entries {
        assert_eq!(e.tensor.rank(), 2, "dofw stores 2-D tensors");
        header.push_str(&format!(
            "{} {} {}\n",
            e.name,
            e.tensor.dims()[0],
            e.tensor.dims()[1]
        ));
    }
    header.push_str("@\n");
    if let Some(parent) = path.as_ref().parent() {
        fs::create_dir_all(parent)?;
    }
    let mut f = fs::File::create(path)?;
    f.write_all(header.as_bytes())?;
    for e in entries {
        for &v in e.tensor.data() {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read a `.dofw` file.
pub fn read_dofw<P: AsRef<Path>>(path: P) -> io::Result<Vec<Entry>> {
    let bytes = fs::read(path)?;
    // Find the header sentinel "\n@\n".
    let sentinel = b"\n@\n";
    let pos = bytes
        .windows(sentinel.len())
        .position(|w| w == sentinel)
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "missing dofw sentinel"))?;
    let header = std::str::from_utf8(&bytes[..pos])
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
    let mut lines = header.lines();
    let magic = lines.next().unwrap_or("");
    if magic != "dofw v1" {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("bad magic {magic:?}"),
        ));
    }
    let count: usize = lines
        .next()
        .and_then(|l| l.strip_prefix("tensors "))
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "bad tensor count"))?;
    let mut shapes = Vec::with_capacity(count);
    for _ in 0..count {
        let line = lines
            .next()
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "truncated header"))?;
        let mut it = line.split_whitespace();
        let name = it
            .next()
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "missing name"))?
            .to_string();
        let rows: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "bad rows"))?;
        let cols: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "bad cols"))?;
        shapes.push((name, rows, cols));
    }
    let mut data_off = pos + sentinel.len();
    let mut entries = Vec::with_capacity(count);
    for (name, rows, cols) in shapes {
        let n = rows * cols;
        let end = data_off + n * 8;
        if end > bytes.len() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "truncated payload",
            ));
        }
        let mut data = Vec::with_capacity(n);
        for chunk in bytes[data_off..end].chunks_exact(8) {
            data.push(f64::from_le_bytes(chunk.try_into().unwrap()));
        }
        data_off = end;
        entries.push(Entry {
            name,
            tensor: Tensor::from_vec(&[rows, cols], data),
        });
    }
    Ok(entries)
}

/// Export an MLP's layers as dofw entries (`w0, b0, w1, b1, …`).
pub fn mlp_entries(layers: &crate::graph::builder::LayerWeights) -> Vec<Entry> {
    let mut out = Vec::with_capacity(layers.len() * 2);
    for (i, (w, b)) in layers.iter().enumerate() {
        out.push(Entry {
            name: format!("w{i}"),
            tensor: w.clone(),
        });
        out.push(Entry {
            name: format!("b{i}"),
            tensor: Tensor::from_vec(&[b.len(), 1], b.clone()),
        });
    }
    out
}

/// Reassemble MLP layers from dofw entries (inverse of [`mlp_entries`]).
pub fn entries_to_mlp(entries: &[Entry]) -> crate::graph::builder::LayerWeights {
    assert!(entries.len() % 2 == 0, "expected w/b pairs");
    let mut layers = Vec::with_capacity(entries.len() / 2);
    for pair in entries.chunks_exact(2) {
        let w = pair[0].tensor.clone();
        let b = pair[1].tensor.data().to_vec();
        assert_eq!(w.dims()[0], b.len(), "bias/weight mismatch");
        layers.push((w, b));
    }
    layers
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Mlp, MlpSpec};
    use crate::graph::Act;

    #[test]
    fn roundtrip_file() {
        let m = Mlp::init(
            MlpSpec {
                in_dim: 3,
                hidden: 4,
                layers: 2,
                out_dim: 1,
                act: Act::Tanh,
            },
            5,
        );
        let entries = mlp_entries(&m.layers);
        let p = std::env::temp_dir().join("dof_test_weights.dofw");
        write_dofw(&p, &entries).unwrap();
        let back = read_dofw(&p).unwrap();
        assert_eq!(back.len(), entries.len());
        for (a, b) in entries.iter().zip(&back) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.tensor, b.tensor);
        }
        let layers = entries_to_mlp(&back);
        assert_eq!(layers.len(), m.layers.len());
        assert_eq!(layers[0].0, m.layers[0].0);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = std::env::temp_dir().join("dof_bad_magic.dofw");
        std::fs::write(&p, b"not a dofw\n@\n").unwrap();
        assert!(read_dofw(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn rejects_truncated_payload() {
        let p = std::env::temp_dir().join("dof_trunc.dofw");
        std::fs::write(&p, b"dofw v1\ntensors 1\nw0 2 2\n@\n\x00\x00").unwrap();
        assert!(read_dofw(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
