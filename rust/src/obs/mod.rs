//! Crate-wide observability: request tracing, per-step execution profiling,
//! and the exportable telemetry registry.
//!
//! Three subsystems, one design rule — **observation must be bitwise
//! invisible**. Turning any of them on changes no computed value, no shard
//! decomposition, and no scheduling decision; `rust/tests/observability.rs`
//! asserts traced ≡ untraced bit-for-bit across thread counts.
//!
//! * [`span`] — [`TraceContext`] identifies a request as it flows
//!   `RouterClient → dispatch → admission/queue/batch → engine → shards`;
//!   every layer records finished [`Span`]s into the bounded, lock-sharded
//!   [`Tracer`] ring (oldest evicted, drops counted exactly). Control-plane
//!   timestamps are logical [`TickClock`](crate::coordinator::TickClock)
//!   ticks; data-plane durations are measured seconds passed in by the
//!   layer that owns the execution.
//! * [`profile`] — [`StepProfiler`] records measured seconds per program
//!   step beside the step's exact analytic FLOPs (the same per-step costs
//!   the compiled programs sum into `cost(batch)`), yielding a
//!   measured-vs-analytic efficiency table per program fingerprint.
//! * [`registry`] + [`trace_view`] — [`Registry`] aggregates metrics,
//!   router, cache, slab-pool, pool, span, and profile snapshots into one
//!   `"telemetry_schema"`-tagged JSON document (plus a Prometheus text
//!   exposition); `dof trace` re-parses a dump's span lines and
//!   pretty-prints a request's span tree.
//!
//! Like `coordinator/`, this module tree must not panic on the serving
//! path, so `unwrap`/`expect` are denied below.

#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod profile;
pub mod registry;
pub mod span;
pub mod trace_view;

pub use profile::{StepProfiler, StepRecord};
pub use registry::{ProfileSummary, Registry, StochasticConfig, TELEMETRY_SCHEMA};
pub use span::{Span, SpanKind, TraceContext, Tracer};
pub use trace_view::{parse_spans, render_tree};
