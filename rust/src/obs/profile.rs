//! Per-step execution profiling: measured time next to analytic cost.
//!
//! The planned executors (`plan/exec.rs`, `plan/hessian.rs`,
//! `jet/program.rs`) optionally carry an `Option<&mut StepProfiler>`; when
//! absent the hot path pays one `is_some()` branch per step and zero
//! allocation. When present, each program step records its measured wall
//! seconds (timed by the executor — this type is pure storage) beside the
//! step's **exact** analytic mul/add counts, taken from the same per-node
//! cost model the programs' `cost(batch)` is summed from. By construction
//! the profiler's FLOP totals equal the program's analytic cost — asserted
//! by `rust/tests/observability.rs` — so the table below is a true
//! measured-vs-analytic efficiency report, not two unrelated estimates.

use crate::util::fmt_duration;

/// One profiled program step.
#[derive(Debug, Clone)]
pub struct StepRecord {
    /// Graph node id the step computed (usize::MAX for synthetic phases
    /// like output contraction that have no single node).
    pub node: usize,
    /// Static phase label ("linear", "activation", "contract", …).
    pub label: &'static str,
    /// Measured execution seconds for this step.
    pub seconds: f64,
    /// Analytic multiply count for this step at the executed batch size.
    pub muls: u64,
    /// Analytic addition count for this step at the executed batch size.
    pub adds: u64,
}

/// Collected per-step records for one program execution (or several:
/// records accumulate until [`StepProfiler::clear`]).
#[derive(Debug, Clone, Default)]
pub struct StepProfiler {
    records: Vec<StepRecord>,
}

impl StepProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one step's measurement.
    pub fn record(&mut self, node: usize, label: &'static str, seconds: f64, muls: u64, adds: u64) {
        self.records.push(StepRecord {
            node,
            label,
            seconds,
            muls,
            adds,
        });
    }

    pub fn records(&self) -> &[StepRecord] {
        &self.records
    }

    pub fn clear(&mut self) {
        self.records.clear();
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn total_seconds(&self) -> f64 {
        self.records.iter().map(|r| r.seconds).sum()
    }

    pub fn total_muls(&self) -> u64 {
        self.records.iter().map(|r| r.muls).sum()
    }

    pub fn total_adds(&self) -> u64 {
        self.records.iter().map(|r| r.adds).sum()
    }

    /// Total analytic FLOPs (muls + adds) across all recorded steps.
    pub fn total_flops(&self) -> u64 {
        self.total_muls() + self.total_adds()
    }

    /// Render the measured-vs-analytic efficiency table. One row per step:
    /// the analytic FLOPs the cost model charges, the measured seconds,
    /// and the implied throughput — a step whose GFLOP/s is far below its
    /// siblings is memory-bound or mis-planned. Rows with zero analytic
    /// cost (value evaluation, copies) show time only.
    pub fn render_table(&self, title: &str) -> String {
        let mut out = String::new();
        out.push_str(&format!("efficiency table: {title}\n"));
        out.push_str(&format!(
            "{:>6}  {:<12} {:>12} {:>12} {:>10} {:>10}\n",
            "node", "step", "muls", "adds", "time", "gflops"
        ));
        for r in &self.records {
            let node = if r.node == usize::MAX {
                "-".to_string()
            } else {
                r.node.to_string()
            };
            let flops = r.muls + r.adds;
            let gflops = if r.seconds > 0.0 && flops > 0 {
                format!("{:.2}", flops as f64 / r.seconds / 1e9)
            } else {
                "-".to_string()
            };
            out.push_str(&format!(
                "{:>6}  {:<12} {:>12} {:>12} {:>10} {:>10}\n",
                node,
                r.label,
                r.muls,
                r.adds,
                fmt_duration(r.seconds),
                gflops
            ));
        }
        let total_flops = self.total_flops();
        let secs = self.total_seconds();
        let total_gflops = if secs > 0.0 && total_flops > 0 {
            format!("{:.2}", total_flops as f64 / secs / 1e9)
        } else {
            "-".to_string()
        };
        out.push_str(&format!(
            "{:>6}  {:<12} {:>12} {:>12} {:>10} {:>10}\n",
            "",
            "total",
            self.total_muls(),
            self.total_adds(),
            fmt_duration(secs),
            total_gflops
        ));
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn totals_sum_records() {
        let mut p = StepProfiler::new();
        p.record(0, "input", 1e-6, 0, 0);
        p.record(1, "linear", 2e-6, 100, 80);
        p.record(2, "activation", 3e-6, 40, 20);
        assert_eq!(p.total_muls(), 140);
        assert_eq!(p.total_adds(), 100);
        assert_eq!(p.total_flops(), 240);
        assert!((p.total_seconds() - 6e-6).abs() < 1e-15);
        assert_eq!(p.records().len(), 3);
        p.clear();
        assert!(p.is_empty());
    }

    #[test]
    fn table_renders_all_rows() {
        let mut p = StepProfiler::new();
        p.record(3, "linear", 1e-3, 1_000_000, 900_000);
        p.record(usize::MAX, "contract", 0.0, 0, 0);
        let t = p.render_table("fp=deadbeef batch=32");
        assert!(t.contains("linear"));
        assert!(t.contains("contract"));
        assert!(t.contains("total"));
        assert!(t.contains("deadbeef"));
        // Zero-cost, zero-time rows render a dash throughput.
        assert!(t.lines().any(|l| l.contains("contract") && l.ends_with('-')));
    }
}
