//! The exportable telemetry registry: one schema-versioned document
//! aggregating every observable surface of the serving stack — model
//! metrics snapshots, router/replica snapshots, program-cache and slab-pool
//! counters, worker-pool lifecycle counters, the span log, and per-program
//! profile summaries.
//!
//! **Control-plane file: no wall clock** (same CI-enforced invariant as
//! `coordinator/fault.rs` and `obs/span.rs`). The registry only *renders*
//! durations its inputs already measured.
//!
//! Two renderings share one registry:
//!
//! * [`Registry::to_json`] — a hand-rolled JSON document tagged
//!   `"telemetry_schema": 1`. Spans are emitted one object per line so the
//!   `dof trace` viewer ([`super::trace_view`]) can re-parse a dump with a
//!   line scanner instead of a JSON parser (this crate deliberately carries
//!   no serde).
//! * [`Registry::to_prometheus`] — a Prometheus-style text exposition of
//!   the counter/gauge subset (`# TYPE` lines included), for scraping.

use crate::autodiff::arena::SlabPoolStats;
use crate::coordinator::{
    AutoscalerSnapshot, MetricsSnapshot, RouterModelSnapshot, ScaleDirection,
};
use crate::parallel::pool::PoolStats;
use crate::util::CacheStats;

use super::profile::StepProfiler;
use super::span::{Span, Tracer};

/// Version tag of the JSON document layout.
pub const TELEMETRY_SCHEMA: u32 = 1;

/// Static configuration of one stochastic (STDE) backend, exported so a
/// telemetry dump is self-describing: an estimate in the dump can be traced
/// back to the sample count / sampling law / seed that produced it.
#[derive(Debug, Clone)]
pub struct StochasticConfig {
    /// Model label the backend is registered under.
    pub model: String,
    /// Default directions-per-group sample count.
    pub samples: u32,
    /// Base seed of the counter-derived per-point direction streams.
    pub seed: u64,
    /// Human-readable sampling law ("gaussian" or "sparse-rademacher(nnz)").
    pub sampling: String,
    /// Total direction count pushed per point (exact carry + sampled).
    pub dirs_per_point: usize,
}

/// Roll-up of one program's profiled execution(s).
#[derive(Debug, Clone)]
pub struct ProfileSummary {
    /// Recorded step count.
    pub steps: usize,
    /// Summed measured seconds.
    pub seconds: f64,
    /// Summed analytic multiplications.
    pub muls: u64,
    /// Summed analytic additions.
    pub adds: u64,
}

/// Aggregates snapshots into one exportable document (see module docs).
/// Build-once: populate with the `add_*`/`set_*` methods, then render.
#[derive(Debug, Default)]
pub struct Registry {
    models: Vec<(String, MetricsSnapshot)>,
    routers: Vec<RouterModelSnapshot>,
    caches: Vec<(String, CacheStats)>,
    slab_pool: Option<SlabPoolStats>,
    pool: Option<PoolStats>,
    spans: Vec<Span>,
    dropped_spans: u64,
    profiles: Vec<(String, ProfileSummary)>,
    autoscaler: Option<AutoscalerSnapshot>,
    stochastic: Vec<StochasticConfig>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one model server's metrics snapshot under `label`.
    pub fn add_model(&mut self, label: &str, snap: MetricsSnapshot) {
        self.models.push((label.to_string(), snap));
    }

    /// Record one router model snapshot (replica scalars included; the
    /// aggregated server metrics belong in [`Registry::add_model`]).
    pub fn add_router(&mut self, snap: RouterModelSnapshot) {
        self.routers.push(snap);
    }

    /// Record the autoscaler's cumulative accounting (scale-up/down
    /// counters plus the full tick-stamped event log).
    pub fn set_autoscaler(&mut self, snap: AutoscalerSnapshot) {
        self.autoscaler = Some(snap);
    }

    /// Record one stochastic backend's static estimator configuration.
    pub fn add_stochastic(&mut self, cfg: StochasticConfig) {
        self.stochastic.push(cfg);
    }

    /// Record one keyed-cache counter set under `name` (plan, jet, hessian).
    pub fn add_cache(&mut self, name: &str, stats: CacheStats) {
        self.caches.push((name.to_string(), stats));
    }

    pub fn set_slab_pool(&mut self, stats: SlabPoolStats) {
        self.slab_pool = Some(stats);
    }

    pub fn set_pool(&mut self, stats: PoolStats) {
        self.pool = Some(stats);
    }

    /// Capture the tracer's current span log and exact drop counter.
    pub fn set_spans(&mut self, tracer: &Tracer) {
        self.spans = tracer.snapshot();
        self.dropped_spans = tracer.dropped_spans();
    }

    /// Record a profile roll-up for one program (keyed by fingerprint or
    /// any stable name).
    pub fn add_profile(&mut self, name: &str, profiler: &StepProfiler) {
        self.profiles.push((
            name.to_string(),
            ProfileSummary {
                steps: profiler.records().len(),
                seconds: profiler.total_seconds(),
                muls: profiler.total_muls(),
                adds: profiler.total_adds(),
            },
        ));
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    // ---- JSON rendering --------------------------------------------------

    /// Render the full document (see module docs for the layout contract).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"telemetry_schema\": {TELEMETRY_SCHEMA},\n"));

        s.push_str("  \"models\": {\n");
        for (i, (label, m)) in self.models.iter().enumerate() {
            let comma = if i + 1 < self.models.len() { "," } else { "" };
            s.push_str(&format!("    \"{}\": {}{}\n", esc(label), metrics_json(m), comma));
        }
        s.push_str("  },\n");

        s.push_str("  \"routers\": [\n");
        for (i, r) in self.routers.iter().enumerate() {
            let comma = if i + 1 < self.routers.len() { "," } else { "" };
            s.push_str(&format!("    {}{}\n", router_json(r), comma));
        }
        s.push_str("  ],\n");

        if let Some(a) = &self.autoscaler {
            let events: Vec<String> = a.events.iter().map(scale_event_json).collect();
            s.push_str(&format!(
                "  \"autoscaler\": {{\"scale_ups\": {}, \"scale_downs\": {}, \"events\": [{}]}},\n",
                a.scale_ups,
                a.scale_downs,
                events.join(", "),
            ));
        }

        if !self.stochastic.is_empty() {
            let cfgs: Vec<String> = self.stochastic.iter().map(stochastic_json).collect();
            s.push_str(&format!("  \"stochastic\": [{}],\n", cfgs.join(", ")));
        }

        s.push_str("  \"caches\": {\n");
        for (i, (name, c)) in self.caches.iter().enumerate() {
            let comma = if i + 1 < self.caches.len() { "," } else { "" };
            s.push_str(&format!(
                "    \"{}\": {{\"hits\": {}, \"misses\": {}, \"entries\": {}}}{}\n",
                esc(name),
                c.hits,
                c.misses,
                c.entries,
                comma
            ));
        }
        s.push_str("  },\n");

        if let Some(sp) = &self.slab_pool {
            s.push_str(&format!(
                "  \"slab_pool\": {{\"hits\": {}, \"misses\": {}, \"retained\": {}}},\n",
                sp.hits, sp.misses, sp.retained
            ));
        }
        if let Some(p) = &self.pool {
            s.push_str(&format!(
                "  \"pool\": {{\"workers\": {}, \"spawn_events\": {}, \"regions\": {}}},\n",
                p.workers, p.spawn_events, p.regions
            ));
        }

        s.push_str("  \"profiles\": {\n");
        for (i, (name, p)) in self.profiles.iter().enumerate() {
            let comma = if i + 1 < self.profiles.len() { "," } else { "" };
            s.push_str(&format!(
                "    \"{}\": {{\"steps\": {}, \"seconds\": {}, \"muls\": {}, \"adds\": {}}}{}\n",
                esc(name),
                p.steps,
                num(p.seconds),
                p.muls,
                p.adds,
                comma
            ));
        }
        s.push_str("  },\n");

        s.push_str(&format!("  \"dropped_spans\": {},\n", self.dropped_spans));
        // One span object per line — the `dof trace` parsing contract.
        s.push_str("  \"spans\": [\n");
        for (i, sp) in self.spans.iter().enumerate() {
            let comma = if i + 1 < self.spans.len() { "," } else { "" };
            s.push_str(&format!("    {}{}\n", span_json(sp), comma));
        }
        s.push_str("  ]\n");
        s.push_str("}\n");
        s
    }

    // ---- Prometheus rendering --------------------------------------------

    /// Render the counter/gauge subset as Prometheus text exposition.
    pub fn to_prometheus(&self) -> String {
        let mut s = String::new();
        let mut counter = |name: &str, help: &str| {
            s.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n"));
        };
        counter("dof_requests_total", "Completed requests per model server.");
        let mut body = String::new();
        for (label, m) in &self.models {
            let l = esc(label);
            body.push_str(&format!("dof_requests_total{{model=\"{l}\"}} {}\n", m.requests));
        }
        s.push_str(&body);
        s.push_str("# TYPE dof_rows_total counter\n");
        s.push_str("# TYPE dof_batches_total counter\n");
        s.push_str("# TYPE dof_shed_total counter\n");
        s.push_str("# TYPE dof_dropped_latency_samples_total counter\n");
        s.push_str("# TYPE dof_latency_seconds gauge\n");
        s.push_str("# TYPE dof_queue_wait_seconds gauge\n");
        for (label, m) in &self.models {
            let l = esc(label);
            s.push_str(&format!("dof_rows_total{{model=\"{l}\"}} {}\n", m.rows));
            s.push_str(&format!("dof_batches_total{{model=\"{l}\"}} {}\n", m.batches));
            s.push_str(&format!("dof_shed_total{{model=\"{l}\"}} {}\n", m.shed));
            s.push_str(&format!(
                "dof_dropped_latency_samples_total{{model=\"{l}\"}} {}\n",
                m.dropped_latency_samples
            ));
            for (q, v) in [
                ("0.5", m.p50_latency),
                ("0.95", m.p95_latency),
                ("0.99", m.p99_latency),
            ] {
                s.push_str(&format!(
                    "dof_latency_seconds{{model=\"{l}\",quantile=\"{q}\"}} {}\n",
                    num(v)
                ));
            }
            s.push_str(&format!(
                "dof_queue_wait_seconds{{model=\"{l}\",quantile=\"0.95\"}} {}\n",
                num(m.p95_queue_wait)
            ));
        }
        s.push_str("# TYPE dof_router_dispatched_total counter\n");
        s.push_str("# TYPE dof_router_failed_total counter\n");
        s.push_str("# TYPE dof_router_retries_total counter\n");
        for r in &self.routers {
            let l = esc(&r.model);
            s.push_str(&format!(
                "dof_router_dispatched_total{{model=\"{l}\"}} {}\n",
                r.dispatched
            ));
            s.push_str(&format!("dof_router_failed_total{{model=\"{l}\"}} {}\n", r.failed));
            s.push_str(&format!("dof_router_retries_total{{model=\"{l}\"}} {}\n", r.retries));
        }
        if let Some(a) = &self.autoscaler {
            s.push_str("# TYPE dof_autoscaler_scale_ups_total counter\n");
            s.push_str(&format!("dof_autoscaler_scale_ups_total {}\n", a.scale_ups));
            s.push_str("# TYPE dof_autoscaler_scale_downs_total counter\n");
            s.push_str(&format!(
                "dof_autoscaler_scale_downs_total {}\n",
                a.scale_downs
            ));
        }
        s.push_str("# TYPE dof_cache_hits_total counter\n");
        s.push_str("# TYPE dof_cache_misses_total counter\n");
        for (name, c) in &self.caches {
            let n = esc(name);
            s.push_str(&format!("dof_cache_hits_total{{cache=\"{n}\"}} {}\n", c.hits));
            s.push_str(&format!("dof_cache_misses_total{{cache=\"{n}\"}} {}\n", c.misses));
        }
        if let Some(sp) = &self.slab_pool {
            s.push_str("# TYPE dof_slab_pool_hits_total counter\n");
            s.push_str(&format!("dof_slab_pool_hits_total {}\n", sp.hits));
            s.push_str(&format!("dof_slab_pool_misses_total {}\n", sp.misses));
            s.push_str(&format!("dof_slab_pool_retained {}\n", sp.retained));
        }
        if let Some(p) = &self.pool {
            s.push_str("# TYPE dof_pool_regions_total counter\n");
            s.push_str(&format!("dof_pool_workers {}\n", p.workers));
            s.push_str(&format!("dof_pool_regions_total {}\n", p.regions));
        }
        s.push_str("# TYPE dof_dropped_spans_total counter\n");
        s.push_str(&format!("dof_dropped_spans_total {}\n", self.dropped_spans));
        s.push_str(&format!("dof_retained_spans {}\n", self.spans.len()));
        s
    }
}

/// Escape a string for embedding in a JSON string literal (labels here are
/// model/cache names; control characters are dropped to hex escapes).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Finite-number rendering (JSON has no NaN/inf; those become 0).
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

fn metrics_json(m: &MetricsSnapshot) -> String {
    format!(
        "{{\"requests\": {}, \"received\": {}, \"rows\": {}, \"batches\": {}, \
         \"padded_rows\": {}, \"mean_latency\": {}, \"p50_latency\": {}, \
         \"p95_latency\": {}, \"p99_latency\": {}, \"mean_exec_latency\": {}, \
         \"p95_exec_latency\": {}, \"mean_queue_wait\": {}, \"p95_queue_wait\": {}, \
         \"batch_efficiency\": {}, \"shards\": {}, \"sharded_batches\": {}, \
         \"parallel_occupancy\": {}, \"accepted\": {}, \"shed\": {}, \"invalid\": {}, \
         \"deadline_expired\": {}, \"engine_faults\": {}, \
         \"dropped_latency_samples\": {}}}",
        m.requests,
        m.received,
        m.rows,
        m.batches,
        m.padded_rows,
        num(m.mean_latency),
        num(m.p50_latency),
        num(m.p95_latency),
        num(m.p99_latency),
        num(m.mean_exec_latency),
        num(m.p95_exec_latency),
        num(m.mean_queue_wait),
        num(m.p95_queue_wait),
        num(m.batch_efficiency),
        m.shards,
        m.sharded_batches,
        num(m.parallel_occupancy),
        m.accepted,
        m.shed,
        m.invalid,
        m.deadline_expired,
        m.engine_faults,
        m.dropped_latency_samples,
    )
}

fn stochastic_json(c: &StochasticConfig) -> String {
    format!(
        "{{\"model\": \"{}\", \"samples\": {}, \"seed\": {}, \
         \"sampling\": \"{}\", \"dirs_per_point\": {}}}",
        esc(&c.model),
        c.samples,
        c.seed,
        esc(&c.sampling),
        c.dirs_per_point,
    )
}

fn router_json(r: &RouterModelSnapshot) -> String {
    let replicas: Vec<String> = r
        .replicas
        .iter()
        .map(|rep| {
            format!(
                "{{\"index\": {}, \"state\": \"{}\", \"consecutive_failures\": {}, \
                 \"quarantine_events\": {}, \"attempts\": {}, \"completed\": {}, \
                 \"failed\": {}, \"inflight\": {}}}",
                rep.index,
                rep.state,
                rep.consecutive_failures,
                rep.quarantine_events,
                rep.attempts,
                rep.completed,
                rep.failed,
                rep.inflight,
            )
        })
        .collect();
    format!(
        "{{\"model\": \"{}\", \"dispatched\": {}, \"completed\": {}, \"failed\": {}, \
         \"shed\": {}, \"retries\": {}, \"deadline_expired\": {}, \"invalid\": {}, \
         \"engine_faults\": {}, \"quarantine_events\": {}, \"queue_depth\": {}, \
         \"peak_queue_depth\": {}, \"interval_peak_queue_depth\": {}, \"epoch\": {}, \
         \"replicas\": [{}]}}",
        esc(&r.model),
        r.dispatched,
        r.completed,
        r.failed,
        r.shed,
        r.retries,
        r.deadline_expired,
        r.invalid,
        r.engine_faults,
        r.quarantine_events,
        r.queue_depth,
        r.peak_queue_depth,
        r.interval_peak_queue_depth,
        r.epoch,
        replicas.join(", "),
    )
}

fn scale_event_json(ev: &crate::coordinator::ScaleEvent) -> String {
    let dir = match ev.direction {
        ScaleDirection::Up => "up",
        ScaleDirection::Down => "down",
    };
    format!(
        "{{\"model\": \"{}\", \"direction\": \"{}\", \"tick\": {}, \
         \"replicas_before\": {}, \"replicas_after\": {}, \
         \"interval_peak_queue_depth\": {}, \"occupancy\": {}}}",
        esc(&ev.model),
        dir,
        ev.tick,
        ev.replicas_before,
        ev.replicas_after,
        ev.interval_peak_queue_depth,
        num(ev.occupancy),
    )
}

/// One span as a single-line JSON object (the `dof trace` line contract:
/// every key below is extracted by [`super::trace_view::parse_spans`]).
fn span_json(sp: &Span) -> String {
    format!(
        "{{\"id\": {}, \"parent\": {}, \"request\": {}, \"kind\": \"{}\", \
         \"label\": \"{}\", \"start_tick\": {}, \"end_tick\": {}, \"seconds\": {}, \
         \"detail\": {}}}",
        sp.id,
        sp.parent,
        sp.request,
        sp.kind.name(),
        esc(&sp.label),
        sp.start_tick,
        sp.end_tick,
        num(sp.seconds),
        sp.detail,
    )
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::super::span::{SpanKind, TraceContext};
    use super::*;
    use crate::coordinator::Metrics;

    fn sample_span(t: &Tracer, parent: u64, kind: SpanKind) -> Span {
        let id = t.next_id();
        Span {
            id,
            parent,
            request: 1,
            kind,
            label: "m".to_string(),
            start_tick: 2,
            end_tick: 3,
            seconds: 0.25,
            detail: 8,
        }
    }

    #[test]
    fn json_has_schema_models_and_span_lines() {
        let m = Metrics::new();
        m.record_request(4, 0.001);
        let mut reg = Registry::new();
        reg.add_model("dof-east", m.snapshot());
        reg.add_cache(
            "plan",
            CacheStats {
                hits: 3,
                misses: 1,
                entries: 1,
            },
        );
        let t = Tracer::with_shards(1, 8);
        let root = sample_span(&t, 0, SpanKind::Request);
        let _ctx = TraceContext {
            request: root.id,
            parent: root.id,
        };
        t.record(root);
        t.record(sample_span(&t, 1, SpanKind::Execute));
        reg.set_spans(&t);
        let json = reg.to_json();
        assert!(json.contains("\"telemetry_schema\": 1"));
        assert!(json.contains("\"dof-east\""));
        assert!(json.contains("\"p99_latency\""));
        assert!(json.contains("\"dropped_spans\": 0"));
        // One span per line, parseable by the trace viewer.
        let span_lines = json
            .lines()
            .filter(|l| l.trim_start().starts_with("{\"id\":"))
            .count();
        assert_eq!(span_lines, 2);
        // Balanced braces (cheap well-formedness check without a parser).
        let open = json.matches('{').count();
        let close = json.matches('}').count();
        assert_eq!(open, close);
    }

    #[test]
    fn prometheus_exposition_has_types_and_values() {
        let m = Metrics::new();
        m.record_request(4, 0.001);
        m.record_shed();
        let mut reg = Registry::new();
        reg.add_model("dof", m.snapshot());
        reg.set_slab_pool(SlabPoolStats {
            hits: 5,
            misses: 2,
            retained: 1,
        });
        let text = reg.to_prometheus();
        assert!(text.contains("# TYPE dof_requests_total counter"));
        assert!(text.contains("dof_requests_total{model=\"dof\"} 1"));
        assert!(text.contains("dof_shed_total{model=\"dof\"} 1"));
        assert!(text.contains("quantile=\"0.99\""));
        assert!(text.contains("dof_slab_pool_hits_total 5"));
    }

    #[test]
    fn autoscaler_section_renders_events_and_counters() {
        use crate::coordinator::ScaleEvent;
        let mut reg = Registry::new();
        reg.set_autoscaler(AutoscalerSnapshot {
            scale_ups: 2,
            scale_downs: 1,
            events: vec![ScaleEvent {
                model: "dof".to_string(),
                direction: ScaleDirection::Up,
                tick: 7,
                replicas_before: 1,
                replicas_after: 2,
                interval_peak_queue_depth: 9,
                occupancy: 0.0,
            }],
        });
        let json = reg.to_json();
        assert!(json.contains("\"autoscaler\": {\"scale_ups\": 2, \"scale_downs\": 1"));
        assert!(json.contains("\"direction\": \"up\""));
        assert!(json.contains("\"tick\": 7"));
        assert!(json.contains("\"interval_peak_queue_depth\": 9"));
        let open = json.matches('{').count();
        let close = json.matches('}').count();
        assert_eq!(open, close);
        let text = reg.to_prometheus();
        assert!(text.contains("dof_autoscaler_scale_ups_total 2"));
        assert!(text.contains("dof_autoscaler_scale_downs_total 1"));
    }

    #[test]
    fn stochastic_section_and_dropped_samples_render() {
        let m = Metrics::new();
        m.record_request(4, 0.001);
        m.record_request(4, f64::NAN); // dropped, counted exactly
        let mut reg = Registry::new();
        reg.add_model("stochastic", m.snapshot());
        reg.add_stochastic(StochasticConfig {
            model: "stochastic".to_string(),
            samples: 64,
            seed: 42,
            sampling: "sparse-rademacher(4)".to_string(),
            dirs_per_point: 129,
        });
        let json = reg.to_json();
        assert!(json.contains("\"dropped_latency_samples\": 1"));
        assert!(json.contains(
            "\"stochastic\": [{\"model\": \"stochastic\", \"samples\": 64, \
             \"seed\": 42, \"sampling\": \"sparse-rademacher(4)\", \
             \"dirs_per_point\": 129}]"
        ));
        let open = json.matches('{').count();
        let close = json.matches('}').count();
        assert_eq!(open, close);
        let text = reg.to_prometheus();
        assert!(text
            .contains("dof_dropped_latency_samples_total{model=\"stochastic\"} 1"));
    }

    #[test]
    fn labels_are_escaped() {
        let m = Metrics::new();
        let mut reg = Registry::new();
        reg.add_model("we\"ird\\label", m.snapshot());
        let json = reg.to_json();
        assert!(json.contains("we\\\"ird\\\\label"));
    }
}
