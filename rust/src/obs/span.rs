//! Request spans and the bounded, lock-sharded span log.
//!
//! **Control-plane file: no wall clock.** Span *timestamps* are logical
//! [`TickClock`](crate::coordinator::TickClock) ticks — the same invariant
//! `coordinator/fault.rs` holds, enforced by the same CI grep — so a span
//! tree recorded under a scripted tick schedule is exactly reproducible.
//! Data-plane *durations* (`seconds`) are measured by the callers that own
//! an execution (router attempt, worker batch, pool shard) and passed in;
//! this module never reads time itself.
//!
//! The log is a fixed-capacity ring: under pressure the **oldest** spans
//! are evicted (latest activity is what an incident investigation needs)
//! and every eviction is counted exactly in `dropped_spans`. Sharding is
//! by span id, so a single-shard tracer gives deterministic ring contents
//! for tests while the default spreads lock contention across shards.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// What phase of a request's life a span covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Root span: one per routed request (owned by the router client).
    Request,
    /// One dispatch attempt against a replica (retries create several).
    Attempt,
    /// Time a request sat in the worker queue before being cut into a batch.
    QueueWait,
    /// Formation of one batch (detail = rows used).
    BatchForm,
    /// Engine execution of one batch.
    Execute,
    /// One pool shard of a sharded execution (detail = shard index).
    Shard,
}

impl SpanKind {
    /// Stable lowercase name used in the telemetry dump.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::Attempt => "attempt",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::BatchForm => "batch_form",
            SpanKind::Execute => "execute",
            SpanKind::Shard => "shard",
        }
    }
}

/// One recorded span. `parent == 0` means "no parent" (span ids start at 1).
#[derive(Debug, Clone)]
pub struct Span {
    /// Monotonically assigned id (unique per [`Tracer`], never 0).
    pub id: u64,
    /// Parent span id within the same request tree (0 at the root).
    pub parent: u64,
    /// The request this span belongs to (the root span's id).
    pub request: u64,
    pub kind: SpanKind,
    /// Human label: model name, replica label, engine region, …
    pub label: String,
    /// Logical tick when the phase began.
    pub start_tick: u64,
    /// Logical tick when the phase ended (== `start_tick` when the clock
    /// did not advance during the phase).
    pub end_tick: u64,
    /// Measured data-plane duration in seconds (0.0 for pure control-plane
    /// spans that only exist for tree structure).
    pub seconds: f64,
    /// Kind-specific payload: rows for `BatchForm`/`Execute`, shard index
    /// for `Shard`, attempt ordinal for `Attempt`, 0 otherwise.
    pub detail: u64,
}

/// Identity a request carries through the serving stack: enough for any
/// layer to attach a child span without seeing the tracer's internals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceContext {
    /// Root span id of the request.
    pub request: u64,
    /// Span id the next child should attach under.
    pub parent: u64,
}

impl TraceContext {
    /// The same request, re-parented under `span` (for handing to a layer
    /// whose spans should nest under one we just opened).
    pub fn child_of(self, span: u64) -> TraceContext {
        TraceContext {
            request: self.request,
            parent: span,
        }
    }
}

/// Bounded, lock-sharded span log plus the monotone id source.
#[derive(Debug)]
pub struct Tracer {
    shards: Vec<Mutex<VecDeque<Span>>>,
    cap_per_shard: usize,
    next_id: AtomicU64,
    dropped: AtomicU64,
}

/// Poison-recovering lock: the span log must stay readable even if a
/// recording thread panicked mid-push (same rationale as `Metrics`).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Tracer {
    /// Default log: 8 shards, 4096 retained spans per shard.
    pub fn new() -> Self {
        Self::with_shards(8, 4096)
    }

    /// Explicit geometry. `shards == 1` makes ring contents and drop
    /// accounting fully deterministic (used by tests); capacity is
    /// per-shard. Zero values are clamped to 1.
    pub fn with_shards(shards: usize, cap_per_shard: usize) -> Self {
        let shards = shards.max(1);
        Tracer {
            shards: (0..shards).map(|_| Mutex::new(VecDeque::new())).collect(),
            cap_per_shard: cap_per_shard.max(1),
            next_id: AtomicU64::new(1),
            dropped: AtomicU64::new(0),
        }
    }

    /// Allocate the next span id (monotone, never 0, unique per tracer).
    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Append a finished span. Under pressure the oldest span in the
    /// target shard is evicted and counted in [`Tracer::dropped_spans`].
    pub fn record(&self, span: Span) {
        let shard = (span.id % self.shards.len() as u64) as usize;
        let mut ring = lock(&self.shards[shard]);
        if ring.len() >= self.cap_per_shard {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(span);
    }

    /// Exact count of spans evicted from the ring since creation.
    pub fn dropped_spans(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Spans currently retained across all shards, sorted by id (which is
    /// also record order per shard, so the merge is globally consistent).
    pub fn snapshot(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for shard in &self.shards {
            out.extend(lock(shard).iter().cloned());
        }
        out.sort_by_key(|s| s.id);
        out
    }

    /// Number of spans currently retained.
    pub fn retained(&self) -> usize {
        self.shards.iter().map(|s| lock(s).len()).sum()
    }
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn span(tracer: &Tracer, parent: u64, kind: SpanKind) -> Span {
        let id = tracer.next_id();
        Span {
            id,
            parent,
            request: 1,
            kind,
            label: String::new(),
            start_tick: 0,
            end_tick: 0,
            seconds: 0.0,
            detail: 0,
        }
    }

    #[test]
    fn ids_are_monotone_and_nonzero() {
        let t = Tracer::new();
        let a = t.next_id();
        let b = t.next_id();
        assert!(a >= 1);
        assert!(b > a);
    }

    #[test]
    fn ring_evicts_oldest_and_counts_exactly() {
        let t = Tracer::with_shards(1, 4);
        for _ in 0..10 {
            let s = span(&t, 0, SpanKind::Execute);
            t.record(s);
        }
        assert_eq!(t.retained(), 4);
        assert_eq!(t.dropped_spans(), 6);
        // Latest spans survive: ids 7..=10.
        let kept: Vec<u64> = t.snapshot().iter().map(|s| s.id).collect();
        assert_eq!(kept, vec![7, 8, 9, 10]);
    }

    #[test]
    fn snapshot_is_id_sorted_across_shards() {
        let t = Tracer::with_shards(4, 16);
        for _ in 0..13 {
            let s = span(&t, 0, SpanKind::Shard);
            t.record(s);
        }
        let ids: Vec<u64> = t.snapshot().iter().map(|s| s.id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted);
        assert_eq!(ids.len(), 13);
        assert_eq!(t.dropped_spans(), 0);
    }

    #[test]
    fn child_of_reparents() {
        let ctx = TraceContext {
            request: 7,
            parent: 7,
        };
        let child = ctx.child_of(12);
        assert_eq!(child.request, 7);
        assert_eq!(child.parent, 12);
    }
}
