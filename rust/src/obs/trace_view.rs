//! `dof trace`: re-parse a telemetry dump's span lines and pretty-print a
//! request's span tree.
//!
//! The parser is a line scanner, not a JSON parser: [`super::registry`]
//! guarantees every span is rendered as a single line starting with
//! `{"id":`, with a fixed key set. That contract keeps this crate free of
//! serde while still making dumps greppable and machine-extractable.

use super::span::{Span, SpanKind};
use crate::util::fmt_duration;

/// Extract the raw text after `"key": ` up to the next `,` or `}`.
fn raw_field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let at = line.find(&pat)? + pat.len();
    let rest = line[at..].trim_start();
    let end = rest
        .char_indices()
        .find(|&(i, c)| {
            if rest.starts_with('"') {
                // String value: close at the first unescaped quote.
                i > 0 && c == '"' && !rest[..i].ends_with('\\')
            } else {
                c == ',' || c == '}'
            }
        })
        .map(|(i, _)| i)?;
    if rest.starts_with('"') {
        Some(&rest[1..end])
    } else {
        Some(rest[..end].trim())
    }
}

fn field_u64(line: &str, key: &str) -> Option<u64> {
    raw_field(line, key)?.parse().ok()
}

fn field_f64(line: &str, key: &str) -> Option<f64> {
    raw_field(line, key)?.parse().ok()
}

/// Undo the registry's minimal JSON escaping.
fn unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

fn kind_from_name(name: &str) -> SpanKind {
    match name {
        "request" => SpanKind::Request,
        "attempt" => SpanKind::Attempt,
        "queue_wait" => SpanKind::QueueWait,
        "batch_form" => SpanKind::BatchForm,
        "shard" => SpanKind::Shard,
        _ => SpanKind::Execute,
    }
}

/// Parse every span line of a telemetry dump (other lines are skipped).
pub fn parse_spans(dump: &str) -> Vec<Span> {
    let mut out = Vec::new();
    for line in dump.lines() {
        let t = line.trim_start();
        if !t.starts_with("{\"id\":") {
            continue;
        }
        let (Some(id), Some(parent), Some(request)) = (
            field_u64(t, "id"),
            field_u64(t, "parent"),
            field_u64(t, "request"),
        ) else {
            continue;
        };
        out.push(Span {
            id,
            parent,
            request,
            kind: kind_from_name(raw_field(t, "kind").unwrap_or("execute")),
            label: unescape(raw_field(t, "label").unwrap_or("")),
            start_tick: field_u64(t, "start_tick").unwrap_or(0),
            end_tick: field_u64(t, "end_tick").unwrap_or(0),
            seconds: field_f64(t, "seconds").unwrap_or(0.0),
            detail: field_u64(t, "detail").unwrap_or(0),
        });
    }
    out.sort_by_key(|s| s.id);
    out
}

fn render_span_line(out: &mut String, s: &Span, depth: usize) {
    let indent = "  ".repeat(depth + 1);
    let label = if s.label.is_empty() {
        String::new()
    } else {
        format!(" {}", s.label)
    };
    let detail = match s.kind {
        SpanKind::BatchForm | SpanKind::Execute => format!(" rows={}", s.detail),
        SpanKind::Shard => format!(" shard={}", s.detail),
        SpanKind::Attempt => format!(" attempt={}", s.detail),
        SpanKind::QueueWait => format!(" rows={}", s.detail),
        SpanKind::Request => format!(" rows={}", s.detail),
    };
    out.push_str(&format!(
        "{indent}#{} {}{label} ticks {}..{}{} {}\n",
        s.id,
        s.kind.name(),
        s.start_tick,
        s.end_tick,
        detail,
        fmt_duration(s.seconds),
    ));
}

fn render_subtree(
    out: &mut String,
    spans: &[Span],
    children: &[Vec<usize>],
    idx: usize,
    depth: usize,
) {
    render_span_line(out, &spans[idx], depth);
    for &c in &children[idx] {
        render_subtree(out, spans, children, c, depth + 1);
    }
}

/// Render the span tree(s) of `spans`, optionally restricted to one
/// request id. Spans whose parent was evicted from the ring are promoted to
/// roots of their request (marked by their non-zero parent id in the line).
pub fn render_tree(spans: &[Span], request: Option<u64>) -> String {
    let mut spans: Vec<Span> = spans
        .iter()
        .filter(|s| match request {
            Some(r) => s.request == r,
            None => true,
        })
        .cloned()
        .collect();
    spans.sort_by_key(|s| s.id);
    if spans.is_empty() {
        return "no spans\n".to_string();
    }
    let index_of = |id: u64| spans.iter().position(|s| s.id == id);
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); spans.len()];
    let mut roots: Vec<usize> = Vec::new();
    for (i, s) in spans.iter().enumerate() {
        match (s.parent, index_of(s.parent)) {
            (0, _) | (_, None) => roots.push(i),
            (_, Some(p)) => children[p].push(i),
        }
    }
    let mut out = String::new();
    let mut last_req = None;
    for &r in &roots {
        if last_req != Some(spans[r].request) {
            last_req = Some(spans[r].request);
            out.push_str(&format!("request {}\n", spans[r].request));
        }
        render_subtree(&mut out, &spans, &children, r, 0);
    }
    out
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn dump() -> String {
        concat!(
            "{\n",
            "  \"telemetry_schema\": 1,\n",
            "  \"spans\": [\n",
            "    {\"id\": 1, \"parent\": 0, \"request\": 1, \"kind\": \"request\", \
             \"label\": \"dof\", \"start_tick\": 0, \"end_tick\": 5, \"seconds\": 0.01, \
             \"detail\": 8},\n",
            "    {\"id\": 2, \"parent\": 1, \"request\": 1, \"kind\": \"attempt\", \
             \"label\": \"replica0\", \"start_tick\": 0, \"end_tick\": 5, \
             \"seconds\": 0.009, \"detail\": 0},\n",
            "    {\"id\": 3, \"parent\": 2, \"request\": 1, \"kind\": \"execute\", \
             \"label\": \"dof\", \"start_tick\": 1, \"end_tick\": 4, \"seconds\": 0.005, \
             \"detail\": 8},\n",
            "    {\"id\": 4, \"parent\": 3, \"request\": 1, \"kind\": \"shard\", \
             \"label\": \"s\", \"start_tick\": 1, \"end_tick\": 1, \"seconds\": 0.002, \
             \"detail\": 1}\n",
            "  ]\n",
            "}\n",
        )
        .to_string()
    }

    #[test]
    fn parses_span_lines_only() {
        let spans = parse_spans(&dump());
        assert_eq!(spans.len(), 4);
        assert_eq!(spans[0].kind, SpanKind::Request);
        assert_eq!(spans[0].label, "dof");
        assert_eq!(spans[1].parent, 1);
        assert_eq!(spans[3].detail, 1);
        assert!((spans[2].seconds - 0.005).abs() < 1e-12);
        assert_eq!(spans[2].end_tick, 4);
    }

    #[test]
    fn tree_is_nested_in_parent_order() {
        let spans = parse_spans(&dump());
        let tree = render_tree(&spans, Some(1));
        let lines: Vec<&str> = tree.lines().collect();
        assert_eq!(lines[0], "request 1");
        assert!(lines[1].starts_with("  #1 request dof"));
        assert!(lines[2].starts_with("    #2 attempt replica0"));
        assert!(lines[3].starts_with("      #3 execute dof"));
        assert!(lines[4].starts_with("        #4 shard s"));
        assert!(lines[3].contains("rows=8"));
        assert!(lines[4].contains("shard=1"));
    }

    #[test]
    fn orphaned_spans_become_roots() {
        // Parent 2 evicted: span 3's subtree must still render.
        let d = dump();
        let filtered: String = d
            .lines()
            .filter(|l| !l.contains("\"id\": 2"))
            .map(|l| format!("{l}\n"))
            .collect();
        let spans = parse_spans(&filtered);
        assert_eq!(spans.len(), 3);
        let tree = render_tree(&spans, None);
        assert!(tree.contains("#3 execute"));
        assert!(tree.contains("#4 shard"));
        let other = render_tree(&spans, Some(99));
        assert_eq!(other, "no spans\n");
    }

    #[test]
    fn escaped_labels_round_trip() {
        let line = "{\"id\": 9, \"parent\": 0, \"request\": 9, \"kind\": \"request\", \
                    \"label\": \"we\\\"ird\\\\label\", \"start_tick\": 0, \"end_tick\": 0, \
                    \"seconds\": 0, \"detail\": 0}";
        let spans = parse_spans(line);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].label, "we\"ird\\label");
    }
}
