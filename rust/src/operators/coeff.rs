//! Coefficient constructions — the single home of every operator
//! coefficient recipe in the crate.
//!
//! Second order (Table 4 of the paper): each experiment in §3 pairs an
//! architecture with a coefficient matrix:
//!
//! | structure         | elliptic                     | low-rank                     | general            |
//! |-------------------|------------------------------|------------------------------|--------------------|
//! | MLP               | `a_ij = Σ_{k≤64} α_ik α_jk`  | `a_ij = Σ_{k≤32} α_ik α_jk`  | `a_ij = δ_ij s_i`  |
//! | MLP w/ sparsity   | block-diag Gram (4×4, k≤4)   | block-diag Gram (4×4, k≤2)   | block-diag `δ s`   |
//!
//! with `α, σ ~ N(0,1)`, `s_0 = −1`, `s_i = 1` otherwise.
//!
//! Higher order (the jet subsystem): [`HigherOrderSpec`] builds the
//! symbolic term lists for the order-3/4 operators (biharmonic plate,
//! Swift–Hohenberg linearization, Kuramoto–Sivashinsky linear part) that
//! [`super::higher::HigherOrderOperator`] turns into polarization bases —
//! declarative specs instead of ad-hoc term assembly at call sites.

use crate::jet::{biharmonic_terms, laplacian_terms, JetTerm};
use crate::tensor::{matmul, Tensor};
use crate::util::Xoshiro256;

/// Declarative description of a coefficient matrix; `build()` materializes
/// the symmetric `N×N` matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoeffSpec {
    /// Gram matrix `α αᵀ` with `α ∈ R^{N×rank}` i.i.d. N(0,1) — PSD;
    /// full-rank elliptic for `rank = n`, low-rank elliptic for `rank < n`.
    EllipticGram { n: usize, rank: usize, seed: u64 },
    /// `diag(s)` with `s_0 = −1`, `s_i = 1` — the paper's "general"
    /// (indefinite, hyperbolic-like) operator.
    SignedDiag { n: usize },
    /// Identity — plain Laplacian (DOF reduces to Forward Laplacian).
    Identity { n: usize },
    /// Block-diagonal Gram: `blocks` blocks of size `block`, each
    /// `σ σᵀ` with `σ ∈ R^{block×rank}` — Table 4 row 2 (elliptic/low-rank).
    BlockDiagGram {
        blocks: usize,
        block: usize,
        rank: usize,
        seed: u64,
    },
    /// Block-diagonal signed identity: `δ_lm δ_ij s_i` — Table 4 row 2
    /// (general).
    BlockDiagSigned { blocks: usize, block: usize },
}

impl CoeffSpec {
    /// Total dimension `N`.
    pub fn n(&self) -> usize {
        match *self {
            CoeffSpec::EllipticGram { n, .. } => n,
            CoeffSpec::SignedDiag { n } => n,
            CoeffSpec::Identity { n } => n,
            CoeffSpec::BlockDiagGram { blocks, block, .. } => blocks * block,
            CoeffSpec::BlockDiagSigned { blocks, block } => blocks * block,
        }
    }

    /// Expected rank of the built matrix (with probability 1 for the random
    /// Gram constructions).
    pub fn expected_rank(&self) -> usize {
        match *self {
            CoeffSpec::EllipticGram { n, rank, .. } => rank.min(n),
            CoeffSpec::SignedDiag { n } => n,
            CoeffSpec::Identity { n } => n,
            CoeffSpec::BlockDiagGram {
                blocks,
                block,
                rank,
                ..
            } => blocks * rank.min(block),
            CoeffSpec::BlockDiagSigned { blocks, block } => blocks * block,
        }
    }

    /// Human-readable operator class, for bench tables.
    pub fn label(&self) -> &'static str {
        match self {
            CoeffSpec::EllipticGram { n, rank, .. } if rank < n => "low-rank",
            CoeffSpec::EllipticGram { .. } => "elliptic",
            CoeffSpec::SignedDiag { .. } => "general",
            CoeffSpec::Identity { .. } => "laplacian",
            CoeffSpec::BlockDiagGram { block, rank, .. } if rank < block => "low-rank",
            CoeffSpec::BlockDiagGram { .. } => "elliptic",
            CoeffSpec::BlockDiagSigned { .. } => "general",
        }
    }

    /// Materialize the symmetric coefficient matrix.
    pub fn build(&self) -> Tensor {
        match *self {
            CoeffSpec::EllipticGram { n, rank, seed } => {
                let mut rng = Xoshiro256::new(seed);
                let alpha = Tensor::randn(&[n, rank], &mut rng);
                matmul(&alpha, &alpha.transpose())
            }
            CoeffSpec::SignedDiag { n } => {
                let mut a = Tensor::eye(n);
                a.set(0, 0, -1.0);
                a
            }
            CoeffSpec::Identity { n } => Tensor::eye(n),
            CoeffSpec::BlockDiagGram {
                blocks,
                block,
                rank,
                seed,
            } => {
                let n = blocks * block;
                let mut a = Tensor::zeros(&[n, n]);
                let mut rng = Xoshiro256::new(seed);
                for l in 0..blocks {
                    let sigma = Tensor::randn(&[block, rank], &mut rng);
                    let g = matmul(&sigma, &sigma.transpose());
                    for i in 0..block {
                        for j in 0..block {
                            a.set(l * block + i, l * block + j, g.at(i, j));
                        }
                    }
                }
                a
            }
            CoeffSpec::BlockDiagSigned { blocks, block } => {
                let n = blocks * block;
                let mut a = Tensor::zeros(&[n, n]);
                for l in 0..blocks {
                    for i in 0..block {
                        let s = if i == 0 { -1.0 } else { 1.0 };
                        a.set(l * block + i, l * block + i, s);
                    }
                }
                a
            }
        }
    }
}

/// Declarative description of a higher-order (order-3/4) operator;
/// `build()` materializes the symbolic term list plus the zeroth-order
/// coefficient. The derivative terms are assembled into jet directions by
/// [`crate::jet::DirectionBasis::from_terms`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum HigherOrderSpec {
    /// Biharmonic plate operator `Δ²` on `R^d` — order 4, elliptic,
    /// exactly `d²` jet directions.
    Biharmonic { d: usize },
    /// Stationary linearization of Swift–Hohenberg about `u = 0`:
    /// `L = r − (1+Δ)² = −Δ² − 2Δ + (r−1)` — order 4 with a second-order
    /// tail and a constant term.
    SwiftHohenberg { d: usize, r: f64 },
    /// Linear part of the Kuramoto–Sivashinsky operator (gradient form):
    /// `L = −Δ² − Δ` — order 4, destabilizing second-order tail.
    KuramotoSivashinsky { d: usize },
}

impl HigherOrderSpec {
    /// Total dimension `N`.
    pub fn n(&self) -> usize {
        match *self {
            HigherOrderSpec::Biharmonic { d }
            | HigherOrderSpec::SwiftHohenberg { d, .. }
            | HigherOrderSpec::KuramotoSivashinsky { d } => d,
        }
    }

    /// Operator order (the jet order `k`).
    pub fn order(&self) -> usize {
        4
    }

    /// Human-readable operator class, for bench tables.
    pub fn label(&self) -> &'static str {
        match self {
            HigherOrderSpec::Biharmonic { .. } => "biharmonic",
            HigherOrderSpec::SwiftHohenberg { .. } => "swift-hohenberg",
            HigherOrderSpec::KuramotoSivashinsky { .. } => "kuramoto-sivashinsky",
        }
    }

    /// Materialize `(derivative terms, zeroth-order coefficient)`.
    pub fn build(&self) -> (Vec<JetTerm>, Option<f64>) {
        match *self {
            HigherOrderSpec::Biharmonic { d } => (biharmonic_terms(d, 1.0), None),
            HigherOrderSpec::SwiftHohenberg { d, r } => {
                let mut terms = biharmonic_terms(d, -1.0);
                terms.extend(laplacian_terms(d, -2.0));
                (terms, Some(r - 1.0))
            }
            HigherOrderSpec::KuramotoSivashinsky { d } => {
                let mut terms = biharmonic_terms(d, -1.0);
                terms.extend(laplacian_terms(d, -1.0));
                (terms, None)
            }
        }
    }
}

/// The exact Table 4 specs for the MLP experiments (N = 64).
pub fn table4_mlp(seed: u64) -> [(&'static str, CoeffSpec); 3] {
    [
        ("Elliptic", CoeffSpec::EllipticGram { n: 64, rank: 64, seed }),
        ("Low-rank", CoeffSpec::EllipticGram { n: 64, rank: 32, seed }),
        ("General", CoeffSpec::SignedDiag { n: 64 }),
    ]
}

/// The exact Table 4 specs for the sparse-MLP experiments
/// (16 blocks × 4 dims).
pub fn table4_sparse(seed: u64) -> [(&'static str, CoeffSpec); 3] {
    [
        (
            "Elliptic",
            CoeffSpec::BlockDiagGram { blocks: 16, block: 4, rank: 4, seed },
        ),
        (
            "Low-rank",
            CoeffSpec::BlockDiagGram { blocks: 16, block: 4, rank: 2, seed },
        ),
        ("General", CoeffSpec::BlockDiagSigned { blocks: 16, block: 4 }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::LdlDecomposition;

    #[test]
    fn gram_is_symmetric_psd_with_expected_rank() {
        let spec = CoeffSpec::EllipticGram { n: 16, rank: 7, seed: 1 };
        let a = spec.build();
        assert!(a.max_abs_diff(&a.transpose()) < 1e-12);
        let dec = LdlDecomposition::of(&a);
        assert_eq!(dec.rank(), 7);
        assert!(dec.is_elliptic());
    }

    #[test]
    fn signed_diag_is_indefinite_full_rank() {
        let a = CoeffSpec::SignedDiag { n: 8 }.build();
        let dec = LdlDecomposition::of(&a);
        assert_eq!(dec.rank(), 8);
        assert!(!dec.is_elliptic());
        assert_eq!(a.at(0, 0), -1.0);
        assert_eq!(a.at(3, 3), 1.0);
    }

    #[test]
    fn block_diag_gram_structure() {
        let spec = CoeffSpec::BlockDiagGram { blocks: 4, block: 3, rank: 2, seed: 5 };
        let a = spec.build();
        assert_eq!(a.dims(), &[12, 12]);
        // Off-block entries are exactly zero.
        assert_eq!(a.at(0, 5), 0.0);
        assert_eq!(a.at(10, 2), 0.0);
        let dec = LdlDecomposition::of(&a);
        assert_eq!(dec.rank(), spec.expected_rank());
        assert_eq!(dec.rank(), 8);
    }

    #[test]
    fn table4_dimensions() {
        for (_, spec) in table4_mlp(3) {
            assert_eq!(spec.n(), 64);
        }
        for (_, spec) in table4_sparse(3) {
            assert_eq!(spec.n(), 64);
        }
        // Low-rank MLP spec must have rank 32.
        assert_eq!(table4_mlp(3)[1].1.expected_rank(), 32);
        // Sparse low-rank: 16 blocks × rank 2 = 32.
        assert_eq!(table4_sparse(3)[1].1.expected_rank(), 32);
    }

    #[test]
    fn higher_order_specs_build() {
        let (terms, c) = HigherOrderSpec::Biharmonic { d: 3 }.build();
        assert_eq!(terms.len(), 3 + 3); // d pure powers + C(3,2) pairs
        assert!(c.is_none());
        let (terms, c) = HigherOrderSpec::SwiftHohenberg { d: 2, r: 0.3 }.build();
        // 2 + 1 biharmonic terms + 2 laplacian terms, c = r − 1.
        assert_eq!(terms.len(), 3 + 2);
        assert!((c.unwrap() - (0.3 - 1.0)).abs() < 1e-15);
        assert_eq!(HigherOrderSpec::KuramotoSivashinsky { d: 2 }.order(), 4);
        assert_eq!(HigherOrderSpec::Biharmonic { d: 5 }.n(), 5);
    }

    #[test]
    fn labels() {
        assert_eq!(CoeffSpec::EllipticGram { n: 4, rank: 4, seed: 0 }.label(), "elliptic");
        assert_eq!(CoeffSpec::EllipticGram { n: 4, rank: 2, seed: 0 }.label(), "low-rank");
        assert_eq!(CoeffSpec::SignedDiag { n: 4 }.label(), "general");
    }
}
