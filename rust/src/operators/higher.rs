//! Higher-order (third/fourth-order) constant-coefficient operators —
//! the jet-subsystem counterpart of [`super::Operator`].
//!
//! Where a second-order [`super::Operator`] caches an `A = LᵀDL`
//! decomposition and hands out [`crate::autodiff::DofEngine`]s, a
//! [`HigherOrderOperator`] caches a polarization
//! [`DirectionBasis`] and hands out [`crate::jet::JetEngine`]s. The
//! coefficient *constructions* live in [`super::coeff::HigherOrderSpec`]
//! (Table-4 style declarative specs), keeping every coefficient recipe —
//! second order and higher — in one module.

use std::sync::Arc;

use crate::graph::Graph;
use crate::jet::{
    self, DirectionBasis, DirectionSampling, JetEngine, JetProgram, JetTerm, StochasticJetEngine,
};

use super::coeff::HigherOrderSpec;

/// A fully-specified operator of order ≤ 4:
/// `L[φ] = Σ_terms coef·∂^axes φ + b·∇φ + c·φ`, with the cached direction
/// basis (the jet analogue of the cached `LᵀDL`).
pub struct HigherOrderOperator {
    /// The symbolic derivative terms (order 1..=4).
    pub terms: Vec<JetTerm>,
    /// Optional first-order coefficients `b ∈ R^N` (see the coefficient
    /// contract on [`super::Operator::b`]: constant in `x`).
    pub b: Option<Vec<f64>>,
    /// Optional zeroth-order coefficient `c`.
    pub c: Option<f64>,
    /// Cached polarization basis assembled from `terms` and `b`.
    pub basis: DirectionBasis,
    /// Display label.
    pub label: String,
    n: usize,
}

impl HigherOrderOperator {
    /// Build from a declarative coefficient spec.
    pub fn from_spec(spec: HigherOrderSpec) -> Self {
        let n = spec.n();
        let (terms, c) = spec.build();
        Self::assemble(n, terms, None, c, spec.label().to_string())
    }

    /// Build from explicit terms.
    pub fn from_terms(n: usize, terms: Vec<JetTerm>, label: impl Into<String>) -> Self {
        Self::assemble(n, terms, None, None, label.into())
    }

    /// Attach lower-order terms (rebuilds the basis: `b` rides along as one
    /// extra jet direction with a weight on `c₁`).
    pub fn with_lower_order(self, b: Option<Vec<f64>>, c: Option<f64>) -> Self {
        Self::assemble(self.n, self.terms, b, c, self.label)
    }

    fn assemble(
        n: usize,
        terms: Vec<JetTerm>,
        b: Option<Vec<f64>>,
        c: Option<f64>,
        label: String,
    ) -> Self {
        let basis = DirectionBasis::from_terms(n, &terms, b.as_deref());
        Self {
            terms,
            b,
            c,
            basis,
            label,
            n,
        }
    }

    /// Input dimension `N`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Operator order `k = max term order` (the jet order).
    pub fn order(&self) -> usize {
        self.basis.order
    }

    /// Jet direction count `t` (the higher-order analogue of `rank(A)`).
    pub fn directions(&self) -> usize {
        self.basis.directions()
    }

    /// Configured jet engine (shares the cached basis).
    pub fn jet_engine(&self) -> JetEngine {
        JetEngine::new(self.basis.clone()).with_constant(self.c)
    }

    /// The compile-once jet program for `graph`, fetched from the keyed
    /// global jet cache (compiled on first use) — the explicit form of the
    /// compile-then-execute split `jet_engine().compute*` performs
    /// internally.
    pub fn jet_program(&self, graph: &Graph) -> Arc<JetProgram> {
        jet::global_jet_cache().get_or_compile(graph, &self.basis, self.c.is_some())
    }

    /// Configured stochastic (STDE) engine: unbiased sampled estimate of
    /// the same contraction, with the exact engines above as its oracle.
    pub fn stochastic_engine(
        &self,
        sampling: DirectionSampling,
        samples: u32,
        seed: u64,
    ) -> StochasticJetEngine {
        StochasticJetEngine::from_terms(self.n, self.terms.clone(), sampling, samples, seed)
            .with_lower_order(self.b.clone(), self.c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder::random_layers, mlp_graph, Act};
    use crate::tensor::Tensor;
    use crate::util::Xoshiro256;

    #[test]
    fn biharmonic_spec_shapes() {
        let op = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: 4 });
        assert_eq!(op.n(), 4);
        assert_eq!(op.order(), 4);
        assert_eq!(op.directions(), 16, "Δ² needs d² directions");
        assert!(op.c.is_none());
    }

    #[test]
    fn swift_hohenberg_is_minus_bih_minus_2lap_plus_c() {
        // Cross-check the composite spec against its parts on a real graph:
        // L_SH[φ] = −Δ²φ − 2Δφ + (r−1)φ.
        let mut rng = Xoshiro256::new(91);
        let d = 3;
        let r = 0.4;
        let g = mlp_graph(&random_layers(&[d, 10, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[3, d], &mut rng).scale(0.5);
        let sh = HigherOrderOperator::from_spec(HigherOrderSpec::SwiftHohenberg { d, r })
            .jet_engine()
            .compute(&g, &x);
        let bih = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d })
            .jet_engine()
            .compute(&g, &x);
        let lap = HigherOrderOperator::from_terms(
            d,
            crate::jet::laplacian_terms(d, 1.0),
            "laplacian",
        )
        .jet_engine()
        .compute(&g, &x);
        for b in 0..3 {
            let want = -bih.operator_values.at(b, 0) - 2.0 * lap.operator_values.at(b, 0)
                + (r - 1.0) * sh.values.at(b, 0);
            let got = sh.operator_values.at(b, 0);
            assert!(
                (got - want).abs() < 1e-9 * want.abs().max(1.0),
                "row {b}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn lower_order_rebuilds_basis() {
        let op = HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d: 3 })
            .with_lower_order(Some(vec![0.5; 3]), Some(-1.0));
        assert_eq!(op.directions(), 10, "d² + 1 extra b-direction");
        assert!(op.c.is_some());
    }
}
