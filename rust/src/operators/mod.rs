//! Differential operators — coefficient constructions and cached operator
//! wrappers that pair a spec with its precomputed engine seed.
//!
//! * [`Operator`] — second order, `L[φ] = Σ a_ij ∂²_ij φ + Σ b_i ∂_i φ +
//!   c φ`, cached `A = LᵀDL`, hands out DOF/Hessian engines;
//! * [`higher::HigherOrderOperator`] — order 3/4 (biharmonic class),
//!   cached polarization [`crate::jet::DirectionBasis`], hands out jet
//!   engines.
//!
//! **Coefficient contract** (the single statement of it — engine and field
//! docs refer here): every coefficient in this release — `A`, `b`, `c`,
//! and the higher-order term list — is **constant in `x`**. The engines
//! exploit this: `b` is seeded once into the scalar stream at the input
//! nodes (or, for jets, rides as one extra direction weighted on `c₁`) and
//! `c·φ` is applied once at the output; none of them is re-evaluated per
//! collocation point. Variable coefficients `a(x), b(x)` would need
//! per-point seeding — a ROADMAP follow-up, not a supported mode. All
//! coefficient *constructions* live in [`coeff`]; build operators from a
//! [`CoeffSpec`] / [`coeff::HigherOrderSpec`] rather than assembling
//! matrices or term lists ad hoc.

pub mod coeff;
pub mod higher;

pub use coeff::{table4_mlp, table4_sparse, CoeffSpec, HigherOrderSpec};
pub use higher::HigherOrderOperator;

use std::sync::Arc;

use crate::autodiff::{DofEngine, HessianEngine};
use crate::graph::Graph;
use crate::jet::{terms_from_symmetric, DirectionSampling, StochasticJetEngine};
use crate::linalg::LdlDecomposition;
use crate::plan::{self, OperatorProgram, PlanOptions};
use crate::tensor::Tensor;

/// A fully-specified second-order operator: coefficient matrix, optional
/// lower-order terms, and the cached decomposition.
pub struct Operator {
    /// The symmetric coefficient matrix `A`.
    pub a: Tensor,
    /// First-order coefficients `b ∈ R^N` (see the module-level
    /// coefficient contract: constant in `x`, seeded once at the inputs).
    pub b: Option<Vec<f64>>,
    /// Zeroth-order coefficient `c` (same contract; applied once at the
    /// output).
    pub c: Option<f64>,
    /// Cached `A = Lᵀ D L`.
    pub ldl: LdlDecomposition,
    /// Display label.
    pub label: String,
}

impl Operator {
    /// Build from a coefficient spec (pure second-order).
    pub fn from_spec(spec: CoeffSpec) -> Self {
        let a = spec.build();
        let ldl = LdlDecomposition::of(&a);
        Self {
            a,
            b: None,
            c: None,
            ldl,
            label: spec.label().to_string(),
        }
    }

    /// Build from an explicit matrix.
    pub fn from_matrix(a: Tensor, label: impl Into<String>) -> Self {
        let ldl = LdlDecomposition::of(&a);
        Self {
            a,
            b: None,
            c: None,
            ldl,
            label: label.into(),
        }
    }

    /// Attach lower-order terms.
    pub fn with_lower_order(mut self, b: Option<Vec<f64>>, c: Option<f64>) -> Self {
        self.b = b;
        self.c = c;
        self
    }

    /// Input dimension `N`.
    pub fn n(&self) -> usize {
        self.a.dims()[0]
    }

    /// Rank of the second-order part (DOF tangent width).
    pub fn rank(&self) -> usize {
        self.ldl.rank()
    }

    /// Configured DOF engine (shares the cached decomposition).
    pub fn dof_engine(&self) -> DofEngine {
        DofEngine::from_ldl(self.ldl.clone())
            .with_lower_order(self.b.clone(), self.c)
    }

    /// Configured Hessian-baseline engine.
    pub fn hessian_engine(&self) -> HessianEngine {
        HessianEngine::new(&self.a).with_lower_order(self.b.clone(), self.c)
    }

    /// Configured stochastic (STDE) engine over the same contraction
    /// (`A` lowered to jet terms via [`terms_from_symmetric`]); the exact
    /// DOF/Hessian engines are its convergence oracle.
    pub fn stochastic_engine(
        &self,
        sampling: DirectionSampling,
        samples: u32,
        seed: u64,
    ) -> StochasticJetEngine {
        StochasticJetEngine::from_terms(self.n(), terms_from_symmetric(&self.a), sampling, samples, seed)
            .with_lower_order(self.b.clone(), self.c)
    }

    /// The compile-once DOF program for `graph`, fetched from the keyed
    /// global plan cache (compiled on first use). This is the explicit
    /// form of the compile-then-execute split the engines' `compute*`
    /// wrappers perform internally; hold it to amortize compilation across
    /// many `execute*` calls and to read the analytic cost/peak numbers
    /// without running a batch.
    pub fn dof_program(&self, graph: &Graph) -> Arc<OperatorProgram> {
        // Derive the options from the engine this operator hands out, so
        // the program's cache key can never drift from what
        // `dof_engine().compute*` would compile.
        let opts: PlanOptions = self.dof_engine().plan_options();
        plan::global_cache().get_or_compile(graph, &self.ldl, opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder::random_layers, mlp_graph, Act};
    use crate::util::Xoshiro256;

    #[test]
    fn operator_engines_agree_for_every_table4_mlp_spec() {
        let mut rng = Xoshiro256::new(61);
        // Scaled-down Table 1 shapes for test speed (N = 8).
        let g = mlp_graph(&random_layers(&[8, 16, 16, 1], &mut rng), Act::Tanh);
        let x = Tensor::randn(&[3, 8], &mut rng);
        let specs = [
            CoeffSpec::EllipticGram { n: 8, rank: 8, seed: 2 },
            CoeffSpec::EllipticGram { n: 8, rank: 4, seed: 2 },
            CoeffSpec::SignedDiag { n: 8 },
        ];
        for spec in specs {
            let op = Operator::from_spec(spec);
            let dof = op.dof_engine().compute(&g, &x);
            let hes = op.hessian_engine().compute(&g, &x);
            for b in 0..3 {
                let dv = dof.operator_values.at(b, 0);
                let hv = hes.operator_values.at(b, 0);
                assert!(
                    (dv - hv).abs() < 1e-8 * hv.abs().max(1.0),
                    "{}: {dv} vs {hv}",
                    op.label
                );
            }
        }
    }

    #[test]
    fn rank_drives_engine_tangent_width() {
        let op = Operator::from_spec(CoeffSpec::EllipticGram { n: 8, rank: 3, seed: 1 });
        assert_eq!(op.rank(), 3);
        assert_eq!(op.dof_engine().rank(), 3);
    }

    #[test]
    fn lower_order_passthrough() {
        let op = Operator::from_spec(CoeffSpec::Identity { n: 4 })
            .with_lower_order(Some(vec![1.0; 4]), Some(0.5));
        let mut rng = Xoshiro256::new(62);
        let g = mlp_graph(&random_layers(&[4, 6, 1], &mut rng), Act::Sin);
        let x = Tensor::randn(&[2, 4], &mut rng);
        let dof = op.dof_engine().compute(&g, &x);
        let hes = op.hessian_engine().compute(&g, &x);
        for b in 0..2 {
            assert!(
                (dof.operator_values.at(b, 0) - hes.operator_values.at(b, 0)).abs() < 1e-9
            );
        }
    }
}
