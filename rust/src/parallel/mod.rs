//! Std-only parallel execution substrate: a persistent worker pool,
//! deterministic batch sharding, and the global thread-count knob
//! (`--threads` / `DOF_THREADS`).
//!
//! ## Design
//!
//! * [`Pool`] is a *view* onto the process's persistent worker team
//!   ([`pool`]): OS threads are spawned **once** on the first parallel
//!   region, then parked on a condvar between regions, so steady-state
//!   serving and bench loops pay zero thread-creation cost per region. A
//!   `Pool::new(t)` region runs on the calling thread plus at most `t − 1`
//!   warm helpers. The original region-scoped implementation survives as
//!   [`Pool::run_sharded_scoped`], the differential baseline the
//!   concurrency suite pins the pooled runtime against.
//! * Work is expressed as an ordered list of **shards** (contiguous row
//!   ranges). Workers pull shard indices from an atomic counter (dynamic
//!   load balance) but results are *always* reduced in shard order, never in
//!   completion order — the first half of the determinism contract.
//! * Shard boundaries are a function of the batch size alone (fixed
//!   [`DEFAULT_SHARD_ROWS`]-row chunks), never of the thread count — the
//!   second half of the contract. Together they make every reduced quantity
//!   (values, `L[φ]`, FLOP tallies, per-shard peak bytes) bit-identical
//!   across `--threads 1/2/4/8`, on either runtime.
//! * [`in_worker`] is a thread-local flag set inside pool workers (and on
//!   the caller while it participates in a region); nested parallel regions
//!   (e.g. the row-parallel GEMM of [`crate::tensor::matmul_into`] called
//!   from a shard worker) detect it and stay serial instead of
//!   oversubscribing the machine.
//!
//! ## Choosing thread counts
//!
//! The engines are compute-bound with streaming access patterns, so physical
//! cores is the right ceiling; the default is
//! `std::thread::available_parallelism()`. Override with `DOF_THREADS=n` or
//! `--threads n` on the CLI. Batches smaller than one shard
//! ([`DEFAULT_SHARD_ROWS`] rows) run inline regardless of the knob.

pub mod pool;

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Rows per work unit for batch sharding. Fixed (thread-count-independent)
/// so that shard decomposition — and therefore every per-shard measurement —
/// is invariant under the `--threads` knob.
pub const DEFAULT_SHARD_ROWS: usize = 8;

thread_local! {
    static IN_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Is the current thread a pool worker? (Nested parallel regions must stay
/// serial.)
pub fn in_worker() -> bool {
    IN_WORKER.with(|f| f.get())
}

pub(crate) struct WorkerGuard {
    prev: bool,
}

impl WorkerGuard {
    pub(crate) fn enter() -> Self {
        let prev = IN_WORKER.with(|f| f.replace(true));
        WorkerGuard { prev }
    }
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_WORKER.with(|f| f.set(prev));
    }
}

/// Run `f` with nested parallel regions suppressed, exactly as if it were
/// executing inside a pool worker. A `--threads 1` execution must be
/// *genuinely* serial — including the row-parallel GEMM, which would
/// otherwise consult the process-global pool — or single-thread baselines
/// silently run multi-core.
pub fn with_serial_guard<R>(f: impl FnOnce() -> R) -> R {
    let _guard = WorkerGuard::enter();
    f()
}

/// Global thread count: 0 = not yet resolved.
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

/// The `DOF_THREADS` env var, when set to a valid positive integer.
/// Library contexts resolve lazily and cannot surface an error, so invalid
/// values are ignored here; binaries should call [`env_threads_checked`]
/// at startup to reject `0` / non-numeric values with a clear message
/// instead of a silent fallback (the `dof` CLI does).
pub fn env_threads() -> Option<usize> {
    std::env::var("DOF_THREADS")
        .ok()
        .and_then(|v| crate::util::parse_thread_count(&v).ok())
}

/// Validated read of `DOF_THREADS`: `Ok(None)` when unset, `Err` with a
/// clear message naming the offending value when set to `0` or a
/// non-number.
pub fn env_threads_checked() -> Result<Option<usize>, String> {
    match std::env::var("DOF_THREADS") {
        Err(_) => Ok(None),
        Ok(v) => crate::util::parse_thread_count(&v)
            .map(Some)
            .map_err(|e| format!("DOF_THREADS: {e}")),
    }
}

fn resolve_global_threads() -> usize {
    let current = GLOBAL_THREADS.load(Ordering::Relaxed);
    if current != 0 {
        return current;
    }
    let t = env_threads().unwrap_or_else(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    });
    // First resolver wins; a racing thread reads the same env either way.
    let _ = GLOBAL_THREADS.compare_exchange(0, t, Ordering::Relaxed, Ordering::Relaxed);
    GLOBAL_THREADS.load(Ordering::Relaxed)
}

/// Override the process-wide thread count (the `--threads` CLI knob).
pub fn set_global_threads(threads: usize) {
    GLOBAL_THREADS.store(threads.max(1), Ordering::Relaxed);
}

/// The process-wide pool, sized from `--threads` / `DOF_THREADS` /
/// `available_parallelism` (in that precedence).
pub fn global() -> Pool {
    Pool::new(resolve_global_threads())
}

/// A thread-budget view onto the process's persistent worker team: a
/// `Pool::new(t)` region runs on the caller plus at most `t − 1` warm
/// helpers (see [`pool`]).
#[derive(Debug, Clone, Copy)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// A pool of `threads` workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        Self {
            threads: threads.max(1),
        }
    }

    /// Pool sized from the environment (see module docs).
    pub fn from_env() -> Self {
        Self::new(resolve_global_threads())
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(shard_index, range)` for every shard, in parallel, and return
    /// the results **in shard order** (deterministic reduction regardless of
    /// which worker finished first).
    ///
    /// Runs inline when the pool is single-threaded, there is ≤ 1 shard, or
    /// the caller is itself a pool worker (no nested oversubscription).
    /// Parallel regions execute on the **persistent worker team**
    /// ([`pool`]): the caller participates and at most `threads − 1` warm
    /// helpers join — no OS threads are created after the team's one-time
    /// spawn.
    pub fn run_sharded<R, F>(&self, ranges: Vec<Range<usize>>, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
    {
        self.run_sharded_labeled("region", ranges, f)
    }

    /// [`Self::run_sharded`] with a diagnostic region label: a shard panic
    /// re-raised at the region boundary carries
    /// `pool region {label:?} shard {i} (rows {s}..{e}) panicked: {msg}`,
    /// so fault reports at the serving boundary name the failing shard
    /// instead of a bare "worker panicked". The label never affects shard
    /// decomposition or reduction order (determinism contract unchanged).
    pub fn run_sharded_labeled<R, F>(&self, label: &str, ranges: Vec<Range<usize>>, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
    {
        let n = ranges.len();
        if self.threads == 1 || n <= 1 || in_worker() {
            // A 1-thread pool means serial all the way down (no nested GEMM
            // parallelism); a single shard on a wider pool may still use it.
            // Inline shards run unguarded by catch_unwind — the caller IS
            // the worker, so the panic already unwinds with full context on
            // the submitting thread.
            let _guard = (self.threads == 1).then(WorkerGuard::enter);
            return ranges
                .into_iter()
                .enumerate()
                .map(|(i, r)| f(i, r))
                .collect();
        }
        pool::run_region(self.threads, label, ranges, f)
    }

    /// The PR 1 region-scoped implementation of [`Self::run_sharded`]:
    /// spawns fresh scoped threads for this region only. Retained as the
    /// **differential baseline** the pooled runtime is asserted
    /// bit-identical to (`rust/tests/concurrency_stress.rs`); production
    /// paths all go through the persistent team.
    pub fn run_sharded_scoped<R, F>(&self, ranges: Vec<Range<usize>>, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
    {
        let n = ranges.len();
        if self.threads == 1 || n <= 1 || in_worker() {
            let _guard = (self.threads == 1).then(WorkerGuard::enter);
            return ranges
                .into_iter()
                .enumerate()
                .map(|(i, r)| f(i, r))
                .collect();
        }
        let next = AtomicUsize::new(0);
        let workers = self.threads.min(n);
        let mut collected: Vec<(usize, R)> = Vec::with_capacity(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let next = &next;
                    let ranges = &ranges;
                    let f = &f;
                    s.spawn(move || {
                        let _guard = WorkerGuard::enter();
                        let mut out = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= ranges.len() {
                                break;
                            }
                            out.push((i, f(i, ranges[i].clone())));
                        }
                        out
                    })
                })
                .collect();
            for h in handles {
                collected.extend(h.join().expect("pool worker panicked"));
            }
        });
        collected.sort_by_key(|&(i, _)| i);
        collected.into_iter().map(|(_, r)| r).collect()
    }
}

/// Fixed-size row chunks `[0..s), [s..2s), …` covering `0..rows` (last chunk
/// may be short). Chunking depends only on `rows` and `shard_rows`.
pub fn split_rows(rows: usize, shard_rows: usize) -> Vec<Range<usize>> {
    let s = shard_rows.max(1);
    let mut out = Vec::with_capacity(div_ceil(rows, s));
    let mut start = 0;
    while start < rows {
        let end = (start + s).min(rows);
        out.push(start..end);
        start = end;
    }
    out
}

/// Split `0..rows` into at most `parts` contiguous chunks whose boundaries
/// are multiples of `align` (the last chunk takes the remainder). Alignment
/// keeps the 4-row GEMM micro-kernel grouping identical to the serial sweep,
/// which is what makes the row-parallel matmul bit-exact.
pub fn split_rows_aligned(rows: usize, parts: usize, align: usize) -> Vec<Range<usize>> {
    let align = align.max(1);
    let parts = parts.max(1);
    let per = div_ceil(div_ceil(rows, parts), align) * align;
    split_rows(rows, per.max(align))
}

/// `ceil(a / b)` without the 1.73+ `usize::div_ceil` (keeps the MSRV low).
#[allow(unknown_lints, clippy::manual_div_ceil)]
fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_rows_covers_exactly() {
        let rs = split_rows(37, 8);
        assert_eq!(rs.len(), 5);
        assert_eq!(rs[0], 0..8);
        assert_eq!(rs[4], 32..37);
        let total: usize = rs.iter().map(|r| r.len()).sum();
        assert_eq!(total, 37);
    }

    #[test]
    fn split_aligned_boundaries() {
        let rs = split_rows_aligned(100, 8, 4);
        for r in &rs[..rs.len() - 1] {
            assert_eq!(r.start % 4, 0);
            assert_eq!(r.len() % 4, 0);
        }
        assert_eq!(rs.last().unwrap().end, 100);
        assert!(rs.len() <= 8);
    }

    #[test]
    fn run_sharded_order_is_deterministic() {
        let pool = Pool::new(4);
        let ranges = split_rows(100, 7);
        let out = pool.run_sharded(ranges.clone(), |i, r| (i, r.start, r.end));
        for (i, (j, s, e)) in out.iter().enumerate() {
            assert_eq!(i, *j);
            assert_eq!(*s, ranges[i].start);
            assert_eq!(*e, ranges[i].end);
        }
    }

    #[test]
    fn run_sharded_single_thread_matches_parallel() {
        let work = |_, r: Range<usize>| -> u64 { r.map(|x| (x as u64) * (x as u64)).sum() };
        let ranges = split_rows(1000, 13);
        let serial = Pool::new(1).run_sharded(ranges.clone(), work);
        let parallel = Pool::new(8).run_sharded(ranges, work);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn workers_report_in_worker() {
        let pool = Pool::new(2);
        let flags = pool.run_sharded(split_rows(4, 1), |_, _| in_worker());
        assert!(flags.iter().all(|&f| f));
        assert!(!in_worker());
    }
}
