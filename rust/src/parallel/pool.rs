//! Persistent worker pool: OS threads spawned **once per process** and
//! reused across every parallel region.
//!
//! The PR 1 pool spawned scoped threads per region — correct, but each
//! `run_sharded` call paid thread creation, and at serving scale (many
//! small batches per second across several `ModelServer` workers) that
//! spawn cost stops being noise. This module replaces the region-scoped
//! lifecycle with a warm team:
//!
//! * **Workers are spawned lazily, exactly once** — the first parallel
//!   region initializes the team ([`PoolStats::spawn_events`] stays at 1
//!   for the process lifetime; asserted by
//!   `rust/tests/concurrency_stress.rs`) and idle workers park on a
//!   condvar, costing nothing between regions.
//! * **Regions are injected, not spawned.** A region publishes a
//!   type-erased shard-claiming task to the shared queue, wakes workers,
//!   and the *caller participates* as one worker of the team (so a
//!   `threads = t` region runs on the caller plus at most `t − 1`
//!   helpers). Multiple regions from different caller threads (e.g.
//!   several `ModelServer` workers) coexist in the queue.
//! * **Determinism is unchanged.** Shard decomposition still depends only
//!   on the batch size ([`crate::parallel::split_rows`]), workers still
//!   claim shard indices from an atomic counter, and results are still
//!   reduced in shard order — which worker (or how many workers) ran a
//!   shard never affects any reduced quantity. The scoped implementation
//!   is retained as [`crate::parallel::Pool::run_sharded_scoped`], the
//!   differential baseline the stress suite pins the pooled runtime
//!   against, bit for bit.
//!
//! ## Safety of the lifetime erasure
//!
//! Region tasks borrow the caller's stack (the shard ranges, the closure,
//! the result slots), so the queue stores a `*const dyn Fn` with its
//! lifetime transmuted away. Soundness rests on two invariants, both
//! enforced under the queue mutex:
//!
//! 1. a worker registers itself in the region's `inside` count **while
//!    holding the queue lock**, before ever dereferencing the task;
//! 2. the caller **removes the region from the queue under the same lock
//!    and then blocks until `inside == 0`** before returning.
//!
//! Registration and removal are totally ordered by the mutex, so every
//! worker that can reach the task pointer is accounted for in `inside`,
//! and the caller's stack outlives every dereference.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use super::WorkerGuard;

/// Runaway backstop on spawned helper threads (the team also never exceeds
/// [`pool_target_threads`] − 1 helpers; the caller is the remaining
/// thread). Generous on purpose: the team is sized by the machine and the
/// `--threads` knob below, and parked helpers cost only their stacks.
const MAX_HELPERS: usize = 127;

/// Minimum team width the pool provisions for. The equivalence and
/// determinism suites sweep `--threads 1/2/4/8`; provisioning at least 8
/// lanes keeps those sweeps genuinely parallel even on narrow CI hosts
/// (idle helpers park on the condvar and cost nothing).
const MIN_TEAM: usize = 8;

/// Thread count the persistent team is provisioned for (caller + helpers):
/// the machine width, raised to the resolved `--threads` / `DOF_THREADS`
/// knob when the operator explicitly asked for more lanes than cores (the
/// scoped runtime honored any requested count; a serving box pinned to
/// `DOF_THREADS=64` must not silently halve on the warm team).
///
/// The width is **frozen at the first parallel region** (spawn-once is the
/// contract). A later `Pool::new(t)` with `t` above the team width still
/// computes correctly — results never depend on lane count — but runs on
/// fewer lanes than requested; callers that need more lanes than cores
/// must raise [`crate::parallel::set_global_threads`] *before* their first
/// region (the bench grid does exactly this for wide `--threads-grid`
/// cells).
fn pool_target_threads() -> usize {
    let machine = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    machine
        .max(super::global().threads())
        .max(MIN_TEAM)
        .min(MAX_HELPERS + 1)
}

/// One parallel region's shared state, visible to pool workers.
///
/// `task` is the lifetime-erased shard runner: it claims one shard index
/// and executes it, returning `false` once all shards are claimed. The
/// typed half (ranges, closure, result slots) lives on the caller's stack;
/// see the module docs for why the erasure is sound.
struct RegionCore {
    task: *const (dyn Fn() -> bool + Sync + 'static),
    /// Helpers admitted so far (mutated only under the queue lock).
    entered: AtomicUsize,
    /// Helper cap for this region (`pool.threads() − 1`; the caller is the
    /// remaining lane).
    max_helpers: usize,
    /// All shards claimed — new workers skip the region and queue scans
    /// drop it.
    drained: AtomicBool,
    /// Workers currently between registration and deregistration.
    inside: Mutex<usize>,
    /// Signals `inside` reaching zero (the caller's retire wait).
    exited: Condvar,
}

// SAFETY: the raw task pointer is only dereferenced by workers registered
// in `inside` (see module docs); every other field is Sync by construction.
unsafe impl Send for RegionCore {}
unsafe impl Sync for RegionCore {}

/// Shared pool state: the region queue plus lifecycle counters.
struct PoolShared {
    queue: Mutex<Vec<Arc<RegionCore>>>,
    /// Wakes parked workers when a region is enqueued.
    work: Condvar,
    /// Helper threads in the team (fixed after spawn).
    helpers: AtomicUsize,
    /// Times the team was spawned — 1 for the whole process life, the
    /// "zero thread creation after warmup" proof.
    spawn_events: AtomicUsize,
    /// Parallel regions executed (diagnostics).
    regions: AtomicUsize,
}

static SHARED: OnceLock<PoolShared> = OnceLock::new();
static SPAWN: OnceLock<()> = OnceLock::new();

fn shared_pool() -> &'static PoolShared {
    let sh = SHARED.get_or_init(|| PoolShared {
        queue: Mutex::new(Vec::new()),
        work: Condvar::new(),
        helpers: AtomicUsize::new(0),
        spawn_events: AtomicUsize::new(0),
        regions: AtomicUsize::new(0),
    });
    SPAWN.get_or_init(|| {
        let helpers = pool_target_threads() - 1;
        sh.spawn_events.fetch_add(1, Ordering::Relaxed);
        for i in 0..helpers {
            std::thread::Builder::new()
                .name(format!("dof-pool-{i}"))
                .spawn(|| worker_loop(SHARED.get().expect("pool initialized")))
                .expect("failed to spawn pool worker");
            sh.helpers.fetch_add(1, Ordering::Relaxed);
        }
    });
    sh
}

/// Lifecycle counters of the persistent team.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Helper threads alive (0 until the first parallel region).
    pub workers: usize,
    /// Times OS threads were created — stays 1 after warmup.
    pub spawn_events: usize,
    /// Parallel regions executed on the pooled runtime.
    pub regions: usize,
}

/// Current pool lifecycle counters (zeros before the first region).
pub fn stats() -> PoolStats {
    match SHARED.get() {
        Some(sh) => PoolStats {
            workers: sh.helpers.load(Ordering::Relaxed),
            spawn_events: sh.spawn_events.load(Ordering::Relaxed),
            regions: sh.regions.load(Ordering::Relaxed),
        },
        None => PoolStats {
            workers: 0,
            spawn_events: 0,
            regions: 0,
        },
    }
}

/// Force team spawn (benchmark warmup) and return the counters.
pub fn warm() -> PoolStats {
    let _ = shared_pool();
    stats()
}

fn worker_loop(shared: &'static PoolShared) {
    // A pool worker is permanently "in worker" context: nested parallel
    // regions issued from inside shard bodies must stay serial.
    let _guard = WorkerGuard::enter();
    let mut q = shared.queue.lock().expect("pool queue poisoned");
    loop {
        // Drop regions whose shards are all claimed, then look for one
        // still accepting helpers.
        q.retain(|r| !r.drained.load(Ordering::Acquire));
        let mut found = None;
        for r in q.iter() {
            if r.entered.load(Ordering::Relaxed) < r.max_helpers {
                // Register under the queue lock — the ordering guarantee
                // the lifetime erasure rests on (see module docs).
                r.entered.fetch_add(1, Ordering::Relaxed);
                *r.inside.lock().expect("region latch poisoned") += 1;
                found = Some(Arc::clone(r));
                break;
            }
        }
        match found {
            Some(region) => {
                drop(q);
                // SAFETY: registered in `inside` under the queue lock, so
                // the caller cannot return before we deregister below.
                let task = unsafe { &*region.task };
                while task() {}
                region.drained.store(true, Ordering::Release);
                {
                    let mut inside =
                        region.inside.lock().expect("region latch poisoned");
                    *inside -= 1;
                    region.exited.notify_all();
                }
                q = shared.queue.lock().expect("pool queue poisoned");
            }
            None => {
                q = shared.work.wait(q).expect("pool queue poisoned");
            }
        }
    }
}

/// Single-writer result slot (each shard index is claimed by exactly one
/// worker via the region's atomic counter).
struct Slot<R>(std::cell::UnsafeCell<Option<R>>);

// SAFETY: each slot is written at most once, by the unique claimant of its
// shard index; reads happen only after the region's completion latch.
unsafe impl<R: Send> Sync for Slot<R> {}

impl<R> Slot<R> {
    fn new() -> Self {
        Slot(std::cell::UnsafeCell::new(None))
    }

    /// SAFETY: caller must be the unique claimant of this slot's shard.
    unsafe fn put(&self, r: R) {
        *self.0.get() = Some(r);
    }

    fn into_inner(self) -> Option<R> {
        self.0.into_inner()
    }
}

/// Run one parallel region on the persistent team: the caller participates
/// and at most `pool_threads − 1` warm helpers join. Results are returned
/// in shard order. Called by [`crate::parallel::Pool::run_sharded`] after
/// its inline fast paths (`threads == 1`, single shard, nested region).
///
/// A shard panic is **contained** here (the team survives; helper threads
/// return to the condvar) and re-raised on the caller with its context
/// preserved: the region `label`, the shard index, its row range, and the
/// original payload message. The serving tier's `catch_unwind` boundary
/// turns that message into an actionable `EngineFault` report. When
/// several shards panic in one region, the lowest shard index is reported
/// (deterministic regardless of which worker observed its panic first).
pub(crate) fn run_region<R, F>(
    pool_threads: usize,
    label: &str,
    ranges: Vec<Range<usize>>,
    f: F,
) -> Vec<R>
where
    R: Send,
    F: Fn(usize, Range<usize>) -> R + Sync,
{
    let n = ranges.len();
    let shared = shared_pool();
    shared.regions.fetch_add(1, Ordering::Relaxed);

    let next = AtomicUsize::new(0);
    let panicked: Mutex<Option<(usize, String)>> = Mutex::new(None);
    let slots: Vec<Slot<R>> = (0..n).map(|_| Slot::new()).collect();
    let run_one = || -> bool {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            return false;
        }
        let range = ranges[i].clone();
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i, range))) {
            // SAFETY: `i` came from the claim counter, so this worker is
            // the slot's unique writer.
            Ok(r) => unsafe { slots[i].put(r) },
            Err(payload) => {
                let msg = crate::util::panic_message(payload);
                let mut first = panicked
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                if first.as_ref().map_or(true, |(j, _)| i < *j) {
                    *first = Some((i, msg));
                }
            }
        }
        true
    };

    let erased: &(dyn Fn() -> bool + Sync) = &run_one;
    // SAFETY: `&'a dyn Fn` and `*const dyn Fn + 'static` share one fat-
    // pointer layout; the erased pointer is dereferenced only by workers
    // registered in `inside`, and this function blocks until `inside == 0`
    // before `run_one` (and everything it borrows) goes out of scope.
    let task = unsafe {
        std::mem::transmute::<
            &(dyn Fn() -> bool + Sync),
            *const (dyn Fn() -> bool + Sync + 'static),
        >(erased)
    };
    let region = Arc::new(RegionCore {
        task,
        entered: AtomicUsize::new(0),
        max_helpers: pool_threads.saturating_sub(1),
        drained: AtomicBool::new(false),
        inside: Mutex::new(0),
        exited: Condvar::new(),
    });

    if region.max_helpers > 0 && n > 1 {
        let mut q = shared.queue.lock().expect("pool queue poisoned");
        q.push(Arc::clone(&region));
        shared.work.notify_all();
    }

    // The caller is one lane of the team; its shard bodies must suppress
    // nested parallelism exactly like a helper's.
    {
        let _guard = WorkerGuard::enter();
        while run_one() {}
    }
    region.drained.store(true, Ordering::Release);

    // Retire: unpublish the region, then wait out every registered helper.
    {
        let mut q = shared.queue.lock().expect("pool queue poisoned");
        if let Some(pos) = q.iter().position(|r| Arc::ptr_eq(r, &region)) {
            q.remove(pos);
        }
    }
    {
        let mut inside = region.inside.lock().expect("region latch poisoned");
        while *inside != 0 {
            inside = region.exited.wait(inside).expect("region latch poisoned");
        }
    }

    let first_panic = panicked
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
        .take();
    if let Some((i, msg)) = first_panic {
        let r = &ranges[i];
        panic!(
            "pool region {label:?} shard {i} (rows {}..{}) panicked: {msg}",
            r.start, r.end
        );
    }
    slots
        .into_iter()
        .map(|s| s.into_inner().expect("pool shard executed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::{split_rows, Pool};

    #[test]
    fn region_results_in_shard_order() {
        let ranges = split_rows(100, 7);
        let out = run_region(4, "test-region", ranges.clone(), |i, r| (i, r.start, r.end));
        for (i, (j, s, e)) in out.iter().enumerate() {
            assert_eq!(i, *j);
            assert_eq!(*s, ranges[i].start);
            assert_eq!(*e, ranges[i].end);
        }
    }

    #[test]
    fn spawns_once_across_many_regions() {
        let work = |_: usize, r: Range<usize>| -> u64 {
            r.map(|x| (x as u64).wrapping_mul(x as u64)).sum()
        };
        let first = Pool::new(4).run_sharded(split_rows(200, 8), work);
        let s0 = stats();
        assert_eq!(s0.spawn_events, 1, "first region spawns the team");
        assert!(s0.workers >= 1);
        for threads in [2usize, 4, 8, 3] {
            let again = Pool::new(threads).run_sharded(split_rows(200, 8), work);
            assert_eq!(again, first);
        }
        let s1 = stats();
        assert_eq!(s1.spawn_events, 1, "no thread creation after warmup");
        assert_eq!(s1.workers, s0.workers);
        assert!(s1.regions > s0.regions);
    }

    #[test]
    fn pooled_matches_scoped_baseline() {
        let work = |i: usize, r: Range<usize>| -> f64 {
            // Order-sensitive float accumulation: catches any reduction
            // reorder between the pooled and scoped paths.
            let mut acc = i as f64;
            for x in r {
                acc += (x as f64) * 1.0000001 + acc * 1e-7;
            }
            acc
        };
        let ranges = split_rows(173, 8);
        for threads in [2usize, 4, 8] {
            let pool = Pool::new(threads);
            let pooled = pool.run_sharded(ranges.clone(), work);
            let scoped = pool.run_sharded_scoped(ranges.clone(), work);
            assert_eq!(pooled, scoped, "threads={threads}");
        }
    }

    #[test]
    fn concurrent_regions_from_many_callers() {
        let joins: Vec<_> = (0..6)
            .map(|c| {
                std::thread::spawn(move || {
                    let work = move |i: usize, r: Range<usize>| -> u64 {
                        r.map(|x| (x as u64) ^ (c as u64) ^ (i as u64)).sum()
                    };
                    let ranges = split_rows(90 + c, 5);
                    let serial = Pool::new(1).run_sharded(ranges.clone(), work);
                    let pooled = Pool::new(4).run_sharded(ranges, work);
                    assert_eq!(serial, pooled, "caller {c}");
                })
            })
            .collect();
        for j in joins {
            j.join().expect("caller thread panicked");
        }
    }

    #[test]
    #[should_panic(expected = "shard 3 (rows 12..16) panicked: shard exploded")]
    fn shard_panic_propagates() {
        let ranges = split_rows(40, 4);
        let _ = Pool::new(4).run_sharded(ranges, |i, _| {
            if i == 3 {
                panic!("shard exploded");
            }
            i
        });
    }

    #[test]
    fn labeled_region_panic_reports_context() {
        // The serving tier catches this payload and turns it into an
        // `EngineFault` — label + shard + row range must survive the trip.
        let ranges = split_rows(24, 8);
        let caught = std::panic::catch_unwind(|| {
            Pool::new(4).run_sharded_labeled("serve-batch", ranges, |i, _| {
                if i == 2 {
                    panic!("tanh overflow at row 17");
                }
                i
            })
        })
        .expect_err("region must re-raise the shard panic");
        let msg = crate::util::panic_message(caught);
        assert!(
            msg.contains("pool region \"serve-batch\" shard 2 (rows 16..24)"),
            "missing context: {msg}"
        );
        assert!(msg.contains("tanh overflow at row 17"), "missing payload: {msg}");
    }

    #[test]
    fn lowest_panicking_shard_wins() {
        // Two shards panic; the report must deterministically name the
        // lower index no matter which worker's panic landed first.
        for _ in 0..8 {
            let caught = std::panic::catch_unwind(|| {
                Pool::new(4).run_sharded(split_rows(64, 4), |i, _| {
                    if i == 5 || i == 11 {
                        panic!("boom {i}");
                    }
                    i
                })
            })
            .expect_err("region must re-raise");
            let msg = crate::util::panic_message(caught);
            assert!(msg.contains("shard 5"), "expected shard 5, got: {msg}");
        }
    }
}
