//! PDE library: second-order problems with manufactured solutions, DOF-based
//! residuals, and a PINN trainer that differentiates *through* the operator.
//!
//! Every problem is posed as `L[u](z) = f(z)` on a box, with `L` a constant-
//! coefficient second-order operator (`A`, `b`, `c`) and `f` manufactured
//! from a closed-form exact solution `u*`: `f := L[u*]`. Closed-form
//! gradients/Hessians of `u*` make `f` exact to machine precision, so PINN
//! training error measures the solver, not the data.

pub mod problems;
pub mod trainer;

pub use problems::{
    biharmonic_plate, fokker_planck, heat_equation, klein_gordon, poisson, swift_hohenberg,
    HigherOrderProblem,
};
pub use trainer::{PinnTrainer, TrainReport};

use crate::operators::Operator;
use crate::tensor::Tensor;
use crate::train::BoxSampler;

/// Closed-form exact solutions with value / gradient / Hessian.
#[derive(Debug, Clone)]
pub enum ExactSolution {
    /// `u(z) = amp · sin(w·z + phase)`.
    SineWave {
        w: Vec<f64>,
        phase: f64,
        amp: f64,
    },
    /// `u(z) = exp(−|z − c|² / (2σ²))`.
    Gaussian { center: Vec<f64>, sigma: f64 },
    /// Sum of sine waves (richer spectrum).
    SumOfSines(Vec<(Vec<f64>, f64, f64)>),
}

impl ExactSolution {
    pub fn dim(&self) -> usize {
        match self {
            ExactSolution::SineWave { w, .. } => w.len(),
            ExactSolution::Gaussian { center, .. } => center.len(),
            ExactSolution::SumOfSines(terms) => terms[0].0.len(),
        }
    }

    /// `u*(z)`.
    pub fn value(&self, z: &[f64]) -> f64 {
        match self {
            ExactSolution::SineWave { w, phase, amp } => {
                let arg: f64 = w.iter().zip(z).map(|(&a, &b)| a * b).sum::<f64>() + phase;
                amp * arg.sin()
            }
            ExactSolution::Gaussian { center, sigma } => {
                let d2: f64 = center
                    .iter()
                    .zip(z)
                    .map(|(&c, &x)| (x - c) * (x - c))
                    .sum();
                (-d2 / (2.0 * sigma * sigma)).exp()
            }
            ExactSolution::SumOfSines(terms) => terms
                .iter()
                .map(|(w, phase, amp)| {
                    let arg: f64 =
                        w.iter().zip(z).map(|(&a, &b)| a * b).sum::<f64>() + phase;
                    amp * arg.sin()
                })
                .sum(),
        }
    }

    /// `∇u*(z)`.
    pub fn gradient(&self, z: &[f64]) -> Vec<f64> {
        match self {
            ExactSolution::SineWave { w, phase, amp } => {
                let arg: f64 = w.iter().zip(z).map(|(&a, &b)| a * b).sum::<f64>() + phase;
                let c = amp * arg.cos();
                w.iter().map(|&wi| c * wi).collect()
            }
            ExactSolution::Gaussian { center, sigma } => {
                let u = self.value(z);
                let s2 = sigma * sigma;
                center
                    .iter()
                    .zip(z)
                    .map(|(&c, &x)| -u * (x - c) / s2)
                    .collect()
            }
            ExactSolution::SumOfSines(terms) => {
                let n = self.dim();
                let mut g = vec![0.0; n];
                for (w, phase, amp) in terms {
                    let arg: f64 =
                        w.iter().zip(z).map(|(&a, &b)| a * b).sum::<f64>() + phase;
                    let c = amp * arg.cos();
                    for (gi, &wi) in g.iter_mut().zip(w) {
                        *gi += c * wi;
                    }
                }
                g
            }
        }
    }

    /// Arbitrary mixed partial `∂^{|axes|} u* / ∂z_axes` — needed by the
    /// manufactured sources of the higher-order (jet) problems. Closed
    /// forms exist for the sine-family solutions (the m-th derivative of
    /// `sin` cycles through `sin, cos, −sin, −cos`); the Gaussian supports
    /// orders ≤ 2 via [`Self::gradient`]/[`Self::hessian`] and panics
    /// above (higher-order problems ship with sine solutions).
    pub fn partial(&self, axes: &[usize], z: &[f64]) -> f64 {
        let m = axes.len();
        if m == 0 {
            return self.value(z);
        }
        fn sine_partial(w: &[f64], phase: f64, amp: f64, axes: &[usize], z: &[f64]) -> f64 {
            let arg: f64 = w.iter().zip(z).map(|(&a, &b)| a * b).sum::<f64>() + phase;
            let m = axes.len();
            // d^m/darg^m sin(arg), cycling with period 4.
            let trig = match m % 4 {
                0 => arg.sin(),
                1 => arg.cos(),
                2 => -arg.sin(),
                _ => -arg.cos(),
            };
            let wprod: f64 = axes.iter().map(|&a| w[a]).product();
            amp * wprod * trig
        }
        match self {
            ExactSolution::SineWave { w, phase, amp } => {
                sine_partial(w, *phase, *amp, axes, z)
            }
            ExactSolution::SumOfSines(terms) => terms
                .iter()
                .map(|(w, phase, amp)| sine_partial(w, *phase, *amp, axes, z))
                .sum(),
            ExactSolution::Gaussian { .. } => match m {
                1 => self.gradient(z)[axes[0]],
                2 => self.hessian(z)[axes[0] * self.dim() + axes[1]],
                _ => panic!(
                    "Gaussian exact solutions support derivatives up to order 2; \
                     use a sine-family solution for order-{m} problems"
                ),
            },
        }
    }

    /// `∇²u*(z)` as a flat row-major `n×n`.
    pub fn hessian(&self, z: &[f64]) -> Vec<f64> {
        let n = self.dim();
        match self {
            ExactSolution::SineWave { w, phase, amp } => {
                let arg: f64 = w.iter().zip(z).map(|(&a, &b)| a * b).sum::<f64>() + phase;
                let s = -amp * arg.sin();
                let mut h = vec![0.0; n * n];
                for i in 0..n {
                    for j in 0..n {
                        h[i * n + j] = s * w[i] * w[j];
                    }
                }
                h
            }
            ExactSolution::Gaussian { center, sigma } => {
                let u = self.value(z);
                let s2 = sigma * sigma;
                let d: Vec<f64> = z.iter().zip(center).map(|(&x, &c)| x - c).collect();
                let mut h = vec![0.0; n * n];
                for i in 0..n {
                    for j in 0..n {
                        let mut v = u * d[i] * d[j] / (s2 * s2);
                        if i == j {
                            v -= u / s2;
                        }
                        h[i * n + j] = v;
                    }
                }
                h
            }
            ExactSolution::SumOfSines(terms) => {
                let mut h = vec![0.0; n * n];
                for (w, phase, amp) in terms {
                    let arg: f64 =
                        w.iter().zip(z).map(|(&a, &b)| a * b).sum::<f64>() + phase;
                    let s = -amp * arg.sin();
                    for i in 0..n {
                        for j in 0..n {
                            h[i * n + j] += s * w[i] * w[j];
                        }
                    }
                }
                h
            }
        }
    }
}

/// Evaluate a pointwise scalar function over the rows of `z`, returning
/// `[batch, 1]` — the shared body of every `source_batch`/`exact_batch`
/// (second-order and higher-order problems alike).
pub(crate) fn batch_column(z: &Tensor, f: impl Fn(&[f64]) -> f64) -> Tensor {
    let batch = z.dims()[0];
    let mut out = Tensor::zeros(&[batch, 1]);
    for b in 0..batch {
        out.set(b, 0, f(z.row(b)));
    }
    out
}

/// A PDE problem `L[u] = f` on a box, with manufactured `f = L[u*]`.
pub struct PdeProblem {
    pub name: String,
    pub operator: Operator,
    pub exact: ExactSolution,
    pub domain: BoxSampler,
}

impl PdeProblem {
    /// Exact source term `f(z) = L[u*](z)` from the closed forms.
    pub fn source(&self, z: &[f64]) -> f64 {
        let n = self.operator.n();
        let h = self.exact.hessian(z);
        let a = self.operator.a.data();
        let mut val = 0.0;
        for idx in 0..n * n {
            val += a[idx] * h[idx];
        }
        if let Some(ref b) = self.operator.b {
            let g = self.exact.gradient(z);
            val += b.iter().zip(&g).map(|(&bi, &gi)| bi * gi).sum::<f64>();
        }
        if let Some(c) = self.operator.c {
            val += c * self.exact.value(z);
        }
        val
    }

    /// Batched source, `[batch, 1]`.
    pub fn source_batch(&self, z: &Tensor) -> Tensor {
        batch_column(z, |row| self.source(row))
    }

    /// Exact solution values, `[batch, 1]`.
    pub fn exact_batch(&self, z: &Tensor) -> Tensor {
        batch_column(z, |row| self.exact.value(row))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::CoeffSpec;

    fn fd_check_solution(sol: &ExactSolution, z: &[f64]) {
        let n = sol.dim();
        let h = 1e-5;
        let g = sol.gradient(z);
        let hess = sol.hessian(z);
        for i in 0..n {
            let mut zp = z.to_vec();
            let mut zm = z.to_vec();
            zp[i] += h;
            zm[i] -= h;
            let fd = (sol.value(&zp) - sol.value(&zm)) / (2.0 * h);
            assert!((g[i] - fd).abs() < 1e-7, "grad[{i}]: {} vs {fd}", g[i]);
            for j in 0..n {
                let gp = sol.gradient(&zp)[j];
                let gm = sol.gradient(&zm)[j];
                let fd2 = (gp - gm) / (2.0 * h);
                assert!(
                    (hess[i * n + j] - fd2).abs() < 1e-6,
                    "hess[{i}][{j}]: {} vs {fd2}",
                    hess[i * n + j]
                );
            }
        }
    }

    #[test]
    fn sine_wave_derivatives() {
        let sol = ExactSolution::SineWave {
            w: vec![1.5, -0.7, 2.0],
            phase: 0.3,
            amp: 1.2,
        };
        fd_check_solution(&sol, &[0.2, -0.4, 0.9]);
    }

    #[test]
    fn gaussian_derivatives() {
        let sol = ExactSolution::Gaussian {
            center: vec![0.5, 0.5],
            sigma: 0.8,
        };
        fd_check_solution(&sol, &[0.1, 0.9]);
    }

    #[test]
    fn partial_matches_gradient_hessian_and_cycles() {
        let sol = ExactSolution::SineWave {
            w: vec![1.5, -0.7, 2.0],
            phase: 0.3,
            amp: 1.2,
        };
        let z = [0.2, -0.4, 0.9];
        let g = sol.gradient(&z);
        let h = sol.hessian(&z);
        for i in 0..3 {
            assert!((sol.partial(&[i], &z) - g[i]).abs() < 1e-14);
            for j in 0..3 {
                assert!((sol.partial(&[i, j], &z) - h[i * 3 + j]).abs() < 1e-14);
            }
        }
        // 4th derivative of sin is sin: ∂⁴ along one axis scales by w⁴.
        let p4 = sol.partial(&[0, 0, 0, 0], &z);
        let w0 = 1.5f64;
        assert!((p4 - w0.powi(4) * sol.value(&z)).abs() < 1e-12);
    }

    #[test]
    fn sum_of_sines_derivatives() {
        let sol = ExactSolution::SumOfSines(vec![
            (vec![1.0, 2.0], 0.0, 1.0),
            (vec![-0.5, 1.5], 1.0, 0.3),
        ]);
        fd_check_solution(&sol, &[0.3, 0.6]);
    }

    #[test]
    fn manufactured_source_consistency() {
        // f = L[u*] must satisfy: DOF on a graph that *is* u* would return
        // f. We verify via the operator contraction against the Hessian
        // engine's ground truth using a random A.
        let sol = ExactSolution::SineWave {
            w: vec![2.0, 1.0, -1.0],
            phase: 0.5,
            amp: 0.9,
        };
        let op = Operator::from_spec(CoeffSpec::EllipticGram { n: 3, rank: 3, seed: 3 })
            .with_lower_order(Some(vec![0.5, -1.0, 0.2]), Some(1.5));
        let prob = PdeProblem {
            name: "test".into(),
            operator: op,
            exact: sol,
            domain: BoxSampler::unit(3),
        };
        let z = [0.1, 0.7, 0.4];
        // Manual: Σ a_ij H_ij + b·g + c·u.
        let hess = prob.exact.hessian(&z);
        let grad = prob.exact.gradient(&z);
        let mut expect = 0.0;
        for i in 0..3 {
            for j in 0..3 {
                expect += prob.operator.a.at(i, j) * hess[i * 3 + j];
            }
        }
        for i in 0..3 {
            expect += prob.operator.b.as_ref().unwrap()[i] * grad[i];
        }
        expect += prob.operator.c.unwrap() * prob.exact.value(&z);
        assert!((prob.source(&z) - expect).abs() < 1e-12);
    }
}
