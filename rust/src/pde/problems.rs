//! Concrete PDE problems. Coordinates are `z = (x_1 … x_d, t)` for
//! evolution equations (time last), matching the paper's convention that
//! "the time variable is comprised in x".

use super::{ExactSolution, PdeProblem};
use crate::operators::{HigherOrderOperator, HigherOrderSpec, Operator};
use crate::tensor::{matmul, Tensor};
use crate::train::BoxSampler;
use crate::util::Xoshiro256;

/// Poisson equation `Δu = f` on `[0,1]^d` — elliptic, `A = I`.
///
/// DOF reduces exactly to Forward Laplacian here (§2.2 "Elliptic
/// Operator").
pub fn poisson(d: usize) -> PdeProblem {
    let a = Tensor::eye(d);
    let w: Vec<f64> = (0..d)
        .map(|i| std::f64::consts::PI * (1.0 + (i % 3) as f64))
        .collect();
    PdeProblem {
        name: format!("poisson-{d}d"),
        operator: Operator::from_matrix(a, "laplacian"),
        exact: ExactSolution::SineWave {
            w,
            phase: 0.25,
            amp: 1.0,
        },
        domain: BoxSampler::unit(d),
    }
}

/// Non-homogeneous heat equation `u_t = Δ_x u + q(x,t)` on `[0,1]^d ×
/// [0,1]`, rewritten as `L[u] = f` with `L = Δ_x − ∂_t`:
/// `A = diag(1,…,1,0)` (rank d of d+1 — a *naturally low-rank* operator,
/// §2.2), `b = (0,…,0,−1)`.
pub fn heat_equation(d: usize) -> PdeProblem {
    let n = d + 1;
    let mut a = Tensor::zeros(&[n, n]);
    for i in 0..d {
        a.set(i, i, 1.0);
    }
    let mut b = vec![0.0; n];
    b[d] = -1.0;
    let mut w: Vec<f64> = (0..d).map(|_| std::f64::consts::PI).collect();
    w.push(1.0); // temporal frequency
    PdeProblem {
        name: format!("heat-{d}d"),
        operator: Operator::from_matrix(a, "heat").with_lower_order(Some(b), None),
        exact: ExactSolution::SineWave {
            w,
            phase: 0.4,
            amp: 1.0,
        },
        domain: BoxSampler::unit(n),
    }
}

/// Klein–Gordon equation `u_tt − Δ_x u + m² u = f` on `[0,1]^d × [0,1]`:
/// `A = diag(−1,…,−1, +1)` (time last) — a *genuinely indefinite* operator,
/// the paper's "general" class — and `c = m²`.
pub fn klein_gordon(d: usize, mass: f64) -> PdeProblem {
    let n = d + 1;
    let mut a = Tensor::zeros(&[n, n]);
    for i in 0..d {
        a.set(i, i, -1.0);
    }
    a.set(d, d, 1.0);
    let mut w: Vec<f64> = (0..d).map(|_| std::f64::consts::PI).collect();
    w.push(2.0);
    PdeProblem {
        name: format!("klein-gordon-{d}d"),
        operator: Operator::from_matrix(a, "klein-gordon")
            .with_lower_order(None, Some(mass * mass)),
        exact: ExactSolution::SineWave {
            w,
            phase: 0.1,
            amp: 1.0,
        },
        domain: BoxSampler::unit(n),
    }
}

/// Stationary Fokker–Planck-type operator `Σ D_ij ∂²_ij p + Σ b_i ∂_i p`
/// with an anisotropic PSD diffusion matrix `D = M Mᵀ` — exercises a dense
/// non-identity `A` (the case generic Forward-Laplacian packages cannot
/// handle and DOF exists for).
pub fn fokker_planck(d: usize, seed: u64) -> PdeProblem {
    let mut rng = Xoshiro256::new(seed);
    let m = Tensor::randn(&[d, d], &mut rng).scale(1.0 / (d as f64).sqrt());
    let diff = matmul(&m, &m.transpose());
    // Drift towards the center.
    let b: Vec<f64> = (0..d).map(|_| -0.5).collect();
    PdeProblem {
        name: format!("fokker-planck-{d}d"),
        operator: Operator::from_matrix(diff, "fokker-planck")
            .with_lower_order(Some(b), None),
        exact: ExactSolution::Gaussian {
            center: vec![0.5; d],
            sigma: 0.6,
        },
        domain: BoxSampler::unit(d),
    }
}

// ---- higher-order (jet) problems -----------------------------------------

/// A PDE problem `L[u] = f` whose operator is third/fourth order —
/// evaluated by the jet subsystem instead of the second-order engines.
/// The source is manufactured from the closed-form exact solution via
/// [`ExactSolution::partial`], so it is exact to machine precision.
pub struct HigherOrderProblem {
    pub name: String,
    pub operator: HigherOrderOperator,
    pub exact: ExactSolution,
    pub domain: BoxSampler,
}

impl HigherOrderProblem {
    /// Exact source term `f(z) = L[u*](z)` from the closed forms.
    pub fn source(&self, z: &[f64]) -> f64 {
        let mut val = 0.0;
        for term in &self.operator.terms {
            val += term.coef * self.exact.partial(&term.axes, z);
        }
        if let Some(ref b) = self.operator.b {
            let g = self.exact.gradient(z);
            val += b.iter().zip(&g).map(|(&bi, &gi)| bi * gi).sum::<f64>();
        }
        if let Some(c) = self.operator.c {
            val += c * self.exact.value(z);
        }
        val
    }

    /// Batched source, `[batch, 1]`.
    pub fn source_batch(&self, z: &Tensor) -> Tensor {
        super::batch_column(z, |row| self.source(row))
    }

    /// Exact solution values, `[batch, 1]`.
    pub fn exact_batch(&self, z: &Tensor) -> Tensor {
        super::batch_column(z, |row| self.exact.value(row))
    }
}

/// Biharmonic plate equation `Δ²u = f` on `[0,1]^d` — the canonical
/// fourth-order elliptic problem (Kirchhoff–Love plate bending). The jet
/// basis needs exactly `d²` directions; for the manufactured sine solution
/// `Δ²u* = |w|⁴·u*`.
pub fn biharmonic_plate(d: usize) -> HigherOrderProblem {
    let w: Vec<f64> = (0..d)
        .map(|i| std::f64::consts::PI * (1.0 + (i % 2) as f64 * 0.5))
        .collect();
    HigherOrderProblem {
        name: format!("biharmonic-plate-{d}d"),
        operator: HigherOrderOperator::from_spec(HigherOrderSpec::Biharmonic { d }),
        exact: ExactSolution::SineWave {
            w,
            phase: 0.35,
            amp: 1.0,
        },
        domain: BoxSampler::unit(d),
    }
}

/// Stationary Swift–Hohenberg linearization
/// `(r − (1+Δ)²)u = −Δ²u − 2Δu + (r−1)u = f` on `[0,1]^d` — fourth order
/// with a second-order tail and a constant term, the linear pattern-forming
/// operator.
pub fn swift_hohenberg(d: usize, r: f64) -> HigherOrderProblem {
    let w: Vec<f64> = (0..d)
        .map(|i| std::f64::consts::PI * (1.0 + (i % 3) as f64 * 0.25))
        .collect();
    HigherOrderProblem {
        name: format!("swift-hohenberg-{d}d"),
        operator: HigherOrderOperator::from_spec(HigherOrderSpec::SwiftHohenberg { d, r }),
        exact: ExactSolution::SineWave {
            w,
            phase: 0.15,
            amp: 0.8,
        },
        domain: BoxSampler::unit(d),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heat_operator_is_low_rank() {
        let p = heat_equation(3);
        assert_eq!(p.operator.n(), 4);
        assert_eq!(p.operator.rank(), 3, "heat A has rank d");
        assert!(p.operator.ldl.is_elliptic());
    }

    #[test]
    fn klein_gordon_is_indefinite() {
        let p = klein_gordon(2, 1.0);
        assert_eq!(p.operator.rank(), 3);
        assert!(!p.operator.ldl.is_elliptic());
        // one positive (time), two negative (space) directions
        assert_eq!(p.operator.ldl.positive_directions(), 1);
    }

    #[test]
    fn poisson_elliptic_identity() {
        let p = poisson(4);
        assert!(p.operator.ldl.is_elliptic());
        assert_eq!(p.operator.rank(), 4);
    }

    #[test]
    fn fokker_planck_dense_psd() {
        let p = fokker_planck(5, 7);
        assert!(p.operator.ldl.is_elliptic());
        assert_eq!(p.operator.rank(), 5);
        // Dense: off-diagonal entries present.
        let mut off = 0.0;
        for i in 0..5 {
            for j in 0..5 {
                if i != j {
                    off += p.operator.a.at(i, j).abs();
                }
            }
        }
        assert!(off > 1e-3, "diffusion matrix should be anisotropic");
    }

    #[test]
    fn biharmonic_source_is_w4_times_u() {
        // Δ²(sin(w·z + φ)) = |w|⁴·sin(w·z + φ) exactly.
        let p = biharmonic_plate(3);
        let z = [0.2, 0.7, 0.4];
        let w = match &p.exact {
            ExactSolution::SineWave { w, .. } => w.clone(),
            _ => unreachable!(),
        };
        let w2: f64 = w.iter().map(|v| v * v).sum();
        let want = w2 * w2 * p.exact.value(&z);
        assert!(
            (p.source(&z) - want).abs() < 1e-9 * want.abs().max(1.0),
            "{} vs {want}",
            p.source(&z)
        );
        assert_eq!(p.operator.order(), 4);
        assert_eq!(p.operator.directions(), 9);
    }

    #[test]
    fn swift_hohenberg_source_matches_symbol() {
        // On sin(w·z+φ): L = −|w|⁴ + 2|w|² + (r−1) times u*.
        let r = 0.25;
        let p = swift_hohenberg(2, r);
        let z = [0.6, 0.3];
        let w = match &p.exact {
            ExactSolution::SineWave { w, .. } => w.clone(),
            _ => unreachable!(),
        };
        let w2: f64 = w.iter().map(|v| v * v).sum();
        let want = (-w2 * w2 + 2.0 * w2 + (r - 1.0)) * p.exact.value(&z);
        assert!(
            (p.source(&z) - want).abs() < 1e-9 * want.abs().max(1.0),
            "{} vs {want}",
            p.source(&z)
        );
    }

    #[test]
    fn heat_source_satisfies_pde() {
        // For the manufactured u*, check f = Δu* − u*_t pointwise.
        let p = heat_equation(2);
        let z = [0.3, 0.6, 0.2];
        let hess = p.exact.hessian(&z);
        let grad = p.exact.gradient(&z);
        let expect = hess[0] + hess[4] - grad[2]; // Δ_x − ∂_t (n = 3)
        assert!((p.source(&z) - expect).abs() < 1e-12);
    }
}
