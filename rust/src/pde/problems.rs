//! Concrete PDE problems. Coordinates are `z = (x_1 … x_d, t)` for
//! evolution equations (time last), matching the paper's convention that
//! "the time variable is comprised in x".

use super::{ExactSolution, PdeProblem};
use crate::operators::Operator;
use crate::tensor::{matmul, Tensor};
use crate::train::BoxSampler;
use crate::util::Xoshiro256;

/// Poisson equation `Δu = f` on `[0,1]^d` — elliptic, `A = I`.
///
/// DOF reduces exactly to Forward Laplacian here (§2.2 "Elliptic
/// Operator").
pub fn poisson(d: usize) -> PdeProblem {
    let a = Tensor::eye(d);
    let w: Vec<f64> = (0..d)
        .map(|i| std::f64::consts::PI * (1.0 + (i % 3) as f64))
        .collect();
    PdeProblem {
        name: format!("poisson-{d}d"),
        operator: Operator::from_matrix(a, "laplacian"),
        exact: ExactSolution::SineWave {
            w,
            phase: 0.25,
            amp: 1.0,
        },
        domain: BoxSampler::unit(d),
    }
}

/// Non-homogeneous heat equation `u_t = Δ_x u + q(x,t)` on `[0,1]^d ×
/// [0,1]`, rewritten as `L[u] = f` with `L = Δ_x − ∂_t`:
/// `A = diag(1,…,1,0)` (rank d of d+1 — a *naturally low-rank* operator,
/// §2.2), `b = (0,…,0,−1)`.
pub fn heat_equation(d: usize) -> PdeProblem {
    let n = d + 1;
    let mut a = Tensor::zeros(&[n, n]);
    for i in 0..d {
        a.set(i, i, 1.0);
    }
    let mut b = vec![0.0; n];
    b[d] = -1.0;
    let mut w: Vec<f64> = (0..d).map(|_| std::f64::consts::PI).collect();
    w.push(1.0); // temporal frequency
    PdeProblem {
        name: format!("heat-{d}d"),
        operator: Operator::from_matrix(a, "heat").with_lower_order(Some(b), None),
        exact: ExactSolution::SineWave {
            w,
            phase: 0.4,
            amp: 1.0,
        },
        domain: BoxSampler::unit(n),
    }
}

/// Klein–Gordon equation `u_tt − Δ_x u + m² u = f` on `[0,1]^d × [0,1]`:
/// `A = diag(−1,…,−1, +1)` (time last) — a *genuinely indefinite* operator,
/// the paper's "general" class — and `c = m²`.
pub fn klein_gordon(d: usize, mass: f64) -> PdeProblem {
    let n = d + 1;
    let mut a = Tensor::zeros(&[n, n]);
    for i in 0..d {
        a.set(i, i, -1.0);
    }
    a.set(d, d, 1.0);
    let mut w: Vec<f64> = (0..d).map(|_| std::f64::consts::PI).collect();
    w.push(2.0);
    PdeProblem {
        name: format!("klein-gordon-{d}d"),
        operator: Operator::from_matrix(a, "klein-gordon")
            .with_lower_order(None, Some(mass * mass)),
        exact: ExactSolution::SineWave {
            w,
            phase: 0.1,
            amp: 1.0,
        },
        domain: BoxSampler::unit(n),
    }
}

/// Stationary Fokker–Planck-type operator `Σ D_ij ∂²_ij p + Σ b_i ∂_i p`
/// with an anisotropic PSD diffusion matrix `D = M Mᵀ` — exercises a dense
/// non-identity `A` (the case generic Forward-Laplacian packages cannot
/// handle and DOF exists for).
pub fn fokker_planck(d: usize, seed: u64) -> PdeProblem {
    let mut rng = Xoshiro256::new(seed);
    let m = Tensor::randn(&[d, d], &mut rng).scale(1.0 / (d as f64).sqrt());
    let diff = matmul(&m, &m.transpose());
    // Drift towards the center.
    let b: Vec<f64> = (0..d).map(|_| -0.5).collect();
    PdeProblem {
        name: format!("fokker-planck-{d}d"),
        operator: Operator::from_matrix(diff, "fokker-planck")
            .with_lower_order(Some(b), None),
        exact: ExactSolution::Gaussian {
            center: vec![0.5; d],
            sigma: 0.6,
        },
        domain: BoxSampler::unit(d),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heat_operator_is_low_rank() {
        let p = heat_equation(3);
        assert_eq!(p.operator.n(), 4);
        assert_eq!(p.operator.rank(), 3, "heat A has rank d");
        assert!(p.operator.ldl.is_elliptic());
    }

    #[test]
    fn klein_gordon_is_indefinite() {
        let p = klein_gordon(2, 1.0);
        assert_eq!(p.operator.rank(), 3);
        assert!(!p.operator.ldl.is_elliptic());
        // one positive (time), two negative (space) directions
        assert_eq!(p.operator.ldl.positive_directions(), 1);
    }

    #[test]
    fn poisson_elliptic_identity() {
        let p = poisson(4);
        assert!(p.operator.ldl.is_elliptic());
        assert_eq!(p.operator.rank(), 4);
    }

    #[test]
    fn fokker_planck_dense_psd() {
        let p = fokker_planck(5, 7);
        assert!(p.operator.ldl.is_elliptic());
        assert_eq!(p.operator.rank(), 5);
        // Dense: off-diagonal entries present.
        let mut off = 0.0;
        for i in 0..5 {
            for j in 0..5 {
                if i != j {
                    off += p.operator.a.at(i, j).abs();
                }
            }
        }
        assert!(off > 1e-3, "diffusion matrix should be anisotropic");
    }

    #[test]
    fn heat_source_satisfies_pde() {
        // For the manufactured u*, check f = Δu* − u*_t pointwise.
        let p = heat_equation(2);
        let z = [0.3, 0.6, 0.2];
        let hess = p.exact.hessian(&z);
        let grad = p.exact.gradient(&z);
        let expect = hess[0] + hess[4] - grad[2]; // Δ_x − ∂_t (n = 3)
        assert!((p.source(&z) - expect).abs() < 1e-12);
    }
}
