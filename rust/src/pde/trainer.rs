//! PINN trainer: minimizes the DOF-residual loss
//!
//! ```text
//! ℓ(θ) = 1/B Σ_b (L[φ_θ](z_b) − f(z_b))²  +  λ/B' Σ_b' (φ_θ(z_b') − u*(z_b'))²
//! ```
//!
//! Interior gradients flow *through the DOF operator* via
//! [`crate::autodiff::dof_tape`]; boundary gradients via the plain reverse
//! pass. This is the end-to-end workload that proves the three pieces
//! (graph engine, DOF, optimizer) compose.
//!
//! The tape's forward pass runs a compiled
//! [`crate::plan::OperatorProgram`] fetched from the keyed global plan
//! cache. Plan keys are weight-value independent, so although each step
//! rebuilds the graph with updated weights, the program is compiled once
//! on step 1 and every later step is a cache hit — compile once, execute
//! per batch ([`PinnTrainer::plan_stats`] exposes the counters).

use crate::autodiff::backward::backward;
use crate::autodiff::dof_tape::{dof_backward_tape, dof_forward_tape};
use crate::nn::Mlp;
use crate::plan;
use crate::tensor::Tensor;
use crate::train::{Adam, AdamConfig, BoundarySampler, BoxSampler};
use crate::util::Xoshiro256;

use super::PdeProblem;

/// One training step's scalars.
#[derive(Debug, Clone, Copy)]
pub struct TrainReport {
    pub step: usize,
    pub residual_loss: f64,
    pub boundary_loss: f64,
    pub total_loss: f64,
}

/// PINN trainer configuration.
#[derive(Debug, Clone, Copy)]
pub struct PinnConfig {
    pub interior_batch: usize,
    pub boundary_batch: usize,
    pub boundary_weight: f64,
    pub adam: AdamConfig,
    pub seed: u64,
}

impl Default for PinnConfig {
    fn default() -> Self {
        Self {
            interior_batch: 128,
            boundary_batch: 64,
            boundary_weight: 10.0,
            adam: AdamConfig::default(),
            seed: 0,
        }
    }
}

/// Trainer state.
pub struct PinnTrainer {
    pub problem: PdeProblem,
    pub model: Mlp,
    pub cfg: PinnConfig,
    opt: Adam,
    rng: Xoshiro256,
    boundary: BoundarySampler,
    step: usize,
}

impl PinnTrainer {
    pub fn new(problem: PdeProblem, model: Mlp, cfg: PinnConfig) -> Self {
        assert_eq!(
            model.spec.in_dim,
            problem.operator.n(),
            "model input dim must match operator dimension"
        );
        let opt = Adam::new(model.spec.param_count(), cfg.adam);
        let boundary = BoundarySampler::all_faces(BoxSampler::new(
            problem.domain.lo.clone(),
            problem.domain.hi.clone(),
        ));
        let rng = Xoshiro256::new(cfg.seed);
        Self {
            problem,
            model,
            cfg,
            opt,
            rng,
            boundary,
            step: 0,
        }
    }

    /// One optimization step; returns the losses at the sampled batch.
    pub fn train_step(&mut self) -> TrainReport {
        let graph = self.model.to_graph();
        let ldl = &self.problem.operator.ldl;
        let b_coef = self.problem.operator.b.as_deref();
        let c_coef = self.problem.operator.c;

        // ---- interior residual term -------------------------------------
        let z = self.problem.domain.sample(self.cfg.interior_batch, &mut self.rng);
        let f = self.problem.source_batch(&z);
        let tape = dof_forward_tape(&graph, ldl, b_coef, &z);
        let out = graph.output();
        let batch = self.cfg.interior_batch;
        // r_b = s^M + c·v^M − f.
        let mut resid = Tensor::zeros(&[batch, 1]);
        for b in 0..batch {
            let mut r = tape.scalars[out].at(b, 0) - f.at(b, 0);
            if let Some(c) = c_coef {
                r += c * tape.values[out].at(b, 0);
            }
            resid.set(b, 0, r);
        }
        let residual_loss = resid.norm_sq() / batch as f64;
        // Cotangents of the MSE: s̄ = 2r/B; v̄ = 2rc/B.
        let s_bar = resid.scale(2.0 / batch as f64);
        let v_bar = match c_coef {
            Some(c) => resid.scale(2.0 * c / batch as f64),
            None => Tensor::zeros(&[batch, 1]),
        };
        let grads = dof_backward_tape(&graph, ldl, &tape, &v_bar, &s_bar);
        let mut flat_grad = self.model.flat_gradient(&grads.by_linear);

        // ---- boundary/data term ------------------------------------------
        let zb = self.boundary.sample(self.cfg.boundary_batch, &mut self.rng);
        let ub = self.problem.exact_batch(&zb);
        let values = graph.eval_all(&zb);
        let pred = &values[out];
        let diff = pred.sub(&ub);
        let bb = self.cfg.boundary_batch;
        let boundary_loss = diff.norm_sq() / bb as f64;
        let seed = diff.scale(2.0 * self.cfg.boundary_weight / bb as f64);
        let bres = backward(&graph, &values, &seed, true);
        // backward's param_grads are keyed by node id; convert to Linear
        // index (Linear nodes appear in graph order).
        let linear_ids: Vec<usize> = graph
            .nodes()
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n.op, crate::graph::Op::Linear { .. }))
            .map(|(id, _)| id)
            .collect();
        let by_linear: Vec<(usize, Tensor, Vec<f64>)> = bres
            .param_grads
            .into_iter()
            .map(|(nid, gw, gb)| {
                let li = linear_ids.binary_search(&nid).expect("linear id");
                (li, gw, gb)
            })
            .collect();
        let bflat = self.model.flat_gradient(&by_linear);
        for (g, &bg) in flat_grad.iter_mut().zip(&bflat) {
            *g += bg;
        }

        // ---- update -------------------------------------------------------
        let mut params = self.model.flatten();
        self.opt.step(&mut params, &flat_grad);
        self.model.unflatten(&params);
        self.step += 1;

        TrainReport {
            step: self.step,
            residual_loss,
            boundary_loss,
            total_loss: residual_loss + self.cfg.boundary_weight * boundary_loss,
        }
    }

    /// Train `n` steps, returning the loss trace.
    pub fn run(&mut self, n: usize) -> Vec<TrainReport> {
        (0..n).map(|_| self.train_step()).collect()
    }

    /// Process-wide plan-cache counters — steady-state training is one
    /// compile (step 1) followed by hits, because plan keys hash the graph
    /// structure and weight zero patterns, not the weight values Adam
    /// moves.
    pub fn plan_stats() -> plan::PlanCacheStats {
        plan::global_cache().stats()
    }

    /// Relative L2 error of the model against `u*` on a fresh sample.
    pub fn rel_l2_error(&mut self, n_points: usize) -> f64 {
        let graph = self.model.to_graph();
        let z = self.problem.domain.sample(n_points, &mut self.rng);
        let pred = graph.eval(&z);
        let exact = self.problem.exact_batch(&z);
        pred.rel_l2_error(&exact)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Act;
    use crate::nn::MlpSpec;
    use crate::pde::problems::{heat_equation, klein_gordon, poisson};

    fn small_model(in_dim: usize) -> Mlp {
        Mlp::init(
            MlpSpec {
                in_dim,
                hidden: 24,
                layers: 2,
                out_dim: 1,
                act: Act::Tanh,
            },
            12345,
        )
    }

    #[test]
    fn poisson_loss_decreases() {
        let p = poisson(2);
        let model = small_model(2);
        let cfg = PinnConfig {
            interior_batch: 32,
            boundary_batch: 16,
            adam: AdamConfig { lr: 3e-3, ..Default::default() },
            ..Default::default()
        };
        let mut tr = PinnTrainer::new(p, model, cfg);
        let reports = tr.run(60);
        let first: f64 = reports[..5].iter().map(|r| r.total_loss).sum::<f64>() / 5.0;
        let last: f64 = reports[reports.len() - 5..]
            .iter()
            .map(|r| r.total_loss)
            .sum::<f64>()
            / 5.0;
        assert!(
            last < first * 0.7,
            "loss should drop ≥30%: first {first:.4} last {last:.4}"
        );
    }

    #[test]
    fn heat_equation_trains_through_low_rank_operator() {
        let p = heat_equation(2); // N = 3, rank 2
        let model = small_model(3);
        let mut tr = PinnTrainer::new(
            p,
            model,
            PinnConfig {
                interior_batch: 32,
                boundary_batch: 16,
                adam: AdamConfig { lr: 3e-3, ..Default::default() },
                ..Default::default()
            },
        );
        let reports = tr.run(50);
        assert!(reports.iter().all(|r| r.total_loss.is_finite()));
        let first = reports[0].total_loss;
        let last = reports.last().unwrap().total_loss;
        assert!(last < first, "first {first} last {last}");
    }

    #[test]
    fn klein_gordon_indefinite_operator_trains() {
        let p = klein_gordon(1, 1.0); // N = 2, indefinite A
        let model = small_model(2);
        let mut tr = PinnTrainer::new(
            p,
            model,
            PinnConfig {
                interior_batch: 32,
                boundary_batch: 16,
                adam: AdamConfig { lr: 3e-3, ..Default::default() },
                ..Default::default()
            },
        );
        let reports = tr.run(50);
        assert!(reports.iter().all(|r| r.total_loss.is_finite()));
        assert!(reports.last().unwrap().total_loss < reports[0].total_loss);
    }

    #[test]
    fn training_steps_hit_the_plan_cache() {
        let before = PinnTrainer::plan_stats();
        let p = poisson(2);
        let model = small_model(2);
        let mut tr = PinnTrainer::new(
            p,
            model,
            PinnConfig {
                interior_batch: 8,
                boundary_batch: 4,
                ..Default::default()
            },
        );
        tr.run(3);
        let after = PinnTrainer::plan_stats();
        // Steps 2 and 3 rebuild the graph with moved weights but must reuse
        // the step-1 program (counters are process-global, so only assert
        // the delta this trainer is guaranteed to produce).
        assert!(
            after.hits >= before.hits + 2,
            "expected ≥2 plan-cache hits from steps 2-3: {before:?} → {after:?}"
        );
    }

    #[test]
    fn rel_l2_error_reasonable_scale() {
        let p = poisson(2);
        let model = small_model(2);
        let mut tr = PinnTrainer::new(p, model, PinnConfig::default());
        let e = tr.rel_l2_error(100);
        assert!(e.is_finite() && e > 0.0);
    }
}
