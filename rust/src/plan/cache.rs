//! Keyed cache of compiled [`OperatorProgram`]s.
//!
//! Serving and training evaluate the *same* `(architecture, operator)` pair
//! over and over; the cache makes "compile once, execute per batch" the
//! default behavior of every `DofEngine::compute*` entry point without the
//! callers threading programs around. Keys are value-independent
//! ([`super::plan_key`] hashes structure and zero patterns, not weight
//! values), so a PINN training loop that rebuilds its graph each Adam step
//! hits the cache from step 2 onward.
//!
//! The mechanism — double-checked compile outside the lock, first insert
//! wins, oldest-entry eviction, hit/miss stats — is the shared
//! [`KeyedCache`] ([`crate::util::keyed_cache`]); this module only
//! contributes the key derivation and the compile closure.

use std::sync::Arc;

use crate::graph::Graph;
use crate::linalg::LdlDecomposition;
use crate::util::keyed_cache::KeyedCache;

use super::{plan_key, OperatorProgram, PlanKey, PlanOptions};

/// Bound on retained programs (oldest evicted past this).
pub const CACHE_CAP: usize = 64;

/// Hit/miss counters plus current occupancy (the shared
/// [`crate::util::CacheStats`] shape).
pub type PlanCacheStats = crate::util::CacheStats;

/// A keyed program cache (see module docs).
pub struct PlanCache {
    inner: KeyedCache<PlanKey, OperatorProgram>,
}

impl PlanCache {
    pub const fn new() -> Self {
        Self {
            inner: KeyedCache::new(CACHE_CAP),
        }
    }

    /// Fetch the program for `(graph, ldl, opts)`, compiling on first use.
    pub fn get_or_compile(
        &self,
        graph: &Graph,
        ldl: &LdlDecomposition,
        opts: PlanOptions,
    ) -> Arc<OperatorProgram> {
        let key = plan_key(graph, ldl, opts);
        self.inner
            .get_or_insert_with(key, || OperatorProgram::compile(graph, ldl, opts))
    }

    pub fn stats(&self) -> PlanCacheStats {
        self.inner.stats()
    }

    /// Drop every retained program (counters are kept).
    pub fn clear(&self) {
        self.inner.clear()
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

static GLOBAL: PlanCache = PlanCache::new();

/// The process-wide program cache used by the engines' `compute*`
/// wrappers, the serving backend, and the training tape.
pub fn global_cache() -> &'static PlanCache {
    &GLOBAL
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{builder::random_layers, mlp_graph, Act};
    use crate::tensor::Tensor;
    use crate::util::Xoshiro256;

    fn fixture(seed: u64) -> (Graph, LdlDecomposition) {
        let mut rng = Xoshiro256::new(seed);
        let g = mlp_graph(&random_layers(&[4, 7, 1], &mut rng), Act::Tanh);
        let b = Tensor::randn(&[4, 4], &mut rng);
        let a = b.add(&b.transpose()).scale(0.5);
        (g, LdlDecomposition::of(&a))
    }

    #[test]
    fn second_lookup_hits() {
        let cache = PlanCache::new();
        let (g, ldl) = fixture(9);
        let opts = PlanOptions {
            sparsity: true,
            lower_order_c: false,
        };
        let p1 = cache.get_or_compile(&g, &ldl, opts);
        let p2 = cache.get_or_compile(&g, &ldl, opts);
        assert!(Arc::ptr_eq(&p1, &p2), "same key must reuse the program");
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1));
    }

    #[test]
    fn weight_value_changes_reuse_weight_structure_changes_do_not() {
        let cache = PlanCache::new();
        let mut rng = Xoshiro256::new(10);
        let layers = random_layers(&[3, 5, 1], &mut rng);
        let layers_moved = random_layers(&[3, 5, 1], &mut rng); // same shape, new values
        let g1 = mlp_graph(&layers, Act::Tanh);
        let g2 = mlp_graph(&layers_moved, Act::Tanh);
        let g3 = mlp_graph(&random_layers(&[3, 5, 5, 1], &mut rng), Act::Tanh);
        let b = Tensor::randn(&[3, 3], &mut rng);
        let ldl = LdlDecomposition::of(&b.add(&b.transpose()).scale(0.5));
        let opts = PlanOptions {
            sparsity: true,
            lower_order_c: false,
        };
        let p1 = cache.get_or_compile(&g1, &ldl, opts);
        let p2 = cache.get_or_compile(&g2, &ldl, opts);
        let p3 = cache.get_or_compile(&g3, &ldl, opts);
        assert!(Arc::ptr_eq(&p1, &p2), "training-step weight moves must hit");
        assert!(!Arc::ptr_eq(&p1, &p3), "different topology must recompile");
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn options_partition_the_key_space() {
        let cache = PlanCache::new();
        let (g, ldl) = fixture(11);
        let a = cache.get_or_compile(
            &g,
            &ldl,
            PlanOptions {
                sparsity: true,
                lower_order_c: false,
            },
        );
        let b = cache.get_or_compile(
            &g,
            &ldl,
            PlanOptions {
                sparsity: false,
                lower_order_c: false,
            },
        );
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(cache.stats().entries, 2);
    }
}
